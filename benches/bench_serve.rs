//! Replays a serve workload trace over one warm server and writes the
//! per-job latency / throughput / reuse report to `BENCH_serve.json`.
//!
//!     cargo bench --bench bench_serve [-- WORKLOAD [OUT]]
//!
//! Defaults to the committed CI trace `config/workloads/smoke.json`.
//! With `BENCH_ASSERT_REUSE=1` the replayer additionally gates on ≥1
//! operand-cache hit, ≥1 warm workspace reuse, ≥1 exercised rejection,
//! zero rework and zero failures (bitwise repeat-run determinism is
//! always enforced). Workloads with streaming `append` jobs (e.g.
//! `config/workloads/streaming.json`) also report and gate the
//! accuracy-vs-staleness audit of each warm basis against the
//! from-scratch prefix solve.

use trunksvd::runtime::serve::{replay_file, ReplayOverrides};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).filter(|a| a != "--bench").collect();
    let workload = args
        .first()
        .cloned()
        .unwrap_or_else(|| format!("{}/config/workloads/smoke.json", env!("CARGO_MANIFEST_DIR")));
    let out = args.get(1).cloned().unwrap_or_else(|| "BENCH_serve.json".to_string());

    let s = replay_file(&workload, Some(&out), &ReplayOverrides::default()).expect("replay");
    let c = s.counters;
    println!(
        "replayed {} x{} runs in {:.3}s: {} completed, {} failed, {} rejected \
         (operand hits {}, rework {}, warm workspace reuses {}) -> {}",
        s.jobs_per_run,
        s.runs,
        s.wall_secs,
        c.completed,
        c.failed,
        c.rejected_backpressure + c.rejected_deadline,
        c.operand_hits,
        c.operand_rework,
        c.ws_warm_reuses,
        out,
    );
    if s.staleness_appends > 0 {
        println!(
            "staleness: {} append(s) audited, max rel sigma err {:.3e} (within_tolerance {})",
            s.staleness_appends, s.staleness_max_rel, s.staleness_ok,
        );
    }
    assert!(s.deterministic, "repeat runs diverged bitwise");
    assert!(s.staleness_ok, "incremental basis drifted past the staleness tolerance");
}
