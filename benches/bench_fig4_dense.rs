//! Reproduces Fig. 4: dense synthetic problems (Eq. 15/16 spectrum) —
//! residuals R1..R10 and execution time for LancSVD (r=64, p in {1,4})
//! vs RandSVD (r=16, p in {6,24}).
//!
//! `BENCH_SHRINK=4` divides the dense row counts for smoke runs.

use trunksvd::bench_support::env_usize;
use trunksvd::coordinator::experiments::{fig4, ExpOpts};
use trunksvd::gen::suite::Suite;

fn main() {
    let suite = Suite::load_default().expect("suite config");
    let o = ExpOpts { shrink: env_usize("BENCH_SHRINK", 1).max(1), ..Default::default() };
    let md = fig4(&suite, &o).expect("fig4");
    println!("{md}");
}
