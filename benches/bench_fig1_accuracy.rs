//! Reproduces Fig. 1: relative residuals R1/R10 on the sparse suite for
//! LancSVD (r=256, p=2) vs the three RandSVD configurations (b=16).
//!
//! `BENCH_SUBSET=46` runs the full suite; default is the representative
//! 8-matrix subset (1-core testbed). `BENCH_SHRINK=4` shrinks r/p.

use trunksvd::bench_support::env_usize;
use trunksvd::coordinator::experiments::{fig1, ExpOpts};
use trunksvd::gen::suite::Suite;

fn main() {
    let suite = Suite::load_default().expect("suite config");
    let o = ExpOpts {
        subset: env_usize("BENCH_SUBSET", 8),
        shrink: env_usize("BENCH_SHRINK", 1).max(1),
        ..Default::default()
    };
    let md = fig1(&suite, &o).expect("fig1");
    println!("{md}");
}
