//! Reproduces Fig. 2: execution time, per-block breakdown, and the
//! LancSVD-vs-RandSVD speed-up (measured CPU wall time + sim-A100 model
//! time; see DESIGN.md §3) on the sparse suite.

use trunksvd::bench_support::env_usize;
use trunksvd::coordinator::experiments::{fig2, ExpOpts};
use trunksvd::gen::suite::Suite;

fn main() {
    let suite = Suite::load_default().expect("suite config");
    let o = ExpOpts {
        subset: env_usize("BENCH_SUBSET", 8),
        shrink: env_usize("BENCH_SHRINK", 1).max(1),
        ..Default::default()
    };
    let md = fig2(&suite, &o).expect("fig2");
    println!("{md}");
}
