//! Validates Table 1: the analytic cost model must match the
//! instrumented flop counters of live runs block-for-block.

use trunksvd::coordinator::experiments::{table1, ExpOpts};

fn main() {
    let md = table1(&ExpOpts::default()).expect("table1");
    println!("{md}");
}
