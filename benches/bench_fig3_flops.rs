//! Reproduces Fig. 3: theoretical flop distribution across the building
//! blocks for both algorithms over the full 46-matrix suite (pure
//! Table-1 cost model — instant).

use trunksvd::coordinator::experiments::{fig3, ExpOpts};
use trunksvd::gen::suite::Suite;

fn main() {
    let suite = Suite::load_default().expect("suite config");
    let md = fig3(&suite, &ExpOpts::default()).expect("fig3");
    println!("{md}");
}
