//! Ablation benches for the design choices DESIGN.md calls out:
//!
//!  A. implicit-transpose (scatter) SpMMᵀ vs an explicit transposed CSR
//!     copy (paper §4.1.2 tried exactly this);
//!  B. LancSVD block size b at fixed r (paper §2.2 "role of b");
//!  C. Krylov width r at fixed b (role of r / k = r/b);
//!  D. initial-vector distribution (paper's centered Poisson vs normal);
//!  E. CholeskyQR2 vs Householder QR for the panel factorization
//!     (the §3.1 design decision).

use trunksvd::backend::cpu::CpuBackend;
use trunksvd::backend::{Backend, Operand};
use trunksvd::bench_support::{banner, env_usize, time_runs};
use trunksvd::coordinator::driver::{run, Algo, BackendChoice, Params};
use trunksvd::coordinator::report::sci;
use trunksvd::gen::sparse::{generate, SparseSpec};
use trunksvd::la::mat::Mat;
use trunksvd::util::rng::Rng;

fn main() {
    let quick = env_usize("BENCH_QUICK", 0) == 1;
    let rows = if quick { 6000 } else { 20_000 };
    let spec = SparseSpec {
        rows,
        cols: rows / 3,
        nnz: rows * 12,
        seed: 17,
        ..Default::default()
    };
    let a = generate(&spec);
    println!("ablation matrix: {}x{} nnz {}", a.rows(), a.cols(), a.nnz());

    banner(
        "A. SpMM-transpose strategy (full LancSVD solve)",
        "scatter baseline vs adaptive background transpose vs eager explicit copy",
    );
    for choice in [BackendChoice::CpuScatter, BackendChoice::Cpu, BackendChoice::CpuExplicitT] {
        let rep = run(
            "ablA",
            Operand::sparse(a.clone()),
            Algo::Lanc,
            &Params { r: 64, p: 2, b: 16, ..Default::default() },
            &choice,
        )
        .unwrap();
        println!(
            "{:<12} total {:>7.3}s  mult_At {:>7.3}s  R10 {}",
            choice.name(),
            rep.secs,
            rep.profile.stat(trunksvd::metrics::Block::MultAt).secs,
            sci(rep.max_residual())
        );
    }

    banner("B. LancSVD block size b (r=64, p=2)", "paper: larger b → more efficient blocks, fewer Krylov steps");
    for b in [4usize, 8, 16, 32] {
        let rep = run(
            "ablB",
            Operand::sparse(a.clone()),
            Algo::Lanc,
            &Params { r: 64, p: 2, b, wanted: 4, ..Default::default() },
            &BackendChoice::Cpu,
        )
        .unwrap();
        println!("b={b:<3} time {:>7.3}s  R4 {}", rep.secs, sci(rep.max_residual()));
    }

    banner("C. Krylov width r (b=16, p=2)", "paper: larger r converges better but orth cost grows super-linearly");
    for r in [32usize, 64, 128, 256] {
        if r > a.cols() {
            continue;
        }
        let rep = run(
            "ablC",
            Operand::sparse(a.clone()),
            Algo::Lanc,
            &Params { r, p: 2, b: 16, ..Default::default() },
            &BackendChoice::Cpu,
        )
        .unwrap();
        println!("r={r:<4} time {:>7.3}s  R10 {}", rep.secs, sci(rep.max_residual()));
    }

    banner("D. Initial distribution (RandSVD r=16 p=24)", "");
    for (label, init) in [
        ("poisson", trunksvd::algo::InitDist::CenteredPoisson),
        ("normal", trunksvd::algo::InitDist::Normal),
    ] {
        let mut be = CpuBackend::new_sparse(a.clone());
        let t0 = std::time::Instant::now();
        let svd = trunksvd::algo::randsvd::randsvd(
            &mut be,
            &trunksvd::algo::RandSvdOpts { r: 16, p: 24, b: 16, seed: 5, init, fuse: None },
        )
        .unwrap();
        let mut chk = CpuBackend::new_sparse(a.clone());
        let res = trunksvd::algo::residuals(&mut chk, &svd, 10);
        println!(
            "{label:<8} time {:>6.3}s  R10 {}",
            t0.elapsed().as_secs_f64(),
            sci(res.iter().fold(0.0f64, |m, &x| m.max(x)))
        );
    }

    banner("F. Restart strategy: basic vs thick (LancSVD r=64 p=3)", "");
    for (label, restart) in [
        ("basic", trunksvd::algo::Restart::Basic),
        ("thick32", trunksvd::algo::Restart::Thick { keep: 32 }),
    ] {
        let rep = run(
            "ablF",
            Operand::sparse(a.clone()),
            Algo::Lanc,
            &Params { r: 64, p: 3, b: 16, restart, ..Default::default() },
            &BackendChoice::Cpu,
        )
        .unwrap();
        println!(
            "{label:<8} time {:>7.3}s  flops {:>8.2} GF  R10 {}",
            rep.secs,
            rep.profile.total_flops() / 1e9,
            sci(rep.max_residual())
        );
    }

    banner("E. Panel factorization: CholeskyQR2 vs Householder (q x 16)", "");
    let mut rng = Rng::new(9);
    let q = if quick { 8192 } else { 32768 };
    let y0: Mat<f64> = Mat::randn(q, 16, &mut rng);
    let mut be: CpuBackend = CpuBackend::new_dense(Mat::zeros(1, 1));
    let st = time_runs(1, 5, || {
        let mut y = y0.clone();
        be.orth_cholqr2(&mut y).unwrap();
    });
    println!("cholqr2     q={q}  {:.4}s", st.median);
    let st = time_runs(1, 5, || {
        let _ = trunksvd::la::qr::householder_qr(&y0);
    });
    println!("householder q={q}  {:.4}s", st.median);

    println!("\nbench_ablation done");
}
