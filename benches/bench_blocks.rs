//! Micro-benchmarks of the Table-1 building blocks (GEMM, SpMM, SpMMᵀ,
//! CholeskyQR2, CGS-CQR2) on the CPU substrate and, when artifacts are
//! present, on the XLA/PJRT path. Feeds the §Perf iteration log.
//!
//! The threaded-kernels section reports serial-vs-parallel speedup and
//! effective GF/s for `spmm`, `spmm_t` (scatter and cached transpose),
//! `gram`, and the Block-ELL SpMM — **at both element precisions** (the
//! paper's GPU runs are fp32; these kernels are bandwidth-bound, so f32
//! should approach 2× f64 throughput) — and records everything to
//! `BENCH_kernels.json` (each entry carries a `dtype` field) so the perf
//! trajectory is tracked PR-over-PR.
//!
//! The `pool_dispatch` section measures the empty-job round-trip latency
//! of the persistent worker pool against the PR 1 spawn-per-call
//! baseline (`pool_dispatch_ns` vs `spawn_dispatch_ns` in the JSON);
//! with `BENCH_ASSERT_DISPATCH=1` (set in CI) the bench *fails* unless
//! the persistent pool dispatches faster than spawning.
//!
//! The `alloc_probe` section pins the workspace refactor's contract in
//! CI: this binary installs the counting global allocator and measures
//! the heap-allocation delta between RandSVD solves that differ only in
//! power-iteration count — `alloc_bytes_per_iter` must be zero in
//! steady state — plus the peak RSS (`VmHWM`). `BENCH_ASSERT_NOALLOC=1`
//! (set in CI) turns the zero-allocation check into a hard failure.
//!
//! The SIMD section times the scalar reference (`TRUNKSVD_SIMD=off`
//! equivalent) against the detected ISA path for the serial spmm, gram
//! and Block-ELL kernels at both precisions, recording `*_simd_speedup`
//! entries; `BENCH_ASSERT_SIMD=1` (set in CI) fails the bench if the
//! SIMD path is slower than scalar on spmm/gram.
//!
//! The `out_of_core` section shards a generated operand to disk, solves
//! it under a resident-bytes cap tight enough that every pass re-streams
//! the whole operand, and records the three-tier transfer picture:
//! disk-tier bytes/loads from the staged ledger, host↔arena bytes,
//! `overlap_efficiency` (1 − stall/load) from the prefetch pipeline, the
//! sharded-vs-in-core slowdown, and bitwise sigma parity against the
//! scatter-only in-core solve. `BENCH_ASSERT_OVERLAP=1` (set in CI)
//! gates parity, overlap, cap enforcement, and exactly-once disk
//! accounting.
//!
//! The `fused` section times the one-sweep fused kernels (`spmm_ata`,
//! `spmm_gram`) against their two-kernel compositions on an
//! over-LLC operand, and replays a sharded RandSVD power solve fused vs
//! unfused to read the disk-tier byte drop off the staged ledger
//! (deterministically 2p/(p+1)). `BENCH_ASSERT_FUSED=1` (set in CI)
//! gates fused-not-slower (full size only) and a ≥1.8× disk-byte drop.
//!
//! The `cost_calibration` section measures the real dispatch-grain and
//! adaptive-transpose crossovers on this host and emits them in the
//! layout `cost::load_calibration` reads — point
//! `TRUNKSVD_COST_CALIB=BENCH_kernels.json` at the output to replace the
//! desk-estimate constants. `--calibrate` adds a finer k-sweep array.
//!
//! `BENCH_QUICK=1` (or the `--smoke` flag) shrinks the size sweep.

use std::rc::Rc;

use trunksvd::backend::cpu::CpuBackend;
use trunksvd::backend::xla::XlaBackend;
use trunksvd::backend::Backend;
use trunksvd::bench_support::{auto_runs, banner, env_usize, gflops, peak_rss_kb, time_runs};
use trunksvd::gen::sparse::{generate, SparseSpec};
use trunksvd::la::blas3;
use trunksvd::la::mat::Mat;
use trunksvd::la::qr::random_orthonormal;
use trunksvd::runtime::{default_artifact_dir, Runtime};
use trunksvd::sparse::blockell::BlockEll;
use trunksvd::util::counting_alloc::{self, CountingAllocator};
use trunksvd::util::json::{self, Json};
use trunksvd::util::pool;
use trunksvd::util::rng::Rng;
use trunksvd::util::scalar::Scalar;

#[global_allocator]
static ALLOC: CountingAllocator = CountingAllocator;

/// Print one serial-vs-parallel comparison and record it as JSON.
#[allow(clippy::too_many_arguments)]
fn kernel_entry(
    entries: &mut Vec<Json>,
    kernel: &str,
    dtype: &str,
    m: usize,
    b: usize,
    threads: usize,
    serial: f64,
    parallel: f64,
    flops: f64,
) {
    let speedup = serial / parallel;
    println!(
        "{kernel:<16} {dtype} m={m:>6} b={b:>3}  serial {serial:>8.4}s  par({threads}) \
         {parallel:>8.4}s  speedup {speedup:>5.2}x  {:>7.2} GF/s",
        gflops(flops, parallel)
    );
    entries.push(json::obj(vec![
        ("kernel", json::str(kernel)),
        ("dtype", json::str(dtype)),
        ("m", json::num(m as f64)),
        ("b", json::num(b as f64)),
        ("threads", json::num(threads as f64)),
        ("serial_s", json::num(serial)),
        ("parallel_s", json::num(parallel)),
        ("speedup", json::num(speedup)),
        ("gflops_parallel", json::num(gflops(flops, parallel))),
    ]));
}

/// Scalar-reference vs detected-ISA timing for one serial kernel: emits
/// a `*_simd_speedup` entry, and with `assert_fast` (BENCH_ASSERT_SIMD=1)
/// enforces that the SIMD path is not slower than the scalar reference.
/// Min-of-runs timing plus up-to-5 retries (keeping the best ratio, early
/// exit at >= 1.0) make the gate robust to scheduler noise — the scalar
/// reference is itself lane-blocked and auto-vectorizes, so the two paths
/// can be genuinely close on some kernels.
#[allow(clippy::too_many_arguments)]
fn simd_speedup_entry(
    entries: &mut Vec<Json>,
    kernel: &str,
    dtype: &str,
    m: usize,
    b: usize,
    fl: f64,
    assert_fast: bool,
    mut f: impl FnMut(),
) {
    use trunksvd::util::simd::{self, SimdLevel};
    let detected = simd::detected_level();
    let (w, r) = auto_runs(fl / 1e9);
    let attempts = if assert_fast { 5 } else { 1 };
    let (mut best, mut t_off, mut t_on) = (0.0f64, f64::INFINITY, f64::INFINITY);
    for _ in 0..attempts {
        simd::set_level(Some(SimdLevel::Off));
        let off = time_runs(w, r, &mut f);
        simd::set_level(Some(detected));
        let on = time_runs(w, r, &mut f);
        let ratio = off.min / on.min.max(1e-12);
        if ratio > best {
            best = ratio;
            t_off = off.min;
            t_on = on.min;
        }
        if best >= 1.0 {
            break;
        }
    }
    simd::set_level(None);
    println!(
        "{kernel:<16} {dtype} m={m:>6} b={b:>3}  scalar {t_off:>9.5}s  {:<5} {t_on:>9.5}s  \
         simd/scalar {best:>5.2}x",
        detected.name()
    );
    entries.push(json::obj(vec![
        ("kernel", json::str(format!("{kernel}_simd_speedup"))),
        ("dtype", json::str(dtype)),
        ("m", json::num(m as f64)),
        ("b", json::num(b as f64)),
        ("threads", json::num(1.0)),
        ("simd_level", json::str(detected.name())),
        ("scalar_s", json::num(t_off)),
        ("simd_s", json::num(t_on)),
        ("simd_over_scalar", json::num(best)),
    ]));
    if assert_fast && detected != SimdLevel::Off {
        assert!(
            best >= 1.0,
            "{kernel} {dtype}: SIMD path ({t_on:.5}s) must not be slower than the scalar \
             reference ({t_off:.5}s) — ratio {best:.3}"
        );
    }
}

/// SIMD-vs-scalar sweep at one element precision. Serial (1 thread) so
/// the measurement isolates the microkernel effect from band fan-out.
fn bench_simd_kernels<S: Scalar>(entries: &mut Vec<Json>, quick: bool, gate: bool) {
    let mut rng = Rng::new(41);
    let m = if quick { 8192 } else { 32768 };
    let b = 8usize;
    let spec = SparseSpec { rows: m, cols: m / 4, nnz: m * 25, seed: 41, ..Default::default() };
    let a: trunksvd::Csr<S> = generate(&spec).cast();
    pool::set_num_threads(1);
    {
        let fl = 2.0 * a.nnz() as f64 * b as f64;
        let x: Mat<S> = Mat::randn(a.cols(), b, &mut rng);
        let mut y: Mat<S> = Mat::zeros(a.rows(), b);
        simd_speedup_entry(entries, "spmm", S::DTYPE, m, b, fl, gate, || {
            a.spmm(x.as_ref(), y.as_mut())
        });
    }
    {
        let q: Mat<S> = Mat::randn(m, b, &mut rng);
        let flg = (b * b) as f64 * m as f64;
        simd_speedup_entry(entries, "gram", S::DTYPE, m, b, flg, gate, || {
            let _ = blas3::gram(q.as_ref());
        });
    }
    {
        let m3 = if quick { 4096 } else { 8192 };
        let spec3 = SparseSpec {
            rows: m3,
            cols: m3 / 4,
            nnz: m3 * 6,
            seed: 7,
            skew: 0.2,
            ..Default::default()
        };
        let a3: trunksvd::Csr<S> = generate(&spec3).cast();
        let be = BlockEll::from_csr_auto(&a3, 16);
        let fl3 = 2.0 * a3.nnz() as f64 * b as f64;
        let x: Mat<S> = Mat::randn(be.padded_cols(), b, &mut rng);
        let mut y: Mat<S> = Mat::zeros(be.padded_rows(), b);
        simd_speedup_entry(entries, "blockell_spmm", S::DTYPE, m3, b, fl3, false, || {
            be.spmm(x.as_ref(), y.as_mut())
        });
    }
    pool::set_num_threads(0);
}

/// Threaded sparse/Gram kernel sweep at one element precision. Returns
/// `(kernel, m, b, parallel_median_secs)` so the caller can report the
/// f32-vs-f64 bandwidth win keyed by problem size.
fn bench_threaded_kernels<S: Scalar>(
    entries: &mut Vec<Json>,
    quick: bool,
    threads: usize,
) -> Vec<(String, usize, usize, f64)> {
    let mut rng = Rng::new(17);
    let mut medians = Vec::new();
    let m2 = if quick { 8192 } else { 32768 };
    let n2 = m2 / 4;
    let spec2 = SparseSpec { rows: m2, cols: n2, nnz: m2 * 25, seed: 5, ..Default::default() };
    let a2: trunksvd::Csr<S> = generate(&spec2).cast();
    let at2 = a2.transpose();
    for &b in &[8usize, 16] {
        let fl = 2.0 * a2.nnz() as f64 * b as f64;
        let (w, r) = auto_runs(fl / 1e9);
        // spmm (gather, row-band parallel)
        let x: Mat<S> = Mat::randn(n2, b, &mut rng);
        let mut y: Mat<S> = Mat::zeros(m2, b);
        pool::set_num_threads(1);
        let s1 = time_runs(w, r, || a2.spmm(x.as_ref(), y.as_mut()));
        pool::set_num_threads(threads);
        let sp = time_runs(w, r, || a2.spmm(x.as_ref(), y.as_mut()));
        kernel_entry(entries, "spmm", S::DTYPE, m2, b, threads, s1.median, sp.median, fl);
        medians.push(("spmm".to_string(), m2, b, sp.median));
        // spmm_t: scatter vs cached explicit transpose
        let xm: Mat<S> = Mat::randn(m2, b, &mut rng);
        let mut yn: Mat<S> = Mat::zeros(n2, b);
        pool::set_num_threads(1);
        let t1 = time_runs(w, r, || a2.spmm_t(xm.as_ref(), yn.as_mut()));
        pool::set_num_threads(threads);
        let tp = time_runs(w, r, || a2.spmm_t(xm.as_ref(), yn.as_mut()));
        kernel_entry(entries, "spmm_t_scatter", S::DTYPE, m2, b, threads, t1.median, tp.median, fl);
        medians.push(("spmm_t_scatter".to_string(), m2, b, tp.median));
        pool::set_num_threads(1);
        let e1 = time_runs(w, r, || at2.spmm(xm.as_ref(), yn.as_mut()));
        pool::set_num_threads(threads);
        let ep = time_runs(w, r, || at2.spmm(xm.as_ref(), yn.as_mut()));
        kernel_entry(entries, "spmm_t_cachedT", S::DTYPE, m2, b, threads, e1.median, ep.median, fl);
        medians.push(("spmm_t_cachedT".to_string(), m2, b, ep.median));
        // gram (row-tiled parallel SYRK)
        let q: Mat<S> = Mat::randn(m2, b, &mut rng);
        let flg = (b * b) as f64 * m2 as f64;
        let (wg, rg) = auto_runs(flg / 2e9);
        pool::set_num_threads(1);
        let g1 = time_runs(wg, rg, || {
            let _ = blas3::gram(q.as_ref());
        });
        pool::set_num_threads(threads);
        let gp = time_runs(wg, rg, || {
            let _ = blas3::gram(q.as_ref());
        });
        kernel_entry(entries, "gram", S::DTYPE, m2, b, threads, g1.median, gp.median, flg);
        medians.push(("gram".to_string(), m2, b, gp.median));
    }
    // Block-ELL SpMM on a smaller, low-skew panel (ELL padding makes a
    // big skewed random matrix memory-hungry), with the width cap at ncb
    // so the conversion cannot fail and this arm always produces data.
    let m3 = if quick { 4096 } else { 8192 };
    let spec3 = SparseSpec {
        rows: m3,
        cols: m3 / 4,
        nnz: m3 * 6,
        seed: 7,
        skew: 0.2,
        ..Default::default()
    };
    let a3: trunksvd::Csr<S> = generate(&spec3).cast();
    let ncb3 = a3.cols().div_ceil(16);
    match BlockEll::from_csr(&a3, 16, ncb3) {
        Ok(be) => {
            for &b in &[8usize, 16] {
                let fl = 2.0 * a3.nnz() as f64 * b as f64;
                let (w, r) = auto_runs(fl / 1e9);
                let xp: Mat<S> = Mat::randn(be.padded_cols(), b, &mut rng);
                let mut yp: Mat<S> = Mat::zeros(be.padded_rows(), b);
                pool::set_num_threads(1);
                let b1 = time_runs(w, r, || be.spmm(xp.as_ref(), yp.as_mut()));
                pool::set_num_threads(threads);
                let bp = time_runs(w, r, || be.spmm(xp.as_ref(), yp.as_mut()));
                kernel_entry(
                    entries,
                    "blockell_spmm",
                    S::DTYPE,
                    m3,
                    b,
                    threads,
                    b1.median,
                    bp.median,
                    fl,
                );
                medians.push(("blockell_spmm".to_string(), m3, b, bp.median));
            }
        }
        Err(e) => println!("blockell_spmm skipped: {e}"),
    }
    pool::set_num_threads(0);
    medians
}

fn main() {
    let quick = env_usize("BENCH_QUICK", 0) == 1
        || std::env::args().any(|a| a == "--smoke");
    let mut rng = Rng::new(1);

    banner("GEMM (C = A·B, k=512, n=16)", "m, GFLOP/s");
    let ms: &[usize] = if quick { &[4096] } else { &[2048, 8192, 32768] };
    for &m in ms {
        let a = Mat::randn(m, 512, &mut rng);
        let b = Mat::randn(512, 16, &mut rng);
        let mut c = Mat::zeros(m, 16);
        let fl = 2.0 * (m * 512 * 16) as f64;
        let (w, r) = auto_runs(fl / 2e9);
        let st = time_runs(w, r, || blas3::gemm_nn(1.0, a.as_ref(), b.as_ref(), 0.0, c.as_mut()));
        println!("gemm_nn  m={m:>6}  {:.2} GF/s ({:.4}s)", gflops(fl, st.median), st.median);
        let mut h = Mat::zeros(512, 16);
        let x = Mat::randn(m, 16, &mut rng);
        let st = time_runs(w, r, || blas3::gemm_tn(1.0, a.as_ref(), x.as_ref(), 0.0, h.as_mut()));
        println!("gemm_tn  m={m:>6}  {:.2} GF/s ({:.4}s)", gflops(fl, st.median), st.median);
    }

    banner("SpMM vs SpMMᵀ (k=16)", "the paper's bottleneck asymmetry");
    let spec = SparseSpec {
        rows: if quick { 8192 } else { 32768 },
        cols: 8192,
        nnz: if quick { 200_000 } else { 800_000 },
        seed: 3,
        ..Default::default()
    };
    let a = generate(&spec);
    let at = a.transpose();
    let x_n = Mat::randn(a.cols(), 16, &mut rng);
    let x_m = Mat::randn(a.rows(), 16, &mut rng);
    let fl = 2.0 * a.nnz() as f64 * 16.0;
    let mut y_m = Mat::zeros(a.rows(), 16);
    let mut y_n = Mat::zeros(a.cols(), 16);
    let (w, r) = auto_runs(fl / 1e9);
    let st = time_runs(w, r, || a.spmm(x_n.as_ref(), y_m.as_mut()));
    println!("spmm   (gather)    {:.2} GF/s ({:.4}s)", gflops(fl, st.median), st.median);
    let st_t = time_runs(w, r, || a.spmm_t(x_m.as_ref(), y_n.as_mut()));
    println!("spmm_t (scatter)   {:.2} GF/s ({:.4}s)", gflops(fl, st_t.median), st_t.median);
    let st_e = time_runs(w, r, || at.spmm(x_m.as_ref(), y_n.as_mut()));
    println!("spmm_t (expl. T)   {:.2} GF/s ({:.4}s)", gflops(fl, st_e.median), st_e.median);

    banner(
        "Threaded kernels: serial vs parallel, f64 and f32",
        "paper-scale panels; results recorded to BENCH_kernels.json",
    );
    let threads = pool::num_threads();
    let mut entries: Vec<Json> = Vec::new();
    let med64 = bench_threaded_kernels::<f64>(&mut entries, quick, threads);
    let med32 = bench_threaded_kernels::<f32>(&mut entries, quick, threads);
    // The headline number: fp32 bandwidth win over fp64 per kernel (the
    // paper's single-precision regime; expect ≥1.5× on the bandwidth-
    // bound spmm/gram at full thread count).
    println!("\nfp32 speedup over fp64 (parallel medians):");
    for ((k64, m64, b64, t64), (_k32, _m32, _b32, t32)) in med64.iter().zip(&med32) {
        let ratio = t64 / t32.max(1e-12);
        println!("  {k64:<16} m={m64:>6} b={b64:>3}  f64/f32 = {ratio:>5.2}x");
        entries.push(json::obj(vec![
            ("kernel", json::str(format!("{k64}_f32_speedup"))),
            ("dtype", json::str("f64/f32")),
            ("m", json::num(*m64 as f64)),
            ("b", json::num(*b64 as f64)),
            ("threads", json::num(threads as f64)),
            ("f64_s", json::num(*t64)),
            ("f32_s", json::num(*t32)),
            ("f64_over_f32", json::num(ratio)),
        ]));
    }
    banner(
        "SIMD microkernels: scalar reference vs detected ISA",
        "serial, 1 thread; BENCH_ASSERT_SIMD=1 gates spmm/gram >= 1.0x",
    );
    let simd_gate = env_usize("BENCH_ASSERT_SIMD", 0) == 1;
    bench_simd_kernels::<f64>(&mut entries, quick, simd_gate);
    bench_simd_kernels::<f32>(&mut entries, quick, simd_gate);

    banner(
        "Pool dispatch (empty-job round trip)",
        "persistent workers vs the spawn-per-call baseline",
    );
    let pool_dispatch_ns: f64;
    {
        use std::sync::atomic::{AtomicUsize, Ordering};
        // Dispatch needs >= 2 bands to involve the pool at all; pin the
        // band count so a TRUNKSVD_THREADS=1 run still measures dispatch.
        let tb = threads.max(2);
        pool::set_num_threads(tb);
        let sink = AtomicUsize::new(0);
        let dispatch_iters = if quick { 2_000 } else { 10_000 };
        // Warm call: spawns the persistent workers once, outside timing.
        pool::parallel_for(tb, |_| {
            sink.fetch_add(1, Ordering::Relaxed);
        });
        let t0 = std::time::Instant::now();
        for _ in 0..dispatch_iters {
            pool::parallel_for(tb, |w| {
                if w == 0 {
                    sink.fetch_add(1, Ordering::Relaxed);
                }
            });
        }
        let pool_ns = t0.elapsed().as_secs_f64() * 1e9 / dispatch_iters as f64;
        // The spawn path is ~10× slower; fewer iterations keep the bench
        // bounded without hurting the comparison.
        let spawn_iters = (dispatch_iters / 10).max(100);
        let t0 = std::time::Instant::now();
        for _ in 0..spawn_iters {
            pool::parallel_for_spawn_baseline(tb, |w| {
                if w == 0 {
                    sink.fetch_add(1, Ordering::Relaxed);
                }
            });
        }
        let spawn_ns = t0.elapsed().as_secs_f64() * 1e9 / spawn_iters as f64;
        pool::set_num_threads(0);
        pool_dispatch_ns = pool_ns;
        let ratio = spawn_ns / pool_ns.max(1.0);
        println!(
            "pool_dispatch    t={tb}  persistent {pool_ns:>9.0} ns/call  \
             spawn {spawn_ns:>9.0} ns/call  spawn/pool {ratio:>5.1}x  \
             (sink {})",
            sink.load(Ordering::Relaxed)
        );
        entries.push(json::obj(vec![
            ("kernel", json::str("pool_dispatch")),
            ("dtype", json::str("n/a")),
            ("threads", json::num(tb as f64)),
            ("pool_dispatch_ns", json::num(pool_ns)),
            ("spawn_dispatch_ns", json::num(spawn_ns)),
            ("spawn_over_pool", json::num(ratio)),
        ]));
        if env_usize("BENCH_ASSERT_DISPATCH", 0) == 1 {
            assert!(
                pool_ns < spawn_ns,
                "persistent pool dispatch ({pool_ns:.0} ns/call) must beat \
                 spawn-per-call ({spawn_ns:.0} ns/call)"
            );
        }
    }

    banner(
        "Allocation probe (steady-state inner iterations)",
        "alloc delta between p and p+10 RandSVD solves sharing one workspace; \
         zero bytes/iter is the workspace contract (BENCH_ASSERT_NOALLOC=1 gates it)",
    );
    {
        use trunksvd::algo::randsvd::randsvd_with;
        use trunksvd::algo::RandSvdOpts;
        use trunksvd::la::workspace::{Plan, Workspace};
        // Pin to one thread: every kernel takes its serial fast path on
        // this thread, so the thread-local counters see the whole solve
        // (parallel dispatch bookkeeping is measured by pool_dispatch,
        // not here — the contract is about per-iteration buffer churn).
        pool::set_num_threads(1);
        let rows = if quick { 2000 } else { 8000 };
        let spec = SparseSpec {
            rows,
            cols: rows / 4,
            nnz: rows * 10,
            seed: 23,
            ..Default::default()
        };
        let a = generate(&spec);
        let (m, n) = (a.rows(), a.cols());
        let (r, b) = (16usize, 8usize);
        let ws: Workspace = Workspace::new(Plan::randsvd(m, n, r, 16, b));
        let run_solve = |p: usize| -> (u64, u64) {
            let mut be = CpuBackend::new_sparse(a.clone()).scatter_only();
            let c0 = counting_alloc::thread_allocs();
            let b0 = counting_alloc::thread_alloc_bytes();
            let svd = randsvd_with(
                &mut be,
                &RandSvdOpts { r, p, b, seed: 5, ..Default::default() },
                &ws,
            )
            .expect("alloc-probe solve");
            assert_eq!(svd.iters, p);
            (
                counting_alloc::thread_allocs() - c0,
                counting_alloc::thread_alloc_bytes() - b0,
            )
        };
        let _ = run_solve(2); // warm lazy statics off-window
        let extra_iters = 10u64;
        let (c_lo, by_lo) = run_solve(3);
        let (c_hi, by_hi) = run_solve(3 + extra_iters as usize);
        pool::set_num_threads(0);
        let d_allocs = c_hi.saturating_sub(c_lo);
        let d_bytes = by_hi.saturating_sub(by_lo);
        let allocs_per_iter = d_allocs as f64 / extra_iters as f64;
        let alloc_bytes_per_iter = d_bytes as f64 / extra_iters as f64;
        let rss = peak_rss_kb();
        println!(
            "alloc_probe      m={m:>6} r={r} b={b}  allocs/iter {allocs_per_iter:>6.1}  \
             bytes/iter {alloc_bytes_per_iter:>8.0}  peak_rss {rss} kB"
        );
        entries.push(json::obj(vec![
            ("kernel", json::str("alloc_probe")),
            ("dtype", json::str("f64")),
            ("m", json::num(m as f64)),
            ("b", json::num(b as f64)),
            ("threads", json::num(1.0)),
            ("allocs_per_iter", json::num(allocs_per_iter)),
            ("alloc_bytes_per_iter", json::num(alloc_bytes_per_iter)),
            ("peak_rss_kb", json::num(rss as f64)),
        ]));
        if env_usize("BENCH_ASSERT_NOALLOC", 0) == 1 {
            assert_eq!(
                (d_allocs, d_bytes),
                (0, 0),
                "steady-state inner iterations must not allocate \
                 ({d_allocs} allocs / {d_bytes} bytes across {extra_iters} extra iterations)"
            );
        }
    }

    banner(
        "Staged-backend transfer ledger (device-contract simulation)",
        "host<->arena crossings per solve; zero hot-loop panel transfers is the \
         contract (BENCH_ASSERT_NOTRANSFER=1 gates it)",
    );
    {
        use trunksvd::algo::lancsvd::lancsvd;
        use trunksvd::algo::LancSvdOpts;
        use trunksvd::backend::staged::StagedBackend;
        let rows = if quick { 2000 } else { 8000 };
        let spec = SparseSpec {
            rows,
            cols: rows / 4,
            nnz: rows * 10,
            seed: 29,
            ..Default::default()
        };
        let a = generate(&spec);
        let (r, b, p) = (16usize, 8usize, 3usize);
        // Two solves differing only in restart count isolate the
        // per-outer-iteration crossing cost, exactly like alloc_probe
        // isolates per-iteration allocations.
        let solve = |p: usize| {
            let mut be = StagedBackend::new_sparse(a.clone());
            let opts = LancSvdOpts { r, p, b, wanted: 8, seed: 7, ..Default::default() };
            lancsvd(&mut be, &opts).expect("staged ledger solve");
            (be.ledger().totals(), be.device_format().unwrap_or("?"))
        };
        let (t_lo, fmt) = solve(p);
        let (t_hi, _) = solve(p + 2);
        let d_cross = t_hi.hot_factor_crossings - t_lo.hot_factor_crossings;
        let d_bytes = t_hi.hot_factor_bytes - t_lo.hot_factor_bytes;
        let cross_per_iter = d_cross as f64 / 2.0;
        let bytes_per_iter = d_bytes as f64 / 2.0;
        println!(
            "staged_ledger    m={rows:>6} r={r} b={b} fmt={fmt}  hot_panel {}  \
             factor_crossings/iter {cross_per_iter:>6.1}  factor_bytes/iter {bytes_per_iter:>8.0}  \
             staged_operand {} B  arena_memcpy {} B",
            t_hi.hot_panel_transfers, t_hi.staged_operand_bytes, t_hi.a2a_bytes
        );
        entries.push(json::obj(vec![
            ("kernel", json::str("staged_ledger")),
            ("dtype", json::str("f64")),
            ("m", json::num(rows as f64)),
            ("b", json::num(b as f64)),
            ("threads", json::num(threads as f64)),
            ("device_format", json::str(fmt)),
            ("hot_panel_transfers", json::num(t_hi.hot_panel_transfers as f64)),
            ("hot_factor_crossings_per_iter", json::num(cross_per_iter)),
            ("hot_factor_bytes_per_iter", json::num(bytes_per_iter)),
            ("h2a_bytes", json::num(t_hi.h2a_bytes as f64)),
            ("a2h_bytes", json::num(t_hi.a2h_bytes as f64)),
            ("a2a_bytes", json::num(t_hi.a2a_bytes as f64)),
            ("staged_operand_bytes", json::num(t_hi.staged_operand_bytes as f64)),
        ]));
        if env_usize("BENCH_ASSERT_NOTRANSFER", 0) == 1 {
            assert_eq!(
                (t_lo.hot_panel_transfers, t_hi.hot_panel_transfers),
                (0, 0),
                "staged backend must perform zero hot-loop panel transfers"
            );
            assert!(
                d_cross > 0 && d_cross % 2 == 0,
                "factor crossings must be constant per outer iteration (delta {d_cross})"
            );
        }
    }

    banner(
        "Out-of-core sharded operand (double-buffered prefetch)",
        "disk-tier bytes per pass, overlap efficiency, sharded-vs-in-core parity \
         and slowdown (BENCH_ASSERT_OVERLAP=1 gates overlap + parity + accounting)",
    );
    let ooc_section = {
        use std::sync::Arc;
        use trunksvd::algo::lancsvd::lancsvd;
        use trunksvd::algo::LancSvdOpts;
        use trunksvd::backend::staged::StagedBackend;
        use trunksvd::backend::Operand;
        use trunksvd::sparse::shard;

        let rows = if quick { 4000 } else { 16000 };
        let spec = SparseSpec {
            rows,
            cols: rows / 4,
            nnz: rows * 12,
            seed: 31,
            ..Default::default()
        };
        let a = generate(&spec);
        let dir_path = std::env::temp_dir().join("trunksvd_bench_shards");
        let _ = std::fs::remove_dir_all(&dir_path);
        let dirs = dir_path.to_str().expect("utf8 temp path").to_string();
        let n_shards = 6usize;
        let sd = Arc::new(shard::write_shards_from_csr(&dirs, &a, n_shards).expect("write shards"));
        // The tightest cap that still runs the prefetch pipeline: two
        // streaming slots, zero pinned prefix — every pass re-streams
        // the whole operand from disk, so overlap is actually exercised.
        let cap = 2 * sd.max_resident_bytes::<f64>();
        let opts = LancSvdOpts { r: 16, p: 3, b: 8, wanted: 8, seed: 7, ..Default::default() };

        // In-core reference: the scatter-only CPU backend is the bitwise
        // parity anchor (sharded Aᵀ·X is a global-row-order scatter).
        let mut be_in = CpuBackend::new_sparse(a.clone()).scatter_only();
        let t0 = std::time::Instant::now();
        let svd_in = lancsvd(&mut be_in, &opts).expect("in-core solve");
        let t_incore = t0.elapsed().as_secs_f64();

        // Sharded CPU solve under the cap.
        let mut be_sh = CpuBackend::new(Operand::sharded(Arc::clone(&sd), cap));
        be_sh.ensure_operand_resident().expect("shard manifest resolves under cap");
        let t0 = std::time::Instant::now();
        let svd_sh = lancsvd(&mut be_sh, &opts).expect("sharded solve");
        let t_sharded = t0.elapsed().as_secs_f64();
        let stats = be_sh.shard_stats().expect("sharded backend has stats");
        let slowdown = t_sharded / t_incore.max(1e-12);
        let overlap = stats.overlap_efficiency();
        let parity = svd_in.sigma.len() == svd_sh.sigma.len()
            && svd_in
                .sigma
                .iter()
                .zip(&svd_sh.sigma)
                .all(|(x, y)| x.to_bits() == y.to_bits());

        // Staged sharded solve: the three-tier ledger (disk↔host↔arena).
        let mut be_st: StagedBackend = StagedBackend::new_sharded(Arc::clone(&sd), cap);
        be_st.ensure_operand_resident().expect("staged shard staging");
        lancsvd(&mut be_st, &opts).expect("staged sharded solve");
        let totals = be_st.ledger().totals();
        let st_stats = be_st.shard_stats().expect("staged sharded stats");
        let file_bytes = sd.total_file_bytes();

        println!(
            "out_of_core      m={rows:>6} shards={n_shards} cap={cap}  passes {}  \
             stream {} B  overlap {overlap:>5.2}  peak {} B  slowdown {slowdown:>5.2}x  \
             parity {}  disk(ledger) {} B in {} loads  hot_panel {}",
            stats.passes,
            stats.stream_bytes,
            stats.peak_resident_bytes,
            if parity { "ok" } else { "MISMATCH" },
            totals.disk_bytes,
            totals.disk_count,
            totals.hot_panel_transfers
        );
        if env_usize("BENCH_ASSERT_OVERLAP", 0) == 1 {
            assert!(parity, "sharded sigma must be bitwise-identical to the in-core solve");
            assert!(
                overlap >= 0.25,
                "prefetch must hide most of the shard I/O (overlap {overlap:.2})"
            );
            assert!(
                stats.peak_resident_bytes <= cap,
                "resident cap violated: peak {} > cap {cap}",
                stats.peak_resident_bytes
            );
            // Each pass streams each shard's file exactly once — no
            // re-reads, no partial reads — and the staged ledger sees
            // every one of those loads on the disk tier.
            assert_eq!(
                totals.disk_bytes,
                (st_stats.pin_bytes + st_stats.stream_bytes) as u64,
                "ledger disk bytes must equal the loader's byte count"
            );
            assert_eq!(
                st_stats.stream_bytes,
                st_stats.passes * file_bytes,
                "each pass must stream each shard exactly once"
            );
            assert_eq!(totals.hot_panel_transfers, 0, "disk tier must not touch panel rule 3");
        }
        let _ = std::fs::remove_dir_all(&dir_path);
        json::obj(vec![
            ("m", json::num(rows as f64)),
            ("shards", json::num(n_shards as f64)),
            ("resident_cap", json::num(cap as f64)),
            ("shard_file_bytes", json::num(file_bytes as f64)),
            ("passes", json::num(stats.passes as f64)),
            ("pin_bytes", json::num(stats.pin_bytes as f64)),
            ("stream_bytes", json::num(stats.stream_bytes as f64)),
            ("load_secs", json::num(stats.load_secs)),
            ("stall_secs", json::num(stats.stall_secs)),
            ("overlap_efficiency", json::num(overlap)),
            ("peak_resident_bytes", json::num(stats.peak_resident_bytes as f64)),
            ("incore_s", json::num(t_incore)),
            ("sharded_s", json::num(t_sharded)),
            ("sharded_over_incore", json::num(slowdown)),
            ("bitwise_parity", json::num(if parity { 1.0 } else { 0.0 })),
            ("disk_bytes", json::num(totals.disk_bytes as f64)),
            ("disk_count", json::num(totals.disk_count as f64)),
            ("h2a_bytes", json::num(totals.h2a_bytes as f64)),
            ("a2h_bytes", json::num(totals.a2h_bytes as f64)),
            ("a2a_bytes", json::num(totals.a2a_bytes as f64)),
            ("hot_panel_transfers", json::num(totals.hot_panel_transfers as f64)),
        ])
    };

    banner(
        "Fused operand passes (A·Q + Gram, Aᵀ(A·Q))",
        "one nonzero sweep vs the two-kernel composition; BENCH_ASSERT_FUSED=1 \
         gates fused-not-slower in core (full size only) and the >=1.8x \
         disk-byte drop out of core",
    );
    let fused_section = {
        use std::sync::Arc;
        use trunksvd::algo::randsvd::randsvd;
        use trunksvd::algo::RandSvdOpts;
        use trunksvd::backend::staged::StagedBackend;
        use trunksvd::sparse::shard;

        let assert_fused = env_usize("BENCH_ASSERT_FUSED", 0) == 1;
        // In-core leg: operand past the LLC crossover (~37 MB of CSR at
        // full size), so the composition's second read of the nonzeros
        // is a real DRAM pass and the fused band sweep's is a cache hit.
        let rows = if quick { 16384 } else { 65536 };
        let spec =
            SparseSpec { rows, cols: rows / 2, nnz: rows * 48, seed: 83, ..Default::default() };
        let a = generate(&spec);
        let k = 8usize;
        let mut rng2 = Rng::new(97);
        let x: Mat<f64> = Mat::randn(a.cols(), k, &mut rng2);
        let mut y: Mat<f64> = Mat::zeros(a.rows(), k);
        let mut z: Mat<f64> = Mat::zeros(a.cols(), k);
        let mut g: Mat<f64> = Mat::zeros(k, k);
        let fl_ata = 4.0 * a.nnz() as f64 * k as f64;
        let (w, r) = auto_runs(fl_ata / 1e9);
        // Min-of-runs with up-to-5 retries keeping the best ratio (the
        // same noise armor as the SIMD gate).
        let (mut ata_ratio, mut gram_ratio) = (0.0f64, 0.0f64);
        let (mut tfa, mut tua, mut tfg, mut tug) = (0.0f64, 0.0, 0.0, 0.0);
        for _ in 0..5 {
            let f_ata = time_runs(w, r, || a.spmm_ata(x.as_ref(), y.as_mut(), z.as_mut())).min;
            let u_ata = time_runs(w, r, || {
                a.spmm(x.as_ref(), y.as_mut());
                a.spmm_t(y.as_ref(), z.as_mut());
            })
            .min;
            let f_gram = time_runs(w, r, || a.spmm_gram(x.as_ref(), y.as_mut(), g.as_mut())).min;
            let u_gram = time_runs(w, r, || {
                a.spmm(x.as_ref(), y.as_mut());
                blas3::gram_into(y.as_ref(), g.as_mut());
            })
            .min;
            if u_ata / f_ata > ata_ratio {
                ata_ratio = u_ata / f_ata;
                (tfa, tua) = (f_ata, u_ata);
            }
            if u_gram / f_gram > gram_ratio {
                gram_ratio = u_gram / f_gram;
                (tfg, tug) = (f_gram, u_gram);
            }
            if ata_ratio >= 1.0 && gram_ratio >= 1.0 {
                break;
            }
        }
        println!(
            "fused_ata        m={rows:>6} nnz={}  fused {tfa:>8.4}s  unfused {tua:>8.4}s  \
             speedup {ata_ratio:>5.2}x  {:>7.2} GF/s",
            a.nnz(),
            gflops(fl_ata, tfa)
        );
        println!(
            "fused_gram       m={rows:>6} nnz={}  fused {tfg:>8.4}s  unfused {tug:>8.4}s  \
             speedup {gram_ratio:>5.2}x",
            a.nnz()
        );

        // Out-of-core leg: deterministic ledger arithmetic, no timing.
        // A fused RandSVD power sweep makes p+1 operand passes against
        // 2p unfused, so at p = 10 the disk tier must record exactly a
        // 20/11 ≈ 1.82x byte drop.
        let rows_ooc = 4000usize;
        let spec = SparseSpec {
            rows: rows_ooc,
            cols: rows_ooc / 4,
            nnz: rows_ooc * 12,
            seed: 89,
            ..Default::default()
        };
        let a_ooc = generate(&spec);
        let dir_path = std::env::temp_dir().join("trunksvd_bench_fused_shards");
        let _ = std::fs::remove_dir_all(&dir_path);
        let dirs = dir_path.to_str().expect("utf8 temp path").to_string();
        let sd =
            Arc::new(shard::write_shards_from_csr(&dirs, &a_ooc, 4).expect("write fused shards"));
        let cap = 2 * sd.max_resident_bytes::<f64>();
        let p = 10usize;
        let solve_disk_bytes = |fuse: bool| -> u64 {
            let mut be: StagedBackend = StagedBackend::new_sharded(Arc::clone(&sd), cap);
            be.ensure_operand_resident().expect("fused shard staging");
            let opts = RandSvdOpts {
                r: 12,
                p,
                b: 4,
                seed: 7,
                fuse: Some(fuse),
                ..Default::default()
            };
            randsvd(&mut be, &opts).expect("sharded power solve");
            be.ledger().totals().disk_bytes
        };
        let disk_fused = solve_disk_bytes(true);
        let disk_unfused = solve_disk_bytes(false);
        let _ = std::fs::remove_dir_all(&dir_path);
        let disk_ratio = disk_unfused as f64 / disk_fused.max(1) as f64;
        println!(
            "fused_ooc        p={p} shards=4  disk fused {disk_fused} B  \
             unfused {disk_unfused} B  drop {disk_ratio:>5.2}x"
        );
        if assert_fused {
            if !quick {
                // At quick size the operand is cache-resident and the
                // in-core comparison is noise; the timing gate only
                // means something past the LLC.
                assert!(
                    ata_ratio >= 1.0,
                    "fused A^T(A q) must not be slower than the composition \
                     (best ratio {ata_ratio:.3})"
                );
                assert!(
                    gram_ratio >= 0.95,
                    "fused A q + Gram regressed past noise (best ratio {gram_ratio:.3})"
                );
            }
            assert!(
                disk_ratio >= 1.8,
                "fused power sweep must cut disk bytes >= 1.8x (got {disk_ratio:.3})"
            );
        }
        json::obj(vec![
            ("m", json::num(rows as f64)),
            ("nnz", json::num(a.nnz() as f64)),
            ("k", json::num(k as f64)),
            ("fused_ata_s", json::num(tfa)),
            ("unfused_ata_s", json::num(tua)),
            ("ata_speedup", json::num(ata_ratio)),
            ("fused_gram_s", json::num(tfg)),
            ("unfused_gram_s", json::num(tug)),
            ("gram_speedup", json::num(gram_ratio)),
            ("ooc_p", json::num(p as f64)),
            ("ooc_disk_bytes_fused", json::num(disk_fused as f64)),
            ("ooc_disk_bytes_unfused", json::num(disk_unfused as f64)),
            ("ooc_disk_drop", json::num(disk_ratio)),
        ])
    };

    banner(
        "Cost-model calibration",
        "measured dispatch/scatter/build crossovers -> cost_calibration section \
         (load with TRUNKSVD_COST_CALIB=BENCH_kernels.json; --calibrate adds a k-sweep)",
    );
    let calibrate = std::env::args().any(|a| a == "--calibrate");
    let cal_section = {
        pool::set_num_threads(1);
        // Per-element streaming cost from a serial axpy sweep: the
        // denominator of the dispatch-grain crossover.
        let nvec = 1usize << 20;
        let xsrc = vec![1.000001f64; nvec];
        let mut ydst = vec![0.0f64; nvec];
        let st = time_runs(2, 7, || trunksvd::la::blas1::axpy(0.5, &xsrc, &mut ydst));
        let elem_ns = st.min * 1e9 / nvec as f64;
        let cutoff = (pool_dispatch_ns / elem_ns.max(1e-3)).clamp(64.0, 16384.0).round() as usize;
        // Scatter penalty and transpose-build cost at the shape the
        // adaptive-transpose decision actually sees (tall sparse, k=8).
        let mc = if quick { 4096 } else { 8192 };
        let kc = 8usize;
        let spec =
            SparseSpec { rows: mc, cols: mc / 2, nnz: mc * 20, seed: 57, ..Default::default() };
        let ac = generate(&spec);
        let measure = |k: usize| -> (f64, f64, f64) {
            let mut rng = Rng::new(71);
            let xm: Mat<f64> = Mat::randn(ac.rows(), k, &mut rng);
            let mut yn: Mat<f64> = Mat::zeros(ac.cols(), k);
            let fl = 2.0 * ac.nnz() as f64 * k as f64;
            let (w, r) = auto_runs(fl / 1e9);
            let t_scatter = time_runs(w, r, || ac.spmm_t(xm.as_ref(), yn.as_mut())).min;
            let t_build = time_runs(1, 3, || {
                let _ = ac.transpose();
            })
            .min;
            let at = ac.transpose();
            let t_gather = time_runs(w, r, || at.spmm(xm.as_ref(), yn.as_mut())).min;
            (t_scatter, t_gather, t_build)
        };
        let (ts, tg, tb) = measure(kc);
        // Model units (see cost::adaptive_transpose_threshold): one
        // gather call ~= k column sweeps of the nnz stream, so the
        // per-sweep time is t_gather/k; the scatter penalty is the extra
        // fraction per call and the build cost is in sweeps.
        let scatter_penalty = ((ts - tg) / tg.max(1e-12)).clamp(0.05, 16.0);
        let build_sweeps = (kc as f64 * tb / tg.max(1e-12)).clamp(1.0, 64.0);
        let mut fields = vec![
            ("build_sweeps", json::num(build_sweeps)),
            ("scatter_penalty", json::num(scatter_penalty)),
            ("parallel_cutoff", json::num(cutoff as f64)),
            ("dispatch_ns", json::num(pool_dispatch_ns)),
            ("elem_ns", json::num(elem_ns)),
            ("m", json::num(mc as f64)),
            ("k", json::num(kc as f64)),
        ];
        println!(
            "cost_calibration  build_sweeps {build_sweeps:>5.2}  scatter_penalty \
             {scatter_penalty:>5.2}  parallel_cutoff {cutoff:>5}  \
             (dispatch {pool_dispatch_ns:.0} ns, elem {elem_ns:.2} ns)"
        );
        if calibrate {
            let mut sweep = Vec::new();
            for &k in &[1usize, 2, 4, 8, 16] {
                let (ts, tg, tb) = measure(k);
                let pen = ((ts - tg) / tg.max(1e-12)).max(0.0);
                println!(
                    "  sweep k={k:>2}  scatter {ts:>9.5}s  gather {tg:>9.5}s  \
                     build {tb:>9.5}s  penalty {pen:>5.2}"
                );
                sweep.push(json::obj(vec![
                    ("k", json::num(k as f64)),
                    ("scatter_s", json::num(ts)),
                    ("gather_s", json::num(tg)),
                    ("build_s", json::num(tb)),
                    ("scatter_penalty", json::num(pen)),
                    ("build_sweeps", json::num((k as f64 * tb / tg.max(1e-12)).max(0.0))),
                ]));
            }
            fields.push(("sweep", json::arr(sweep)));
        }
        pool::set_num_threads(0);
        json::obj(fields)
    };

    let n_entries = entries.len();
    let doc = json::obj(vec![
        ("bench", json::str("kernels")),
        ("threads", json::num(threads as f64)),
        ("quick", json::num(if quick { 1.0 } else { 0.0 })),
        ("cost_calibration", cal_section),
        ("out_of_core", ooc_section),
        ("fused", fused_section),
        ("kernels", json::arr(entries)),
    ]);
    std::fs::write("BENCH_kernels.json", json::write(&doc)).expect("write BENCH_kernels.json");
    println!("wrote BENCH_kernels.json ({n_entries} entries + cost_calibration)");

    banner("Orthogonalization (q x 16 panel)", "CholeskyQR2 and CGS-CQR2 (s=128)");
    let qs: &[usize] = if quick { &[4096] } else { &[4096, 32768] };
    for &q in qs {
        let y0: Mat<f64> = Mat::randn(q, 16, &mut rng);
        let p: Mat<f64> = random_orthonormal(q, 128, &mut rng);
        let mut be: CpuBackend = CpuBackend::new_dense(Mat::zeros(1, 1));
        let fl4 = trunksvd::cost::ca4(16, q);
        let (w, r) = auto_runs(fl4 / 2e9);
        let st = time_runs(w, r, || {
            let mut y = y0.clone();
            be.orth_cholqr2(&mut y).unwrap();
        });
        println!("cholqr2  q={q:>6}  cpu  {:.2} GF/s ({:.4}s)", gflops(fl4, st.median), st.median);
        let fl5 = trunksvd::cost::ca5(16, q, 128);
        let st = time_runs(w, r, || {
            let mut y = y0.clone();
            be.orth_cgs_cqr2(&mut y, p.panel(0, 128)).unwrap();
        });
        println!("cgs_cqr2 q={q:>6}  cpu  {:.2} GF/s ({:.4}s)", gflops(fl5, st.median), st.median);

        // XLA path (artifact + PJRT) when available. The client is
        // leaked: xla_extension 0.5.1 cannot re-create a CPU client
        // after one is destroyed in the same process.
        let dir = default_artifact_dir();
        if std::path::Path::new(&format!("{dir}/manifest.json")).exists() {
            let rt = Rc::new(Runtime::new(&dir).unwrap());
            std::mem::forget(rt.clone());
            let mut xbe = XlaBackend::new_dense(rt, Mat::zeros(512, 4)).unwrap();
            // warm the executable cache before timing
            let mut y = y0.clone();
            xbe.orth_cholqr2(&mut y).unwrap();
            let st = time_runs(1, 3, || {
                let mut y = y0.clone();
                xbe.orth_cholqr2(&mut y).unwrap();
            });
            println!(
                "cholqr2  q={q:>6}  xla  {:.2} GF/s ({:.4}s)",
                gflops(fl4, st.median),
                st.median
            );
            let mut y = y0.clone();
            xbe.orth_cgs_cqr2(&mut y, p.panel(0, 128)).unwrap();
            let st = time_runs(1, 3, || {
                let mut y = y0.clone();
                xbe.orth_cgs_cqr2(&mut y, p.panel(0, 128)).unwrap();
            });
            println!(
                "cgs_cqr2 q={q:>6}  xla  {:.2} GF/s ({:.4}s)",
                gflops(fl5, st.median),
                st.median
            );
        }
    }
    println!("\nbench_blocks done");
}
