//! Micro-benchmarks of the Table-1 building blocks (GEMM, SpMM, SpMMᵀ,
//! CholeskyQR2, CGS-CQR2) on the CPU substrate and, when artifacts are
//! present, on the XLA/PJRT path. Feeds the §Perf iteration log.
//!
//! `BENCH_QUICK=1` shrinks the size sweep.

use std::rc::Rc;

use trunksvd::backend::cpu::CpuBackend;
use trunksvd::backend::xla::XlaBackend;
use trunksvd::backend::Backend;
use trunksvd::bench_support::{auto_runs, banner, env_usize, gflops, time_runs};
use trunksvd::gen::sparse::{generate, SparseSpec};
use trunksvd::la::blas3;
use trunksvd::la::mat::Mat;
use trunksvd::la::qr::random_orthonormal;
use trunksvd::runtime::{default_artifact_dir, Runtime};
use trunksvd::util::rng::Rng;

fn main() {
    let quick = env_usize("BENCH_QUICK", 0) == 1;
    let mut rng = Rng::new(1);

    banner("GEMM (C = A·B, k=512, n=16)", "m, GFLOP/s");
    let ms: &[usize] = if quick { &[4096] } else { &[2048, 8192, 32768] };
    for &m in ms {
        let a = Mat::randn(m, 512, &mut rng);
        let b = Mat::randn(512, 16, &mut rng);
        let mut c = Mat::zeros(m, 16);
        let fl = 2.0 * (m * 512 * 16) as f64;
        let (w, r) = auto_runs(fl / 2e9);
        let st = time_runs(w, r, || blas3::gemm_nn(1.0, a.as_ref(), b.as_ref(), 0.0, &mut c));
        println!("gemm_nn  m={m:>6}  {:.2} GF/s ({:.4}s)", gflops(fl, st.median), st.median);
        let mut h = Mat::zeros(512, 16);
        let x = Mat::randn(m, 16, &mut rng);
        let st = time_runs(w, r, || blas3::gemm_tn(1.0, a.as_ref(), x.as_ref(), 0.0, &mut h));
        println!("gemm_tn  m={m:>6}  {:.2} GF/s ({:.4}s)", gflops(fl, st.median), st.median);
    }

    banner("SpMM vs SpMMᵀ (k=16)", "the paper's bottleneck asymmetry");
    let spec = SparseSpec {
        rows: if quick { 8192 } else { 32768 },
        cols: 8192,
        nnz: if quick { 200_000 } else { 800_000 },
        seed: 3,
        ..Default::default()
    };
    let a = generate(&spec);
    let at = a.transpose();
    let x_n = Mat::randn(a.cols(), 16, &mut rng);
    let x_m = Mat::randn(a.rows(), 16, &mut rng);
    let fl = 2.0 * a.nnz() as f64 * 16.0;
    let mut y_m = Mat::zeros(a.rows(), 16);
    let mut y_n = Mat::zeros(a.cols(), 16);
    let (w, r) = auto_runs(fl / 1e9);
    let st = time_runs(w, r, || a.spmm(&x_n, &mut y_m));
    println!("spmm   (gather)    {:.2} GF/s ({:.4}s)", gflops(fl, st.median), st.median);
    let st_t = time_runs(w, r, || a.spmm_t(&x_m, &mut y_n));
    println!("spmm_t (scatter)   {:.2} GF/s ({:.4}s)", gflops(fl, st_t.median), st_t.median);
    let st_e = time_runs(w, r, || at.spmm(&x_m, &mut y_n));
    println!("spmm_t (expl. T)   {:.2} GF/s ({:.4}s)", gflops(fl, st_e.median), st_e.median);

    banner("Orthogonalization (q x 16 panel)", "CholeskyQR2 and CGS-CQR2 (s=128)");
    let qs: &[usize] = if quick { &[4096] } else { &[4096, 32768] };
    for &q in qs {
        let y0 = Mat::randn(q, 16, &mut rng);
        let p = random_orthonormal(q, 128, &mut rng);
        let mut be = CpuBackend::new_dense(Mat::zeros(1, 1));
        let fl4 = trunksvd::cost::ca4(16, q);
        let (w, r) = auto_runs(fl4 / 2e9);
        let st = time_runs(w, r, || {
            let mut y = y0.clone();
            be.orth_cholqr2(&mut y).unwrap();
        });
        println!("cholqr2  q={q:>6}  cpu  {:.2} GF/s ({:.4}s)", gflops(fl4, st.median), st.median);
        let fl5 = trunksvd::cost::ca5(16, q, 128);
        let st = time_runs(w, r, || {
            let mut y = y0.clone();
            be.orth_cgs_cqr2(&mut y, p.panel(0, 128)).unwrap();
        });
        println!("cgs_cqr2 q={q:>6}  cpu  {:.2} GF/s ({:.4}s)", gflops(fl5, st.median), st.median);

        // XLA path (artifact + PJRT) when available. The client is
        // leaked: xla_extension 0.5.1 cannot re-create a CPU client
        // after one is destroyed in the same process.
        let dir = default_artifact_dir();
        if std::path::Path::new(&format!("{dir}/manifest.json")).exists() {
            let rt = Rc::new(Runtime::new(&dir).unwrap());
            std::mem::forget(rt.clone());
            let mut xbe = XlaBackend::new_dense(rt, Mat::zeros(512, 4)).unwrap();
            // warm the executable cache before timing
            let mut y = y0.clone();
            xbe.orth_cholqr2(&mut y).unwrap();
            let st = time_runs(1, 3, || {
                let mut y = y0.clone();
                xbe.orth_cholqr2(&mut y).unwrap();
            });
            println!(
                "cholqr2  q={q:>6}  xla  {:.2} GF/s ({:.4}s)",
                gflops(fl4, st.median),
                st.median
            );
            let mut y = y0.clone();
            xbe.orth_cgs_cqr2(&mut y, p.panel(0, 128)).unwrap();
            let st = time_runs(1, 3, || {
                let mut y = y0.clone();
                xbe.orth_cgs_cqr2(&mut y, p.panel(0, 128)).unwrap();
            });
            println!(
                "cgs_cqr2 q={q:>6}  xla  {:.2} GF/s ({:.4}s)",
                gflops(fl5, st.median),
                st.median
            );
        }
    }
    println!("\nbench_blocks done");
}
