//! Property/stress tests for the persistent worker pool (`util::pool`).
//!
//! The pool is the threading substrate under every kernel, so these
//! tests pin the contract the kernels rely on: complete and disjoint
//! coverage for uneven partitions, graceful zero-length handling, serial
//! degradation of nested calls (documented behavior, never a deadlock),
//! cheap dispatch (a 10k-call smoke loop), safe `set_num_threads`
//! resizing mid-process — including a resize storm interleaved with
//! kernel calls and a concurrent submitter thread — and panic
//! containment (a panicking job must propagate to its caller without
//! wedging or poisoning the pool for the next call).
//!
//! The thread-count and cutoff overrides are process-global, so every
//! test serializes on `POOL_LOCK` and restores the defaults on exit
//! (panic-safe via the `PoolReset` drop guard). Tests that must exercise
//! the *parallel* path on small fixtures force it with
//! `set_parallel_cutoff(1)`; the default cost-model cutoff would send
//! them down the serial fast path.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::Mutex;
use std::time::Instant;

use trunksvd::la::blas3::mat_nn;
use trunksvd::la::mat::Mat;
use trunksvd::sparse::coo::Coo;
use trunksvd::sparse::csr::Csr;
use trunksvd::util::pool;
use trunksvd::util::rng::Rng;

static POOL_LOCK: Mutex<()> = Mutex::new(());

const THREAD_SWEEP: [usize; 4] = [1, 2, 3, 8];

/// Restores the pool defaults even if the guarded test panics.
struct PoolReset;
impl Drop for PoolReset {
    fn drop(&mut self) {
        pool::set_num_threads(0);
        pool::set_parallel_cutoff(0);
    }
}

#[test]
fn parallel_for_uneven_and_zero_lengths() {
    let _guard = POOL_LOCK.lock().unwrap_or_else(|p| p.into_inner());
    let _reset = PoolReset;
    for &t in &THREAD_SWEEP {
        pool::set_num_threads(t);
        // n = 0 must not invoke the body at all.
        pool::parallel_for(0, |_| panic!("t={t}: body must not run for n=0"));
        for n in [1usize, 2, 3, 7, 97, 1000, 1023] {
            let hits: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0)).collect();
            pool::parallel_for(n, |i| {
                hits[i].fetch_add(1, Ordering::Relaxed);
            });
            for (i, h) in hits.iter().enumerate() {
                assert_eq!(h.load(Ordering::Relaxed), 1, "t={t} n={n} index {i}");
            }
        }
    }
}

#[test]
fn chunks_mut_uneven_partitions_cover_exactly_once() {
    let _guard = POOL_LOCK.lock().unwrap_or_else(|p| p.into_inner());
    let _reset = PoolReset;
    pool::set_parallel_cutoff(1); // force the parallel path on tiny slices
    for &t in &THREAD_SWEEP {
        pool::set_num_threads(t);
        for &(len, chunk) in &[
            (0usize, 1usize),
            (1, 3),
            (10, 3),
            (103, 10),
            (1000, 7),
            (64, 64),
            (65, 64),
            (1024, 1),
            (17, 100), // single ragged chunk
        ] {
            let n_chunks = len.div_ceil(chunk);
            let calls: Vec<AtomicU64> = (0..n_chunks).map(|_| AtomicU64::new(0)).collect();
            let mut v = vec![u64::MAX; len];
            pool::parallel_chunks_mut(&mut v, chunk, |ci, c| {
                calls[ci].fetch_add(1, Ordering::Relaxed);
                // Last chunk may be ragged; all others are full.
                if ci + 1 < n_chunks {
                    assert_eq!(c.len(), chunk, "t={t} len={len} chunk {ci}");
                }
                for x in c.iter_mut() {
                    *x = ci as u64;
                }
            });
            for (ci, c) in calls.iter().enumerate() {
                assert_eq!(c.load(Ordering::Relaxed), 1, "t={t} len={len} chunk {ci}");
            }
            for (i, x) in v.iter().enumerate() {
                assert_eq!(*x, (i / chunk) as u64, "t={t} len={len} elem {i}");
            }
        }
    }
}

#[test]
fn row_blocks_uneven_panels_cover_exactly_once() {
    let _guard = POOL_LOCK.lock().unwrap_or_else(|p| p.into_inner());
    let _reset = PoolReset;
    pool::set_parallel_cutoff(1);
    for &t in &THREAD_SWEEP {
        pool::set_num_threads(t);
        for &(rows, cols, align) in &[
            (1usize, 1usize, 1usize),
            (103, 5, 8),
            (256, 4, 32),
            (57, 3, 8),
            (5, 9, 64), // fewer rows than one aligned block
            (64, 2, 1),
        ] {
            let mut v = vec![0u64; rows * cols];
            pool::parallel_row_blocks(&mut v, rows, align, |lo, hi, band| {
                assert!(lo < hi && hi <= rows, "t={t} rows={rows} band [{lo},{hi})");
                assert_eq!(band.len(), cols, "t={t} rows={rows}");
                for (j, col) in band.iter_mut().enumerate() {
                    assert_eq!(col.len(), hi - lo, "t={t} rows={rows} col {j}");
                    for (o, x) in col.iter_mut().enumerate() {
                        *x += 1 + ((lo + o) * 100 + j) as u64;
                    }
                }
            });
            for j in 0..cols {
                for i in 0..rows {
                    assert_eq!(
                        v[j * rows + i],
                        1 + (i * 100 + j) as u64,
                        "t={t} rows={rows} ({i},{j})"
                    );
                }
            }
        }
        // Zero-column panel: a single serial call with no columns.
        let mut empty: Vec<u64> = Vec::new();
        let calls = AtomicU64::new(0);
        pool::parallel_row_blocks(&mut empty, 5, 2, |lo, hi, band| {
            calls.fetch_add(1, Ordering::Relaxed);
            assert_eq!((lo, hi), (0, 5), "t={t}");
            assert!(band.is_empty(), "t={t}");
        });
        assert_eq!(calls.load(Ordering::Relaxed), 1, "t={t}");
    }
}

#[test]
fn reduce_preserves_band_order_when_forced_parallel() {
    let _guard = POOL_LOCK.lock().unwrap_or_else(|p| p.into_inner());
    let _reset = PoolReset;
    pool::set_parallel_cutoff(1);
    for &t in &THREAD_SWEEP {
        pool::set_num_threads(t);
        for n in [0usize, 1, 2, 17, 257, 1000] {
            let v = pool::parallel_reduce(
                n,
                Vec::new(),
                |lo, hi| (lo..hi).collect::<Vec<usize>>(),
                |mut a, mut b| {
                    a.append(&mut b);
                    a
                },
            );
            assert_eq!(v, (0..n).collect::<Vec<usize>>(), "t={t} n={n}");
        }
    }
}

#[test]
fn parallel_tasks_consumes_each_task_exactly_once() {
    let _guard = POOL_LOCK.lock().unwrap_or_else(|p| p.into_inner());
    let _reset = PoolReset;
    for &t in &THREAD_SWEEP {
        pool::set_num_threads(t);
        for n in [0usize, 1, 2, 5, 23] {
            let tasks: Vec<Vec<usize>> = (0..n).map(|k| vec![k; k % 4]).collect();
            let seen: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0)).collect();
            pool::parallel_tasks(tasks, |k, task| {
                assert_eq!(task.len(), k % 4, "t={t} n={n} task {k}");
                assert!(task.iter().all(|&x| x == k), "t={t} n={n} task {k}");
                seen[k].fetch_add(1, Ordering::Relaxed);
            });
            for (k, s) in seen.iter().enumerate() {
                assert_eq!(s.load(Ordering::Relaxed), 1, "t={t} n={n} task {k}");
            }
        }
    }
}

#[test]
fn nested_calls_run_serially_without_deadlock() {
    let _guard = POOL_LOCK.lock().unwrap_or_else(|p| p.into_inner());
    let _reset = PoolReset;
    pool::set_parallel_cutoff(1);
    pool::set_num_threads(4);
    let total = AtomicU64::new(0);
    pool::parallel_for(8, |i| {
        // Nested entry points degrade to serial on this worker — they
        // must complete and be correct, never deadlock on the pool.
        let s = pool::parallel_reduce(
            500,
            0u64,
            |lo, hi| (lo as u64..hi as u64).sum::<u64>(),
            |a, b| a + b,
        );
        assert_eq!(s, 124_750, "outer index {i}");
        let mut v = vec![0u64; 64];
        pool::parallel_chunks_mut(&mut v, 8, |ci, c| {
            for x in c.iter_mut() {
                *x = ci as u64;
            }
        });
        assert!(v.iter().enumerate().all(|(k, &x)| x == (k / 8) as u64), "outer index {i}");
        total.fetch_add(s, Ordering::Relaxed);
    });
    assert_eq!(total.load(Ordering::Relaxed), 8 * 124_750);
    assert!(!pool::in_parallel_job(), "in-job flag must clear after the call");
}

#[test]
fn dispatch_smoke_10k_calls() {
    let _guard = POOL_LOCK.lock().unwrap_or_else(|p| p.into_inner());
    let _reset = PoolReset;
    pool::set_num_threads(4);
    let count = AtomicU64::new(0);
    let t0 = Instant::now();
    for _ in 0..10_000 {
        pool::parallel_for(4, |_| {
            count.fetch_add(1, Ordering::Relaxed);
        });
    }
    let elapsed = t0.elapsed();
    assert_eq!(count.load(Ordering::Relaxed), 40_000);
    // Spawn-per-call dispatch costs tens of µs per call; the persistent
    // pool must stay well under that even on a loaded CI runner. This is
    // a wedge/regression canary, not a microbenchmark (that lives in
    // bench_blocks as pool_dispatch_ns).
    assert!(
        elapsed.as_secs_f64() < 30.0,
        "10k dispatches took {:.2}s — pool dispatch has regressed to spawn-like cost",
        elapsed.as_secs_f64()
    );
}

#[test]
fn resize_storm_interleaved_with_kernel_calls() {
    let _guard = POOL_LOCK.lock().unwrap_or_else(|p| p.into_inner());
    let _reset = PoolReset;
    pool::set_parallel_cutoff(1);
    // Reference kernel data: a small sparse matrix and its dense oracle.
    let mut rng = Rng::new(0xB00);
    let mut coo = Coo::new(120, 80);
    for _ in 0..1500 {
        coo.push(rng.below(120), rng.below(80), rng.normal());
    }
    let a = Csr::from_coo(&coo).unwrap();
    let ad = a.to_dense();
    let x = Mat::randn(80, 5, &mut rng);
    let expect = mat_nn(&ad, &x);

    // A concurrent submitter hammers the pool from another thread while
    // the main thread storms `set_num_threads`; broadcasts from the two
    // threads serialize on the pool's submit lock.
    let side_count = AtomicU64::new(0);
    let stop = AtomicUsize::new(0);
    std::thread::scope(|scope| {
        let side = scope.spawn(|| {
            while stop.load(Ordering::SeqCst) == 0 {
                pool::parallel_for(64, |_| {
                    side_count.fetch_add(1, Ordering::Relaxed);
                });
            }
        });
        for round in 0..200 {
            // 0 clears the override (env/available default) — also a
            // legal point in the storm.
            pool::set_num_threads(round % 9);
            let s = pool::parallel_reduce(
                5000,
                0u64,
                |lo, hi| (lo as u64..hi as u64).sum::<u64>(),
                |a, b| a + b,
            );
            assert_eq!(s, 12_497_500, "round {round}");
            let mut y = Mat::zeros(120, 5);
            a.spmm(x.as_ref(), y.as_mut());
            assert!(y.max_abs_diff(&expect) < 1e-12, "round {round}");
        }
        stop.store(1, Ordering::SeqCst);
        side.join().expect("side submitter panicked");
    });
    assert_eq!(side_count.load(Ordering::Relaxed) % 64, 0);
    assert!(side_count.load(Ordering::Relaxed) > 0);
}

#[test]
fn panic_in_job_propagates_and_pool_survives() {
    let _guard = POOL_LOCK.lock().unwrap_or_else(|p| p.into_inner());
    let _reset = PoolReset;
    pool::set_parallel_cutoff(1);
    pool::set_num_threads(4);
    // Silence the default per-thread panic banner for the deliberate
    // panics below; restored before the verification phase.
    let prev_hook = std::panic::take_hook();
    std::panic::set_hook(Box::new(|_| {}));

    // Panic on a high index (a worker band at t=4).
    let r = catch_unwind(AssertUnwindSafe(|| {
        pool::parallel_for(100, |i| {
            if i >= 90 {
                panic!("deliberate worker-band panic");
            }
        });
    }));
    assert!(r.is_err(), "worker-band panic must reach the caller");

    // Panic on index 0 (the submitter's own band) — payload must be the
    // original one.
    let r = catch_unwind(AssertUnwindSafe(|| {
        pool::parallel_for(100, |i| {
            if i == 0 {
                panic!("deliberate band-0 panic");
            }
        });
    }));
    let payload = r.expect_err("band-0 panic must reach the caller");
    let msg = payload
        .downcast_ref::<&str>()
        .copied()
        .or_else(|| payload.downcast_ref::<String>().map(|s| s.as_str()))
        .unwrap_or("");
    assert!(msg.contains("band-0"), "submitter panic payload preserved, got {msg:?}");

    // Panic inside a reduce map.
    let r = catch_unwind(AssertUnwindSafe(|| {
        pool::parallel_reduce(
            4000,
            0u64,
            |lo, _hi| {
                if lo == 0 {
                    panic!("deliberate reduce panic");
                }
                1u64
            },
            |a, b| a + b,
        );
    }));
    assert!(r.is_err(), "reduce panic must reach the caller");

    std::panic::set_hook(prev_hook);

    // The pool must be fully functional afterwards: not wedged, not
    // poisoned, full coverage, across repeated calls and a resize.
    for round in 0..50 {
        let hits: Vec<AtomicU64> = (0..257).map(|_| AtomicU64::new(0)).collect();
        pool::parallel_for(257, |i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        assert!(
            hits.iter().all(|h| h.load(Ordering::Relaxed) == 1),
            "post-panic round {round}"
        );
    }
    pool::set_num_threads(2);
    let s = pool::parallel_reduce(
        3000,
        0u64,
        |lo, hi| (lo as u64..hi as u64).sum::<u64>(),
        |a, b| a + b,
    );
    assert_eq!(s, 4_498_500, "post-panic resize");
}

#[test]
fn band_affinity_stable_across_calls() {
    let _guard = POOL_LOCK.lock().unwrap_or_else(|p| p.into_inner());
    let _reset = PoolReset;
    pool::set_num_threads(4);
    let n = 64;
    let run = || {
        let ids: Vec<Mutex<String>> = (0..n).map(|_| Mutex::new(String::new())).collect();
        pool::parallel_for(n, |i| {
            *ids[i].lock().unwrap() = format!("{:?}", std::thread::current().id());
        });
        ids.into_iter()
            .map(|m| m.into_inner().unwrap())
            .collect::<Vec<String>>()
    };
    // Warm call spawns the workers; the next calls must route every
    // index to the same long-lived thread (sticky banding = the cache /
    // NUMA affinity property).
    let first = run();
    for call in 0..5 {
        assert_eq!(run(), first, "index→thread mapping drifted on call {call}");
    }
}

#[test]
fn overrides_round_trip_and_defaults() {
    let _guard = POOL_LOCK.lock().unwrap_or_else(|p| p.into_inner());
    let _reset = PoolReset;
    let t0 = {
        pool::set_num_threads(0);
        pool::num_threads()
    };
    assert!(t0 >= 1);
    pool::set_num_threads(5);
    assert_eq!(pool::num_threads(), 5);
    pool::set_num_threads(0);
    assert_eq!(pool::num_threads(), t0);
    let c0 = {
        pool::set_parallel_cutoff(0);
        pool::parallel_cutoff()
    };
    assert!(c0 >= 1);
    pool::set_parallel_cutoff(123);
    assert_eq!(pool::parallel_cutoff(), 123);
    pool::set_parallel_cutoff(0);
    assert_eq!(pool::parallel_cutoff(), c0);
}
