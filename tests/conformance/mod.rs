//! Shared backend-conformance batteries.
//!
//! One parameterized test set, executed over every `Backend`
//! implementation × element precision by `test_backend_conformance.rs`:
//!
//! * [`op_parity_battery`] — every primitive op against the
//!   `CpuBackend` reference at ε-scaled tolerances, on sparse and dense
//!   operands;
//! * [`lifecycle_battery`] — plan-lifecycle rules: ops before `plan()`
//!   work (lazy staging), workspace reuse across solves on *one*
//!   backend is bitwise-reproducible, re-plan on shape change restages,
//!   plan mismatches are rejected;
//! * [`e2e_battery`] — end-to-end `lancsvd`/`randsvd` residual targets
//!   on the `gen/` scenario zoo (prescribed-decay dense spectra, the
//!   sparse suite generator) at per-dtype targets.
//!
//! The staged backend's ledger assertions live in the test file itself
//! (they are not generic — only `StagedBackend` has a ledger).

use std::rc::Rc;

use trunksvd::algo::lancsvd::{lancsvd, lancsvd_with};
use trunksvd::algo::randsvd::randsvd;
use trunksvd::algo::{residuals, LancSvdOpts, RandSvdOpts};
use trunksvd::backend::cpu::CpuBackend;
use trunksvd::backend::staged::StagedBackend;
use trunksvd::backend::xla::XlaBackend;
use trunksvd::backend::{Backend, Operand};
use trunksvd::gen::dense::{dense_with_spectrum, paper_dense};
use trunksvd::gen::sparse::{generate, SparseSpec};
use trunksvd::la::mat::Mat;
use trunksvd::la::workspace::{Plan, Workspace};
use trunksvd::runtime::Runtime;
use trunksvd::util::rng::Rng;
use trunksvd::util::scalar::Scalar;
use trunksvd::Csr;

/// Backend under conformance test.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Kind {
    Cpu,
    Xla,
    Staged,
}

/// Construct a backend of the given kind over an operand. The XLA
/// backend runs over a host-only runtime (no PJRT client), which pins
/// its fallback ("stub") paths deterministically regardless of whether
/// AOT artifacts exist in the environment.
pub fn make<S: Scalar>(kind: Kind, op: Operand<S>) -> Box<dyn Backend<S>> {
    match kind {
        Kind::Cpu => Box::new(CpuBackend::new(op)),
        Kind::Staged => Box::new(StagedBackend::new(op)),
        Kind::Xla => {
            let rt = Rc::new(Runtime::host_only());
            let be = XlaBackend::new(rt, op).expect("host-only xla always constructs");
            Box::new(be)
        }
    }
}

/// ε-scaled relative tolerance for kernel parity over `dim`-length
/// accumulations.
pub fn kernel_tol<S: Scalar>(dim: usize) -> f64 {
    S::EPSILON.to_f64() * 32.0 * (dim.max(1) as f64).sqrt()
}

/// Per-dtype end-to-end residual targets `(dense, sparse)` — fp32 is
/// held to the paper's single-precision accuracy class, fp64 to the
/// reference class the repo's existing algorithm tests pin.
pub fn e2e_targets<S: Scalar>() -> (f64, f64) {
    if S::DTYPE == "f32" {
        (1e-3, 1e-2)
    } else {
        (1e-8, 1e-5)
    }
}

fn assert_close<S: Scalar>(what: &str, got: &Mat<S>, want: &Mat<S>, tol: f64) {
    assert_eq!((got.rows(), got.cols()), (want.rows(), want.cols()), "{what} shape");
    let scale = 1.0 + want.fro_norm().to_f64();
    let diff = got.max_abs_diff(want).to_f64();
    assert!(diff <= tol * scale, "{what}: diff {diff:.3e} > tol {:.3e}", tol * scale);
}

fn sparse_fixture<S: Scalar>(seed: u64) -> Csr<S> {
    let spec = SparseSpec { rows: 150, cols: 80, nnz: 2200, seed, ..Default::default() };
    generate(&spec).cast()
}

/// Well-conditioned lower-triangular b×b factor for the TRSM parity leg.
fn lower_factor<S: Scalar>(b: usize, rng: &mut Rng) -> Mat<S> {
    let g: Mat<S> = Mat::randn(b, b, rng);
    Mat::from_fn(b, b, |i, j| {
        if i == j {
            S::from_f64(1.0 + i as f64)
        } else if i > j {
            S::from_f64(0.25) * g.at(i, j)
        } else {
            S::ZERO
        }
    })
}

/// Battery 1: every primitive op vs the CPU reference, sparse + dense.
pub fn op_parity_battery<S: Scalar>(kind: Kind) {
    for sparse in [true, false] {
        let (op, label): (Operand<S>, &str) = if sparse {
            (Operand::sparse(sparse_fixture::<S>(31)), "sparse")
        } else {
            let mut rng = Rng::new(32);
            (Operand::Dense(Mat::randn(150, 80, &mut rng)), "dense")
        };
        let (m, n) = op.shape();
        let mut be = make(kind, op.clone());
        let mut cpu = CpuBackend::new(op);
        let mut rng = Rng::new(33);
        let tol = kernel_tol::<S>(m.max(n));

        // A·X and (twice, to engage cached-transpose/staged paths) Aᵀ·X.
        let x: Mat<S> = Mat::randn(n, 6, &mut rng);
        assert_close(
            &format!("{label} apply_a"),
            &be.apply_a(x.as_ref()),
            &cpu.apply_a(x.as_ref()),
            tol,
        );
        let z: Mat<S> = Mat::randn(m, 6, &mut rng);
        for pass in 0..2 {
            assert_close(
                &format!("{label} apply_at pass {pass}"),
                &be.apply_at(z.as_ref()),
                &cpu.apply_at(z.as_ref()),
                tol,
            );
        }

        // Gram, projection, update, TRSM, GEMM.
        let q: Mat<S> = Mat::randn(m, 8, &mut rng);
        assert_close(
            &format!("{label} gram"),
            &be.gram(q.as_ref()),
            &cpu.gram(q.as_ref()),
            tol,
        );
        let p: Mat<S> = Mat::randn(m, 12, &mut rng);
        let h_b = be.proj(p.as_ref(), q.as_ref());
        let h_c = cpu.proj(p.as_ref(), q.as_ref());
        assert_close(&format!("{label} proj"), &h_b, &h_c, tol);
        let mut qb = q.clone();
        let mut qc = q.clone();
        be.subtract_proj(qb.as_mut(), p.as_ref(), h_b.as_ref());
        cpu.subtract_proj(qc.as_mut(), p.as_ref(), h_c.as_ref());
        assert_close(&format!("{label} subtract_proj"), &qb, &qc, tol);
        let l = lower_factor::<S>(8, &mut rng);
        let mut tb = q.clone();
        let mut tc = q.clone();
        be.tri_solve_right(tb.as_mut(), l.as_ref());
        cpu.tri_solve_right(tc.as_mut(), l.as_ref());
        assert_close(&format!("{label} tri_solve_right"), &tb, &tc, tol);
        let g1: Mat<S> = Mat::randn(m, 10, &mut rng);
        let g2: Mat<S> = Mat::randn(10, 7, &mut rng);
        assert_close(
            &format!("{label} gemm_nn"),
            &be.gemm_nn(g1.as_ref(), g2.as_ref()),
            &cpu.gemm_nn(g1.as_ref(), g2.as_ref()),
            tol,
        );

        // copy_into is a semantic copy on every backend.
        let src: Mat<S> = Mat::randn(m, 4, &mut rng);
        let mut dst: Mat<S> = Mat::zeros(m, 4);
        be.copy_into(src.as_ref(), dst.as_mut());
        assert_eq!(dst.data(), src.data(), "{label} copy_into is exact");

        // Fused orthogonalization kernels (value wrappers drive the
        // *_into forms with a throwaway workspace).
        let y0: Mat<S> = Mat::randn(m, 8, &mut rng);
        let mut yb = y0.clone();
        let mut yc = y0.clone();
        let rb = be.orth_cholqr2(&mut yb).unwrap();
        let rc = cpu.orth_cholqr2(&mut yc).unwrap();
        assert_close(&format!("{label} cholqr2 Q"), &yb, &yc, tol * 16.0);
        assert_close(&format!("{label} cholqr2 R"), &rb, &rc, tol * 16.0);
        let hist = {
            let mut hpanel: Mat<S> = Mat::randn(m, 8, &mut rng);
            cpu.orth_cholqr2(&mut hpanel).unwrap();
            hpanel
        };
        let w0: Mat<S> = Mat::randn(m, 8, &mut rng);
        let mut wb = w0.clone();
        let mut wc = w0.clone();
        let (hb, rb) = be.orth_cgs_cqr2(&mut wb, hist.as_ref()).unwrap();
        let (hc, rc) = cpu.orth_cgs_cqr2(&mut wc, hist.as_ref()).unwrap();
        assert_close(&format!("{label} cgs_cqr2 Q"), &wb, &wc, tol * 16.0);
        assert_close(&format!("{label} cgs_cqr2 H"), &hb, &hc, tol * 16.0);
        assert_close(&format!("{label} cgs_cqr2 R"), &rb, &rc, tol * 16.0);
    }
}

/// Battery 2: plan-lifecycle rules.
pub fn lifecycle_battery<S: Scalar>(kind: Kind) {
    // (a) Ops before any plan() must work (lazy staging / fallback).
    let a = sparse_fixture::<S>(41);
    let (m, n) = (a.rows(), a.cols());
    let mut be = make(kind, Operand::sparse(a));
    let mut rng = Rng::new(42);
    let x: Mat<S> = Mat::randn(n, 3, &mut rng);
    let y = be.apply_a(x.as_ref());
    assert_eq!((y.rows(), y.cols()), (m, 3), "unplanned op must run");

    // (b) One backend, one workspace, two planned solves: bitwise
    // reproducible (dense operand — no adaptive-transpose timing state).
    let prob = paper_dense(96, 32, 5);
    let ad: Mat<S> = prob.a.cast();
    let opts = LancSvdOpts { r: 16, p: 2, b: 8, wanted: 4, ..Default::default() };
    let ws: Workspace<S> = Workspace::new(Plan::lancsvd(96, 32, 16, 2, 8));
    let mut be = make(kind, Operand::Dense(ad));
    let s1 = lancsvd_with(be.as_mut(), &opts, &ws).unwrap();
    let s2 = lancsvd_with(be.as_mut(), &opts, &ws).unwrap();
    assert_eq!(s1.sigma, s2.sigma, "workspace-reuse sigmas must reproduce bitwise");
    assert_eq!(s1.u.data(), s2.u.data(), "workspace-reuse U must reproduce bitwise");
    assert_eq!(s1.v.data(), s2.v.data(), "workspace-reuse V must reproduce bitwise");

    // (c) Re-plan on shape change: the same backend accepts a larger
    // plan and still meets the residual target.
    let opts2 = LancSvdOpts { r: 24, p: 3, b: 8, wanted: 6, ..Default::default() };
    let ws2: Workspace<S> = Workspace::new(Plan::lancsvd(96, 32, 24, 3, 8));
    let s3 = lancsvd_with(be.as_mut(), &opts2, &ws2).unwrap();
    let mut check = CpuBackend::new_dense(prob.a.cast::<S>());
    let res = residuals(&mut check, &s3, 6);
    let (dense_target, _) = e2e_targets::<S>();
    assert!(res.iter().all(|&r| r < dense_target), "re-planned solve residuals {res:?}");

    // (d) A mismatched workspace is rejected, not misused.
    assert!(lancsvd_with(be.as_mut(), &opts, &ws2).is_err(), "plan mismatch must error");
}

/// Battery 3: end-to-end residual targets on the scenario zoo.
pub fn e2e_battery<S: Scalar>(kind: Kind) {
    let (dense_target, sparse_target) = e2e_targets::<S>();

    // Prescribed geometric decay: leading sigmas must be recovered.
    let sigma: Vec<f64> = (0..16).map(|i| 2.0f64.powi(-(i as i32))).collect();
    let prob = dense_with_spectrum(100, 16, &sigma, 1);
    let mut be = make(kind, Operand::Dense(prob.a.cast::<S>()));
    let opts = LancSvdOpts { r: 16, p: 6, b: 8, wanted: 6, ..Default::default() };
    let svd = lancsvd(be.as_mut(), &opts).unwrap();
    for i in 0..6 {
        let rel = (svd.sigma[i].to_f64() - sigma[i]).abs() / sigma[i];
        assert!(rel < dense_target.sqrt(), "sigma_{i} rel err {rel:.3e}");
    }
    let mut check = CpuBackend::new_dense(prob.a.cast::<S>());
    let res = residuals(&mut check, &svd, 6);
    assert!(res.iter().all(|&r| r < dense_target), "decay-dense residuals {res:?}");

    // The paper's Eq. 15/16 dense problem.
    let prob = paper_dense(120, 40, 7);
    let mut be = make(kind, Operand::Dense(prob.a.cast::<S>()));
    let opts = LancSvdOpts { r: 16, p: 4, b: 8, wanted: 5, ..Default::default() };
    let svd = lancsvd(be.as_mut(), &opts).unwrap();
    let mut check = CpuBackend::new_dense(prob.a.cast::<S>());
    let res = residuals(&mut check, &svd, 5);
    assert!(res.iter().all(|&r| r < dense_target), "paper-dense residuals {res:?}");

    // Sparse suite scenarios: a default-profile matrix and a heavy-row
    // skewed one, through both algorithms.
    for (seed, skew) in [(51u64, 0.8f64), (52, 1.6)] {
        let spec = SparseSpec { rows: 180, cols: 90, nnz: 2600, seed, skew, ..Default::default() };
        let a: Csr<S> = generate(&spec).cast();
        let mut be = make(kind, Operand::sparse(a.clone()));
        let opts = LancSvdOpts { r: 24, p: 3, b: 8, wanted: 6, seed: 3, ..Default::default() };
        let svd = lancsvd(be.as_mut(), &opts).unwrap();
        let mut check = CpuBackend::new_sparse(a.clone());
        let res = residuals(&mut check, &svd, 6);
        assert!(
            res.iter().all(|&r| r < sparse_target),
            "lancsvd sparse (skew {skew}) residuals {res:?}"
        );

        let mut be = make(kind, Operand::sparse(a.clone()));
        let opts = RandSvdOpts { r: 12, p: 16, b: 4, seed: 3, ..Default::default() };
        let svd = randsvd(be.as_mut(), &opts).unwrap();
        let mut check = CpuBackend::new_sparse(a);
        let res = residuals(&mut check, &svd, 4);
        assert!(
            res.iter().all(|&r| r < sparse_target),
            "randsvd sparse (skew {skew}) residuals {res:?}"
        );
    }
}
