//! Serve-mode battery: concurrency/determinism, fault injection,
//! cache keying, deadline/backpressure, restart-boundary yields, the
//! line protocol, and the committed smoke-workload replay.
//!
//! None of these tests mutate the global compute pool, so they run
//! safely in parallel within this binary; determinism assertions hold
//! because the pool's thread count is fixed for the process and its
//! partitioning is schedule-independent.

use std::sync::Arc;
use std::time::{Duration, Instant};

use trunksvd::backend::Operand;
use trunksvd::coordinator::driver::{Algo, Params};
use trunksvd::gen::sparse::{generate, SparseSpec};
use trunksvd::runtime::serve::{
    assert_reuse_gates, replay_file, serve_lines, JobDefaults, JobResult, JobSpec, JobStatus,
    ReplayOverrides, ServeConfig, Server,
};
use trunksvd::sparse::shard;
use trunksvd::util::json;
use trunksvd::util::scalar::DType;

fn tiny(dtype: DType) -> Params {
    Params { r: 8, p: 2, b: 4, seed: 13, tol: None, wanted: 4, dtype, ..Params::default() }
}

fn sparse_op(rows: usize, cols: usize, nnz: usize, seed: u64) -> Operand<f64> {
    Operand::sparse(generate(&SparseSpec { rows, cols, nnz, seed, ..Default::default() }))
}

fn sigma_bits(r: &JobResult) -> Vec<u64> {
    r.sigma.iter().map(|x| x.to_bits()).collect()
}

fn tmp(name: &str) -> String {
    let d = std::env::temp_dir().join("trunksvd_serve_tests").join(name);
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d.to_str().unwrap().to_string()
}

/// Satellite 1: N concurrent submitters × {lancsvd, randsvd} × {f32, f64}
/// against one shared operand. Every repetition of a combo must return
/// bitwise-identical singular values, and every shape class must see at
/// least one warm workspace reuse.
#[test]
fn concurrent_submitters_bitwise_identical_per_combo() {
    const COMBOS: [(Algo, DType); 4] = [
        (Algo::Lanc, DType::F64),
        (Algo::Lanc, DType::F32),
        (Algo::Rand, DType::F64),
        (Algo::Rand, DType::F32),
    ];
    const SUBMITTERS: usize = 4;
    const REPS: usize = 2;

    let mut server =
        Server::new(ServeConfig { solvers: 3, queue_cap: 64, ..ServeConfig::default() });
    let op = sparse_op(300, 120, 4000, 5);

    let mut all: Vec<(usize, JobResult)> = Vec::new();
    std::thread::scope(|s| {
        let mut handles = Vec::new();
        for t in 0..SUBMITTERS {
            let server = &server;
            let op = op.clone();
            handles.push(s.spawn(move || {
                let mut out = Vec::new();
                for rep in 0..REPS {
                    for (ci, (algo, dt)) in COMBOS.iter().enumerate() {
                        let spec = JobSpec::new(
                            format!("t{t}-r{rep}-c{ci}"),
                            *algo,
                            tiny(*dt),
                            op.clone(),
                        );
                        out.push((ci, server.submit(spec).wait()));
                    }
                }
                out
            }));
        }
        for h in handles {
            all.extend(h.join().unwrap());
        }
    });

    let total = SUBMITTERS * REPS * COMBOS.len();
    assert_eq!(all.len(), total);
    for (_, r) in &all {
        assert_eq!(r.status, JobStatus::Done, "job {} failed: {:?}", r.id, r.status);
        assert_eq!(r.sigma.len(), 4, "job {}", r.id);
        for w in r.sigma.windows(2) {
            assert!(w[0] >= w[1], "sigma not descending in {}: {:?}", r.id, r.sigma);
        }
    }
    // Bitwise identity within each combo, regardless of which worker
    // ran which repetition.
    for ci in 0..COMBOS.len() {
        let group: Vec<&JobResult> =
            all.iter().filter(|(c, _)| *c == ci).map(|(_, r)| r).collect();
        assert_eq!(group.len(), SUBMITTERS * REPS);
        let reference = sigma_bits(group[0]);
        for r in &group[1..] {
            assert_eq!(
                sigma_bits(r),
                reference,
                "combo {ci}: {} disagrees with {}",
                r.id,
                group[0].id
            );
        }
    }

    server.shutdown();
    let c = server.counters();
    assert_eq!(c.completed, total as u64);
    assert_eq!(c.failed, 0);
    assert_eq!(c.operand_rework, 0);
    // The cache key is (operand identity, dtype, backend) — algo is
    // deliberately excluded (the staged operand is algo-independent) —
    // so the 4 combos collapse onto 2 keys, each built exactly once.
    assert_eq!(c.operand_misses, 2, "{c:?}");
    assert_eq!(c.operand_hits, (total - 2) as u64, "{c:?}");

    // 4 distinct shape classes (plan kind × dtype), each warm at least once.
    let classes = server.class_stats();
    assert_eq!(classes.len(), 4, "{classes:?}");
    let mut created = 0;
    let mut warm = 0;
    for (label, st, _free) in &classes {
        assert!(st.created >= 1, "class {label} never built an arena");
        assert!(st.warm_reuses >= 1, "class {label} never reused a warm arena");
        created += st.created;
        warm += st.warm_reuses;
    }
    assert_eq!(created + warm, total as u64);
}

/// Satellite 2a: validation failures (r not a multiple of b; r beyond
/// min(m, n); inadmissible shard resident-cap) come back as `Failed`
/// without wedging the server — a subsequent well-formed job succeeds.
#[test]
fn validation_failures_fail_cleanly_and_server_stays_healthy() {
    let mut server = Server::new(ServeConfig { solvers: 2, ..ServeConfig::default() });
    let op = sparse_op(200, 80, 2500, 9);

    let bad_rb = server
        .submit(JobSpec::new(
            "bad-rb",
            Algo::Lanc,
            Params { r: 10, b: 4, ..tiny(DType::F64) },
            op.clone(),
        ))
        .wait();
    assert!(matches!(bad_rb.status, JobStatus::Failed(_)), "{:?}", bad_rb.status);

    let bad_r = server
        .submit(JobSpec::new(
            "bad-r",
            Algo::Lanc,
            Params { r: 96, b: 8, ..tiny(DType::F64) },
            op.clone(),
        ))
        .wait();
    assert!(matches!(bad_r.status, JobStatus::Failed(_)), "{:?}", bad_r.status);

    // Sharded operand whose resident cap is one byte below the largest
    // shard: the eager staging done at backend build must surface a
    // clean error, not a panic.
    let dir = tmp("inadmissible_cap");
    let a = generate(&SparseSpec { rows: 200, cols: 80, nnz: 2500, seed: 9, ..Default::default() });
    let sd = Arc::new(shard::write_shards_from_csr(&dir, &a, 4).unwrap());
    let maxb = sd.max_resident_bytes::<f64>();
    let bad_cap = server
        .submit(JobSpec::new(
            "bad-cap",
            Algo::Lanc,
            tiny(DType::F64),
            Operand::sharded(Arc::clone(&sd), maxb - 1),
        ))
        .wait();
    match &bad_cap.status {
        JobStatus::Failed(msg) => {
            assert!(msg.starts_with("backend build:"), "unexpected failure text: {msg}")
        }
        other => panic!("expected Failed, got {other:?}"),
    }

    let ok = server.submit(JobSpec::new("ok", Algo::Lanc, tiny(DType::F64), op)).wait();
    assert_eq!(ok.status, JobStatus::Done, "{:?}", ok.status);

    server.shutdown();
    let c = server.counters();
    assert_eq!(c.failed, 3);
    assert_eq!(c.completed, 1);
    assert_eq!(c.rejected_backpressure + c.rejected_deadline, 0);
}

/// Satellite 2b: a mid-solve panic is contained by the worker — the
/// job reports `Failed`, the poisoned workspace and half-built backend
/// are discarded (never returned to the pools), and the next job on
/// the same class + operand rebuilds (counted as rework) and succeeds.
#[test]
fn mid_solve_panic_contained_and_rework_counted() {
    let mut server = Server::new(ServeConfig { solvers: 2, ..ServeConfig::default() });
    let op = sparse_op(240, 100, 3000, 17);

    let mut boom = JobSpec::new("boom", Algo::Lanc, tiny(DType::F64), op.clone());
    boom.inject_panic = true;
    let r = server.submit(boom).wait();
    match &r.status {
        JobStatus::Failed(msg) => assert!(msg.contains("panicked"), "{msg}"),
        other => panic!("expected Failed, got {other:?}"),
    }

    let after = server.submit(JobSpec::new("after", Algo::Lanc, tiny(DType::F64), op)).wait();
    assert_eq!(after.status, JobStatus::Done, "{:?}", after.status);
    assert!(!after.operand_hit, "post-panic job must rebuild the backend, not hit a stale slot");

    server.shutdown();
    let c = server.counters();
    assert_eq!(c.failed, 1);
    assert_eq!(c.completed, 1);
    assert!(c.ws_discarded >= 1, "{c:?}");
    assert_eq!(c.operand_rework, 1, "{c:?}");
}

/// Satellite 3: cache-keying properties, asserted on counters (not
/// timing). Arc-clones of one `Csr` share a generation stamp (hit); a
/// regenerated bit-identical matrix mints a fresh generation (miss,
/// conservatively — but the math agrees bitwise); a plan differing
/// only in `p` keeps the operand key yet lands in a distinct shape
/// class (cold arena).
#[test]
fn cache_keying_generation_arc_and_shape_class() {
    let mut server = Server::new(ServeConfig { solvers: 1, ..ServeConfig::default() });
    let spec = SparseSpec { rows: 220, cols: 90, nnz: 2600, seed: 23, ..Default::default() };
    let op = Operand::sparse(generate(&spec));

    let a = server.submit(JobSpec::new("a", Algo::Lanc, tiny(DType::F64), op.clone())).wait();
    let b = server.submit(JobSpec::new("b", Algo::Lanc, tiny(DType::F64), op.clone())).wait();
    let c = server
        .submit(JobSpec::new("c", Algo::Lanc, tiny(DType::F64), Operand::sparse(generate(&spec))))
        .wait();
    for r in [&a, &b, &c] {
        assert_eq!(r.status, JobStatus::Done, "job {}: {:?}", r.id, r.status);
    }
    assert!(!a.operand_hit, "first sight of a generation must miss");
    assert!(b.operand_hit, "Arc-clone shares the generation stamp and must hit");
    assert!(!c.operand_hit, "a regenerated Csr mints a fresh generation and must miss");
    assert!(b.workspace_warm && c.workspace_warm, "b={} c={}", b.workspace_warm, c.workspace_warm);
    assert_eq!(sigma_bits(&a), sigma_bits(&b));
    assert_eq!(sigma_bits(&a), sigma_bits(&c), "identical content must agree bitwise");

    // Same operand, padding p bumped: operand cache hits, workspace
    // pool must NOT serve a warm arena from the old class.
    let d = server
        .submit(JobSpec::new("d", Algo::Lanc, Params { p: 3, ..tiny(DType::F64) }, op))
        .wait();
    assert_eq!(d.status, JobStatus::Done, "{:?}", d.status);
    assert!(d.operand_hit, "p is not part of the operand key");
    assert!(!d.workspace_warm, "p IS part of the shape class; arena must be cold");

    server.shutdown();
    let c = server.counters();
    assert_eq!(c.operand_hits, 2, "{c:?}");
    assert_eq!(c.operand_misses, 2, "{c:?}");
    assert_eq!(c.operand_rework, 0, "{c:?}");
    assert_eq!(server.class_stats().len(), 2, "{:?}", server.class_stats());
}

/// Satellite 4: with one solver held busy and a queue capacity of 1,
/// an overflow job gets a typed backpressure rejection and a queued
/// job whose deadline lapses in the queue gets a typed deadline
/// rejection — both recorded as `rejected`, never `failed`.
#[test]
fn deadline_and_backpressure_rejections_are_typed() {
    let mut server =
        Server::new(ServeConfig { solvers: 1, queue_cap: 1, ..ServeConfig::default() });
    let op = sparse_op(150, 60, 1500, 29);

    let mut slow = JobSpec::new("slow", Algo::Lanc, tiny(DType::F64), op.clone());
    slow.inject_delay = Some(Duration::from_millis(600));
    let h_slow = server.submit(slow);

    // Wait for the worker to actually dequeue the slow job so the
    // queue is empty; polling the depth (not sleeping a fixed time)
    // keeps this deterministic on slow CI machines.
    let t0 = Instant::now();
    while server.queue_depth() > 0 {
        assert!(t0.elapsed() < Duration::from_secs(10), "slow job never dequeued");
        std::thread::sleep(Duration::from_millis(2));
    }

    let mut stale = JobSpec::new("stale", Algo::Lanc, tiny(DType::F64), op.clone());
    stale.deadline = Some(Duration::from_millis(50));
    let h_stale = server.submit(stale); // fills the single queue slot

    let burst = server.submit(JobSpec::new("burst", Algo::Lanc, tiny(DType::F64), op)).wait();
    match &burst.status {
        JobStatus::Rejected(msg) => assert!(msg.contains("queue full"), "{msg}"),
        other => panic!("expected backpressure rejection, got {other:?}"),
    }

    let stale = h_stale.wait();
    match &stale.status {
        JobStatus::Rejected(msg) => assert!(msg.contains("deadline"), "{msg}"),
        other => panic!("expected deadline rejection, got {other:?}"),
    }

    let slow = h_slow.wait();
    assert_eq!(slow.status, JobStatus::Done, "{:?}", slow.status);

    server.shutdown();
    let c = server.counters();
    assert_eq!(c.rejected_backpressure, 1, "{c:?}");
    assert_eq!(c.rejected_deadline, 1, "{c:?}");
    assert_eq!(c.completed, 1, "{c:?}");
    assert_eq!(c.failed, 0, "rejections must not be recorded as failures: {c:?}");
}

/// Tentpole invariant: LancSVD restart boundaries are cooperative
/// yield points inside serve workers; `tol: None` with `p = 3` runs
/// all three outer iterations, yielding at j = 2 and j = 3.
#[test]
fn restart_boundaries_yield_and_are_counted() {
    let mut server = Server::new(ServeConfig { solvers: 1, ..ServeConfig::default() });
    let r = server
        .submit(JobSpec::new(
            "yields",
            Algo::Lanc,
            Params { p: 3, ..tiny(DType::F64) },
            sparse_op(200, 80, 2400, 31),
        ))
        .wait();
    assert_eq!(r.status, JobStatus::Done, "{:?}", r.status);
    assert_eq!(r.iters, 3);
    server.shutdown();
    let c = server.counters();
    assert_eq!(c.restart_yields, 2, "{c:?}");
}

/// Satellite 4 (protocol surface): the line protocol reports `ok`,
/// `rejected` (deadline 0) and `failed` (unparseable algo) as three
/// distinct statuses on the output stream.
#[test]
fn protocol_reports_rejections_distinct_from_failures() {
    let mut server = Server::new(ServeConfig { solvers: 2, ..ServeConfig::default() });
    let defaults = JobDefaults {
        algo: Algo::Lanc,
        params: Params { r: 8, p: 2, b: 4, wanted: 3, ..Params::default() },
    };
    let operand = r#"{"sparse": {"rows": 150, "cols": 60, "nnz": 1400, "seed": 3}}"#;
    let lines = [
        format!(r#"{{"id": "good", "operand": {operand}}}"#),
        format!(r#"{{"id": "late", "deadline_ms": 0, "operand": {operand}}}"#),
        format!(r#"{{"id": "broken", "algo": "nope", "operand": {operand}}}"#),
    ];
    let input = lines.join("\n") + "\n";
    let mut out = Vec::new();
    serve_lines(&server, &defaults, &input, &mut out).unwrap();
    server.shutdown();

    let text = String::from_utf8(out).unwrap();
    let mut by_status = std::collections::HashMap::new();
    for line in text.lines() {
        let v = json::parse(line).unwrap();
        let tag = v.get("status").unwrap().as_str().unwrap().to_string();
        *by_status.entry(tag).or_insert(0u32) += 1;
    }
    assert_eq!(by_status.get("ok"), Some(&1), "{text}");
    assert_eq!(by_status.get("rejected"), Some(&1), "{text}");
    assert_eq!(by_status.get("failed"), Some(&1), "{text}");
}

/// Satellite 5 backing test: replaying the committed smoke workload
/// (repeat = 2 over one warm server) is bitwise deterministic and
/// passes every reuse gate; the written report agrees.
#[test]
fn replay_smoke_twice_is_bitwise_and_reuse_gated() {
    let workload = concat!(env!("CARGO_MANIFEST_DIR"), "/config/workloads/smoke.json");
    let out_path =
        std::env::temp_dir().join(format!("trunksvd_bench_serve_{}.json", std::process::id()));
    let out = out_path.to_str().unwrap().to_string();

    let s = replay_file(workload, Some(&out), &ReplayOverrides::default()).unwrap();
    assert_eq!(s.runs, 2);
    assert_eq!(s.jobs_per_run, 7);
    assert!(s.deterministic);
    assert_reuse_gates(&s.counters).unwrap();

    let rep = json::parse_file(&out).unwrap();
    let det = rep.get("determinism").unwrap();
    assert_eq!(det.get("bitwise_identical").unwrap().as_bool(), Some(true));
    assert!(rep.get("counters").unwrap().get("operand_hits").unwrap().as_f64().unwrap() >= 1.0);
    assert!(rep.get("counters").unwrap().get("ws_warm_reuses").unwrap().as_f64().unwrap() >= 1.0);
    let _ = std::fs::remove_file(&out_path);
}

/// Streaming-tenant battery: interleaved append+query traffic from 4
/// submitters shares ONE warm incremental basis. Appends are fungible
/// (each absorbs the *next* `cols` columns of the stream source), so
/// with a single solver any interleaving absorbs the same column
/// sequence: the post-append `cols_seen` values form exactly
/// {12, 24, …, 96}, only the first append misses the cache, and the
/// finalized spectrum — plus every counter — is bitwise independent of
/// the submission interleaving.
#[test]
fn interleaved_append_query_streams_share_one_warm_basis() {
    use trunksvd::gen::dense::paper_dense;

    const SUBMITTERS: usize = 4;
    const APPENDS_EACH: usize = 2;
    const COLS: usize = 12;

    let params = tiny(DType::F64);
    let run_once = |tag: &str| {
        let mut server =
            Server::new(ServeConfig { solvers: 1, queue_cap: 64, ..ServeConfig::default() });
        let op = Operand::dense(paper_dense(120, 96, 31).a);

        let mut appends: Vec<JobResult> = Vec::new();
        let mut queries: Vec<JobResult> = Vec::new();
        std::thread::scope(|s| {
            let mut handles = Vec::new();
            for t in 0..SUBMITTERS {
                let server = &server;
                let op = op.clone();
                let params = params.clone();
                handles.push(s.spawn(move || {
                    let mut out = Vec::new();
                    for i in 0..APPENDS_EACH {
                        let a = server.submit(JobSpec::append(
                            format!("{tag}-t{t}-a{i}"),
                            "tenant",
                            params.clone(),
                            op.clone(),
                            COLS,
                        ));
                        // The query is submitted after this thread's
                        // append, so FIFO execution guarantees it sees
                        // a live basis.
                        let ar = a.wait();
                        let q = server
                            .submit(JobSpec::query(
                                format!("{tag}-t{t}-q{i}"),
                                "tenant",
                                params.clone(),
                                op.clone(),
                            ))
                            .wait();
                        out.push((ar, q));
                    }
                    out
                }));
            }
            for h in handles {
                for (a, q) in h.join().unwrap() {
                    appends.push(a);
                    queries.push(q);
                }
            }
        });

        for r in appends.iter().chain(&queries) {
            assert_eq!(r.status, JobStatus::Done, "job {}: {:?}", r.id, r.status);
        }
        // Fungible appends: the multiset of post-append stream lengths
        // is the full ladder, whatever the interleaving was.
        let mut lens: Vec<usize> = appends.iter().map(|r| r.cols_seen).collect();
        lens.sort_unstable();
        let ladder: Vec<usize> = (1..=SUBMITTERS * APPENDS_EACH).map(|i| i * COLS).collect();
        assert_eq!(lens, ladder, "append ladder broken");
        let misses = appends.iter().filter(|r| !r.operand_hit).count();
        assert_eq!(misses, 1, "exactly the first append may build the basis");
        for q in &queries {
            assert!(q.operand_hit, "query {} ran without a warm basis", q.id);
            assert!(q.cols_seen >= COLS && q.cols_seen % COLS == 0, "query {}", q.id);
        }

        let fin = server
            .submit(JobSpec::finalize(format!("{tag}-fin"), "tenant", params.clone(), op))
            .wait();
        assert_eq!(fin.status, JobStatus::Done, "{:?}", fin.status);
        assert_eq!(fin.cols_seen, SUBMITTERS * APPENDS_EACH * COLS);
        assert_eq!(fin.sigma.len(), 4, "{:?}", fin.sigma);
        for w in fin.sigma.windows(2) {
            assert!(w[0] >= w[1], "finalized sigma not descending: {:?}", fin.sigma);
        }

        server.shutdown();
        let c = server.counters();
        assert_eq!(c.failed, 0, "{c:?}");
        assert_eq!(c.operand_rework, 0, "{c:?}");
        assert_eq!(c.stream_appends, (SUBMITTERS * APPENDS_EACH) as u64, "{c:?}");
        assert_eq!(c.stream_queries, (SUBMITTERS * APPENDS_EACH) as u64, "{c:?}");
        (sigma_bits(&fin), c)
    };

    let (sig1, c1) = run_once("r1");
    let (sig2, c2) = run_once("r2");
    assert_eq!(sig1, sig2, "finalized spectrum depends on submission interleaving");
    assert_eq!(
        (c1.completed, c1.operand_hits, c1.operand_misses, c1.stream_appends, c1.stream_queries),
        (c2.completed, c2.operand_hits, c2.operand_misses, c2.stream_appends, c2.stream_queries),
        "counters depend on submission interleaving: {c1:?} vs {c2:?}"
    );
}

/// Streaming-tenant fault containment: a panic mid-append discards the
/// torn basis entirely — the next append is a from-scratch rework (not
/// a resume of half-committed state, pinned by bitwise agreement with
/// the pre-panic append), and the server keeps serving queries and
/// ordinary solves afterwards.
#[test]
fn mid_append_panic_discards_torn_basis_and_rework_recovers() {
    use trunksvd::gen::dense::paper_dense;

    let mut server = Server::new(ServeConfig { solvers: 1, ..ServeConfig::default() });
    let op = Operand::dense(paper_dense(80, 48, 19).a);
    let params = tiny(DType::F64);

    let a1 =
        server.submit(JobSpec::append("a1", "tenant", params.clone(), op.clone(), 16)).wait();
    assert_eq!(a1.status, JobStatus::Done, "{:?}", a1.status);
    assert_eq!(a1.cols_seen, 16);

    let mut boom = JobSpec::append("boom", "tenant", params.clone(), op.clone(), 16);
    boom.inject_panic = true;
    let r = server.submit(boom).wait();
    match &r.status {
        JobStatus::Failed(msg) => assert!(msg.contains("append panicked"), "{msg}"),
        other => panic!("expected Failed, got {other:?}"),
    }

    // The torn basis is gone: the rework append restarts the stream at
    // column 0 and must reproduce a1 exactly.
    let a2 =
        server.submit(JobSpec::append("a2", "tenant", params.clone(), op.clone(), 16)).wait();
    assert_eq!(a2.status, JobStatus::Done, "{:?}", a2.status);
    assert!(!a2.operand_hit, "post-panic append must rebuild, not hit a torn slot");
    assert_eq!(a2.cols_seen, 16, "rework must restart the stream, not resume torn state");
    assert_eq!(sigma_bits(&a2), sigma_bits(&a1), "rework diverged from the original append");

    let q = server.submit(JobSpec::query("q", "tenant", params.clone(), op.clone())).wait();
    assert_eq!(q.status, JobStatus::Done, "{:?}", q.status);
    assert_eq!(sigma_bits(&q), sigma_bits(&a2));

    let solve = server.submit(JobSpec::new("solve", Algo::Lanc, params, op)).wait();
    assert_eq!(solve.status, JobStatus::Done, "server unhealthy after panic: {:?}", solve.status);

    server.shutdown();
    let c = server.counters();
    assert_eq!(c.failed, 1, "{c:?}");
    assert_eq!(c.completed, 4, "{c:?}");
    assert_eq!(c.operand_rework, 1, "{c:?}");
    assert!(c.ws_discarded >= 1, "{c:?}");
}

/// Fused-PR satellite: the `--socket` transport end-to-end. A detached
/// thread runs [`serve_unix`] on a temp socket; a client connects over
/// the unix socket and gets the same ok/rejected/failed triage as the
/// in-memory line protocol, and a second connection exercises the
/// cross-connection operand memo (same spec ⇒ server cache hit).
#[cfg(unix)]
#[test]
fn unix_socket_serve_triages_ok_rejected_failed() {
    use std::io::{BufRead, BufReader, Write};
    use std::net::Shutdown;
    use std::os::unix::net::UnixStream;
    use trunksvd::runtime::serve::serve_unix;

    let sock = format!("{}/serve.sock", tmp("socket"));
    // serve_unix accepts until the listener errors, so it outlives the
    // test: leak the server and let process teardown reap the daemon
    // thread (it blocks in accept() holding no per-test state).
    let server: &'static Server = Box::leak(Box::new(Server::new(ServeConfig {
        solvers: 2,
        queue_cap: 64,
        ..ServeConfig::default()
    })));
    let defaults = JobDefaults {
        algo: Algo::Lanc,
        params: Params { r: 8, p: 2, b: 4, wanted: 3, ..Params::default() },
    };
    {
        let sock = sock.clone();
        std::thread::spawn(move || {
            let _ = serve_unix(server, &sock, &defaults);
        });
    }
    // The listener binds on the daemon thread; connect with retry.
    let connect = || -> UnixStream {
        for _ in 0..500 {
            if let Ok(s) = UnixStream::connect(&sock) {
                return s;
            }
            std::thread::sleep(Duration::from_millis(10));
        }
        panic!("serve_unix never bound {sock}");
    };

    let operand = r#"{"sparse": {"rows": 150, "cols": 60, "nnz": 1400, "seed": 3}}"#;
    let mut c1 = connect();
    let lines = [
        // Well-formed solve ⇒ ok.
        format!(r#"{{"id": "good", "operand": {operand}}}"#),
        // deadline_ms 0 ⇒ typed rejection at admission.
        format!(r#"{{"id": "late", "deadline_ms": 0, "operand": {operand}}}"#),
        // Unknown algo ⇒ failed under a fresh protocol id (parse-stage
        // errors never reach the queue).
        format!(r#"{{"id": "broken", "algo": "nope", "operand": {operand}}}"#),
        // Not JSON at all ⇒ failed, and the connection stays up.
        "this is not json".to_string(),
    ];
    c1.write_all((lines.join("\n") + "\n").as_bytes()).unwrap();
    c1.shutdown(Shutdown::Write).unwrap();

    let mut results: Vec<(String, String)> = Vec::new();
    for line in BufReader::new(c1).lines() {
        let v = json::parse(&line.unwrap()).unwrap();
        results.push((
            v.get("id").unwrap().as_str().unwrap().to_string(),
            v.get("status").unwrap().as_str().unwrap().to_string(),
        ));
    }
    assert_eq!(results.len(), 4, "{results:?}");
    let status_of = |id: &str| {
        results.iter().find(|(i, _)| i == id).map(|(_, s)| s.as_str()).unwrap_or("<missing>")
    };
    assert_eq!(status_of("good"), "ok", "{results:?}");
    assert_eq!(status_of("late"), "rejected", "{results:?}");
    let parse_failures: Vec<&(String, String)> =
        results.iter().filter(|(i, _)| i.starts_with("job-")).collect();
    assert_eq!(parse_failures.len(), 2, "{results:?}");
    for (_, status) in &parse_failures {
        assert_eq!(status, "failed", "{results:?}");
    }

    // Second connection, same operand spec: the shared protocol memo
    // resolves it to the same Arc, so the server's operand cache hits.
    let mut c2 = connect();
    c2.write_all(format!("{{\"id\": \"warm\", \"operand\": {operand}}}\n").as_bytes()).unwrap();
    c2.shutdown(Shutdown::Write).unwrap();
    let mut warm = Vec::new();
    for line in BufReader::new(c2).lines() {
        warm.push(json::parse(&line.unwrap()).unwrap());
    }
    assert_eq!(warm.len(), 1, "second connection expected exactly one result");
    assert_eq!(warm[0].get("id").unwrap().as_str(), Some("warm"));
    assert_eq!(warm[0].get("status").unwrap().as_str(), Some("ok"));
    assert_eq!(
        warm[0].get("operand_hit").and_then(|v| v.as_bool()),
        Some(true),
        "cross-connection operand reuse must hit the cache"
    );
    assert!(warm[0].get("sigma").unwrap().as_arr().unwrap().len() >= 3);

    let c = server.counters();
    assert!(c.completed >= 2, "{c:?}");
    assert_eq!(c.rejected_deadline, 1, "{c:?}");
}
