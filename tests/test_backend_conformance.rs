//! Cross-backend conformance suite: one parameterized battery executed
//! over `CpuBackend`, `XlaBackend` (host-only runtime → stub/fallback
//! paths), and `StagedBackend`, in both element precisions — plus the
//! staged backend's transfer-ledger assertions (zero hot-loop panel
//! transfers per inner iteration; only POTRF/GESVD factor crossings).
//!
//! The shared batteries live in `tests/conformance/mod.rs`; the
//! normative contract they check is documented in `backend/mod.rs`
//! ("Backend author's contract").

mod conformance;

use conformance::{e2e_battery, lifecycle_battery, op_parity_battery, Kind};
use trunksvd::algo::lancsvd::lancsvd;
use trunksvd::algo::randsvd::randsvd;
use trunksvd::algo::{LancSvdOpts, RandSvdOpts};
use trunksvd::backend::staged::{Direction, LedgerTotals, StagedBackend};
use trunksvd::gen::sparse::{generate, SparseSpec};
use trunksvd::metrics::Block;
use trunksvd::util::scalar::Scalar;
use trunksvd::Csr;

// ---- battery 1: op-level parity vs CpuBackend --------------------------

#[test]
fn op_parity_cpu_f64() {
    op_parity_battery::<f64>(Kind::Cpu);
}

#[test]
fn op_parity_cpu_f32() {
    op_parity_battery::<f32>(Kind::Cpu);
}

#[test]
fn op_parity_xla_f64() {
    op_parity_battery::<f64>(Kind::Xla);
}

#[test]
fn op_parity_xla_f32() {
    op_parity_battery::<f32>(Kind::Xla);
}

#[test]
fn op_parity_staged_f64() {
    op_parity_battery::<f64>(Kind::Staged);
}

#[test]
fn op_parity_staged_f32() {
    op_parity_battery::<f32>(Kind::Staged);
}

// ---- battery 2: plan lifecycle -----------------------------------------

#[test]
fn lifecycle_cpu_f64() {
    lifecycle_battery::<f64>(Kind::Cpu);
}

#[test]
fn lifecycle_cpu_f32() {
    lifecycle_battery::<f32>(Kind::Cpu);
}

#[test]
fn lifecycle_xla_f64() {
    lifecycle_battery::<f64>(Kind::Xla);
}

#[test]
fn lifecycle_xla_f32() {
    lifecycle_battery::<f32>(Kind::Xla);
}

#[test]
fn lifecycle_staged_f64() {
    lifecycle_battery::<f64>(Kind::Staged);
}

#[test]
fn lifecycle_staged_f32() {
    lifecycle_battery::<f32>(Kind::Staged);
}

// ---- battery 3: end-to-end residual targets ----------------------------

#[test]
fn e2e_cpu_f64() {
    e2e_battery::<f64>(Kind::Cpu);
}

#[test]
fn e2e_cpu_f32() {
    e2e_battery::<f32>(Kind::Cpu);
}

#[test]
fn e2e_xla_f64() {
    e2e_battery::<f64>(Kind::Xla);
}

#[test]
fn e2e_xla_f32() {
    e2e_battery::<f32>(Kind::Xla);
}

#[test]
fn e2e_staged_f64() {
    e2e_battery::<f64>(Kind::Staged);
}

#[test]
fn e2e_staged_f32() {
    e2e_battery::<f32>(Kind::Staged);
}

// ---- battery 4: staged-backend transfer-ledger discipline --------------

fn ledger_fixture<S: Scalar>(seed: u64) -> Csr<S> {
    let spec = SparseSpec { rows: 140, cols: 70, nnz: 1800, seed, ..Default::default() };
    generate(&spec).cast()
}

fn randsvd_totals<S: Scalar>(p: usize) -> LedgerTotals {
    let mut be = StagedBackend::new_sparse(ledger_fixture::<S>(61));
    let opts = RandSvdOpts { r: 12, p, b: 4, seed: 9, ..Default::default() };
    randsvd(&mut be, &opts).unwrap();
    be.ledger().totals()
}

fn lancsvd_totals<S: Scalar>(p: usize) -> LedgerTotals {
    let mut be = StagedBackend::new_sparse(ledger_fixture::<S>(62));
    let opts = LancSvdOpts { r: 16, p, b: 8, wanted: 4, seed: 9, ..Default::default() };
    lancsvd(&mut be, &opts).unwrap();
    be.ledger().totals()
}

/// Zero hot-loop panel transfers, and the sanctioned factor crossings
/// grow *linearly* with the power-iteration count — i.e. each inner
/// iteration performs exactly the same fixed set of POTRF crossings and
/// nothing else crosses.
fn randsvd_ledger_linear<S: Scalar>() {
    let t4 = randsvd_totals::<S>(4);
    let t5 = randsvd_totals::<S>(5);
    let t8 = randsvd_totals::<S>(8);
    for t in [&t4, &t5, &t8] {
        assert_eq!(t.hot_panel_transfers, 0, "hot-loop panel transfer: {t:?}");
        assert_eq!(t.plans, 1);
        assert!(t.staged_operand_bytes > 0);
    }
    let per_iter_count = t5.hot_factor_crossings - t4.hot_factor_crossings;
    let per_iter_bytes = t5.hot_factor_bytes - t4.hot_factor_bytes;
    assert!(per_iter_count > 0, "POTRF crossings expected every iteration");
    assert_eq!(
        t8.hot_factor_crossings - t4.hot_factor_crossings,
        4 * per_iter_count,
        "factor crossings must be constant per inner iteration"
    );
    assert_eq!(
        t8.hot_factor_bytes - t4.hot_factor_bytes,
        4 * per_iter_bytes,
        "factor crossing volume must be constant per inner iteration"
    );
}

#[test]
fn staged_ledger_randsvd_linear_f64() {
    randsvd_ledger_linear::<f64>();
}

#[test]
fn staged_ledger_randsvd_linear_f32() {
    randsvd_ledger_linear::<f32>();
}

#[test]
fn staged_ledger_lancsvd_linear_f64() {
    let t2 = lancsvd_totals::<f64>(2);
    let t3 = lancsvd_totals::<f64>(3);
    let t5 = lancsvd_totals::<f64>(5);
    for t in [&t2, &t3, &t5] {
        assert_eq!(t.hot_panel_transfers, 0, "hot-loop panel transfer: {t:?}");
    }
    let per_outer = t3.hot_factor_crossings - t2.hot_factor_crossings;
    assert!(per_outer > 0, "POTRF crossings expected every outer iteration");
    assert_eq!(
        t5.hot_factor_crossings - t2.hot_factor_crossings,
        3 * per_outer,
        "factor crossings must be constant per outer iteration"
    );
}

/// Event-level view of the same contract: every panel-sized host→arena
/// upload happens in a setup/finalize window (operand staging, RNG
/// sketch `stage_in`) — never under a hot phase — while hot phases see
/// only factor-sized crossings.
#[test]
fn staged_ledger_panel_uploads_only_in_setup_windows() {
    let mut be = StagedBackend::new_sparse(ledger_fixture::<f64>(63));
    let opts = LancSvdOpts { r: 16, p: 3, b: 8, wanted: 4, seed: 5, ..Default::default() };
    lancsvd(&mut be, &opts).unwrap();
    let hot = [Block::MultA, Block::MultAt, Block::OrthM, Block::OrthN];
    let mut saw_panel_upload = false;
    let mut saw_hot_factor = false;
    for ev in be.ledger().events() {
        if ev.dir == Direction::ArenaToArena {
            continue;
        }
        if ev.panel {
            saw_panel_upload = true;
            assert!(
                !hot.contains(&ev.phase),
                "panel-sized {:?} transfer for op '{}' in hot phase {:?}",
                ev.dir,
                ev.op,
                ev.phase
            );
        } else if hot.contains(&ev.phase) {
            saw_hot_factor = true;
        }
    }
    assert!(saw_panel_upload, "operand staging / stage_in must appear in the ledger");
    assert!(saw_hot_factor, "POTRF factor crossings must appear in the ledger");
    // Arena staging memcpys exist on the Block-ELL path and are cheap to
    // distinguish from host crossings.
    assert_eq!(be.device_format(), Some("blockell"));
    assert!(be.ledger().totals().a2a_bytes > 0);
}

/// All three backends agree on the computed spectrum of one problem —
/// the cross-backend sanity check the per-op parity battery implies.
#[test]
fn backends_agree_on_spectrum() {
    use trunksvd::backend::Operand;
    let a = ledger_fixture::<f64>(64);
    let opts = LancSvdOpts { r: 16, p: 3, b: 8, wanted: 6, seed: 11, ..Default::default() };
    let sigmas: Vec<Vec<f64>> = [Kind::Cpu, Kind::Xla, Kind::Staged]
        .into_iter()
        .map(|kind| {
            let mut be = conformance::make::<f64>(kind, Operand::sparse(a.clone()));
            lancsvd(be.as_mut(), &opts).unwrap().sigma[..6].to_vec()
        })
        .collect();
    for (i, other) in sigmas.iter().enumerate().skip(1) {
        for j in 0..6 {
            assert!(
                (sigmas[0][j] - other[j]).abs() <= 1e-8 * sigmas[0][0],
                "backend {i} sigma_{j}: {} vs {}",
                other[j],
                sigmas[0][j]
            );
        }
    }
}
