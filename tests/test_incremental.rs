//! Incremental-SVD accuracy battery: the streaming/updatable factor's
//! contract, pinned at the integration level.
//!
//! * Block-arrival **order invariance** at a fixed rank cap — singular
//!   values are invariant under column permutation, so feeding the same
//!   column blocks in any order must land on the same spectrum (up to
//!   the discarded-tail perturbation), on both the cpu and staged
//!   backends, in both precisions.
//! * **σ-threshold truncation** agrees with a from-scratch dense Jacobi
//!   SVD: the threshold drops exactly the below-gap triplets and the
//!   surviving values match the batch reference.
//! * **Bitwise repeatability**: the same stream absorbed twice at a
//!   fixed pool thread count returns bit-identical singular values, for
//!   every count in {1, 2, default} × {f32, f64}.
//! * **Zero allocations**: after construction and warmup,
//!   [`IncrementalSvd::update_with`] against a planned workspace
//!   performs no heap allocation (counting global allocator, pool
//!   pinned to one thread so kernels take their serial fast paths).
//!
//! Tests that pin the pool serialize on `POOL_LOCK`, as in
//! `tests/test_workspace.rs`.

use std::sync::Mutex;

use trunksvd::algo::incremental::IncrementalSvd;
use trunksvd::backend::cpu::CpuBackend;
use trunksvd::backend::staged::StagedBackend;
use trunksvd::backend::Backend;
use trunksvd::gen::dense::dense_with_spectrum;
use trunksvd::la::mat::Mat;
use trunksvd::la::svd::jacobi_svd;
use trunksvd::la::workspace::Workspace;
use trunksvd::util::counting_alloc::{thread_alloc_bytes, thread_allocs, CountingAllocator};
use trunksvd::util::pool;
use trunksvd::util::scalar::Scalar;

#[global_allocator]
static ALLOC: CountingAllocator = CountingAllocator;

/// Serializes tests that pin the global pool thread count.
static POOL_LOCK: Mutex<()> = Mutex::new(());

struct PoolReset;
impl Drop for PoolReset {
    fn drop(&mut self) {
        pool::set_num_threads(0);
    }
}

const M: usize = 48;
const N: usize = 24;
const RANK_CAP: usize = 12;
const BLOCK: usize = 6;
const DOMINANT: usize = 8;

/// Dummy-operand backends: the incremental update only touches
/// workspace views, never the staged operand.
fn cpu<S: Scalar>() -> CpuBackend<S> {
    CpuBackend::new_dense(Mat::zeros(1, 1))
}
fn staged<S: Scalar>() -> StagedBackend<S> {
    StagedBackend::new_dense(Mat::zeros(1, 1))
}

/// Test stream: 8 dominant singular values above a tail parked at
/// 16·ε of the working precision — far below every gate used here (the
/// rank-12 cap discards only noise), but still above the projection's
/// rounding floor so the residual orthonormalization stays on its
/// non-degenerate path.
fn stream_matrix<S: Scalar>(seed: u64) -> (Mat<S>, Vec<f64>) {
    let tail = S::EPSILON.to_f64() * 16.0;
    let mut sigma: Vec<f64> = (0..DOMINANT).map(|i| 1.0 / (1.0 + i as f64)).collect();
    sigma.extend(std::iter::repeat(tail).take(N - DOMINANT));
    let prob = dense_with_spectrum(M, N, &sigma, seed);
    (prob.a.cast::<S>(), sigma)
}

/// Absorb the blocks of `a` (width `BLOCK`) in the given arrival order.
fn absorb<S: Scalar, B: Backend<S> + ?Sized>(
    be: &mut B,
    a: &Mat<S>,
    order: &[usize],
) -> IncrementalSvd<S> {
    let mut inc = IncrementalSvd::new(M, N, RANK_CAP, BLOCK, 0.0);
    let ws = Workspace::new(inc.plan());
    for &bi in order {
        inc.update_with(be, a.panel(bi * BLOCK, BLOCK), &ws).unwrap();
    }
    assert_eq!(inc.cols_seen(), N);
    assert!(inc.rank() <= RANK_CAP, "rank {} exceeds cap", inc.rank());
    inc
}

fn sigma_f64<S: Scalar>(inc: &IncrementalSvd<S>) -> Vec<f64> {
    inc.sigma().iter().map(|x| x.to_f64()).collect()
}

fn sigma_bits<S: Scalar>(inc: &IncrementalSvd<S>) -> Vec<u64> {
    inc.sigma().iter().map(|x| x.to_f64().to_bits()).collect()
}

/// Order-invariance core: three arrival orders of the same four blocks
/// must agree with each other and with the planted spectrum on the
/// dominant values, to `tol` relative.
fn order_invariance_on<S: Scalar, B: Backend<S> + ?Sized>(be: &mut B, tol: f64) -> Vec<f64> {
    const ORDERS: [[usize; 4]; 3] = [[0, 1, 2, 3], [3, 2, 1, 0], [2, 0, 3, 1]];
    let (a, truth) = stream_matrix::<S>(11);
    let mut spectra = Vec::new();
    for order in &ORDERS {
        let inc = absorb(be, &a, order);
        let s = sigma_f64(&inc);
        assert!(s.len() >= DOMINANT, "rank collapsed to {}", s.len());
        for i in 0..DOMINANT {
            let rel = (s[i] - truth[i]).abs() / truth[i];
            assert!(rel < tol, "order {order:?} sigma_{i}: {} vs {} ({rel:.3e})", s[i], truth[i]);
        }
        spectra.push(s);
    }
    for s in &spectra[1..] {
        for i in 0..DOMINANT {
            let rel = (s[i] - spectra[0][i]).abs() / spectra[0][i];
            assert!(rel < tol, "arrival orders disagree at sigma_{i} (rel {rel:.3e})");
        }
    }
    spectra.remove(0)
}

/// Satellite battery 1: block-arrival order invariance at a fixed rank
/// cap, across {cpu, staged} × {f32, f64}; the two backends must also
/// agree with each other.
#[test]
fn order_invariance_across_backends_and_dtypes() {
    let _guard = POOL_LOCK.lock().unwrap_or_else(|p| p.into_inner());
    let _reset = PoolReset;
    pool::set_num_threads(1);

    let cpu64 = order_invariance_on::<f64, _>(&mut cpu(), 1e-9);
    let stg64 = order_invariance_on::<f64, _>(&mut staged(), 1e-9);
    for i in 0..DOMINANT {
        let rel = (cpu64[i] - stg64[i]).abs() / cpu64[i];
        assert!(rel < 1e-9, "cpu/staged f64 disagree at sigma_{i} (rel {rel:.3e})");
    }

    let cpu32 = order_invariance_on::<f32, _>(&mut cpu(), 2e-3);
    let stg32 = order_invariance_on::<f32, _>(&mut staged(), 2e-3);
    for i in 0..DOMINANT {
        let rel = (cpu32[i] - stg32[i]).abs() / cpu32[i];
        assert!(rel < 2e-3, "cpu/staged f32 disagree at sigma_{i} (rel {rel:.3e})");
    }
}

/// Satellite battery 2: the σ-threshold variant truncates exactly the
/// below-gap triplets and the survivors match a from-scratch dense
/// Jacobi SVD of the full matrix.
fn sigma_threshold_matches_reference<S: Scalar>(tol: f64) {
    // Hard spectral gap: 3 values at O(1), the rest five decades down —
    // but above the σ-threshold noise floor of the working precision.
    let mut sigma = vec![1.0, 0.7, 0.5];
    sigma.extend(std::iter::repeat(1e-5).take(N - 3));
    let a = dense_with_spectrum(M, N, &sigma, 3).a.cast::<S>();

    let mut inc = IncrementalSvd::<S>::new(M, N, N, BLOCK, 1e-3);
    let ws = Workspace::new(inc.plan());
    let mut be = cpu::<S>();
    for j0 in (0..N).step_by(BLOCK) {
        inc.update_with(&mut be, a.panel(j0, BLOCK), &ws).unwrap();
    }

    // The threshold 1e-3·σ₁ sits inside the gap: everything at 1e-5
    // must be gone, all three dominant triplets must survive.
    assert_eq!(inc.rank(), 3, "threshold kept rank {}", inc.rank());

    let reference = jacobi_svd(&a).unwrap();
    for i in 0..3 {
        let (got, want) = (inc.sigma()[i].to_f64(), reference.s[i].to_f64());
        let rel = (got - want).abs() / want;
        assert!(rel < tol, "sigma_{i}: {got} vs batch {want} (rel {rel:.3e})");
    }
}

#[test]
fn sigma_threshold_truncation_matches_dense_reference() {
    let _guard = POOL_LOCK.lock().unwrap_or_else(|p| p.into_inner());
    let _reset = PoolReset;
    pool::set_num_threads(1);
    sigma_threshold_matches_reference::<f64>(1e-9);
    sigma_threshold_matches_reference::<f32>(2e-3);
}

/// Satellite battery 3: at any fixed pool thread count, absorbing the
/// same stream twice is bitwise repeatable (the pool's partitioning is
/// schedule-independent, so a fixed count fully determines the
/// arithmetic).
#[test]
fn bitwise_repeatable_across_thread_counts_and_dtypes() {
    fn run<S: Scalar>() -> Vec<u64> {
        let (a, _) = stream_matrix::<S>(17);
        let mut be = cpu::<S>();
        let inc = absorb(&mut be, &a, &[0, 1, 2, 3]);
        sigma_bits(&inc)
    }

    let _guard = POOL_LOCK.lock().unwrap_or_else(|p| p.into_inner());
    let _reset = PoolReset;
    for threads in [1usize, 2, 0] {
        pool::set_num_threads(threads);
        assert_eq!(run::<f64>(), run::<f64>(), "f64 not repeatable at threads={threads}");
        assert_eq!(run::<f32>(), run::<f32>(), "f32 not repeatable at threads={threads}");
    }
}

/// Satellite battery 4: once the accumulator and its planned workspace
/// exist and the first blocks have warmed every lazy path, further
/// `update_with` calls allocate nothing.
fn update_with_allocation_free<S: Scalar>() {
    let (a, _) = stream_matrix::<S>(23);
    let mut inc = IncrementalSvd::<S>::new(M, N, RANK_CAP, BLOCK, 0.0);
    let ws = Workspace::new(inc.plan());
    let mut be = cpu::<S>();

    // Warm off-window: first update builds rank from 0 (degenerate
    // branch), second runs the full path once so lazy statics and the
    // backend profile are initialized.
    inc.update_with(&mut be, a.panel(0, BLOCK), &ws).unwrap();
    inc.update_with(&mut be, a.panel(BLOCK, BLOCK), &ws).unwrap();

    let (c0, b0) = (thread_allocs(), thread_alloc_bytes());
    inc.update_with(&mut be, a.panel(2 * BLOCK, BLOCK), &ws).unwrap();
    inc.update_with(&mut be, a.panel(3 * BLOCK, BLOCK), &ws).unwrap();
    let allocs = (thread_allocs() - c0, thread_alloc_bytes() - b0);
    assert_eq!(
        allocs,
        (0, 0),
        "{}: warmed update_with must not allocate (allocs, bytes) = {allocs:?}",
        S::DTYPE
    );
    assert_eq!(inc.cols_seen(), N);
}

#[test]
fn update_with_is_allocation_free_after_warmup() {
    let _guard = POOL_LOCK.lock().unwrap_or_else(|p| p.into_inner());
    let _reset = PoolReset;
    pool::set_num_threads(1);
    update_with_allocation_free::<f64>();
    update_with_allocation_free::<f32>();
}
