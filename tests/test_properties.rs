//! Property-based integration tests (hand-rolled seeded sweeps — no
//! proptest in the offline vendor set; see DESIGN.md §3).
//!
//! Each property runs across a deterministic family of random cases; a
//! failure prints the case seed so it can be replayed.

use trunksvd::algo::cgs_qr::cgs_qr;
use trunksvd::algo::{lancsvd::lancsvd, randsvd::randsvd, residuals, LancSvdOpts, RandSvdOpts};
use trunksvd::backend::cpu::CpuBackend;
use trunksvd::cost;
use trunksvd::gen::dense::dense_with_spectrum;
use trunksvd::gen::sparse::{generate, SparseSpec};
use trunksvd::la::blas3::{mat_nn, mat_tn};
use trunksvd::la::mat::Mat;
use trunksvd::la::norms::orth_error;
use trunksvd::la::svd::jacobi_svd;
use trunksvd::metrics::Block;
use trunksvd::sparse::mm;
use trunksvd::util::rng::Rng;

/// Deterministic case-parameter helper.
fn cases(n: usize) -> impl Iterator<Item = Rng> {
    (0..n as u64).map(|i| Rng::new(0xABCD_0000 + i))
}

#[test]
fn prop_cgs_qr_orthogonality_and_reconstruction() {
    for (case, mut rng) in cases(12).enumerate() {
        let q_rows = 24 + rng.below(300);
        let r_cols = 1 + rng.below(24.min(q_rows));
        let b = 1 + rng.below(12);
        let y0 = Mat::randn(q_rows, r_cols, &mut rng);
        let mut y = y0.clone();
        let mut be = CpuBackend::new_dense(Mat::zeros(1, 1));
        let r = cgs_qr(&mut be, &mut y, b).unwrap();
        assert!(
            orth_error(&y) < 1e-11,
            "case {case}: orth {} (q={q_rows} r={r_cols} b={b})",
            orth_error(&y)
        );
        let back = mat_nn(&y, &r);
        assert!(
            back.max_abs_diff(&y0) / y0.fro_norm() < 1e-11,
            "case {case}: reconstruction (q={q_rows} r={r_cols} b={b})"
        );
    }
}

#[test]
fn prop_lancsvd_matches_jacobi_truth_on_random_spectra() {
    for (case, mut rng) in cases(6).enumerate() {
        let n = 24 + rng.below(40);
        let m = n + 10 + rng.below(100);
        // random descending spectrum over ~6 decades
        let mut sigma: Vec<f64> = (0..n).map(|_| 10f64.powf(-6.0 * rng.uniform())).collect();
        sigma.sort_by(|a, b| b.partial_cmp(a).unwrap());
        let prob = dense_with_spectrum(m, n, &sigma, 1000 + case as u64);
        let mut be = CpuBackend::new_dense(prob.a.clone());
        let b = 8;
        let r = (n / b) * b; // largest multiple of b that fits
        let svd = lancsvd(
            &mut be,
            &LancSvdOpts {
                r,
                p: 6,
                b,
                wanted: 5,
                tol: Some(1e-11),
                seed: case as u64,
                ..Default::default()
            },
        )
        .unwrap();
        let truth = jacobi_svd(&prob.a).unwrap();
        for i in 0..5 {
            let (got, want) = (svd.sigma[i], truth.s[i]);
            assert!(
                (got - want).abs() <= 1e-8 * truth.s[0],
                "case {case} sigma_{i}: {got:.6e} vs {want:.6e}"
            );
        }
    }
}

#[test]
fn prop_randsvd_residuals_decrease_with_p() {
    for (case, mut rng) in cases(4).enumerate() {
        let n = 30 + rng.below(30);
        let m = n + rng.below(200);
        let sigma: Vec<f64> = (0..n).map(|i| 0.9f64.powi(i as i32)).collect();
        let prob = dense_with_spectrum(m, n, &sigma, 2000 + case as u64);
        let worst = |p: usize| {
            let mut be = CpuBackend::new_dense(prob.a.clone());
            let svd = randsvd(
                &mut be,
                &RandSvdOpts { r: 12, p, b: 4, seed: case as u64, ..Default::default() },
            )
            .unwrap();
            let mut chk = CpuBackend::new_dense(prob.a.clone());
            residuals(&mut chk, &svd, 5).iter().fold(0.0f64, |mx, &x| mx.max(x))
        };
        let (r2, r16) = (worst(2), worst(16));
        assert!(
            r16 <= r2 * 1.5,
            "case {case}: residual must not grow with p ({r2:.2e} -> {r16:.2e})"
        );
    }
}

#[test]
fn prop_cost_model_equals_instrumentation() {
    // The analytic Table-1 model and the backend flop counters share
    // formulas; for any (r, p, b) they must agree exactly on the four
    // loop blocks (init/restart guards excluded on the lanc side).
    for (case, mut rng) in cases(6).enumerate() {
        let rows = 200 + rng.below(400);
        let cols = 100 + rng.below(200);
        let spec = SparseSpec {
            rows,
            cols,
            nnz: 4 * (rows + cols),
            seed: 3000 + case as u64,
            ..Default::default()
        };
        let a = generate(&spec);
        let prob = cost::Problem { m: rows, n: cols, nnz: Some(a.nnz()) };
        let b = 8;
        let r = b * (1 + rng.below(3));
        let p = 1 + rng.below(3);
        if r > cols.min(rows) {
            continue;
        }
        // RandSVD: exact match on all four blocks.
        let model = cost::randsvd_cost(prob, r, p, b);
        let mut be = CpuBackend::new_sparse(a.clone());
        let svd = randsvd(
            &mut be,
            &RandSvdOpts { r, p, b, seed: 1, ..Default::default() },
        )
        .unwrap();
        for (name, want, got) in [
            ("mult_a", model.mult_a, svd.profile.stat(Block::MultA).flops),
            ("mult_at", model.mult_at, svd.profile.stat(Block::MultAt).flops),
            ("orth_m", model.orth_m, svd.profile.stat(Block::OrthM).flops),
            ("orth_n", model.orth_n, svd.profile.stat(Block::OrthN).flops),
        ] {
            assert!(
                (want - got).abs() <= 1e-6 * want.max(1.0),
                "case {case} randsvd {name}: model {want:.4e} vs measured {got:.4e} (r={r} p={p})"
            );
        }
    }
}

#[test]
fn prop_spmm_pair_consistency() {
    for (case, mut rng) in cases(8).enumerate() {
        let rows = 20 + rng.below(300);
        let cols = 10 + rng.below(200);
        let spec = SparseSpec {
            rows,
            cols,
            nnz: 2 * (rows + cols),
            seed: 4000 + case as u64,
            skew: 1.2,
            ..Default::default()
        };
        let a = generate(&spec);
        let k = 1 + rng.below(9);
        let x = Mat::randn(cols, k, &mut rng);
        let z = Mat::randn(rows, k, &mut rng);
        let ad = a.to_dense();
        let mut y = Mat::zeros(rows, k);
        a.spmm(x.as_ref(), y.as_mut());
        assert!(y.max_abs_diff(&mat_nn(&ad, &x)) < 1e-11, "case {case} spmm");
        let mut w = Mat::zeros(cols, k);
        a.spmm_t(z.as_ref(), w.as_mut());
        assert!(w.max_abs_diff(&mat_tn(&ad, &z)) < 1e-11, "case {case} spmm_t");
        // scatter == explicit transpose
        let mut w2 = Mat::zeros(cols, k);
        a.transpose().spmm(z.as_ref(), w2.as_mut());
        assert!(w.max_abs_diff(&w2) < 1e-11, "case {case} transpose equivalence");
    }
}

#[test]
fn prop_matrixmarket_roundtrip() {
    let dir = std::env::temp_dir().join("trunksvd_prop_mm");
    std::fs::create_dir_all(&dir).unwrap();
    for (case, mut rng) in cases(6).enumerate() {
        let rows = 5 + rng.below(100);
        let cols = 5 + rng.below(100);
        let spec = SparseSpec {
            rows,
            cols,
            nnz: rows + cols + rng.below(500),
            seed: 5000 + case as u64,
            ..Default::default()
        };
        let a = generate(&spec);
        let path = dir.join(format!("m{case}.mtx")).to_string_lossy().into_owned();
        mm::write_csr(&path, &a).unwrap();
        let b = mm::read_csr(&path).unwrap();
        assert_eq!((a.rows(), a.cols(), a.nnz()), (b.rows(), b.cols(), b.nnz()));
        assert!(a.to_dense().max_abs_diff(&b.to_dense()) < 1e-14, "case {case}");
    }
}

#[test]
fn prop_backend_profile_flops_positive_and_phased() {
    // Failure-injection-adjacent sanity: every phase an algorithm claims
    // to enter must have recorded calls, and flops must be finite.
    let spec = SparseSpec { rows: 300, cols: 150, nnz: 2500, seed: 9, ..Default::default() };
    let a = generate(&spec);
    let mut be = CpuBackend::new_sparse(a);
    let svd = lancsvd(
        &mut be,
        &LancSvdOpts { r: 32, p: 2, b: 8, wanted: 5, ..Default::default() },
    )
    .unwrap();
    for b in [Block::MultA, Block::MultAt, Block::OrthM, Block::OrthN, Block::SmallSvd] {
        let s = svd.profile.stat(b);
        assert!(s.calls > 0, "phase {b:?} never entered");
        assert!(s.flops.is_finite() && s.flops >= 0.0);
    }
}

#[test]
fn prop_failure_injection_rank_deficient_operands() {
    // Rank-deficient *problem matrices* (duplicated sparse columns) must
    // not break either algorithm; Q bases stay orthonormal through the
    // CGS2 fallback.
    for (case, mut rng) in cases(4).enumerate() {
        let rows = 150 + rng.below(100);
        let cols = 60;
        let spec = SparseSpec {
            rows,
            cols,
            nnz: 6 * cols,
            seed: 6000 + case as u64,
            value_decay: 8.0, // brutal decay → near rank deficiency
            ..Default::default()
        };
        let a = generate(&spec);
        let mut be = CpuBackend::new_sparse(a.clone());
        let svd = lancsvd(
            &mut be,
            &LancSvdOpts { r: 32, p: 2, b: 8, wanted: 5, seed: 1, ..Default::default() },
        )
        .unwrap();
        assert!(
            orth_error(&svd.u) < 1e-7,
            "case {case}: U lost orthonormality: {}",
            orth_error(&svd.u)
        );
        assert!(svd.sigma.iter().all(|s| s.is_finite()));
    }
}
