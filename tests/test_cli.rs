//! CLI integration tests (through `cli::main_with_args`, no subprocess).

use trunksvd::cli::main_with_args;

fn argv(s: &str) -> Vec<String> {
    s.split_whitespace().map(|t| t.to_string()).collect()
}

#[test]
fn gen_then_solve_mtx_roundtrip() {
    let dir = std::env::temp_dir().join("trunksvd_cli_tests");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("connectus.mtx");
    let path = path.to_string_lossy();
    assert_eq!(main_with_args(argv(&format!("gen --name connectus --out {path}"))), 0);
    assert!(std::fs::metadata(&*path).unwrap().len() > 1000);
    assert_eq!(
        main_with_args(argv(&format!(
            "solve --mtx {path} --algo lanc --r 64 --p 1 --b 16 --wanted 5"
        ))),
        0
    );
}

#[test]
fn solve_suite_rand() {
    assert_eq!(
        main_with_args(argv(
            "solve --suite mesh_deform --algo rand --r 16 --p 4 --wanted 3"
        )),
        0
    );
}

#[test]
fn experiment_table2_and_fig3() {
    let out = std::env::temp_dir().join("trunksvd_cli_reports");
    let out = out.to_string_lossy();
    assert_eq!(main_with_args(argv(&format!("experiment table2 --out {out}"))), 0);
    assert!(std::path::Path::new(&format!("{out}/table2_suite.md")).exists());
    assert_eq!(main_with_args(argv(&format!("experiment fig3 --out {out}"))), 0);
    assert!(std::path::Path::new(&format!("{out}/fig3_flops.csv")).exists());
}

#[test]
fn bad_inputs_are_rejected() {
    assert_eq!(main_with_args(argv("solve")), 1);
    assert_eq!(main_with_args(argv("solve --suite not_a_matrix")), 1);
    assert_eq!(main_with_args(argv("solve --dense 100 --n 50 --algo bogus")), 1);
    assert_eq!(main_with_args(argv("experiment fig99")), 1);
    assert_eq!(main_with_args(argv("gen --name rel8")), 1);
}

#[test]
fn solve_with_tolerance_stops_early() {
    assert_eq!(
        main_with_args(argv(
            "solve --dense 800 --n 128 --algo lanc --r 64 --p 20 --tol 1e-9 --wanted 5"
        )),
        0
    );
}
