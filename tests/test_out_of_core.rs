//! Out-of-core sharded-operand integration suite.
//!
//! The normative claim (ISSUE 7 / `backend/mod.rs` §6): a solve over a
//! row-band shard directory under any admissible resident-bytes cap is
//! **bitwise-identical** to the in-core solve at a fixed thread count —
//! the prefetch pipeline overlaps I/O only, it never reorders compute.
//! The bitwise anchor is the scatter-only CPU backend (sharded Aᵀ·X is
//! a global-row-order scatter by construction).
//!
//! Also covered here: the staged backend's three-tier ledger (each
//! shard's file bytes hit the disk tier exactly once per pass; the disk
//! tier never pollutes the host↔arena hot-loop accounting), resident-cap
//! enforcement (peak decoded bytes ≤ cap; an inadmissible cap is an
//! `Err` from the driver, not a panic), the streaming MatrixMarket
//! converter at solve level, and driver/backend policy (`cpu-expt` is
//! rejected out-of-core).
//!
//! Every test that touches the global pool serializes on `POOL_LOCK`
//! and restores defaults on exit (same idiom as `test_threaded_kernels`).

use std::sync::{Arc, Mutex};

use trunksvd::algo::lancsvd::lancsvd;
use trunksvd::algo::randsvd::randsvd;
use trunksvd::algo::{LancSvdOpts, RandSvdOpts, TruncatedSvd};
use trunksvd::backend::cpu::CpuBackend;
use trunksvd::backend::staged::StagedBackend;
use trunksvd::backend::Operand;
use trunksvd::coordinator::driver::{make_backend_at, BackendChoice};
use trunksvd::gen::sparse::{generate, SparseSpec};
use trunksvd::sparse::shard::{self, ShardDir};
use trunksvd::util::pool;
use trunksvd::util::scalar::Scalar;
use trunksvd::Csr;

static POOL_LOCK: Mutex<()> = Mutex::new(());

struct PoolReset;
impl Drop for PoolReset {
    fn drop(&mut self) {
        pool::set_num_threads(0);
    }
}

fn tmp(name: &str) -> String {
    let dir = std::env::temp_dir().join("trunksvd_ooc_tests").join(name);
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir.to_str().unwrap().to_string()
}

fn test_matrix() -> Csr {
    generate(&SparseSpec { rows: 600, cols: 220, nnz: 7000, seed: 41, ..Default::default() })
}

fn assert_bitwise_svd<S: Scalar>(a: &TruncatedSvd<S>, b: &TruncatedSvd<S>, what: &str) {
    assert_eq!(a.iters, b.iters, "{what}: iteration counts differ");
    assert_eq!(a.sigma.len(), b.sigma.len(), "{what}: rank differs");
    for (i, (x, y)) in a.sigma.iter().zip(&b.sigma).enumerate() {
        assert_eq!(x.to_f64().to_bits(), y.to_f64().to_bits(), "{what}: sigma[{i}]");
    }
    for (m, (x, y)) in [("u", (&a.u, &b.u)), ("v", (&a.v, &b.v))] {
        assert_eq!(x.data().len(), y.data().len(), "{what}: {m} shape");
        for (i, (p, q)) in x.data().iter().zip(y.data()).enumerate() {
            assert_eq!(p.to_f64().to_bits(), q.to_f64().to_bits(), "{what}: {m}[{i}]");
        }
    }
}

/// Solve in-core (scatter-only) and sharded-under-cap at one precision,
/// both algorithms, asserting bitwise-identical factors throughout.
fn parity_at<S: Scalar>(a: &Csr<S>, sd: &Arc<ShardDir>, cap: usize) {
    // Pin the unfused kernels on BOTH sides: the on-disk operand would
    // auto-enable the fused tier (tested in `test_fused_ops`), while the
    // tiny in-core reference would stay unfused — this suite's normative
    // claim is about the classic kernel composition.
    let fuse = Some(false);
    let lopts =
        LancSvdOpts { r: 16, p: 3, b: 8, wanted: 6, seed: 7, fuse, ..Default::default() };
    let ropts = RandSvdOpts { r: 12, p: 6, b: 8, seed: 7, fuse, ..Default::default() };

    let mut be_in = CpuBackend::new_sparse(a.clone()).scatter_only();
    let lanc_in = lancsvd(&mut be_in, &lopts).unwrap();
    let mut be_in = CpuBackend::new_sparse(a.clone()).scatter_only();
    let rand_in = randsvd(&mut be_in, &ropts).unwrap();

    let mut be_sh = CpuBackend::<S>::new(Operand::sharded(Arc::clone(sd), cap));
    be_sh.ensure_operand_resident().unwrap();
    let lanc_sh = lancsvd(&mut be_sh, &lopts).unwrap();
    let mut be_sh = CpuBackend::<S>::new(Operand::sharded(Arc::clone(sd), cap));
    let rand_sh = randsvd(&mut be_sh, &ropts).unwrap();

    assert_bitwise_svd(&lanc_in, &lanc_sh, "lancsvd");
    assert_bitwise_svd(&rand_in, &rand_sh, "randsvd");
}

#[test]
fn sharded_solves_bitwise_match_incore_both_dtypes() {
    let _guard = POOL_LOCK.lock().unwrap_or_else(|p| p.into_inner());
    let _reset = PoolReset;
    pool::set_num_threads(3);
    let a = test_matrix();
    let dir = tmp("parity");
    let sd = Arc::new(shard::write_shards_from_csr(&dir, &a, 5).unwrap());
    assert_eq!(sd.num_shards(), 5);
    // Tight cap: zero pinned prefix, every pass streams through the
    // double-buffered prefetch slots.
    let cap64 = 2 * sd.max_resident_bytes::<f64>();
    parity_at::<f64>(&a, &sd, cap64);
    // Unlimited cap (pin everything, no loader thread) must also match.
    parity_at::<f64>(&a, &sd, 0);
    // f32: disk stores f64; the shard load's from_f64 cast is the same
    // cast `Csr::cast` applies, so parity holds at f32 too.
    let a32: Csr<f32> = a.cast();
    let cap32 = 2 * sd.max_resident_bytes::<f32>();
    parity_at::<f32>(&a32, &sd, cap32);
}

#[test]
fn converted_mtx_shards_solve_bitwise_like_the_file() {
    let _guard = POOL_LOCK.lock().unwrap_or_else(|p| p.into_inner());
    let _reset = PoolReset;
    pool::set_num_threads(2);
    let a = test_matrix();
    let dir = tmp("convert");
    let mtx = format!("{dir}/a.mtx");
    trunksvd::sparse::mm::write_csr(&mtx, &a).unwrap();
    // Stream-convert (never materializes the COO) and re-read the file
    // in-core: the two operand paths must agree bit-for-bit end to end.
    let sd = Arc::new(shard::convert_mtx_to_shards(&mtx, &format!("{dir}/shards"), 4).unwrap());
    let a_file = trunksvd::sparse::mm::read_csr(&mtx).unwrap();
    let cap = 2 * sd.max_resident_bytes::<f64>();
    parity_at::<f64>(&a_file, &sd, cap);
}

#[test]
fn staged_ledger_accounts_disk_tier_exactly_once_per_pass() {
    let _guard = POOL_LOCK.lock().unwrap_or_else(|p| p.into_inner());
    let _reset = PoolReset;
    pool::set_num_threads(2);
    let a = test_matrix();
    let dir = tmp("ledger");
    let n_shards = 4usize;
    let sd = Arc::new(shard::write_shards_from_csr(&dir, &a, n_shards).unwrap());
    let file_bytes = sd.total_file_bytes();
    // Unfused pinned: the sharded and in-core reference solves must run
    // the same op sequence for the crossing-count comparison below
    // (fused-tier ledger accounting is covered in `test_fused_ops`).
    let opts =
        LancSvdOpts { r: 16, p: 3, b: 8, wanted: 6, seed: 7, fuse: Some(false), ..Default::default() };

    // Streaming regime: every pass reloads every shard.
    let cap = 2 * sd.max_resident_bytes::<f64>();
    let mut be: StagedBackend = StagedBackend::new_sharded(Arc::clone(&sd), cap);
    be.ensure_operand_resident().unwrap();
    lancsvd(&mut be, &opts).unwrap();
    let t = be.ledger().totals();
    let st = be.shard_stats().unwrap();
    assert!(st.passes > 0);
    assert_eq!(st.pin_loads, 0, "tight cap must pin nothing");
    assert_eq!(
        st.stream_bytes,
        st.passes * file_bytes,
        "each pass must stream each shard's file exactly once"
    );
    assert_eq!(st.stream_loads, st.passes * n_shards);
    assert_eq!(t.disk_count as usize, st.stream_loads, "every load ledgered, none twice");
    assert_eq!(t.disk_bytes as usize, st.stream_bytes);
    // Rule 3 and the POTRF-only factor accounting are host↔arena
    // properties; the disk tier must not leak into either.
    assert_eq!(t.hot_panel_transfers, 0);

    // In-core staged reference: identical hot-loop accounting.
    let mut be_ref: StagedBackend = StagedBackend::new_sparse(a.clone());
    lancsvd(&mut be_ref, &opts).unwrap();
    let tr = be_ref.ledger().totals();
    assert_eq!(
        t.hot_factor_crossings, tr.hot_factor_crossings,
        "disk traffic must not change factor-crossing counts"
    );
    assert_eq!((tr.disk_count, tr.disk_bytes), (0, 0), "in-core solve has no disk tier");

    // Unlimited cap: the whole operand is pinned once at staging —
    // disk bytes appear exactly once regardless of pass count, and the
    // pinned prefix counts as staged operand bytes.
    let mut be_pin: StagedBackend = StagedBackend::new_sharded(Arc::clone(&sd), 0);
    be_pin.ensure_operand_resident().unwrap();
    lancsvd(&mut be_pin, &opts).unwrap();
    let tp = be_pin.ledger().totals();
    let sp = be_pin.shard_stats().unwrap();
    assert_eq!(sp.pin_loads, n_shards);
    assert_eq!((sp.stream_loads, sp.stream_bytes), (0, 0));
    assert_eq!(tp.disk_bytes as usize, file_bytes, "pinned: one load per shard, ever");
    assert!(tp.staged_operand_bytes as usize >= file_bytes);
}

#[test]
fn resident_cap_is_enforced_and_validated() {
    let _guard = POOL_LOCK.lock().unwrap_or_else(|p| p.into_inner());
    let _reset = PoolReset;
    pool::set_num_threads(2);
    let a = test_matrix();
    let dir = tmp("cap");
    let sd = Arc::new(shard::write_shards_from_csr(&dir, &a, 5).unwrap());
    let maxb = sd.max_resident_bytes::<f64>();

    // Peak decoded shard bytes stay under the cap for a whole solve.
    for cap in [2 * maxb, 3 * maxb] {
        let mut be = CpuBackend::<f64>::new(Operand::sharded(Arc::clone(&sd), cap));
        be.ensure_operand_resident().unwrap();
        let opts = LancSvdOpts { r: 16, p: 3, b: 8, wanted: 6, seed: 7, ..Default::default() };
        lancsvd(&mut be, &opts).unwrap();
        let st = be.shard_stats().unwrap();
        assert!(
            st.peak_resident_bytes <= cap,
            "peak {} exceeds cap {cap}",
            st.peak_resident_bytes
        );
    }

    // A cap smaller than the largest shard is an Err from the driver
    // (make_backend_at resolves the manifest eagerly), not a panic.
    let too_small = Operand::<f64>::sharded(Arc::clone(&sd), maxb - 1);
    assert!(make_backend_at::<f64>(too_small, &BackendChoice::Cpu).is_err());
    let staged_small = Operand::<f64>::sharded(Arc::clone(&sd), maxb - 1);
    assert!(make_backend_at::<f64>(staged_small, &BackendChoice::Staged).is_err());

    // cpu-expt needs the whole operand in core for its eager transpose.
    let op = Operand::<f64>::sharded(Arc::clone(&sd), 0);
    assert!(make_backend_at::<f64>(op, &BackendChoice::CpuExplicitT).is_err());
}
