//! Integration: AOT artifacts → PJRT runtime → XlaBackend, and parity of
//! the two backends through the full algorithms.
//!
//! Requires `make artifacts` (tests that need artifacts skip gracefully
//! when the manifest is absent so `cargo test` works pre-AOT, but the CI
//! flow always builds artifacts first).

use std::rc::Rc;
use std::sync::{Mutex, OnceLock};

/// The bundled xla_extension 0.5.1 PJRT CPU plugin is unreliable when a
/// process creates more than one TfrtCpuClient (flaky SIGSEGV on the
/// 2nd/3rd creation). All tests in this file therefore serialize on
/// PJRT_LOCK and share a single, never-destroyed Runtime. The Rc inside
/// the wrapper is only ever touched while the lock is held, which makes
/// the unsafe Send/Sync sound in this harness.
static PJRT_LOCK: Mutex<()> = Mutex::new(());

struct SharedRt(Option<Rc<Runtime>>);
unsafe impl Send for SharedRt {}
unsafe impl Sync for SharedRt {}
static SHARED_RT: OnceLock<SharedRt> = OnceLock::new();

use trunksvd::algo::{lancsvd::lancsvd, randsvd::randsvd, residuals, LancSvdOpts, RandSvdOpts};
use trunksvd::backend::cpu::CpuBackend;
use trunksvd::backend::xla::XlaBackend;
use trunksvd::backend::Backend;
use trunksvd::gen::dense::paper_dense;
use trunksvd::gen::sparse::{generate, SparseSpec};
use trunksvd::la::mat::Mat;
use trunksvd::la::norms::orth_error;
use trunksvd::runtime::{convert, default_artifact_dir, Runtime};
use trunksvd::util::rng::Rng;

fn runtime_with_artifacts() -> Option<Rc<Runtime>> {
    SHARED_RT
        .get_or_init(|| {
            let dir = default_artifact_dir();
            if !std::path::Path::new(&format!("{dir}/manifest.json")).exists() {
                eprintln!("SKIP: no artifacts at {dir} (run `make artifacts`)");
                return SharedRt(None);
            }
            SharedRt(Some(Rc::new(Runtime::new(&dir).expect("runtime"))))
        })
        .0
        .clone()
}

#[test]
fn cholqr2_artifact_runs_and_matches_host() {
    let _guard = PJRT_LOCK.lock().unwrap_or_else(|p| p.into_inner());
    let Some(rt) = runtime_with_artifacts() else { return };
    let mut rng = Rng::new(1);
    // q=700 exercises padding to the 1024 bucket.
    let y = Mat::randn(700, 16, &mut rng);

    let mut xbe = XlaBackend::new_dense(rt.clone(), Mat::zeros(512, 4)).unwrap();
    let mut q_x = y.clone();
    let r_x = xbe.orth_cholqr2(&mut q_x).unwrap();

    let mut cbe = CpuBackend::new_dense(Mat::zeros(1, 1));
    let mut q_c = y.clone();
    let r_c = cbe.orth_cholqr2(&mut q_c).unwrap();

    assert!(orth_error(&q_x) < 1e-12, "artifact Q orthonormal");
    assert!(r_x.max_abs_diff(&r_c) / r_c.fro_norm() < 1e-12, "R parity");
    assert!(q_x.max_abs_diff(&q_c) < 1e-10, "Q parity");
    assert!(rt.stats().artifact_execs >= 1, "artifact path was used");
}

#[test]
fn cgs_cqr2_artifact_with_s_padding_matches_host() {
    let _guard = PJRT_LOCK.lock().unwrap_or_else(|p| p.into_inner());
    let Some(rt) = runtime_with_artifacts() else { return };
    let mut rng = Rng::new(2);
    let q_rows = 600; // pads to 1024
    let s = 48; // pads to the 64 bucket
    let p = trunksvd::la::qr::random_orthonormal(q_rows, s, &mut rng);
    let y = Mat::randn(q_rows, 16, &mut rng);

    let mut xbe = XlaBackend::new_dense(rt.clone(), Mat::zeros(512, 4)).unwrap();
    let mut q_x = y.clone();
    let (h_x, r_x) = xbe.orth_cgs_cqr2(&mut q_x, p.as_ref()).unwrap();

    let mut cbe = CpuBackend::new_dense(Mat::zeros(1, 1));
    let mut q_c = y.clone();
    let (h_c, r_c) = cbe.orth_cgs_cqr2(&mut q_c, p.as_ref()).unwrap();

    assert_eq!((h_x.rows(), h_x.cols()), (s, 16));
    assert!(orth_error(&q_x) < 1e-12);
    assert!(h_x.max_abs_diff(&h_c) < 1e-10, "H parity");
    assert!(r_x.max_abs_diff(&r_c) / r_c.fro_norm() < 1e-11, "R parity");
    assert!(q_x.max_abs_diff(&q_c) < 1e-9, "Q parity");
}

#[test]
fn breakdown_panel_falls_back_and_stays_orthonormal() {
    let _guard = PJRT_LOCK.lock().unwrap_or_else(|p| p.into_inner());
    let Some(rt) = runtime_with_artifacts() else { return };
    let mut rng = Rng::new(3);
    let mut y = Mat::randn(600, 16, &mut rng);
    let c0 = y.col(0).to_vec();
    y.col_mut(7).copy_from_slice(&c0); // exact rank deficiency
    let mut xbe = XlaBackend::new_dense(rt, Mat::zeros(512, 4)).unwrap();
    let mut q = y.clone();
    let _r = xbe.orth_cholqr2(&mut q).unwrap();
    assert!(
        orth_error(&q) < 1e-8,
        "fallback must keep Q orthonormal: {}",
        orth_error(&q)
    );
}

#[test]
fn dense_apply_artifacts_match_cpu() {
    let _guard = PJRT_LOCK.lock().unwrap_or_else(|p| p.into_inner());
    let Some(rt) = runtime_with_artifacts() else { return };
    let prob = paper_dense(700, 300, 4); // pads to 1024 x 512
    let mut xbe = XlaBackend::new_dense(rt, prob.a.clone()).unwrap();
    let mut cbe = CpuBackend::new_dense(prob.a.clone());
    let mut rng = Rng::new(5);
    let x = Mat::randn(300, 16, &mut rng);
    let y_x = xbe.apply_a(x.as_ref());
    let y_c = cbe.apply_a(x.as_ref());
    assert!(y_x.max_abs_diff(&y_c) < 1e-10 * prob.a.fro_norm());
    let z = Mat::randn(700, 16, &mut rng);
    let w_x = xbe.apply_at(z.as_ref());
    let w_c = cbe.apply_at(z.as_ref());
    assert!(w_x.max_abs_diff(&w_c) < 1e-10 * prob.a.fro_norm());
}

#[test]
fn randsvd_parity_cpu_vs_xla_dense() {
    let _guard = PJRT_LOCK.lock().unwrap_or_else(|p| p.into_inner());
    let Some(rt) = runtime_with_artifacts() else { return };
    let prob = paper_dense(600, 200, 6);
    let opts = RandSvdOpts { r: 16, p: 8, b: 16, seed: 11, ..Default::default() };

    let mut cbe = CpuBackend::new_dense(prob.a.clone());
    let svd_c = randsvd(&mut cbe, &opts).unwrap();
    let mut xbe = XlaBackend::new_dense(rt, prob.a.clone()).unwrap();
    let svd_x = randsvd(&mut xbe, &opts).unwrap();

    for i in 0..10 {
        let (a, b) = (svd_c.sigma[i], svd_x.sigma[i]);
        assert!(
            (a - b).abs() <= 1e-9 * svd_c.sigma[0],
            "sigma_{i}: cpu {a:.6e} xla {b:.6e}"
        );
    }
    let mut be = CpuBackend::new_dense(prob.a.clone());
    let res = residuals(&mut be, &svd_x, 10);
    let res_c = residuals(&mut be, &svd_c, 10);
    for i in 0..10 {
        assert!(
            res[i] < res_c[i].max(1e-12) * 100.0,
            "xla residual {i}: {:.2e} vs cpu {:.2e}",
            res[i],
            res_c[i]
        );
    }
}

#[test]
fn lancsvd_parity_cpu_vs_xla_sparse() {
    let _guard = PJRT_LOCK.lock().unwrap_or_else(|p| p.into_inner());
    let Some(rt) = runtime_with_artifacts() else { return };
    let spec = SparseSpec { rows: 900, cols: 600, nnz: 12_000, seed: 21, ..Default::default() };
    let a = generate(&spec);
    let opts = LancSvdOpts { r: 64, p: 2, b: 16, wanted: 10, seed: 13, ..Default::default() };

    let mut cbe = CpuBackend::new_sparse(a.clone());
    let svd_c = lancsvd(&mut cbe, &opts).unwrap();
    let mut xbe = XlaBackend::new_sparse(rt.clone(), a.clone());
    let svd_x = lancsvd(&mut xbe, &opts).unwrap();

    for i in 0..10 {
        let (c, x) = (svd_c.sigma[i], svd_x.sigma[i]);
        assert!(
            (c - x).abs() <= 1e-8 * svd_c.sigma[0].max(1.0),
            "sigma_{i}: cpu {c:.6e} xla {x:.6e}"
        );
    }
    // The fused-orth artifacts really ran.
    assert!(rt.stats().artifact_execs > 0, "expected artifact executions");
}

#[test]
fn spmm_blockell_artifact_demo_shape() {
    let _guard = PJRT_LOCK.lock().unwrap_or_else(|p| p.into_inner());
    let Some(rt) = runtime_with_artifacts() else { return };
    // Demo shape from aot.py: nbr=32, mbpr=8, bs=16, n=512, k=16.
    let (nbr, mbpr, bs, n, k) = (32usize, 8usize, 16usize, 512usize, 16usize);
    let shapes: [&[usize]; 3] = [&[nbr, mbpr, bs, bs], &[nbr, mbpr], &[n, k]];
    if !rt.has_artifact("spmm_blockell", &shapes) {
        eprintln!("SKIP: spmm demo artifact missing");
        return;
    }
    // Random block-sparse matrix with 3 blocks per block-row.
    let mut rng = Rng::new(31);
    let mut blocks = vec![0.0f64; nbr * mbpr * bs * bs];
    let mut idx = vec![0i32; nbr * mbpr];
    let mut dense = Mat::zeros(nbr * bs, n);
    for br in 0..nbr {
        for slot in 0..3 {
            let bc = rng.below(n / bs);
            idx[br * mbpr + slot] = bc as i32;
            for i in 0..bs {
                for j in 0..bs {
                    let v = rng.normal();
                    blocks[((br * mbpr + slot) * bs + i) * bs + j] = v;
                    // duplicate block columns accumulate, mirror that:
                    dense.add_at(br * bs + i, bc * bs + j, v);
                }
            }
        }
    }
    let x = Mat::randn(n, k, &mut rng);
    let blocks_lit = xla::Literal::vec1(&blocks)
        .reshape(&[nbr as i64, mbpr as i64, bs as i64, bs as i64])
        .unwrap();
    let idx_lit = xla::Literal::vec1(&idx).reshape(&[nbr as i64, mbpr as i64]).unwrap();
    let x_lit = convert::mat_to_literal(&x, n, k).unwrap();
    let outs = rt
        .run_artifact("spmm_blockell", &shapes, &[blocks_lit, idx_lit, x_lit])
        .unwrap();
    let y = convert::literal_to_mat(&outs[0], nbr * bs, k).unwrap();
    let expect = trunksvd::la::blas3::mat_nn(&dense, &x);
    assert!(
        y.max_abs_diff(&expect) < 1e-10,
        "pallas spmm vs dense: {}",
        y.max_abs_diff(&expect)
    );
}
