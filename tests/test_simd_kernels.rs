//! SIMD microkernel layer: cross-ISA bitwise parity and remainder-lane
//! audit for the rewritten hot kernels (CSR SpMM, Block-ELL SpMM,
//! Gram/SYRK, the CholeskyQR2 panel update path and POTRF).
//!
//! The contract under test (see `util::simd` module docs): for a fixed
//! thread count, `TRUNKSVD_SIMD=off` (the lane-blocked scalar reference)
//! and every ISA path produce **bitwise identical** results — the
//! microkernels share one accumulator layout and one reduction tree and
//! never use FMA, so vectorization changes speed, not bits. Tests flip
//! the level in-process via `simd::set_level`, which mirrors the env
//! override.
//!
//! The level/thread/cutoff overrides are process-global, so every test
//! serializes on `SIMD_LOCK` and restores the defaults before returning
//! (including on panic, via the `Reset` drop guard).

use std::sync::Mutex;

use trunksvd::cost;
use trunksvd::la::blas1;
use trunksvd::la::blas3::{self, mat_nn, mat_tn};
use trunksvd::la::chol;
use trunksvd::la::mat::Mat;
use trunksvd::sparse::blockell::BlockEll;
use trunksvd::sparse::coo::Coo;
use trunksvd::sparse::csr::Csr;
use trunksvd::util::pool;
use trunksvd::util::rng::Rng;
use trunksvd::util::scalar::Scalar;
use trunksvd::util::simd::{self, SimdLevel};

static SIMD_LOCK: Mutex<()> = Mutex::new(());

/// Restores the SIMD level and pool defaults even on panic.
struct Reset;
impl Drop for Reset {
    fn drop(&mut self) {
        simd::set_level(None);
        pool::set_num_threads(0);
        pool::set_parallel_cutoff(0);
    }
}

fn random_coo(rows: usize, cols: usize, nnz: usize, seed: u64) -> Coo {
    let mut rng = Rng::new(seed);
    let mut c = Coo::new(rows, cols);
    for _ in 0..nnz {
        c.push(rng.below(rows), rng.below(cols), rng.normal());
    }
    c
}

fn bits<S: Scalar>(v: &[S]) -> Vec<u64> {
    v.iter().map(|x| x.to_f64().to_bits()).collect()
}

/// Shared fixtures for the parity fingerprint, built once per dtype so
/// every level/thread combination sees identical inputs.
struct Fixtures<S: Scalar> {
    a: Csr<S>,
    be: BlockEll<S>,
    x: Mat<S>,
    z: Mat<S>,
    q: Mat<S>,
    xp: Mat<S>,
    l: Mat<S>,
    lbar: Mat<S>,
    panel: Mat<S>,
    spd: Mat<S>,
}

fn fixtures<S: Scalar>() -> Fixtures<S> {
    let a: Csr<S> = Csr::from_coo(&random_coo(311, 257, 9000, 71)).unwrap().cast();
    let be = BlockEll::from_csr(&a, 8, a.cols().div_ceil(8)).unwrap();
    let mut rng = Rng::new(72);
    let x: Mat<S> = Mat::randn(a.cols(), 5, &mut rng);
    let z: Mat<S> = Mat::randn(a.rows(), 5, &mut rng);
    let q: Mat<S> = Mat::randn(500, 9, &mut rng);
    let xp: Mat<S> = Mat::randn(be.padded_cols(), 5, &mut rng);
    // Well-conditioned lower-triangular factors for the TRSM/TRMM path.
    let b = 9;
    let mut l: Mat<S> = Mat::zeros(b, b);
    let mut lbar: Mat<S> = Mat::zeros(b, b);
    for j in 0..b {
        for i in j..b {
            let d = if i == j { 2.0 + j as f64 } else { 0.3 * rng.normal() };
            l.set(i, j, S::from_f64(d));
            lbar.set(i, j, S::from_f64(0.5 * rng.normal()));
        }
    }
    let panel: Mat<S> = Mat::randn(200, b, &mut rng);
    // SPD operand big enough for the blocked POTRF path (n > 64).
    let g: Mat<S> = Mat::randn(110, 100, &mut rng);
    let mut spd = mat_tn(&g, &g);
    // Generous diagonal boost: keeps the f32 POTRF far from breakdown so
    // the fingerprint never depends on marginal pivots.
    for i in 0..100 {
        spd.add_at(i, i, S::from_f64(1.0));
    }
    Fixtures { a, be, x, z, q, xp, l, lbar, panel, spd }
}

/// One pass over every SIMD-rewritten kernel, fingerprinted bit-exactly:
/// gather SpMM, scatter SpMMᵀ, Block-ELL SpMM, Gram/SYRK, the CholQR2
/// panel update (TRSM + TRMM), blocked POTRF, and the blas1 dot/axpy.
fn simd_fingerprint<S: Scalar>(f: &Fixtures<S>) -> Vec<u64> {
    let mut out = Vec::new();
    let mut y = Mat::zeros(f.a.rows(), f.x.cols());
    f.a.spmm(f.x.as_ref(), y.as_mut());
    out.extend(bits(y.data()));
    let mut w = Mat::zeros(f.a.cols(), f.z.cols());
    f.a.spmm_t(f.z.as_ref(), w.as_mut());
    out.extend(bits(w.data()));
    let mut yp = Mat::zeros(f.be.padded_rows(), f.xp.cols());
    f.be.spmm(f.xp.as_ref(), yp.as_mut());
    out.extend(bits(yp.data()));
    let g = blas3::gram(f.q.as_ref());
    out.extend(bits(g.data()));
    let mut qp = f.panel.clone();
    blas3::trsm_right_lt(f.l.as_ref(), qp.as_mut());
    out.extend(bits(qp.data()));
    let r = blas3::trmm_lt_lt(&f.l, &f.lbar);
    out.extend(bits(r.data()));
    let mut lc = Mat::zeros(f.spd.rows(), f.spd.cols());
    chol::potrf_into(f.spd.as_ref(), lc.as_mut()).unwrap();
    out.extend(bits(lc.data()));
    out.push(blas1::dot(f.q.col(0), f.q.col(1)).to_f64().to_bits());
    let mut acc = f.q.col(2).to_vec();
    blas1::axpy(S::from_f64(-0.75), f.q.col(3), &mut acc);
    blas1::scal(S::from_f64(1.25), &mut acc);
    out.extend(bits(&acc));
    out
}

/// Satellite 3: for each fixed thread count, the scalar reference and
/// every ISA path (detected, plus each named level — unsupported ones
/// clamp to the reference, making the check vacuous there by design)
/// produce bitwise-identical results on all rewritten kernels.
fn parity_off_vs_isa<S: Scalar>() {
    let _guard = SIMD_LOCK.lock().unwrap_or_else(|p| p.into_inner());
    let _reset = Reset;
    pool::set_parallel_cutoff(1); // force the banded paths on small fixtures
    let f = fixtures::<S>();
    let detected = simd::detected_level();
    for &t in &[1usize, 2, 8] {
        pool::set_num_threads(t);
        simd::set_level(Some(SimdLevel::Off));
        let reference = simd_fingerprint(&f);
        for lvl in [detected, SimdLevel::Avx2, SimdLevel::Neon] {
            simd::set_level(Some(lvl));
            let got = simd_fingerprint(&f);
            assert!(
                got == reference,
                "dtype={} t={t} level={} not bitwise equal to scalar reference",
                S::DTYPE,
                lvl.name()
            );
        }
        // Auto (env default in this test binary) must also agree.
        simd::set_level(None);
        let auto = simd_fingerprint(&f);
        assert!(auto == reference, "dtype={} t={t} auto level disagrees", S::DTYPE);
    }
}

#[test]
fn simd_off_vs_isa_bitwise_f64() {
    parity_off_vs_isa::<f64>();
}

#[test]
fn simd_off_vs_isa_bitwise_f32() {
    parity_off_vs_isa::<f32>();
}

/// Satellite 1: remainder-lane audit. Edge column counts around the
/// 4-column register blocking (k in {1,2,3,5,7}) on row counts that are
/// not multiples of the band alignment (32) or the ELL block size (8),
/// at both precisions, against the dense reference.
fn edge_shapes<S: Scalar>() {
    let _guard = SIMD_LOCK.lock().unwrap_or_else(|p| p.into_inner());
    let _reset = Reset;
    pool::set_parallel_cutoff(1);
    let tol = if S::DTYPE == "f32" { 1e-3 } else { 1e-10 };
    for &t in &[1usize, 3] {
        pool::set_num_threads(t);
        for (si, &(m, n, nnz)) in [(33usize, 17usize, 150usize), (61, 40, 500), (127, 63, 1200)]
            .iter()
            .enumerate()
        {
            let a: Csr<S> = Csr::from_coo(&random_coo(m, n, nnz, 80 + si as u64)).unwrap().cast();
            let ad = a.to_dense();
            let be = BlockEll::from_csr(&a, 8, a.cols().div_ceil(8)).unwrap();
            let mut rng = Rng::new(91 + si as u64);
            for k in [1usize, 2, 3, 5, 7] {
                let x: Mat<S> = Mat::randn(n, k, &mut rng);
                let mut y: Mat<S> = Mat::zeros(m, k);
                a.spmm(x.as_ref(), y.as_mut());
                let expect = mat_nn(&ad, &x);
                assert!(
                    y.max_abs_diff(&expect) < S::from_f64(tol),
                    "spmm dtype={} t={t} {m}x{n} k={k}",
                    S::DTYPE
                );
                let mut xp: Mat<S> = Mat::zeros(be.padded_cols(), k);
                for j in 0..k {
                    for i in 0..n {
                        xp.set(i, j, x.at(i, j));
                    }
                }
                let mut yp: Mat<S> = Mat::zeros(be.padded_rows(), k);
                be.spmm(xp.as_ref(), yp.as_mut());
                for j in 0..k {
                    for i in 0..m {
                        let d = (yp.at(i, j) - expect.at(i, j)).abs().to_f64();
                        assert!(
                            d < tol,
                            "blockell dtype={} t={t} {m}x{n} k={k} ({i},{j})",
                            S::DTYPE
                        );
                    }
                    for i in m..be.padded_rows() {
                        assert_eq!(yp.at(i, j).to_f64(), 0.0, "padding t={t} k={k}");
                    }
                }
            }
        }
    }
}

#[test]
fn remainder_lane_edge_shapes_f64() {
    edge_shapes::<f64>();
}

#[test]
fn remainder_lane_edge_shapes_f32() {
    edge_shapes::<f32>();
}

/// Tentpole (b): the per-operand band-plan cache. Repeat solves against
/// the same operand are bitwise-identical call over call (the cached
/// partition is deterministic), clones get distinct cache identities but
/// the same results, and the answers match the dense reference.
#[test]
fn band_plan_cache_repeat_and_clone_solves() {
    let _guard = SIMD_LOCK.lock().unwrap_or_else(|p| p.into_inner());
    let _reset = Reset;
    pool::set_parallel_cutoff(1);
    pool::set_num_threads(4);
    let a = Csr::from_coo(&random_coo(900, 300, 30_000, 13)).unwrap();
    let ad = a.to_dense();
    let mut rng = Rng::new(14);
    let x = Mat::randn(300, 6, &mut rng);
    let expect = mat_nn(&ad, &x);
    let mut y = Mat::zeros(900, 6);
    a.spmm(x.as_ref(), y.as_mut());
    assert!(y.max_abs_diff(&expect) < 1e-10);
    let first = bits(y.data());
    for _ in 0..4 {
        let mut again = Mat::zeros(900, 6);
        a.spmm(x.as_ref(), again.as_mut());
        assert_eq!(bits(again.data()), first, "repeat solve drifted");
    }
    let b = a.clone();
    assert_ne!(a.generation(), b.generation(), "clone must get a fresh cache identity");
    let mut yc = Mat::zeros(900, 6);
    b.spmm(x.as_ref(), yc.as_mut());
    assert_eq!(bits(yc.data()), first, "clone solve drifted");
}

/// The calibration loader: file round-trip through the public API, both
/// document layouts, clamping, and rejection of non-calibration files.
#[test]
fn cost_calibration_loader() {
    let dir = std::env::temp_dir();
    let path = dir.join("trunksvd_simd_test_calib.json");
    let path = path.to_str().unwrap().to_string();
    std::fs::write(
        &path,
        r#"{"bench": "kernels",
            "cost_calibration": {"build_sweeps": 9.5, "scatter_penalty": 1.5,
                                 "parallel_cutoff": 100000},
            "kernels": []}"#,
    )
    .unwrap();
    let c = cost::load_calibration(&path).expect("calibration should load");
    assert_eq!(c.build_sweeps, 9.5);
    assert_eq!(c.scatter_penalty, 1.5);
    assert_eq!(c.parallel_cutoff, 16384, "out-of-range cutoff must clamp");
    let _ = std::fs::remove_file(&path);
    assert!(cost::load_calibration(&path).is_none(), "missing file");
}

/// TRUNKSVD_PIN / TRUNKSVD_SIMD surface sanity: the parsers accept the
/// documented spellings and the resolved defaults are callable.
#[test]
fn env_knob_parsers() {
    use pool::PinLevel;
    assert_eq!(PinLevel::parse("off"), Some(PinLevel::Off));
    assert_eq!(PinLevel::parse("core"), Some(PinLevel::Core));
    assert_eq!(PinLevel::parse("NODE"), Some(PinLevel::Node));
    assert_eq!(PinLevel::parse("bogus"), None);
    assert_eq!(SimdLevel::parse("off"), Some(SimdLevel::Off));
    assert_eq!(SimdLevel::parse("avx2"), Some(SimdLevel::Avx2));
    assert_eq!(SimdLevel::parse("auto"), None);
    // Resolved once per process; just exercise the lookups.
    let _ = pool::pin_level();
    let topo = pool::topology();
    assert!(topo.num_nodes() >= 1);
    assert_eq!(pool::parse_cpulist("0-2,5"), vec![0, 1, 2, 5]);
}
