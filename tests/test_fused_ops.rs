//! Fused operand-pass tier, integration level.
//!
//! The tentpole claims pinned here (see `backend/mod.rs` §8):
//!
//! * **ε-parity** — LancSVD with the fused A·Q + Gram sweep and the
//!   Gram-downdated first CholeskyQR pass agrees with the classic
//!   composition to rounding (the downdate W = G − HᵀH is algebraically
//!   exact); RandSVD's fused power step is a different (coarser)
//!   iteration, so it is held to residual quality, not trajectory
//!   parity.
//! * **Bitwise determinism at a fixed thread count** — repeating a
//!   fused solve under an unchanged pool reproduces every factor bit,
//!   for both algorithms, both dtypes, at 1 / 2 / all threads.
//! * **One operand pass per fused power iteration** — out of core, a
//!   RandSVD power step reads each disk shard exactly once instead of
//!   twice: p+1 total passes vs 2p unfused, pinned against the shard
//!   loader's own statistics and the staged ledger's disk tier.
//! * **In-core/out-of-core bitwise parity of the fused step** — the
//!   fused Aᵀ(A·Q) kernel is a band-serial scatter in global row order,
//!   the same order the shard stream replays, so the sharded fused
//!   solve is bitwise the in-core scatter-only fused solve.
//!
//! Pool-pinning tests serialize on `POOL_LOCK` and restore defaults on
//! exit (same idiom as `test_threaded_kernels`).

use std::sync::{Arc, Mutex};

use trunksvd::algo::lancsvd::lancsvd;
use trunksvd::algo::randsvd::randsvd;
use trunksvd::algo::{residuals, LancSvdOpts, RandSvdOpts, TruncatedSvd};
use trunksvd::backend::cpu::CpuBackend;
use trunksvd::backend::staged::StagedBackend;
use trunksvd::backend::Operand;
use trunksvd::gen::sparse::{generate, SparseSpec};
use trunksvd::sparse::shard;
use trunksvd::util::pool;
use trunksvd::util::scalar::Scalar;
use trunksvd::Csr;

static POOL_LOCK: Mutex<()> = Mutex::new(());

struct PoolReset;
impl Drop for PoolReset {
    fn drop(&mut self) {
        pool::set_num_threads(0);
    }
}

fn tmp(name: &str) -> String {
    let dir = std::env::temp_dir().join("trunksvd_fused_tests").join(name);
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir.to_str().unwrap().to_string()
}

fn test_matrix() -> Csr {
    generate(&SparseSpec { rows: 600, cols: 220, nnz: 7000, seed: 41, ..Default::default() })
}

fn assert_bitwise_svd<S: Scalar>(a: &TruncatedSvd<S>, b: &TruncatedSvd<S>, what: &str) {
    assert_eq!(a.iters, b.iters, "{what}: iteration counts differ");
    assert_eq!(a.sigma.len(), b.sigma.len(), "{what}: rank differs");
    for (i, (x, y)) in a.sigma.iter().zip(&b.sigma).enumerate() {
        assert_eq!(x.to_f64().to_bits(), y.to_f64().to_bits(), "{what}: sigma[{i}]");
    }
    for (m, (x, y)) in [("u", (&a.u, &b.u)), ("v", (&a.v, &b.v))] {
        assert_eq!(x.data().len(), y.data().len(), "{what}: {m} shape");
        for (i, (p, q)) in x.data().iter().zip(y.data()).enumerate() {
            assert_eq!(p.to_f64().to_bits(), q.to_f64().to_bits(), "{what}: {m}[{i}]");
        }
    }
}

fn lanc_opts(fuse: Option<bool>) -> LancSvdOpts {
    LancSvdOpts { r: 16, p: 2, b: 8, wanted: 6, seed: 7, fuse, ..Default::default() }
}

fn rand_opts(fuse: Option<bool>) -> RandSvdOpts {
    RandSvdOpts { r: 12, p: 6, b: 4, seed: 7, fuse, ..Default::default() }
}

/// One dtype's leg of the parity/determinism matrix, under whatever
/// thread count the caller pinned. `res_floor` absorbs the dtype's
/// converged-residual noise floor in the fused-vs-unfused quality
/// comparison.
fn fused_leg_at<S: Scalar>(a: &Csr<S>, sig_tol: f64, res_floor: f64) {
    // LancSVD: fused vs unfused is ε-parity (the Gram downdate is
    // algebraically exact; CholeskyQR2's second pass restores
    // orthogonality), on both backends.
    let mut be = CpuBackend::new_sparse(a.clone()).scatter_only();
    let lanc_unf = lancsvd(&mut be, &lanc_opts(Some(false))).unwrap();
    let mut be = CpuBackend::new_sparse(a.clone()).scatter_only();
    let lanc_fus = lancsvd(&mut be, &lanc_opts(Some(true))).unwrap();
    let s0 = lanc_unf.sigma[0].to_f64();
    for i in 0..6 {
        let d = (lanc_fus.sigma[i].to_f64() - lanc_unf.sigma[i].to_f64()).abs();
        assert!(
            d <= sig_tol * s0,
            "cpu lancsvd sigma[{i}]: fused {} vs unfused {} (tol {sig_tol:e})",
            lanc_fus.sigma[i].to_f64(),
            lanc_unf.sigma[i].to_f64()
        );
    }
    let mut sbe = StagedBackend::new_sparse(a.clone());
    let lanc_staged = lancsvd(&mut sbe, &lanc_opts(Some(true))).unwrap();
    assert_eq!(sbe.ledger().hot_panel_transfers(), 0, "fused hot loop leaked a panel");
    for i in 0..6 {
        let d = (lanc_staged.sigma[i].to_f64() - lanc_unf.sigma[i].to_f64()).abs();
        assert!(d <= sig_tol * s0, "staged lancsvd sigma[{i}] drifted past {sig_tol:e}");
    }

    // RandSVD fused: the fused power step is one AᵀA application per
    // iteration, same as the classic S1–S4 sweep, so at equal p its
    // measured residuals must track the unfused run's (same convergence
    // rate; only rounding trajectories differ).
    let mut be = CpuBackend::new_sparse(a.clone()).scatter_only();
    let rand_fus = randsvd(&mut be, &rand_opts(Some(true))).unwrap();
    let mut be = CpuBackend::new_sparse(a.clone()).scatter_only();
    let rand_unf = randsvd(&mut be, &rand_opts(Some(false))).unwrap();
    let max_res = |svd: &TruncatedSvd<S>| {
        let mut chk = CpuBackend::new_sparse(a.clone()).scatter_only();
        residuals(&mut chk, svd, 6).iter().fold(0.0f64, |m, &x| m.max(x))
    };
    let (rf, ru) = (max_res(&rand_fus), max_res(&rand_unf));
    assert!(
        rf <= 5.0 * ru + res_floor,
        "fused randsvd residual {rf:.3e} vs unfused {ru:.3e} (floor {res_floor:e})"
    );
    let mut sbe = StagedBackend::new_sparse(a.clone());
    let _ = randsvd(&mut sbe, &rand_opts(Some(true))).unwrap();
    assert_eq!(sbe.ledger().hot_panel_transfers(), 0, "fused randsvd leaked a panel");

    // Bitwise repeatability at this fixed thread count, both algorithms.
    let mut be = CpuBackend::new_sparse(a.clone()).scatter_only();
    let lanc_again = lancsvd(&mut be, &lanc_opts(Some(true))).unwrap();
    assert_bitwise_svd(&lanc_fus, &lanc_again, "lancsvd fused repeat");
    let mut be = CpuBackend::new_sparse(a.clone()).scatter_only();
    let rand_again = randsvd(&mut be, &rand_opts(Some(true))).unwrap();
    assert_bitwise_svd(&rand_fus, &rand_again, "randsvd fused repeat");
}

#[test]
fn fused_parity_and_determinism_across_dtypes_and_threads() {
    let _guard = POOL_LOCK.lock().unwrap_or_else(|p| p.into_inner());
    let _reset = PoolReset;
    let a = test_matrix();
    let a32: Csr<f32> = a.cast();
    // 0 = pool default (all available workers).
    for threads in [1usize, 2, 0] {
        pool::set_num_threads(threads);
        fused_leg_at::<f64>(&a, 1e-9, 1e-8);
        fused_leg_at::<f32>(&a32, 2e-3, 1e-3);
    }
}

#[test]
fn fused_sharded_randsvd_bitwise_matches_incore_and_halves_disk_traffic() {
    let _guard = POOL_LOCK.lock().unwrap_or_else(|p| p.into_inner());
    let _reset = PoolReset;
    pool::set_num_threads(3);
    let a = test_matrix();
    let dir = tmp("fused_parity");
    let n_shards = 5usize;
    let sd = Arc::new(shard::write_shards_from_csr(&dir, &a, n_shards).unwrap());
    let file_bytes = sd.total_file_bytes();
    // Tight cap: zero pinned prefix, every pass streams every shard.
    let cap = 2 * sd.max_resident_bytes::<f64>();
    let p = 10usize;
    let opts = RandSvdOpts { r: 12, p, b: 4, seed: 7, fuse: Some(true), ..Default::default() };

    // The fused Aᵀ(A·Q) is a band-serial scatter in global row order —
    // the same order the shard stream replays — so out-of-core fused is
    // bitwise the in-core scatter-only fused solve.
    let mut be_in = CpuBackend::new_sparse(a.clone()).scatter_only();
    let svd_in = randsvd(&mut be_in, &opts).unwrap();
    let mut be_sh = CpuBackend::<f64>::new(Operand::sharded(Arc::clone(&sd), cap));
    be_sh.ensure_operand_resident().unwrap();
    let svd_sh = randsvd(&mut be_sh, &opts).unwrap();
    assert_bitwise_svd(&svd_in, &svd_sh, "randsvd fused ooc");

    // Pass accounting: p−1 fused one-sweep iterations + the final
    // unfused iteration's A and Aᵀ passes = p+1, against 2p unfused.
    let st = be_sh.shard_stats().unwrap();
    assert_eq!(st.passes, p + 1, "fused solve must make exactly p+1 operand passes");
    let mut be_unf = CpuBackend::<f64>::new(Operand::sharded(Arc::clone(&sd), cap));
    be_unf.ensure_operand_resident().unwrap();
    let _ = randsvd(&mut be_unf, &RandSvdOpts { fuse: Some(false), ..opts.clone() }).unwrap();
    let st_unf = be_unf.shard_stats().unwrap();
    assert_eq!(st_unf.passes, 2 * p, "unfused solve reads the operand twice per iteration");

    // Staged ledger: the disk tier sees each pass stream the whole
    // shard set exactly once, and the fused/unfused byte ratio is the
    // tentpole's ≥1.8× traffic drop (2p/(p+1) at p = 10).
    let mut sbe: StagedBackend = StagedBackend::new_sharded(Arc::clone(&sd), cap);
    sbe.ensure_operand_resident().unwrap();
    let _ = randsvd(&mut sbe, &opts).unwrap();
    let t = sbe.ledger().totals();
    assert_eq!(t.disk_bytes as usize, (p + 1) * file_bytes, "fused staged disk bytes");
    assert_eq!(t.hot_panel_transfers, 0);
    let mut sbe_unf: StagedBackend = StagedBackend::new_sharded(Arc::clone(&sd), cap);
    sbe_unf.ensure_operand_resident().unwrap();
    let _ = randsvd(&mut sbe_unf, &RandSvdOpts { fuse: Some(false), ..opts }).unwrap();
    let t_unf = sbe_unf.ledger().totals();
    assert_eq!(t_unf.disk_bytes as usize, 2 * p * file_bytes, "unfused staged disk bytes");
    let ratio = t_unf.disk_bytes as f64 / t.disk_bytes as f64;
    assert!(ratio >= 1.8, "fused power step must cut disk traffic >= 1.8x, got {ratio:.3}");
}

#[test]
fn fused_auto_policy_engages_for_disk_operands() {
    // `fuse: None` + an on-disk operand must resolve to the fused path
    // (the cost model's `on_disk` arm) — pinned end to end via the pass
    // counter rather than any internal flag.
    let _guard = POOL_LOCK.lock().unwrap_or_else(|p| p.into_inner());
    let _reset = PoolReset;
    pool::set_num_threads(2);
    let a = test_matrix();
    let dir = tmp("auto_policy");
    let sd = Arc::new(shard::write_shards_from_csr(&dir, &a, 4).unwrap());
    let cap = 2 * sd.max_resident_bytes::<f64>();
    let p = 6usize;
    let mut be = CpuBackend::<f64>::new(Operand::sharded(Arc::clone(&sd), cap));
    be.ensure_operand_resident().unwrap();
    let opts = RandSvdOpts { r: 12, p, b: 4, seed: 7, fuse: None, ..Default::default() };
    let svd = randsvd(&mut be, &opts).unwrap();
    assert_eq!(be.shard_stats().unwrap().passes, p + 1, "auto policy must fuse on disk");
    // Backstop: the auto decision lands on exactly the forced-fused
    // trajectory (bitwise, per the in-core/out-of-core parity claim).
    let mut be_in = CpuBackend::new_sparse(a).scatter_only();
    let svd_in =
        randsvd(&mut be_in, &RandSvdOpts { fuse: Some(true), ..opts }).unwrap();
    assert_bitwise_svd(&svd_in, &svd, "auto-fused vs forced-fused");
}
