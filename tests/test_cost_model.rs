//! Property tests for the cost model's *policy* functions — the
//! adaptive-transpose threshold and the pool's serial cutoff. Future
//! calibration of the model constants from `BENCH_kernels.json`
//! measurements must not be able to silently invert these policies:
//! monotonicity and crossover shape are pinned here, not exact values.

use trunksvd::cost::{
    adaptive_transpose_threshold, ca3, ca4, ca5, lancsvd_cost, parallel_cutoff, parse_fuse,
    randsvd_cost, should_fuse_with, FusePolicy, Problem, FUSE_LLC_BYTES,
};

const CAP: usize = 64;

#[test]
fn threshold_monotone_in_block_width() {
    // Wider column blocks amortize the one-time build over more scatter
    // traffic per call ⇒ the threshold is non-increasing in k, for every
    // operand profile in a sweep.
    for &(rows, cols, nnz) in &[
        (10_000usize, 4_000usize, 80_000usize),
        (2_000, 50_000, 300_000),
        (100_000, 100_000, 1_000_000),
    ] {
        let mut prev = usize::MAX;
        for k in [1usize, 2, 4, 8, 16, 32, 64] {
            let t = adaptive_transpose_threshold(rows, cols, nnz, k);
            assert!((1..=CAP).contains(&t), "threshold {t} out of [1, {CAP}]");
            assert!(
                t <= prev,
                "threshold must not grow with k: k={k} gives {t}, previous {prev} \
                 (rows {rows} cols {cols} nnz {nnz})"
            );
            prev = t;
        }
    }
}

#[test]
fn threshold_nnz_sweep_has_unique_crossover() {
    // Sweeping nnz upward at fixed shape/k, the policy starts in the
    // cache-resident regime (threshold pinned at the cap: never build)
    // and drops to the DRAM-crossover estimate exactly once — a single
    // downward step, never a re-ascent (a re-ascent would mean the
    // policy re-disables the build for *larger* operands).
    for k in [2usize, 8, 16] {
        let mut saw_drop = false;
        let mut prev = CAP;
        for e in 0..=24 {
            // nnz from 2^8 to 2^32: spans both sides of the gate.
            let nnz = 1usize << (8 + e);
            let t = adaptive_transpose_threshold(50_000, 20_000, nnz, k);
            if t < prev {
                assert!(!saw_drop, "second drop at nnz {nnz} (k {k}): {prev} -> {t}");
                saw_drop = true;
            } else {
                assert_eq!(t, prev, "threshold re-ascended at nnz {nnz} (k {k})");
            }
            prev = t;
        }
        assert!(saw_drop, "crossover must exist inside the sweep (k {k})");
        assert!(prev < CAP, "post-crossover threshold must leave the cap (k {k})");
    }
}

#[test]
fn threshold_aspect_bump_orders_wide_operands() {
    // Wide-and-short operands scatter with worse locality: their
    // crossover must come no later than the square operand's at every k.
    for k in [1usize, 2, 4, 8] {
        let square = adaptive_transpose_threshold(30_000, 30_000, 500_000, k);
        let wide = adaptive_transpose_threshold(1_000, 200_000, 500_000, k);
        assert!(wide <= square, "k={k}: wide {wide} > square {square}");
    }
}

#[test]
fn threshold_degenerate_inputs_stay_in_range() {
    let cases = [
        (0usize, 0usize, 0usize, 0usize),
        (1, 1, 1, 1),
        (10, 10, usize::MAX / 2, 0),
        (0, 1 << 20, 1 << 20, 64),
    ];
    for (rows, cols, nnz, k) in cases {
        let t = adaptive_transpose_threshold(rows, cols, nnz, k);
        assert!((1..=CAP).contains(&t), "({rows},{cols},{nnz},{k}) gave {t}");
    }
}

#[test]
fn parallel_cutoff_sits_between_dispatch_and_panel_scale() {
    let c = parallel_cutoff();
    // Lower bound: a band must own at least a cache line of work, or
    // dispatch cost dominates trivially.
    assert!(c >= 64, "cutoff {c} below any plausible dispatch break-even");
    // Upper bound: the paper-scale panels (m >= 4096, b >= 8) must fan
    // out even split across two bands.
    assert!(c <= 4096 * 8 / 2, "cutoff {c} would serialize paper-scale panels");
    // Stability: the policy is a pure function (no hidden global state).
    assert_eq!(c, parallel_cutoff());
}

#[test]
fn fuse_policy_is_monotone_with_single_crossover() {
    // Same shape-pinning as the transpose threshold: calibration (or an
    // LLC-constant retune) must not be able to invert the fusion gate.
    // Sweeping operand bytes upward under Auto, the decision flips
    // off→on exactly once, at the LLC boundary.
    let mut flips = 0;
    let mut prev = should_fuse_with(FusePolicy::Auto, 0, false);
    assert!(!prev, "an empty operand must not fuse under Auto");
    for e in 10..=40 {
        let f = should_fuse_with(FusePolicy::Auto, 1usize << e, false);
        if f != prev {
            assert!(f && !prev, "fusion gate re-descended at 2^{e} bytes");
            flips += 1;
        }
        prev = f;
    }
    assert_eq!(flips, 1, "exactly one off→on crossover in the sweep");
    assert!(prev, "post-crossover the gate must stay on");
    // Disk residency dominates size: even a tiny on-disk operand fuses
    // (every saved pass is a saved read of the whole shard set).
    assert!(should_fuse_with(FusePolicy::Auto, 0, true));
    // Explicit overrides are absolute in both directions.
    assert!(should_fuse_with(FusePolicy::On, 0, false));
    assert!(!should_fuse_with(FusePolicy::Off, usize::MAX, true));
}

#[test]
fn fuse_env_spellings_match_knob_conventions() {
    // TRUNKSVD_FUSE accepts the same boolean spellings as the other
    // runtime knobs, trimmed and case-insensitive; anything else is
    // None (the resolver then falls back to Auto).
    for (s, want) in [
        ("auto", Some(FusePolicy::Auto)),
        ("  Auto\t", Some(FusePolicy::Auto)),
        ("on", Some(FusePolicy::On)),
        ("ON", Some(FusePolicy::On)),
        ("1", Some(FusePolicy::On)),
        ("true", Some(FusePolicy::On)),
        ("off", Some(FusePolicy::Off)),
        ("0", Some(FusePolicy::Off)),
        ("False", Some(FusePolicy::Off)),
        ("", None),
        ("yes", None),
        ("fused", None),
    ] {
        assert_eq!(parse_fuse(s), want, "spelling {s:?}");
    }
    let _ = FUSE_LLC_BYTES; // re-exported constant stays public API
}

#[test]
fn table1_costs_are_monotone_in_every_argument() {
    // CA4/CA5/CA3 monotonicity: calibration cannot flip a cost's sign
    // or direction without breaking these.
    assert!(ca4(16, 2000) > ca4(16, 1000));
    assert!(ca4(32, 1000) > ca4(16, 1000));
    assert!(ca5(16, 1000, 64) > ca5(16, 1000, 16));
    assert!(ca5(16, 2000, 64) > ca5(16, 1000, 64));
    assert!(ca3(16, 1000, 256) > ca3(16, 1000, 64));
    // And the algorithm totals grow with every solve parameter.
    let prob = Problem { m: 20_000, n: 8_000, nnz: Some(160_000) };
    assert!(randsvd_cost(prob, 16, 8, 16).total() > randsvd_cost(prob, 16, 4, 16).total());
    assert!(randsvd_cost(prob, 32, 4, 16).total() > randsvd_cost(prob, 16, 4, 16).total());
    assert!(lancsvd_cost(prob, 64, 4, 16).total() > lancsvd_cost(prob, 64, 2, 16).total());
    assert!(lancsvd_cost(prob, 128, 2, 16).total() > lancsvd_cost(prob, 64, 2, 16).total());
}
