//! Property tests for the `gen/` scenario zoo: the conformance suite's
//! end-to-end targets are only meaningful if the generated problems
//! actually have the spectra and sparsity structure they claim.
//!
//! Dense: `dense_with_spectrum` must *realize* its prescribed singular
//! values (decay law, condition number) with orthonormal factors, and
//! `paper_spectrum` must follow Eq. 16 exactly. Sparse: `generate` must
//! honor its nnz/shape/structure invariants (degree clamps, sorted
//! unique columns, skew ordering, value-decay spread) and stay
//! transpose-consistent (the invariant the staged backend's arena Aᵀ
//! build rests on).

use trunksvd::gen::dense::{dense_with_spectrum, paper_dense, paper_spectrum};
use trunksvd::gen::sparse::{generate, SparseSpec};
use trunksvd::la::blas3::mat_tn;
use trunksvd::la::norms::orth_error;
use trunksvd::la::svd::jacobi_svd;

// ---- dense generators --------------------------------------------------

#[test]
fn dense_realizes_prescribed_decay_laws() {
    // Geometric, algebraic, and clustered decay profiles: the SVD of the
    // generated matrix must reproduce each spectrum to f64 rounding.
    let geometric: Vec<f64> = (0..12).map(|i| 3.0f64.powi(-(i as i32))).collect();
    let algebraic: Vec<f64> = (1..=12).map(|i| 1.0 / (i as f64).powi(2)).collect();
    let clustered: Vec<f64> = (0..12).map(|i| if i < 6 { 1.0 } else { 1e-3 }).collect();
    for (label, sigma) in
        [("geometric", geometric), ("algebraic", algebraic), ("clustered", clustered)]
    {
        let prob = dense_with_spectrum(60, 12, &sigma, 11);
        let svd = jacobi_svd(&prob.a).unwrap();
        for i in 0..12 {
            let rel = (svd.s[i] - sigma[i]).abs() / sigma[i];
            assert!(rel < 1e-10, "{label}: sigma_{i} rel err {rel:.3e}");
        }
        // The factors really are orthonormal and really diagonalize A:
        // UᵀAV = diag(sigma).
        assert!(orth_error(&prob.u) < 1e-12, "{label}: U orth");
        assert!(orth_error(&prob.v) < 1e-12, "{label}: V orth");
        let core = mat_tn(&prob.u, &trunksvd::la::blas3::mat_nn(&prob.a, &prob.v));
        for i in 0..12 {
            for j in 0..12 {
                let want = if i == j { sigma[i] } else { 0.0 };
                assert!(
                    (core.at(i, j) - want).abs() < 1e-10,
                    "{label}: core({i},{j}) = {} want {want}",
                    core.at(i, j)
                );
            }
        }
    }
}

#[test]
fn dense_realizes_prescribed_condition_number() {
    // cond(A) = sigma_max / sigma_min must match the request across
    // several orders of magnitude.
    for decades in [2i32, 5, 8] {
        let cond = 10f64.powi(decades);
        let n = 10;
        let sigma: Vec<f64> =
            (0..n).map(|i| cond.powf(-(i as f64) / (n as f64 - 1.0))).collect();
        let prob = dense_with_spectrum(40, n, &sigma, 7);
        let svd = jacobi_svd(&prob.a).unwrap();
        let measured = svd.s[0] / svd.s[n - 1];
        let rel = (measured - cond).abs() / cond;
        assert!(rel < 1e-6, "cond 1e{decades}: measured {measured:.6e} rel err {rel:.2e}");
    }
}

#[test]
fn paper_spectrum_follows_eq16() {
    let n = 64;
    let half = n / 2;
    let s = paper_spectrum(n);
    assert_eq!(s.len(), n);
    // Top half: descending geometric with ratio 10^(15/half); the i-th
    // largest is 10^(15·(half−i)/half − 14).
    for i in 0..half {
        let expect = 10f64.powf(15.0 * (half - i) as f64 / half as f64 - 14.0);
        assert!(
            (s[i] - expect).abs() / expect < 1e-12,
            "paper spectrum [{i}] = {} want {expect}",
            s[i]
        );
    }
    // Bottom half sits at the 1e-14 floor.
    for (i, &v) in s.iter().enumerate().skip(half) {
        assert_eq!(v, 1e-14, "floor entry {i}");
    }
    // paper_dense realizes the top of that spectrum (floor entries are
    // below Jacobi's resolution, the leading ones are exact).
    let prob = paper_dense(48, 16, 3);
    let svd = jacobi_svd(&prob.a).unwrap();
    let expect = paper_spectrum(16);
    for i in 0..6 {
        let rel = (svd.s[i] - expect[i]).abs() / expect[i];
        assert!(rel < 1e-9, "paper_dense sigma_{i} rel err {rel:.2e}");
    }
}

// ---- sparse generator --------------------------------------------------

#[test]
fn sparse_honors_shape_and_nnz_across_profiles() {
    for (rows, cols, nnz, skew) in [
        (200usize, 90usize, 1500usize, 0.0f64),
        (120, 300, 4000, 0.8),
        (500, 60, 3000, 1.6),
    ] {
        let spec = SparseSpec { rows, cols, nnz, seed: 21, skew, ..Default::default() };
        let a = generate(&spec);
        assert_eq!((a.rows(), a.cols()), (rows, cols), "shape");
        let requested = nnz.min(rows * cols / 2).max(rows.max(cols));
        let got = a.nnz() as isize;
        assert!(
            (got - requested as isize).unsigned_abs() <= rows / 2 + 32,
            "nnz {got} vs requested {requested} (rows {rows} cols {cols} skew {skew})"
        );
        // Structural invariants: sorted, unique, in-range column indices
        // per row; no row exceeds the column count.
        for i in 0..rows {
            let (ci, _) = a.row(i);
            assert!(ci.len() <= cols, "row {i} degree {} > cols", ci.len());
            for w in ci.windows(2) {
                assert!(w[0] < w[1], "row {i}: unsorted/duplicate columns");
            }
            if let Some(&last) = ci.last() {
                assert!((last as usize) < cols, "row {i}: column out of range");
            }
        }
    }
}

#[test]
fn sparse_nnz_request_is_clamped_not_overflowed() {
    // Requests above the rows*cols/2 density cap clamp; requests below
    // max(rows, cols) are raised to it (every suite entry stays
    // rank-coverable).
    let over = generate(&SparseSpec {
        rows: 40,
        cols: 40,
        nnz: 10_000,
        seed: 1,
        ..Default::default()
    });
    assert!(over.nnz() <= 40 * 40 / 2 + 40, "over-dense clamp: {}", over.nnz());
    let under = generate(&SparseSpec { rows: 80, cols: 30, nnz: 1, seed: 1, ..Default::default() });
    assert!(under.nnz() >= 80 - 40, "sparse floor: {}", under.nnz());
}

#[test]
fn sparse_skew_orders_max_degree_monotonically() {
    // The Zipf exponent must *order* the heavy-row tail: higher skew ⇒
    // heavier heaviest row (weak monotonicity with slack for rounding).
    let max_deg = |skew: f64| {
        let a = generate(&SparseSpec {
            rows: 300,
            cols: 200,
            nnz: 3000,
            seed: 5,
            skew,
            ..Default::default()
        });
        (0..a.rows()).map(|i| a.row(i).0.len()).max().unwrap()
    };
    let d0 = max_deg(0.0);
    let d1 = max_deg(0.8);
    let d2 = max_deg(1.6);
    assert!(d1 >= d0, "skew 0.8 ({d1}) vs 0.0 ({d0})");
    assert!(d2 > d1, "skew 1.6 ({d2}) vs 0.8 ({d1})");
}

#[test]
fn sparse_value_decay_controls_magnitude_spread() {
    let spread = |decay: f64| {
        let a = generate(&SparseSpec {
            rows: 300,
            cols: 150,
            nnz: 3000,
            seed: 9,
            value_decay: decay,
            ..Default::default()
        });
        let mags: Vec<f64> = a.values().iter().map(|v| v.abs()).filter(|&v| v > 0.0).collect();
        let max = mags.iter().cloned().fold(0.0, f64::max);
        let min = mags.iter().cloned().fold(f64::INFINITY, f64::min);
        max / min
    };
    // Row+column scalings each span `decay` decades, so the spread grows
    // steeply with the requested decay (and is tiny without it).
    let s1 = spread(1.0);
    let s6 = spread(6.0);
    assert!(s6 > s1 * 1e3, "decay 6 spread {s6:.1e} vs decay 1 spread {s1:.1e}");
    assert!(s6 > 1e6, "decay 6 spread {s6:.1e}");
}

#[test]
fn sparse_transpose_is_involutive_and_preserves_structure() {
    // The staged backend's arena Aᵀ (and the adaptive-transpose cache)
    // rely on transpose being an exact structural involution.
    let spec = SparseSpec {
        rows: 180,
        cols: 77,
        nnz: 2100,
        seed: 13,
        skew: 1.2,
        ..Default::default()
    };
    let a = generate(&spec);
    let at = a.transpose();
    assert_eq!((at.rows(), at.cols()), (77, 180));
    assert_eq!(at.nnz(), a.nnz());
    let aa = at.transpose();
    assert_eq!(aa.indptr(), a.indptr());
    assert_eq!(aa.indices(), a.indices());
    assert_eq!(aa.values(), a.values());
    // And numerically: (Aᵀ)ᵀ == A densely.
    assert_eq!(aa.to_dense().max_abs_diff(&a.to_dense()), 0.0);
}

#[test]
fn generators_are_deterministic_in_seed() {
    let spec = SparseSpec { rows: 150, cols: 60, nnz: 1200, seed: 17, ..Default::default() };
    let a = generate(&spec);
    let b = generate(&spec);
    assert_eq!(a.indptr(), b.indptr());
    assert_eq!(a.indices(), b.indices());
    assert_eq!(a.values(), b.values());
    let other = generate(&SparseSpec { seed: 18, ..spec });
    assert_ne!(a.values(), other.values(), "different seeds must differ");
    let d1 = dense_with_spectrum(30, 8, &[8.0, 4.0, 2.0, 1.0, 0.5, 0.25, 0.125, 0.0625], 23);
    let d2 = dense_with_spectrum(30, 8, &[8.0, 4.0, 2.0, 1.0, 0.5, 0.25, 0.125, 0.0625], 23);
    assert_eq!(d1.a.data(), d2.a.data());
}
