//! f32-vs-f64 parity of the generic `Scalar` substrate.
//!
//! Mirrors `test_threaded_kernels.rs`: the same worker-thread sweep over
//! the sparse/Gram kernels, but instantiated at *both* element precisions
//! with tolerances scaled by `S::EPSILON` instead of hard-coded f64
//! magnitudes, plus cross-dtype agreement (the f32 kernel outputs must
//! match the f64 reference to f32 accuracy — deterministic because both
//! dtypes draw from the same seeded f64 RNG stream and round).
//!
//! The end-to-end test runs `lancsvd`/`randsvd` at fp32 on a small
//! synthetic problem and asserts the *measured* relative residuals meet
//! the paper's 1e-4-class accuracy target — the same target the fp64 run
//! is held to — validating the single-precision path rather than assuming
//! it.

use std::sync::Mutex;

use trunksvd::algo::{lancsvd::lancsvd, randsvd::randsvd, residuals, LancSvdOpts, RandSvdOpts};
use trunksvd::backend::cpu::CpuBackend;
use trunksvd::gen::dense::dense_with_spectrum;
use trunksvd::gen::sparse::{generate, SparseSpec};
use trunksvd::la::blas3::{self, mat_nn, mat_tn};
use trunksvd::la::mat::Mat;
use trunksvd::la::norms::orth_error;
use trunksvd::sparse::coo::Coo;
use trunksvd::sparse::csr::Csr;
use trunksvd::util::pool;
use trunksvd::util::rng::Rng;
use trunksvd::util::scalar::Scalar;

static POOL_LOCK: Mutex<()> = Mutex::new(());

const THREAD_SWEEP: [usize; 3] = [1, 2, 8];

/// ε-scaled kernel tolerance: ~5e4·ε_S covers the accumulation error of
/// the longest row/tile dots in these shapes with a wide margin while
/// staying far below any real defect (f64 ≈ 1.1e-11, f32 ≈ 6.0e-3).
fn kernel_tol<S: Scalar>() -> f64 {
    5e4 * S::EPSILON.to_f64()
}

/// Restores the pool defaults even if the guarded closure panics.
struct PoolReset;
impl Drop for PoolReset {
    fn drop(&mut self) {
        pool::set_num_threads(0);
        pool::set_parallel_cutoff(0);
    }
}

fn random_coo(rows: usize, cols: usize, nnz: usize, seed: u64) -> Coo {
    let mut rng = Rng::new(seed);
    let mut c = Coo::new(rows, cols);
    for _ in 0..nnz {
        c.push(rng.below(rows), rng.below(cols), rng.normal());
    }
    c
}

/// One full kernel-parity sweep at precision `S`: spmm / spmm_t /
/// transpose-equivalence / gram against the dense reference at the same
/// precision, across the thread sweep.
fn kernel_parity_sweep<S: Scalar>() {
    let tol = kernel_tol::<S>();
    let shapes: &[(usize, usize, usize)] = &[
        (1, 7, 4),
        (37, 23, 150),
        (129, 65, 1000),
        (1000, 333, 12_000), // takes the parallel transpose fill path
    ];
    for &t in &THREAD_SWEEP {
        pool::set_num_threads(t);
        for (si, &(m, n, nnz)) in shapes.iter().enumerate() {
            let a64 = Csr::from_coo(&random_coo(m, n, nnz, 140 + si as u64)).unwrap();
            let a: Csr<S> = a64.cast();
            let ad: Mat<S> = a.to_dense();
            let mut rng = Rng::new(190 + si as u64);
            for k in [1usize, 3, 8] {
                let x: Mat<S> = Mat::randn(n, k, &mut rng);
                let mut y: Mat<S> = Mat::zeros(m, k);
                a.spmm(x.as_ref(), y.as_mut());
                let err = y.max_abs_diff(&mat_nn(&ad, &x)).to_f64();
                assert!(err < tol, "spmm {} t={t} {m}x{n} k={k}: {err:.3e}", S::DTYPE);
                let z: Mat<S> = Mat::randn(m, k, &mut rng);
                let mut w: Mat<S> = Mat::zeros(n, k);
                a.spmm_t(z.as_ref(), w.as_mut());
                let err = w.max_abs_diff(&mat_tn(&ad, &z)).to_f64();
                assert!(err < tol, "spmm_t {} t={t} {m}x{n} k={k}: {err:.3e}", S::DTYPE);
                // scatter == explicit-transpose gather at this precision
                let at = a.transpose();
                let mut w2: Mat<S> = Mat::zeros(n, k);
                at.spmm(z.as_ref(), w2.as_mut());
                let err = w.max_abs_diff(&w2).to_f64();
                assert!(err < tol, "transpose {} t={t} {m}x{n} k={k}: {err:.3e}", S::DTYPE);
            }
            let q: Mat<S> = Mat::randn(m, 7.min(m), &mut rng);
            let g = blas3::gram(q.as_ref());
            let err = g.max_abs_diff(&mat_tn(&q, &q)).to_f64();
            assert!(err < tol, "gram {} t={t} rows={m}: {err:.3e}", S::DTYPE);
        }
    }
}

#[test]
fn kernels_hold_eps_scaled_parity_in_both_dtypes() {
    let _guard = POOL_LOCK.lock().unwrap_or_else(|p| p.into_inner());
    let _reset = PoolReset;
    // Force the parallel path so the sweep covers the banded kernels on
    // these small fixtures (the default cutoff would run them serial).
    pool::set_parallel_cutoff(1);
    kernel_parity_sweep::<f64>();
    kernel_parity_sweep::<f32>();
}

#[test]
fn f32_kernels_match_f64_reference_across_threads() {
    // Cross-dtype: the f32 outputs must agree with the f64 outputs of the
    // *same* seeded inputs to f32 accuracy — deterministic because both
    // dtypes round the same f64 RNG stream (see util::rng).
    let _guard = POOL_LOCK.lock().unwrap_or_else(|p| p.into_inner());
    let _reset = PoolReset;
    pool::set_parallel_cutoff(1); // cover the banded paths on small fixtures
    let tol = kernel_tol::<f32>();
    for &t in &THREAD_SWEEP {
        pool::set_num_threads(t);
        let a64 = Csr::from_coo(&random_coo(400, 170, 6000, 77)).unwrap();
        let a32: Csr<f32> = a64.cast();
        for k in [1usize, 5, 8] {
            let mut rng64 = Rng::new(33);
            let mut rng32 = Rng::new(33);
            let x64: Mat<f64> = Mat::randn(170, k, &mut rng64);
            let x32: Mat<f32> = Mat::randn(170, k, &mut rng32);
            let mut y64: Mat<f64> = Mat::zeros(400, k);
            let mut y32: Mat<f32> = Mat::zeros(400, k);
            a64.spmm(x64.as_ref(), y64.as_mut());
            a32.spmm(x32.as_ref(), y32.as_mut());
            let err = y64.cast::<f32>().max_abs_diff(&y32).to_f64();
            assert!(err < tol, "spmm cross-dtype t={t} k={k}: {err:.3e}");
            let z64: Mat<f64> = Mat::randn(400, k, &mut rng64);
            let z32: Mat<f32> = Mat::randn(400, k, &mut rng32);
            let mut w64: Mat<f64> = Mat::zeros(170, k);
            let mut w32: Mat<f32> = Mat::zeros(170, k);
            a64.spmm_t(z64.as_ref(), w64.as_mut());
            a32.spmm_t(z32.as_ref(), w32.as_mut());
            let err = w64.cast::<f32>().max_abs_diff(&w32).to_f64();
            assert!(err < tol, "spmm_t cross-dtype t={t} k={k}: {err:.3e}");
        }
        let mut rng64 = Rng::new(44);
        let mut rng32 = Rng::new(44);
        let q64: Mat<f64> = Mat::randn(700, 9, &mut rng64);
        let q32: Mat<f32> = Mat::randn(700, 9, &mut rng32);
        let g64 = blas3::gram(q64.as_ref());
        let g32 = blas3::gram(q32.as_ref());
        // gram accumulates 700-term dots; scale the tolerance by the
        // row count times the unit-variance entry magnitude.
        let err = g64.cast::<f32>().max_abs_diff(&g32).to_f64();
        assert!(err < 50.0 * tol, "gram cross-dtype t={t}: {err:.3e}");
    }
}

/// Solve at precision `S` on a known mild spectrum and return the largest
/// measured relative residual over the leading `wanted` triplets.
fn lanc_residual_at<S: Scalar>(a64: &Mat, wanted: usize) -> f64 {
    let a: Mat<S> = a64.cast();
    let mut be: CpuBackend<S> = CpuBackend::new_dense(a.clone());
    let opts = LancSvdOpts { r: 16, p: 5, b: 8, wanted, seed: 9, ..Default::default() };
    let svd = lancsvd(&mut be, &opts).unwrap();
    // Orthogonality defect scales like √ε of the working precision
    // (≈1.5e-8 at f64, ≈3.5e-4 at f32) — generous vs the observed defect.
    assert!(orth_error(&svd.u) < S::EPSILON.to_f64().sqrt(), "U orth ({})", S::DTYPE);
    let mut check: CpuBackend<S> = CpuBackend::new_dense(a);
    residuals(&mut check, &svd, wanted).iter().fold(0.0f64, |m, &x| m.max(x))
}

fn rand_residual_at<S: Scalar>(a64: &Mat, wanted: usize) -> f64 {
    let a: Mat<S> = a64.cast();
    let mut be: CpuBackend<S> = CpuBackend::new_dense(a.clone());
    let opts = RandSvdOpts { r: 16, p: 12, b: 8, seed: 9, ..Default::default() };
    let svd = randsvd(&mut be, &opts).unwrap();
    let mut check: CpuBackend<S> = CpuBackend::new_dense(a);
    residuals(&mut check, &svd, wanted).iter().fold(0.0f64, |m, &x| m.max(x))
}

#[test]
fn end_to_end_fp32_meets_the_fp64_accuracy_target() {
    let _guard = POOL_LOCK.lock().unwrap_or_else(|p| p.into_inner());
    let _reset = PoolReset;
    pool::set_num_threads(2);
    // Mildly conditioned dense problem (σ_i = 1/(1+i)): both precisions
    // must reach the paper's 1e-4-class relative-residual target on the
    // leading triplets.
    const TARGET: f64 = 1e-4;
    let sigma: Vec<f64> = (0..16).map(|i| 1.0 / (1.0 + i as f64)).collect();
    let prob = dense_with_spectrum(150, 16, &sigma, 5);
    let lanc64 = lanc_residual_at::<f64>(&prob.a, 4);
    let lanc32 = lanc_residual_at::<f32>(&prob.a, 4);
    assert!(lanc64 < TARGET, "lancsvd f64 residual {lanc64:.3e}");
    assert!(lanc32 < TARGET, "lancsvd f32 residual {lanc32:.3e}");
    let rand64 = rand_residual_at::<f64>(&prob.a, 4);
    let rand32 = rand_residual_at::<f32>(&prob.a, 4);
    assert!(rand64 < TARGET, "randsvd f64 residual {rand64:.3e}");
    assert!(rand32 < TARGET, "randsvd f32 residual {rand32:.3e}");
}

#[test]
fn fp32_lancsvd_on_sparse_operand() {
    let _guard = POOL_LOCK.lock().unwrap_or_else(|p| p.into_inner());
    let _reset = PoolReset;
    pool::set_num_threads(2);
    let spec = SparseSpec {
        rows: 200,
        cols: 90,
        nnz: 2500,
        seed: 9,
        value_decay: 1.0,
        ..Default::default()
    };
    let a64 = generate(&spec);
    let a32: Csr<f32> = a64.cast();
    let mut be: CpuBackend<f32> = CpuBackend::new_sparse(a32.clone());
    let opts = LancSvdOpts { r: 48, p: 3, b: 16, wanted: 8, seed: 1, ..Default::default() };
    let svd = lancsvd(&mut be, &opts).unwrap();
    let mut check: CpuBackend<f32> = CpuBackend::new_sparse(a32);
    let res = residuals(&mut check, &svd, 8);
    assert!(res.iter().all(|&x| x < 1e-3), "fp32 sparse lancsvd residuals {res:?}");
    // Singular values agree with the f64 solve to f32-class accuracy.
    let mut be64 = CpuBackend::new_sparse(a64.clone());
    let svd64 = lancsvd(&mut be64, &opts).unwrap();
    for i in 0..8 {
        let s64 = svd64.sigma[i];
        let s32 = svd.sigma[i].to_f64();
        assert!((s64 - s32).abs() < 1e-3 * s64.max(1e-6), "sigma_{i}: f64 {s64} vs f32 {s32}");
    }
}

/// ε-scaled parity of the out-parameter (`*_into`) kernel paths at one
/// precision: the workspace-planned forms must agree with the dense
/// reference compositions to the same tolerance class as the
/// value-returning forms they replaced.
fn into_path_parity_sweep<S: Scalar>() {
    use trunksvd::backend::Backend;
    use trunksvd::la::chol::potrf_into;
    use trunksvd::la::norms::orth_error;
    use trunksvd::la::workspace::{Plan, Workspace};

    let tol = kernel_tol::<S>();
    let rows = 150usize;
    let (s_hist, b) = (12usize, 6usize);
    let mut rng = Rng::new(314);
    let ws: Workspace<S> = Workspace::new(Plan::orth(rows, s_hist, b));
    let mut be: CpuBackend<S> = CpuBackend::new_dense(Mat::<S>::zeros(1, 1));

    // gram_into == QᵀQ.
    let q: Mat<S> = Mat::randn(rows, b, &mut rng);
    let mut w: Mat<S> = Mat::zeros(b, b);
    be.gram_into(q.as_ref(), w.as_mut());
    let err = w.max_abs_diff(&mat_tn(&q, &q)).to_f64();
    assert!(err < tol, "gram_into {}: {err:.3e}", S::DTYPE);

    // proj_into / subtract_proj: Q − P·(PᵀQ) == reference.
    let p: Mat<S> = trunksvd::la::qr::random_orthonormal(rows, s_hist, &mut rng);
    let y0: Mat<S> = Mat::randn(rows, b, &mut rng);
    let mut h: Mat<S> = Mat::zeros(s_hist, b);
    be.proj_into(p.as_ref(), y0.as_ref(), h.as_mut());
    let err = h.max_abs_diff(&mat_tn(&p, &y0)).to_f64();
    assert!(err < tol, "proj_into {}: {err:.3e}", S::DTYPE);
    let mut y = y0.clone();
    be.subtract_proj(y.as_mut(), p.as_ref(), h.as_ref());
    let mut expect = y0.clone();
    let ph = mat_nn(&p, &h);
    for (e, c) in expect.data_mut().iter_mut().zip(ph.data()) {
        *e -= *c;
    }
    let err = y.max_abs_diff(&expect).to_f64();
    assert!(err < tol, "subtract_proj {}: {err:.3e}", S::DTYPE);

    // potrf_into reconstructs an SPD Gram matrix.
    let g: Mat<S> = Mat::randn(rows, b, &mut rng);
    let mut spd = mat_tn(&g, &g);
    for i in 0..b {
        let v = spd.at(i, i) + S::from_f64(1e-2);
        spd.set(i, i, v);
    }
    let mut l: Mat<S> = Mat::zeros(b, b);
    potrf_into(spd.as_ref(), l.as_mut()).unwrap();
    let back = mat_nn(&l, &l.transpose());
    let err = back.max_abs_diff(&spd).to_f64();
    assert!(err < 100.0 * tol, "potrf_into {}: {err:.3e}", S::DTYPE);

    // Full orth pipeline through the workspace: Q orthonormal (to √ε of
    // the working precision) and Y ≈ P·H + Q·R.
    let y0: Mat<S> = Mat::randn(rows, b, &mut rng);
    let mut qq = y0.clone();
    let mut hh: Mat<S> = Mat::zeros(s_hist, b);
    let mut rr: Mat<S> = Mat::zeros(b, b);
    be.orth_cgs_cqr2_into(qq.as_mut(), p.as_ref(), hh.as_mut(), rr.as_mut(), &ws).unwrap();
    let oe = orth_error(&qq).to_f64();
    assert!(oe < S::EPSILON.to_f64().sqrt(), "cgs_cqr2_into orth {}: {oe:.3e}", S::DTYPE);
    let mut back = mat_nn(&p, &hh);
    let qr = mat_nn(&qq, &rr);
    for (a_, c) in back.data_mut().iter_mut().zip(qr.data()) {
        *a_ += *c;
    }
    let rel = (back.max_abs_diff(&y0) / y0.fro_norm()).to_f64();
    assert!(rel < tol, "cgs_cqr2_into reconstruct {}: {rel:.3e}", S::DTYPE);
}

#[test]
fn into_kernels_hold_eps_scaled_parity_in_both_dtypes() {
    let _guard = POOL_LOCK.lock().unwrap_or_else(|p| p.into_inner());
    let _reset = PoolReset;
    pool::set_parallel_cutoff(1); // cover the banded paths on small fixtures
    for &t in &THREAD_SWEEP {
        pool::set_num_threads(t);
        into_path_parity_sweep::<f64>();
        into_path_parity_sweep::<f32>();
    }
}

#[test]
fn f32_into_paths_match_f64_reference() {
    // Cross-dtype: the f32 *_into outputs agree with the f64 outputs of
    // the same seeded inputs to f32 accuracy, matching the guarantee the
    // value-returning forms carried before the workspace refactor.
    use trunksvd::backend::Backend;
    use trunksvd::la::workspace::{Plan, Workspace};
    let _guard = POOL_LOCK.lock().unwrap_or_else(|p| p.into_inner());
    let _reset = PoolReset;
    pool::set_num_threads(2);
    let tol = kernel_tol::<f32>();
    let rows = 200usize;
    let b = 8usize;
    let mut rng64 = Rng::new(77);
    let mut rng32 = Rng::new(77);
    let y64: Mat<f64> = Mat::randn(rows, b, &mut rng64);
    let y32: Mat<f32> = Mat::randn(rows, b, &mut rng32);

    let ws64: Workspace<f64> = Workspace::new(Plan::orth(rows, 0, b));
    let ws32: Workspace<f32> = Workspace::new(Plan::orth(rows, 0, b));
    let mut be64: CpuBackend<f64> = CpuBackend::new_dense(Mat::zeros(1, 1));
    let mut be32: CpuBackend<f32> = CpuBackend::new_dense(Mat::<f32>::zeros(1, 1));

    let mut q64 = y64.clone();
    let mut r64: Mat<f64> = Mat::zeros(b, b);
    be64.orth_cholqr2_into(q64.as_mut(), r64.as_mut(), &ws64).unwrap();
    let mut q32 = y32.clone();
    let mut r32: Mat<f32> = Mat::zeros(b, b);
    be32.orth_cholqr2_into(q32.as_mut(), r32.as_mut(), &ws32).unwrap();

    let err_q = q64.cast::<f32>().max_abs_diff(&q32).to_f64();
    let err_r = r64.cast::<f32>().max_abs_diff(&r32).to_f64() / r64.fro_norm().max(1.0);
    assert!(err_q < 50.0 * tol, "cholqr2_into Q cross-dtype: {err_q:.3e}");
    assert!(err_r < 50.0 * tol, "cholqr2_into R cross-dtype: {err_r:.3e}");
}
