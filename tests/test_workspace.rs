//! The allocation-free-steady-state contract, pinned.
//!
//! This binary installs the counting global allocator
//! (`util::counting_alloc`) and asserts the workspace refactor's core
//! guarantee: once a solve's [`Workspace`] is planned and warmed, the
//! inner iterations of both algorithms perform **zero heap
//! allocations** on the CPU backend. Counters are per-thread and every
//! measured region runs with the pool pinned to one thread (all kernels
//! take their serial fast paths on the calling thread), so concurrent
//! tests in this binary cannot pollute a measurement window.
//!
//! Also covered here at the integration level: `Workspace`/`Plan`
//! shape-mismatch and aliasing panics, plan reuse across solves, and
//! the plan hook reaching the backend.

use std::sync::Mutex;

use trunksvd::algo::randsvd::randsvd_with;
use trunksvd::algo::{lancsvd::lancsvd, LancSvdOpts, RandSvdOpts};
use trunksvd::backend::cpu::CpuBackend;
use trunksvd::backend::Backend;
use trunksvd::gen::dense::paper_dense;
use trunksvd::gen::sparse::{generate, SparseSpec};
use trunksvd::la::mat::Mat;
use trunksvd::la::workspace::{names, Plan, PlanKind, Workspace};
use trunksvd::util::counting_alloc::{thread_alloc_bytes, thread_allocs, CountingAllocator};
use trunksvd::util::pool;
use trunksvd::util::rng::Rng;

#[global_allocator]
static ALLOC: CountingAllocator = CountingAllocator;

/// Serializes tests that pin the global pool thread count.
static POOL_LOCK: Mutex<()> = Mutex::new(());

struct PoolReset;
impl Drop for PoolReset {
    fn drop(&mut self) {
        pool::set_num_threads(0);
    }
}

/// One LancSVD inner block-step (S2–S5 of Alg. 2) against a warmed
/// workspace, exactly as `lancsvd_with` runs it mid-basis.
fn lanc_inner_step<S, B>(be: &mut B, ws: &Workspace<S>, s: usize, b: usize)
where
    S: trunksvd::Scalar,
    B: Backend<S> + ?Sized,
{
    let mut qbar = ws.buf(names::LANC_QBAR);
    let mut qnext = ws.buf(names::LANC_QNEXT);
    let mut p_basis = ws.buf(names::LANC_P);
    let mut pbar_basis = ws.buf(names::LANC_PBAR);
    let mut lt_buf = ws.buf(names::ORTH_R);
    let mut h_buf = ws.buf(names::ORTH_H);

    pbar_basis.set_panel(s, &qbar);
    {
        let (hist, mut rest) = p_basis.split_at_col(s);
        let mut qi = rest.panel_mut(0, b);
        be.apply_at_into(qbar.as_ref(), qi.reborrow());
        let lt = lt_buf.view_mut(b, b);
        if s == 0 {
            be.orth_cholqr2_into(qi, lt, ws).unwrap();
        } else {
            let h = h_buf.view_mut(s, b);
            be.orth_cgs_cqr2_into(qi, hist, h, lt, ws).unwrap();
        }
    }
    be.apply_a_into(p_basis.panel(s, b), qnext.as_mut());
    {
        let hist = pbar_basis.panel(0, s + b);
        let h = h_buf.view_mut(s + b, b);
        let ri = lt_buf.view_mut(b, b);
        be.orth_cgs_cqr2_into(qnext.as_mut(), hist, h, ri, ws).unwrap();
    }
    std::mem::swap(&mut *qbar, &mut *qnext);
}

/// Measure allocations across `iters` inner block-steps after `warm`
/// warm-up steps; returns (allocs, bytes) of the measured window.
fn measure_lanc_steps<S, B>(
    be: &mut B,
    ws: &Workspace<S>,
    s: usize,
    b: usize,
    warm: usize,
    iters: usize,
) -> (u64, u64)
where
    S: trunksvd::Scalar,
    B: Backend<S> + ?Sized,
{
    for _ in 0..warm {
        lanc_inner_step(be, ws, s, b);
    }
    let (c0, b0) = (thread_allocs(), thread_alloc_bytes());
    for _ in 0..iters {
        lanc_inner_step(be, ws, s, b);
    }
    (thread_allocs() - c0, thread_alloc_bytes() - b0)
}

fn lanc_fixture_dense(m: usize, n: usize, b: usize, r: usize) -> (CpuBackend, Workspace) {
    let prob = paper_dense(m, n, 33);
    let mut be = CpuBackend::new_dense(prob.a);
    let ws: Workspace = Workspace::new(Plan::lancsvd(m, n, r, 2, b));
    be.plan(ws.plan());
    // Seed Q̄ with a random orthonormal block so the steps are well posed.
    let mut rng = Rng::new(7);
    {
        let mut qbar = ws.buf(names::LANC_QBAR);
        rng.fill_normal(qbar.data_mut());
        let mut lt_buf = ws.buf(names::ORTH_R);
        let lt = lt_buf.view_mut(b, b);
        be.orth_cholqr2_into(qbar.as_mut(), lt, &ws).unwrap();
    }
    (be, ws)
}

#[test]
fn lancsvd_inner_iteration_is_allocation_free_dense() {
    let _guard = POOL_LOCK.lock().unwrap_or_else(|p| p.into_inner());
    let _reset = PoolReset;
    pool::set_num_threads(1); // serial fast paths: all work on this thread
    let (m, n, b, r) = (200usize, 80usize, 8usize, 16usize);
    let (mut be, ws) = lanc_fixture_dense(m, n, b, r);
    let (allocs, bytes) = measure_lanc_steps(&mut be, &ws, 8, b, 3, 40);
    assert_eq!(
        (allocs, bytes),
        (0, 0),
        "dense LancSVD inner step must not allocate in steady state"
    );
}

#[test]
fn lancsvd_inner_iteration_is_allocation_free_sparse() {
    let _guard = POOL_LOCK.lock().unwrap_or_else(|p| p.into_inner());
    let _reset = PoolReset;
    pool::set_num_threads(1);
    let spec = SparseSpec { rows: 300, cols: 120, nnz: 5000, seed: 4, ..Default::default() };
    let a = generate(&spec);
    let (b, r) = (8usize, 16usize);
    // Scatter arm: the Aᵀ·X kernel stays on spmm_t.
    {
        let mut be = CpuBackend::new_sparse(a.clone()).scatter_only();
        let ws: Workspace = Workspace::new(Plan::lancsvd(300, 120, r, 2, b));
        be.plan(ws.plan());
        seed_qbar(&mut be, &ws, b);
        let (allocs, bytes) = measure_lanc_steps(&mut be, &ws, 8, b, 3, 40);
        assert_eq!((allocs, bytes), (0, 0), "sparse scatter inner step allocated");
    }
    // Cached-gather arm: the eager explicit transpose (built at setup).
    {
        let mut be = CpuBackend::new_sparse(a).with_explicit_transpose();
        let ws: Workspace = Workspace::new(Plan::lancsvd(300, 120, r, 2, b));
        be.plan(ws.plan());
        seed_qbar(&mut be, &ws, b);
        let (allocs, bytes) = measure_lanc_steps(&mut be, &ws, 8, b, 3, 40);
        assert_eq!((allocs, bytes), (0, 0), "sparse gather inner step allocated");
    }
}

/// The fused form of the inner block step (S4 `apply_a_gram_into` + S5
/// Gram-downdated CGS+CholeskyQR2), exactly as `lancsvd_with` runs it
/// with the fused tier enabled.
fn lanc_inner_step_fused<S, B>(be: &mut B, ws: &Workspace<S>, s: usize, b: usize)
where
    S: trunksvd::Scalar,
    B: Backend<S> + ?Sized,
{
    let mut qbar = ws.buf(names::LANC_QBAR);
    let mut qnext = ws.buf(names::LANC_QNEXT);
    let mut p_basis = ws.buf(names::LANC_P);
    let mut pbar_basis = ws.buf(names::LANC_PBAR);
    let mut lt_buf = ws.buf(names::ORTH_R);
    let mut h_buf = ws.buf(names::ORTH_H);
    let mut g_buf = ws.buf(names::LANC_G);

    pbar_basis.set_panel(s, &qbar);
    {
        let (hist, mut rest) = p_basis.split_at_col(s);
        let mut qi = rest.panel_mut(0, b);
        be.apply_at_into(qbar.as_ref(), qi.reborrow());
        let lt = lt_buf.view_mut(b, b);
        if s == 0 {
            be.orth_cholqr2_into(qi, lt, ws).unwrap();
        } else {
            let h = h_buf.view_mut(s, b);
            be.orth_cgs_cqr2_into(qi, hist, h, lt, ws).unwrap();
        }
    }
    let mut gram = g_buf.view_mut(b, b);
    be.apply_a_gram_into(p_basis.panel(s, b), qnext.as_mut(), gram.reborrow());
    {
        let hist = pbar_basis.panel(0, s + b);
        let h = h_buf.view_mut(s + b, b);
        let ri = lt_buf.view_mut(b, b);
        be.orth_cgs_cqr2_pregram_into(qnext.as_mut(), hist, gram.as_ref(), h, ri, ws).unwrap();
    }
    std::mem::swap(&mut *qbar, &mut *qnext);
}

#[test]
fn fused_lancsvd_inner_iteration_is_allocation_free_sparse() {
    // The fused tier's serial fast paths (one-sweep A·Q + Gram, and the
    // Gram-downdated first CholeskyQR pass) must honor the same
    // steady-state contract as the classic composition.
    let _guard = POOL_LOCK.lock().unwrap_or_else(|p| p.into_inner());
    let _reset = PoolReset;
    pool::set_num_threads(1);
    let spec = SparseSpec { rows: 300, cols: 120, nnz: 5000, seed: 4, ..Default::default() };
    let a = generate(&spec);
    let (b, r) = (8usize, 16usize);
    let mut be = CpuBackend::new_sparse(a).scatter_only();
    let ws: Workspace = Workspace::new(Plan::lancsvd(300, 120, r, 2, b));
    be.plan(ws.plan());
    seed_qbar(&mut be, &ws, b);
    for _ in 0..3 {
        lanc_inner_step_fused(&mut be, &ws, 8, b);
    }
    let (c0, b0) = (thread_allocs(), thread_alloc_bytes());
    for _ in 0..40 {
        lanc_inner_step_fused(&mut be, &ws, 8, b);
    }
    let (allocs, bytes) = (thread_allocs() - c0, thread_alloc_bytes() - b0);
    assert_eq!((allocs, bytes), (0, 0), "fused inner step allocated");
}

#[test]
fn fused_randsvd_allocation_count_is_independent_of_p() {
    // Fused power iterations run Aᵀ(A·Q) through the planned `rand.z`
    // sketch; steady state must stay allocation-free end to end.
    let _guard = POOL_LOCK.lock().unwrap_or_else(|p| p.into_inner());
    let _reset = PoolReset;
    pool::set_num_threads(1);
    let spec = SparseSpec { rows: 300, cols: 120, nnz: 5000, seed: 6, ..Default::default() };
    let a = generate(&spec);
    let ws: Workspace = Workspace::new(Plan::randsvd(300, 120, 12, 16, 4));
    let solve_allocs = |p: usize| -> (u64, u64) {
        let opts =
            RandSvdOpts { r: 12, p, b: 4, seed: 3, fuse: Some(true), ..Default::default() };
        let mut be = CpuBackend::new_sparse(a.clone()).scatter_only();
        let (c0, b0) = (thread_allocs(), thread_alloc_bytes());
        let svd = randsvd_with(&mut be, &opts, &ws).unwrap();
        assert_eq!(svd.iters, p);
        (thread_allocs() - c0, thread_alloc_bytes() - b0)
    };
    let _ = solve_allocs(2); // warm lazy statics off-window
    let (c3, by3) = solve_allocs(3);
    let (c13, by13) = solve_allocs(13);
    assert_eq!(c3, c13, "fused allocation count must not scale with p ({c3} vs {c13})");
    assert_eq!(by3, by13, "fused allocated bytes must not scale with p ({by3} vs {by13})");
}

fn seed_qbar<S: trunksvd::Scalar>(be: &mut CpuBackend<S>, ws: &Workspace<S>, b: usize) {
    let mut rng = Rng::new(9);
    let mut qbar = ws.buf(names::LANC_QBAR);
    rng.fill_normal(qbar.data_mut());
    let mut lt_buf = ws.buf(names::ORTH_R);
    let lt = lt_buf.view_mut(b, b);
    be.orth_cholqr2_into(qbar.as_mut(), lt, ws).unwrap();
}

#[test]
fn randsvd_allocation_count_is_independent_of_p() {
    // End-to-end form of the steady-state contract: a solve with 13
    // power iterations must allocate exactly as much as a solve with 3 —
    // i.e. the per-iteration allocation count is zero.
    let _guard = POOL_LOCK.lock().unwrap_or_else(|p| p.into_inner());
    let _reset = PoolReset;
    pool::set_num_threads(1);
    let prob = paper_dense(120, 50, 21);
    let ws: Workspace = Workspace::new(Plan::randsvd(120, 50, 12, 16, 4));
    let solve_allocs = |p: usize, a: &Mat| -> (u64, u64) {
        let opts = RandSvdOpts { r: 12, p, b: 4, seed: 3, ..Default::default() };
        let mut be = CpuBackend::new_dense(a.clone());
        let (c0, b0) = (thread_allocs(), thread_alloc_bytes());
        let svd = randsvd_with(&mut be, &opts, &ws).unwrap();
        let out = (thread_allocs() - c0, thread_alloc_bytes() - b0);
        assert_eq!(svd.iters, p);
        out
    };
    // Warm lazy statics (env lookups, cost-model OnceLocks) off-window.
    let _ = solve_allocs(2, &prob.a);
    let (c3, by3) = solve_allocs(3, &prob.a);
    let (c13, by13) = solve_allocs(13, &prob.a);
    assert_eq!(c3, c13, "allocation count must not scale with p ({c3} vs {c13})");
    assert_eq!(by3, by13, "allocated bytes must not scale with p ({by3} vs {by13})");
}

#[test]
fn wide_sketch_iterations_are_allocation_free() {
    // r > 64: the SpMM outputs are r columns wide (not b), exercising
    // the breadth of the pool serial path's stack column table — a
    // regression here would silently re-allocate per iteration for the
    // wider half of the documented parameter range.
    let _guard = POOL_LOCK.lock().unwrap_or_else(|p| p.into_inner());
    let _reset = PoolReset;
    pool::set_num_threads(1);
    let spec = SparseSpec { rows: 300, cols: 150, nnz: 6000, seed: 8, ..Default::default() };
    let a = generate(&spec);
    let ws: Workspace = Workspace::new(Plan::randsvd(300, 150, 96, 8, 16));
    let solve_allocs = |p: usize| -> (u64, u64) {
        let opts = RandSvdOpts { r: 96, p, b: 16, seed: 4, ..Default::default() };
        let mut be = CpuBackend::new_sparse(a.clone()).scatter_only();
        let (c0, b0) = (thread_allocs(), thread_alloc_bytes());
        let svd = randsvd_with(&mut be, &opts, &ws).unwrap();
        assert_eq!(svd.iters, p);
        (thread_allocs() - c0, thread_alloc_bytes() - b0)
    };
    let _ = solve_allocs(2); // warm lazy statics off-window
    let (c2, by2) = solve_allocs(2);
    let (c6, by6) = solve_allocs(6);
    assert_eq!(c2, c6, "wide-sketch allocation count must not scale with p");
    assert_eq!(by2, by6, "wide-sketch allocated bytes must not scale with p");
}

#[test]
fn plan_reuse_matches_fresh_workspace_end_to_end() {
    let _guard = POOL_LOCK.lock().unwrap_or_else(|p| p.into_inner());
    let _reset = PoolReset;
    pool::set_num_threads(1);
    let prob = paper_dense(100, 40, 12);
    let opts = LancSvdOpts { r: 16, p: 3, b: 8, wanted: 5, ..Default::default() };
    let mut be = CpuBackend::new_dense(prob.a.clone());
    let fresh = lancsvd(&mut be, &opts).unwrap();
    let ws: Workspace = Workspace::new(Plan::lancsvd(100, 40, 16, 3, 8));
    for round in 0..3 {
        let mut be = CpuBackend::new_dense(prob.a.clone());
        let again = trunksvd::algo::lancsvd::lancsvd_with(&mut be, &opts, &ws).unwrap();
        assert_eq!(fresh.sigma, again.sigma, "round {round} sigma drifted");
        assert_eq!(fresh.u.data(), again.u.data(), "round {round} U drifted");
        assert_eq!(fresh.v.data(), again.v.data(), "round {round} V drifted");
    }
}

#[test]
fn plan_hook_reaches_backend_through_solves() {
    let prob = paper_dense(60, 24, 5);
    let mut be = CpuBackend::new_dense(prob.a);
    assert!(be.planned().is_none());
    let opts = LancSvdOpts { r: 8, p: 1, b: 4, wanted: 3, ..Default::default() };
    let _ = lancsvd(&mut be, &opts).unwrap();
    let plan = be.planned().expect("lancsvd must hand its plan to the backend");
    assert_eq!(plan.kind, PlanKind::LancSvd);
    assert_eq!((plan.m, plan.n, plan.r, plan.b), (60, 24, 8, 4));
}

#[test]
#[should_panic(expected = "aliasing rejected")]
fn integration_double_borrow_panics() {
    let ws: Workspace = Workspace::new(Plan::orth(64, 16, 8));
    let _one = ws.buf(names::ORTH_SNAP);
    let _two = ws.buf(names::ORTH_SNAP);
}

#[test]
#[should_panic(expected = "caller expects")]
fn integration_shape_mismatch_panics() {
    let ws: Workspace = Workspace::new(Plan::lancsvd(50, 20, 8, 2, 4));
    let _p = ws.mat(names::LANC_P, 50, 8); // planned as 20x8
}

#[test]
fn workspace_rejects_wrong_plan_kind() {
    let prob = paper_dense(60, 24, 5);
    let mut be = CpuBackend::new_dense(prob.a);
    let opts = LancSvdOpts { r: 8, p: 1, b: 4, wanted: 3, ..Default::default() };
    let wrong: Workspace = Workspace::new(Plan::randsvd(60, 24, 8, 1, 4));
    assert!(trunksvd::algo::lancsvd::lancsvd_with(&mut be, &opts, &wrong).is_err());
}
