//! Parity of the threaded sparse/Gram kernels against the dense
//! reference across worker-thread counts (the `TRUNKSVD_THREADS`
//! dimension, swept in-process via `pool::set_num_threads`), ragged
//! shapes, k = 1, and empty-row matrices — plus a *determinism sweep*:
//! at a fixed thread count, every threaded kernel must produce
//! bitwise-identical output across repeated calls, in both element
//! precisions. The persistent pool's band affinity is a static
//! partition, so rerunning a kernel (even after resizing the pool away
//! and back) may not perturb a single bit; only *changing* the thread
//! count is allowed to change floating-point summation order (and only
//! for reduction-shaped kernels).
//!
//! The thread/cutoff overrides are process-global, so every test that
//! touches them serializes on `POOL_LOCK` and restores the defaults
//! before returning.

use std::sync::Mutex;

use trunksvd::la::blas3::{self, mat_nn, mat_tn};
use trunksvd::la::mat::Mat;
use trunksvd::sparse::blockell::BlockEll;
use trunksvd::sparse::coo::Coo;
use trunksvd::sparse::csr::Csr;
use trunksvd::util::pool;
use trunksvd::util::rng::Rng;
use trunksvd::util::scalar::Scalar;

static POOL_LOCK: Mutex<()> = Mutex::new(());

const THREAD_SWEEP: [usize; 3] = [1, 2, 8];
const TOL: f64 = 1e-10;

fn random_coo(rows: usize, cols: usize, nnz: usize, seed: u64) -> Coo {
    let mut rng = Rng::new(seed);
    let mut c = Coo::new(rows, cols);
    for _ in 0..nnz {
        c.push(rng.below(rows), rng.below(cols), rng.normal());
    }
    c
}

/// Restores the pool defaults even if the guarded closure panics.
struct PoolReset;
impl Drop for PoolReset {
    fn drop(&mut self) {
        pool::set_num_threads(0);
        pool::set_parallel_cutoff(0);
    }
}

#[test]
fn csr_spmm_and_spmm_t_parity_across_threads() {
    let _guard = POOL_LOCK.lock().unwrap_or_else(|p| p.into_inner());
    let _reset = PoolReset;
    // Force the parallel path: at the default cost-model cutoff most of
    // these small fixtures would take the serial fast path and the
    // sweep would stop covering the banded kernels.
    pool::set_parallel_cutoff(1);
    // Ragged shapes (not multiples of any block/tile size), including a
    // 1-row and a 1-col matrix and one with many empty rows.
    let shapes: &[(usize, usize, usize)] = &[
        (1, 7, 4),
        (37, 23, 150),
        (64, 64, 500),
        (129, 65, 1000),
        (1000, 333, 12_000), // takes the parallel transpose fill path
        (50, 1, 20),
    ];
    for &t in &THREAD_SWEEP {
        pool::set_num_threads(t);
        for (si, &(m, n, nnz)) in shapes.iter().enumerate() {
            let a = Csr::from_coo(&random_coo(m, n, nnz, 40 + si as u64)).unwrap();
            let ad = a.to_dense();
            let mut rng = Rng::new(90 + si as u64);
            for k in [1usize, 2, 3, 5, 8, 16] {
                let x = Mat::randn(n, k, &mut rng);
                let mut y = Mat::zeros(m, k);
                a.spmm(x.as_ref(), y.as_mut());
                assert!(
                    y.max_abs_diff(&mat_nn(&ad, &x)) < TOL,
                    "spmm t={t} shape {m}x{n} k={k}"
                );
                let z = Mat::randn(m, k, &mut rng);
                let mut w = Mat::zeros(n, k);
                a.spmm_t(z.as_ref(), w.as_mut());
                assert!(
                    w.max_abs_diff(&mat_tn(&ad, &z)) < TOL,
                    "spmm_t t={t} shape {m}x{n} k={k}"
                );
            }
        }
    }
}

#[test]
fn csr_transpose_and_from_coo_parity_across_threads() {
    let _guard = POOL_LOCK.lock().unwrap_or_else(|p| p.into_inner());
    let _reset = PoolReset;
    pool::set_parallel_cutoff(1); // cover the banded paths on small fixtures
    for &t in &THREAD_SWEEP {
        pool::set_num_threads(t);
        // from_coo: duplicates merge, columns sort, ragged shape.
        let mut c = Coo::new(3, 5);
        c.push(2, 4, 1.0);
        c.push(2, 0, 2.0);
        c.push(2, 4, 3.0);
        c.push(0, 1, 5.0);
        let a = Csr::from_coo(&c).unwrap();
        assert_eq!(a.nnz(), 3, "t={t}");
        assert_eq!(a.row(2), (&[0u32, 4][..], &[2.0, 4.0][..]), "t={t}");
        // Large matrix: from_coo and both transpose fill paths agree
        // with the dense reference.
        let coo = random_coo(700, 450, 20_000, 3);
        let a = Csr::from_coo(&coo).unwrap();
        let ad = a.to_dense();
        let at = a.transpose();
        assert!(at.to_dense().max_abs_diff(&ad.transpose()) < 1e-15, "t={t}");
        // Per-row column indices stay sorted through the parallel paths.
        for i in 0..a.rows() {
            let (rc, _) = a.row(i);
            assert!(rc.windows(2).all(|w| w[0] < w[1]), "t={t} row {i}");
        }
        for i in 0..at.rows() {
            let (rc, _) = at.row(i);
            assert!(rc.windows(2).all(|w| w[0] < w[1]), "t={t} at row {i}");
        }
    }
}

#[test]
fn gram_parity_across_threads() {
    let _guard = POOL_LOCK.lock().unwrap_or_else(|p| p.into_inner());
    let _reset = PoolReset;
    pool::set_parallel_cutoff(1); // cover the banded paths on small fixtures
    for &t in &THREAD_SWEEP {
        pool::set_num_threads(t);
        let mut rng = Rng::new(5);
        // Rows straddle the SYRK tile (256) and the thread partition;
        // b=1 and odd b exercise the remainder column loops.
        for &(rows, b) in &[(1usize, 1usize), (33, 5), (255, 8), (257, 16), (1023, 16), (2048, 3)]
        {
            let q = Mat::randn(rows, b, &mut rng);
            let w = blas3::gram(q.as_ref());
            assert!(
                w.max_abs_diff(&mat_tn(&q, &q)) < TOL,
                "gram t={t} shape {rows}x{b}"
            );
            for i in 0..b {
                for j in 0..b {
                    assert_eq!(w.at(i, j), w.at(j, i), "gram symmetry t={t}");
                }
            }
        }
    }
}

#[test]
fn blockell_spmm_parity_across_threads() {
    let _guard = POOL_LOCK.lock().unwrap_or_else(|p| p.into_inner());
    let _reset = PoolReset;
    pool::set_parallel_cutoff(1); // cover the banded paths on small fixtures
    let a = Csr::from_coo(&random_coo(170, 90, 2000, 8)).unwrap();
    let ad = a.to_dense();
    for &t in &THREAD_SWEEP {
        pool::set_num_threads(t);
        for &bs in &[8usize, 16] {
            let be = BlockEll::from_csr(&a, bs, 64).unwrap();
            let mut rng = Rng::new(21);
            for k in [1usize, 4, 6] {
                let mut x = Mat::zeros(be.padded_cols(), k);
                for j in 0..k {
                    for i in 0..a.cols() {
                        x.set(i, j, rng.normal());
                    }
                }
                let mut y = Mat::zeros(be.padded_rows(), k);
                be.spmm(x.as_ref(), y.as_mut());
                // Unpadded corner matches dense A · X.
                for j in 0..k {
                    for i in 0..a.rows() {
                        let e: f64 = (0..a.cols()).map(|c| ad.at(i, c) * x.at(c, j)).sum();
                        assert!((y.at(i, j) - e).abs() < TOL, "t={t} bs={bs} ({i},{j})");
                    }
                }
                for i in a.rows()..be.padded_rows() {
                    assert_eq!(y.at(i, 0), 0.0, "t={t} bs={bs} padding row {i}");
                }
            }
        }
    }
}

/// Exact bit pattern of a scalar slice (f32 → f64 widening is exact, so
/// the f64 bits are a faithful fingerprint for both dtypes).
fn bits<S: Scalar>(v: &[S]) -> Vec<u64> {
    v.iter().map(|x| x.to_f64().to_bits()).collect()
}

/// One pass over every threaded kernel, fingerprinted bit-exactly:
/// gather SpMM, scatter SpMMᵀ, explicit transpose (values + structure),
/// Gram/SYRK, and the Block-ELL SpMM.
fn threaded_kernel_fingerprint<S: Scalar>(
    a: &Csr<S>,
    be: &BlockEll<S>,
    x: &Mat<S>,
    z: &Mat<S>,
    q: &Mat<S>,
    xp: &Mat<S>,
) -> Vec<u64> {
    let mut out = Vec::new();
    let mut y = Mat::zeros(a.rows(), x.cols());
    a.spmm(x.as_ref(), y.as_mut());
    out.extend(bits(y.data()));
    let mut w = Mat::zeros(a.cols(), z.cols());
    a.spmm_t(z.as_ref(), w.as_mut());
    out.extend(bits(w.data()));
    let at = a.transpose();
    out.extend(at.indptr().iter().map(|&p| p as u64));
    out.extend(at.indices().iter().map(|&c| c as u64));
    out.extend(bits(at.values()));
    let g = blas3::gram(q.as_ref());
    out.extend(bits(g.data()));
    let mut yp = Mat::zeros(be.padded_rows(), xp.cols());
    be.spmm(xp.as_ref(), yp.as_mut());
    out.extend(bits(yp.data()));
    out
}

/// Determinism sweep at one element precision: at every fixed thread
/// count, repeated kernel calls are bitwise-identical — including after
/// resizing the pool away and back (band affinity must not introduce
/// run-to-run nondeterminism). The cutoff override forces the parallel
/// path on the test-sized fixtures.
fn determinism_sweep<S: Scalar>() {
    let _guard = POOL_LOCK.lock().unwrap_or_else(|p| p.into_inner());
    let _reset = PoolReset;
    pool::set_parallel_cutoff(1);
    // nnz >= 4096 so the transpose takes its banded parallel fill path.
    let a: Csr<S> = Csr::from_coo(&random_coo(311, 257, 9000, 71)).unwrap().cast();
    let be = BlockEll::from_csr(&a, 8, a.cols().div_ceil(8)).unwrap();
    let mut rng = Rng::new(72);
    let x: Mat<S> = Mat::randn(a.cols(), 5, &mut rng);
    let z: Mat<S> = Mat::randn(a.rows(), 5, &mut rng);
    let q: Mat<S> = Mat::randn(500, 9, &mut rng);
    let xp: Mat<S> = Mat::randn(be.padded_cols(), 5, &mut rng);

    let sweep: [usize; 4] = [1, 2, 3, 8];
    let mut per_t = Vec::with_capacity(sweep.len());
    for &t in &sweep {
        pool::set_num_threads(t);
        let first = threaded_kernel_fingerprint(&a, &be, &x, &z, &q, &xp);
        for call in 0..2 {
            let again = threaded_kernel_fingerprint(&a, &be, &x, &z, &q, &xp);
            assert!(again == first, "dtype={} t={t} repeat {call} not bitwise equal", S::DTYPE);
        }
        per_t.push(first);
    }
    // Resize away and back: the t-specific bit patterns must reproduce.
    for (i, &t) in sweep.iter().enumerate() {
        pool::set_num_threads(t);
        let again = threaded_kernel_fingerprint(&a, &be, &x, &z, &q, &xp);
        assert!(
            again == per_t[i],
            "dtype={} t={t} after resize round-trip not bitwise equal",
            S::DTYPE
        );
    }
}

#[test]
fn determinism_sweep_f64() {
    determinism_sweep::<f64>();
}

#[test]
fn determinism_sweep_f32() {
    determinism_sweep::<f32>();
}

#[test]
fn empty_and_degenerate_shapes() {
    let _guard = POOL_LOCK.lock().unwrap_or_else(|p| p.into_inner());
    let _reset = PoolReset;
    for &t in &THREAD_SWEEP {
        pool::set_num_threads(t);
        // All-empty-rows matrix.
        let a = Csr::from_parts(6, 4, vec![0; 7], vec![], vec![]).unwrap();
        let x = Mat::from_fn(4, 3, |i, j| (i + j) as f64);
        let mut y = Mat::from_fn(6, 3, |_, _| 7.0);
        a.spmm(x.as_ref(), y.as_mut());
        assert_eq!(y.fro_norm(), 0.0, "t={t} spmm over empty matrix");
        let z = Mat::from_fn(6, 3, |i, j| (i * j) as f64);
        let mut w = Mat::from_fn(4, 3, |_, _| 7.0);
        a.spmm_t(z.as_ref(), w.as_mut());
        assert_eq!(w.fro_norm(), 0.0, "t={t} spmm_t over empty matrix");
        // Single column output (k = 1) on a matrix with empty rows.
        let mut c = Coo::new(5, 5);
        c.push(0, 4, 3.0);
        c.push(4, 0, 2.0);
        let a = Csr::from_coo(&c).unwrap();
        let x = Mat::from_fn(5, 1, |i, _| i as f64 + 1.0);
        let mut y = Mat::zeros(5, 1);
        a.spmm(x.as_ref(), y.as_mut());
        assert_eq!(y.at(0, 0), 15.0, "t={t}");
        assert_eq!(y.at(4, 0), 2.0, "t={t}");
        assert_eq!(y.at(2, 0), 0.0, "t={t}");
        // gram of an empty panel.
        let w = blas3::gram(Mat::<f64>::zeros(10, 0).as_ref());
        assert_eq!((w.rows(), w.cols()), (0, 0), "t={t}");
    }
}
