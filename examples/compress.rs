//! Low-rank compression of a smooth 2-D field.
//!
//! Generates a synthetic "image" (a superposition of anisotropic
//! Gaussians — smooth fields have rapidly decaying singular values),
//! compresses it to rank k with the truncated SVD, and reports the
//! storage ratio and reconstruction PSNR as k grows.

use trunksvd::algo::{lancsvd::lancsvd, LancSvdOpts};
use trunksvd::backend::cpu::CpuBackend;
use trunksvd::la::blas3::mat_nn;
use trunksvd::la::mat::Mat;
use trunksvd::util::rng::Rng;

fn synth_field(rows: usize, cols: usize, blobs: usize, rng: &mut Rng) -> Mat {
    let mut centers = Vec::new();
    for _ in 0..blobs {
        centers.push((
            rng.uniform_in(0.0, rows as f64),
            rng.uniform_in(0.0, cols as f64),
            rng.uniform_in(8.0, 40.0),  // sigma_r
            rng.uniform_in(8.0, 40.0),  // sigma_c
            rng.uniform_in(0.2, 1.0),   // amplitude
        ));
    }
    Mat::from_fn(rows, cols, |i, j| {
        centers
            .iter()
            .map(|&(ci, cj, sr, sc, amp)| {
                let di = (i as f64 - ci) / sr;
                let dj = (j as f64 - cj) / sc;
                amp * (-0.5 * (di * di + dj * dj)).exp()
            })
            .sum()
    })
}

fn psnr(orig: &Mat, approx: &Mat) -> f64 {
    let n = (orig.rows() * orig.cols()) as f64;
    let mse = orig
        .data()
        .iter()
        .zip(approx.data())
        .map(|(a, b)| (a - b) * (a - b))
        .sum::<f64>()
        / n;
    let peak = orig.data().iter().fold(0.0f64, |m, &v| m.max(v.abs()));
    10.0 * (peak * peak / mse.max(1e-300)).log10()
}

fn main() -> trunksvd::Result<()> {
    let (rows, cols) = (1200, 800);
    let mut rng = Rng::new(11);
    println!("synthesizing {rows}x{cols} smooth field (40 gaussian blobs)...");
    let img = synth_field(rows, cols, 40, &mut rng);

    println!("\n{:>5} {:>12} {:>10} {:>10}", "rank", "storage", "ratio", "PSNR dB");
    for k in [4usize, 8, 16, 32] {
        let mut be = CpuBackend::new_dense(img.clone());
        let svd = lancsvd(
            &mut be,
            &LancSvdOpts {
                r: (2 * k).max(32),
                p: 3,
                b: 16,
                wanted: k,
                tol: Some(1e-10),
                ..Default::default()
            },
        )?;
        // Reconstruct rank-k approximation U_k S_k V_kᵀ.
        let (u, s, v) = svd.truncated(k);
        let mut us = u.clone();
        for j in 0..k {
            for x in us.col_mut(j) {
                *x *= s[j];
            }
        }
        let approx = mat_nn(&us, &v.transpose());
        let full = rows * cols;
        let stored = k * (rows + cols + 1);
        println!(
            "{:>5} {:>12} {:>9.1}x {:>10.1}",
            k,
            stored,
            full as f64 / stored as f64,
            psnr(&img, &approx)
        );
    }
    println!("\nsmooth fields compress well: PSNR grows rapidly with rank while");
    println!("storage stays k(m+n+1) words vs mn for the dense field.");
    Ok(())
}
