//! End-to-end driver: the full paper pipeline on a real small workload.
//!
//! Exercises all layers together — suite generation (L3), the AOT
//! JAX/Pallas artifacts through PJRT when present (L1/L2 via the XLA
//! backend), the algorithms, the residual metric, and the reporting
//! stack — and reports the paper's headline metric: LancSVD speed-up
//! over RandSVD at matched target accuracy.
//!
//! Results of a full run are recorded in EXPERIMENTS.md.
//!
//! ```text
//! cargo run --release --example e2e_paper            # subset (default 6)
//! cargo run --release --example e2e_paper -- 12 xla  # 12 matrices, XLA
//! ```

use std::rc::Rc;

use trunksvd::backend::Operand;
use trunksvd::cost::device::DeviceModel;
use trunksvd::coordinator::driver::{run, Algo, BackendChoice, Params};
use trunksvd::coordinator::report::{sci, Table};
use trunksvd::gen::dense::paper_dense;
use trunksvd::gen::sparse::generate;
use trunksvd::gen::suite::Suite;
use trunksvd::runtime::{default_artifact_dir, Runtime};

fn main() -> trunksvd::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let subset: usize = args.first().and_then(|s| s.parse().ok()).unwrap_or(6);
    let use_xla = args.get(1).map(|s| s == "xla").unwrap_or(false);

    let backend = if use_xla {
        let rt = Runtime::new(&default_artifact_dir())?;
        println!("backend: xla ({} AOT artifacts)", rt.artifact_count());
        BackendChoice::Xla(Rc::new(rt))
    } else {
        println!("backend: cpu (pass 'xla' as 2nd arg for the PJRT path)");
        BackendChoice::Cpu
    };

    let suite = Suite::load_default()?;
    let entries = suite.representative(subset);
    println!("running {} sparse matrices + 1 dense problem\n", entries.len());

    let lanc_params = Params { r: 256, p: 2, b: 16, ..Default::default() };
    let rand_params = Params { r: 16, p: 96, b: 16, ..Default::default() };

    let mut t = Table::new(&[
        "matrix", "m", "n", "lanc s", "lanc R10", "rand s", "rand R10", "speedup", "simA100",
    ]);
    let dm = DeviceModel::a100();
    let mut speedups = Vec::new();
    for e in &entries {
        let a = generate(&e.spec);
        let op = Operand::sparse(a);
        let lanc = run(&e.name, op.clone(), Algo::Lanc, &lanc_params, &backend)?;
        let rand = run(&e.name, op, Algo::Rand, &rand_params, &backend)?;
        let speedup = rand.secs / lanc.secs;
        let sim = dm.sim_time(&rand.profile, true) / dm.sim_time(&lanc.profile, true);
        speedups.push(sim);
        t.row(vec![
            e.name.clone(),
            e.spec.rows.to_string(),
            e.spec.cols.to_string(),
            format!("{:.2}", lanc.secs),
            sci(lanc.max_residual()),
            format!("{:.2}", rand.secs),
            sci(rand.max_residual()),
            format!("{speedup:.2}x"),
            format!("{sim:.2}x"),
        ]);
        println!("{}", lanc.summary());
        println!("{}", rand.summary());
    }

    // One dense problem (paper §4.2 configuration, scaled).
    let dense = paper_dense(12_500, 500, 3);
    let lanc = run(
        "dense_m12500",
        Operand::Dense(dense.a.clone()),
        Algo::Lanc,
        &Params { r: 64, p: 4, b: 16, ..Default::default() },
        &backend,
    )?;
    let rand = run(
        "dense_m12500",
        Operand::Dense(dense.a),
        Algo::Rand,
        &Params { r: 16, p: 24, b: 16, ..Default::default() },
        &backend,
    )?;
    let dense_speedup = rand.secs / lanc.secs;
    let dense_sim = dm.sim_time(&rand.profile, false) / dm.sim_time(&lanc.profile, false);
    t.row(vec![
        "dense_m12500".into(),
        "12500".into(),
        "500".into(),
        format!("{:.2}", lanc.secs),
        sci(lanc.max_residual()),
        format!("{:.2}", rand.secs),
        sci(rand.max_residual()),
        format!("{dense_speedup:.2}x"),
        format!("{dense_sim:.2}x"),
    ]);

    println!("\n{}", t.to_markdown());
    speedups.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let median = speedups[speedups.len() / 2];
    let wins = speedups.iter().filter(|&&s| s > 1.0).count();
    println!(
        "headline (sim-A100 model time): LancSVD faster on {}/{} sparse matrices, \
         median speed-up {:.2}x; dense measured speed-up {:.2}x (sim {:.2}x)",
        wins,
        speedups.len(),
        median,
        dense_speedup,
        dense_sim
    );
    println!("paper: speed-ups 1.2x-2.5x (sparse, most matrices), ~6x fewer iterations (dense)");
    Ok(())
}
