//! Quickstart: compute the 10 largest singular triplets of a dense
//! synthetic matrix with both algorithms and compare against the known
//! spectrum.
//!
//! ```text
//! cargo run --release --example quickstart
//! ```

use trunksvd::algo::{lancsvd::lancsvd, randsvd::randsvd, residuals, LancSvdOpts, RandSvdOpts};
use trunksvd::backend::cpu::CpuBackend;
use trunksvd::gen::dense::paper_dense;

fn main() -> trunksvd::Result<()> {
    // A 4000x500 dense matrix with the paper's Eq. 16 spectrum.
    let (m, n) = (4000, 500);
    println!("building dense test problem {m}x{n} (Eq. 15/16 spectrum)...");
    let prob = paper_dense(m, n, 42);

    // --- Block Lanczos (Alg. 2): r=64, b=16, up to 4 restarts ---
    let mut be = CpuBackend::new_dense(prob.a.clone());
    let t0 = std::time::Instant::now();
    let lanc = lancsvd(
        &mut be,
        &LancSvdOpts { r: 64, p: 4, b: 16, wanted: 10, tol: Some(1e-12), ..Default::default() },
    )?;
    let lanc_secs = t0.elapsed().as_secs_f64();

    // --- Randomized SVD (Alg. 1): r=16, p=24 power iterations ---
    let mut be = CpuBackend::new_dense(prob.a.clone());
    let t0 = std::time::Instant::now();
    let rand = randsvd(&mut be, &RandSvdOpts { r: 16, p: 24, b: 16, ..Default::default() })?;
    let rand_secs = t0.elapsed().as_secs_f64();

    let mut check = CpuBackend::new_dense(prob.a.clone());
    let lanc_res = residuals(&mut check, &lanc, 10);
    let rand_res = residuals(&mut check, &rand, 10);

    println!("\n{:>3} {:>13} {:>13} {:>13} {:>10} {:>10}", "i", "true sigma", "lanc", "rand", "lanc R_i", "rand R_i");
    for i in 0..10 {
        println!(
            "{:>3} {:>13.6e} {:>13.6e} {:>13.6e} {:>10.1e} {:>10.1e}",
            i + 1,
            prob.sigma[i],
            lanc.sigma[i],
            rand.sigma[i],
            lanc_res[i],
            rand_res[i]
        );
    }
    println!(
        "\nLancSVD: {lanc_secs:.2}s ({} restarts)   RandSVD: {rand_secs:.2}s ({} iterations)",
        lanc.iters, rand.iters
    );
    println!("speed-up at comparable accuracy: {:.2}x", rand_secs / lanc_secs);
    Ok(())
}
