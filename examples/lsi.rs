//! Latent semantic indexing — the data-science workload from the paper's
//! introduction (dimensionality reduction of a large sparse term-document
//! matrix before querying).
//!
//! Builds a synthetic topic-model corpus (no datasets ship offline),
//! factorizes the term-document matrix with LancSVD, and shows that
//! querying in the k-dimensional latent space recovers topic structure
//! that raw term matching misses.

use trunksvd::algo::{lancsvd::lancsvd, LancSvdOpts};
use trunksvd::backend::cpu::CpuBackend;
use trunksvd::la::blas1::{dot, nrm2};
use trunksvd::sparse::coo::Coo;
use trunksvd::sparse::csr::Csr;
use trunksvd::util::rng::Rng;

const N_TOPICS: usize = 8;
const VOCAB: usize = 2000;
const DOCS: usize = 1200;
const WORDS_PER_DOC: usize = 60;

/// Zipf-ish topic-conditioned word sampler.
struct TopicModel {
    /// cumulative word distribution per topic
    cum: Vec<Vec<f64>>,
}

impl TopicModel {
    fn new(rng: &mut Rng) -> TopicModel {
        let mut cum = Vec::new();
        for _t in 0..N_TOPICS {
            // Each topic concentrates on ~150 "own" words plus background.
            let mut w = vec![0.1; VOCAB];
            for _ in 0..150 {
                let word = rng.below(VOCAB);
                w[word] += 20.0 * rng.uniform();
            }
            let mut c = Vec::with_capacity(VOCAB);
            let mut acc = 0.0;
            for x in &w {
                acc += x;
                c.push(acc);
            }
            for x in c.iter_mut() {
                *x /= acc;
            }
            cum.push(c);
        }
        TopicModel { cum }
    }

    fn sample(&self, topic: usize, rng: &mut Rng) -> usize {
        let u = rng.uniform();
        self.cum[topic].partition_point(|&c| c < u).min(VOCAB - 1)
    }
}

fn main() -> trunksvd::Result<()> {
    let mut rng = Rng::new(7);
    let model = TopicModel::new(&mut rng);

    // Term-document matrix (terms x docs) with tf weights.
    println!("generating corpus: {DOCS} docs, vocab {VOCAB}, {N_TOPICS} topics...");
    let mut coo = Coo::new(VOCAB, DOCS);
    let mut doc_topic = Vec::with_capacity(DOCS);
    for d in 0..DOCS {
        let topic = d % N_TOPICS;
        doc_topic.push(topic);
        for _ in 0..WORDS_PER_DOC {
            let w = model.sample(topic, &mut rng);
            coo.push(w, d, 1.0);
        }
    }
    let a = Csr::from_coo(&coo)?;
    println!("term-doc matrix: {}x{} nnz {}", a.rows(), a.cols(), a.nnz());

    // Truncated SVD with k = 16 latent dimensions.
    let k = 16;
    let mut be = CpuBackend::new_sparse(a.clone());
    let t0 = std::time::Instant::now();
    let svd = lancsvd(
        &mut be,
        &LancSvdOpts { r: 64, p: 3, b: 16, wanted: k, tol: Some(1e-8), ..Default::default() },
    )?;
    println!(
        "LancSVD: {:.2}s, {} restarts, sigma_1 {:.2}, sigma_{k} {:.2}",
        t0.elapsed().as_secs_f64(),
        svd.iters,
        svd.sigma[0],
        svd.sigma[k - 1]
    );

    // Latent doc representations: D = Sigma * V^T columns (k x DOCS).
    let latent: Vec<Vec<f64>> = (0..DOCS)
        .map(|d| (0..k).map(|j| svd.sigma[j] * svd.v.at(d, j)).collect())
        .collect();

    // Evaluate: nearest-neighbor topic purity in latent space.
    let cosine = |x: &[f64], y: &[f64]| dot(x, y) / (nrm2(x) * nrm2(y)).max(1e-300);
    let mut correct = 0;
    let probes = 200;
    for probe in 0..probes {
        let d = (probe * 13) % DOCS;
        let mut best = (f64::MIN, 0usize);
        for other in 0..DOCS {
            if other == d {
                continue;
            }
            let c = cosine(&latent[d], &latent[other]);
            if c > best.0 {
                best = (c, other);
            }
        }
        if doc_topic[best.1] == doc_topic[d] {
            correct += 1;
        }
    }
    let purity = correct as f64 / probes as f64;
    println!("latent-space nearest-neighbor topic purity: {:.1}% (chance {:.1}%)",
        100.0 * purity, 100.0 / N_TOPICS as f64);
    assert!(purity > 0.6, "LSI should comfortably beat chance");
    println!("ok: latent space recovers topic structure");
    Ok(())
}
