"""Layer-2 JAX compute graphs (the AOT-compiled building blocks).

Assembles the paper's device building blocks from the Layer-1 Pallas
kernels plus in-graph small factorizations:

* ``cholqr2_graph``   — Alg. 4 fused end-to-end (Gram → Cholesky → TRSM,
  twice) returning (Q, R).
* ``cgs_cqr2_graph``  — Alg. 5 fused (project/update twice + CholeskyQR2)
  returning (Q, H, R).
* ``matmul_nn/tn``    — apply-A / apply-Aᵀ / finalize GEMMs.
* ``spmm_graph``      — block-ELL SpMM wrapper.

Design note vs. the paper: the paper ships the b×b POTRF to LAPACK on the
host (Table 1). Keeping it *in-graph* (a fori_loop right-looking Cholesky
over a 16×16 operand — negligible flops) removes two PCIe-equivalent
transfers per orthogonalization; the rust XlaBackend detects a breakdown
by checking the returned R for NaNs and falls back to the host path,
preserving the paper's CGS fallback semantics. We deliberately avoid
``jnp.linalg.cholesky``/``solve_triangular``: on CPU those lower to LAPACK
custom-calls that the xla_extension 0.5.1 PJRT client cannot execute.
"""

import jax
import jax.numpy as jnp

from .kernels import common  # noqa: F401  (enables x64)
from .kernels.gram import gram
from .kernels.panel_update import panel_update
from .kernels.row_gemm import row_gemm
from .kernels.spmm_blockell import spmm_blockell
from .kernels.tall_gemm import tall_gemm


def chol_lower(w):
    """Right-looking Cholesky of an SPD matrix, pure jnp (no custom
    calls). Returns lower-triangular L; a non-SPD input yields NaNs, which
    the rust runtime detects as the breakdown signal."""
    w = jnp.asarray(w)
    n = w.shape[0]
    idx = jnp.arange(n)

    def body(j, a):
        # Explicitly NaN-out non-positive pivots so breakdown is always
        # signalled (sqrt of a tiny *positive* rounding residue would
        # otherwise silently produce a garbage factor).
        piv = a[j, j]
        d = jnp.sqrt(jnp.where(piv > 0.0, piv, jnp.nan))
        col = jnp.where(idx > j, a[:, j] / d, 0.0)
        col = col.at[j].set(d)
        mask = (idx[:, None] > j) & (idx[None, :] > j)
        a = a - jnp.outer(col, col) * mask
        return a.at[:, j].set(col)

    a = jax.lax.fori_loop(0, n, body, w)
    return jnp.tril(a)


def tri_inv_lower(l):
    """L⁻¹ for lower-triangular L by row-wise forward substitution."""
    l = jnp.asarray(l)
    n = l.shape[0]
    eye = jnp.eye(n, dtype=l.dtype)

    def body(i, x):
        mask = (jnp.arange(n)[:, None] < i).astype(l.dtype)
        row = (eye[i] - l[i] @ (x * mask)) / l[i, i]
        return x.at[i].set(row)

    return jax.lax.fori_loop(0, n, body, jnp.zeros((n, n), dtype=l.dtype))


def _cholqr_pass(q):
    """One CholeskyQR pass: returns (Q·L⁻ᵀ, L)."""
    w = gram(q)
    l = chol_lower(w)
    linv = tri_inv_lower(l)
    return row_gemm(q, linv.T), l


def cholqr2_graph(q):
    """Alg. 4: returns (Q_out, R) with Q_in = Q_out·R, R = L̄ᵀ·Lᵀ."""
    q, l1 = _cholqr_pass(q)
    q, l2 = _cholqr_pass(q)
    r = l2.T @ l1.T
    return q, r


def cgs_cqr2_graph(q, p):
    """Alg. 5: returns (Q_out, H, R) with Q_in ≈ P·H + Q_out·R.

    H follows the paper's step S12 accumulation (H + H̄). Zero-padded
    columns of P are exact no-ops (their H rows are zero), which is what
    makes the runtime's s-bucket padding bit-safe.
    """
    h = tall_gemm(p, q)  # S1
    q = panel_update(q, p, h)  # S2
    q, l1 = _cholqr_pass(q)  # S3–S5
    hbar = tall_gemm(p, q)  # S6
    q = panel_update(q, p, hbar)  # S7
    q, l2 = _cholqr_pass(q)  # S8–S10
    r = l2.T @ l1.T  # S11
    h = h + hbar  # S12
    return q, h, r


def matmul_nn_graph(a, x):
    """Y = A·X (dense apply-A / finalize GEMM)."""
    return row_gemm(a, x)


def matmul_tn_graph(a, x):
    """Y = Aᵀ·X (dense apply-Aᵀ)."""
    return tall_gemm(a, x)


def spmm_graph(blocks, idx, x):
    """Y = A·X, A in block-ELL form (sparse apply-A)."""
    return spmm_blockell(blocks, idx, x)


# --- pure-jnp references for the graph-level tests --------------------


def cholqr2_ref(q):
    qq, r = jnp.linalg.qr(q)
    # Fix sign convention: R diagonal positive (CholeskyQR2 produces
    # positive-diagonal R because L has positive diagonal).
    sign = jnp.sign(jnp.diag(r))
    sign = jnp.where(sign == 0, 1.0, sign)
    return qq * sign[None, :], r * sign[:, None]
