"""AOT pipeline: lower the L2 graphs to HLO-text artifacts + manifest.

Reads ``config/suite.json`` (shared with the rust side) and emits one
``.hlo.txt`` per (op, bucketed shape) the runtime may request, plus
``manifest.json`` mapping op + input shapes → file. The rust runtime
(`rust/src/runtime/`) compiles these lazily through PJRT and caches the
executables.

Interchange format is HLO **text**, not a serialized HloModuleProto:
jax ≥ 0.5 emits protos with 64-bit instruction ids that xla_extension
0.5.1 rejects; the text parser reassigns ids (see aot_recipe /
/opt/xla-example/gen_hlo.py).

Incremental: existing artifact files are kept unless --force; the Makefile
treats the manifest as the build product.
"""

import argparse
import hashlib
import json
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .kernels import common  # noqa: F401  (enables x64)

F64 = jnp.float64
I32 = jnp.int32


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(shape, dtype=F64):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def _pow2_range(lo, hi):
    v = lo
    while v <= hi:
        yield v
        v *= 2


def next_pow2(x, lo, hi):
    v = lo
    while v < x and v < hi:
        v *= 2
    return v


class ArtifactSet:
    """Collects (op, input shapes, output shapes, lowered-fn) entries."""

    def __init__(self, out_dir, force=False):
        self.out_dir = out_dir
        self.force = force
        self.entries = []
        self.written = 0
        self.skipped = 0

    def add(self, op, fn, in_specs, dims=None):
        """Lower fn over in_specs and write the artifact (if stale)."""
        name_bits = [op] + ["x".join(str(d) for d in s.shape) for s in in_specs]
        fname = "_".join(name_bits) + ".hlo.txt"
        path = os.path.join(self.out_dir, fname)
        lowered = jax.jit(fn).lower(*in_specs)
        out_shapes = [list(s.shape) for s in jax.tree_util.tree_leaves(lowered.out_info)]
        if self.force or not os.path.exists(path):
            text = to_hlo_text(lowered)
            with open(path, "w") as f:
                f.write(text)
            self.written += 1
        else:
            self.skipped += 1
        self.entries.append(
            {
                "op": op,
                "file": fname,
                "inputs": [list(s.shape) for s in in_specs],
                "input_dtypes": [str(s.dtype) for s in in_specs],
                "outputs": out_shapes,
                "dims": dims or {},
            }
        )

    def write_manifest(self):
        path = os.path.join(self.out_dir, "manifest.json")
        cfg = {"artifacts": self.entries, "version": 1}
        with open(path, "w") as f:
            json.dump(cfg, f, indent=1)
        return path


def find_config():
    for cand in (
        os.environ.get("TRUNKSVD_CONFIG"),
        "config/suite.json",
        "../config/suite.json",
        os.path.join(os.path.dirname(__file__), "..", "..", "config", "suite.json"),
    ):
        if cand and os.path.exists(cand):
            return cand
    raise FileNotFoundError("config/suite.json not found")


def build_all(out_dir, force=False, quick=False):
    cfg = json.load(open(find_config()))
    bk = cfg["artifact_buckets"]
    b = bk["b"]
    q_buckets = list(_pow2_range(bk["q_pow2_min"], bk["q_pow2_max"]))
    s_buckets = list(bk["s_buckets"])
    r_buckets = [16, 64, 256]
    n_pad = 512  # dense-suite n=500 → bucket 512
    if quick:  # CI-speed subset
        q_buckets = q_buckets[:2]
        s_buckets = s_buckets[:2]
        r_buckets = [16]

    os.makedirs(out_dir, exist_ok=True)
    art = ArtifactSet(out_dir, force=force)

    # Orthogonalization graphs (Algs. 4/5) for every q bucket.
    for q in q_buckets:
        art.add(
            "cholqr2",
            model.cholqr2_graph,
            [spec((q, b))],
            dims={"q": q, "b": b},
        )
        for s in s_buckets:
            art.add(
                "cgs_cqr2",
                model.cgs_cqr2_graph,
                [spec((q, b)), spec((q, s))],
                dims={"q": q, "s": s, "b": b},
            )

    # Dense apply-A / apply-Aᵀ (the A operand rides along as an argument
    # and stays device-resident via execute_b on the rust side).
    for q in q_buckets:
        for r in r_buckets:
            art.add(
                "matmul_nn",
                model.matmul_nn_graph,
                [spec((q, n_pad)), spec((n_pad, r))],
                dims={"m": q, "k": n_pad, "n": r},
            )
            art.add(
                "matmul_tn",
                model.matmul_tn_graph,
                [spec((q, n_pad)), spec((q, r))],
                dims={"q": q, "a": n_pad, "b": r},
            )
            # Finalize GEMMs: (q×r)·(r×r) and the n-side (n_pad×r)·(r×r).
            art.add(
                "matmul_nn",
                model.matmul_nn_graph,
                [spec((q, r)), spec((r, r))],
                dims={"m": q, "k": r, "n": r},
            )
        # Restart GEMM: P̄ (q×256) · Ū₁ (256×16).
        art.add(
            "matmul_nn",
            model.matmul_nn_graph,
            [spec((q, 256)), spec((256, 16))],
            dims={"m": q, "k": 256, "n": 16},
        )

    # Block-ELL SpMM demo shape (integration-tested end-to-end from rust).
    art.add(
        "spmm_blockell",
        model.spmm_graph,
        [
            spec((32, 8, 16, 16)),
            spec((32, 8), I32),
            spec((512, 16)),
        ],
        dims={"nbr": 32, "mbpr": 8, "bs": 16, "n": 512, "k": 16},
    )

    manifest = art.write_manifest()
    print(
        f"artifacts: {art.written} written, {art.skipped} up-to-date, "
        f"manifest {manifest} ({len(art.entries)} entries)"
    )


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="artifacts", help="output directory")
    ap.add_argument("--force", action="store_true", help="rewrite all")
    ap.add_argument("--quick", action="store_true", help="small subset (tests)")
    args = ap.parse_args()
    build_all(args.out, force=args.force, quick=args.quick)


if __name__ == "__main__":
    main()
