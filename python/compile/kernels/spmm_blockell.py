"""Pallas kernel: block-ELL SpMM, Y = A·X.

Hardware adaptation of the paper's cuSPARSE CSR SpMM (DESIGN.md
§Hardware-Adaptation): CSR's per-row gather does not map onto the MXU, so
the sparse matrix is re-tiled into dense bs×bs blocks in ELL layout —
every block-row holds `mbpr` blocks (zero-padded), making the kernel a
regular gather + small-matmul loop:

    Y[i·bs : (i+1)·bs, :] = Σ_j  blocks[i, j] @ X[idx[i, j]·bs : …, :]

Grid = one program per block-row. X stays resident (memory-space ANY /
whole-array block) and is dynamically sliced per block — the TPU version
would use PrefetchScalarGridSpec to schedule the X gathers; interpret mode
executes the same dynamic slices directly.

VMEM estimate (bs=16, k=16, f64): per step mbpr×(2 KiB block + 2 KiB X
slice) streamed + 2 KiB accumulator — deeply memory-bound, as the paper
observes for SpMM on the A100.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import INTERPRET


def _spmm_kernel(idx_ref, blocks_ref, x_ref, o_ref, *, mbpr, bs):
    k = o_ref.shape[1]

    def body(j, acc):
        c = idx_ref[0, j]
        xblk = x_ref[pl.dslice(c * bs, bs), :]
        return acc + blocks_ref[0, j] @ xblk

    acc = jnp.zeros((bs, k), dtype=o_ref.dtype)
    o_ref[...] = jax.lax.fori_loop(0, mbpr, body, acc)


@jax.jit
def spmm_blockell(blocks, idx, x):
    """Y = A·X with A in block-ELL form (see ref.spmm_blockell_ref)."""
    nbr, mbpr, bs, bs2 = blocks.shape
    assert bs == bs2
    n, k = x.shape
    assert n % bs == 0
    grid = (nbr,)
    kernel = functools.partial(_spmm_kernel, mbpr=mbpr, bs=bs)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, mbpr), lambda i: (i, 0)),
            pl.BlockSpec((1, mbpr, bs, bs), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((n, k), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bs, k), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nbr * bs, k), x.dtype),
        interpret=INTERPRET,
    )(idx, blocks, x)
