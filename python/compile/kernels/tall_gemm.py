"""Pallas kernel: H = PᵀQ (transposed tall-skinny GEMM).

The block-CGS projection of Alg. 5 (steps S1/S6) and the dense apply-Aᵀ.
Same streaming structure as the Gram kernel: both q×s and q×b operands are
row-tiled through VMEM, the s×b accumulator persists across the grid.

VMEM estimate (q tile 256, s=256, b=16, f64): 512 KiB + 32 KiB streamed,
512 KiB accumulator — well under VMEM; arithmetic intensity grows with s,
crossing into MXU-bound around s ≥ 64.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import INTERPRET, pick_row_tile


def _tall_gemm_kernel(p_ref, q_ref, o_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += p_ref[...].T @ q_ref[...]


@functools.partial(jax.jit, static_argnames=("row_tile",))
def tall_gemm(p, q, row_tile=None):
    """H = PᵀQ via a row-tiled Pallas reduction."""
    qr, s = p.shape
    qr2, b = q.shape
    assert qr == qr2, "row dims must match"
    t = pick_row_tile(qr, row_tile)
    grid = (qr // t,)
    return pl.pallas_call(
        _tall_gemm_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((t, s), lambda i: (i, 0)),
            pl.BlockSpec((t, b), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((s, b), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((s, b), q.dtype),
        interpret=INTERPRET,
    )(p, q)
