"""Pallas kernel: Y = A·X (row-tiled GEMM).

The dense apply-A (Table 1's cuBLAS GEMM for dense problems) and the
finalize multiplications (U_T = Q̄·V̄ etc.). A is streamed in row tiles;
X (n×k, with n ≤ 512 and k ≤ 256 in this system) stays VMEM-resident
across the whole grid.

VMEM estimate (tile 256, n=512, k=256, f64): A tile 1 MiB + X 1 MiB +
out tile 512 KiB — fits; each grid step is a full 256×512·512×k MXU pass.
"""

import functools

import jax
from jax.experimental import pallas as pl

from .common import INTERPRET, pick_row_tile


def _row_gemm_kernel(a_ref, x_ref, o_ref):
    o_ref[...] = a_ref[...] @ x_ref[...]


@functools.partial(jax.jit, static_argnames=("row_tile",))
def row_gemm(a, x, row_tile=None):
    """Y = A·X with A row-tiled and X grid-resident."""
    m, n = a.shape
    n2, k = x.shape
    assert n == n2, "inner dims must match"
    t = pick_row_tile(m, row_tile)
    grid = (m // t,)
    return pl.pallas_call(
        _row_gemm_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((t, n), lambda i: (i, 0)),
            pl.BlockSpec((n, k), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((t, k), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, k), a.dtype),
        interpret=INTERPRET,
    )(a, x)
