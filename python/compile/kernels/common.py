"""Shared Pallas kernel configuration.

All kernels run with ``interpret=True``: the image's PJRT runtime is the
CPU plugin, and real-TPU Pallas lowering emits Mosaic custom-calls the CPU
client cannot execute (see /opt/xla-example/README.md). The BlockSpec
tiling below is still written as it would be for TPU VMEM so the HBM↔VMEM
schedule (and its footprint estimates in DESIGN.md §10) is meaningful.
"""

import jax

# f64 everywhere: the paper's experiments use IEEE double precision.
jax.config.update("jax_enable_x64", True)

# Row-tile used by the tall-skinny kernels. 256 f64 rows x 256 max panel
# cols x 8 B = 512 KiB per streamed operand block: comfortably inside a
# 16 MiB TPU VMEM alongside the b x b accumulator.
DEFAULT_ROW_TILE = 256

INTERPRET = True


def pick_row_tile(q: int, tile: int | None = None) -> int:
    """Choose a row tile that divides q (q is a power-of-two bucket in
    production; tests use arbitrary small q)."""
    t = tile or DEFAULT_ROW_TILE
    if q % t == 0:
        return t
    # largest divisor of q not exceeding t
    for cand in range(min(t, q), 0, -1):
        if q % cand == 0:
            return cand
    return q
