"""Pallas kernel: Q' = Q − P·H (block-CGS update).

Steps S2/S7 of Alg. 5: subtract the projection onto the history panel.
Row-tiled like the other tall-skinny kernels; H (s×b) is grid-resident.
Fused subtract avoids materializing P·H in HBM — on TPU this halves the
HBM traffic of the update versus a GEMM-then-subtract pair.
"""

import functools

import jax
from jax.experimental import pallas as pl

from .common import INTERPRET, pick_row_tile


def _panel_update_kernel(q_ref, p_ref, h_ref, o_ref):
    o_ref[...] = q_ref[...] - p_ref[...] @ h_ref[...]


@functools.partial(jax.jit, static_argnames=("row_tile",))
def panel_update(q, p, h, row_tile=None):
    """Q' = Q − P·H, row-tiled."""
    qr, b = q.shape
    qr2, s = p.shape
    s2, b2 = h.shape
    assert qr == qr2 and s == s2 and b == b2, "shape mismatch"
    t = pick_row_tile(qr, row_tile)
    grid = (qr // t,)
    return pl.pallas_call(
        _panel_update_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((t, b), lambda i: (i, 0)),
            pl.BlockSpec((t, s), lambda i: (i, 0)),
            pl.BlockSpec((s, b), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((t, b), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((qr, b), q.dtype),
        interpret=INTERPRET,
    )(q, p, h)
