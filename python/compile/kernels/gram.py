"""Pallas kernel: Gram matrix W = QᵀQ.

The SYRK-shaped block of CholeskyQR2 (Alg. 4 steps S1/S4). TPU mapping:
the q-dimension is streamed through VMEM in row tiles while the b×b
accumulator stays resident across the grid — the systolic-array analogue
of the paper's cuBLAS SYRK call.

VMEM/grid estimate (q=65536, b=16, f64): tile 256×16 = 32 KiB streamed +
2 KiB accumulator; MXU work per step is a 16×256·256×16 contraction.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import INTERPRET, pick_row_tile


def _gram_kernel(q_ref, o_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    tile = q_ref[...]
    o_ref[...] += tile.T @ tile


@functools.partial(jax.jit, static_argnames=("row_tile",))
def gram(q, row_tile=None):
    """W = QᵀQ via a row-tiled Pallas reduction."""
    qr, b = q.shape
    t = pick_row_tile(qr, row_tile)
    grid = (qr // t,)
    return pl.pallas_call(
        _gram_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((t, b), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((b, b), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, b), q.dtype),
        interpret=INTERPRET,
    )(q)
