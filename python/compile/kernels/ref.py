"""Pure-jnp oracles for every Pallas kernel (the correctness contract).

Each Pallas kernel in this package has a reference implementation here;
``python/tests/test_kernels.py`` sweeps shapes with hypothesis and asserts
allclose between kernel and oracle.
"""

import jax.numpy as jnp

from . import common  # noqa: F401  (enables x64)


def gram_ref(q):
    """W = QᵀQ."""
    return q.T @ q


def tall_gemm_ref(p, q):
    """H = PᵀQ (block-CGS projection / transposed tall GEMM)."""
    return p.T @ q


def row_gemm_ref(a, x):
    """Y = A·X (row-tiled GEMM, the dense apply-A)."""
    return a @ x


def panel_update_ref(q, p, h):
    """Q' = Q − P·H (block-CGS update)."""
    return q - p @ h


def spmm_blockell_ref(blocks, idx, x):
    """Y = A·X with A in block-ELL form.

    blocks: (nbr, mbpr, bs, bs) dense blocks (zero blocks pad short rows)
    idx:    (nbr, mbpr) int32 block-column indices (0 for padding; the
            padding blocks are all-zero so the index value is irrelevant)
    x:      (ncb*bs, k) dense right-hand side
    """
    nbr, mbpr, bs, _ = blocks.shape
    k = x.shape[1]
    xb = x.reshape(-1, bs, k)  # (ncb, bs, k)
    gathered = xb[idx]  # (nbr, mbpr, bs, k)
    y = jnp.einsum("rjab,rjbk->rak", blocks, gathered)
    return y.reshape(nbr * bs, k)


def blockell_from_dense(a_dense, bs):
    """Convert a dense matrix to block-ELL parts (test/reference helper;
    the production converter lives in rust/src/sparse/blockell.rs)."""
    import numpy as np

    m, n = a_dense.shape
    assert m % bs == 0 and n % bs == 0, "pad before converting"
    nbr, ncb = m // bs, n // bs
    rows = []
    for i in range(nbr):
        cols = []
        for j in range(ncb):
            blk = a_dense[i * bs : (i + 1) * bs, j * bs : (j + 1) * bs]
            if np.any(blk != 0):
                cols.append((j, blk))
        rows.append(cols)
    mbpr = max(1, max(len(r) for r in rows))
    blocks = np.zeros((nbr, mbpr, bs, bs), dtype=np.float64)
    idx = np.zeros((nbr, mbpr), dtype=np.int32)
    for i, cols in enumerate(rows):
        for s, (j, blk) in enumerate(cols):
            blocks[i, s] = blk
            idx[i, s] = j
    return blocks, idx
