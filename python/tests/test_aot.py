"""AOT pipeline tests: HLO-text generation, manifest shape consistency,
and incremental (no-op) rebuilds."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from compile import aot, model


def test_to_hlo_text_roundtrips_simple_fn():
    lowered = jax.jit(lambda x, y: (x @ y + 2.0,)).lower(
        jax.ShapeDtypeStruct((4, 4), jnp.float64),
        jax.ShapeDtypeStruct((4, 4), jnp.float64),
    )
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text
    assert "f64[4,4]" in text


def test_quick_build_writes_manifest_and_is_incremental(tmp_path):
    out = str(tmp_path / "arts")
    aot.build_all(out, quick=True)
    manifest = json.load(open(os.path.join(out, "manifest.json")))
    arts = manifest["artifacts"]
    assert len(arts) > 10
    ops = {a["op"] for a in arts}
    assert {"cholqr2", "cgs_cqr2", "matmul_nn", "matmul_tn", "spmm_blockell"} <= ops
    # every referenced file exists and is HLO text
    for a in arts:
        path = os.path.join(out, a["file"])
        assert os.path.exists(path), a["file"]
        with open(path) as f:
            head = f.read(200)
        assert "HloModule" in head
    # shapes recorded consistently
    ch = next(a for a in arts if a["op"] == "cholqr2")
    q, b = ch["inputs"][0]
    assert ch["outputs"][0] == [q, b]
    assert ch["outputs"][1] == [b, b]
    cg = next(a for a in arts if a["op"] == "cgs_cqr2")
    (q, b), (q2, s) = cg["inputs"]
    assert q == q2
    assert cg["outputs"] == [[q, b], [s, b], [b, b]]
    # incremental: second run rewrites nothing
    mtimes = {
        a["file"]: os.path.getmtime(os.path.join(out, a["file"])) for a in arts
    }
    aot.build_all(out, quick=True)
    for f, t in mtimes.items():
        assert os.path.getmtime(os.path.join(out, f)) == t, f


def test_pow2_helpers():
    assert list(aot._pow2_range(512, 4096)) == [512, 1024, 2048, 4096]
    assert aot.next_pow2(500, 512, 65536) == 512
    assert aot.next_pow2(513, 512, 65536) == 1024
    assert aot.next_pow2(10**9, 512, 65536) == 65536


def test_config_is_found():
    path = aot.find_config()
    cfg = json.load(open(path))
    assert len(cfg["sparse"]) == 46


def test_lowered_graph_numerics_survive_lowering(tmp_path):
    # Lower cholqr2, rebuild via jax from the same stablehlo, compare —
    # guards against the graphs depending on unlowered host callbacks.
    q = np.random.default_rng(0).standard_normal((64, 8))
    want_q, want_r = (np.asarray(t) for t in model.cholqr2_graph(q))
    lowered = jax.jit(model.cholqr2_graph).lower(
        jax.ShapeDtypeStruct((64, 8), jnp.float64)
    )
    compiled = lowered.compile()
    got_q, got_r = (np.asarray(t) for t in compiled(q))
    np.testing.assert_allclose(got_q, want_q, rtol=1e-13, atol=1e-13)
    np.testing.assert_allclose(got_r, want_r, rtol=1e-13, atol=1e-13)
