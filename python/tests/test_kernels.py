"""L1 correctness: every Pallas kernel vs. its pure-jnp oracle.

Hypothesis sweeps shapes (and row tiles) — the core correctness signal for
the kernel layer.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile.kernels import ref
from compile.kernels.gram import gram
from compile.kernels.panel_update import panel_update
from compile.kernels.row_gemm import row_gemm
from compile.kernels.spmm_blockell import spmm_blockell
from compile.kernels.tall_gemm import tall_gemm

SETTINGS = dict(max_examples=25, deadline=None)


def rng_mat(seed, *shape):
    return np.random.default_rng(seed).standard_normal(shape)


@settings(**SETTINGS)
@given(
    q=st.integers(1, 40).map(lambda x: 8 * x),
    b=st.integers(1, 24),
    tile=st.sampled_from([None, 8, 16, 64]),
    seed=st.integers(0, 2**31),
)
def test_gram_matches_ref(q, b, tile, seed):
    x = rng_mat(seed, q, b)
    got = gram(x, row_tile=tile)
    assert_allclose(np.asarray(got), ref.gram_ref(x), rtol=1e-12, atol=1e-12)


@settings(**SETTINGS)
@given(
    q=st.integers(1, 32).map(lambda x: 8 * x),
    s=st.integers(1, 40),
    b=st.integers(1, 20),
    tile=st.sampled_from([None, 8, 32]),
    seed=st.integers(0, 2**31),
)
def test_tall_gemm_matches_ref(q, s, b, tile, seed):
    p = rng_mat(seed, q, s)
    x = rng_mat(seed + 1, q, b)
    got = tall_gemm(p, x, row_tile=tile)
    assert_allclose(np.asarray(got), ref.tall_gemm_ref(p, x), rtol=1e-12, atol=1e-12)


@settings(**SETTINGS)
@given(
    m=st.integers(1, 32).map(lambda x: 8 * x),
    n=st.integers(1, 48),
    k=st.integers(1, 20),
    tile=st.sampled_from([None, 8, 32]),
    seed=st.integers(0, 2**31),
)
def test_row_gemm_matches_ref(m, n, k, tile, seed):
    a = rng_mat(seed, m, n)
    x = rng_mat(seed + 1, n, k)
    got = row_gemm(a, x, row_tile=tile)
    assert_allclose(np.asarray(got), ref.row_gemm_ref(a, x), rtol=1e-12, atol=1e-12)


@settings(**SETTINGS)
@given(
    q=st.integers(1, 24).map(lambda x: 8 * x),
    s=st.integers(1, 32),
    b=st.integers(1, 16),
    tile=st.sampled_from([None, 8]),
    seed=st.integers(0, 2**31),
)
def test_panel_update_matches_ref(q, s, b, tile, seed):
    qm = rng_mat(seed, q, b)
    p = rng_mat(seed + 1, q, s)
    h = rng_mat(seed + 2, s, b)
    got = panel_update(qm, p, h, row_tile=tile)
    assert_allclose(
        np.asarray(got), ref.panel_update_ref(qm, p, h), rtol=1e-12, atol=1e-12
    )


@settings(max_examples=10, deadline=None)
@given(
    nbr=st.integers(1, 6),
    ncb=st.integers(1, 6),
    bs=st.sampled_from([4, 8, 16]),
    k=st.integers(1, 12),
    density=st.floats(0.1, 0.9),
    seed=st.integers(0, 2**31),
)
def test_spmm_blockell_matches_ref_and_dense(nbr, ncb, bs, k, density, seed):
    rng = np.random.default_rng(seed)
    # Build a block-sparse dense matrix, convert to block-ELL.
    a = rng.standard_normal((nbr * bs, ncb * bs))
    keep = rng.random((nbr, ncb)) < density
    for i in range(nbr):
        for j in range(ncb):
            if not keep[i, j]:
                a[i * bs : (i + 1) * bs, j * bs : (j + 1) * bs] = 0.0
    blocks, idx = ref.blockell_from_dense(a, bs)
    x = rng.standard_normal((ncb * bs, k))
    want = a @ x
    got_ref = ref.spmm_blockell_ref(blocks, idx, x)
    assert_allclose(np.asarray(got_ref), want, rtol=1e-12, atol=1e-12)
    got = spmm_blockell(blocks, idx.astype(np.int32), x)
    assert_allclose(np.asarray(got), want, rtol=1e-12, atol=1e-12)


def test_gram_zero_row_padding_is_exact():
    # The runtime pads q to power-of-two buckets with zero rows; the
    # result must be bitwise-identical to the unpadded kernel output.
    x = rng_mat(0, 24, 5)
    xp = np.vstack([x, np.zeros((8, 5))])
    g_pad = np.asarray(gram(xp, row_tile=8))
    g_unpad = np.asarray(gram(x, row_tile=8))
    assert_allclose(g_pad, g_unpad, rtol=0, atol=0)
    assert_allclose(g_pad, ref.gram_ref(x), rtol=1e-12, atol=1e-12)


def test_tall_gemm_zero_col_padding_is_exact():
    q = rng_mat(1, 16, 3)
    p = rng_mat(2, 16, 4)
    p_pad = np.hstack([p, np.zeros((16, 4))])
    h = np.asarray(tall_gemm(p_pad, q, row_tile=8))
    assert_allclose(h[:4], ref.tall_gemm_ref(p, q), rtol=1e-13, atol=1e-14)
    assert np.all(h[4:] == 0.0)


def test_kernels_are_f64():
    x = rng_mat(3, 16, 4)
    assert np.asarray(gram(x)).dtype == np.float64


@pytest.mark.parametrize("bad_tile", [3, 7])
def test_row_tile_fallback_divides(bad_tile):
    # pick_row_tile must find a divisor; kernel still correct.
    x = rng_mat(4, 32, 4)
    got = gram(x, row_tile=bad_tile)
    assert_allclose(np.asarray(got), ref.gram_ref(x), rtol=1e-12, atol=1e-12)
