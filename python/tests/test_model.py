"""L2 correctness: the fused orthogonalization graphs and the in-graph
small factorizations vs. numpy/jnp references."""

import numpy as np
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile import model

SETTINGS = dict(max_examples=15, deadline=None)


def rng_mat(seed, *shape):
    return np.random.default_rng(seed).standard_normal(shape)


def spd(seed, n):
    g = rng_mat(seed, n + 4, n)
    return g.T @ g + 1e-3 * np.eye(n)


@settings(**SETTINGS)
@given(n=st.integers(1, 24), seed=st.integers(0, 2**31))
def test_chol_lower_matches_numpy(n, seed):
    w = spd(seed, n)
    l = np.asarray(model.chol_lower(w))
    want = np.linalg.cholesky(w)
    assert_allclose(l, want, rtol=1e-10, atol=1e-12)


@settings(**SETTINGS)
@given(n=st.integers(1, 20), seed=st.integers(0, 2**31))
def test_tri_inv_lower(n, seed):
    l = np.linalg.cholesky(spd(seed, n))
    linv = np.asarray(model.tri_inv_lower(l))
    assert_allclose(linv @ l, np.eye(n), rtol=1e-10, atol=1e-10)
    # strictly lower-triangular output
    assert np.allclose(np.triu(linv, 1), 0.0)


def test_chol_lower_breakdown_yields_nan():
    # A clearly indefinite matrix must signal breakdown with NaN (the
    # runtime's fallback trigger).
    w = np.array([[1.0, 2.0], [2.0, 1.0]])
    l = np.asarray(model.chol_lower(w))
    assert np.isnan(l).any()


def test_cholqr2_graph_breakdown_usable_or_detectable():
    # Rank-deficient panel contract (DESIGN.md §7): the graph result is
    # either *usable* (orthonormal Q — the dead direction was replaced by
    # normalized rounding noise, exactly what the CGS2 fallback would do)
    # or *detectable* (NaN somewhere), in which case the rust runtime
    # falls back to the host CGS2 path. It must never be silently wrong.
    for seed in range(5):
        y = rng_mat(seed, 32, 4)
        y[:, 2] = y[:, 0]
        qq, r = (np.asarray(t) for t in model.cholqr2_graph(y))
        finite = np.isfinite(qq).all() and np.isfinite(r).all()
        if finite:
            orth_err = np.abs(qq.T @ qq - np.eye(4)).max()
            assert orth_err < 1e-8, f"seed {seed}: silently wrong ({orth_err:.2e})"


@settings(**SETTINGS)
@given(
    q=st.integers(2, 24).map(lambda x: 8 * x),
    b=st.sampled_from([2, 4, 8, 16]),
    seed=st.integers(0, 2**31),
)
def test_cholqr2_graph(q, b, seed):
    y = rng_mat(seed, q, b)
    qq, r = model.cholqr2_graph(y)
    qq, r = np.asarray(qq), np.asarray(r)
    # Orthonormal + reconstructs + upper triangular.
    assert_allclose(qq.T @ qq, np.eye(b), rtol=0, atol=1e-12)
    assert_allclose(qq @ r, y, rtol=1e-11, atol=1e-11)
    assert np.allclose(np.tril(r, -1), 0.0)


@settings(**SETTINGS)
@given(
    q=st.integers(4, 20).map(lambda x: 8 * x),
    s=st.sampled_from([4, 8, 16]),
    b=st.sampled_from([2, 4, 8]),
    seed=st.integers(0, 2**31),
)
def test_cgs_cqr2_graph(q, s, b, seed):
    # Orthonormal history panel P via numpy QR.
    p, _ = np.linalg.qr(rng_mat(seed, q, s))
    y = rng_mat(seed + 1, q, b)
    qq, h, r = (np.asarray(t) for t in model.cgs_cqr2_graph(y, p))
    assert_allclose(qq.T @ qq, np.eye(b), rtol=0, atol=1e-12)
    assert_allclose(p.T @ qq, np.zeros((s, b)), rtol=0, atol=1e-11)
    assert_allclose(p @ h + qq @ r, y, rtol=1e-10, atol=1e-10)


def test_cgs_cqr2_zero_padded_history_is_exact():
    # The runtime pads P's column count to the next s bucket with zeros.
    q, s, b = 64, 6, 4
    p, _ = np.linalg.qr(rng_mat(3, q, s))
    y = rng_mat(4, q, b)
    p_pad = np.hstack([p, np.zeros((q, 10))])
    q1, h1, r1 = (np.asarray(t) for t in model.cgs_cqr2_graph(y, p))
    q2, h2, r2 = (np.asarray(t) for t in model.cgs_cqr2_graph(y, p_pad))
    assert_allclose(q1, q2, rtol=0, atol=1e-13)
    assert_allclose(r1, r2, rtol=0, atol=1e-13)
    assert_allclose(h2[:s], h1, rtol=0, atol=1e-13)
    assert np.all(h2[s:] == 0.0)


def test_cholqr2_zero_padded_rows_are_exact():
    q, b = 40, 4
    y = rng_mat(5, q, b)
    y_pad = np.vstack([y, np.zeros((24, b))])
    q1, r1 = (np.asarray(t) for t in model.cholqr2_graph(y))
    q2, r2 = (np.asarray(t) for t in model.cholqr2_graph(y_pad))
    assert_allclose(r1, r2, rtol=0, atol=1e-13)
    assert_allclose(q2[:q], q1, rtol=0, atol=1e-13)
    assert np.all(q2[q:] == 0.0)


def test_matmul_graphs():
    a = rng_mat(6, 48, 16)
    x = rng_mat(7, 16, 5)
    assert_allclose(np.asarray(model.matmul_nn_graph(a, x)), a @ x, rtol=1e-12, atol=1e-12)
    z = rng_mat(8, 48, 3)
    assert_allclose(np.asarray(model.matmul_tn_graph(a, z)), a.T @ z, rtol=1e-12, atol=1e-12)
