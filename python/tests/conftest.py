import os
import sys

import jax

# f64 everywhere (paper uses IEEE double precision).
jax.config.update("jax_enable_x64", True)

# Make `compile` importable when pytest runs from python/ or the repo root.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
