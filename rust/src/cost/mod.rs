//! Analytic flop-cost model (Table 1 of the paper).
//!
//! CA3/CA4/CA5 mirror the paper's cost functions for Algorithms 3–5; the
//! totals for RandSVD and LancSVD follow the summation rows of Table 1.
//! The same per-op formulas are used by the backends' instrumentation, so
//! `bench_table1_cost` can validate model == measured-counter exactly.
//! Fig. 3 (flop distribution across building blocks) is generated directly
//! from [`randsvd_cost`] / [`lancsvd_cost`] breakdowns.
//!
//! The dispatch heuristics ([`adaptive_transpose_threshold`],
//! [`parallel_cutoff`]) ship with desk-estimate constants that a
//! measured [`CostCalibration`] (from `bench_blocks --calibrate`, loaded
//! via `TRUNKSVD_COST_CALIB`) can replace at runtime.

pub mod device;

use crate::util::json::Json;
use std::sync::OnceLock;

/// Measured overrides for the dispatch-heuristic constants baked into
/// [`adaptive_transpose_threshold`] and [`parallel_cutoff`].
///
/// The built-in constants are desk estimates (memory-sweep counts, a
/// 5 µs dispatch guess); `bench_blocks` measures the real crossovers on
/// the host it runs on and emits them as a `cost_calibration` section in
/// `BENCH_kernels.json`. Pointing `TRUNKSVD_COST_CALIB` at that file (or
/// any JSON holding the section, or the bare section object) swaps the
/// constants for the measured values — clamped to the same sanity ranges
/// the tests pin, so a corrupt or wildly-off calibration can degrade
/// quality but never break the dispatch invariants.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct CostCalibration {
    /// One-time transpose-build cost in nnz-proportional memory sweeps
    /// (clamped to [1, 64]).
    pub build_sweeps: f64,
    /// Extra sweeps per scatter `spmm_t` call vs the gather kernel,
    /// per k-column (clamped to [0.05, 16]).
    pub scatter_penalty: f64,
    /// Pool serial/parallel grain in output elements per band
    /// (clamped to [64, 16384] — the range `test_cost_model` pins).
    pub parallel_cutoff: usize,
}

impl CostCalibration {
    /// The built-in desk-estimate constants.
    pub const DEFAULT: CostCalibration =
        CostCalibration { build_sweeps: 6.0, scatter_penalty: 1.0, parallel_cutoff: 1024 };

    fn clamped(self) -> CostCalibration {
        CostCalibration {
            build_sweeps: self.build_sweeps.clamp(1.0, 64.0),
            scatter_penalty: self.scatter_penalty.clamp(0.05, 16.0),
            parallel_cutoff: self.parallel_cutoff.clamp(64, 16384),
        }
    }

    /// Parse from a JSON value: either the bare calibration object or a
    /// document with a `cost_calibration` section (the `BENCH_kernels`
    /// layout). Missing/non-numeric fields fall back to the defaults;
    /// non-finite values are rejected the same way.
    pub fn from_json(doc: &Json) -> Option<CostCalibration> {
        let obj = doc.get("cost_calibration").unwrap_or(doc);
        let field = |key: &str| obj.get(key).and_then(Json::as_f64).filter(|v| v.is_finite());
        let d = CostCalibration::DEFAULT;
        let build_sweeps = field("build_sweeps").unwrap_or(d.build_sweeps);
        let scatter_penalty = field("scatter_penalty").unwrap_or(d.scatter_penalty);
        let parallel_cutoff = field("parallel_cutoff")
            .map(|v| v.max(0.0) as usize)
            .unwrap_or(d.parallel_cutoff);
        // A doc with none of the fields is not a calibration at all.
        if field("build_sweeps").is_none()
            && field("scatter_penalty").is_none()
            && field("parallel_cutoff").is_none()
        {
            return None;
        }
        Some(CostCalibration { build_sweeps, scatter_penalty, parallel_cutoff }.clamped())
    }
}

/// Load a calibration from a JSON file (`BENCH_kernels.json` or a bare
/// calibration object). Returns `None` on unreadable/unparseable files
/// or files without any calibration field.
pub fn load_calibration(path: &str) -> Option<CostCalibration> {
    let doc = crate::util::json::parse_file(path).ok()?;
    CostCalibration::from_json(&doc)
}

/// The active calibration: `TRUNKSVD_COST_CALIB=<file>` if set and
/// loadable, else the built-in defaults. Resolved once per process.
pub fn calibration() -> CostCalibration {
    static CAL: OnceLock<CostCalibration> = OnceLock::new();
    *CAL.get_or_init(|| {
        std::env::var("TRUNKSVD_COST_CALIB")
            .ok()
            .and_then(|p| load_calibration(&p))
            .unwrap_or(CostCalibration::DEFAULT)
    })
}

/// Fusion policy for the fused operand-pass tier
/// (`Backend::apply_a_gram_into` / `Backend::apply_ata_into`), resolved
/// from `TRUNKSVD_FUSE={auto,on,off}`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FusePolicy {
    /// Cost-model decision: fuse when the operand exceeds the assumed
    /// last-level cache or lives on disk (see [`should_fuse_with`]).
    Auto,
    /// Always take the fused kernels.
    On,
    /// Always take the unfused composition.
    Off,
}

/// Last-level-cache size the [`FusePolicy::Auto`] heuristic assumes
/// (32 MiB — the order of a mainstream server LLC). Operands below this
/// are re-streamed from cache, so a second pass is nearly free and the
/// fused kernels' extra synchronization (serial band loop between the
/// gather and scatter halves) can only cost; operands above it pay DRAM
/// bandwidth per pass, which is exactly what fusing halves.
pub const FUSE_LLC_BYTES: usize = 32 << 20;

/// Parse a `TRUNKSVD_FUSE` value. Accepts `auto`, `on`/`1`/`true`,
/// `off`/`0`/`false` (ASCII case-insensitive, surrounding whitespace
/// ignored); anything else is `None` so the caller can fall back loudly.
pub fn parse_fuse(s: &str) -> Option<FusePolicy> {
    match s.trim().to_ascii_lowercase().as_str() {
        "auto" => Some(FusePolicy::Auto),
        "on" | "1" | "true" => Some(FusePolicy::On),
        "off" | "0" | "false" => Some(FusePolicy::Off),
        _ => None,
    }
}

/// The active fusion policy: `TRUNKSVD_FUSE` if set and recognized, else
/// [`FusePolicy::Auto`]. Resolved once per process, like [`calibration`].
pub fn fuse_policy() -> FusePolicy {
    static POLICY: OnceLock<FusePolicy> = OnceLock::new();
    *POLICY.get_or_init(|| {
        std::env::var("TRUNKSVD_FUSE")
            .ok()
            .and_then(|v| parse_fuse(&v))
            .unwrap_or(FusePolicy::Auto)
    })
}

/// Pure fusion decision for a given policy: should the algorithms take
/// the fused operand-pass kernels for an operand of `operand_bytes`
/// (values + index structure), `on_disk` when it streams from shards?
///
/// The Auto rationale is bandwidth, not flops: the fused kernels do the
/// same arithmetic as the unfused composition but touch the operand once
/// per power/Lanczos step instead of twice. That only buys anything when
/// a pass actually costs DRAM (operand larger than the LLC) or disk
/// (sharded under a resident cap) traffic; cache-resident operands stay
/// unfused so the tiny fixtures in the test suite keep exercising the
/// classic composition by default.
pub fn should_fuse_with(policy: FusePolicy, operand_bytes: usize, on_disk: bool) -> bool {
    match policy {
        FusePolicy::On => true,
        FusePolicy::Off => false,
        FusePolicy::Auto => on_disk || operand_bytes > FUSE_LLC_BYTES,
    }
}

/// [`should_fuse_with`] under the process-wide [`fuse_policy`] — the
/// entry point `randsvd`/`lancsvd` consult when `opts.fuse` is `None`.
pub fn should_fuse(operand_bytes: usize, on_disk: bool) -> bool {
    should_fuse_with(fuse_policy(), operand_bytes, on_disk)
}

/// Problem description for the cost model.
#[derive(Clone, Copy, Debug)]
pub struct Problem {
    pub m: usize,
    pub n: usize,
    /// `Some(nnz)` for sparse A (SpMM cost 2·nnz·k), `None` for dense
    /// (GEMM cost 2·m·n·k).
    pub nnz: Option<usize>,
}

impl Problem {
    pub fn mult_cost(&self, k: usize) -> f64 {
        match self.nnz {
            Some(nz) => 2.0 * nz as f64 * k as f64,
            None => 2.0 * self.m as f64 * self.n as f64 * k as f64,
        }
    }
}

/// Flop breakdown across the Fig. 3 building-block categories.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct CostBreakdown {
    /// multiplications with A
    pub mult_a: f64,
    /// multiplications with Aᵀ
    pub mult_at: f64,
    /// orthogonalization of m-dimension panels
    pub orth_m: f64,
    /// orthogonalization of n-dimension panels
    pub orth_n: f64,
    /// host-side small SVD (O(r³) with the Jacobi constant)
    pub small_svd: f64,
    /// post-loop GEMMs (U_T/V_T formation, restart)
    pub finalize: f64,
}

impl CostBreakdown {
    pub fn total(&self) -> f64 {
        self.mult_a + self.mult_at + self.orth_m + self.orth_n + self.small_svd + self.finalize
    }

    pub fn as_pairs(&self) -> [(&'static str, f64); 6] {
        [
            ("mult_A", self.mult_a),
            ("mult_At", self.mult_at),
            ("orth_m", self.orth_m),
            ("orth_n", self.orth_n),
            ("small_svd", self.small_svd),
            ("finalize", self.finalize),
        ]
    }
}

/// First-cut estimate of the adaptive-transpose crossover: after how many
/// scatter `spmm_t` calls does building the explicit transposed CSR copy
/// (paper §4.1.2) pay for itself?
///
/// Model (all costs in nnz-proportional memory sweeps, the right unit for
/// these bandwidth-bound kernels):
///
/// * one scatter Aᵀ·X call costs ≈ `SCATTER_PENALTY` extra sweeps of the
///   nnz stream per k-column group vs the gather kernel on the cached
///   transpose (random writes into the n-length output columns defeat
///   the write-combining the row-gather kernel gets for free);
/// * the one-time transpose build costs ≈ `BUILD_SWEEPS` sweeps (parallel
///   histogram + banded column fill, each re-scanning the index/value
///   streams, plus the allocation traffic) — amortized further by the
///   fact it runs on a background thread and only steals bandwidth;
/// * wide-and-short matrices (cols ≫ rows) scatter into longer output
///   columns with worse locality, captured by a mild aspect bump.
///
/// Crossover: `N · k · SCATTER_PENALTY ≥ BUILD_SWEEPS` ⇒
/// `N ≈ BUILD_SWEEPS / (k · SCATTER_PENALTY)`, clamped to [1, 64] — with
/// one nnz gate in front: operands whose value/index streams and output
/// columns are cache-resident scatter as fast as they gather (the penalty
/// model above is a DRAM-traffic argument), so the explicit copy would
/// only pay memory rent; those stay on scatter (threshold pushed to the
/// cap). The `TRUNKSVD_ADAPTIVE_SPMMT` env var still overrides the
/// estimate (see `backend::AdaptiveTranspose`).
pub fn adaptive_transpose_threshold(rows: usize, cols: usize, nnz: usize, k: usize) -> usize {
    let cal = calibration();
    // Cache-residency gate: ~(nnz values + nnz indices + cols outputs)
    // below a few hundred KiB means no DRAM round-trips to save.
    if nnz.saturating_add(cols) < 32_768 {
        return 64;
    }
    // Locality bump: scatter touches `cols` output cells per column; when
    // the column space dwarfs the row count the scatter working set spills
    // caches sooner, so the crossover comes earlier (divide the build
    // sweeps over a larger per-call penalty).
    let aspect = if rows > 0 && cols > 4 * rows { 2.0 } else { 1.0 };
    let per_call = (k.max(1) as f64) * cal.scatter_penalty * aspect;
    let n = (cal.build_sweeps / per_call).ceil() as usize;
    n.clamp(1, 64)
}

/// Minimum number of owned output elements per worker band before the
/// persistent pool (`util::pool`) fans a kernel out, i.e. the serial
/// fast-path threshold for small panels.
///
/// Model: one pool dispatch costs a condvar wake + join handshake,
/// ~2–10 µs on a mainstream multicore host. The threaded kernels here
/// are memory-bound and touch their output at ~0.5–2 ns per element
/// (each output element also amortizes a bounded amount of operand
/// traffic), so a band must own roughly
/// `dispatch_cost / per_element_cost ≈ 5 µs / 2 ns ≈ 2.5 K` elements
/// before perfect scaling merely breaks even — and the panels the
/// paper's algorithms emit (q×b with b ≤ 32) hit the pool dozens of
/// times per iteration, so dispatching below the crossover costs real
/// wall time. We use 1024 as the grain: conservative enough that a
/// 2-band split already owns ~2× the break-even work per extra thread,
/// small enough that the m ≥ 4096 panels of the paper's sweeps fan out
/// fully. A measured value from `TRUNKSVD_COST_CALIB` (see
/// [`CostCalibration`]) replaces the 1024 desk estimate. Runtime
/// overrides: `TRUNKSVD_PARALLEL_CUTOFF` or `pool::set_parallel_cutoff`
/// (used by the tests to force the parallel path on tiny fixtures).
pub fn parallel_cutoff() -> usize {
    calibration().parallel_cutoff
}

/// CA4: CholeskyQR2 on a q×b panel (Alg. 4).
/// Two passes of: Gram (b²q) + POTRF (b³/3) + TRSM (b²q), plus the b³ TRMM.
pub fn ca4(b: usize, q: usize) -> f64 {
    let (b, q) = (b as f64, q as f64);
    2.0 * (b * b * q) + 2.0 * (b * b * b / 3.0) + 2.0 * (b * b * q) + b * b * b
}

/// CA5: CGS-CQR2 of a q×b panel against a q×s history (Alg. 5).
/// Two passes of: projection H = PᵀQ (2qsb) + update Q −= PH (2qsb) +
/// Gram (b²q) + POTRF (b³/3) + TRSM (b²q), plus TRMM (b³) and the s·b add.
pub fn ca5(b: usize, q: usize, s: usize) -> f64 {
    let (b, q, s) = (b as f64, q as f64, s as f64);
    2.0 * (2.0 * q * s * b) // S1/S6 projections
        + 2.0 * (2.0 * q * s * b) // S2/S7 updates
        + 2.0 * (b * b * q) // S3/S8 gram
        + 2.0 * (b * b * b / 3.0) // S4/S9 potrf
        + 2.0 * (b * b * q) // S5/S10 trsm
        + b * b * b // S11 trmm
        + s * b // S12 add
}

/// CA3: CGS-QR of a q×r matrix with block size b (Alg. 3).
pub fn ca3(b: usize, q: usize, r: usize) -> f64 {
    let k = r / b.min(r).max(1);
    let mut c = ca4(b.min(r), q);
    for j in 2..=k {
        c += ca5(b, q, (j - 1) * b);
    }
    c
}

/// Host Jacobi-SVD cost model for an r×r factor (O(r³); the constant
/// matches the instrumentation in the algorithms).
pub fn small_svd_cost(r: usize) -> f64 {
    9.0 * (r as f64).powi(3)
}

/// RandSVD (Alg. 1) total-cost breakdown for parameters (r, p, b).
pub fn randsvd_cost(prob: Problem, r: usize, p: usize, b: usize) -> CostBreakdown {
    let (m, n) = (prob.m, prob.n);
    let pf = p as f64;
    CostBreakdown {
        mult_a: pf * prob.mult_cost(r),                       // S1
        orth_m: pf * ca3(b, m, r),                            // S2
        mult_at: pf * prob.mult_cost(r),                      // S3
        orth_n: pf * ca3(b, n, r),                            // S4
        small_svd: small_svd_cost(r),                         // S5
        finalize: 2.0 * (m as f64) * (r as f64) * (r as f64)  // S6
            + 2.0 * (n as f64) * (r as f64) * (r as f64),     // S7
    }
}

/// LancSVD (Alg. 2) total-cost breakdown for parameters (r, p, b).
pub fn lancsvd_cost(prob: Problem, r: usize, p: usize, b: usize) -> CostBreakdown {
    let (m, n) = (prob.m, prob.n);
    let k = r / b;
    let mut c = CostBreakdown {
        orth_m: ca4(b, m), // S1 init orthonormalization
        ..Default::default()
    };
    for j in 1..=p {
        for i in 1..=k {
            c.mult_at += prob.mult_cost(b); // S2
            if i == 1 {
                c.orth_n += ca4(b, n); // S3a
            } else {
                c.orth_n += ca5(b, n, (i - 1) * b); // S3b
            }
            c.mult_a += prob.mult_cost(b); // S4
            c.orth_m += ca5(b, m, i * b); // S5
        }
        c.small_svd += small_svd_cost(r); // S6
        if j < p {
            c.finalize += 2.0 * (b as f64) * (m as f64) * (r as f64); // S7 restart
            c.orth_m += ca4(b, m); // restart re-orthonormalization guard
        }
    }
    // S8/S9 final basis GEMMs.
    c.finalize += 2.0 * (n as f64) * (r as f64) * (r as f64);
    c.finalize += 2.0 * (m as f64) * (r as f64) * (r as f64);
    c
}

#[cfg(test)]
mod tests {
    use super::*;

    const SP: Problem = Problem { m: 10_000, n: 4_000, nnz: Some(80_000) };

    #[test]
    fn adaptive_threshold_shape() {
        // Wider column blocks amortize the build faster ⇒ lower threshold.
        let t1 = adaptive_transpose_threshold(10_000, 4_000, 80_000, 1);
        let t16 = adaptive_transpose_threshold(10_000, 4_000, 80_000, 16);
        assert!(t16 <= t1, "k=16 {t16} vs k=1 {t1}");
        assert!((1..=64).contains(&t1));
        assert_eq!(t16, 1, "wide blocks should adopt almost immediately");
        // Wide-and-short operands cross over no later than square ones.
        let sq = adaptive_transpose_threshold(10_000, 10_000, 80_000, 2);
        let wide = adaptive_transpose_threshold(512, 100_000, 80_000, 2);
        assert!(wide <= sq, "wide {wide} vs square {sq}");
        // Cache-resident operands never pay for the copy: threshold at cap.
        assert_eq!(adaptive_transpose_threshold(500, 300, 9_000, 16), 64);
        assert_eq!(adaptive_transpose_threshold(0, 0, 0, 0), 64);
        // Degenerate k on a large operand stays sane.
        assert!(adaptive_transpose_threshold(10, 10, 100_000, 0) >= 1);
    }

    #[test]
    fn parallel_cutoff_sane() {
        // At least one element per band, and small enough that the
        // paper-scale panels (m >= 4096, b >= 8) always fan out.
        let c = parallel_cutoff();
        assert!(c >= 1);
        assert!(c <= 4096 * 8 / 2, "cutoff {c} would serialize paper-scale panels");
    }

    #[test]
    fn ca_functions_positive_and_monotone() {
        assert!(ca4(16, 1000) > 0.0);
        assert!(ca4(16, 2000) > ca4(16, 1000));
        assert!(ca5(16, 1000, 64) > ca5(16, 1000, 16));
        assert!(ca3(16, 1000, 256) > ca3(16, 1000, 64));
    }

    #[test]
    fn ca3_reduces_to_ca4_for_single_block() {
        assert_eq!(ca3(16, 5000, 16), ca4(16, 5000));
    }

    #[test]
    fn randsvd_cost_linear_in_p() {
        let c1 = randsvd_cost(SP, 16, 1, 16);
        let c2 = randsvd_cost(SP, 16, 2, 16);
        let loop1 = c1.mult_a + c1.mult_at + c1.orth_m + c1.orth_n;
        let loop2 = c2.mult_a + c2.mult_at + c2.orth_m + c2.orth_n;
        assert!((loop2 / loop1 - 2.0).abs() < 1e-12);
        // non-loop parts identical
        assert_eq!(c1.small_svd, c2.small_svd);
        assert_eq!(c1.finalize, c2.finalize);
    }

    #[test]
    fn spmm_count_equivalence() {
        // Paper §4.1.2: LancSVD(r=256,p=2,b=16) performs r/b·p = 32
        // products with each of A and Aᵀ; RandSVD(r=16,p=32,b=16) performs
        // 32 too — the configurations match in SpMM flops.
        let lanc = lancsvd_cost(SP, 256, 2, 16);
        let rand = randsvd_cost(SP, 16, 32, 16);
        assert!((lanc.mult_at - rand.mult_at).abs() < 1e-9);
        assert!((lanc.mult_a - rand.mult_a).abs() < 1e-9);
    }

    #[test]
    fn paper_flop_comparison_randsvd_cheaper() {
        // Fig. 3 discussion: RandSVD (r=16, p=96) needs *fewer* flops than
        // LancSVD (r=256, p=2) on typical sparse problems, despite being
        // slower in wall time.
        let lanc = lancsvd_cost(SP, 256, 2, 16);
        let rand = randsvd_cost(SP, 16, 96, 16);
        assert!(
            rand.total() < lanc.total(),
            "rand {:.3e} < lanc {:.3e}",
            rand.total(),
            lanc.total()
        );
    }

    #[test]
    fn dense_mult_cost() {
        let dp = Problem { m: 1000, n: 500, nnz: None };
        assert_eq!(dp.mult_cost(16), 2.0 * 1000.0 * 500.0 * 16.0);
    }

    #[test]
    fn calibration_from_json_forms() {
        use crate::util::json;
        // Bare object.
        let bare = json::parse(
            r#"{"build_sweeps": 4.5, "scatter_penalty": 0.8, "parallel_cutoff": 2048}"#,
        )
        .unwrap();
        let c = CostCalibration::from_json(&bare).unwrap();
        assert_eq!(
            c,
            CostCalibration { build_sweeps: 4.5, scatter_penalty: 0.8, parallel_cutoff: 2048 }
        );
        // BENCH_kernels layout: wrapped in a cost_calibration section.
        let doc = json::parse(
            r#"{"bench": "kernels", "cost_calibration": {"build_sweeps": 12.0}}"#,
        )
        .unwrap();
        let c = CostCalibration::from_json(&doc).unwrap();
        assert_eq!(c.build_sweeps, 12.0);
        assert_eq!(c.scatter_penalty, CostCalibration::DEFAULT.scatter_penalty);
        assert_eq!(c.parallel_cutoff, CostCalibration::DEFAULT.parallel_cutoff);
        // A document without any calibration field is not a calibration.
        let other = json::parse(r#"{"results": []}"#).unwrap();
        assert!(CostCalibration::from_json(&other).is_none());
    }

    #[test]
    fn calibration_clamps_to_pinned_ranges() {
        use crate::util::json;
        let wild = json::parse(
            r#"{"build_sweeps": 1e9, "scatter_penalty": -3.0, "parallel_cutoff": 7}"#,
        )
        .unwrap();
        let c = CostCalibration::from_json(&wild).unwrap();
        assert_eq!(c.build_sweeps, 64.0);
        assert_eq!(c.scatter_penalty, 0.05);
        assert_eq!(c.parallel_cutoff, 64);
        let huge = json::parse(r#"{"parallel_cutoff": 1000000}"#).unwrap();
        assert_eq!(CostCalibration::from_json(&huge).unwrap().parallel_cutoff, 16384);
    }

    #[test]
    fn load_calibration_file_roundtrip() {
        let dir = std::env::temp_dir();
        let path = dir.join("trunksvd_test_cost_calib.json");
        let path = path.to_str().unwrap();
        std::fs::write(
            path,
            r#"{"cost_calibration": {"build_sweeps": 8.0, "scatter_penalty": 2.0, "parallel_cutoff": 512}}"#,
        )
        .unwrap();
        let c = load_calibration(path).unwrap();
        assert_eq!(
            c,
            CostCalibration { build_sweeps: 8.0, scatter_penalty: 2.0, parallel_cutoff: 512 }
        );
        let _ = std::fs::remove_file(path);
        assert!(load_calibration("/nonexistent/trunksvd_calib.json").is_none());
    }

    #[test]
    fn parse_fuse_accepts_known_spellings() {
        assert_eq!(parse_fuse("auto"), Some(FusePolicy::Auto));
        assert_eq!(parse_fuse(" AUTO "), Some(FusePolicy::Auto));
        assert_eq!(parse_fuse("on"), Some(FusePolicy::On));
        assert_eq!(parse_fuse("1"), Some(FusePolicy::On));
        assert_eq!(parse_fuse("true"), Some(FusePolicy::On));
        assert_eq!(parse_fuse("off"), Some(FusePolicy::Off));
        assert_eq!(parse_fuse("0"), Some(FusePolicy::Off));
        assert_eq!(parse_fuse("False"), Some(FusePolicy::Off));
        assert_eq!(parse_fuse(""), None);
        assert_eq!(parse_fuse("yes"), None);
        assert_eq!(parse_fuse("2"), None);
    }

    #[test]
    fn should_fuse_auto_crosses_at_llc_and_disk() {
        use FusePolicy::*;
        // Monotone in operand bytes: once fused, bigger stays fused.
        let mut prev = false;
        for bytes in [0, 1, FUSE_LLC_BYTES, FUSE_LLC_BYTES + 1, usize::MAX] {
            let f = should_fuse_with(Auto, bytes, false);
            assert!(f >= prev, "auto fusion not monotone at {bytes}");
            prev = f;
        }
        // LLC crossover is exactly "strictly larger than the cache".
        assert!(!should_fuse_with(Auto, FUSE_LLC_BYTES, false));
        assert!(should_fuse_with(Auto, FUSE_LLC_BYTES + 1, false));
        // Disk tier always fuses, even for tiny shards.
        assert!(should_fuse_with(Auto, 0, true));
        // Forced policies ignore both signals.
        assert!(should_fuse_with(On, 0, false));
        assert!(!should_fuse_with(Off, usize::MAX, true));
        // Degenerate clamp: empty operand in core never fuses under Auto.
        assert!(!should_fuse_with(Auto, 0, false));
    }

    #[test]
    fn default_fuse_policy_without_env_is_auto() {
        if std::env::var("TRUNKSVD_FUSE").is_err() {
            assert_eq!(fuse_policy(), FusePolicy::Auto);
            assert!(!should_fuse(1024, false));
            assert!(should_fuse(1024, true));
        }
    }

    #[test]
    fn default_calibration_active_without_env() {
        // The test binary never sets TRUNKSVD_COST_CALIB, so the resolved
        // calibration must be the built-in defaults (this also pins the
        // parallel_cutoff() == 1024 behaviour the pool tests assume).
        if std::env::var("TRUNKSVD_COST_CALIB").is_err() {
            assert_eq!(calibration(), CostCalibration::DEFAULT);
            assert_eq!(parallel_cutoff(), 1024);
        }
    }
}
