//! Calibrated device-time model (the "simulated A100" of DESIGN.md §3).
//!
//! The paper's headline Fig. 2 result — LancSVD beating RandSVD by
//! 1.2×–2.5× at matched accuracy — is driven by the A100's *kernel-rate
//! asymmetry*: dense orthogonalization GEMMs run near fp64 peak
//! (~10 TFLOP/s) while cuSPARSE's transposed SpMM crawls at tens of
//! GFLOP/s. A scalar CPU substrate has no such asymmetry (every kernel
//! runs at a few GFLOP/s), so wall-clock alone cannot reproduce the
//! paper's *who-wins* shape. Per the substitution rule we therefore also
//! report **model time**: measured per-block flop counts and call counts
//! priced with per-block rates calibrated to the paper's platform.
//!
//! Rates are deliberately coarse (one significant digit); the claims we
//! check are ordinal (who wins, crossovers), not absolute.

use crate::metrics::{Block, Profile};

/// Per-block execution rates + a per-kernel-launch latency.
#[derive(Clone, Copy, Debug)]
pub struct DeviceModel {
    pub name: &'static str,
    /// dense GEMM / orthogonalization rate (flops/s)
    pub gemm_rate: f64,
    /// SpMM with A (gather CSR) rate
    pub spmm_rate: f64,
    /// SpMM with Aᵀ (implicit transpose / scatter) rate — the paper's
    /// slow kernel
    pub spmm_t_rate: f64,
    /// host small-factorization rate (POTRF/GESVD on the CPU)
    pub host_rate: f64,
    /// per-kernel-launch + transfer latency (s) — GPU only
    pub launch_latency: f64,
    /// disk-tier streaming bandwidth (bytes/s) for out-of-core shard
    /// loads (NVMe-class sequential reads)
    pub disk_bw: f64,
}

impl DeviceModel {
    /// NVIDIA A100 (paper platform): fp64 ~9.7 TF GEMM; cuSPARSE SpMM
    /// ~60 GF gather, ~15 GF scattered/transposed (consistent with the
    /// paper's Figs. 2–3 time-vs-flop discrepancy); ~10 µs launches. The
    /// host factorizations run on the paper's 16-core EPYC 7282 + MKL
    /// (multi-threaded dgesvd/dpotrf ≈ 20 GF/s effective).
    pub fn a100() -> DeviceModel {
        DeviceModel {
            name: "sim-A100",
            gemm_rate: 9.7e12,
            spmm_rate: 6.0e10,
            spmm_t_rate: 1.5e10,
            host_rate: 2.0e10,
            launch_latency: 1.0e-5,
            disk_bw: 2.0e9,
        }
    }

    /// The current testbed (1-core scalar CPU) — used by tests to verify
    /// the model ranks kernels like the measured wall clock does.
    pub fn cpu_1core() -> DeviceModel {
        DeviceModel {
            name: "cpu-1core",
            gemm_rate: 3.0e9,
            spmm_rate: 1.5e9,
            spmm_t_rate: 1.0e9,
            host_rate: 2.0e9,
            launch_latency: 0.0,
            disk_bw: 5.0e8,
        }
    }

    /// Disk-tier shard sizing: pick the row-band shard size for the
    /// out-of-core pipeline (`sparse::shard`) from the model. Load and
    /// compute are both linear in shard bytes, so rate matching drops
    /// out; what remains is
    ///
    /// * a **latency floor** — each shard must stream long enough to
    ///   amortize the per-request latency (seek + syscall / async-copy
    ///   launch, modeled by `launch_latency`): ≥ 20 latencies' worth of
    ///   `disk_bw` streaming, and
    /// * a **cap ceiling** — two streaming slots plus pinned slack must
    ///   fit the resident cap: ≤ cap/4 (so ≥ half the cap stays for the
    ///   pinned prefix). Without a cap, target a ~16-deep pipeline so
    ///   the prefetch slot always has a next shard to hide.
    pub fn shard_bytes(&self, total_bytes: usize, resident_cap: usize) -> usize {
        let floor = (20.0 * self.launch_latency * self.disk_bw).max(1.0) as usize;
        let mut bytes = floor.max(total_bytes.div_ceil(16)).max(1);
        if resident_cap > 0 {
            bytes = bytes.min((resident_cap / 4).max(1));
        }
        bytes.min(total_bytes.max(1))
    }

    /// Number of row-band shards [`DeviceModel::shard_bytes`] implies
    /// for an operand of `total_bytes`.
    pub fn shard_count(&self, total_bytes: usize, resident_cap: usize) -> usize {
        total_bytes.div_ceil(self.shard_bytes(total_bytes, resident_cap)).max(1)
    }

    fn rate(&self, b: Block, sparse: bool) -> f64 {
        match b {
            Block::MultA => {
                if sparse {
                    self.spmm_rate
                } else {
                    self.gemm_rate
                }
            }
            Block::MultAt => {
                if sparse {
                    self.spmm_t_rate
                } else {
                    self.gemm_rate
                }
            }
            Block::OrthM | Block::OrthN | Block::Finalize | Block::Init => self.gemm_rate,
            Block::SmallSvd | Block::Other => self.host_rate,
        }
    }

    /// Price a measured profile on this device: Σ flops/rate + launches.
    pub fn sim_time(&self, prof: &Profile, sparse: bool) -> f64 {
        let mut t = 0.0;
        for b in Block::ALL {
            let s = prof.stat(b);
            t += s.flops / self.rate(b, sparse);
            t += s.calls as f64 * self.launch_latency;
        }
        t
    }

    /// Price an analytic cost breakdown (Fig. 3 companion).
    pub fn sim_time_breakdown(&self, c: &crate::cost::CostBreakdown, sparse: bool) -> f64 {
        c.mult_a / self.rate(Block::MultA, sparse)
            + c.mult_at / self.rate(Block::MultAt, sparse)
            + (c.orth_m + c.orth_n + c.finalize) / self.gemm_rate
            + c.small_svd / self.host_rate
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cost::{lancsvd_cost, randsvd_cost, Problem};

    #[test]
    fn a100_model_reproduces_paper_ordering() {
        // A representative paper-scale sparse problem: on the A100 model
        // LancSVD(256,2) must beat RandSVD(16,96) — the 96 transposed
        // SpMMs dominate — even though RandSVD does fewer flops.
        let prob = Problem { m: 345_688, n: 12_347, nnz: Some(821_839) }; // rel8
        let dm = DeviceModel::a100();
        let lanc = lancsvd_cost(prob, 256, 2, 16);
        let rand = randsvd_cost(prob, 16, 96, 16);
        assert!(rand.total() < lanc.total(), "rand fewer flops (Fig. 3)");
        let t_lanc = dm.sim_time_breakdown(&lanc, true);
        let t_rand = dm.sim_time_breakdown(&rand, true);
        let speedup = t_rand / t_lanc;
        assert!(
            speedup > 1.2 && speedup < 6.0,
            "sim-A100 speedup {speedup:.2} out of the paper-shaped range"
        );
    }

    #[test]
    fn dense_problems_have_no_spmm_penalty() {
        // Dense: both algorithms run GEMMs; the gap narrows to the
        // iteration-count ratio (paper Fig. 4 bottom).
        let prob = Problem { m: 250_000, n: 10_000, nnz: None };
        let dm = DeviceModel::a100();
        let lanc = dm.sim_time_breakdown(&lancsvd_cost(prob, 64, 4, 16), false);
        let rand = dm.sim_time_breakdown(&randsvd_cost(prob, 16, 24, 16), false);
        let speedup = rand / lanc;
        assert!(speedup > 0.8 && speedup < 4.0, "dense speedup {speedup:.2}");
    }

    #[test]
    fn shard_sizing_respects_latency_floor_and_cap() {
        let dm = DeviceModel::a100();
        // Latency floor: 20 × 10 µs × 2 GB/s = 400 KB per shard minimum.
        let floor = (20.0 * dm.launch_latency * dm.disk_bw) as usize;
        assert_eq!(floor, 400_000);
        // Uncapped: a 1 GB operand targets the 16-deep pipeline.
        let total = 1usize << 30;
        assert_eq!(dm.shard_count(total, 0), 16);
        assert!(dm.shard_bytes(total, 0) >= floor);
        // A tight cap shrinks shards (cap/4) and multiplies their count.
        let cap = 16 << 20; // 16 MB resident
        assert_eq!(dm.shard_bytes(total, cap), cap / 4);
        assert!(dm.shard_count(total, cap) > dm.shard_count(total, 0));
        // Tiny operands never split below one shard of everything.
        assert_eq!(dm.shard_count(1000, 0), 1);
        assert_eq!(dm.shard_bytes(1000, 0), 1000);
        // Zero-latency testbed model: floor degenerates, cap still binds.
        let cm = DeviceModel::cpu_1core();
        assert_eq!(cm.shard_bytes(total, cap), cap / 4);
    }

    #[test]
    fn some_suite_matrices_favor_randsvd() {
        // Paper: LancSVD loses on ~7/46 — typically when nnz is small
        // relative to the dimensions (orthogonalization dominates).
        let dm = DeviceModel::a100();
        let sparse_lo = Problem { m: 64_719, n: 1_785_345, nnz: Some(652_140) }; // Delor64K
        let lanc = dm.sim_time_breakdown(&lancsvd_cost(sparse_lo, 256, 2, 16), true);
        let rand = dm.sim_time_breakdown(&randsvd_cost(sparse_lo, 16, 96, 16), true);
        assert!(rand / lanc < 1.6, "low-nnz case should be close or rand-favored: {:.2}", rand / lanc);
    }
}
