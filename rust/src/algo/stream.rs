//! Single-pass randomized sketch SVD for one-shot column streams
//! (Halko–Martinsson–Tropp §5.5).
//!
//! Where [`crate::algo::incremental::IncrementalSvd`] maintains an
//! exact-rotation basis per block — O(m·r) work per arriving column —
//! [`StreamSketch`] only *accumulates two sketches* as blocks arrive and
//! never revisits the data:
//!
//! * Y += C·Ω_C   (m×r; Ω rows are drawn per **global column index**,
//!   so the accumulated Y equals A·Ω regardless of how the stream is
//!   blocked)
//! * Wᵀ[:, seen..seen+c] = ΨᵀC   (l×n; the co-sketch of Aᵀ)
//!
//! [`StreamSketch::finalize`] then recovers the factorization without
//! the data: Q = orth(Y), and B solves the small least-squares system
//! (ΨᵀQ)·B ≈ Wᵀ — so A ≈ Q·B — via normal equations + Cholesky. The
//! final SVD of Bᵀ (n×l, host Jacobi) yields A ≈ (Q·Û)·Σ·V̂ᵀ.
//!
//! This is the right tool when each block can only be touched once
//! (data too large to store, or arriving over a wire); the incremental
//! path is more accurate when blocks can be revisited within the
//! update. Accuracy follows HMT Thm. 5.x sketch bounds: near-exact for
//! streams of numerical rank ≤ r, additive O(σ_{r+1}) otherwise.

use crate::backend::Backend;
use crate::error::{Error, Result};
use crate::la::chol::potrf;
use crate::la::mat::{Mat, MatRef};
use crate::la::svd::jacobi_svd;
use crate::metrics::{Block, Profile};
use crate::util::rng::Rng;
use crate::util::scalar::Scalar;

use super::orth::cholqr2;
use super::TruncatedSvd;

/// Fixed per-column RNG stream offset (splitmix64 increment): column
/// j's Ω row is drawn from `Rng::new(seed ⊕ GOLDEN·(j+1))`, which makes
/// the accumulated Y = A·Ω independent of how the stream was blocked.
const GOLDEN: u64 = 0x9E37_79B9_7F4A_7C15;

/// Single-pass randomized sketch of a column stream (HMT §5.5):
/// absorb blocks once, finalize without the data.
pub struct StreamSketch<S: Scalar = f64> {
    rows: usize,
    cols_max: usize,
    r: usize,
    l: usize,
    seed: u64,
    cols_seen: usize,
    /// right-sketch accumulator Y = A·Ω (m×r)
    y: Mat<S>,
    /// left test matrix Ψ (m×l), fixed at construction
    psi: Mat<S>,
    /// co-sketch Wᵀ = ΨᵀA (l×cols_max; live panel l×cols_seen)
    wt: Mat<S>,
    /// per-block Ω rows (block_cap-free: sized per call via view; this
    /// is the one growing scratch, capacity cols_max×r)
    omega: Mat<S>,
    /// per-block Y increment scratch (m×r)
    yinc: Mat<S>,
}

impl<S: Scalar> StreamSketch<S> {
    /// New sketch for `rows`-row streams of up to `cols_max` columns:
    /// target rank `r`, left-sketch oversampling `oversample` ≥ 1
    /// (l = r + oversample; HMT recommend l ≈ 2r for one-pass).
    pub fn new(rows: usize, cols_max: usize, r: usize, oversample: usize, seed: u64) -> Self {
        assert!(r >= 1 && r <= rows, "sketch rank {r} outside 1..={rows}");
        assert!(oversample >= 1, "one-pass sketch needs oversample >= 1");
        let l = r + oversample;
        let mut rng = Rng::new(seed ^ GOLDEN);
        let mut psi = Mat::zeros(rows, l);
        rng.fill_normal(psi.data_mut());
        StreamSketch {
            rows,
            cols_max,
            r,
            l,
            seed,
            cols_seen: 0,
            y: Mat::zeros(rows, r),
            psi,
            wt: Mat::zeros(l, cols_max),
            omega: Mat::zeros(cols_max.max(1), r),
            yinc: Mat::zeros(rows, r),
        }
    }

    pub fn rank(&self) -> usize {
        self.r
    }
    pub fn cols_seen(&self) -> usize {
        self.cols_seen
    }

    /// Absorb one arriving block C (m×c). Each column is touched once:
    /// one GEMM against its Ω rows into the Y accumulator, one
    /// projection ΨᵀC into the co-sketch panel. Partition-invariant —
    /// the sketches after absorbing [C₁ C₂] equal those after
    /// absorbing the concatenated block.
    pub fn absorb_block<B: Backend<S> + ?Sized>(&mut self, be: &mut B, c: MatRef<'_, S>) {
        assert_eq!(c.rows, self.rows, "stream block rows");
        let cc = c.cols;
        assert!(cc >= 1, "empty block");
        assert!(
            self.cols_seen + cc <= self.cols_max,
            "stream exceeds the planned capacity ({} + {cc} > {})",
            self.cols_seen,
            self.cols_max
        );
        be.profile_mut().set_phase(Block::Other);
        // Ω rows for these columns, keyed by global column index.
        let mut omega = self.omega.view_mut(cc, self.r);
        for i in 0..cc {
            let j = self.cols_seen + i;
            let mut rng = Rng::new(self.seed ^ GOLDEN.wrapping_mul(j as u64 + 1));
            for q in 0..self.r {
                omega.set(i, q, S::from_f64(rng.normal()));
            }
        }
        // Y += C·Ω_C
        let mut yinc = self.yinc.as_mut();
        be.gemm_nn_into(c, omega.as_ref(), yinc.reborrow());
        for (y, d) in self.y.data_mut().iter_mut().zip(yinc.as_ref().data) {
            *y += *d;
        }
        // Wᵀ co-sketch columns for this block: ΨᵀC.
        be.proj_into(self.psi.as_ref(), c, self.wt.panel_mut(self.cols_seen, cc));
        self.cols_seen += cc;
    }

    /// Recover A ≈ U·Σ·Vᵀ from the sketches alone (the data is gone):
    /// Q = orth(Y); solve (ΨᵀQ)·B ≈ Wᵀ by normal equations; SVD of Bᵀ.
    pub fn finalize<B: Backend<S> + ?Sized>(&self, be: &mut B) -> Result<TruncatedSvd<S>> {
        let (r, n) = (self.r, self.cols_seen);
        if n == 0 {
            return Err(Error::InvalidParam("stream sketch: no columns absorbed".into()));
        }
        be.profile_mut().set_phase(Block::Other);
        // Q = orth(Y) (CholeskyQR2 + fallback, m×r).
        let mut q = self.y.clone();
        cholqr2(be, &mut q)?;
        // M = ΨᵀQ (l×r) and the normal equations G·B = Mᵀ·Wᵀ with
        // G = MᵀM (r×r, SPD for any genuinely oversampled sketch).
        let mq = be.proj(self.psi.as_ref(), q.as_ref());
        let mut g = Mat::zeros(r, r);
        be.gram_into(mq.as_ref(), g.as_mut());
        let mut b = Mat::zeros(r, n);
        be.proj_into(mq.as_ref(), self.wt.panel(0, n), b.as_mut());
        let lchol = potrf(&g)?;
        chol_solve_in_place(&lchol, &mut b);
        // SVD of Bᵀ (n×r, satisfies the Jacobi m ≥ n shape):
        // Bᵀ = V̂·Σ·Ûᵀ, so A ≈ Q·B = (Q·Û)·Σ·V̂ᵀ.
        let bt = b.transpose();
        let svd = jacobi_svd(&bt)?;
        let u = be.gemm_nn(q.as_ref(), svd.v.as_ref());
        Ok(TruncatedSvd {
            u,
            sigma: svd.s,
            v: svd.u,
            profile: Profile::new(),
            iters: 1,
            est_residuals: Vec::new(),
        })
    }
}

/// Solve (L·Lᵀ)·X = B in place, column by column (forward + backward
/// substitution against the lower Cholesky factor; factor-sized, host).
fn chol_solve_in_place<S: Scalar>(l: &Mat<S>, x: &mut Mat<S>) {
    let n = l.rows();
    assert_eq!(x.rows(), n, "chol_solve shape");
    for j in 0..x.cols() {
        let col = x.col_mut(j);
        for i in 0..n {
            let mut s = col[i];
            for t in 0..i {
                s -= l.at(i, t) * col[t];
            }
            col[i] = s / l.at(i, i);
        }
        for i in (0..n).rev() {
            let mut s = col[i];
            for t in (i + 1)..n {
                s -= l.at(t, i) * col[t];
            }
            col[i] = s / l.at(i, i);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::cpu::CpuBackend;
    use crate::la::blas3::mat_nn;
    use crate::la::norms::orth_error;
    use crate::la::qr::random_orthonormal;
    use crate::util::rng::Rng;

    fn dummy_backend() -> CpuBackend {
        CpuBackend::new_dense(Mat::zeros(1, 1))
    }

    fn low_rank(m: usize, n: usize, rank: usize, seed: u64) -> Mat {
        let mut rng = Rng::new(seed);
        let u = random_orthonormal(m, rank, &mut rng);
        let w = Mat::randn(rank, n, &mut rng);
        mat_nn(&u, &w)
    }

    #[test]
    fn one_pass_recovers_low_rank_stream() {
        let a = low_rank(48, 30, 5, 11);
        let mut sk = StreamSketch::new(48, 30, 8, 6, 42);
        let mut be = dummy_backend();
        for j0 in (0..30).step_by(6) {
            sk.absorb_block(&mut be, a.panel(j0, 6));
        }
        let svd = sk.finalize(&mut be).unwrap();
        let mut us = svd.u.clone();
        for j in 0..svd.sigma.len() {
            for x in us.col_mut(j) {
                *x *= svd.sigma[j];
            }
        }
        let back = mat_nn(&us, &svd.v.transpose());
        assert!(
            back.max_abs_diff(&a) / a.fro_norm() < 1e-8,
            "one-pass reconstruction {}",
            back.max_abs_diff(&a)
        );
        assert!(orth_error(&svd.u) < 1e-8);
    }

    #[test]
    fn sketch_is_partition_invariant() {
        let a = low_rank(40, 24, 4, 7);
        let mut be = dummy_backend();
        let mut one = StreamSketch::new(40, 24, 6, 4, 9);
        one.absorb_block(&mut be, a.as_ref());
        let mut many = StreamSketch::new(40, 24, 6, 4, 9);
        for j0 in 0..24 {
            many.absorb_block(&mut be, a.panel(j0, 1));
        }
        let sa = one.finalize(&mut be).unwrap();
        let sb = many.finalize(&mut be).unwrap();
        // Mathematically identical sketches; summation order differs
        // per blocking, so compare to rounding accuracy, not bitwise.
        for (x, y) in sa.sigma.iter().zip(&sb.sigma).take(4) {
            assert!(
                (x - y).abs() <= 1e-10 * sa.sigma[0].max(1e-300),
                "blocking changed the sketch: {x} vs {y}"
            );
        }
    }

    #[test]
    fn finalize_without_columns_errors() {
        let sk: StreamSketch = StreamSketch::new(10, 10, 2, 2, 1);
        assert!(sk.finalize(&mut dummy_backend()).is_err());
    }
}
