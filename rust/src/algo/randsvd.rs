//! RandSVD: truncated SVD via randomized subspace iteration (Algorithm 1).
//!
//! The Halko–Martinsson–Tropp randomized method with p−1 subspace
//! (power) iterations. Each iteration multiplies the sketch by A and Aᵀ
//! and re-orthonormalizes both tall-and-skinny factors with CGS-QR
//! (Alg. 3); after the loop an r×r SVD of the last triangular factor
//! yields the truncated decomposition (Eqs. 4–6 of the paper).

use crate::backend::Backend;
use crate::error::{Error, Result};
use crate::la::mat::Mat;
use crate::la::svd::jacobi_svd;
use crate::metrics::{Block, Timer};
use crate::util::rng::Rng;
use crate::util::scalar::Scalar;

use super::cgs_qr::cgs_qr;
use super::{InitDist, RandSvdOpts, TruncatedSvd};

/// Run RandSVD on the backend's operand matrix (any [`Scalar`]
/// precision; the paper's GPU regime is `S = f32`).
pub fn randsvd<S: Scalar, B: Backend<S> + ?Sized>(
    be: &mut B,
    opts: &RandSvdOpts,
) -> Result<TruncatedSvd<S>> {
    let (m, n) = (be.m(), be.n());
    let RandSvdOpts { r, p, b, seed, init } = *opts;
    if r == 0 || r > n.min(m) {
        return Err(Error::InvalidParam(format!("r={r} out of range for {m}x{n}")));
    }
    if p == 0 {
        return Err(Error::InvalidParam("p must be >= 1".into()));
    }
    if b == 0 {
        return Err(Error::InvalidParam("b must be >= 1".into()));
    }

    // Initial random sketch Q0 ∈ R^{n×r}.
    be.profile_mut().set_phase(Block::Init);
    let t = Timer::start(0.0);
    let mut rng = Rng::new(seed);
    let mut q = match init {
        InitDist::CenteredPoisson => Mat::rand_centered_poisson(n, r, &mut rng),
        InitDist::Normal => Mat::randn(n, r, &mut rng),
    };
    t.stop(be.profile_mut());

    let mut qbar = Mat::zeros(m, r);
    let mut r_last = Mat::zeros(r, r);
    for _j in 1..=p {
        // S1: Ȳ = A·Q
        be.profile_mut().set_phase(Block::MultA);
        qbar = be.apply_a(q.as_ref());
        // S2: Ȳ = Q̄·R̄ (orthogonalization in the m dimension)
        be.profile_mut().set_phase(Block::OrthM);
        let _rbar = cgs_qr(be, &mut qbar, b)?;
        // S3: Y = Aᵀ·Q̄
        be.profile_mut().set_phase(Block::MultAt);
        q = be.apply_at(qbar.as_ref());
        // S4: Y = Q·R (orthogonalization in the n dimension)
        be.profile_mut().set_phase(Block::OrthN);
        r_last = cgs_qr(be, &mut q, b)?;
    }

    // S5: SVD of the small r×r factor on the host.
    be.profile_mut().set_phase(Block::SmallSvd);
    let t = Timer::start(9.0 * (r * r * r) as f64); // O(r³) bookkeeping
    let svd = jacobi_svd(&r_last)?;
    t.stop(be.profile_mut());

    // S6/S7: U_T = Q̄·V̄, V_T = Q·Ū.
    // From AᵀQ̄ = QR: A ≈ Q̄·Rᵀ·Qᵀ = Q̄·(V̄ΣŪᵀ)·Qᵀ = (Q̄V̄)·Σ·(QŪ)ᵀ.
    be.profile_mut().set_phase(Block::Finalize);
    let u_t = be.gemm_nn(qbar.as_ref(), svd.v.as_ref());
    let v_t = be.gemm_nn(q.as_ref(), svd.u.as_ref());

    Ok(TruncatedSvd {
        u: u_t,
        sigma: svd.s,
        v: v_t,
        profile: be.take_profile(),
        iters: p,
        est_residuals: Vec::new(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::residuals;
    use crate::backend::cpu::CpuBackend;
    use crate::gen::dense::{dense_with_spectrum, paper_dense};
    use crate::la::norms::orth_error;

    #[test]
    fn recovers_well_separated_spectrum() {
        let sigma: Vec<f64> = (0..8).map(|i| 4.0f64.powi(-(i as i32))).collect();
        let prob = dense_with_spectrum(60, 8, &sigma, 1);
        let mut be = CpuBackend::new_dense(prob.a.clone());
        let opts = RandSvdOpts { r: 8, p: 8, b: 4, ..Default::default() };
        let svd = randsvd(&mut be, &opts).unwrap();
        for i in 0..4 {
            assert!(
                (svd.sigma[i] - sigma[i]).abs() / sigma[i] < 1e-8,
                "sigma_{i}: {} vs {}",
                svd.sigma[i],
                sigma[i]
            );
        }
        assert!(orth_error(&svd.u) < 1e-10);
        assert!(orth_error(&svd.v) < 1e-10);
        let mut be2 = CpuBackend::new_dense(prob.a);
        let res = residuals(&mut be2, &svd, 4);
        assert!(res.iter().all(|&x| x < 1e-8), "residuals {res:?}");
    }

    #[test]
    fn more_power_iterations_improve_accuracy() {
        // Paper Fig. 1/4 phenomenon: p=1 is poor unless the spectrum is
        // well separated; accuracy improves monotonically-ish with p.
        let prob = paper_dense(120, 40, 2);
        let a = prob.a.clone();
        let res_at = |p: usize| {
            let mut be = CpuBackend::new_dense(a.clone());
            let opts = RandSvdOpts { r: 8, p, b: 8, seed: 7, ..Default::default() };
            let svd = randsvd(&mut be, &opts).unwrap();
            let mut be2 = CpuBackend::new_dense(a.clone());
            residuals(&mut be2, &svd, 4).iter().fold(0.0f64, |m, &x| m.max(x))
        };
        let r1 = res_at(1);
        let r8 = res_at(8);
        assert!(r8 < r1 * 0.5, "p=1 {r1:.3e} vs p=8 {r8:.3e}");
        assert!(r8 < 1e-4, "p=8 {r8:.3e}");
    }

    #[test]
    fn works_on_sparse_operand() {
        use crate::gen::sparse::{generate, SparseSpec};
        let spec = SparseSpec { rows: 150, cols: 80, nnz: 1600, seed: 5, ..Default::default() };
        let a = generate(&spec);
        let mut be = CpuBackend::new_sparse(a.clone());
        let opts = RandSvdOpts { r: 12, p: 20, b: 4, seed: 3, ..Default::default() };
        let svd = randsvd(&mut be, &opts).unwrap();
        let mut be2 = CpuBackend::new_sparse(a);
        let res = residuals(&mut be2, &svd, 6);
        assert!(res.iter().all(|&x| x < 1e-6), "residuals {res:?}");
        // profile covered the four phases
        assert!(svd.profile.stat(Block::MultA).calls >= 20);
        assert!(svd.profile.stat(Block::OrthN).secs >= 0.0);
    }

    #[test]
    fn rejects_bad_params() {
        let prob = paper_dense(30, 10, 3);
        let mut be = CpuBackend::new_dense(prob.a);
        assert!(randsvd(&mut be, &RandSvdOpts { r: 0, ..Default::default() }).is_err());
        assert!(randsvd(&mut be, &RandSvdOpts { r: 100, ..Default::default() }).is_err());
        assert!(randsvd(&mut be, &RandSvdOpts { r: 4, p: 0, ..Default::default() }).is_err());
        assert!(randsvd(&mut be, &RandSvdOpts { r: 4, p: 1, b: 0, ..Default::default() }).is_err());
    }

    #[test]
    fn r_not_multiple_of_b() {
        let prob = paper_dense(50, 20, 9);
        let mut be = CpuBackend::new_dense(prob.a.clone());
        let opts = RandSvdOpts { r: 10, p: 6, b: 4, seed: 2, ..Default::default() };
        let svd = randsvd(&mut be, &opts).unwrap();
        assert_eq!(svd.u.cols(), 10);
        let mut be2 = CpuBackend::new_dense(prob.a);
        let res = residuals(&mut be2, &svd, 3);
        assert!(res.iter().all(|&x| x < 1e-5), "residuals {res:?}");
    }
}
