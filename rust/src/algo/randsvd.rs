//! RandSVD: truncated SVD via randomized subspace iteration (Algorithm 1).
//!
//! The Halko–Martinsson–Tropp randomized method with p−1 subspace
//! (power) iterations. Each iteration multiplies the sketch by A and Aᵀ
//! and re-orthonormalizes both tall-and-skinny factors with CGS-QR
//! (Alg. 3); after the loop an r×r SVD of the last triangular factor
//! yields the truncated decomposition (Eqs. 4–6 of the paper).
//!
//! ## Allocation-free steady state
//!
//! [`randsvd`] computes a [`Plan`] from `(m, n, r, p, b)`, allocates a
//! [`Workspace`] (banded first-touch through the worker pool), hands
//! the plan to the backend, and runs [`randsvd_with`]. The two sketches
//! and the triangular factor live in planned buffers; `apply_a_into` /
//! `apply_at_into` write one sketch from the other, and `cgs_qr_into`
//! orthonormalizes in place — each power iteration performs zero heap
//! allocations on the CPU backend (pinned by `tests/test_workspace.rs`,
//! which asserts the total allocation count of a solve is *independent
//! of p*). Callers with many solves of one shape pass their own
//! workspace to [`randsvd_with`] and pay setup once.

use crate::backend::Backend;
use crate::error::{Error, Result};
use crate::la::svd::jacobi_svd_into;
use crate::la::workspace::{names, Plan, PlanKind, Workspace};
use crate::metrics::{Block, Timer};
use crate::util::rng::Rng;
use crate::util::scalar::Scalar;

use super::cgs_qr::cgs_qr_into;
use super::{InitDist, RandSvdOpts, TruncatedSvd};

fn check_opts(m: usize, n: usize, opts: &RandSvdOpts) -> Result<()> {
    let RandSvdOpts { r, p, b, .. } = *opts;
    if r == 0 || r > n.min(m) {
        return Err(Error::InvalidParam(format!("r={r} out of range for {m}x{n}")));
    }
    if p == 0 {
        return Err(Error::InvalidParam("p must be >= 1".into()));
    }
    if b == 0 {
        return Err(Error::InvalidParam("b must be >= 1".into()));
    }
    Ok(())
}

/// Run RandSVD on the backend's operand matrix (any [`Scalar`]
/// precision; the paper's GPU regime is `S = f32`). Plans and allocates
/// a fresh workspace; see [`randsvd_with`] to reuse one across solves.
pub fn randsvd<S: Scalar, B: Backend<S> + ?Sized>(
    be: &mut B,
    opts: &RandSvdOpts,
) -> Result<TruncatedSvd<S>> {
    let (m, n) = (be.m(), be.n());
    check_opts(m, n, opts)?;
    let ws = Workspace::new(Plan::randsvd(m, n, opts.r, opts.p, opts.b));
    randsvd_with(be, opts, &ws)
}

/// [`randsvd`] over a caller-provided workspace (must have been
/// allocated from a matching [`Plan::randsvd`]).
pub fn randsvd_with<S: Scalar, B: Backend<S> + ?Sized>(
    be: &mut B,
    opts: &RandSvdOpts,
    ws: &Workspace<S>,
) -> Result<TruncatedSvd<S>> {
    let (m, n) = (be.m(), be.n());
    let RandSvdOpts { r, p, b, seed, init, fuse } = *opts;
    check_opts(m, n, opts)?;
    ws.plan().require(PlanKind::RandSvd, m, n, r, b)?;
    be.plan(ws.plan());
    // Fusion policy: explicit opt-in/out via opts, else the cost model
    // (operand larger than LLC, or streamed from disk).
    let fuse = fuse
        .unwrap_or_else(|| crate::cost::should_fuse(be.operand_bytes(), be.operand_on_disk()));

    let mut q = ws.mat(names::RAND_Q, n, r);
    let mut z = ws.mat(names::RAND_Z, n, r);
    let mut qbar = ws.mat(names::RAND_QBAR, m, r);
    let mut r_last = ws.mat(names::RAND_R, r, r);
    let mut svd_u = ws.mat(names::SVD_U, r, r);
    let mut svd_v = ws.mat(names::SVD_V, r, r);

    // Initial random sketch Q0 ∈ R^{n×r}, drawn straight into the
    // planned buffer and declared to the backend (`stage_in` uploads it
    // on device targets) while still inside the setup phase — the first
    // hot-loop A·Q must find the sketch device-resident.
    be.profile_mut().set_phase(Block::Init);
    let t = Timer::start(0.0);
    let mut rng = Rng::new(seed);
    match init {
        InitDist::CenteredPoisson => rng.fill_centered_poisson(q.data_mut()),
        InitDist::Normal => rng.fill_normal(q.data_mut()),
    }
    be.stage_in(q.as_ref());
    t.stop(be.profile_mut());

    for j in 1..=p {
        // Power-iteration boundary: same cooperative safepoint as the
        // LancSVD restart loop (no-op without a hook — `runtime::serve`).
        if j > 1 {
            crate::util::pool::restart_yield();
        }
        // Fused power step (all but the last iteration): Z = Aᵀ(A·Q) in
        // ONE sweep over the operand's nonzeros — each CSR band (and,
        // out-of-core, each disk shard) is read exactly once instead of
        // twice. The intermediate Ȳ = A·Q lands in `qbar` unorthonormal-
        // ized; only the n-side sketch is re-orthonormalized per fused
        // step (κ(A)² conditioning per step, standard for subspace
        // iteration without intermediate QR). The LAST iteration always
        // runs the unfused S1–S4 path so the finalize identity
        // AᵀQ̄ = QR holds with an orthonormal Q̄. Total operand passes:
        // p+1 fused vs 2p unfused.
        if fuse && j < p {
            be.profile_mut().set_phase(Block::MultA);
            be.apply_ata_into(q.as_ref(), qbar.as_mut(), z.as_mut());
            be.profile_mut().set_phase(Block::OrthN);
            cgs_qr_into(be, z.as_mut(), r_last.as_mut(), b, ws)?;
            std::mem::swap(&mut *q, &mut *z);
            continue;
        }
        // S1: Ȳ = A·Q
        be.profile_mut().set_phase(Block::MultA);
        be.apply_a_into(q.as_ref(), qbar.as_mut());
        // S2: Ȳ = Q̄·R̄ (orthogonalization in the m dimension; R̄ is
        // discarded — the buffer is overwritten by S4's factor).
        be.profile_mut().set_phase(Block::OrthM);
        cgs_qr_into(be, qbar.as_mut(), r_last.as_mut(), b, ws)?;
        // S3: Y = Aᵀ·Q̄
        be.profile_mut().set_phase(Block::MultAt);
        be.apply_at_into(qbar.as_ref(), q.as_mut());
        // S4: Y = Q·R (orthogonalization in the n dimension)
        be.profile_mut().set_phase(Block::OrthN);
        cgs_qr_into(be, q.as_mut(), r_last.as_mut(), b, ws)?;
    }

    // S5: SVD of the small r×r factor on the host, into planned buffers.
    be.profile_mut().set_phase(Block::SmallSvd);
    let t = Timer::start(9.0 * (r * r * r) as f64); // O(r³) bookkeeping
    let mut sigma: Vec<S> = Vec::with_capacity(r);
    jacobi_svd_into(r_last.as_ref(), svd_u.as_mut(), &mut sigma, svd_v.as_mut())?;
    t.stop(be.profile_mut());

    // S6/S7: U_T = Q̄·V̄, V_T = Q·Ū.
    // From AᵀQ̄ = QR: A ≈ Q̄·Rᵀ·Qᵀ = Q̄·(V̄ΣŪᵀ)·Qᵀ = (Q̄V̄)·Σ·(QŪ)ᵀ.
    be.profile_mut().set_phase(Block::Finalize);
    let mut u_t = crate::la::mat::Mat::zeros(m, r);
    be.gemm_nn_into(qbar.as_ref(), svd_v.as_ref(), u_t.as_mut());
    let mut v_t = crate::la::mat::Mat::zeros(n, r);
    be.gemm_nn_into(q.as_ref(), svd_u.as_ref(), v_t.as_mut());

    Ok(TruncatedSvd {
        u: u_t,
        sigma,
        v: v_t,
        profile: be.take_profile(),
        iters: p,
        est_residuals: Vec::new(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::residuals;
    use crate::backend::cpu::CpuBackend;
    use crate::gen::dense::{dense_with_spectrum, paper_dense};
    use crate::la::norms::orth_error;

    #[test]
    fn recovers_well_separated_spectrum() {
        let sigma: Vec<f64> = (0..8).map(|i| 4.0f64.powi(-(i as i32))).collect();
        let prob = dense_with_spectrum(60, 8, &sigma, 1);
        let mut be = CpuBackend::new_dense(prob.a.clone());
        let opts = RandSvdOpts { r: 8, p: 8, b: 4, ..Default::default() };
        let svd = randsvd(&mut be, &opts).unwrap();
        for i in 0..4 {
            assert!(
                (svd.sigma[i] - sigma[i]).abs() / sigma[i] < 1e-8,
                "sigma_{i}: {} vs {}",
                svd.sigma[i],
                sigma[i]
            );
        }
        assert!(orth_error(&svd.u) < 1e-10);
        assert!(orth_error(&svd.v) < 1e-10);
        let mut be2 = CpuBackend::new_dense(prob.a);
        let res = residuals(&mut be2, &svd, 4);
        assert!(res.iter().all(|&x| x < 1e-8), "residuals {res:?}");
    }

    #[test]
    fn workspace_reuse_across_solves_is_exact() {
        let prob = paper_dense(90, 30, 4);
        let opts = RandSvdOpts { r: 12, p: 6, b: 4, seed: 11, ..Default::default() };
        let mut be = CpuBackend::new_dense(prob.a.clone());
        let fresh = randsvd(&mut be, &opts).unwrap();
        let ws = Workspace::new(Plan::randsvd(90, 30, 12, 6, 4));
        let mut be1 = CpuBackend::new_dense(prob.a.clone());
        let first = randsvd_with(&mut be1, &opts, &ws).unwrap();
        let mut be2 = CpuBackend::new_dense(prob.a.clone());
        let second = randsvd_with(&mut be2, &opts, &ws).unwrap();
        assert_eq!(fresh.sigma, first.sigma);
        assert_eq!(first.sigma, second.sigma);
        assert_eq!(first.u.data(), second.u.data());
        assert_eq!(first.v.data(), second.v.data());
        let bad = Workspace::new(Plan::randsvd(90, 30, 8, 6, 4));
        let mut be3 = CpuBackend::new_dense(prob.a);
        assert!(randsvd_with(&mut be3, &opts, &bad).is_err());
    }

    #[test]
    fn more_power_iterations_improve_accuracy() {
        // Paper Fig. 1/4 phenomenon: p=1 is poor unless the spectrum is
        // well separated; accuracy improves monotonically-ish with p.
        let prob = paper_dense(120, 40, 2);
        let a = prob.a.clone();
        let res_at = |p: usize| {
            let mut be = CpuBackend::new_dense(a.clone());
            let opts = RandSvdOpts { r: 8, p, b: 8, seed: 7, ..Default::default() };
            let svd = randsvd(&mut be, &opts).unwrap();
            let mut be2 = CpuBackend::new_dense(a.clone());
            residuals(&mut be2, &svd, 4).iter().fold(0.0f64, |m, &x| m.max(x))
        };
        let r1 = res_at(1);
        let r8 = res_at(8);
        assert!(r8 < r1 * 0.5, "p=1 {r1:.3e} vs p=8 {r8:.3e}");
        assert!(r8 < 1e-4, "p=8 {r8:.3e}");
    }

    #[test]
    fn works_on_sparse_operand() {
        use crate::gen::sparse::{generate, SparseSpec};
        let spec = SparseSpec { rows: 150, cols: 80, nnz: 1600, seed: 5, ..Default::default() };
        let a = generate(&spec);
        let mut be = CpuBackend::new_sparse(a.clone());
        let opts = RandSvdOpts { r: 12, p: 20, b: 4, seed: 3, ..Default::default() };
        let svd = randsvd(&mut be, &opts).unwrap();
        let mut be2 = CpuBackend::new_sparse(a);
        let res = residuals(&mut be2, &svd, 6);
        assert!(res.iter().all(|&x| x < 1e-6), "residuals {res:?}");
        // profile covered the four phases
        assert!(svd.profile.stat(Block::MultA).calls >= 20);
        assert!(svd.profile.stat(Block::OrthN).secs >= 0.0);
    }

    #[test]
    fn rejects_bad_params() {
        let prob = paper_dense(30, 10, 3);
        let mut be = CpuBackend::new_dense(prob.a);
        assert!(randsvd(&mut be, &RandSvdOpts { r: 0, ..Default::default() }).is_err());
        assert!(randsvd(&mut be, &RandSvdOpts { r: 100, ..Default::default() }).is_err());
        assert!(randsvd(&mut be, &RandSvdOpts { r: 4, p: 0, ..Default::default() }).is_err());
        assert!(randsvd(&mut be, &RandSvdOpts { r: 4, p: 1, b: 0, ..Default::default() }).is_err());
    }

    #[test]
    fn r_not_multiple_of_b() {
        let prob = paper_dense(50, 20, 9);
        let mut be = CpuBackend::new_dense(prob.a.clone());
        let opts = RandSvdOpts { r: 10, p: 6, b: 4, seed: 2, ..Default::default() };
        let svd = randsvd(&mut be, &opts).unwrap();
        assert_eq!(svd.u.cols(), 10);
        let mut be2 = CpuBackend::new_dense(prob.a);
        let res = residuals(&mut be2, &svd, 3);
        assert!(res.iter().all(|&x| x < 1e-5), "residuals {res:?}");
    }
}
