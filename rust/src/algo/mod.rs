//! The paper's truncated-SVD algorithms and their shared building blocks.

pub mod cgs_qr;
pub mod incremental;
pub mod lancsvd;
pub mod orth;
pub mod randsvd;
pub mod stream;

use crate::backend::Backend;
use crate::la::blas1::nrm2;
use crate::la::mat::Mat;
use crate::metrics::{Block, Profile};
use crate::util::scalar::Scalar;

/// Initial-vector distribution (paper §4: cuRAND Poisson; normal kept for
/// ablations).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum InitDist {
    /// Centered unit-variance Poisson (the paper's choice).
    CenteredPoisson,
    /// Standard normal.
    Normal,
}

/// Options for RandSVD (Algorithm 1).
#[derive(Clone, Debug)]
pub struct RandSvdOpts {
    /// Subspace width (number of computed triplets), r ≥ wanted count.
    pub r: usize,
    /// Number of subspace iterations (p = 1 is the direct method of
    /// Martinsson et al.; p > 1 adds power iterations).
    pub p: usize,
    /// Block size for the CGS-QR factorizations.
    pub b: usize,
    /// PRNG seed for the initial vectors.
    pub seed: u64,
    /// Initial-vector distribution.
    pub init: InitDist,
    /// Fused operand-pass override: `Some(true)`/`Some(false)` force the
    /// fused/unfused power step; `None` defers to the cost-model policy
    /// ([`crate::cost::should_fuse`], overridable via `TRUNKSVD_FUSE`).
    pub fuse: Option<bool>,
}

impl Default for RandSvdOpts {
    fn default() -> Self {
        RandSvdOpts {
            r: 16,
            p: 96,
            b: 16,
            seed: 0xC0FFEE,
            init: InitDist::CenteredPoisson,
            fuse: None,
        }
    }
}

/// Restart strategy for LancSVD.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Restart {
    /// The paper's basic Golub/Luk/Overton restart: re-seed with the b
    /// leading approximate left singular vectors and rebuild the basis.
    Basic,
    /// Thick restart (the paper's stated future work, after
    /// Baglama–Reichel): keep the leading `keep` Ritz pairs, rebuild B as
    /// the arrow matrix diag(Σ) + residual coupling, and continue the
    /// recurrence from the existing residual block — preserving far more
    /// of the Krylov information per restart.
    Thick { keep: usize },
}

/// Options for LancSVD (Algorithm 2).
#[derive(Clone, Debug)]
pub struct LancSvdOpts {
    /// Krylov basis size (must be a multiple of `b`).
    pub r: usize,
    /// Number of restarts (outer iterations).
    pub p: usize,
    /// Lanczos block size.
    pub b: usize,
    /// PRNG seed for the initial block.
    pub seed: u64,
    /// Initial-vector distribution.
    pub init: InitDist,
    /// Optional early stop: restarting ends once the estimated residuals
    /// of the first `wanted` triplets all drop below `tol` (the paper's
    /// "practical implementation ... p is increased till the desired
    /// accuracy"; here p becomes the iteration cap).
    pub tol: Option<f64>,
    /// Number of leading triplets `tol` applies to (default: b).
    pub wanted: usize,
    /// Restart strategy (paper default: basic).
    pub restart: Restart,
    /// Fused operand-pass override: `Some(true)`/`Some(false)` force the
    /// fused/unfused A·Q + Gram sweep; `None` defers to the cost-model
    /// policy ([`crate::cost::should_fuse`], overridable via
    /// `TRUNKSVD_FUSE`).
    pub fuse: Option<bool>,
}

impl Default for LancSvdOpts {
    fn default() -> Self {
        LancSvdOpts {
            r: 256,
            p: 2,
            b: 16,
            seed: 0xC0FFEE,
            init: InitDist::CenteredPoisson,
            tol: None,
            wanted: 10,
            restart: Restart::Basic,
            fuse: None,
        }
    }
}

/// A computed truncated SVD, A ≈ U·diag(sigma)·Vᵀ. Generic over the
/// working precision of the solve (default f64); residual *estimates*
/// are always reported as f64.
#[derive(Debug)]
pub struct TruncatedSvd<S: Scalar = f64> {
    /// Left singular vectors, m×r.
    pub u: Mat<S>,
    /// Singular values, descending.
    pub sigma: Vec<S>,
    /// Right singular vectors, n×r.
    pub v: Mat<S>,
    /// Per-building-block time/flop profile of the solve.
    pub profile: Profile,
    /// Outer iterations actually performed (≤ p when `tol` stops early).
    pub iters: usize,
    /// Residual estimates from the algorithm's own stopping bound (free
    /// for LancSVD via ‖R_k·v̄_i‖; empty for RandSVD).
    pub est_residuals: Vec<f64>,
}

impl<S: Scalar> TruncatedSvd<S> {
    /// Keep only the leading `count` triplets.
    pub fn truncated(&self, count: usize) -> (Mat<S>, Vec<S>, Mat<S>) {
        let c = count.min(self.sigma.len());
        (self.u.panel_owned(0, c), self.sigma[..c].to_vec(), self.v.panel_owned(0, c))
    }
}

/// The paper's accuracy metric (Eq. 14): Rᵢ = ‖A·vᵢ − σᵢ·uᵢ‖₂ / σᵢ for the
/// first `count` triplets, computed with one SpMM/GEMM through the
/// backend. (The paper prints ‖Auᵢ − σᵢvᵢ‖; with A m×n the dimensionally
/// consistent form uses vᵢ ∈ ℝⁿ on the left — see DESIGN.md §7.)
pub fn residuals<S: Scalar, B: Backend<S> + ?Sized>(
    be: &mut B,
    svd: &TruncatedSvd<S>,
    count: usize,
) -> Vec<f64> {
    let c = count.min(svd.sigma.len());
    if c == 0 {
        return Vec::new();
    }
    be.profile_mut().set_phase(Block::Other);
    let av = be.apply_a(svd.v.panel(0, c));
    let mut out = Vec::with_capacity(c);
    for i in 0..c {
        let sigma = svd.sigma[i];
        let mut diff = av.col(i).to_vec();
        crate::la::blas1::axpy(-sigma, svd.u.col(i), &mut diff);
        let r = nrm2(&diff);
        out.push(if sigma > S::ZERO { (r / sigma).to_f64() } else { f64::INFINITY });
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::cpu::CpuBackend;
    use crate::gen::dense::paper_dense;

    #[test]
    fn residuals_zero_for_exact_svd() {
        let p = paper_dense(40, 12, 3);
        let mut be = CpuBackend::new_dense(p.a.clone());
        let svd = TruncatedSvd {
            u: p.u.panel_owned(0, 5),
            sigma: p.sigma[..5].to_vec(),
            v: p.v.panel_owned(0, 5),
            profile: Profile::new(),
            iters: 0,
            est_residuals: vec![],
        };
        let res = residuals(&mut be, &svd, 5);
        assert_eq!(res.len(), 5);
        for (i, r) in res.iter().enumerate() {
            // The relative-residual floor for triplet i is ε·σ₁/σᵢ (the
            // problem matrix itself carries ~ε·σ₁ construction rounding).
            let floor = 1e-13 * p.sigma[0] / p.sigma[i];
            assert!(*r < floor.max(1e-13), "residual {i} = {r} (floor {floor:.1e})");
        }
    }

    #[test]
    fn residuals_large_for_wrong_vectors() {
        let p = paper_dense(40, 12, 4);
        let mut be = CpuBackend::new_dense(p.a.clone());
        // swap u columns so pairs mismatch
        let mut u = p.u.panel_owned(0, 2);
        let c0 = u.col(0).to_vec();
        let c1 = u.col(1).to_vec();
        u.col_mut(0).copy_from_slice(&c1);
        u.col_mut(1).copy_from_slice(&c0);
        let svd = TruncatedSvd {
            u,
            sigma: p.sigma[..2].to_vec(),
            v: p.v.panel_owned(0, 2),
            profile: Profile::new(),
            iters: 0,
            est_residuals: vec![],
        };
        let res = residuals(&mut be, &svd, 2);
        assert!(res[0] > 0.5, "res {res:?}");
    }
}
