//! CGS-QR: QR factorization via block Gram-Schmidt (Algorithm 3), in
//! workspace-planned out-parameter form.
//!
//! Factors a tall-and-skinny q×r matrix as Q·R by orthonormalizing the
//! first b-column block with CholeskyQR2 (Alg. 4) and each subsequent
//! block against the already-built panel with CGS-CQR2 (Alg. 5). Q is
//! formed explicitly **in place inside the input panel** (the paper's
//! choice for GPU efficiency): the current block and the history are
//! disjoint column ranges of one buffer, split with
//! [`MatMut::split_at_col`], so no block is ever copied out. R is
//! assembled block-column-wise into a caller-provided r×r buffer, and
//! the per-block H/R factors come from the workspace — zero heap
//! allocations in steady state.

use crate::backend::Backend;
use crate::error::{Error, Result};
use crate::la::mat::{Mat, MatMut};
use crate::la::workspace::{names, Plan, Workspace};
use crate::util::scalar::Scalar;

/// Blocked CGS QR factorization, out-parameter form. `y` (q×r) is
/// orthonormalized in place; `r` (r×r, fully overwritten: upper
/// triangle + zeros) satisfies `Y_in ≈ Q_out · R`. `b` is the block
/// size; `y.cols` need not be a multiple of `b` (the last block is
/// narrower). `ws` supplies the `orth.*` scratch.
pub fn cgs_qr_into<S: Scalar, B: Backend<S> + ?Sized>(
    be: &mut B,
    mut y: MatMut<'_, S>,
    mut r: MatMut<'_, S>,
    b: usize,
    ws: &Workspace<S>,
) -> Result<()> {
    let r_cols = y.cols;
    if b == 0 {
        return Err(Error::InvalidParam("block size b must be >= 1".into()));
    }
    assert_eq!((r.rows, r.cols), (r_cols, r_cols), "cgs_qr R shape");
    r.fill(S::ZERO);

    // S1: first block via CholeskyQR2.
    let b0 = b.min(r_cols);
    {
        let q0 = y.panel_mut(0, b0);
        let mut r0_buf = ws.buf(names::ORTH_R);
        let mut r0 = r0_buf.view_mut(b0, b0);
        be.orth_cholqr2_into(q0, r0.reborrow(), ws)?;
        for j in 0..b0 {
            for i in 0..=j {
                r.set(i, j, r0.at(i, j));
            }
        }
    }

    // S2: remaining blocks via CGS-CQR2 against the growing panel.
    let mut j0 = b0;
    while j0 < r_cols {
        let jb = b.min(r_cols - j0);
        {
            let (hist, mut rest) = y.split_at_col(j0);
            let qj = rest.panel_mut(0, jb);
            let mut h_buf = ws.buf(names::ORTH_H);
            let mut h = h_buf.view_mut(j0, jb);
            let mut rj_buf = ws.buf(names::ORTH_R);
            let mut rj = rj_buf.view_mut(jb, jb);
            be.orth_cgs_cqr2_into(qj, hist, h.reborrow(), rj.reborrow(), ws)?;
            // Assemble the block column of R: H stacked on R_j.
            for j in 0..jb {
                for i in 0..j0 {
                    r.set(i, j0 + j, h.at(i, j));
                }
                for i in 0..=j {
                    r.set(j0 + i, j0 + j, rj.at(i, j));
                }
            }
        }
        j0 += jb;
    }
    Ok(())
}

/// Value-returning wrapper (tests / one-shot callers): allocates R and
/// a throwaway orth workspace sized for this panel.
pub fn cgs_qr<S: Scalar, B: Backend<S> + ?Sized>(
    be: &mut B,
    y: &mut Mat<S>,
    b: usize,
) -> Result<Mat<S>> {
    if b == 0 {
        return Err(Error::InvalidParam("block size b must be >= 1".into()));
    }
    let ws = Workspace::new(Plan::orth(y.rows(), y.cols(), b.min(y.cols().max(1))));
    let mut r = Mat::zeros(y.cols(), y.cols());
    cgs_qr_into(be, y.as_mut(), r.as_mut(), b, &ws)?;
    Ok(r)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::cpu::CpuBackend;
    use crate::la::blas3::mat_nn;
    use crate::la::norms::orth_error;
    use crate::util::rng::Rng;

    fn dummy_backend() -> CpuBackend {
        CpuBackend::new_dense(Mat::zeros(1, 1))
    }

    #[test]
    fn factorizes_tall_skinny() {
        let mut be = dummy_backend();
        let mut rng = Rng::new(10);
        for &(q_rows, r_cols, b) in
            &[(100usize, 16usize, 4usize), (333, 24, 8), (64, 16, 16), (90, 10, 3)]
        {
            let y0 = Mat::randn(q_rows, r_cols, &mut rng);
            let mut y = y0.clone();
            let r = cgs_qr(&mut be, &mut y, b).unwrap();
            assert!(orth_error(&y) < 1e-12, "orth {q_rows}x{r_cols} b={b}: {}", orth_error(&y));
            let back = mat_nn(&y, &r);
            assert!(
                back.max_abs_diff(&y0) / y0.fro_norm() < 1e-12,
                "reconstruct {q_rows}x{r_cols} b={b}"
            );
            // R strictly upper triangular below the diagonal.
            for j in 0..r_cols {
                for i in (j + 1)..r_cols {
                    assert_eq!(r.at(i, j), 0.0, "R({i},{j})");
                }
            }
        }
    }

    #[test]
    fn into_form_reuses_one_workspace() {
        // Repeated factorizations through one arena give the same
        // numbers as fresh throwaway workspaces.
        let mut be = dummy_backend();
        let mut rng = Rng::new(13);
        let ws = Workspace::new(Plan::orth(80, 12, 4));
        for _ in 0..3 {
            let y0 = Mat::randn(80, 12, &mut rng);
            let mut y1 = y0.clone();
            let r1 = cgs_qr(&mut be, &mut y1, 4).unwrap();
            let mut y2 = y0.clone();
            let mut r2 = Mat::zeros(12, 12);
            cgs_qr_into(&mut be, y2.as_mut(), r2.as_mut(), 4, &ws).unwrap();
            assert!(y1.max_abs_diff(&y2) == 0.0);
            assert!(r1.max_abs_diff(&r2) == 0.0);
        }
    }

    #[test]
    fn matches_householder_qr_up_to_signs() {
        let mut be = dummy_backend();
        let mut rng = Rng::new(11);
        let y0 = Mat::randn(80, 12, &mut rng);
        let mut y = y0.clone();
        let _ = cgs_qr(&mut be, &mut y, 4).unwrap();
        let (qh, _) = crate::la::qr::householder_qr(&y0);
        // Same column space: ‖Q_cgs − Q_h (Q_hᵀ Q_cgs)‖ ≈ 0.
        let proj = crate::la::blas3::mat_tn(&qh, &y);
        let back = mat_nn(&qh, &proj);
        assert!(back.max_abs_diff(&y) < 1e-10);
    }

    #[test]
    fn rejects_zero_block() {
        let mut be = dummy_backend();
        let mut y = Mat::zeros(10, 4);
        assert!(cgs_qr(&mut be, &mut y, 0).is_err());
    }

    #[test]
    fn single_block_equals_cholqr2() {
        let mut be = dummy_backend();
        let mut rng = Rng::new(12);
        let y0 = Mat::randn(50, 8, &mut rng);
        let mut y1 = y0.clone();
        let mut y2 = y0.clone();
        let r1 = cgs_qr(&mut be, &mut y1, 8).unwrap();
        let r2 = crate::algo::orth::cholqr2(&mut be, &mut y2).unwrap();
        assert!(y1.max_abs_diff(&y2) < 1e-14);
        assert!(r1.max_abs_diff(&r2) < 1e-14);
    }
}
