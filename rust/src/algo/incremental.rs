//! Incremental truncated SVD (the paper's §2 remark: when r is not known
//! in advance, "construct an incremental truncated SVD using, for
//! example, an incremental version of the QR factorization").
//!
//! Maintains a rank-≤r factorization A ≈ U·diag(s)·Vᵀ of a matrix whose
//! *columns arrive in blocks* (the streaming/low-memory regime the
//! paper's conclusion reserves for RandSVD). Per appended block C (m×c):
//!
//! 1. project:   H = UᵀC;  residual E = C − U·H
//! 2. expand:    E = Q_E·R_E  (CholeskyQR2 + fallback — Alg. 4 reused)
//! 3. small SVD: [diag(s) H; 0 R_E] = Ū Σ V̄ᵀ   ((r+c)×(r+c), host)
//! 4. rotate + truncate: U ← [U Q_E]·Ū_r, V bookkeeping, s ← Σ_r
//!
//! The σ-threshold variant (`tol`) drops triplets with σ_i < tol·σ_1,
//! implementing the user-defined threshold of Eq. 3.

use crate::backend::Backend;
use crate::error::Result;
use crate::la::mat::Mat;
use crate::la::svd::jacobi_svd;
use crate::metrics::Block;
use crate::util::scalar::Scalar;

use super::orth::cholqr2;

/// Streaming truncated SVD of a column stream (generic over the working
/// precision; the σ threshold `tol` stays an f64 ratio).
pub struct IncrementalSvd<S: Scalar = f64> {
    rows: usize,
    rank_cap: usize,
    /// relative σ threshold (triplets below tol·σ₁ are truncated away)
    tol: f64,
    u: Mat<S>,
    s: Vec<S>,
    /// right factor as a growing (cols_seen × rank) matrix
    v: Mat<S>,
    cols_seen: usize,
}

impl<S: Scalar> IncrementalSvd<S> {
    /// New accumulator for m-row inputs with rank cap `r`.
    pub fn new(rows: usize, rank_cap: usize, tol: f64) -> IncrementalSvd<S> {
        IncrementalSvd {
            rows,
            rank_cap,
            tol,
            u: Mat::zeros(rows, 0),
            s: Vec::new(),
            v: Mat::zeros(0, 0),
            cols_seen: 0,
        }
    }

    pub fn rank(&self) -> usize {
        self.s.len()
    }
    pub fn cols_seen(&self) -> usize {
        self.cols_seen
    }
    pub fn u(&self) -> &Mat<S> {
        &self.u
    }
    pub fn sigma(&self) -> &[S] {
        &self.s
    }
    pub fn v(&self) -> &Mat<S> {
        &self.v
    }

    /// Append a block of columns C (m×c).
    pub fn push_block<B: Backend<S> + ?Sized>(&mut self, be: &mut B, c: &Mat<S>) -> Result<()> {
        assert_eq!(c.rows(), self.rows, "column block rows");
        let k = self.rank();
        let cc = c.cols();
        be.profile_mut().set_phase(Block::Other);

        // 1. project onto the current left basis: H = UᵀC, E = C − U·H.
        //
        // Note: we do NOT reuse Alg. 5 here. Its paper-faithful step S12
        // (H ← H + H̄ instead of the exact H + H̄·L₁ᵀ) is harmless for the
        // Lanczos panels but becomes an O(1) error when the residual
        // block is *numerically zero* (new columns entirely inside
        // span(U)) — the common case for low-rank streams. The explicit
        // re-orthogonalization below folds every correction exactly.
        let (mut h, mut e) = if k > 0 {
            let h = be.proj(self.u.as_ref(), c.as_ref());
            let mut e = c.clone();
            be.subtract_proj(e.as_mut(), self.u.as_ref(), h.as_ref());
            (h, e)
        } else {
            (Mat::zeros(0, cc), c.clone())
        };

        // 2. orthonormalize the residual (Alg. 4 + CGS2 fallback), then
        // re-orthogonalize it against U, folding the corrections:
        // Q_old = U·G + Q_new·T  ⇒  H += G·R_E,  R_E ← T·R_E.
        let mut r_e = cholqr2(be, &mut e)?;
        if k > 0 {
            let g = be.proj(self.u.as_ref(), e.as_ref());
            be.subtract_proj(e.as_mut(), self.u.as_ref(), g.as_ref());
            let t = cholqr2(be, &mut e)?;
            let g_re = crate::la::blas3::mat_nn(&g, &r_e);
            for (hv, c) in h.data_mut().iter_mut().zip(g_re.data()) {
                *hv += *c;
            }
            r_e = crate::la::blas3::mat_nn(&t, &r_e);
        }

        // 3. small SVD of the augmented core [diag(s) H; 0 R_E].
        let aug = k + cc;
        let mut core = Mat::zeros(aug, aug);
        for i in 0..k {
            core.set(i, i, self.s[i]);
        }
        for j in 0..cc {
            for i in 0..k {
                core.set(i, k + j, h.at(i, j));
            }
            for i in 0..cc {
                core.set(k + i, k + j, r_e.at(i, j));
            }
        }
        let svd = jacobi_svd(&core)?;

        // 4. decide the new rank (cap + σ threshold).
        let smax = svd.s.first().copied().unwrap_or(S::ZERO);
        let mut new_rank = svd.s.len().min(self.rank_cap);
        while new_rank > 1 && svd.s[new_rank - 1] < S::from_f64(self.tol) * smax {
            new_rank -= 1;
        }

        // Rotate the left basis: U ← [U Q_E]·Ū_new.
        let ext = self.u.hcat(&e); // m×aug
        let u_new = be.gemm_nn(ext.as_ref(), svd.u.panel(0, new_rank));

        // Rotate/extend the right factor: V_new = [V 0; 0 I]·V̄_new.
        let old_cols = self.cols_seen;
        let mut v_ext = Mat::zeros(old_cols + cc, aug);
        for j in 0..k {
            for i in 0..old_cols {
                v_ext.set(i, j, self.v.at(i, j));
            }
        }
        for j in 0..cc {
            v_ext.set(old_cols + j, k + j, S::ONE);
        }
        let v_new = be.gemm_nn(v_ext.as_ref(), svd.v.panel(0, new_rank));

        self.u = u_new;
        self.v = v_new;
        self.s = svd.s[..new_rank].to_vec();
        self.cols_seen += cc;
        Ok(())
    }

    /// Current reconstruction A ≈ U·diag(s)·Vᵀ (tests / small problems).
    pub fn reconstruct(&self) -> Mat<S> {
        let k = self.rank();
        let mut us = self.u.clone();
        for j in 0..k {
            let s = self.s[j];
            for x in us.col_mut(j) {
                *x *= s;
            }
        }
        crate::la::blas3::mat_nn(&us, &self.v.transpose())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::cpu::CpuBackend;
    use crate::gen::dense::dense_with_spectrum;
    use crate::la::norms::orth_error;
    use crate::util::rng::Rng;

    fn dummy_backend() -> CpuBackend {
        CpuBackend::new_dense(Mat::zeros(1, 1))
    }

    #[test]
    fn exact_when_rank_cap_not_hit() {
        // Feed a rank-5 matrix in blocks; with cap ≥ 5 the factorization
        // must be exact.
        let mut rng = Rng::new(1);
        let u = crate::la::qr::random_orthonormal(40, 5, &mut rng);
        let w = Mat::randn(5, 24, &mut rng);
        let a = crate::la::blas3::mat_nn(&u, &w);
        let mut inc = IncrementalSvd::new(40, 12, 0.0);
        let mut be = dummy_backend();
        for j0 in (0..24).step_by(6) {
            inc.push_block(&mut be, &a.panel_owned(j0, 6)).unwrap();
        }
        assert_eq!(inc.cols_seen(), 24);
        assert!(inc.rank() <= 12);
        let back = inc.reconstruct();
        assert!(
            back.max_abs_diff(&a) / a.fro_norm() < 1e-10,
            "reconstruction {}",
            back.max_abs_diff(&a)
        );
        assert!(orth_error(inc.u()) < 1e-10);
    }

    #[test]
    fn matches_batch_truncated_svd() {
        let sigma: Vec<f64> = (0..20).map(|i| 2.0f64.powi(-(i as i32))).collect();
        let prob = dense_with_spectrum(60, 20, &sigma, 3);
        let mut inc = IncrementalSvd::new(60, 8, 0.0);
        let mut be = dummy_backend();
        for j0 in (0..20).step_by(5) {
            inc.push_block(&mut be, &prob.a.panel_owned(j0, 5)).unwrap();
        }
        // Leading singular values match the truth (truncation error is
        // bounded by the discarded tail, so allow a small perturbation).
        for i in 0..4 {
            assert!(
                (inc.sigma()[i] - sigma[i]).abs() / sigma[i] < 1e-6,
                "sigma_{i}: {} vs {}",
                inc.sigma()[i],
                sigma[i]
            );
        }
    }

    #[test]
    fn tol_threshold_truncates_rank() {
        // Spectrum with a hard gap: 3 large + 17 tiny values.
        let mut sigma = vec![1.0, 0.9, 0.8];
        sigma.extend(std::iter::repeat(1e-9).take(17));
        let prob = dense_with_spectrum(50, 20, &sigma, 4);
        let mut inc = IncrementalSvd::new(50, 20, 1e-6);
        let mut be = dummy_backend();
        for j0 in (0..20).step_by(4) {
            inc.push_block(&mut be, &prob.a.panel_owned(j0, 4)).unwrap();
        }
        assert!(inc.rank() <= 4, "threshold should cap rank, got {}", inc.rank());
        assert!((inc.sigma()[0] - 1.0).abs() < 1e-8);
    }

    #[test]
    fn single_column_blocks() {
        let mut rng = Rng::new(5);
        let a = Mat::randn(30, 7, &mut rng);
        let mut inc = IncrementalSvd::new(30, 7, 0.0);
        let mut be = dummy_backend();
        for j in 0..7 {
            inc.push_block(&mut be, &a.panel_owned(j, 1)).unwrap();
        }
        let back = inc.reconstruct();
        assert!(back.max_abs_diff(&a) / a.fro_norm() < 1e-10);
    }
}
