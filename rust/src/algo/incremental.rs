//! Incremental truncated SVD (the paper's §2 remark: when r is not known
//! in advance, "construct an incremental truncated SVD using, for
//! example, an incremental version of the QR factorization").
//!
//! Maintains a rank-≤r factorization A ≈ U·diag(s)·Vᵀ of a matrix whose
//! *columns arrive in blocks* (the streaming/low-memory regime the
//! paper's conclusion reserves for RandSVD). Per appended block C (m×c):
//!
//! 1. project:   H = UᵀC;  residual E = C − U·H
//! 2. expand:    E = Q_E·R_E  (CholeskyQR2 + fallback — Alg. 4 reused)
//! 3. small SVD: [diag(s) H; 0 R_E] = Ū Σ V̄ᵀ   ((k+c)×(k+c), host)
//! 4. rotate + truncate: U ← [U Q_E]·Ū_r, V bookkeeping, s ← Σ_r
//!
//! The σ-threshold variant (`tol`) drops triplets with σ_i < tol·σ_1,
//! implementing the user-defined threshold of Eq. 3.
//!
//! The update runs entirely on the allocation-free out-parameter
//! substrate: every per-block operand is a view of a
//! [`Plan::incremental`] workspace buffer, every kernel is a `*_into`
//! backend op (so cpu/staged backends see — and ledger — the traffic),
//! and the host GESVD reuses a [`JacobiScratch`]. After construction,
//! [`IncrementalSvd::update_with`] performs zero heap allocations on
//! the non-degenerate path (pinned by `tests/test_incremental.rs`).

use crate::backend::Backend;
use crate::error::Result;
use crate::la::mat::{Mat, MatRef};
use crate::la::svd::{jacobi_svd_scratch_into, JacobiScratch};
use crate::la::workspace::{names, Plan, PlanKind, Workspace};
use crate::metrics::Block;
use crate::util::scalar::Scalar;

/// Streaming truncated SVD of a column stream (generic over the working
/// precision; the σ threshold `tol` stays an f64 ratio).
///
/// All state is preallocated at construction for a stream of up to
/// `cols_max` columns arriving in blocks of at most `block_cap`
/// columns, with the retained rank capped at `rank_cap`. The serve
/// layer keeps one of these warm per stream tenant — the whole basis
/// (U, σ, V, cols_seen) lives in this struct.
pub struct IncrementalSvd<S: Scalar = f64> {
    rows: usize,
    cols_max: usize,
    rank_cap: usize,
    block_cap: usize,
    /// relative σ threshold (triplets below tol·σ₁ are truncated away)
    tol: f64,
    /// live rank k ≤ rank_cap
    k: usize,
    cols_seen: usize,
    /// left basis storage (rows×rank_cap; live panel = leading k cols)
    u: Mat<S>,
    /// singular values (len k)
    s: Vec<S>,
    /// right factor storage. The live factor is cols_seen×k
    /// column-major with leading dimension cols_seen, packed flat at
    /// the front of this buffer — NOT at the buffer's own leading
    /// dimension — so it stays contiguous as the stream grows.
    v: Mat<S>,
    /// core-SVD singular values (capacity rank_cap + block_cap)
    core_s: Vec<S>,
    /// host-GESVD bookkeeping, reused across updates
    jac: JacobiScratch<S>,
}

impl<S: Scalar> IncrementalSvd<S> {
    /// New accumulator for `rows`-row inputs streaming up to `cols_max`
    /// total columns in blocks of ≤ `block_cap`, rank cap `rank_cap`.
    pub fn new(
        rows: usize,
        cols_max: usize,
        rank_cap: usize,
        block_cap: usize,
        tol: f64,
    ) -> IncrementalSvd<S> {
        assert!(rank_cap >= 1, "rank cap must be >= 1");
        assert!(block_cap >= 1, "block cap must be >= 1");
        assert!(rank_cap <= rows, "rank cap {rank_cap} exceeds row count {rows}");
        let aug = rank_cap + block_cap;
        IncrementalSvd {
            rows,
            cols_max,
            rank_cap,
            block_cap,
            tol,
            k: 0,
            cols_seen: 0,
            u: Mat::zeros(rows, rank_cap),
            s: Vec::with_capacity(rank_cap),
            v: Mat::zeros(cols_max, rank_cap),
            core_s: Vec::with_capacity(aug),
            jac: JacobiScratch::with_capacity(aug, aug),
        }
    }

    /// The workspace plan every [`IncrementalSvd::update_with`] call on
    /// this accumulator requires.
    pub fn plan(&self) -> Plan {
        Plan::incremental(self.rows, self.cols_max, self.rank_cap, self.block_cap)
    }

    pub fn rank(&self) -> usize {
        self.k
    }
    pub fn cols_seen(&self) -> usize {
        self.cols_seen
    }
    pub fn rows(&self) -> usize {
        self.rows
    }
    pub fn cols_max(&self) -> usize {
        self.cols_max
    }
    pub fn rank_cap(&self) -> usize {
        self.rank_cap
    }
    pub fn block_cap(&self) -> usize {
        self.block_cap
    }
    /// Live left basis (rows×rank view of the preallocated storage).
    pub fn u(&self) -> MatRef<'_, S> {
        self.u.panel(0, self.k)
    }
    pub fn sigma(&self) -> &[S] {
        &self.s
    }
    /// Live right factor (cols_seen×rank). Packed flat at the front of
    /// the storage buffer (see the field docs), so the view is built
    /// directly over the leading `cols_seen·rank` elements.
    pub fn v(&self) -> MatRef<'_, S> {
        MatRef {
            rows: self.cols_seen,
            cols: self.k,
            data: &self.v.data()[..self.cols_seen * self.k],
        }
    }

    /// Append a block of columns C (m×c, c ≤ block_cap) through the
    /// planned workspace: allocation-free after construction (on the
    /// non-degenerate path) and routed through the backend `*_into`
    /// ops, so a staged backend's transfer ledger sees every crossing
    /// (see the backend contract §9 on which crossings are sanctioned).
    pub fn update_with<B: Backend<S> + ?Sized>(
        &mut self,
        be: &mut B,
        c: MatRef<'_, S>,
        ws: &Workspace<S>,
    ) -> Result<()> {
        let m = self.rows;
        let r = self.rank_cap;
        assert_eq!(c.rows, m, "column block rows");
        let cc = c.cols;
        assert!(cc >= 1 && cc <= self.block_cap, "block width {cc} outside 1..={}", self.block_cap);
        assert!(
            self.cols_seen + cc <= self.cols_max,
            "stream exceeds the planned capacity ({} + {cc} > {})",
            self.cols_seen,
            self.cols_max
        );
        ws.plan().require(PlanKind::Incremental, m, self.cols_max, r, self.block_cap)?;
        be.profile_mut().set_phase(Block::Other);

        let k = self.k;
        let aug = k + cc;

        let mut ext = ws.buf(names::INC_EXT);
        let mut h = ws.buf(names::INC_H);
        let mut re = ws.buf(names::INC_RE);

        // Assemble [U | C] in the extended panel. The copy of U is what
        // lets the rotation GEMM below read the *old* basis while the
        // new one is written back into `self.u`.
        {
            let mut ext_v = ext.view_mut(m, aug);
            if k > 0 {
                be.copy_into(self.u.panel(0, k), ext_v.panel_mut(0, k));
            }
            be.copy_into(c, ext_v.panel_mut(k, cc));
        }

        // 1+2. project the tail onto the live basis (H = UᵀC,
        // E = C − U·H), orthonormalize it (Alg. 4 + fallback), then
        // re-orthogonalize against U folding the corrections exactly:
        // Q_old = U·G + Q_new·T  ⇒  H += G·R_E,  R_E ← T·R_E.
        //
        // Note: we do NOT reuse Alg. 5 here. Its paper-faithful step S12
        // (H ← H + H̄ instead of the exact H + H̄·L₁ᵀ) is harmless for the
        // Lanczos panels but becomes an O(1) error when the residual
        // block is *numerically zero* (new columns entirely inside
        // span(U)) — the common case for low-rank streams.
        {
            let mut ext_v = ext.view_mut(m, aug);
            let (u_live, mut e) = ext_v.split_at_col(k);
            if k > 0 {
                let mut h_v = h.view_mut(k, cc);
                be.proj_into(u_live, e.as_ref(), h_v.reborrow());
                be.subtract_proj(e.reborrow(), u_live, h_v.as_ref());
            }
            be.orth_cholqr2_into(e.reborrow(), re.view_mut(cc, cc), ws)?;
            if k > 0 {
                let mut g = ws.buf(names::INC_G);
                let mut g_v = g.view_mut(k, cc);
                be.proj_into(u_live, e.as_ref(), g_v.reborrow());
                be.subtract_proj(e.reborrow(), u_live, g_v.as_ref());
                let mut t = ws.buf(names::INC_T);
                let mut t_v = t.view_mut(cc, cc);
                be.orth_cholqr2_into(e, t_v.reborrow(), ws)?;
                let mut gre = ws.buf(names::INC_GRE);
                let mut gre_v = gre.view_mut(k, cc);
                be.gemm_nn_into(g_v.as_ref(), re.view_mut(cc, cc).as_ref(), gre_v.reborrow());
                let mut h_v = h.view_mut(k, cc);
                for (hv, gv) in h_v.data.iter_mut().zip(gre_v.as_ref().data) {
                    *hv += *gv;
                }
                let mut tre = ws.buf(names::INC_TRE);
                let mut tre_v = tre.view_mut(cc, cc);
                be.gemm_nn_into(t_v.as_ref(), re.view_mut(cc, cc).as_ref(), tre_v.reborrow());
                be.copy_into(tre_v.as_ref(), re.view_mut(cc, cc));
            }
        }

        // 3. small SVD of the augmented core [diag(s) H; 0 R_E] — the
        // host GESVD of Table 1, factor-sized, reusing the scratch.
        let mut core = ws.buf(names::INC_CORE);
        let mut cu = ws.buf(names::INC_CU);
        let mut cv = ws.buf(names::INC_CV);
        let mut core_v = core.view_mut(aug, aug);
        core_v.fill(S::ZERO);
        for i in 0..k {
            core_v.set(i, i, self.s[i]);
        }
        {
            let h_v = h.view_mut(k, cc);
            let re_v = re.view_mut(cc, cc);
            for j in 0..cc {
                for i in 0..k {
                    core_v.set(i, k + j, h_v.at(i, j));
                }
                for i in 0..cc {
                    core_v.set(k + i, k + j, re_v.at(i, j));
                }
            }
        }
        let mut cu_v = cu.view_mut(aug, aug);
        let mut cv_v = cv.view_mut(aug, aug);
        jacobi_svd_scratch_into(
            core_v.as_ref(),
            cu_v.reborrow(),
            &mut self.core_s,
            cv_v.reborrow(),
            &mut self.jac,
        )?;

        // 4. decide the new rank (cap + σ threshold)...
        let smax = self.core_s.first().copied().unwrap_or(S::ZERO);
        let mut new_rank = self.core_s.len().min(r);
        while new_rank > 1 && self.core_s[new_rank - 1] < S::from_f64(self.tol) * smax {
            new_rank -= 1;
        }

        // ...rotate the left basis U ← [U Q_E]·Ū_r...
        let mut unew = ws.buf(names::INC_UNEW);
        {
            let mut unew_v = unew.view_mut(m, new_rank);
            be.gemm_nn_into(
                ext.view_mut(m, aug).as_ref(),
                cu_v.as_ref().panel(0, new_rank),
                unew_v.reborrow(),
            );
            be.copy_into(unew_v.as_ref(), self.u.panel_mut(0, new_rank));
        }

        // ...and the right factor V ← [V 0; 0 I]·V̄_r, repacked flat at
        // the stream's new length.
        let old = self.cols_seen;
        let rows_v = old + cc;
        let mut vext = ws.buf(names::INC_VEXT);
        let mut vnew = ws.buf(names::INC_VNEW);
        {
            let mut vext_v = vext.view_mut(rows_v, aug);
            vext_v.fill(S::ZERO);
            for j in 0..k {
                let src = &self.v.data()[j * old..(j + 1) * old];
                vext_v.col_mut(j)[..old].copy_from_slice(src);
            }
            for j in 0..cc {
                vext_v.set(old + j, k + j, S::ONE);
            }
            let mut vnew_v = vnew.view_mut(rows_v, new_rank);
            be.gemm_nn_into(vext_v.as_ref(), cv_v.as_ref().panel(0, new_rank), vnew_v.reborrow());
            be.copy_into(vnew_v.as_ref(), self.v.view_mut(rows_v, new_rank));
        }

        self.s.clear();
        self.s.extend_from_slice(&self.core_s[..new_rank]);
        self.k = new_rank;
        self.cols_seen += cc;
        Ok(())
    }

    /// Allocating convenience over [`IncrementalSvd::update_with`] with
    /// a throwaway workspace (tests / one-shot callers; streaming
    /// callers build the workspace once from [`IncrementalSvd::plan`]).
    pub fn push_block<B: Backend<S> + ?Sized>(&mut self, be: &mut B, c: &Mat<S>) -> Result<()> {
        let ws = Workspace::new(self.plan());
        self.update_with(be, c.as_ref(), &ws)
    }

    /// Current reconstruction A ≈ U·diag(s)·Vᵀ (tests / small problems).
    pub fn reconstruct(&self) -> Mat<S> {
        let k = self.k;
        let mut us = self.u().to_owned();
        for j in 0..k {
            let s = self.s[j];
            for x in us.col_mut(j) {
                *x *= s;
            }
        }
        crate::la::blas3::mat_nn(&us, &self.v().to_owned().transpose())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::cpu::CpuBackend;
    use crate::gen::dense::dense_with_spectrum;
    use crate::la::norms::orth_error;
    use crate::util::rng::Rng;

    fn dummy_backend() -> CpuBackend {
        CpuBackend::new_dense(Mat::zeros(1, 1))
    }

    #[test]
    fn exact_when_rank_cap_not_hit() {
        // Feed a rank-5 matrix in blocks; with cap ≥ 5 the factorization
        // must be exact.
        let mut rng = Rng::new(1);
        let u = crate::la::qr::random_orthonormal(40, 5, &mut rng);
        let w = Mat::randn(5, 24, &mut rng);
        let a = crate::la::blas3::mat_nn(&u, &w);
        let mut inc = IncrementalSvd::new(40, 24, 12, 6, 0.0);
        let ws = Workspace::new(inc.plan());
        let mut be = dummy_backend();
        for j0 in (0..24).step_by(6) {
            inc.update_with(&mut be, a.panel(j0, 6), &ws).unwrap();
        }
        assert_eq!(inc.cols_seen(), 24);
        assert!(inc.rank() <= 12);
        let back = inc.reconstruct();
        assert!(
            back.max_abs_diff(&a) / a.fro_norm() < 1e-10,
            "reconstruction {}",
            back.max_abs_diff(&a)
        );
        assert!(orth_error(&inc.u().to_owned()) < 1e-10);
    }

    #[test]
    fn matches_batch_truncated_svd() {
        let sigma: Vec<f64> = (0..20).map(|i| 2.0f64.powi(-(i as i32))).collect();
        let prob = dense_with_spectrum(60, 20, &sigma, 3);
        let mut inc = IncrementalSvd::new(60, 20, 8, 5, 0.0);
        let ws = Workspace::new(inc.plan());
        let mut be = dummy_backend();
        for j0 in (0..20).step_by(5) {
            inc.update_with(&mut be, prob.a.panel(j0, 5), &ws).unwrap();
        }
        // Leading singular values match the truth (truncation error is
        // bounded by the discarded tail, so allow a small perturbation).
        for i in 0..4 {
            assert!(
                (inc.sigma()[i] - sigma[i]).abs() / sigma[i] < 1e-6,
                "sigma_{i}: {} vs {}",
                inc.sigma()[i],
                sigma[i]
            );
        }
    }

    #[test]
    fn tol_threshold_truncates_rank() {
        // Spectrum with a hard gap: 3 large + 17 tiny values.
        let mut sigma = vec![1.0, 0.9, 0.8];
        sigma.extend(std::iter::repeat(1e-9).take(17));
        let prob = dense_with_spectrum(50, 20, &sigma, 4);
        let mut inc = IncrementalSvd::new(50, 20, 20, 4, 1e-6);
        let ws = Workspace::new(inc.plan());
        let mut be = dummy_backend();
        for j0 in (0..20).step_by(4) {
            inc.update_with(&mut be, prob.a.panel(j0, 4), &ws).unwrap();
        }
        assert!(inc.rank() <= 4, "threshold should cap rank, got {}", inc.rank());
        assert!((inc.sigma()[0] - 1.0).abs() < 1e-8);
    }

    #[test]
    fn single_column_blocks() {
        let mut rng = Rng::new(5);
        let a = Mat::randn(30, 7, &mut rng);
        let mut inc = IncrementalSvd::new(30, 7, 7, 1, 0.0);
        let ws = Workspace::new(inc.plan());
        let mut be = dummy_backend();
        for j in 0..7 {
            inc.update_with(&mut be, a.panel(j, 1), &ws).unwrap();
        }
        let back = inc.reconstruct();
        assert!(back.max_abs_diff(&a) / a.fro_norm() < 1e-10);
    }

    #[test]
    fn push_block_convenience_matches_update_with() {
        let mut rng = Rng::new(6);
        let a = Mat::randn(25, 12, &mut rng);
        let mut inc_a = IncrementalSvd::new(25, 12, 6, 4, 0.0);
        let mut inc_b = IncrementalSvd::new(25, 12, 6, 4, 0.0);
        let ws = Workspace::new(inc_a.plan());
        let mut be = dummy_backend();
        for j0 in (0..12).step_by(4) {
            inc_a.update_with(&mut be, a.panel(j0, 4), &ws).unwrap();
            inc_b.push_block(&mut be, &a.panel_owned(j0, 4)).unwrap();
        }
        assert_eq!(inc_a.rank(), inc_b.rank());
        for (x, y) in inc_a.sigma().iter().zip(inc_b.sigma()) {
            assert_eq!(x.to_bits(), y.to_bits(), "push_block must be the same arithmetic");
        }
    }
}
