//! LancSVD: truncated SVD via the block Golub–Kahan–Lanczos method with
//! one-sided full orthogonalization and the basic restart (Algorithm 2).
//!
//! Each inner iteration extends two orthonormal bases, P ∈ ℝ^{n×r} and
//! P̄ ∈ ℝ^{m×r}, by one b-column block:
//!
//! ```text
//!   Qᵢ   = Aᵀ·Q̄ᵢ      orthogonalized against P(1..i−1)   →  Lᵢ   (S2, S3)
//!   Q̄ᵢ₊₁ = A·Qᵢ       orthogonalized against P̄(1..i)      →  Rᵢ   (S4, S5)
//! ```
//!
//! which assembles the lower block-bidiagonal B_k of Eq. 8 (Lᵢ diagonal
//! blocks, Rᵢ sub-diagonal blocks) satisfying A·P_k = P̄_k·B_k +
//! Q̄_{k+1}·R_k·E_kᵀ. The SVD of B_k then yields the truncated triplets
//! (Eqs. 9–12), and ‖R_k·v̄ᵢ[last b]‖ is a *free* residual estimate used
//! for the restart stopping test.
//!
//! The restart (paper §2.2, Golub/Luk/Overton) re-seeds the iteration with
//! Q̄₁ = P̄·Ū₁, the current approximation of the b leading left singular
//! vectors, preserving the most relevant search directions.

use crate::backend::Backend;
use crate::error::{Error, Result};
use crate::la::blas1::nrm2;
use crate::la::mat::Mat;
use crate::la::svd::jacobi_svd;
use crate::metrics::{Block, Timer};
use crate::util::rng::Rng;
use crate::util::scalar::Scalar;

use super::orth::{cgs_cqr2, cholqr2, random_orthonormal_panel};
use super::{InitDist, LancSvdOpts, Restart, TruncatedSvd};

/// Run LancSVD on the backend's operand matrix (any [`Scalar`]
/// precision; the paper's GPU regime is `S = f32`).
pub fn lancsvd<S: Scalar, B: Backend<S> + ?Sized>(
    be: &mut B,
    opts: &LancSvdOpts,
) -> Result<TruncatedSvd<S>> {
    let (m, n) = (be.m(), be.n());
    let LancSvdOpts { r, p, b, seed, init, tol, wanted, restart } = opts.clone();
    if b == 0 || r == 0 || p == 0 {
        return Err(Error::InvalidParam("r, p, b must all be >= 1".into()));
    }
    if r % b != 0 {
        return Err(Error::InvalidParam(format!("r={r} must be a multiple of b={b}")));
    }
    if r > n.min(m) {
        return Err(Error::InvalidParam(format!("r={r} exceeds min dim of {m}x{n}")));
    }
    // Thick restart keeps `keep` Ritz pairs (rounded up to a b multiple);
    // at least one fresh block must fit after them.
    let keep = match restart {
        Restart::Basic => 0,
        Restart::Thick { keep } => {
            let k = keep.max(1).div_ceil(b) * b;
            if k + b > r {
                return Err(Error::InvalidParam(format!(
                    "thick restart keep={keep} (rounded {k}) leaves no room in r={r}"
                )));
            }
            k
        }
    };

    // S1: random orthonormal start block Q̄₁ ∈ ℝ^{m×b}.
    be.profile_mut().set_phase(Block::Init);
    let mut rng = Rng::new(seed);
    let mut qbar_cur = match init {
        InitDist::CenteredPoisson => random_orthonormal_panel(be, m, b, &mut rng)?,
        InitDist::Normal => {
            let mut q = Mat::randn(m, b, &mut rng);
            cholqr2(be, &mut q)?;
            q
        }
    };

    let mut p_basis = Mat::zeros(n, r); // [Q₁ … Q_k]
    let mut pbar_basis = Mat::zeros(m, r); // [Q̄₁ … Q̄_k]
    let mut bmat = Mat::zeros(r, r);
    let mut rk_last = Mat::zeros(b, b);
    let mut svd_b = None;
    let mut iters = 0;
    let mut est_res: Vec<f64> = Vec::new();
    // Columns of the bases already valid at loop entry (0, or `keep`
    // after a thick restart).
    let mut filled = 0usize;

    for j in 1..=p {
        iters = j;
        // Extend the bases block-by-block until the Krylov width is full.
        while filled < r {
            let s = filled;
            // Record Q̄ᵢ into P̄ before extending the m-side basis.
            pbar_basis.set_panel(s, &qbar_cur);

            // S2: Qᵢ = Aᵀ·Q̄ᵢ
            be.profile_mut().set_phase(Block::MultAt);
            let mut qi = be.apply_at(qbar_cur.as_ref());

            // S3: orthogonalize in the n dimension → Lᵢᵀ (upper).
            be.profile_mut().set_phase(Block::OrthN);
            let lt = if s == 0 {
                cholqr2(be, &mut qi)? // S3a
            } else {
                let (_h, lt) = {
                    let panel = p_basis.panel(0, s);
                    cgs_cqr2(be, &mut qi, panel)? // S3b
                };
                lt
            };
            p_basis.set_panel(s, &qi);
            // B diagonal block: Lᵢ = (Lᵢᵀ)ᵀ, lower triangular.
            for jj in 0..b {
                for ii in jj..b {
                    bmat.set(s + ii, s + jj, lt.at(jj, ii));
                }
            }

            // S4: Q̄ᵢ₊₁ = A·Qᵢ
            be.profile_mut().set_phase(Block::MultA);
            let mut qbar_next = be.apply_a(qi.as_ref());

            // S5: orthogonalize in the m dimension against P̄ᵢ → Rᵢ.
            be.profile_mut().set_phase(Block::OrthM);
            let (_hbar, ri) = {
                let panel = pbar_basis.panel(0, s + b);
                cgs_cqr2(be, &mut qbar_next, panel)?
            };
            if s + b < r {
                // B sub-diagonal block (upper-triangular Rᵢ).
                for jj in 0..b {
                    for ii in 0..=jj {
                        bmat.set(s + b + ii, s + jj, ri.at(ii, jj));
                    }
                }
            } else {
                rk_last = ri; // ‖R_k‖ drives the residual estimate
            }
            qbar_cur = qbar_next;
            filled += b;
        }

        // S6: SVD of B_k on the host.
        be.profile_mut().set_phase(Block::SmallSvd);
        let t = Timer::start(9.0 * (r * r * r) as f64);
        let svd = jacobi_svd(&bmat)?;
        t.stop(be.profile_mut());

        // Free residual estimates: ‖A·(P v̄ᵢ) − σᵢ·(P̄ ūᵢ)‖ = ‖R_k·v̄ᵢ[r−b..r]‖.
        let coupling = |i: usize| -> Vec<S> {
            let mut tail = vec![S::ZERO; b];
            for (t_i, tv) in tail.iter_mut().enumerate() {
                let mut acc = S::ZERO;
                for c in 0..b {
                    acc += rk_last.at(t_i, c) * svd.v.at(r - b + c, i);
                }
                *tv = acc;
            }
            tail
        };
        est_res = (0..wanted.min(r))
            .map(|i| {
                let sigma = svd.s[i];
                if sigma > S::ZERO {
                    (nrm2(&coupling(i)) / sigma).to_f64()
                } else {
                    f64::INFINITY
                }
            })
            .collect();

        let converged = tol
            .map(|t| est_res.iter().take(wanted).all(|&x| x < t))
            .unwrap_or(false);

        if j < p && !converged {
            be.profile_mut().set_phase(Block::Finalize);
            match restart {
                Restart::Basic => {
                    // S7: Q̄₁ ← P̄·Ū₁ (first b columns of Ū), rebuild all.
                    qbar_cur = be.gemm_nn(pbar_basis.as_ref(), svd.u.panel(0, b));
                    be.profile_mut().set_phase(Block::OrthM);
                    cholqr2(be, &mut qbar_cur)?;
                    bmat.data_mut().fill(S::ZERO);
                    filled = 0;
                }
                Restart::Thick { .. } => {
                    // Keep `keep` Ritz pairs: new bases are the Ritz
                    // vectors; B becomes the arrow matrix diag(Σ) with
                    // the residual coupling S = R_k·V̄[last b, :keep] in
                    // the first sub-row block; the continuation block is
                    // the *existing* residual Q̄_{k+1} (already ⊥ P̄·Ū).
                    let p_new = be.gemm_nn(p_basis.as_ref(), svd.v.panel(0, keep));
                    let pbar_new = be.gemm_nn(pbar_basis.as_ref(), svd.u.panel(0, keep));
                    p_basis.data_mut().fill(S::ZERO);
                    pbar_basis.data_mut().fill(S::ZERO);
                    p_basis.set_panel(0, &p_new);
                    pbar_basis.set_panel(0, &pbar_new);
                    bmat.data_mut().fill(S::ZERO);
                    for i in 0..keep {
                        bmat.set(i, i, svd.s[i]);
                    }
                    for i in 0..keep {
                        let s_col = coupling(i);
                        for (t_i, &v) in s_col.iter().enumerate() {
                            bmat.set(keep + t_i, i, v);
                        }
                    }
                    filled = keep;
                    // qbar_cur is already the residual block Q̄_{k+1}.
                }
            }
            svd_b = Some(svd);
        } else {
            svd_b = Some(svd);
            if converged {
                break;
            }
        }
    }

    let svd = svd_b.expect("at least one outer iteration ran");
    // S8/S9: map back to the problem space: U = P̄·Ū, V = P·V̄.
    be.profile_mut().set_phase(Block::Finalize);
    let u_t = be.gemm_nn(pbar_basis.as_ref(), svd.u.as_ref());
    let v_t = be.gemm_nn(p_basis.as_ref(), svd.v.as_ref());

    Ok(TruncatedSvd {
        u: u_t,
        sigma: svd.s,
        v: v_t,
        profile: be.take_profile(),
        iters,
        est_residuals: est_res,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::residuals;
    use crate::backend::cpu::CpuBackend;
    use crate::gen::dense::{dense_with_spectrum, paper_dense};
    use crate::la::norms::orth_error;

    #[test]
    fn recovers_spectrum_dense() {
        let sigma: Vec<f64> = (0..16).map(|i| 2.0f64.powi(-(i as i32))).collect();
        let prob = dense_with_spectrum(100, 16, &sigma, 1);
        let mut be = CpuBackend::new_dense(prob.a.clone());
        // b ≥ wanted (paper §2.2: "b should be chosen as large as the
        // number of desired singular values" for the restart to preserve
        // a Lanczos vector per wanted triplet).
        let opts = LancSvdOpts { r: 16, p: 6, b: 8, wanted: 6, ..Default::default() };
        let svd = lancsvd(&mut be, &opts).unwrap();
        for i in 0..6 {
            assert!(
                (svd.sigma[i] - sigma[i]).abs() / sigma[i] < 1e-9,
                "sigma_{i}: {} vs {}",
                svd.sigma[i],
                sigma[i]
            );
        }
        assert!(orth_error(&svd.u) < 1e-9, "U orth {}", orth_error(&svd.u));
        assert!(orth_error(&svd.v) < 1e-9, "V orth {}", orth_error(&svd.v));
        let mut be2 = CpuBackend::new_dense(prob.a);
        let res = residuals(&mut be2, &svd, 6);
        assert!(res.iter().all(|&x| x < 1e-8), "residuals {res:?}");
    }

    #[test]
    fn est_residuals_track_true_residuals() {
        let prob = paper_dense(150, 60, 2);
        let mut be = CpuBackend::new_dense(prob.a.clone());
        let opts = LancSvdOpts { r: 32, p: 2, b: 8, wanted: 10, ..Default::default() };
        let svd = lancsvd(&mut be, &opts).unwrap();
        let mut be2 = CpuBackend::new_dense(prob.a);
        let truth = residuals(&mut be2, &svd, 10);
        for i in 0..10 {
            let est = svd.est_residuals[i];
            let act = truth[i];
            // The estimate must be a usable proxy (same order of magnitude
            // or an upper bound within ~100x once converged digits agree).
            assert!(
                est < 1e-6 || act <= est * 100.0,
                "triplet {i}: est {est:.3e} vs act {act:.3e}"
            );
        }
    }

    #[test]
    fn restart_improves_accuracy() {
        // Paper Fig. 4: p=1 gives ~1e-6..1e-4; p=4 reaches ~1e-14.
        let prob = paper_dense(200, 64, 3);
        let a = prob.a.clone();
        let worst = |p: usize| {
            let mut be = CpuBackend::new_dense(a.clone());
            let opts = LancSvdOpts { r: 32, p, b: 8, wanted: 10, seed: 5, ..Default::default() };
            let svd = lancsvd(&mut be, &opts).unwrap();
            let mut be2 = CpuBackend::new_dense(a.clone());
            residuals(&mut be2, &svd, 10).iter().fold(0.0f64, |mx, &x| mx.max(x))
        };
        let r1 = worst(1);
        let r4 = worst(4);
        assert!(r4 < r1, "restarts must not hurt: p1={r1:.3e} p4={r4:.3e}");
        assert!(r4 < 1e-8, "p=4 should converge hard: {r4:.3e}");
    }

    #[test]
    fn tol_stops_early() {
        let prob = paper_dense(150, 48, 4);
        let mut be = CpuBackend::new_dense(prob.a);
        let opts = LancSvdOpts {
            r: 48,
            p: 20,
            b: 8,
            wanted: 6,
            tol: Some(1e-10),
            ..Default::default()
        };
        let svd = lancsvd(&mut be, &opts).unwrap();
        assert!(svd.iters < 20, "should stop early, ran {}", svd.iters);
        assert!(svd.est_residuals.iter().take(6).all(|&x| x < 1e-10));
    }

    #[test]
    fn works_on_sparse_operand() {
        use crate::gen::sparse::{generate, SparseSpec};
        let spec = SparseSpec { rows: 200, cols: 90, nnz: 2500, seed: 9, ..Default::default() };
        let a = generate(&spec);
        let mut be = CpuBackend::new_sparse(a.clone());
        let opts = LancSvdOpts { r: 48, p: 3, b: 16, wanted: 10, seed: 1, ..Default::default() };
        let svd = lancsvd(&mut be, &opts).unwrap();
        let mut be2 = CpuBackend::new_sparse(a);
        let res = residuals(&mut be2, &svd, 10);
        assert!(res.iter().all(|&x| x < 1e-5), "residuals {res:?}");
        // Phases exercised: k = r/b = 3 inner steps × 3 restarts.
        assert!(svd.profile.stat(Block::MultAt).calls >= 9);
        assert!(svd.profile.stat(Block::OrthM).calls > 0);
    }

    #[test]
    fn thick_restart_matches_basic_quality_cheaper() {
        use crate::algo::Restart;
        let prob = paper_dense(400, 96, 8);
        let a = prob.a.clone();
        let solve = |restart: Restart| {
            let mut be = CpuBackend::new_dense(a.clone());
            let svd = lancsvd(
                &mut be,
                &LancSvdOpts { r: 48, p: 4, b: 16, wanted: 10, restart, ..Default::default() },
            )
            .unwrap();
            let mut c = CpuBackend::new_dense(a.clone());
            let res = residuals(&mut c, &svd, 10);
            let flops = svd.profile.total_flops();
            (res.iter().cloned().fold(0.0f64, f64::max), flops)
        };
        let (basic_res, basic_flops) = solve(Restart::Basic);
        let (thick_res, thick_flops) = solve(Restart::Thick { keep: 16 });
        // Same accuracy class, strictly less work per restart.
        assert!(
            thick_res < basic_res.max(1e-12) * 1e3,
            "thick {thick_res:.2e} vs basic {basic_res:.2e}"
        );
        assert!(
            thick_flops < basic_flops,
            "thick must reuse work: {thick_flops:.3e} vs {basic_flops:.3e}"
        );
    }

    #[test]
    fn thick_restart_orthonormal_bases() {
        use crate::algo::Restart;
        let prob = paper_dense(300, 64, 9);
        let mut be = CpuBackend::new_dense(prob.a.clone());
        let svd = lancsvd(
            &mut be,
            &LancSvdOpts {
                r: 32,
                p: 5,
                b: 8,
                wanted: 8,
                restart: Restart::Thick { keep: 8 },
                ..Default::default()
            },
        )
        .unwrap();
        assert!(orth_error(&svd.u) < 1e-9, "U orth {}", orth_error(&svd.u));
        assert!(orth_error(&svd.v) < 1e-9, "V orth {}", orth_error(&svd.v));
        let mut c = CpuBackend::new_dense(prob.a);
        let res = residuals(&mut c, &svd, 8);
        assert!(res.iter().all(|&x| x < 1e-8), "residuals {res:?}");
    }

    #[test]
    fn thick_restart_rejects_keep_too_large() {
        use crate::algo::Restart;
        let prob = paper_dense(100, 40, 2);
        let mut be = CpuBackend::new_dense(prob.a);
        let opts = LancSvdOpts {
            r: 32,
            p: 2,
            b: 16,
            restart: Restart::Thick { keep: 32 },
            ..Default::default()
        };
        assert!(lancsvd(&mut be, &opts).is_err());
    }

    #[test]
    fn rejects_bad_params() {
        let prob = paper_dense(30, 10, 3);
        let mut be = CpuBackend::new_dense(prob.a);
        let bad = |r: usize, p: usize, b: usize| LancSvdOpts {
            r,
            p,
            b,
            ..Default::default()
        };
        assert!(lancsvd(&mut be, &bad(0, 1, 1)).is_err());
        assert!(lancsvd(&mut be, &bad(10, 1, 3)).is_err(), "r not multiple of b");
        assert!(lancsvd(&mut be, &bad(100, 1, 4)).is_err(), "r too large");
        assert!(lancsvd(&mut be, &bad(8, 0, 4)).is_err());
    }
}
