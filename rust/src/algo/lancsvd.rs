//! LancSVD: truncated SVD via the block Golub–Kahan–Lanczos method with
//! one-sided full orthogonalization and the basic restart (Algorithm 2).
//!
//! Each inner iteration extends two orthonormal bases, P ∈ ℝ^{n×r} and
//! P̄ ∈ ℝ^{m×r}, by one b-column block:
//!
//! ```text
//!   Qᵢ   = Aᵀ·Q̄ᵢ      orthogonalized against P(1..i−1)   →  Lᵢ   (S2, S3)
//!   Q̄ᵢ₊₁ = A·Qᵢ       orthogonalized against P̄(1..i)      →  Rᵢ   (S4, S5)
//! ```
//!
//! which assembles the lower block-bidiagonal B_k of Eq. 8 (Lᵢ diagonal
//! blocks, Rᵢ sub-diagonal blocks) satisfying A·P_k = P̄_k·B_k +
//! Q̄_{k+1}·R_k·E_kᵀ. The SVD of B_k then yields the truncated triplets
//! (Eqs. 9–12), and ‖R_k·v̄ᵢ[last b]‖ is a *free* residual estimate used
//! for the restart stopping test.
//!
//! The restart (paper §2.2, Golub/Luk/Overton) re-seeds the iteration with
//! Q̄₁ = P̄·Ū₁, the current approximation of the b leading left singular
//! vectors, preserving the most relevant search directions.
//!
//! ## Allocation-free steady state
//!
//! [`lancsvd`] computes a [`Plan`] from `(m, n, r, p, b)`, allocates a
//! [`Workspace`] (banded first-touch through the worker pool), hands the
//! plan to the backend, and runs [`lancsvd_with`]. Every inner-iteration
//! operand is a borrow of a planned buffer: the new block Qᵢ is computed
//! *in place inside the basis panel* (`split_at_col` separates it from
//! the history it is orthogonalized against), the small factors land in
//! `orth.*` scratch, and the current/next left blocks swap by pointer.
//! Steady-state inner iterations therefore perform zero heap
//! allocations on the CPU backend (pinned by `tests/test_workspace.rs`);
//! per-restart host work (the r×r Jacobi SVD bookkeeping) stays O(r²)
//! and off the device path. Callers with many solves of one shape pass
//! their own workspace to [`lancsvd_with`] and pay setup once.

use crate::backend::Backend;
use crate::error::{Error, Result};
use crate::la::blas1::nrm2;
use crate::la::mat::Mat;
use crate::la::svd::jacobi_svd_into;
use crate::la::workspace::{names, Plan, PlanKind, Workspace};
use crate::metrics::{Block, Timer};
use crate::util::rng::Rng;
use crate::util::scalar::Scalar;

use super::{InitDist, LancSvdOpts, Restart, TruncatedSvd};

/// Validate options against the operand shape; returns the rounded
/// thick-restart keep count (0 for the basic restart).
fn check_opts(m: usize, n: usize, opts: &LancSvdOpts) -> Result<usize> {
    let LancSvdOpts { r, p, b, restart, .. } = *opts;
    if b == 0 || r == 0 || p == 0 {
        return Err(Error::InvalidParam("r, p, b must all be >= 1".into()));
    }
    if r % b != 0 {
        return Err(Error::InvalidParam(format!("r={r} must be a multiple of b={b}")));
    }
    if r > n.min(m) {
        return Err(Error::InvalidParam(format!("r={r} exceeds min dim of {m}x{n}")));
    }
    // Thick restart keeps `keep` Ritz pairs (rounded up to a b multiple);
    // at least one fresh block must fit after them.
    match restart {
        Restart::Basic => Ok(0),
        Restart::Thick { keep } => {
            let k = keep.max(1).div_ceil(b) * b;
            if k + b > r {
                return Err(Error::InvalidParam(format!(
                    "thick restart keep={keep} (rounded {k}) leaves no room in r={r}"
                )));
            }
            Ok(k)
        }
    }
}

/// Run LancSVD on the backend's operand matrix (any [`Scalar`]
/// precision; the paper's GPU regime is `S = f32`). Plans and allocates
/// a fresh workspace; see [`lancsvd_with`] to reuse one across solves.
pub fn lancsvd<S: Scalar, B: Backend<S> + ?Sized>(
    be: &mut B,
    opts: &LancSvdOpts,
) -> Result<TruncatedSvd<S>> {
    let (m, n) = (be.m(), be.n());
    check_opts(m, n, opts)?;
    let ws = Workspace::new(Plan::lancsvd(m, n, opts.r, opts.p, opts.b));
    lancsvd_with(be, opts, &ws)
}

/// [`lancsvd`] over a caller-provided workspace (must have been
/// allocated from a matching [`Plan::lancsvd`]); repeated solves reuse
/// the arena and pay allocation + first-touch once.
pub fn lancsvd_with<S: Scalar, B: Backend<S> + ?Sized>(
    be: &mut B,
    opts: &LancSvdOpts,
    ws: &Workspace<S>,
) -> Result<TruncatedSvd<S>> {
    let (m, n) = (be.m(), be.n());
    let LancSvdOpts { r, p, b, seed, init, tol, wanted, restart, fuse } = opts.clone();
    let keep = check_opts(m, n, opts)?;
    ws.plan().require(PlanKind::LancSvd, m, n, r, b)?;
    be.plan(ws.plan());
    // Fusion policy: explicit opt-in/out via opts, else the cost model
    // (operand larger than LLC, or streamed from disk).
    let fuse = fuse
        .unwrap_or_else(|| crate::cost::should_fuse(be.operand_bytes(), be.operand_on_disk()));

    // Solve-state buffers, borrowed for the whole solve. The orth
    // kernels borrow only their own `orth.{w,l1,l2,hbar,snap}` scratch,
    // so no aliasing can occur; `orth.{h,r}` are borrowed here as the
    // H/small-factor destinations.
    let mut qbar = ws.mat(names::LANC_QBAR, m, b);
    let mut qnext = ws.mat(names::LANC_QNEXT, m, b);
    let mut p_basis = ws.mat(names::LANC_P, n, r);
    let mut pbar_basis = ws.mat(names::LANC_PBAR, m, r);
    let mut bmat = ws.mat(names::LANC_B, r, r);
    let mut rk_last = ws.mat(names::LANC_RK, b, b);
    let mut gram = ws.mat(names::LANC_G, b, b);
    let mut svd_u = ws.mat(names::SVD_U, r, r);
    let mut svd_v = ws.mat(names::SVD_V, r, r);
    let mut tmp = ws.buf(names::LANC_TMP);
    let mut lt_buf = ws.buf(names::ORTH_R);
    let mut h_buf = ws.buf(names::ORTH_H);

    // Reset reused state (the arena may carry a previous solve).
    p_basis.data_mut().fill(S::ZERO);
    pbar_basis.data_mut().fill(S::ZERO);
    bmat.data_mut().fill(S::ZERO);
    rk_last.data_mut().fill(S::ZERO);

    // S1: random orthonormal start block Q̄₁ ∈ ℝ^{m×b}. The host RNG
    // fill is declared to the backend (`stage_in` uploads it on device
    // targets) before the first device op touches it.
    be.profile_mut().set_phase(Block::Init);
    let mut rng = Rng::new(seed);
    match init {
        InitDist::CenteredPoisson => rng.fill_centered_poisson(qbar.data_mut()),
        InitDist::Normal => rng.fill_normal(qbar.data_mut()),
    }
    be.stage_in(qbar.as_ref());
    {
        let lt = lt_buf.view_mut(b, b);
        be.orth_cholqr2_into(qbar.as_mut(), lt, ws)?;
    }

    let mut svals: Vec<S> = Vec::with_capacity(r);
    let mut have_svd = false;
    let mut iters = 0;
    let mut est_res: Vec<f64> = Vec::with_capacity(wanted);
    let mut coupling_tail = vec![S::ZERO; b];
    // Columns of the bases already valid at loop entry (0, or `keep`
    // after a thick restart).
    let mut filled = 0usize;

    for j in 1..=p {
        // Restart boundary: a cooperative safepoint where a serving
        // scheduler can interleave co-tenant solves (no numeric effect;
        // no-op unless the thread installed a hook — `runtime::serve`).
        if j > 1 {
            crate::util::pool::restart_yield();
        }
        iters = j;
        // Extend the bases block-by-block until the Krylov width is full.
        while filled < r {
            let s = filled;
            // Record Q̄ᵢ into P̄ before extending the m-side basis — a
            // device-to-device panel copy, never a host round trip.
            be.copy_into(qbar.as_ref(), pbar_basis.panel_mut(s, b));

            // S2: Qᵢ = Aᵀ·Q̄ᵢ, computed in place inside the P panel.
            be.profile_mut().set_phase(Block::MultAt);
            {
                let (hist, mut rest) = p_basis.split_at_col(s);
                let mut qi = rest.panel_mut(0, b);
                be.apply_at_into(qbar.as_ref(), qi.reborrow());

                // S3: orthogonalize in the n dimension → Lᵢᵀ (upper).
                be.profile_mut().set_phase(Block::OrthN);
                let mut lt = lt_buf.view_mut(b, b);
                if s == 0 {
                    be.orth_cholqr2_into(qi, lt.reborrow(), ws)?; // S3a
                } else {
                    let h = h_buf.view_mut(s, b);
                    be.orth_cgs_cqr2_into(qi, hist, h, lt.reborrow(), ws)?; // S3b
                }
                // B diagonal block: Lᵢ = (Lᵢᵀ)ᵀ, lower triangular.
                for jj in 0..b {
                    for ii in jj..b {
                        bmat.set(s + ii, s + jj, lt.at(jj, ii));
                    }
                }
            }

            // S4: Q̄ᵢ₊₁ = A·Qᵢ. Fused: the b×b Gram Q̄ᵢ₊₁ᵀQ̄ᵢ₊₁ is
            // accumulated in the SAME sweep over the operand's nonzeros
            // while each output band is still in cache, so S5's first
            // CholeskyQR pass can downdate it (W = G − HᵀH) instead of
            // re-reading the m×b panel.
            be.profile_mut().set_phase(Block::MultA);
            if fuse {
                be.apply_a_gram_into(p_basis.panel(s, b), qnext.as_mut(), gram.as_mut());
            } else {
                be.apply_a_into(p_basis.panel(s, b), qnext.as_mut());
            }

            // S5: orthogonalize in the m dimension against P̄ᵢ → Rᵢ.
            be.profile_mut().set_phase(Block::OrthM);
            {
                let hist = pbar_basis.panel(0, s + b);
                let h = h_buf.view_mut(s + b, b);
                let mut ri = lt_buf.view_mut(b, b);
                if fuse {
                    be.orth_cgs_cqr2_pregram_into(
                        qnext.as_mut(),
                        hist,
                        gram.as_ref(),
                        h,
                        ri.reborrow(),
                        ws,
                    )?;
                } else {
                    be.orth_cgs_cqr2_into(qnext.as_mut(), hist, h, ri.reborrow(), ws)?;
                }
                if s + b < r {
                    // B sub-diagonal block (upper-triangular Rᵢ).
                    for jj in 0..b {
                        for ii in 0..=jj {
                            bmat.set(s + b + ii, s + jj, ri.at(ii, jj));
                        }
                    }
                } else {
                    // ‖R_k‖ drives the residual estimate.
                    rk_last.data_mut().copy_from_slice(ri.data);
                }
            }
            std::mem::swap(&mut *qbar, &mut *qnext);
            filled += b;
        }

        // S6: SVD of B_k on the host, into the planned Ū/V̄ buffers.
        be.profile_mut().set_phase(Block::SmallSvd);
        let t = Timer::start(9.0 * (r * r * r) as f64);
        jacobi_svd_into(bmat.as_ref(), svd_u.as_mut(), &mut svals, svd_v.as_mut())?;
        t.stop(be.profile_mut());
        have_svd = true;

        // Free residual estimates: ‖A·(P v̄ᵢ) − σᵢ·(P̄ ūᵢ)‖ = ‖R_k·v̄ᵢ[r−b..r]‖.
        let coupling = |i: usize, tail: &mut [S]| {
            for (t_i, tv) in tail.iter_mut().enumerate() {
                let mut acc = S::ZERO;
                for c in 0..b {
                    acc += rk_last.at(t_i, c) * svd_v.at(r - b + c, i);
                }
                *tv = acc;
            }
        };
        est_res.clear();
        for i in 0..wanted.min(r) {
            let sigma = svals[i];
            if sigma > S::ZERO {
                coupling(i, &mut coupling_tail);
                est_res.push((nrm2(&coupling_tail) / sigma).to_f64());
            } else {
                est_res.push(f64::INFINITY);
            }
        }

        let converged = tol
            .map(|t| est_res.iter().take(wanted).all(|&x| x < t))
            .unwrap_or(false);

        if j < p && !converged {
            be.profile_mut().set_phase(Block::Finalize);
            match restart {
                Restart::Basic => {
                    // S7: Q̄₁ ← P̄·Ū₁ (first b columns of Ū), rebuild all.
                    be.gemm_nn_into(pbar_basis.as_ref(), svd_u.panel(0, b), qbar.as_mut());
                    be.profile_mut().set_phase(Block::OrthM);
                    let lt = lt_buf.view_mut(b, b);
                    be.orth_cholqr2_into(qbar.as_mut(), lt, ws)?;
                    bmat.data_mut().fill(S::ZERO);
                    filled = 0;
                }
                Restart::Thick { .. } => {
                    // Keep `keep` Ritz pairs: new bases are the Ritz
                    // vectors; B becomes the arrow matrix diag(Σ) with
                    // the residual coupling S = R_k·V̄[last b, :keep] in
                    // the first sub-row block; the continuation block is
                    // the *existing* residual Q̄_{k+1} (already ⊥ P̄·Ū).
                    {
                        let mut p_new = tmp.view_mut(n, keep);
                        be.gemm_nn_into(p_basis.as_ref(), svd_v.panel(0, keep), p_new.reborrow());
                        p_basis.data_mut().fill(S::ZERO);
                        be.copy_into(p_new.as_ref(), p_basis.panel_mut(0, keep));
                    }
                    {
                        let mut pbar_new = tmp.view_mut(m, keep);
                        be.gemm_nn_into(
                            pbar_basis.as_ref(),
                            svd_u.panel(0, keep),
                            pbar_new.reborrow(),
                        );
                        pbar_basis.data_mut().fill(S::ZERO);
                        be.copy_into(pbar_new.as_ref(), pbar_basis.panel_mut(0, keep));
                    }
                    bmat.data_mut().fill(S::ZERO);
                    for i in 0..keep {
                        bmat.set(i, i, svals[i]);
                    }
                    for i in 0..keep {
                        coupling(i, &mut coupling_tail);
                        for (t_i, &v) in coupling_tail.iter().enumerate() {
                            bmat.set(keep + t_i, i, v);
                        }
                    }
                    filled = keep;
                    // qbar is already the residual block Q̄_{k+1}.
                }
            }
        } else if converged {
            break;
        }
    }

    debug_assert!(have_svd, "at least one outer iteration ran");
    // S8/S9: map back to the problem space: U = P̄·Ū, V = P·V̄.
    be.profile_mut().set_phase(Block::Finalize);
    let mut u_t = Mat::zeros(m, r);
    be.gemm_nn_into(pbar_basis.as_ref(), svd_u.as_ref(), u_t.as_mut());
    let mut v_t = Mat::zeros(n, r);
    be.gemm_nn_into(p_basis.as_ref(), svd_v.as_ref(), v_t.as_mut());

    Ok(TruncatedSvd {
        u: u_t,
        sigma: svals,
        v: v_t,
        profile: be.take_profile(),
        iters,
        est_residuals: est_res,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::residuals;
    use crate::backend::cpu::CpuBackend;
    use crate::gen::dense::{dense_with_spectrum, paper_dense};
    use crate::la::norms::orth_error;

    #[test]
    fn recovers_spectrum_dense() {
        let sigma: Vec<f64> = (0..16).map(|i| 2.0f64.powi(-(i as i32))).collect();
        let prob = dense_with_spectrum(100, 16, &sigma, 1);
        let mut be = CpuBackend::new_dense(prob.a.clone());
        // b ≥ wanted (paper §2.2: "b should be chosen as large as the
        // number of desired singular values" for the restart to preserve
        // a Lanczos vector per wanted triplet).
        let opts = LancSvdOpts { r: 16, p: 6, b: 8, wanted: 6, ..Default::default() };
        let svd = lancsvd(&mut be, &opts).unwrap();
        for i in 0..6 {
            assert!(
                (svd.sigma[i] - sigma[i]).abs() / sigma[i] < 1e-9,
                "sigma_{i}: {} vs {}",
                svd.sigma[i],
                sigma[i]
            );
        }
        assert!(orth_error(&svd.u) < 1e-9, "U orth {}", orth_error(&svd.u));
        assert!(orth_error(&svd.v) < 1e-9, "V orth {}", orth_error(&svd.v));
        let mut be2 = CpuBackend::new_dense(prob.a);
        let res = residuals(&mut be2, &svd, 6);
        assert!(res.iter().all(|&x| x < 1e-8), "residuals {res:?}");
    }

    #[test]
    fn workspace_reuse_across_solves_is_exact() {
        // Two solves through one arena must equal a fresh-workspace
        // solve bitwise (plan reuse across restarts/solves).
        let prob = paper_dense(120, 40, 6);
        let opts = LancSvdOpts { r: 16, p: 3, b: 8, wanted: 5, ..Default::default() };
        let mut be = CpuBackend::new_dense(prob.a.clone());
        let fresh = lancsvd(&mut be, &opts).unwrap();
        let ws = Workspace::new(Plan::lancsvd(120, 40, 16, 3, 8));
        let mut be1 = CpuBackend::new_dense(prob.a.clone());
        let first = lancsvd_with(&mut be1, &opts, &ws).unwrap();
        let mut be2 = CpuBackend::new_dense(prob.a.clone());
        let second = lancsvd_with(&mut be2, &opts, &ws).unwrap();
        for i in 0..5 {
            assert_eq!(fresh.sigma[i], first.sigma[i], "fresh vs first sigma_{i}");
            assert_eq!(first.sigma[i], second.sigma[i], "first vs second sigma_{i}");
        }
        assert_eq!(first.u.data(), second.u.data(), "U must be reproducible");
        assert_eq!(first.v.data(), second.v.data(), "V must be reproducible");
        // A mismatched workspace is rejected, not misused.
        let bad = Workspace::new(Plan::lancsvd(120, 40, 32, 3, 8));
        let mut be3 = CpuBackend::new_dense(prob.a);
        assert!(lancsvd_with(&mut be3, &opts, &bad).is_err());
    }

    #[test]
    fn est_residuals_track_true_residuals() {
        let prob = paper_dense(150, 60, 2);
        let mut be = CpuBackend::new_dense(prob.a.clone());
        let opts = LancSvdOpts { r: 32, p: 2, b: 8, wanted: 10, ..Default::default() };
        let svd = lancsvd(&mut be, &opts).unwrap();
        let mut be2 = CpuBackend::new_dense(prob.a);
        let truth = residuals(&mut be2, &svd, 10);
        for i in 0..10 {
            let est = svd.est_residuals[i];
            let act = truth[i];
            // The estimate must be a usable proxy (same order of magnitude
            // or an upper bound within ~100x once converged digits agree).
            assert!(
                est < 1e-6 || act <= est * 100.0,
                "triplet {i}: est {est:.3e} vs act {act:.3e}"
            );
        }
    }

    #[test]
    fn restart_improves_accuracy() {
        // Paper Fig. 4: p=1 gives ~1e-6..1e-4; p=4 reaches ~1e-14.
        let prob = paper_dense(200, 64, 3);
        let a = prob.a.clone();
        let worst = |p: usize| {
            let mut be = CpuBackend::new_dense(a.clone());
            let opts = LancSvdOpts { r: 32, p, b: 8, wanted: 10, seed: 5, ..Default::default() };
            let svd = lancsvd(&mut be, &opts).unwrap();
            let mut be2 = CpuBackend::new_dense(a.clone());
            residuals(&mut be2, &svd, 10).iter().fold(0.0f64, |mx, &x| mx.max(x))
        };
        let r1 = worst(1);
        let r4 = worst(4);
        assert!(r4 < r1, "restarts must not hurt: p1={r1:.3e} p4={r4:.3e}");
        assert!(r4 < 1e-8, "p=4 should converge hard: {r4:.3e}");
    }

    #[test]
    fn tol_stops_early() {
        let prob = paper_dense(150, 48, 4);
        let mut be = CpuBackend::new_dense(prob.a);
        let opts = LancSvdOpts {
            r: 48,
            p: 20,
            b: 8,
            wanted: 6,
            tol: Some(1e-10),
            ..Default::default()
        };
        let svd = lancsvd(&mut be, &opts).unwrap();
        assert!(svd.iters < 20, "should stop early, ran {}", svd.iters);
        assert!(svd.est_residuals.iter().take(6).all(|&x| x < 1e-10));
    }

    #[test]
    fn works_on_sparse_operand() {
        use crate::gen::sparse::{generate, SparseSpec};
        let spec = SparseSpec { rows: 200, cols: 90, nnz: 2500, seed: 9, ..Default::default() };
        let a = generate(&spec);
        let mut be = CpuBackend::new_sparse(a.clone());
        let opts = LancSvdOpts { r: 48, p: 3, b: 16, wanted: 10, seed: 1, ..Default::default() };
        let svd = lancsvd(&mut be, &opts).unwrap();
        let mut be2 = CpuBackend::new_sparse(a);
        let res = residuals(&mut be2, &svd, 10);
        assert!(res.iter().all(|&x| x < 1e-5), "residuals {res:?}");
        // Phases exercised: k = r/b = 3 inner steps × 3 restarts.
        assert!(svd.profile.stat(Block::MultAt).calls >= 9);
        assert!(svd.profile.stat(Block::OrthM).calls > 0);
    }

    #[test]
    fn thick_restart_matches_basic_quality_cheaper() {
        use crate::algo::Restart;
        let prob = paper_dense(400, 96, 8);
        let a = prob.a.clone();
        let solve = |restart: Restart| {
            let mut be = CpuBackend::new_dense(a.clone());
            let svd = lancsvd(
                &mut be,
                &LancSvdOpts { r: 48, p: 4, b: 16, wanted: 10, restart, ..Default::default() },
            )
            .unwrap();
            let mut c = CpuBackend::new_dense(a.clone());
            let res = residuals(&mut c, &svd, 10);
            let flops = svd.profile.total_flops();
            (res.iter().cloned().fold(0.0f64, f64::max), flops)
        };
        let (basic_res, basic_flops) = solve(Restart::Basic);
        let (thick_res, thick_flops) = solve(Restart::Thick { keep: 16 });
        // Same accuracy class, strictly less work per restart.
        assert!(
            thick_res < basic_res.max(1e-12) * 1e3,
            "thick {thick_res:.2e} vs basic {basic_res:.2e}"
        );
        assert!(
            thick_flops < basic_flops,
            "thick must reuse work: {thick_flops:.3e} vs {basic_flops:.3e}"
        );
    }

    #[test]
    fn thick_restart_orthonormal_bases() {
        use crate::algo::Restart;
        let prob = paper_dense(300, 64, 9);
        let mut be = CpuBackend::new_dense(prob.a.clone());
        let svd = lancsvd(
            &mut be,
            &LancSvdOpts {
                r: 32,
                p: 5,
                b: 8,
                wanted: 8,
                restart: Restart::Thick { keep: 8 },
                ..Default::default()
            },
        )
        .unwrap();
        assert!(orth_error(&svd.u) < 1e-9, "U orth {}", orth_error(&svd.u));
        assert!(orth_error(&svd.v) < 1e-9, "V orth {}", orth_error(&svd.v));
        let mut c = CpuBackend::new_dense(prob.a);
        let res = residuals(&mut c, &svd, 8);
        assert!(res.iter().all(|&x| x < 1e-8), "residuals {res:?}");
    }

    #[test]
    fn thick_restart_rejects_keep_too_large() {
        use crate::algo::Restart;
        let prob = paper_dense(100, 40, 2);
        let mut be = CpuBackend::new_dense(prob.a);
        let opts = LancSvdOpts {
            r: 32,
            p: 2,
            b: 16,
            restart: Restart::Thick { keep: 32 },
            ..Default::default()
        };
        assert!(lancsvd(&mut be, &opts).is_err());
    }

    #[test]
    fn rejects_bad_params() {
        let prob = paper_dense(30, 10, 3);
        let mut be = CpuBackend::new_dense(prob.a);
        let bad = |r: usize, p: usize, b: usize| LancSvdOpts {
            r,
            p,
            b,
            ..Default::default()
        };
        assert!(lancsvd(&mut be, &bad(0, 1, 1)).is_err());
        assert!(lancsvd(&mut be, &bad(10, 1, 3)).is_err(), "r not multiple of b");
        assert!(lancsvd(&mut be, &bad(100, 1, 4)).is_err(), "r too large");
        assert!(lancsvd(&mut be, &bad(8, 0, 4)).is_err());
    }
}
