//! Orthogonalization building blocks (Algorithms 4 and 5 of the paper).
//!
//! * [`cholqr2`] — CholeskyQR2 (Alg. 4): Gram → POTRF → TRSM, twice.
//! * [`cgs_cqr2`] — block classical Gram-Schmidt against a fixed panel
//!   followed by CholeskyQR2, with a full second pass (Alg. 5).
//!
//! Both keep the paper's hybrid split: the Gram products, CGS projections
//! and triangular solves run on the device [`Backend`]; the tiny b×b
//! Cholesky runs on the host. On a Cholesky breakdown (rank-deficient
//! panel) the code falls back to column-wise CGS2 (paper §3.2), completing
//! dead columns with fresh random directions so the returned Q always has
//! orthonormal columns.

use crate::backend::Backend;
use crate::error::{Error, Result};
use crate::la::blas1::{axpy, dot, nrm2, scal};
use crate::la::blas3::trmm_lt_lt;
use crate::la::chol::potrf;
use crate::la::mat::{Mat, MatRef};
use crate::metrics::Timer;
use crate::util::rng::Rng;
use crate::util::scalar::Scalar;

/// One CholeskyQR pass: W = QᵀQ, L = chol(W), Q ← Q·L⁻ᵀ. Returns L.
/// The POTRF is charged to the current phase as host (small-factor) work.
fn cholqr_pass<S: Scalar, B: Backend<S> + ?Sized>(be: &mut B, q: &mut Mat<S>) -> Result<Mat<S>> {
    let w = be.gram(q.as_ref());
    let b = w.rows();
    let t = Timer::start(b as f64 * b as f64 * b as f64 / 3.0);
    let l = potrf(&w);
    t.stop(be.profile_mut());
    let l = l?;
    be.tri_solve_right(q, &l);
    Ok(l)
}

/// CholeskyQR2 (Alg. 4). Orthonormalizes the q×b panel `q` in place and
/// returns the upper-triangular R (b×b) with `Q_in = Q_out · R`.
///
/// Note on Alg. 4 step S7: the paper prints `R = Lᵀ·L̄ᵀ`, but from
/// Q₀ = Q₁Lᵀ and Q₁ = Q₂L̄ᵀ it follows Q₀ = Q₂·(L̄ᵀLᵀ), so the factor
/// consistent with `Q_in = Q_out·R` is `R = L̄ᵀ·Lᵀ`; we compute that and
/// verify it by reconstruction in the tests.
pub fn cholqr2_host<S: Scalar, B: Backend<S> + ?Sized>(
    be: &mut B,
    q: &mut Mat<S>,
) -> Result<Mat<S>> {
    let snapshot = q.clone();
    let l1 = match cholqr_pass(be, q) {
        Ok(l) => l,
        Err(Error::CholeskyBreakdown { .. }) => {
            *q = snapshot;
            return cgs2_fallback(be, q, None);
        }
        Err(e) => return Err(e),
    };
    let l2 = match cholqr_pass(be, q) {
        Ok(l) => l,
        Err(Error::CholeskyBreakdown { .. }) => {
            *q = snapshot;
            return cgs2_fallback(be, q, None);
        }
        Err(e) => return Err(e),
    };
    // R = L̄ᵀ·Lᵀ (upper triangular; see doc comment). Charged at the
    // Table-1 TRMM cost (b³) so model == instrumentation exactly.
    let b = l1.rows();
    let t = Timer::start((b * b * b) as f64);
    let r = trmm_lt_lt(&l2, &l1);
    t.stop(be.profile_mut());
    Ok(r)
}

/// CGS + CholeskyQR2 orthogonalization against a fixed panel (Alg. 5).
///
/// Orthogonalizes the q×b panel `q` against `p` (q×s, orthonormal) and
/// within itself, in place, with a full second pass. Returns `(H, R)` with
/// H s×b, R b×b upper triangular such that `Q_in ≈ P·H + Q_out·R`.
/// Following the paper's step S12, H is accumulated as H + H̄ (the exact
/// correction H + H̄·Lᵀ differs at rounding level only).
pub fn cgs_cqr2_host<S: Scalar, B: Backend<S> + ?Sized>(
    be: &mut B,
    q: &mut Mat<S>,
    p: MatRef<'_, S>,
) -> Result<(Mat<S>, Mat<S>)> {
    assert_eq!(p.rows, q.rows(), "cgs_cqr2 panel rows");
    let snapshot = q.clone();
    // First pass: project out P, then CholeskyQR.
    let mut h = be.proj(p, q.as_ref()); // S1
    be.subtract_proj(q, p, &h); // S2
    let l1 = match cholqr_pass(be, q) {
        Ok(l) => l,
        Err(Error::CholeskyBreakdown { .. }) => {
            // For the fallback path H is recomputed directly from the
            // snapshot: H = Pᵀ·Q_in (P orthonormal).
            let h = be.proj(p, snapshot.as_ref());
            *q = snapshot;
            let r = cgs2_fallback(be, q, Some(p))?;
            return Ok((h, r));
        }
        Err(e) => return Err(e),
    };
    // Second pass: re-project and re-normalize.
    let hbar = be.proj(p, q.as_ref()); // S6
    be.subtract_proj(q, p, &hbar); // S7
    let l2 = match cholqr_pass(be, q) {
        Ok(l) => l,
        Err(Error::CholeskyBreakdown { .. }) => {
            *q = snapshot.clone();
            let r = cgs2_fallback(be, q, Some(p))?;
            let h = be.proj(p, snapshot.as_ref());
            return Ok((h, r));
        }
        Err(e) => return Err(e),
    };
    // S11: R = L̄ᵀ·Lᵀ (see cholqr2 note); S12: H += H̄. Charged at the
    // Table-1 costs (b³ TRMM + s·b add) for exact model validation.
    let b = l1.rows();
    let t = Timer::start((b * b * b) as f64 + (h.rows() * h.cols()) as f64);
    let r = trmm_lt_lt(&l2, &l1);
    for (hv, hb) in h.data_mut().iter_mut().zip(hbar.data()) {
        *hv += *hb;
    }
    t.stop(be.profile_mut());
    Ok((h, r))
}

/// Backend-dispatching entry point for Alg. 4 (the XLA backend overrides
/// the trait method with its fused AOT graph).
pub fn cholqr2<S: Scalar, B: Backend<S> + ?Sized>(be: &mut B, q: &mut Mat<S>) -> Result<Mat<S>> {
    be.orth_cholqr2(q)
}

/// Backend-dispatching entry point for Alg. 5.
pub fn cgs_cqr2<S: Scalar, B: Backend<S> + ?Sized>(
    be: &mut B,
    q: &mut Mat<S>,
    p: MatRef<'_, S>,
) -> Result<(Mat<S>, Mat<S>)> {
    be.orth_cgs_cqr2(q, p)
}

/// Column-wise classical Gram-Schmidt with re-orthogonalization — the
/// breakdown fallback of paper §3.2. Orthonormalizes `q` in place against
/// `p` (if given) and itself; returns the triangular factor R. Columns
/// that vanish (exact rank deficiency) are replaced by fresh random
/// directions (their R column is zero).
pub fn cgs2_fallback<S: Scalar, B: Backend<S> + ?Sized>(
    be: &mut B,
    q: &mut Mat<S>,
    p: Option<MatRef<'_, S>>,
) -> Result<Mat<S>> {
    let rows = q.rows();
    let b = q.cols();
    let t = Timer::start(0.0); // wall-time only; flop count folded into R
    let mut r = Mat::zeros(b, b);
    let mut rng = Rng::new(0x5EED_FA11);
    for j in 0..b {
        let mut norm_orig = nrm2(q.col(j));
        if norm_orig == S::ZERO {
            norm_orig = S::ONE;
        }
        let mut attempts = 0;
        loop {
            // Two CGS passes against P and the already-finished columns.
            for _pass in 0..2 {
                if let Some(pp) = p {
                    for kcol in 0..pp.cols {
                        let coef = dot(pp.col(kcol), q.col(j));
                        let pc = pp.col(kcol).to_vec();
                        axpy(-coef, &pc, q.col_mut(j));
                    }
                }
                for i in 0..j {
                    let coef = dot(q.col(i), q.col(j));
                    if _pass == 0 && attempts == 0 {
                        r.add_at(i, j, coef);
                    }
                    let ci = q.col(i).to_vec();
                    axpy(-coef, &ci, q.col_mut(j));
                }
            }
            let nn = nrm2(q.col(j));
            // Dead-column cutoff scales with the working precision
            // (ε-relative, ~1e-14 at f64 / ~1e-5 at f32).
            if nn > S::from_f64(100.0) * S::EPSILON * norm_orig.max(S::ONE) {
                if attempts == 0 {
                    r.set(j, j, nn);
                }
                scal(S::ONE / nn, q.col_mut(j));
                break;
            }
            // Dead column: replace with a random direction, R entry 0.
            attempts += 1;
            if attempts > 8 {
                return Err(Error::InvalidParam(format!(
                    "cgs2 fallback could not complete column {j} of a {rows}x{b} panel"
                )));
            }
            let mut fresh = vec![S::ZERO; rows];
            rng.fill_normal(&mut fresh);
            q.col_mut(j).copy_from_slice(&fresh);
            for ri in 0..b {
                if ri != j {
                    r.set(ri, j, if ri < j { r.at(ri, j) } else { S::ZERO });
                }
            }
            r.set(j, j, S::ZERO);
        }
    }
    t.stop(be.profile_mut());
    Ok(r)
}

/// Generate a random orthonormal q×b panel via the backend (paper Alg. 2
/// step S1: random init + Alg. 4 orthonormalization).
pub fn random_orthonormal_panel<S: Scalar, B: Backend<S> + ?Sized>(
    be: &mut B,
    rows: usize,
    b: usize,
    rng: &mut Rng,
) -> Result<Mat<S>> {
    let mut q = Mat::rand_centered_poisson(rows, b, rng);
    cholqr2(be, &mut q)?;
    Ok(q)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::cpu::CpuBackend;
    use crate::la::blas3::{mat_nn, mat_tn};
    use crate::la::norms::orth_error;

    fn dummy_backend() -> CpuBackend {
        // The operand matrix is irrelevant for orthogonalization ops.
        CpuBackend::new_dense(Mat::zeros(1, 1))
    }

    #[test]
    fn cholqr2_orthonormalizes_and_reconstructs() {
        let mut be = dummy_backend();
        let mut rng = Rng::new(1);
        for &(q_rows, b) in &[(50usize, 8usize), (200, 16), (64, 1)] {
            let y = Mat::randn(q_rows, b, &mut rng);
            let mut q = y.clone();
            let r = cholqr2(&mut be, &mut q).unwrap();
            assert!(orth_error(&q) < 1e-13, "orth {q_rows}x{b}");
            let back = mat_nn(&q, &r);
            let scale = y.fro_norm();
            assert!(back.max_abs_diff(&y) / scale < 1e-13, "reconstruct {q_rows}x{b}");
            // R upper triangular
            for j in 0..b {
                for i in (j + 1)..b {
                    assert_eq!(r.at(i, j), 0.0);
                }
            }
        }
    }

    #[test]
    fn cholqr2_ill_conditioned_panel() {
        // Columns with widely varying scales: CholeskyQR-1 would lose
        // orthogonality; the second pass must recover it.
        let mut be = dummy_backend();
        let mut rng = Rng::new(2);
        let mut y = Mat::randn(100, 6, &mut rng);
        for j in 0..6 {
            let s = 10f64.powi(-2 * j as i32);
            scal(s, y.col_mut(j));
        }
        let mut q = y.clone();
        let r = cholqr2(&mut be, &mut q).unwrap();
        assert!(orth_error(&q) < 1e-12);
        assert!(mat_nn(&q, &r).max_abs_diff(&y) / y.fro_norm() < 1e-12);
    }

    #[test]
    fn cholqr2_breakdown_falls_back() {
        // Exactly rank-deficient panel triggers the CGS2 fallback.
        let mut be = dummy_backend();
        let mut rng = Rng::new(3);
        let mut y = Mat::randn(40, 5, &mut rng);
        let c0 = y.col(0).to_vec();
        y.col_mut(3).copy_from_slice(&c0);
        let mut q = y.clone();
        let _r = cholqr2(&mut be, &mut q).unwrap();
        assert!(orth_error(&q) < 1e-10, "fallback orthonormal: {}", orth_error(&q));
    }

    #[test]
    fn cgs_cqr2_orthogonal_to_panel_and_reconstructs() {
        let mut be = dummy_backend();
        let mut rng = Rng::new(4);
        let rows = 120;
        let (s, b) = (12, 6);
        let p = crate::la::qr::random_orthonormal(rows, s, &mut rng);
        let y = Mat::randn(rows, b, &mut rng);
        let mut q = y.clone();
        let (h, r) = cgs_cqr2(&mut be, &mut q, p.as_ref()).unwrap();
        // Q orthonormal and ⟂ P
        assert!(orth_error(&q) < 1e-13);
        let cross = mat_tn(&p, &q);
        assert!(cross.fro_norm() < 1e-12, "cross {}", cross.fro_norm());
        // Y ≈ P·H + Q·R
        let back = {
            let mut t = mat_nn(&p, &h);
            let qr = mat_nn(&q, &r);
            for (a, c) in t.data_mut().iter_mut().zip(qr.data()) {
                *a += c;
            }
            t
        };
        assert!(back.max_abs_diff(&y) / y.fro_norm() < 1e-12);
        assert_eq!((h.rows(), h.cols()), (s, b));
    }

    #[test]
    fn cgs_cqr2_on_vector_already_in_span() {
        // Columns of Y that lie inside span(P) should break down to the
        // fallback and still produce an orthonormal Q.
        let mut be = dummy_backend();
        let mut rng = Rng::new(5);
        let rows = 60;
        let p = crate::la::qr::random_orthonormal(rows, 8, &mut rng);
        let mut y = Mat::zeros(rows, 4);
        // First two columns are combinations of P's columns.
        for j in 0..2 {
            let mut comb = vec![0.0; rows];
            for k in 0..8 {
                axpy(rng.normal(), p.col(k), &mut comb);
            }
            y.col_mut(j).copy_from_slice(&comb);
        }
        for j in 2..4 {
            let mut v = vec![0.0; rows];
            rng.fill_normal(&mut v);
            y.col_mut(j).copy_from_slice(&v);
        }
        let mut q = y.clone();
        let (_h, _r) = cgs_cqr2(&mut be, &mut q, p.as_ref()).unwrap();
        assert!(orth_error(&q) < 1e-9, "orth {}", orth_error(&q));
        let cross = mat_tn(&p, &q);
        assert!(cross.fro_norm() < 1e-9, "cross {}", cross.fro_norm());
    }

    #[test]
    fn random_panel_is_orthonormal() {
        let mut be = dummy_backend();
        let mut rng = Rng::new(6);
        let q = random_orthonormal_panel(&mut be, 80, 16, &mut rng).unwrap();
        assert_eq!((q.rows(), q.cols()), (80, 16));
        assert!(orth_error(&q) < 1e-13);
    }
}
