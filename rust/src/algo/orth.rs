//! Orthogonalization building blocks (Algorithms 4 and 5 of the paper),
//! in workspace-planned out-parameter form.
//!
//! * [`cholqr2_into`] — CholeskyQR2 (Alg. 4): Gram → POTRF → TRSM, twice,
//!   in place on a borrowed panel, R written into a caller buffer.
//! * [`cgs_cqr2_into`] — block classical Gram-Schmidt against a fixed
//!   panel followed by CholeskyQR2, with a full second pass (Alg. 5);
//!   H and R written into caller buffers.
//!
//! All per-pass scratch (the b×b Gram/Cholesky factors, the second-pass
//! projection block, and the breakdown snapshot) comes from the solve's
//! [`Workspace`] (`orth.*` entries), so in steady state these kernels
//! perform **zero heap allocations** — the breakdown fallback path is
//! the only exception and is exercised only on rank-deficient panels.
//! The legacy value-returning forms ([`cholqr2`], [`cgs_cqr2`]) remain
//! as thin wrappers for tests and one-shot callers.
//!
//! Both keep the paper's hybrid split: the Gram products, CGS projections
//! and triangular solves run on the device [`Backend`]; the tiny b×b
//! Cholesky runs on the host (in place on a workspace buffer). On a
//! Cholesky breakdown (rank-deficient panel) the code falls back to
//! column-wise CGS2 (paper §3.2), completing dead columns with fresh
//! random directions so the returned Q always has orthonormal columns.

use crate::backend::Backend;
use crate::error::{Error, Result};
use crate::la::blas1::{axpy, dot, nrm2, scal};
use crate::la::chol::potrf_into;
use crate::la::mat::{Mat, MatMut, MatRef};
use crate::la::workspace::{names, Workspace};
use crate::metrics::Timer;
use crate::util::rng::Rng;
use crate::util::scalar::Scalar;

/// One CholeskyQR pass: W = QᵀQ, L = chol(W), Q ← Q·L⁻ᵀ. W comes from
/// the workspace; L is written into the caller's buffer (it outlives
/// the pass — the factor product needs both passes' L). The POTRF is
/// charged to the current phase as host (small-factor) work.
fn cholqr_pass_into<S: Scalar, B: Backend<S> + ?Sized>(
    be: &mut B,
    q: &mut MatMut<'_, S>,
    l: &mut MatMut<'_, S>,
    ws: &Workspace<S>,
) -> Result<()> {
    let b = q.cols;
    {
        let mut w_buf = ws.buf(names::ORTH_W);
        let mut w = w_buf.view_mut(b, b);
        be.gram_into(q.as_ref(), w.reborrow());
        let t = Timer::start(b as f64 * b as f64 * b as f64 / 3.0);
        let res = potrf_into(w.as_ref(), l.reborrow());
        t.stop(be.profile_mut());
        res?;
    }
    be.tri_solve_right(q.reborrow(), l.as_ref());
    Ok(())
}

/// CholeskyQR2 (Alg. 4), host composition (the trait's default for
/// [`Backend::orth_cholqr2_into`]). Orthonormalizes the q×b panel in
/// place and writes the upper-triangular R (b×b, `Q_in = Q_out·R`) into
/// `r`.
///
/// Note on Alg. 4 step S7: the paper prints `R = Lᵀ·L̄ᵀ`, but from
/// Q₀ = Q₁Lᵀ and Q₁ = Q₂L̄ᵀ it follows Q₀ = Q₂·(L̄ᵀLᵀ), so the factor
/// consistent with `Q_in = Q_out·R` is `R = L̄ᵀ·Lᵀ`; we compute that and
/// verify it by reconstruction in the tests.
pub fn cholqr2_into_host<S: Scalar, B: Backend<S> + ?Sized>(
    be: &mut B,
    mut q: MatMut<'_, S>,
    r: MatMut<'_, S>,
    ws: &Workspace<S>,
) -> Result<()> {
    let b = q.cols;
    assert_eq!((r.rows, r.cols), (b, b), "cholqr2 R shape");
    // Snapshot for the breakdown fallback (restores Q_in exactly).
    let mut snap_buf = ws.buf(names::ORTH_SNAP);
    let mut snap = snap_buf.view_mut(q.rows, b);
    snap.data.copy_from_slice(q.data);
    let mut l1_buf = ws.buf(names::ORTH_L1);
    let mut l1 = l1_buf.view_mut(b, b);
    let mut l2_buf = ws.buf(names::ORTH_L2);
    let mut l2 = l2_buf.view_mut(b, b);
    match cholqr_pass_into(be, &mut q, &mut l1, ws) {
        Ok(()) => {}
        Err(Error::CholeskyBreakdown { .. }) => {
            q.data.copy_from_slice(snap.data);
            return cgs2_fallback(be, q, None, r);
        }
        Err(e) => return Err(e),
    }
    match cholqr_pass_into(be, &mut q, &mut l2, ws) {
        Ok(()) => {}
        Err(Error::CholeskyBreakdown { .. }) => {
            q.data.copy_from_slice(snap.data);
            return cgs2_fallback(be, q, None, r);
        }
        Err(e) => return Err(e),
    }
    // R = L̄ᵀ·Lᵀ (upper triangular; see doc comment). Charged at the
    // Table-1 TRMM cost (b³) so model == instrumentation exactly.
    let t = Timer::start((b * b * b) as f64);
    crate::la::blas3::trmm_lt_lt_into(l2.as_ref(), l1.as_ref(), r);
    t.stop(be.profile_mut());
    Ok(())
}

/// CGS + CholeskyQR2 orthogonalization against a fixed panel (Alg. 5),
/// host composition (the trait's default for
/// [`Backend::orth_cgs_cqr2_into`]).
///
/// Orthogonalizes the q×b panel against `p` (q×s, orthonormal) and
/// within itself, in place, with a full second pass. Writes H (s×b) and
/// R (b×b upper triangular) such that `Q_in ≈ P·H + Q_out·R`. Following
/// the paper's step S12, H is accumulated as H + H̄ (the exact
/// correction H + H̄·Lᵀ differs at rounding level only).
pub fn cgs_cqr2_into_host<S: Scalar, B: Backend<S> + ?Sized>(
    be: &mut B,
    mut q: MatMut<'_, S>,
    p: MatRef<'_, S>,
    mut h: MatMut<'_, S>,
    mut r: MatMut<'_, S>,
    ws: &Workspace<S>,
) -> Result<()> {
    assert_eq!(p.rows, q.rows, "cgs_cqr2 panel rows");
    let b = q.cols;
    assert_eq!((h.rows, h.cols), (p.cols, b), "cgs_cqr2 H shape");
    assert_eq!((r.rows, r.cols), (b, b), "cgs_cqr2 R shape");
    let mut snap_buf = ws.buf(names::ORTH_SNAP);
    let mut snap = snap_buf.view_mut(q.rows, b);
    snap.data.copy_from_slice(q.data);
    // First pass: project out P, then CholeskyQR.
    be.proj_into(p, q.as_ref(), h.reborrow()); // S1
    be.subtract_proj(q.reborrow(), p, h.as_ref()); // S2
    let mut l1_buf = ws.buf(names::ORTH_L1);
    let mut l1 = l1_buf.view_mut(b, b);
    let mut l2_buf = ws.buf(names::ORTH_L2);
    let mut l2 = l2_buf.view_mut(b, b);
    match cholqr_pass_into(be, &mut q, &mut l1, ws) {
        Ok(()) => {}
        Err(Error::CholeskyBreakdown { .. }) => {
            // For the fallback path H is recomputed directly from the
            // snapshot: H = Pᵀ·Q_in (P orthonormal).
            be.proj_into(p, snap.as_ref(), h.reborrow());
            q.data.copy_from_slice(snap.data);
            return cgs2_fallback(be, q, Some(p), r);
        }
        Err(e) => return Err(e),
    }
    // Second pass: re-project and re-normalize.
    let mut hbar_buf = ws.buf(names::ORTH_HBAR);
    let mut hbar = hbar_buf.view_mut(p.cols, b);
    be.proj_into(p, q.as_ref(), hbar.reborrow()); // S6
    be.subtract_proj(q.reborrow(), p, hbar.as_ref()); // S7
    match cholqr_pass_into(be, &mut q, &mut l2, ws) {
        Ok(()) => {}
        Err(Error::CholeskyBreakdown { .. }) => {
            be.proj_into(p, snap.as_ref(), h.reborrow());
            q.data.copy_from_slice(snap.data);
            return cgs2_fallback(be, q, Some(p), r);
        }
        Err(e) => return Err(e),
    }
    // S11: R = L̄ᵀ·Lᵀ (see cholqr2 note); S12: H += H̄. Charged at the
    // Table-1 costs (b³ TRMM + s·b add) for exact model validation.
    let t = Timer::start((b * b * b) as f64 + (h.rows * h.cols) as f64);
    crate::la::blas3::trmm_lt_lt_into(l2.as_ref(), l1.as_ref(), r.reborrow());
    for (hv, hb) in h.data.iter_mut().zip(hbar.data.iter()) {
        *hv += *hb;
    }
    t.stop(be.profile_mut());
    Ok(())
}

/// CGS + CholeskyQR2 with the panel Gram precomputed (contract rule 8;
/// the trait's default for [`Backend::orth_cgs_cqr2_pregram_into`]).
///
/// `g` must hold QᵀQ of the *incoming* panel — the fused
/// `apply_a_gram_into` sweep produces it alongside the panel itself.
/// The first CholeskyQR pass then forms its Gram by the downdate
/// `W = G − HᵀH` instead of re-streaming the q×b panel: with `p`
/// orthonormal (the Lanczos invariant) and `Q₁ = Q − P·H`,
/// `Q₁ᵀQ₁ = G − HᵀH` exactly in exact arithmetic. The downdate can lose
/// positive-definiteness to rounding where the direct Gram would not,
/// so on a first-pass breakdown the Gram is recomputed directly and the
/// Cholesky retried before falling back to CGS2. The second pass is the
/// standard re-streamed one — it restores orthogonality to machine
/// precision, which is what keeps the fused path ε-equal to the unfused
/// composition.
pub fn cgs_cqr2_pregram_into_host<S: Scalar, B: Backend<S> + ?Sized>(
    be: &mut B,
    mut q: MatMut<'_, S>,
    p: MatRef<'_, S>,
    g: MatRef<'_, S>,
    mut h: MatMut<'_, S>,
    mut r: MatMut<'_, S>,
    ws: &Workspace<S>,
) -> Result<()> {
    assert_eq!(p.rows, q.rows, "cgs_cqr2 panel rows");
    let b = q.cols;
    assert_eq!((g.rows, g.cols), (b, b), "cgs_cqr2 pregram G shape");
    assert_eq!((h.rows, h.cols), (p.cols, b), "cgs_cqr2 H shape");
    assert_eq!((r.rows, r.cols), (b, b), "cgs_cqr2 R shape");
    let mut snap_buf = ws.buf(names::ORTH_SNAP);
    let mut snap = snap_buf.view_mut(q.rows, b);
    snap.data.copy_from_slice(q.data);
    // First pass: project out P, then CholeskyQR on the downdated Gram.
    be.proj_into(p, q.as_ref(), h.reborrow()); // S1
    be.subtract_proj(q.reborrow(), p, h.as_ref()); // S2
    let mut l1_buf = ws.buf(names::ORTH_L1);
    let mut l1 = l1_buf.view_mut(b, b);
    let mut l2_buf = ws.buf(names::ORTH_L2);
    let mut l2 = l2_buf.view_mut(b, b);
    let first = {
        let mut w_buf = ws.buf(names::ORTH_W);
        let mut w = w_buf.view_mut(b, b);
        // W = G − HᵀH: the 2sb² downdate + b³/3 POTRF replace the b²q
        // Gram re-stream (host factor-sized work, rule 3).
        w.data.copy_from_slice(g.data);
        let t = Timer::start(
            2.0 * h.rows as f64 * (b * b) as f64 + (b * b * b) as f64 / 3.0,
        );
        crate::la::blas3::gemm_tn(-S::ONE, h.as_ref(), h.as_ref(), S::ONE, w.reborrow());
        let mut res = potrf_into(w.as_ref(), l1.reborrow());
        t.stop(be.profile_mut());
        if matches!(res, Err(Error::CholeskyBreakdown { .. })) {
            // Rounding in the downdate can lose definiteness the direct
            // Gram still has: recompute and retry before declaring a
            // real breakdown.
            be.gram_into(q.as_ref(), w.reborrow());
            let t = Timer::start((b * b * b) as f64 / 3.0);
            res = potrf_into(w.as_ref(), l1.reborrow());
            t.stop(be.profile_mut());
        }
        res
    };
    match first {
        Ok(()) => be.tri_solve_right(q.reborrow(), l1.as_ref()),
        Err(Error::CholeskyBreakdown { .. }) => {
            be.proj_into(p, snap.as_ref(), h.reborrow());
            q.data.copy_from_slice(snap.data);
            return cgs2_fallback(be, q, Some(p), r);
        }
        Err(e) => return Err(e),
    }
    // Second pass: identical to the unfused composition.
    let mut hbar_buf = ws.buf(names::ORTH_HBAR);
    let mut hbar = hbar_buf.view_mut(p.cols, b);
    be.proj_into(p, q.as_ref(), hbar.reborrow()); // S6
    be.subtract_proj(q.reborrow(), p, hbar.as_ref()); // S7
    match cholqr_pass_into(be, &mut q, &mut l2, ws) {
        Ok(()) => {}
        Err(Error::CholeskyBreakdown { .. }) => {
            be.proj_into(p, snap.as_ref(), h.reborrow());
            q.data.copy_from_slice(snap.data);
            return cgs2_fallback(be, q, Some(p), r);
        }
        Err(e) => return Err(e),
    }
    // S11/S12 as in the unfused composition.
    let t = Timer::start((b * b * b) as f64 + (h.rows * h.cols) as f64);
    crate::la::blas3::trmm_lt_lt_into(l2.as_ref(), l1.as_ref(), r.reborrow());
    for (hv, hb) in h.data.iter_mut().zip(hbar.data.iter()) {
        *hv += *hb;
    }
    t.stop(be.profile_mut());
    Ok(())
}

/// Backend-dispatching entry point for the out-parameter Alg. 4 (the
/// XLA backend overrides the trait method with its fused AOT graph).
pub fn cholqr2_into<S: Scalar, B: Backend<S> + ?Sized>(
    be: &mut B,
    q: MatMut<'_, S>,
    r: MatMut<'_, S>,
    ws: &Workspace<S>,
) -> Result<()> {
    be.orth_cholqr2_into(q, r, ws)
}

/// Backend-dispatching entry point for the out-parameter Alg. 5.
pub fn cgs_cqr2_into<S: Scalar, B: Backend<S> + ?Sized>(
    be: &mut B,
    q: MatMut<'_, S>,
    p: MatRef<'_, S>,
    h: MatMut<'_, S>,
    r: MatMut<'_, S>,
    ws: &Workspace<S>,
) -> Result<()> {
    be.orth_cgs_cqr2_into(q, p, h, r, ws)
}

/// Value-returning Alg. 4 wrapper (tests / examples / one-shot callers;
/// allocates a throwaway workspace through the trait wrapper).
pub fn cholqr2<S: Scalar, B: Backend<S> + ?Sized>(be: &mut B, q: &mut Mat<S>) -> Result<Mat<S>> {
    be.orth_cholqr2(q)
}

/// Value-returning Alg. 5 wrapper (tests / examples / one-shot callers).
pub fn cgs_cqr2<S: Scalar, B: Backend<S> + ?Sized>(
    be: &mut B,
    q: &mut Mat<S>,
    p: MatRef<'_, S>,
) -> Result<(Mat<S>, Mat<S>)> {
    be.orth_cgs_cqr2(q, p)
}

/// Column-wise classical Gram-Schmidt with re-orthogonalization — the
/// breakdown fallback of paper §3.2. Orthonormalizes `q` in place against
/// `p` (if given) and itself; writes the triangular factor into `r`.
/// Columns that vanish (exact rank deficiency) are replaced by fresh
/// random directions (their R column is zero). This path only runs on
/// rank-deficient panels, so its small bookkeeping allocations are off
/// the steady-state contract.
pub fn cgs2_fallback<S: Scalar, B: Backend<S> + ?Sized>(
    be: &mut B,
    mut q: MatMut<'_, S>,
    p: Option<MatRef<'_, S>>,
    mut r: MatMut<'_, S>,
) -> Result<()> {
    let rows = q.rows;
    let b = q.cols;
    assert_eq!((r.rows, r.cols), (b, b), "cgs2 fallback R shape");
    let t = Timer::start(0.0); // wall-time only; flop count folded into R
    r.fill(S::ZERO);
    let mut rng = Rng::new(0x5EED_FA11);
    for j in 0..b {
        let mut norm_orig = nrm2(q.col(j));
        if norm_orig == S::ZERO {
            norm_orig = S::ONE;
        }
        let mut attempts = 0;
        loop {
            // Two CGS passes against P and the already-finished columns.
            for _pass in 0..2 {
                if let Some(pp) = p {
                    for kcol in 0..pp.cols {
                        let coef = dot(pp.col(kcol), q.col(j));
                        axpy(-coef, pp.col(kcol), q.col_mut(j));
                    }
                }
                for i in 0..j {
                    let coef = dot(q.col(i), q.col(j));
                    if _pass == 0 && attempts == 0 {
                        let prev = r.at(i, j);
                        r.set(i, j, prev + coef);
                    }
                    let (ci, cj) = q.col_pair_mut(i, j);
                    axpy(-coef, ci, cj);
                }
            }
            let nn = nrm2(q.col(j));
            // Dead-column cutoff scales with the working precision
            // (ε-relative, ~1e-14 at f64 / ~1e-5 at f32).
            if nn > S::from_f64(100.0) * S::EPSILON * norm_orig.max(S::ONE) {
                if attempts == 0 {
                    r.set(j, j, nn);
                }
                scal(S::ONE / nn, q.col_mut(j));
                break;
            }
            // Dead column: replace with a random direction, R entry 0.
            attempts += 1;
            if attempts > 8 {
                return Err(Error::InvalidParam(format!(
                    "cgs2 fallback could not complete column {j} of a {rows}x{b} panel"
                )));
            }
            rng.fill_normal(q.col_mut(j));
            for ri in j..b {
                r.set(ri, j, S::ZERO);
            }
        }
    }
    t.stop(be.profile_mut());
    Ok(())
}

/// Generate a random orthonormal q×b panel via the backend (paper Alg. 2
/// step S1: random init + Alg. 4 orthonormalization). Setup-phase
/// helper; the solve loops fill their workspace buffers directly.
pub fn random_orthonormal_panel<S: Scalar, B: Backend<S> + ?Sized>(
    be: &mut B,
    rows: usize,
    b: usize,
    rng: &mut Rng,
) -> Result<Mat<S>> {
    let mut q = Mat::rand_centered_poisson(rows, b, rng);
    cholqr2(be, &mut q)?;
    Ok(q)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::cpu::CpuBackend;
    use crate::la::blas3::{mat_nn, mat_tn};
    use crate::la::norms::orth_error;
    use crate::la::workspace::Plan;

    fn dummy_backend() -> CpuBackend {
        // The operand matrix is irrelevant for orthogonalization ops.
        CpuBackend::new_dense(Mat::zeros(1, 1))
    }

    #[test]
    fn cholqr2_orthonormalizes_and_reconstructs() {
        let mut be = dummy_backend();
        let mut rng = Rng::new(1);
        for &(q_rows, b) in &[(50usize, 8usize), (200, 16), (64, 1)] {
            let y = Mat::randn(q_rows, b, &mut rng);
            let mut q = y.clone();
            let r = cholqr2(&mut be, &mut q).unwrap();
            assert!(orth_error(&q) < 1e-13, "orth {q_rows}x{b}");
            let back = mat_nn(&q, &r);
            let scale = y.fro_norm();
            assert!(back.max_abs_diff(&y) / scale < 1e-13, "reconstruct {q_rows}x{b}");
            // R upper triangular
            for j in 0..b {
                for i in (j + 1)..b {
                    assert_eq!(r.at(i, j), 0.0);
                }
            }
        }
    }

    #[test]
    fn into_form_matches_wrapper_with_shared_workspace() {
        // The workspace-reusing into-form must produce the same numbers
        // as the throwaway-workspace wrapper, across repeated calls on
        // one arena (plan reuse).
        let mut be = dummy_backend();
        let mut rng = Rng::new(41);
        let ws = Workspace::new(Plan::orth(120, 24, 8));
        for trial in 0..3 {
            let y = Mat::randn(120, 8, &mut rng);
            let mut q1 = y.clone();
            let r1 = cholqr2(&mut be, &mut q1).unwrap();
            let mut q2 = y.clone();
            let mut r2 = Mat::zeros(8, 8);
            cholqr2_into(&mut be, q2.as_mut(), r2.as_mut(), &ws).unwrap();
            assert!(q1.max_abs_diff(&q2) == 0.0, "trial {trial} Q");
            assert!(r1.max_abs_diff(&r2) == 0.0, "trial {trial} R");

            let p = crate::la::qr::random_orthonormal(120, 24, &mut rng);
            let z = Mat::randn(120, 8, &mut rng);
            let mut q3 = z.clone();
            let (h3, r3) = cgs_cqr2(&mut be, &mut q3, p.as_ref()).unwrap();
            let mut q4 = z.clone();
            let mut h4 = Mat::zeros(24, 8);
            let mut r4 = Mat::zeros(8, 8);
            cgs_cqr2_into(&mut be, q4.as_mut(), p.as_ref(), h4.as_mut(), r4.as_mut(), &ws)
                .unwrap();
            assert!(q3.max_abs_diff(&q4) == 0.0, "trial {trial} Q (cgs)");
            assert!(h3.max_abs_diff(&h4) == 0.0, "trial {trial} H");
            assert!(r3.max_abs_diff(&r4) == 0.0, "trial {trial} R (cgs)");
        }
    }

    #[test]
    fn cholqr2_ill_conditioned_panel() {
        // Columns with widely varying scales: CholeskyQR-1 would lose
        // orthogonality; the second pass must recover it.
        let mut be = dummy_backend();
        let mut rng = Rng::new(2);
        let mut y = Mat::randn(100, 6, &mut rng);
        for j in 0..6 {
            let s = 10f64.powi(-2 * j as i32);
            scal(s, y.col_mut(j));
        }
        let mut q = y.clone();
        let r = cholqr2(&mut be, &mut q).unwrap();
        assert!(orth_error(&q) < 1e-12);
        assert!(mat_nn(&q, &r).max_abs_diff(&y) / y.fro_norm() < 1e-12);
    }

    #[test]
    fn cholqr2_breakdown_falls_back() {
        // Exactly rank-deficient panel triggers the CGS2 fallback.
        let mut be = dummy_backend();
        let mut rng = Rng::new(3);
        let mut y = Mat::randn(40, 5, &mut rng);
        let c0 = y.col(0).to_vec();
        y.col_mut(3).copy_from_slice(&c0);
        let mut q = y.clone();
        let _r = cholqr2(&mut be, &mut q).unwrap();
        assert!(orth_error(&q) < 1e-10, "fallback orthonormal: {}", orth_error(&q));
    }

    #[test]
    fn cgs_cqr2_orthogonal_to_panel_and_reconstructs() {
        let mut be = dummy_backend();
        let mut rng = Rng::new(4);
        let rows = 120;
        let (s, b) = (12, 6);
        let p = crate::la::qr::random_orthonormal(rows, s, &mut rng);
        let y = Mat::randn(rows, b, &mut rng);
        let mut q = y.clone();
        let (h, r) = cgs_cqr2(&mut be, &mut q, p.as_ref()).unwrap();
        // Q orthonormal and ⟂ P
        assert!(orth_error(&q) < 1e-13);
        let cross = mat_tn(&p, &q);
        assert!(cross.fro_norm() < 1e-12, "cross {}", cross.fro_norm());
        // Y ≈ P·H + Q·R
        let back = {
            let mut t = mat_nn(&p, &h);
            let qr = mat_nn(&q, &r);
            for (a, c) in t.data_mut().iter_mut().zip(qr.data()) {
                *a += c;
            }
            t
        };
        assert!(back.max_abs_diff(&y) / y.fro_norm() < 1e-12);
        assert_eq!((h.rows(), h.cols()), (s, b));
    }

    #[test]
    fn cgs_cqr2_on_vector_already_in_span() {
        // Columns of Y that lie inside span(P) should break down to the
        // fallback and still produce an orthonormal Q.
        let mut be = dummy_backend();
        let mut rng = Rng::new(5);
        let rows = 60;
        let p = crate::la::qr::random_orthonormal(rows, 8, &mut rng);
        let mut y = Mat::zeros(rows, 4);
        // First two columns are combinations of P's columns.
        for j in 0..2 {
            let mut comb = vec![0.0; rows];
            for k in 0..8 {
                axpy(rng.normal(), p.col(k), &mut comb);
            }
            y.col_mut(j).copy_from_slice(&comb);
        }
        for j in 2..4 {
            let mut v = vec![0.0; rows];
            rng.fill_normal(&mut v);
            y.col_mut(j).copy_from_slice(&v);
        }
        let mut q = y.clone();
        let (_h, _r) = cgs_cqr2(&mut be, &mut q, p.as_ref()).unwrap();
        assert!(orth_error(&q) < 1e-9, "orth {}", orth_error(&q));
        let cross = mat_tn(&p, &q);
        assert!(cross.fro_norm() < 1e-9, "cross {}", cross.fro_norm());
    }

    #[test]
    fn pregram_matches_unfused_composition() {
        // The Gram-downdated first pass must agree with the re-streamed
        // one to roundoff; the second CholeskyQR pass makes both paths
        // orthonormal to machine precision.
        let mut be = dummy_backend();
        let mut rng = Rng::new(7);
        let ws = Workspace::new(Plan::orth(150, 24, 8));
        for trial in 0..3 {
            let p = crate::la::qr::random_orthonormal(150, 12, &mut rng);
            let y = Mat::randn(150, 8, &mut rng);
            let g = mat_tn(&y, &y);
            let mut q1 = y.clone();
            let mut h1 = Mat::zeros(12, 8);
            let mut r1 = Mat::zeros(8, 8);
            cgs_cqr2_into(&mut be, q1.as_mut(), p.as_ref(), h1.as_mut(), r1.as_mut(), &ws)
                .unwrap();
            let mut q2 = y.clone();
            let mut h2 = Mat::zeros(12, 8);
            let mut r2 = Mat::zeros(8, 8);
            cgs_cqr2_pregram_into_host(
                &mut be,
                q2.as_mut(),
                p.as_ref(),
                g.as_ref(),
                h2.as_mut(),
                r2.as_mut(),
                &ws,
            )
            .unwrap();
            assert!(orth_error(&q2) < 1e-13, "trial {trial} orth");
            let cross = mat_tn(&p, &q2);
            assert!(cross.fro_norm() < 1e-12, "trial {trial} cross");
            let scale = y.fro_norm();
            assert!(q1.max_abs_diff(&q2) < 1e-10, "trial {trial} Q");
            assert!(h1.max_abs_diff(&h2) / scale < 1e-10, "trial {trial} H");
            assert!(r1.max_abs_diff(&r2) / scale < 1e-10, "trial {trial} R");
            // Y ≈ P·H + Q·R through the pregram path too.
            let mut back = mat_nn(&p, &h2);
            let qr = mat_nn(&q2, &r2);
            for (a, c) in back.data_mut().iter_mut().zip(qr.data()) {
                *a += c;
            }
            assert!(back.max_abs_diff(&y) / scale < 1e-12, "trial {trial} reconstruct");
        }
    }

    #[test]
    fn pregram_breakdown_falls_back() {
        // Panel columns inside span(P) zero out after S2: the downdated
        // Gram (and the recomputed one) break down, and the CGS2
        // fallback must still deliver an orthonormal Q ⟂ P.
        let mut be = dummy_backend();
        let mut rng = Rng::new(8);
        let rows = 80;
        let ws = Workspace::new(Plan::orth(rows, 8, 4));
        let p = crate::la::qr::random_orthonormal(rows, 8, &mut rng);
        let mut y = Mat::zeros(rows, 4);
        for j in 0..2 {
            let mut comb = vec![0.0; rows];
            for k in 0..8 {
                axpy(rng.normal(), p.col(k), &mut comb);
            }
            y.col_mut(j).copy_from_slice(&comb);
        }
        for j in 2..4 {
            let mut v = vec![0.0; rows];
            rng.fill_normal(&mut v);
            y.col_mut(j).copy_from_slice(&v);
        }
        let g = mat_tn(&y, &y);
        let mut q = y.clone();
        let mut h = Mat::zeros(8, 4);
        let mut r = Mat::zeros(4, 4);
        cgs_cqr2_pregram_into_host(
            &mut be,
            q.as_mut(),
            p.as_ref(),
            g.as_ref(),
            h.as_mut(),
            r.as_mut(),
            &ws,
        )
        .unwrap();
        assert!(orth_error(&q) < 1e-9, "orth {}", orth_error(&q));
        let cross = mat_tn(&p, &q);
        assert!(cross.fro_norm() < 1e-9, "cross {}", cross.fro_norm());
    }

    #[test]
    fn random_panel_is_orthonormal() {
        let mut be = dummy_backend();
        let mut rng = Rng::new(6);
        let q = random_orthonormal_panel(&mut be, 80, 16, &mut rng).unwrap();
        assert_eq!((q.rows(), q.cols()), (80, 16));
        assert!(orth_error(&q) < 1e-13);
    }
}
