//! MatrixMarket coordinate-format I/O.
//!
//! Supports the subset used by the SuiteSparse collection for this paper:
//! `matrix coordinate real|integer|pattern general|symmetric`. Symmetric
//! inputs are expanded to general storage on read.
//!
//! Two entry points share one parser:
//!
//! * [`read_coo`] / [`read_csr`] materialize the whole matrix (the
//!   in-core path);
//! * [`MmStream`] visits entries one at a time without building a COO —
//!   the out-of-core shard converter (`sparse::shard`) runs two such
//!   passes over files that do not fit in memory.
//!
//! Parse errors report **1-based line numbers** (`line N: ...`) so a bad
//! entry in a multi-gigabyte file is locatable.

use std::io::{BufRead, BufWriter, Write};

use super::coo::Coo;
use super::csr::Csr;
use crate::error::{Error, Result};
use crate::util::scalar::Scalar;

fn io_err(path: &str, e: std::io::Error) -> Error {
    Error::Io { path: path.to_string(), source: e }
}

fn parse_err(detail: impl Into<String>) -> Error {
    Error::Parse { what: "matrixmarket", detail: detail.into() }
}

fn parse_err_at(lineno: usize, detail: impl std::fmt::Display) -> Error {
    parse_err(format!("line {lineno}: {detail}"))
}

/// Parsed MatrixMarket header + size line.
#[derive(Clone, Copy, Debug)]
pub struct MmHeader {
    pub rows: usize,
    pub cols: usize,
    /// Declared *stored* entry count (the size-line nnz). Symmetric files
    /// expand to up to twice this many emitted entries.
    pub entries: usize,
    pub pattern: bool,
    pub symmetric: bool,
}

/// Streaming MatrixMarket reader: header and size line are parsed by
/// [`MmStream::open`]; [`MmStream::for_each`] then visits every stored
/// entry (with symmetric expansion) without materializing the file.
pub struct MmStream {
    path: String,
    lines: std::io::Lines<std::io::BufReader<std::fs::File>>,
    lineno: usize,
    header: MmHeader,
}

impl MmStream {
    /// Open `path` and parse the banner + size line (skipping comments).
    pub fn open(path: &str) -> Result<MmStream> {
        let f = std::fs::File::open(path).map_err(|e| io_err(path, e))?;
        let reader = std::io::BufReader::new(f);
        let mut lines = reader.lines();
        let mut lineno = 0usize;

        // Banner line.
        lineno += 1;
        let banner = lines
            .next()
            .ok_or_else(|| parse_err("empty file"))?
            .map_err(|e| io_err(path, e))?;
        let h = banner.to_ascii_lowercase();
        let toks: Vec<&str> = h.split_whitespace().collect();
        if toks.len() < 5 || toks[0] != "%%matrixmarket" || toks[1] != "matrix" {
            return Err(parse_err_at(lineno, format!("bad header: {banner}")));
        }
        if toks[2] != "coordinate" {
            return Err(parse_err_at(lineno, "only coordinate format supported"));
        }
        let field = toks[3]; // real | integer | pattern
        let symmetry = toks[4]; // general | symmetric
        if !matches!(field, "real" | "integer" | "pattern") {
            return Err(parse_err_at(lineno, format!("unsupported field type {field}")));
        }
        if !matches!(symmetry, "general" | "symmetric") {
            return Err(parse_err_at(lineno, format!("unsupported symmetry {symmetry}")));
        }

        // Size line (skipping comments).
        let mut size_line = None;
        for line in lines.by_ref() {
            lineno += 1;
            let line = line.map_err(|e| io_err(path, e))?;
            let t = line.trim();
            if t.is_empty() || t.starts_with('%') {
                continue;
            }
            size_line = Some(line);
            break;
        }
        let size_line = size_line.ok_or_else(|| parse_err("missing size line"))?;
        let dims: Vec<usize> = size_line
            .split_whitespace()
            .map(|t| t.parse::<usize>().map_err(|_| parse_err_at(lineno, "bad size line")))
            .collect::<Result<_>>()?;
        if dims.len() != 3 {
            return Err(parse_err_at(lineno, "size line needs 3 fields"));
        }
        let header = MmHeader {
            rows: dims[0],
            cols: dims[1],
            entries: dims[2],
            pattern: field == "pattern",
            symmetric: symmetry == "symmetric",
        };
        Ok(MmStream { path: path.to_string(), lines, lineno, header })
    }

    #[inline]
    pub fn header(&self) -> MmHeader {
        self.header
    }

    /// Visit every stored entry as `emit(row, col, value)` with 0-based
    /// indices; symmetric inputs additionally emit the mirrored
    /// off-diagonal entry. Validates the declared entry count at EOF.
    pub fn for_each(self, mut emit: impl FnMut(usize, usize, f64)) -> Result<()> {
        let MmStream { path, lines, mut lineno, header } = self;
        let MmHeader { rows, cols, entries, pattern, symmetric } = header;
        let mut seen = 0usize;
        for line in lines {
            lineno += 1;
            let line = line.map_err(|e| io_err(&path, e))?;
            let t = line.trim();
            if t.is_empty() || t.starts_with('%') {
                continue;
            }
            let mut it = t.split_whitespace();
            let i: usize = it
                .next()
                .and_then(|s| s.parse().ok())
                .ok_or_else(|| parse_err_at(lineno, format!("bad entry line: {t}")))?;
            let j: usize = it
                .next()
                .and_then(|s| s.parse().ok())
                .ok_or_else(|| parse_err_at(lineno, format!("bad entry line: {t}")))?;
            let v: f64 = if pattern {
                1.0
            } else {
                it.next()
                    .and_then(|s| s.parse().ok())
                    .ok_or_else(|| parse_err_at(lineno, format!("bad value in: {t}")))?
            };
            if i == 0 || j == 0 || i > rows || j > cols {
                return Err(parse_err_at(lineno, format!("index out of range: {t}")));
            }
            emit(i - 1, j - 1, v);
            if symmetric && i != j {
                emit(j - 1, i - 1, v);
            }
            seen += 1;
        }
        if seen != entries {
            return Err(parse_err(format!("expected {entries} entries, found {seen}")));
        }
        Ok(())
    }
}

/// Read a MatrixMarket file into COO (in-core path over [`MmStream`]).
pub fn read_coo(path: &str) -> Result<Coo> {
    let stream = MmStream::open(path)?;
    let h = stream.header();
    let mut coo = Coo::new(h.rows, h.cols);
    stream.for_each(|i, j, v| coo.push(i, j, v))?;
    Ok(coo)
}

/// Read a MatrixMarket file straight into CSR.
pub fn read_csr(path: &str) -> Result<Csr> {
    Csr::from_coo(&read_coo(path)?)
}

/// Write a CSR matrix (any precision) as `matrix coordinate real
/// general`; values are emitted through f64 with full round-trip digits.
pub fn write_csr<S: Scalar>(path: &str, a: &Csr<S>) -> Result<()> {
    let f = std::fs::File::create(path).map_err(|e| io_err(path, e))?;
    let mut w = BufWriter::new(f);
    (|| -> std::io::Result<()> {
        writeln!(w, "%%MatrixMarket matrix coordinate real general")?;
        writeln!(w, "% written by trunksvd")?;
        writeln!(w, "{} {} {}", a.rows(), a.cols(), a.nnz())?;
        for i in 0..a.rows() {
            let (cols, vals) = a.row(i);
            for (&c, &v) in cols.iter().zip(vals) {
                writeln!(w, "{} {} {:.17e}", i + 1, c as usize + 1, v.to_f64())?;
            }
        }
        w.flush()
    })()
    .map_err(|e| io_err(path, e))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::coo::Coo;
    use crate::util::rng::Rng;

    fn tmp(name: &str) -> String {
        let dir = std::env::temp_dir().join("trunksvd_mm_tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name).to_string_lossy().into_owned()
    }

    #[test]
    fn roundtrip() {
        let mut rng = Rng::new(5);
        let mut coo = Coo::new(13, 9);
        for _ in 0..40 {
            coo.push(rng.below(13), rng.below(9), rng.normal());
        }
        let a = Csr::from_coo(&coo).unwrap();
        let path = tmp("rt.mtx");
        write_csr(&path, &a).unwrap();
        let b = read_csr(&path).unwrap();
        assert_eq!((a.rows(), a.cols(), a.nnz()), (b.rows(), b.cols(), b.nnz()));
        assert!(a.to_dense().max_abs_diff(&b.to_dense()) < 1e-15);
    }

    #[test]
    fn reads_pattern_and_symmetric() {
        let path = tmp("sym.mtx");
        std::fs::write(
            &path,
            "%%MatrixMarket matrix coordinate pattern symmetric\n% c\n3 3 2\n2 1\n3 3\n",
        )
        .unwrap();
        let a = read_csr(&path).unwrap();
        assert_eq!(a.nnz(), 3); // (2,1), (1,2), (3,3)
        let d = a.to_dense();
        assert_eq!(d.at(1, 0), 1.0);
        assert_eq!(d.at(0, 1), 1.0);
        assert_eq!(d.at(2, 2), 1.0);
    }

    #[test]
    fn rejects_bad_files() {
        let path = tmp("bad1.mtx");
        std::fs::write(&path, "nonsense\n").unwrap();
        assert!(read_coo(&path).is_err());
        let path = tmp("bad2.mtx");
        std::fs::write(&path, "%%MatrixMarket matrix coordinate real general\n2 2 1\n5 1 3.0\n")
            .unwrap();
        assert!(read_coo(&path).is_err());
        let path = tmp("bad3.mtx");
        std::fs::write(&path, "%%MatrixMarket matrix coordinate real general\n2 2 2\n1 1 3.0\n")
            .unwrap();
        assert!(read_coo(&path).is_err(), "nnz mismatch");
    }

    #[test]
    fn parse_errors_carry_line_numbers() {
        // Bad entry on physical line 4 (banner, comment, size, entry).
        let path = tmp("lineno1.mtx");
        std::fs::write(
            &path,
            "%%MatrixMarket matrix coordinate real general\n% c\n2 2 2\n1 1 3.0\n9 9 1.0\n",
        )
        .unwrap();
        let e = read_coo(&path).unwrap_err();
        let msg = format!("{e}");
        assert!(msg.contains("line 5"), "out-of-range index location missing: {msg}");
        // Malformed value, line 3 (banner, size, entry).
        let path = tmp("lineno2.mtx");
        std::fs::write(&path, "%%MatrixMarket matrix coordinate real general\n1 1 1\n1 1 x\n")
            .unwrap();
        let msg = format!("{}", read_coo(&path).unwrap_err());
        assert!(msg.contains("line 3"), "bad value location missing: {msg}");
        // Bad size line keeps its own location too.
        let path = tmp("lineno3.mtx");
        std::fs::write(&path, "%%MatrixMarket matrix coordinate real general\n% c\nnope\n")
            .unwrap();
        let msg = format!("{}", MmStream::open(&path).unwrap_err());
        assert!(msg.contains("line 3"), "size-line location missing: {msg}");
    }

    #[test]
    fn stream_matches_read_coo() {
        let mut rng = Rng::new(9);
        let mut coo = Coo::new(21, 15);
        for _ in 0..60 {
            coo.push(rng.below(21), rng.below(15), rng.normal());
        }
        let a = Csr::from_coo(&coo).unwrap();
        let path = tmp("stream.mtx");
        write_csr(&path, &a).unwrap();
        let stream = MmStream::open(&path).unwrap();
        let h = stream.header();
        assert_eq!((h.rows, h.cols, h.entries), (21, 15, a.nnz()));
        assert!(!h.pattern && !h.symmetric);
        let mut streamed = Coo::new(h.rows, h.cols);
        stream.for_each(|i, j, v| streamed.push(i, j, v)).unwrap();
        let b = Csr::from_coo(&streamed).unwrap();
        let c = read_csr(&path).unwrap();
        assert_eq!(b.indptr(), c.indptr());
        assert_eq!(b.indices(), c.indices());
        assert_eq!(b.values(), c.values());
    }
}
