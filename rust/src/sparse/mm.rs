//! MatrixMarket coordinate-format I/O.
//!
//! Supports the subset used by the SuiteSparse collection for this paper:
//! `matrix coordinate real|integer|pattern general|symmetric`. Symmetric
//! inputs are expanded to general storage on read.

use std::io::{BufRead, BufWriter, Write};

use super::coo::Coo;
use super::csr::Csr;
use crate::error::{Error, Result};
use crate::util::scalar::Scalar;

fn io_err(path: &str, e: std::io::Error) -> Error {
    Error::Io { path: path.to_string(), source: e }
}

fn parse_err(detail: impl Into<String>) -> Error {
    Error::Parse { what: "matrixmarket", detail: detail.into() }
}

/// Read a MatrixMarket file into COO.
pub fn read_coo(path: &str) -> Result<Coo> {
    let f = std::fs::File::open(path).map_err(|e| io_err(path, e))?;
    let reader = std::io::BufReader::new(f);
    let mut lines = reader.lines();

    // Header line.
    let header = lines
        .next()
        .ok_or_else(|| parse_err("empty file"))?
        .map_err(|e| io_err(path, e))?;
    let h = header.to_ascii_lowercase();
    let toks: Vec<&str> = h.split_whitespace().collect();
    if toks.len() < 5 || toks[0] != "%%matrixmarket" || toks[1] != "matrix" {
        return Err(parse_err(format!("bad header: {header}")));
    }
    if toks[2] != "coordinate" {
        return Err(parse_err("only coordinate format supported"));
    }
    let field = toks[3]; // real | integer | pattern
    let symmetry = toks[4]; // general | symmetric
    if !matches!(field, "real" | "integer" | "pattern") {
        return Err(parse_err(format!("unsupported field type {field}")));
    }
    if !matches!(symmetry, "general" | "symmetric") {
        return Err(parse_err(format!("unsupported symmetry {symmetry}")));
    }

    // Size line (skipping comments).
    let mut size_line = None;
    for line in lines.by_ref() {
        let line = line.map_err(|e| io_err(path, e))?;
        let t = line.trim();
        if t.is_empty() || t.starts_with('%') {
            continue;
        }
        size_line = Some(line);
        break;
    }
    let size_line = size_line.ok_or_else(|| parse_err("missing size line"))?;
    let dims: Vec<usize> = size_line
        .split_whitespace()
        .map(|t| t.parse::<usize>().map_err(|_| parse_err("bad size line")))
        .collect::<Result<_>>()?;
    if dims.len() != 3 {
        return Err(parse_err("size line needs 3 fields"));
    }
    let (rows, cols, nnz) = (dims[0], dims[1], dims[2]);

    let mut coo = Coo::new(rows, cols);
    let mut seen = 0usize;
    for line in lines {
        let line = line.map_err(|e| io_err(path, e))?;
        let t = line.trim();
        if t.is_empty() || t.starts_with('%') {
            continue;
        }
        let mut it = t.split_whitespace();
        let i: usize = it
            .next()
            .and_then(|s| s.parse().ok())
            .ok_or_else(|| parse_err(format!("bad entry line: {t}")))?;
        let j: usize = it
            .next()
            .and_then(|s| s.parse().ok())
            .ok_or_else(|| parse_err(format!("bad entry line: {t}")))?;
        let v: f64 = if field == "pattern" {
            1.0
        } else {
            it.next()
                .and_then(|s| s.parse().ok())
                .ok_or_else(|| parse_err(format!("bad value in: {t}")))?
        };
        if i == 0 || j == 0 || i > rows || j > cols {
            return Err(parse_err(format!("index out of range: {t}")));
        }
        coo.push(i - 1, j - 1, v);
        if symmetry == "symmetric" && i != j {
            coo.push(j - 1, i - 1, v);
        }
        seen += 1;
    }
    if seen != nnz {
        return Err(parse_err(format!("expected {nnz} entries, found {seen}")));
    }
    Ok(coo)
}

/// Read a MatrixMarket file straight into CSR.
pub fn read_csr(path: &str) -> Result<Csr> {
    Csr::from_coo(&read_coo(path)?)
}

/// Write a CSR matrix (any precision) as `matrix coordinate real
/// general`; values are emitted through f64 with full round-trip digits.
pub fn write_csr<S: Scalar>(path: &str, a: &Csr<S>) -> Result<()> {
    let f = std::fs::File::create(path).map_err(|e| io_err(path, e))?;
    let mut w = BufWriter::new(f);
    (|| -> std::io::Result<()> {
        writeln!(w, "%%MatrixMarket matrix coordinate real general")?;
        writeln!(w, "% written by trunksvd")?;
        writeln!(w, "{} {} {}", a.rows(), a.cols(), a.nnz())?;
        for i in 0..a.rows() {
            let (cols, vals) = a.row(i);
            for (&c, &v) in cols.iter().zip(vals) {
                writeln!(w, "{} {} {:.17e}", i + 1, c as usize + 1, v.to_f64())?;
            }
        }
        w.flush()
    })()
    .map_err(|e| io_err(path, e))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::coo::Coo;
    use crate::util::rng::Rng;

    fn tmp(name: &str) -> String {
        let dir = std::env::temp_dir().join("trunksvd_mm_tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name).to_string_lossy().into_owned()
    }

    #[test]
    fn roundtrip() {
        let mut rng = Rng::new(5);
        let mut coo = Coo::new(13, 9);
        for _ in 0..40 {
            coo.push(rng.below(13), rng.below(9), rng.normal());
        }
        let a = Csr::from_coo(&coo).unwrap();
        let path = tmp("rt.mtx");
        write_csr(&path, &a).unwrap();
        let b = read_csr(&path).unwrap();
        assert_eq!((a.rows(), a.cols(), a.nnz()), (b.rows(), b.cols(), b.nnz()));
        assert!(a.to_dense().max_abs_diff(&b.to_dense()) < 1e-15);
    }

    #[test]
    fn reads_pattern_and_symmetric() {
        let path = tmp("sym.mtx");
        std::fs::write(
            &path,
            "%%MatrixMarket matrix coordinate pattern symmetric\n% c\n3 3 2\n2 1\n3 3\n",
        )
        .unwrap();
        let a = read_csr(&path).unwrap();
        assert_eq!(a.nnz(), 3); // (2,1), (1,2), (3,3)
        let d = a.to_dense();
        assert_eq!(d.at(1, 0), 1.0);
        assert_eq!(d.at(0, 1), 1.0);
        assert_eq!(d.at(2, 2), 1.0);
    }

    #[test]
    fn rejects_bad_files() {
        let path = tmp("bad1.mtx");
        std::fs::write(&path, "nonsense\n").unwrap();
        assert!(read_coo(&path).is_err());
        let path = tmp("bad2.mtx");
        std::fs::write(&path, "%%MatrixMarket matrix coordinate real general\n2 2 1\n5 1 3.0\n")
            .unwrap();
        assert!(read_coo(&path).is_err());
        let path = tmp("bad3.mtx");
        std::fs::write(&path, "%%MatrixMarket matrix coordinate real general\n2 2 2\n1 1 3.0\n")
            .unwrap();
        assert!(read_coo(&path).is_err(), "nnz mismatch");
    }
}
