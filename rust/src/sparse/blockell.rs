//! Block-ELL sparse format (the TPU-shaped SpMM layout).
//!
//! Hardware adaptation of CSR for the Pallas SpMM kernel
//! (`python/compile/kernels/spmm_blockell.py`, DESIGN.md
//! §Hardware-Adaptation): the matrix is cut into dense bs×bs blocks;
//! every block-row stores the same number of blocks (`mbpr`,
//! zero-padded), so the kernel is a regular gather + small-matmul loop
//! with static shapes. This module is the production converter used to
//! feed the AOT SpMM artifact from rust, plus a threaded host SpMM
//! (parallel over block-row bands on the persistent `util::pool` workers,
//! 4-column register-blocked bs×bs micro-kernel) so the format is
//! competitive on the CPU substrate too. The pool's static banding keeps
//! each worker on the same bs-aligned block-row stripe across the
//! repeated SpMM calls of an iteration (band affinity), and small panels
//! below the `cost::parallel_cutoff` grain run serial without paying
//! dispatch.

use super::csr::Csr;
use crate::error::{Error, Result};
use crate::la::mat::{Mat, MatMut, MatRef};
use crate::util::pool::{self, parallel_row_blocks_work, parallel_tasks};
use crate::util::scalar::Scalar;

/// A block-ELL matrix: `blocks[(br*mbpr + s)*bs*bs ..]` is the s-th
/// (row-major bs×bs) block of block-row `br`, with block-column index
/// `idx[br*mbpr + s]`. Padding slots hold all-zero blocks (index 0).
#[derive(Clone, Debug)]
pub struct BlockEll<S: Scalar = f64> {
    pub bs: usize,
    pub nbr: usize,
    pub ncb: usize,
    pub mbpr: usize,
    /// row-major block payloads, len = nbr*mbpr*bs*bs
    pub blocks: Vec<S>,
    /// block-column indices, len = nbr*mbpr
    pub idx: Vec<i32>,
    /// original (unpadded) dimensions
    pub rows: usize,
    pub cols: usize,
}

impl<S: Scalar> BlockEll<S> {
    /// Convert a CSR matrix; rows/cols are zero-padded to multiples of
    /// `bs`. `max_mbpr` bounds the ELL width (Err if exceeded — densely
    /// populated rows would blow up the padded storage).
    pub fn from_csr(a: &Csr<S>, bs: usize, max_mbpr: usize) -> Result<BlockEll<S>> {
        assert!(bs > 0);
        let nbr = a.rows().div_ceil(bs);
        let ncb = a.cols().div_ceil(bs);
        // Pass 1: the set of occupied block columns per block row.
        let mut block_cols: Vec<Vec<i32>> = vec![Vec::new(); nbr];
        for i in 0..a.rows() {
            let br = i / bs;
            let (cols, _) = a.row(i);
            for &c in cols {
                let bc = (c as usize / bs) as i32;
                // rows are sorted by column, so dedup on the fly
                if block_cols[br].last() != Some(&bc) && !block_cols[br].contains(&bc) {
                    block_cols[br].push(bc);
                }
            }
        }
        for bc in block_cols.iter_mut() {
            bc.sort_unstable();
        }
        let mbpr = block_cols.iter().map(|v| v.len()).max().unwrap_or(0).max(1);
        if mbpr > max_mbpr {
            return Err(Error::InvalidParam(format!(
                "block-ELL width {mbpr} exceeds cap {max_mbpr} (matrix too row-dense for ELL)"
            )));
        }
        // Pass 2: fill payloads.
        let mut blocks = vec![S::ZERO; nbr * mbpr * bs * bs];
        let mut idx = vec![0i32; nbr * mbpr];
        for (br, bcs) in block_cols.iter().enumerate() {
            for (s, &bc) in bcs.iter().enumerate() {
                idx[br * mbpr + s] = bc;
            }
        }
        for i in 0..a.rows() {
            let br = i / bs;
            let ri = i % bs;
            let (cols, vals) = a.row(i);
            for (&c, &v) in cols.iter().zip(vals) {
                let bc = (c as usize / bs) as i32;
                let cj = c as usize % bs;
                let s = block_cols[br].binary_search(&bc).expect("pass-1 recorded this block");
                blocks[((br * mbpr + s) * bs + ri) * bs + cj] = v;
            }
        }
        Ok(BlockEll {
            bs,
            nbr,
            ncb,
            mbpr,
            blocks,
            idx,
            rows: a.rows(),
            cols: a.cols(),
        })
    }

    /// [`BlockEll::from_csr`] with the width cap at the block-column
    /// count — the conversion itself can then never fail (a fully dense
    /// block-row is representable), leaving the *fill-factor* check to
    /// the caller. This is the staging entry point device backends use:
    /// convert, inspect [`BlockEll::fill_factor`], and fall back to an
    /// arena CSR when the ELL padding would blow the memory budget.
    pub fn from_csr_auto(a: &Csr<S>, bs: usize) -> BlockEll<S> {
        let ncb = a.cols().div_ceil(bs).max(1);
        BlockEll::from_csr(a, bs, ncb).expect("width cap at ncb cannot be exceeded")
    }

    /// Padded shape of the dense right-hand side the SpMM artifact
    /// expects: (ncb·bs, k).
    pub fn padded_cols(&self) -> usize {
        self.ncb * self.bs
    }
    pub fn padded_rows(&self) -> usize {
        self.nbr * self.bs
    }

    /// Fill factor: stored block entries / nnz-equivalent (diagnostic for
    /// the ELL padding overhead).
    pub fn fill_factor(&self, nnz: usize) -> f64 {
        (self.nbr * self.mbpr * self.bs * self.bs) as f64 / nnz.max(1) as f64
    }

    /// Y = A·X on the host (Y is padded_rows×k, X is padded_cols×k;
    /// borrowed views so callers pass workspace buffers allocation-free).
    ///
    /// Production kernel: parallel over contiguous *block-row* bands
    /// (each thread owns whole bs-row stripes of Y, so block-scatter
    /// accumulation is private), with a 4-column register-blocked bs×bs
    /// micro-kernel — each block row load feeds 4 dots, and the inner
    /// contiguous length-bs dots run on the `util::simd` vector
    /// microkernels (`Scalar::simd_dot4` / `simd_dot`).
    pub fn spmm(&self, x: MatRef<S>, mut y: MatMut<S>) {
        assert_eq!(x.rows, self.padded_cols(), "block-ELL spmm X rows");
        assert_eq!(
            (y.rows, y.cols),
            (self.padded_rows(), x.cols),
            "block-ELL spmm out"
        );
        let k = x.cols;
        if k == 0 || self.nbr == 0 || self.ncb == 0 {
            y.fill(S::ZERO);
            return;
        }
        let rows_pad = self.padded_rows();
        // Work estimate: every stored block entry is re-streamed once
        // per 4-column group, plus the padded output writes.
        let work = self.blocks.len() * k.div_ceil(4) + rows_pad * k;
        parallel_row_blocks_work(y.data, rows_pad, self.bs, work, |r0, r1, cols| {
            self.spmm_band(&x, r0, r1, cols)
        });
    }

    /// The spmm band body: rows `[r0, r1)` (bs-aligned) of Y = A·X into
    /// `cols` (the band's sub-slices of the output columns). Shared by
    /// [`BlockEll::spmm`] and the fused [`BlockEll::spmm_gram`]; each
    /// output element accumulates its block-row's slots in fixed slot
    /// order, so any bs-aligned band partition is bitwise-identical.
    fn spmm_band(&self, x: &MatRef<S>, r0: usize, r1: usize, cols: &mut [&mut [S]]) {
        let k = x.cols;
        let bs = self.bs;
        let mbpr = self.mbpr;
        let blocks = &self.blocks;
        let idx = &self.idx;
        for cb in cols.iter_mut() {
            cb.fill(S::ZERO);
        }
        let br0 = r0 / bs;
        for lb in 0..(r1 - r0) / bs {
            let br = br0 + lb;
            for s in 0..mbpr {
                let slot = br * mbpr + s;
                let bc = idx[slot] as usize;
                let base = slot * bs * bs;
                let blk = &blocks[base..base + bs * bs];
                let mut j = 0;
                while j + 3 < k {
                    let x0 = &x.col(j)[bc * bs..(bc + 1) * bs];
                    let x1 = &x.col(j + 1)[bc * bs..(bc + 1) * bs];
                    let x2 = &x.col(j + 2)[bc * bs..(bc + 1) * bs];
                    let x3 = &x.col(j + 3)[bc * bs..(bc + 1) * bs];
                    let [c0, c1, c2, c3] = &mut cols[j..j + 4] else { unreachable!() };
                    for ri in 0..bs {
                        let row = &blk[ri * bs..(ri + 1) * bs];
                        let (s0, s1, s2, s3) = S::simd_dot4(row, x0, x1, x2, x3);
                        let o = lb * bs + ri;
                        c0[o] += s0;
                        c1[o] += s1;
                        c2[o] += s2;
                        c3[o] += s3;
                    }
                    j += 4;
                }
                while j < k {
                    let xj = &x.col(j)[bc * bs..(bc + 1) * bs];
                    let cj = &mut cols[j];
                    for ri in 0..bs {
                        let row = &blk[ri * bs..(ri + 1) * bs];
                        cj[lb * bs + ri] += S::simd_dot(row, xj);
                    }
                    j += 1;
                }
            }
        }
    }

    /// Fused Y = A·X and G = YᵀY in one sweep over the stored blocks
    /// (contract rule 8, block-ELL substrate). Each block-row band
    /// reduces its freshly-gathered slice of Y into a private Gram
    /// accumulator while it is cache-resident; accumulators fold in
    /// band-index order (bitwise-reproducible at a fixed thread count).
    /// The Y half is bitwise-identical to [`BlockEll::spmm`]; the Gram
    /// is taken over the *padded* panel, whose padding rows are exactly
    /// zero, so it is ε-equal to the unpadded Gram.
    pub fn spmm_gram(&self, x: MatRef<S>, mut y: MatMut<S>, mut g: MatMut<S>) {
        assert_eq!(x.rows, self.padded_cols(), "block-ELL spmm_gram X rows");
        assert_eq!(
            (y.rows, y.cols),
            (self.padded_rows(), x.cols),
            "block-ELL spmm_gram out"
        );
        assert_eq!((g.rows, g.cols), (x.cols, x.cols), "block-ELL spmm_gram g");
        let k = x.cols;
        let rows_pad = self.padded_rows();
        if k == 0 || self.nbr == 0 || self.ncb == 0 {
            y.fill(S::ZERO);
            g.data.fill(S::ZERO);
            return;
        }
        let work = self.blocks.len() * k.div_ceil(4) + rows_pad * k;
        let nb = pool::planned_bands(work, self.nbr);
        if nb <= 1 {
            // Serial: gather pass, then the Gram accumulated in place
            // (no scratch allocation — the zero-alloc gate path).
            self.spmm(x, y.reborrow());
            g.data.fill(S::ZERO);
            crate::la::blas3::gram_accumulate(y.as_ref(), 0, rows_pad, g.data);
            for j in 0..k {
                for i in 0..j {
                    g.data[i * k + j] = g.data[j * k + i];
                }
            }
            return;
        }
        let bs = self.bs;
        let per = self.nbr.div_ceil(nb);
        let nbands = self.nbr.div_ceil(per);
        let mut accs = vec![S::ZERO; nbands * k * k];
        let mut tasks: Vec<(usize, usize, Vec<&mut [S]>, &mut [S])> = Vec::with_capacity(nbands);
        {
            let mut col_tails: Vec<&mut [S]> = y.data.chunks_mut(rows_pad).collect();
            let mut acc_rest: &mut [S] = &mut accs;
            for w in 0..nbands {
                let r0 = w * per * bs;
                let r1 = ((w + 1) * per).min(self.nbr) * bs;
                let mut band_cols: Vec<&mut [S]> = Vec::with_capacity(k);
                for tail in col_tails.iter_mut() {
                    let t = std::mem::take(tail);
                    let (head, rest) = t.split_at_mut(r1 - r0);
                    band_cols.push(head);
                    *tail = rest;
                }
                let (acc_band, acc_tail) = acc_rest.split_at_mut(k * k);
                acc_rest = acc_tail;
                tasks.push((r0, r1, band_cols, acc_band));
            }
        }
        parallel_tasks(tasks, |_w, (r0, r1, mut band_cols, acc)| {
            self.spmm_band(&x, r0, r1, &mut band_cols);
            crate::la::blas3::gram_accumulate_cols(&band_cols, acc);
        });
        let (first, rest) = accs.split_at_mut(k * k);
        for chunk in rest.chunks(k * k) {
            for (fv, &cv) in first.iter_mut().zip(chunk) {
                *fv += cv;
            }
        }
        crate::la::blas3::gram_mirror(first, &mut g);
    }

    /// Allocating wrapper around [`BlockEll::spmm`] — kept as the oracle
    /// entry point the AOT artifact integration tests call.
    pub fn spmm_ref(&self, x: &Mat<S>) -> Mat<S> {
        let mut y = Mat::zeros(self.padded_rows(), x.cols());
        self.spmm(x.as_ref(), y.as_mut());
        y
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen::sparse::{generate, SparseSpec};
    use crate::util::rng::Rng;

    #[test]
    fn roundtrip_matches_dense_spmm() {
        let spec = SparseSpec { rows: 90, cols: 70, nnz: 600, seed: 4, ..Default::default() };
        let a = generate(&spec);
        let be = BlockEll::from_csr(&a, 16, 64).unwrap();
        assert_eq!(be.padded_rows() % 16, 0);
        let mut rng = Rng::new(5);
        // padded X: real rows then zeros
        let mut x = Mat::zeros(be.padded_cols(), 3);
        for j in 0..3 {
            for i in 0..70 {
                x.set(i, j, rng.normal());
            }
        }
        let y = be.spmm_ref(&x);
        // compare the unpadded corner against an explicit CSR evaluation
        for j in 0..3 {
            for i in 0..90 {
                let e = {
                    let (cols, vals) = a.row(i);
                    cols.iter().zip(vals).map(|(&c, &v)| v * x.at(c as usize, j)).sum::<f64>()
                };
                assert!((y.at(i, j) - e).abs() < 1e-12, "({i},{j})");
            }
        }
        // padded rows are zero
        for i in 90..be.padded_rows() {
            assert_eq!(y.at(i, 0), 0.0);
        }
    }

    #[test]
    fn spmm_register_blocked_matches_naive() {
        // k=6 exercises the 4-column micro-kernel plus the remainder
        // loop; bs=8 with ragged 130x100 exercises block padding.
        let spec = SparseSpec { rows: 130, cols: 100, nnz: 1500, seed: 11, ..Default::default() };
        let a = generate(&spec);
        let be = BlockEll::from_csr(&a, 8, 64).unwrap();
        let ad = a.to_dense();
        let mut rng = Rng::new(12);
        let mut x = Mat::zeros(be.padded_cols(), 6);
        for j in 0..6 {
            for i in 0..100 {
                x.set(i, j, rng.normal());
            }
        }
        let y = be.spmm_ref(&x);
        for j in 0..6 {
            for i in 0..130 {
                let e = (0..100).map(|c| ad.at(i, c) * x.at(c, j)).sum::<f64>();
                assert!((y.at(i, j) - e).abs() < 1e-10, "({i},{j})");
            }
        }
        for i in 130..be.padded_rows() {
            assert_eq!(y.at(i, 0), 0.0);
        }
    }

    #[test]
    fn spmm_gram_matches_unfused() {
        // Y must be bitwise spmm; G ε-equal to YᵀY over the padded
        // panel (padding rows are zero, so also over the logical one).
        let spec = SparseSpec { rows: 600, cols: 180, nnz: 9000, seed: 21, ..Default::default() };
        let a = generate(&spec);
        let be = BlockEll::from_csr(&a, 16, 64).unwrap();
        let mut rng = Rng::new(22);
        for k in [1usize, 5, 8] {
            let mut x = Mat::zeros(be.padded_cols(), k);
            for j in 0..k {
                for i in 0..180 {
                    x.set(i, j, rng.normal());
                }
            }
            let y0 = be.spmm_ref(&x);
            let mut y = Mat::zeros(be.padded_rows(), k);
            let mut g = Mat::zeros(k, k);
            be.spmm_gram(x.as_ref(), y.as_mut(), g.as_mut());
            let same = y0.data().iter().zip(y.data()).all(|(p, q)| p.to_bits() == q.to_bits());
            assert!(same, "k={k}: fused Y differs from spmm");
            let expect = crate::la::blas3::mat_tn(&y0, &y0);
            let scale = expect.fro_norm().max(1.0);
            assert!(g.max_abs_diff(&expect) / scale < 1e-12, "k={k}: Gram mismatch");
        }
    }

    #[test]
    fn from_csr_auto_never_fails() {
        // Even the ELL-hostile close-to-dense-row matrix converts when
        // the cap sits at ncb; parity with the capped constructor.
        let spec = SparseSpec {
            rows: 64,
            cols: 256,
            nnz: 1600,
            seed: 7,
            skew: 2.0,
            ..Default::default()
        };
        let a = generate(&spec);
        let be = BlockEll::from_csr_auto(&a, 16);
        assert!(be.mbpr <= a.cols().div_ceil(16));
        let mut rng = Rng::new(8);
        let mut x = Mat::zeros(be.padded_cols(), 2);
        for j in 0..2 {
            for i in 0..256 {
                x.set(i, j, rng.normal());
            }
        }
        let y = be.spmm_ref(&x);
        for i in 0..64 {
            let (cols, vals) = a.row(i);
            let e: f64 = cols.iter().zip(vals).map(|(&c, &v)| v * x.at(c as usize, 0)).sum();
            assert!((y.at(i, 0) - e).abs() < 1e-10, "row {i}");
        }
    }

    #[test]
    fn ell_width_cap_enforced() {
        // A close-to-dense row exceeds a tight width cap.
        let spec = SparseSpec {
            rows: 64,
            cols: 256,
            nnz: 1600,
            seed: 7,
            skew: 2.0,
            ..Default::default()
        };
        let a = generate(&spec);
        assert!(BlockEll::from_csr(&a, 16, 2).is_err());
        assert!(BlockEll::from_csr(&a, 16, 64).is_ok());
    }

    #[test]
    fn fill_factor_reasonable() {
        let spec = SparseSpec { rows: 256, cols: 256, nnz: 2000, seed: 9, ..Default::default() };
        let a = generate(&spec);
        let be = BlockEll::from_csr(&a, 16, 64).unwrap();
        let ff = be.fill_factor(a.nnz());
        assert!(ff >= 1.0, "fill {ff}");
        // blocks store bs*bs slots per >=1 nnz; for random sparsity this
        // is large but must stay finite/positive.
        assert!(ff < 400.0, "fill {ff}");
    }

    #[test]
    fn empty_matrix() {
        let a = Csr::from_parts(32, 32, vec![0; 33], vec![], vec![]).unwrap();
        let be = BlockEll::from_csr(&a, 16, 8).unwrap();
        assert_eq!(be.mbpr, 1); // min width, zero blocks
        let x = Mat::zeros(32, 2);
        assert_eq!(be.spmm_ref(&x).fro_norm(), 0.0);
    }
}
