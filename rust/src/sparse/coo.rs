//! Coordinate-format sparse matrix (assembly / I/O staging format).

use crate::error::{shape_err, Result};
use crate::util::scalar::Scalar;

/// COO triplet matrix. Duplicates are allowed until conversion (they sum).
/// Generic over the element precision `S` (default `f64`).
#[derive(Clone, Debug, Default)]
pub struct Coo<S: Scalar = f64> {
    pub rows: usize,
    pub cols: usize,
    pub row_idx: Vec<u32>,
    pub col_idx: Vec<u32>,
    pub values: Vec<S>,
}

impl<S: Scalar> Coo<S> {
    pub fn new(rows: usize, cols: usize) -> Coo<S> {
        Coo { rows, cols, ..Default::default() }
    }

    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    pub fn push(&mut self, i: usize, j: usize, v: S) {
        debug_assert!(i < self.rows && j < self.cols);
        self.row_idx.push(i as u32);
        self.col_idx.push(j as u32);
        self.values.push(v);
    }

    /// Validate all indices are in range (used after parsing).
    pub fn validate(&self) -> Result<()> {
        for (&i, &j) in self.row_idx.iter().zip(&self.col_idx) {
            if i as usize >= self.rows || j as usize >= self.cols {
                return Err(shape_err(
                    "coo",
                    format!("entry ({i},{j}) outside {}x{}", self.rows, self.cols),
                ));
            }
        }
        Ok(())
    }

    /// Transposed copy.
    pub fn transpose(&self) -> Coo<S> {
        Coo {
            rows: self.cols,
            cols: self.rows,
            row_idx: self.col_idx.clone(),
            col_idx: self.row_idx.clone(),
            values: self.values.clone(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn push_and_validate() {
        let mut c = Coo::new(3, 4);
        c.push(0, 0, 1.0);
        c.push(2, 3, -2.0);
        assert_eq!(c.nnz(), 2);
        assert!(c.validate().is_ok());
        c.row_idx.push(5);
        c.col_idx.push(0);
        c.values.push(1.0);
        assert!(c.validate().is_err());
    }

    #[test]
    fn transpose_swaps() {
        let mut c = Coo::new(2, 5);
        c.push(1, 4, 3.0);
        let t = c.transpose();
        assert_eq!((t.rows, t.cols), (5, 2));
        assert_eq!((t.row_idx[0], t.col_idx[0]), (4, 1));
    }
}
