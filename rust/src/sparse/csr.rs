//! CSR sparse matrix and the SpMM kernels (the cuSPARSE stand-ins).
//!
//! The paper's central performance observation is the asymmetry between
//! SpMM with A (gather along rows, fast) and SpMM with Aᵀ (scatter, slow
//! in cuSPARSE). Our CSR substrate reproduces exactly that structural
//! asymmetry: `spmm` streams rows and accumulates locally, while `spmm_t`
//! scatters into the output. An explicit `transpose()` (CSC conversion)
//! gives the alternative the paper tried ("explicitly storing a transposed
//! copy"), which we also evaluate in the ablation bench.

use super::coo::Coo;
use crate::error::{shape_err, Result};
use crate::la::mat::Mat;

/// Compressed sparse row matrix, f64 values, u32 column indices.
#[derive(Clone, Debug)]
pub struct Csr {
    rows: usize,
    cols: usize,
    indptr: Vec<usize>,
    indices: Vec<u32>,
    values: Vec<f64>,
}

impl Csr {
    /// Build from COO, summing duplicates and sorting columns in each row.
    pub fn from_coo(coo: &Coo) -> Result<Csr> {
        coo.validate()?;
        let rows = coo.rows;
        // Count entries per row.
        let mut counts = vec![0usize; rows + 1];
        for &i in &coo.row_idx {
            counts[i as usize + 1] += 1;
        }
        for i in 0..rows {
            counts[i + 1] += counts[i];
        }
        let mut indices = vec![0u32; coo.nnz()];
        let mut values = vec![0.0; coo.nnz()];
        let mut next = counts.clone();
        for k in 0..coo.nnz() {
            let i = coo.row_idx[k] as usize;
            let p = next[i];
            indices[p] = coo.col_idx[k];
            values[p] = coo.values[k];
            next[i] += 1;
        }
        // Sort each row by column; merge duplicates.
        let mut out_indptr = vec![0usize; rows + 1];
        let mut out_indices = Vec::with_capacity(coo.nnz());
        let mut out_values = Vec::with_capacity(coo.nnz());
        let mut scratch: Vec<(u32, f64)> = Vec::new();
        for i in 0..rows {
            let lo = counts[i];
            let hi = counts[i + 1];
            scratch.clear();
            scratch.extend(indices[lo..hi].iter().copied().zip(values[lo..hi].iter().copied()));
            scratch.sort_unstable_by_key(|&(c, _)| c);
            let mut k = 0;
            while k < scratch.len() {
                let (c, mut v) = scratch[k];
                k += 1;
                while k < scratch.len() && scratch[k].0 == c {
                    v += scratch[k].1;
                    k += 1;
                }
                out_indices.push(c);
                out_values.push(v);
            }
            out_indptr[i + 1] = out_indices.len();
        }
        Ok(Csr {
            rows,
            cols: coo.cols,
            indptr: out_indptr,
            indices: out_indices,
            values: out_values,
        })
    }

    /// Build directly from CSR parts (validated).
    pub fn from_parts(
        rows: usize,
        cols: usize,
        indptr: Vec<usize>,
        indices: Vec<u32>,
        values: Vec<f64>,
    ) -> Result<Csr> {
        if indptr.len() != rows + 1 || indices.len() != values.len() || indptr[rows] != indices.len()
        {
            return Err(shape_err("csr", "inconsistent indptr/indices/values"));
        }
        for w in indptr.windows(2) {
            if w[1] < w[0] {
                return Err(shape_err("csr", "indptr not monotone"));
            }
        }
        if indices.iter().any(|&c| c as usize >= cols) {
            return Err(shape_err("csr", "column index out of range"));
        }
        Ok(Csr { rows, cols, indptr, indices, values })
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }
    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }
    #[inline]
    pub fn nnz(&self) -> usize {
        self.values.len()
    }
    #[inline]
    pub fn indptr(&self) -> &[usize] {
        &self.indptr
    }
    #[inline]
    pub fn indices(&self) -> &[u32] {
        &self.indices
    }
    #[inline]
    pub fn values(&self) -> &[f64] {
        &self.values
    }

    /// Row view: (column indices, values).
    #[inline]
    pub fn row(&self, i: usize) -> (&[u32], &[f64]) {
        let lo = self.indptr[i];
        let hi = self.indptr[i + 1];
        (&self.indices[lo..hi], &self.values[lo..hi])
    }

    /// Explicit transpose (CSR of Aᵀ, i.e. a CSC view of A).
    pub fn transpose(&self) -> Csr {
        let mut counts = vec![0usize; self.cols + 1];
        for &c in &self.indices {
            counts[c as usize + 1] += 1;
        }
        for i in 0..self.cols {
            counts[i + 1] += counts[i];
        }
        let mut indices = vec![0u32; self.nnz()];
        let mut values = vec![0.0; self.nnz()];
        let mut next = counts.clone();
        for i in 0..self.rows {
            let (cols, vals) = self.row(i);
            for (&c, &v) in cols.iter().zip(vals) {
                let p = next[c as usize];
                indices[p] = i as u32;
                values[p] = v;
                next[c as usize] += 1;
            }
        }
        Csr {
            rows: self.cols,
            cols: self.rows,
            indptr: counts,
            indices,
            values,
        }
    }

    /// Y = A · X  (SpMM; X is n×k, Y is m×k, both column-major dense).
    ///
    /// Row-gather form: for each output row, accumulate dot products of the
    /// sparse row against the k dense columns. Fast path of the paper.
    pub fn spmm(&self, x: &Mat, y: &mut Mat) {
        assert_eq!(x.rows(), self.cols, "spmm inner dim");
        assert_eq!((y.rows(), y.cols()), (self.rows, x.cols()), "spmm out");
        let k = x.cols();
        y.data_mut().fill(0.0);
        // Process dense columns in pairs to amortize index decoding.
        let m = self.rows;
        let mut j = 0;
        while j + 1 < k {
            // Split y's storage into the two target columns.
            let (c0, c1) = {
                let data = y.data_mut();
                let (head, tail) = data.split_at_mut((j + 1) * m);
                (&mut head[j * m..], &mut tail[..m])
            };
            let x0 = x.col(j);
            let x1 = x.col(j + 1);
            for i in 0..m {
                let lo = self.indptr[i];
                let hi = self.indptr[i + 1];
                let (mut s0, mut s1) = (0.0, 0.0);
                for p in lo..hi {
                    let c = self.indices[p] as usize;
                    let v = self.values[p];
                    s0 += v * x0[c];
                    s1 += v * x1[c];
                }
                c0[i] = s0;
                c1[i] = s1;
            }
            j += 2;
        }
        if j < k {
            let x0 = x.col(j);
            let c0 = y.col_mut(j);
            for i in 0..m {
                let lo = self.indptr[i];
                let hi = self.indptr[i + 1];
                let mut s0 = 0.0;
                for p in lo..hi {
                    s0 += self.values[p] * x0[self.indices[p] as usize];
                }
                c0[i] = s0;
            }
        }
    }

    /// Y = Aᵀ · X  (transposed SpMM; X is m×k, Y is n×k).
    ///
    /// Scatter form: walks A's rows and scatters updates into Y — the
    /// structurally slow kernel the paper identifies as the bottleneck
    /// (implicit transpose in cuSPARSE). Kept deliberately in scatter form;
    /// the "explicit transposed copy" alternative is `transpose()+spmm`.
    pub fn spmm_t(&self, x: &Mat, y: &mut Mat) {
        assert_eq!(x.rows(), self.rows, "spmm_t inner dim");
        assert_eq!((y.rows(), y.cols()), (self.cols, x.cols()), "spmm_t out");
        let k = x.cols();
        y.data_mut().fill(0.0);
        let n = self.cols;
        for i in 0..self.rows {
            let lo = self.indptr[i];
            let hi = self.indptr[i + 1];
            if lo == hi {
                continue;
            }
            for j in 0..k {
                let xij = x.at(i, j);
                if xij == 0.0 {
                    continue;
                }
                let yj = &mut y.data_mut()[j * n..(j + 1) * n];
                for p in lo..hi {
                    yj[self.indices[p] as usize] += self.values[p] * xij;
                }
            }
        }
    }

    /// Densify (tests / tiny matrices only).
    pub fn to_dense(&self) -> Mat {
        let mut m = Mat::zeros(self.rows, self.cols);
        for i in 0..self.rows {
            let (cols, vals) = self.row(i);
            for (&c, &v) in cols.iter().zip(vals) {
                m.set(i, c as usize, v);
            }
        }
        m
    }

    /// Frobenius norm.
    pub fn fro_norm(&self) -> f64 {
        self.values.iter().map(|v| v * v).sum::<f64>().sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::la::blas3::{mat_nn, mat_tn};
    use crate::util::rng::Rng;

    fn random_coo(rows: usize, cols: usize, nnz: usize, seed: u64) -> Coo {
        let mut rng = Rng::new(seed);
        let mut c = Coo::new(rows, cols);
        for _ in 0..nnz {
            c.push(rng.below(rows), rng.below(cols), rng.normal());
        }
        c
    }

    #[test]
    fn from_coo_sorts_and_merges() {
        let mut c = Coo::new(2, 3);
        c.push(0, 2, 1.0);
        c.push(0, 0, 2.0);
        c.push(0, 2, 3.0); // duplicate with the first
        c.push(1, 1, 5.0);
        let a = Csr::from_coo(&c).unwrap();
        assert_eq!(a.nnz(), 3);
        let (cols, vals) = a.row(0);
        assert_eq!(cols, &[0, 2]);
        assert_eq!(vals, &[2.0, 4.0]);
        let (cols, vals) = a.row(1);
        assert_eq!(cols, &[1]);
        assert_eq!(vals, &[5.0]);
    }

    #[test]
    fn spmm_matches_dense() {
        let coo = random_coo(23, 17, 80, 7);
        let a = Csr::from_coo(&coo).unwrap();
        let ad = a.to_dense();
        let mut rng = Rng::new(8);
        for k in [1, 2, 3, 8] {
            let x = Mat::randn(17, k, &mut rng);
            let mut y = Mat::zeros(23, k);
            a.spmm(&x, &mut y);
            let expect = mat_nn(&ad, &x);
            assert!(y.max_abs_diff(&expect) < 1e-12, "k={k}");
        }
    }

    #[test]
    fn spmm_t_matches_dense() {
        let coo = random_coo(19, 29, 100, 9);
        let a = Csr::from_coo(&coo).unwrap();
        let ad = a.to_dense();
        let mut rng = Rng::new(10);
        for k in [1, 5] {
            let x = Mat::randn(19, k, &mut rng);
            let mut y = Mat::zeros(29, k);
            a.spmm_t(&x, &mut y);
            let expect = mat_tn(&ad, &x);
            assert!(y.max_abs_diff(&expect) < 1e-12, "k={k}");
        }
    }

    #[test]
    fn transpose_roundtrip_and_equivalence() {
        let coo = random_coo(31, 11, 90, 11);
        let a = Csr::from_coo(&coo).unwrap();
        let at = a.transpose();
        assert_eq!((at.rows(), at.cols()), (11, 31));
        assert!(at.transpose().to_dense().max_abs_diff(&a.to_dense()) < 1e-15);
        // Aᵀ·X via scatter == (Aᵀ as CSR)·X via gather
        let mut rng = Rng::new(12);
        let x = Mat::randn(31, 4, &mut rng);
        let mut y1 = Mat::zeros(11, 4);
        let mut y2 = Mat::zeros(11, 4);
        a.spmm_t(&x, &mut y1);
        at.spmm(&x, &mut y2);
        assert!(y1.max_abs_diff(&y2) < 1e-12);
    }

    #[test]
    fn empty_rows_ok() {
        let mut c = Coo::new(4, 4);
        c.push(1, 1, 2.0);
        let a = Csr::from_coo(&c).unwrap();
        let x = Mat::eye(4);
        let mut y = Mat::zeros(4, 4);
        a.spmm(&x, &mut y);
        assert_eq!(y.at(1, 1), 2.0);
        assert_eq!(y.fro_norm(), 2.0);
    }

    #[test]
    fn from_parts_validation() {
        assert!(Csr::from_parts(2, 2, vec![0, 1, 2], vec![0, 1], vec![1.0, 2.0]).is_ok());
        assert!(Csr::from_parts(2, 2, vec![0, 2, 1], vec![0, 1], vec![1.0, 2.0]).is_err());
        assert!(Csr::from_parts(2, 2, vec![0, 1, 2], vec![0, 5], vec![1.0, 2.0]).is_err());
    }
}
