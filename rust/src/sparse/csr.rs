//! CSR sparse matrix and the SpMM kernels (the cuSPARSE stand-ins).
//!
//! The paper's central performance observation is the asymmetry between
//! SpMM with A (gather along rows, fast) and SpMM with Aᵀ (scatter, slow
//! in cuSPARSE). Our CSR substrate reproduces exactly that structural
//! asymmetry: `spmm` streams rows and accumulates locally, while `spmm_t`
//! scatters into the output. An explicit `transpose()` (CSC conversion)
//! gives the alternative the paper tried ("explicitly storing a transposed
//! copy"), which we also evaluate in the ablation bench.
//!
//! Threading model (every kernel here runs on the persistent worker pool
//! in `util::pool` — no per-call thread spawn anywhere in the hot path,
//! and the pool's static banding gives each worker the *same* row/column
//! band of the same matrix call after call, so iterative algorithms keep
//! their operand bands cache-warm per worker):
//!
//! * `spmm` partitions the *output rows* into contiguous bands: each
//!   thread walks its sparse rows once per group of 4 dense columns
//!   (register blocking matching the `gemm_nn` idiom), so writes are
//!   disjoint by construction and A's row stream is read k/4 times
//!   instead of k. The row×column-group dots run on the
//!   `util::simd` gathered microkernels, and the parallel partition is
//!   nnz-balanced and *memoized per operand* (pointer + generation key;
//!   see `band_plan`), so repeat solves against the same A skip the
//!   balancing scan entirely.
//! * `spmm_t` partitions the *output columns* across threads: column j
//!   of Y only accumulates `A[i,:]ᵀ · X[i,j]` terms, so a thread that
//!   owns whole columns scatters race-free. The per-call borrows of the
//!   output column and of `X[:,j]` are hoisted out of the row loop.
//! * `transpose` runs a parallel column-count histogram, then fills the
//!   output in parallel over *destination column bands* balanced by nnz:
//!   a band's destination range `[counts[c0], counts[c1])` is contiguous,
//!   so bands write disjoint slices while each worker re-scans only the
//!   (cheap, u32) index stream.
//! * `from_coo` uses the same parallel histogram for the row-counting
//!   pass and sorts/merges row segments in parallel over row blocks.
//!
//! ## Element precision (the `Scalar` abstraction)
//!
//! [`Csr<S>`] is generic over [`Scalar`] (`f32`/`f64`, default `f64`):
//! indices stay `u32`/`usize`, only the value array changes width. The
//! SpMM kernels here are memory-bandwidth-bound (each nonzero is touched
//! once per dense-column group), so the fp32 instantiation moves roughly
//! half the bytes per flop — the ~2× win the paper's single-precision GPU
//! runs exploit, measured per-dtype by `bench_blocks`. The dtype is a
//! runtime choice: matrices are generated/read as `Csr<f64>` and
//! converted with [`Csr::cast`] when the driver is asked for `--dtype
//! f32`; parity suites (`tests/test_dtype_parity.rs`) hold the f32 kernels
//! to `S::EPSILON`-scaled agreement with the f64 reference.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use super::coo::Coo;
use crate::error::{shape_err, Result};
use crate::la::mat::{Mat, MatMut, MatRef};
use crate::util::pool::{
    self, num_threads, parallel_chunks_mut_work, parallel_histogram, parallel_reduce_work,
    parallel_row_blocks_bounds, parallel_row_blocks_work, parallel_tasks,
};
use crate::util::scalar::Scalar;

/// Compressed sparse row matrix, `S` values (default `f64`), u32 column
/// indices. See the module doc for the `Scalar`/dtype story.
///
/// Every `Csr` carries a process-unique *generation* stamp assigned at
/// construction (cloning assigns a fresh one): `(data pointer, gen)` is
/// a collision-free identity key, which the band-plan cache below uses
/// to recognize "the same A as last call" without hashing the operand.
#[derive(Debug)]
pub struct Csr<S: Scalar = f64> {
    rows: usize,
    cols: usize,
    indptr: Vec<usize>,
    indices: Vec<u32>,
    values: Vec<S>,
    gen: u64,
}

impl<S: Scalar> Clone for Csr<S> {
    fn clone(&self) -> Self {
        Csr {
            rows: self.rows,
            cols: self.cols,
            indptr: self.indptr.clone(),
            indices: self.indices.clone(),
            values: self.values.clone(),
            gen: fresh_gen(),
        }
    }
}

/// Next matrix generation stamp. Process-unique, so a `(ptr, gen)` pair
/// can never suffer pointer-reuse (ABA) confusion: a freed-and-reused
/// allocation necessarily belongs to a younger generation.
fn fresh_gen() -> u64 {
    static NEXT: AtomicU64 = AtomicU64::new(1);
    NEXT.fetch_add(1, Ordering::Relaxed)
}

/// One cached row-band partition: nnz-balanced `spmm` bounds for a
/// specific operand at a specific band count.
struct BandPlan {
    key: (usize, u64, usize), // (indptr ptr, generation, bands)
    bounds: Arc<Vec<usize>>,
}

/// Small global memo of band plans, keyed by matrix identity
/// (pointer + generation — see [`Csr::generation`]) and band count.
/// Iterative solvers hit the same handful of operands thousands of
/// times; the linear scan over <= 32 entries is noise next to the
/// O(log rows) × bands partition it avoids recomputing, and eviction is
/// FIFO (dead generations age out naturally).
static BAND_PLANS: Mutex<Vec<BandPlan>> = Mutex::new(Vec::new());
const BAND_PLAN_CAP: usize = 32;

/// Row bounds (strictly increasing, `0 .. rows`) splitting `indptr`'s
/// rows into `bands` contiguous bands of roughly equal `nnz + rows`
/// weight (the spmm work model), rounded to `align`-row boundaries so
/// bands don't shear cache lines / first-touch pages. Shared with the
/// out-of-core shard writer (`sparse::shard`), which cuts row-band
/// shards on the same 32-row-aligned nnz-balanced boundaries.
pub(crate) fn balanced_row_bounds(indptr: &[usize], bands: usize, align: usize) -> Vec<usize> {
    let rows = indptr.len() - 1;
    let total = indptr[rows] + rows;
    let mut bounds = Vec::with_capacity(bands + 1);
    bounds.push(0usize);
    for w in 1..bands {
        let target = (total as u128 * w as u128 / bands as u128) as usize;
        // First row where the cumulative weight reaches the target
        // (indptr[r] + r is strictly increasing in r).
        let prev = *bounds.last().unwrap();
        let (mut lo, mut hi) = (prev, rows);
        while lo < hi {
            let mid = lo + (hi - lo) / 2;
            if indptr[mid] + mid < target {
                lo = mid + 1;
            } else {
                hi = mid;
            }
        }
        let aligned = ((lo + align / 2) / align) * align; // round to nearest boundary
        if aligned > prev && aligned < rows {
            bounds.push(aligned);
        }
    }
    bounds.push(rows);
    bounds
}

/// Fetch (or compute and memoize) the nnz-balanced spmm band plan for
/// `a` at `bands` bands. Returns `None` when balancing degenerates to a
/// single band (caller should use the uniform helper's serial path).
fn band_plan<S: Scalar>(a: &Csr<S>, bands: usize) -> Option<Arc<Vec<usize>>> {
    let key = (a.indptr.as_ptr() as usize, a.gen, bands);
    {
        let plans = BAND_PLANS.lock().unwrap_or_else(|e| e.into_inner());
        if let Some(p) = plans.iter().find(|p| p.key == key) {
            return Some(Arc::clone(&p.bounds));
        }
    }
    let bounds = Arc::new(balanced_row_bounds(&a.indptr, bands, 32));
    if bounds.len() < 3 {
        // Everything collapsed into one band (tiny or degenerate
        // operand): not worth caching, not worth banding.
        return None;
    }
    let mut plans = BAND_PLANS.lock().unwrap_or_else(|e| e.into_inner());
    if !plans.iter().any(|p| p.key == key) {
        if plans.len() >= BAND_PLAN_CAP {
            plans.remove(0);
        }
        plans.push(BandPlan { key, bounds: Arc::clone(&bounds) });
    }
    Some(bounds)
}

/// The spmm band body: gather rows `[r0, r1)` of `A·X` into `cols`
/// (the band's sub-slices of the output columns). Shared by the uniform
/// and cached-band-plan partitions — and by the out-of-core sharded
/// spmm (`sparse::shard`), which runs it on shard-local CSR arrays;
/// every output element is written exactly once by a fixed-order dot,
/// so any row partition (in-core bands or disk shards) produces
/// bitwise-identical results. The inner dots are the
/// `simd_gather_dot*` microkernels, 4-column register-blocked.
pub(crate) fn spmm_rows<S: Scalar>(
    indptr: &[usize],
    indices: &[u32],
    values: &[S],
    x: &MatRef<S>,
    r0: usize,
    r1: usize,
    cols: &mut [&mut [S]],
) {
    let k = x.cols;
    let mut j = 0;
    while j + 3 < k {
        let x0 = x.col(j);
        let x1 = x.col(j + 1);
        let x2 = x.col(j + 2);
        let x3 = x.col(j + 3);
        let [c0, c1, c2, c3] = &mut cols[j..j + 4] else { unreachable!() };
        for i in r0..r1 {
            let lo = indptr[i];
            let hi = indptr[i + 1];
            let (s0, s1, s2, s3) =
                S::simd_gather_dot4(&values[lo..hi], &indices[lo..hi], x0, x1, x2, x3);
            c0[i - r0] = s0;
            c1[i - r0] = s1;
            c2[i - r0] = s2;
            c3[i - r0] = s3;
        }
        j += 4;
    }
    if j + 1 < k {
        let x0 = x.col(j);
        let x1 = x.col(j + 1);
        let [c0, c1] = &mut cols[j..j + 2] else { unreachable!() };
        for i in r0..r1 {
            let lo = indptr[i];
            let hi = indptr[i + 1];
            let (s0, s1) = S::simd_gather_dot2(&values[lo..hi], &indices[lo..hi], x0, x1);
            c0[i - r0] = s0;
            c1[i - r0] = s1;
        }
        j += 2;
    }
    if j < k {
        let x0 = x.col(j);
        let cj = &mut cols[j];
        for i in r0..r1 {
            let lo = indptr[i];
            let hi = indptr[i + 1];
            cj[i - r0] = S::simd_gather_dot1(&values[lo..hi], &indices[lo..hi], x0);
        }
    }
}

/// Split `[0, cols)` into up to `t` consecutive bands with roughly equal
/// nnz, where `counts` is the per-column prefix array (len cols+1,
/// `counts[cols] == nnz`). Every column is covered exactly once.
fn balanced_bands(counts: &[usize], t: usize) -> Vec<(usize, usize)> {
    let cols = counts.len() - 1;
    let nnz = *counts.last().unwrap();
    let t = t.clamp(1, cols.max(1));
    let mut bands = Vec::with_capacity(t);
    let mut c0 = 0usize;
    for w in 1..=t {
        if c0 >= cols {
            break;
        }
        let target = (nnz as u128 * w as u128 / t as u128) as usize;
        let mut c1 = c0 + 1;
        while c1 < cols && counts[c1] < target {
            c1 += 1;
        }
        if w == t {
            c1 = cols;
        }
        bands.push((c0, c1));
        c0 = c1;
    }
    debug_assert!(cols == 0 || bands.last().unwrap().1 == cols);
    bands
}

impl<S: Scalar> Csr<S> {
    /// Build from COO, summing duplicates and sorting columns in each row.
    pub fn from_coo(coo: &Coo<S>) -> Result<Csr<S>> {
        coo.validate()?;
        let rows = coo.rows;
        let nnz = coo.nnz();
        // Entries per row: parallel histogram over entry blocks, summed.
        let mut counts = parallel_histogram(nnz, rows + 1, |lo, hi, c| {
            for &i in &coo.row_idx[lo..hi] {
                c[i as usize + 1] += 1;
            }
        });
        for i in 0..rows {
            counts[i + 1] += counts[i];
        }
        // Stage entries into per-row segments (serial: random-target
        // writes; the expensive sort/merge below is the parallel part).
        let mut indices = vec![0u32; nnz];
        let mut values = vec![S::ZERO; nnz];
        let mut next = counts.clone();
        for k in 0..nnz {
            let i = coo.row_idx[k] as usize;
            let p = next[i];
            indices[p] = coo.col_idx[k];
            values[p] = coo.values[k];
            next[i] += 1;
        }
        // Sort each row by column and merge duplicates, in parallel over
        // contiguous row blocks; the ordered reduce concatenates blocks
        // back in row order. Work is nnz-proportional (each entry is
        // scanned, sorted, and rewritten), not row-proportional.
        let (out_indices, out_values, row_lens) = parallel_reduce_work(
            rows,
            nnz,
            (Vec::new(), Vec::new(), Vec::new()),
            |lo, hi| {
                let mut oi: Vec<u32> = Vec::with_capacity(counts[hi] - counts[lo]);
                let mut ov: Vec<S> = Vec::with_capacity(counts[hi] - counts[lo]);
                let mut lens: Vec<usize> = Vec::with_capacity(hi - lo);
                let mut scratch: Vec<(u32, S)> = Vec::new();
                for i in lo..hi {
                    let (s, e) = (counts[i], counts[i + 1]);
                    scratch.clear();
                    scratch.extend(
                        indices[s..e].iter().copied().zip(values[s..e].iter().copied()),
                    );
                    scratch.sort_unstable_by_key(|&(c, _)| c);
                    let before = oi.len();
                    let mut k = 0;
                    while k < scratch.len() {
                        let (c, mut v) = scratch[k];
                        k += 1;
                        while k < scratch.len() && scratch[k].0 == c {
                            v += scratch[k].1;
                            k += 1;
                        }
                        oi.push(c);
                        ov.push(v);
                    }
                    lens.push(oi.len() - before);
                }
                (oi, ov, lens)
            },
            |mut a, mut b| {
                a.0.append(&mut b.0);
                a.1.append(&mut b.1);
                a.2.append(&mut b.2);
                a
            },
        );
        let mut out_indptr = vec![0usize; rows + 1];
        for (i, l) in row_lens.iter().enumerate() {
            out_indptr[i + 1] = out_indptr[i] + l;
        }
        Ok(Csr {
            rows,
            cols: coo.cols,
            indptr: out_indptr,
            indices: out_indices,
            values: out_values,
            gen: fresh_gen(),
        })
    }

    /// Build directly from CSR parts (validated).
    pub fn from_parts(
        rows: usize,
        cols: usize,
        indptr: Vec<usize>,
        indices: Vec<u32>,
        values: Vec<S>,
    ) -> Result<Csr<S>> {
        if indptr.len() != rows + 1 || indices.len() != values.len() || indptr[rows] != indices.len()
        {
            return Err(shape_err("csr", "inconsistent indptr/indices/values"));
        }
        for w in indptr.windows(2) {
            if w[1] < w[0] {
                return Err(shape_err("csr", "indptr not monotone"));
            }
        }
        if indices.iter().any(|&c| c as usize >= cols) {
            return Err(shape_err("csr", "column index out of range"));
        }
        Ok(Csr { rows, cols, indptr, indices, values, gen: fresh_gen() })
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }
    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }
    #[inline]
    pub fn nnz(&self) -> usize {
        self.values.len()
    }
    #[inline]
    pub fn indptr(&self) -> &[usize] {
        &self.indptr
    }
    #[inline]
    pub fn indices(&self) -> &[u32] {
        &self.indices
    }
    #[inline]
    pub fn values(&self) -> &[S] {
        &self.values
    }

    /// Process-unique identity stamp (fresh per construction and per
    /// clone): together with the data pointer this keys the band-plan
    /// cache, immune to allocator address reuse.
    #[inline]
    pub fn generation(&self) -> u64 {
        self.gen
    }

    /// Copy into another element precision (values round through f64);
    /// the index structure is shared-shape, so this is the dtype
    /// conversion used when `--dtype f32` is selected at the driver.
    pub fn cast<T: Scalar>(&self) -> Csr<T> {
        Csr {
            rows: self.rows,
            cols: self.cols,
            indptr: self.indptr.clone(),
            indices: self.indices.clone(),
            values: self.values.iter().map(|&v| T::from_f64(v.to_f64())).collect(),
            gen: fresh_gen(),
        }
    }

    /// Row view: (column indices, values).
    #[inline]
    pub fn row(&self, i: usize) -> (&[u32], &[S]) {
        let lo = self.indptr[i];
        let hi = self.indptr[i + 1];
        (&self.indices[lo..hi], &self.values[lo..hi])
    }

    /// Explicit transpose (CSR of Aᵀ, i.e. a CSC view of A).
    ///
    /// Histogram and fill are both parallel (see the module doc); the
    /// fill partitions destination columns into nnz-balanced bands whose
    /// output ranges are contiguous, so bands write disjoint slices.
    pub fn transpose(&self) -> Csr<S> {
        let nnz = self.nnz();
        let cols = self.cols;
        let mut counts = parallel_histogram(nnz, cols + 1, |lo, hi, c| {
            for &ci in &self.indices[lo..hi] {
                c[ci as usize + 1] += 1;
            }
        });
        for i in 0..cols {
            counts[i + 1] += counts[i];
        }
        let mut indices = vec![0u32; nnz];
        let mut values = vec![S::ZERO; nnz];
        let t = num_threads().min(cols.max(1));
        if t <= 1 || nnz < 4096 {
            let mut next = counts.clone();
            for i in 0..self.rows {
                let (rc, rv) = self.row(i);
                for (&c, &v) in rc.iter().zip(rv) {
                    let p = next[c as usize];
                    indices[p] = i as u32;
                    values[p] = v;
                    next[c as usize] += 1;
                }
            }
        } else {
            // nnz-balanced destination bands are unevenly sized, so they
            // go to the pool as prepared per-band tasks (the low-level
            // `parallel_tasks` primitive) rather than an even split.
            let bands = balanced_bands(&counts, t);
            let counts_ref = &counts;
            let mut tasks = Vec::with_capacity(bands.len());
            {
                let mut idx_rest: &mut [u32] = &mut indices;
                let mut val_rest: &mut [S] = &mut values;
                for &(c0, c1) in &bands {
                    let take = counts_ref[c1] - counts_ref[c0];
                    let (idx_band, idx_tail) = idx_rest.split_at_mut(take);
                    let (val_band, val_tail) = val_rest.split_at_mut(take);
                    idx_rest = idx_tail;
                    val_rest = val_tail;
                    tasks.push((c0, c1, idx_band, val_band));
                }
            }
            parallel_tasks(tasks, |_w, (c0, c1, idx_band, val_band)| {
                let base = counts_ref[c0];
                let mut next: Vec<usize> = counts_ref[c0..c1].iter().map(|&p| p - base).collect();
                for i in 0..self.rows {
                    let (rc, rv) = self.row(i);
                    for (&c, &v) in rc.iter().zip(rv) {
                        let cu = c as usize;
                        if cu < c0 || cu >= c1 {
                            continue;
                        }
                        let p = next[cu - c0];
                        idx_band[p] = i as u32;
                        val_band[p] = v;
                        next[cu - c0] = p + 1;
                    }
                }
            });
        }
        Csr {
            rows: self.cols,
            cols: self.rows,
            indptr: counts,
            indices,
            values,
            gen: fresh_gen(),
        }
    }

    /// Y = A · X  (SpMM; X is n×k, Y is m×k, both column-major dense).
    ///
    /// Out-parameter form over borrowed views: X is a [`MatRef`] and Y a
    /// [`MatMut`], so the backends pass workspace buffers / basis panels
    /// straight through with no staging copy and no allocation.
    ///
    /// Row-gather form: for each output row, accumulate dot products of the
    /// sparse row against the k dense columns. Fast path of the paper.
    /// Parallel over contiguous row bands of Y; 4-column register blocking
    /// amortizes each index decode over 4 multiply-adds, and each row×
    /// column-group dot runs on the `Scalar::simd_gather_dot*`
    /// microkernels (AVX2 hardware gathers where available; every level
    /// is bitwise-identical, see `util::simd`). Every output element is
    /// written exactly once, so no pre-zeroing pass is needed — which
    /// also makes *any* row partition bit-safe, so the parallel path
    /// uses nnz-balanced bands from the per-operand plan cache
    /// ([`Csr::generation`]) instead of a uniform split.
    pub fn spmm(&self, x: MatRef<S>, y: MatMut<S>) {
        assert_eq!(x.rows, self.cols, "spmm inner dim");
        assert_eq!((y.rows, y.cols), (self.rows, x.cols), "spmm out");
        let k = x.cols;
        let m = self.rows;
        if m == 0 || k == 0 {
            return;
        }
        let indptr = &self.indptr;
        let indices = &self.indices;
        let values = &self.values;
        // Work estimate: the nnz stream dominates (each nonzero feeds k
        // FMAs), plus the m×k output writes — the output size alone
        // would serialize short-and-dense operands.
        let work = self.nnz() * k + m * k;
        let bands = pool::planned_bands(work, m.div_ceil(32));
        if bands > 1 {
            if let Some(bounds) = band_plan(self, bands) {
                parallel_row_blocks_bounds(y.data, m, &bounds, |r0, r1, cols| {
                    spmm_rows(indptr, indices, values, &x, r0, r1, cols)
                });
                return;
            }
        }
        parallel_row_blocks_work(y.data, m, 32, work, |r0, r1, cols| {
            spmm_rows(indptr, indices, values, &x, r0, r1, cols)
        });
    }

    /// Y = Aᵀ · X  (transposed SpMM; X is m×k, Y is n×k; borrowed views
    /// as for [`Csr::spmm`]).
    ///
    /// Scatter form: walks A's rows and scatters updates into Y — the
    /// structurally slow kernel the paper identifies as the bottleneck
    /// (implicit transpose in cuSPARSE). Kept deliberately in scatter
    /// form; the "explicit transposed copy" alternative is
    /// `transpose()+spmm` (adaptively cached by the CPU backend). The
    /// parallel path assigns whole output *columns* to threads, so each
    /// thread's scatter targets are private and the output-column /
    /// X-column borrows hoist out of the row loop.
    pub fn spmm_t(&self, x: MatRef<S>, y: MatMut<S>) {
        assert_eq!(x.rows, self.rows, "spmm_t inner dim");
        assert_eq!((y.rows, y.cols), (self.cols, x.cols), "spmm_t out");
        let n = self.cols;
        if n == 0 || x.cols == 0 {
            return;
        }
        let indptr = &self.indptr;
        let indices = &self.indices;
        let values = &self.values;
        // Work estimate: every output column re-streams the whole nnz
        // stream (scatter form), plus the n×k output writes.
        let work = self.nnz() * x.cols + n * x.cols;
        parallel_chunks_mut_work(y.data, n, work, |j, yj| {
            yj.fill(S::ZERO);
            let xj = x.col(j);
            for (i, &xij) in xj.iter().enumerate() {
                if xij == S::ZERO {
                    continue;
                }
                let lo = indptr[i];
                let hi = indptr[i + 1];
                for p in lo..hi {
                    yj[indices[p] as usize] += values[p] * xij;
                }
            }
        });
    }

    /// Fused Y = A·X and G = YᵀY in one sweep over the nonzeros
    /// (contract rule 8: the `apply_a_gram_into` kernel).
    ///
    /// Each nnz-balanced row band (same cached plan as [`Csr::spmm`])
    /// gathers its slice of Y and immediately reduces it into a private
    /// b×b Gram accumulator while the slice is still cache-resident —
    /// the q×b panel is never re-streamed from memory for the Gram.
    /// Per-band accumulators fold in band-index order, so results are
    /// bitwise-reproducible at a fixed thread count; the Y half is
    /// bitwise-identical to [`Csr::spmm`] under any partition (gather
    /// writes each element exactly once), the Gram half is ε-equal to
    /// `gram_into` (different reduction banding).
    ///
    /// The serial path (pool planned to one band) is allocation-free:
    /// it accumulates the upper triangle straight into `g`'s storage and
    /// mirrors in place, which is what the steady-state zero-alloc gate
    /// exercises.
    pub fn spmm_gram(&self, x: MatRef<S>, mut y: MatMut<S>, mut g: MatMut<S>) {
        assert_eq!(x.rows, self.cols, "spmm_gram inner dim");
        assert_eq!((y.rows, y.cols), (self.rows, x.cols), "spmm_gram y");
        assert_eq!((g.rows, g.cols), (x.cols, x.cols), "spmm_gram g");
        let k = x.cols;
        let m = self.rows;
        let work = self.nnz() * k + m * k;
        let bounds = if m > 0 && k > 0 {
            let bands = pool::planned_bands(work, m.div_ceil(32));
            if bands > 1 {
                band_plan(self, bands)
            } else {
                None
            }
        } else {
            None
        };
        let Some(bounds) = bounds else {
            // Serial / degenerate: one gather pass, then the Gram
            // accumulated in place (no scratch allocation).
            self.spmm(x, y.reborrow());
            g.data.fill(S::ZERO);
            crate::la::blas3::gram_accumulate(y.as_ref(), 0, m, g.data);
            for j in 0..k {
                for i in 0..j {
                    g.data[i * k + j] = g.data[j * k + i];
                }
            }
            return;
        };
        let indptr = &self.indptr;
        let indices = &self.indices;
        let values = &self.values;
        let nb = bounds.len() - 1;
        let mut accs = vec![S::ZERO; nb * k * k];
        let mut tasks: Vec<(usize, usize, Vec<&mut [S]>, &mut [S])> = Vec::with_capacity(nb);
        {
            let mut col_tails: Vec<&mut [S]> = y.data.chunks_mut(m).collect();
            let mut acc_rest: &mut [S] = &mut accs;
            for w in 0..nb {
                let (r0, r1) = (bounds[w], bounds[w + 1]);
                let mut band_cols: Vec<&mut [S]> = Vec::with_capacity(k);
                for tail in col_tails.iter_mut() {
                    let t = std::mem::take(tail);
                    let (head, rest) = t.split_at_mut(r1 - r0);
                    band_cols.push(head);
                    *tail = rest;
                }
                let (acc_band, acc_tail) = acc_rest.split_at_mut(k * k);
                acc_rest = acc_tail;
                tasks.push((r0, r1, band_cols, acc_band));
            }
        }
        parallel_tasks(tasks, |_w, (r0, r1, mut band_cols, acc)| {
            spmm_rows(indptr, indices, values, &x, r0, r1, &mut band_cols);
            crate::la::blas3::gram_accumulate_cols(&band_cols, acc);
        });
        // Fold the per-band upper triangles in band order (fixed by the
        // cached plan, independent of thread scheduling), then mirror.
        let (first, rest) = accs.split_at_mut(k * k);
        for chunk in rest.chunks(k * k) {
            for (fv, &cv) in first.iter_mut().zip(chunk) {
                *fv += cv;
            }
        }
        crate::la::blas3::gram_mirror(first, &mut g);
    }

    /// Fused Y = A·X, Z = Aᵀ·Y: the normal-equations power step in one
    /// sweep over the nonzeros (contract rule 8: `apply_ata_into`).
    ///
    /// The outer loop walks the cached nnz-balanced row bands *serially*
    /// in increasing row order; each band gathers its slice of Y in
    /// parallel over sub-rows, then immediately scatters that same band
    /// of nonzeros into Z in parallel over output columns while the
    /// band's CSR arrays are still cache-resident — A is streamed once
    /// per power iteration instead of twice.
    ///
    /// Bitwise: the gather half writes each Y element exactly once under
    /// any partition, and the scatter half accumulates each Z column in
    /// global increasing-row order (band-serial outer loop, first band
    /// zero-fills), which is exactly the order [`Csr::spmm_t`] uses — so
    /// the fused result is bitwise-identical to the unfused
    /// `spmm` + `spmm_t` composition at *any* thread count.
    pub fn spmm_ata(&self, x: MatRef<S>, mut y: MatMut<S>, mut z: MatMut<S>) {
        assert_eq!(x.rows, self.cols, "spmm_ata inner dim");
        assert_eq!((y.rows, y.cols), (self.rows, x.cols), "spmm_ata y");
        assert_eq!((z.rows, z.cols), (self.cols, x.cols), "spmm_ata z");
        let k = x.cols;
        let m = self.rows;
        let n = self.cols;
        let work = self.nnz() * k + m * k;
        let bounds = if m > 0 && n > 0 && k > 0 {
            let bands = pool::planned_bands(work, m.div_ceil(32));
            if bands > 1 {
                band_plan(self, bands)
            } else {
                None
            }
        } else {
            None
        };
        let Some(bounds) = bounds else {
            // Serial / degenerate: the unfused composition is already
            // allocation-free and the operand fits in cache anyway.
            self.spmm(x, y.reborrow());
            self.spmm_t(y.as_ref(), z);
            return;
        };
        let indptr = &self.indptr;
        let indices = &self.indices;
        let values = &self.values;
        let nb = bounds.len() - 1;
        let t = num_threads().max(1);
        for w in 0..nb {
            let (r0, r1) = (bounds[w], bounds[w + 1]);
            // Gather rows [r0, r1) of Y in parallel over 32-row-aligned
            // sub-bands (any split is bitwise-safe for the gather).
            let sub = (r1 - r0).div_ceil(t).max(1).div_ceil(32) * 32;
            let mut tasks: Vec<(usize, usize, Vec<&mut [S]>)> = Vec::new();
            {
                let mut col_tails: Vec<&mut [S]> = Vec::with_capacity(k);
                let mut rest: &mut [S] = &mut y.data[..];
                for _ in 0..k {
                    let (col, tail) = rest.split_at_mut(m);
                    rest = tail;
                    col_tails.push(&mut col[r0..r1]);
                }
                let mut tr0 = r0;
                while tr0 < r1 {
                    let tr1 = (tr0 + sub).min(r1);
                    let cols: Vec<&mut [S]> = col_tails
                        .iter_mut()
                        .map(|c| {
                            let tail = std::mem::take(c);
                            let (head, rest) = tail.split_at_mut(tr1 - tr0);
                            *c = rest;
                            head
                        })
                        .collect();
                    tasks.push((tr0, tr1, cols));
                    tr0 = tr1;
                }
            }
            parallel_tasks(tasks, |_i, (tr0, tr1, mut cols)| {
                spmm_rows(indptr, indices, values, &x, tr0, tr1, &mut cols);
            });
            // Scatter the same band into Z, parallel over whole output
            // columns (race-free; per-column order fixed by the serial
            // band walk). First band zero-fills, matching spmm_t.
            let first = w == 0;
            let y_ref = y.as_ref();
            let band_nnz = indptr[r1] - indptr[r0];
            let zwork = band_nnz * k + if first { n * k } else { 0 };
            parallel_chunks_mut_work(z.data, n, zwork, |j, zj| {
                if first {
                    zj.fill(S::ZERO);
                }
                let yj = &y_ref.col(j)[r0..r1];
                for (ii, &xij) in yj.iter().enumerate() {
                    if xij == S::ZERO {
                        continue;
                    }
                    let i = r0 + ii;
                    let lo = indptr[i];
                    let hi = indptr[i + 1];
                    for p in lo..hi {
                        zj[indices[p] as usize] += values[p] * xij;
                    }
                }
            });
        }
    }

    /// Densify (tests / tiny matrices only).
    pub fn to_dense(&self) -> Mat<S> {
        let mut m = Mat::zeros(self.rows, self.cols);
        for i in 0..self.rows {
            let (cols, vals) = self.row(i);
            for (&c, &v) in cols.iter().zip(vals) {
                m.set(i, c as usize, v);
            }
        }
        m
    }

    /// Frobenius norm.
    pub fn fro_norm(&self) -> S {
        self.values.iter().map(|v| *v * *v).sum::<S>().sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::la::blas3::{mat_nn, mat_tn};
    use crate::util::rng::Rng;

    fn random_coo(rows: usize, cols: usize, nnz: usize, seed: u64) -> Coo {
        let mut rng = Rng::new(seed);
        let mut c = Coo::new(rows, cols);
        for _ in 0..nnz {
            c.push(rng.below(rows), rng.below(cols), rng.normal());
        }
        c
    }

    #[test]
    fn from_coo_sorts_and_merges() {
        let mut c = Coo::new(2, 3);
        c.push(0, 2, 1.0);
        c.push(0, 0, 2.0);
        c.push(0, 2, 3.0); // duplicate with the first
        c.push(1, 1, 5.0);
        let a = Csr::from_coo(&c).unwrap();
        assert_eq!(a.nnz(), 3);
        let (cols, vals) = a.row(0);
        assert_eq!(cols, &[0, 2]);
        assert_eq!(vals, &[2.0, 4.0]);
        let (cols, vals) = a.row(1);
        assert_eq!(cols, &[1]);
        assert_eq!(vals, &[5.0]);
    }

    #[test]
    fn spmm_matches_dense() {
        let coo = random_coo(23, 17, 80, 7);
        let a = Csr::from_coo(&coo).unwrap();
        let ad = a.to_dense();
        let mut rng = Rng::new(8);
        for k in [1, 2, 3, 4, 5, 6, 7, 8] {
            let x = Mat::randn(17, k, &mut rng);
            let mut y = Mat::zeros(23, k);
            a.spmm(x.as_ref(), y.as_mut());
            let expect = mat_nn(&ad, &x);
            assert!(y.max_abs_diff(&expect) < 1e-12, "k={k}");
        }
    }

    #[test]
    fn spmm_t_matches_dense() {
        let coo = random_coo(19, 29, 100, 9);
        let a = Csr::from_coo(&coo).unwrap();
        let ad = a.to_dense();
        let mut rng = Rng::new(10);
        for k in [1, 5] {
            let x = Mat::randn(19, k, &mut rng);
            let mut y = Mat::zeros(29, k);
            a.spmm_t(x.as_ref(), y.as_mut());
            let expect = mat_tn(&ad, &x);
            assert!(y.max_abs_diff(&expect) < 1e-12, "k={k}");
        }
    }

    #[test]
    fn transpose_roundtrip_and_equivalence() {
        let coo = random_coo(31, 11, 90, 11);
        let a = Csr::from_coo(&coo).unwrap();
        let at = a.transpose();
        assert_eq!((at.rows(), at.cols()), (11, 31));
        assert!(at.transpose().to_dense().max_abs_diff(&a.to_dense()) < 1e-15);
        // Aᵀ·X via scatter == (Aᵀ as CSR)·X via gather
        let mut rng = Rng::new(12);
        let x = Mat::randn(31, 4, &mut rng);
        let mut y1 = Mat::zeros(11, 4);
        let mut y2 = Mat::zeros(11, 4);
        a.spmm_t(x.as_ref(), y1.as_mut());
        at.spmm(x.as_ref(), y2.as_mut());
        assert!(y1.max_abs_diff(&y2) < 1e-12);
    }

    #[test]
    fn transpose_parallel_band_fill_matches_serial() {
        // Big enough (nnz >= 4096) to take the banded parallel fill path
        // when more than one worker thread is configured.
        let coo = random_coo(500, 300, 9000, 13);
        let a = Csr::from_coo(&coo).unwrap();
        let at = a.transpose();
        assert!(at.to_dense().max_abs_diff(&a.to_dense().transpose()) < 1e-15);
        // Row indices inside each transposed row must stay sorted (the
        // band fill preserves the serial row-scan order).
        for c in 0..at.rows() {
            let (rc, _) = at.row(c);
            assert!(rc.windows(2).all(|w| w[0] < w[1]), "col {c} unsorted");
        }
    }

    #[test]
    fn empty_rows_ok() {
        let mut c = Coo::new(4, 4);
        c.push(1, 1, 2.0);
        let a = Csr::from_coo(&c).unwrap();
        let x = Mat::eye(4);
        let mut y = Mat::zeros(4, 4);
        a.spmm(x.as_ref(), y.as_mut());
        assert_eq!(y.at(1, 1), 2.0);
        assert_eq!(y.fro_norm(), 2.0);
    }

    #[test]
    fn from_parts_validation() {
        assert!(Csr::from_parts(2, 2, vec![0, 1, 2], vec![0, 1], vec![1.0, 2.0]).is_ok());
        assert!(Csr::from_parts(2, 2, vec![0, 2, 1], vec![0, 1], vec![1.0, 2.0]).is_err());
        assert!(Csr::from_parts(2, 2, vec![0, 1, 2], vec![0, 5], vec![1.0, 2.0]).is_err());
    }

    #[test]
    fn generation_is_unique_per_construction_and_clone() {
        let a = Csr::from_coo(&random_coo(10, 10, 30, 1)).unwrap();
        let b = a.clone();
        let c: Csr<f32> = a.cast();
        let t = a.transpose();
        assert_ne!(a.generation(), b.generation(), "clone must get a fresh stamp");
        assert_ne!(a.generation(), c.generation());
        assert_ne!(a.generation(), t.generation());
        assert_ne!(b.generation(), t.generation());
    }

    #[test]
    fn balanced_row_bounds_shape() {
        // A skewed operand: first rows dense, rest nearly empty.
        let rows = 640usize;
        let mut indptr = vec![0usize; rows + 1];
        for i in 0..rows {
            let row_nnz = if i < 64 { 100 } else { 1 };
            indptr[i + 1] = indptr[i] + row_nnz;
        }
        let bounds = balanced_row_bounds(&indptr, 4, 32);
        assert_eq!(*bounds.first().unwrap(), 0);
        assert_eq!(*bounds.last().unwrap(), rows);
        assert!(bounds.windows(2).all(|w| w[0] < w[1]), "{bounds:?}");
        assert!(bounds[1..bounds.len() - 1].iter().all(|b| b % 32 == 0), "{bounds:?}");
        // The heavy head must be split finer than a uniform partition
        // would: the first band cannot own all 64 dense rows *and* a
        // proportional share of the tail.
        assert!(bounds[1] <= 64, "nnz balancing ignored the dense head: {bounds:?}");
        // Degenerate: everything in one aligned block collapses.
        let tiny = vec![0usize, 1, 2, 3];
        assert_eq!(balanced_row_bounds(&tiny, 4, 32), vec![0, 3]);
    }

    #[test]
    fn band_plan_caches_per_identity() {
        let a = Csr::from_coo(&random_coo(512, 64, 8000, 17)).unwrap();
        let p1 = band_plan(&a, 4).expect("plan for a 512-row operand");
        let p2 = band_plan(&a, 4).expect("second lookup");
        assert!(Arc::ptr_eq(&p1, &p2), "same identity + bands must hit the cache");
        assert_eq!(*p1.last().unwrap(), 512);
        // A clone is a distinct identity: same bounds values, distinct plan.
        let b = a.clone();
        let p3 = band_plan(&b, 4).expect("plan for the clone");
        assert!(!Arc::ptr_eq(&p1, &p3));
        assert_eq!(*p1, *p3, "clone has identical structure, so identical bounds");
        // Different band count = different plan key.
        if let Some(p4) = band_plan(&a, 2) {
            assert_ne!(p1.len(), p4.len());
        }
    }

    #[test]
    fn spmm_gram_matches_unfused_small_and_banded() {
        // Small (serial path) and large (cached-band-plan parallel path)
        // operands: Y must be bitwise spmm, G ε-equal to YᵀY.
        for &(rows, cols, nnz, seed) in
            &[(23usize, 17usize, 80usize, 7u64), (700, 200, 20_000, 25)]
        {
            let a = Csr::from_coo(&random_coo(rows, cols, nnz, seed)).unwrap();
            let mut rng = Rng::new(seed + 1);
            for k in [1usize, 3, 6, 8] {
                let x = Mat::randn(cols, k, &mut rng);
                let mut y0 = Mat::zeros(rows, k);
                a.spmm(x.as_ref(), y0.as_mut());
                let mut y = Mat::zeros(rows, k);
                let mut g = Mat::zeros(k, k);
                a.spmm_gram(x.as_ref(), y.as_mut(), g.as_mut());
                let same =
                    y0.data().iter().zip(y.data()).all(|(p, q)| p.to_bits() == q.to_bits());
                assert!(same, "{rows}x{cols} k={k}: fused Y differs from spmm");
                let expect = mat_tn(&y0, &y0);
                let scale = expect.fro_norm().max(1.0);
                assert!(
                    g.max_abs_diff(&expect) / scale < 1e-12,
                    "{rows}x{cols} k={k}: Gram mismatch"
                );
            }
        }
    }

    #[test]
    fn spmm_ata_bitwise_matches_unfused_composition() {
        // Both the serial fallback and the band-serial fused sweep must
        // reproduce spmm + spmm_t exactly (the scatter accumulates each
        // column in the same global row order).
        for &(rows, cols, nnz, seed) in
            &[(19usize, 29usize, 100usize, 9u64), (700, 300, 25_000, 27)]
        {
            let a = Csr::from_coo(&random_coo(rows, cols, nnz, seed)).unwrap();
            let mut rng = Rng::new(seed + 2);
            for k in [1usize, 4, 7] {
                let x = Mat::randn(cols, k, &mut rng);
                let mut y0 = Mat::zeros(rows, k);
                let mut z0 = Mat::zeros(cols, k);
                a.spmm(x.as_ref(), y0.as_mut());
                a.spmm_t(y0.as_ref(), z0.as_mut());
                let mut y = Mat::zeros(rows, k);
                let mut z = Mat::zeros(cols, k);
                a.spmm_ata(x.as_ref(), y.as_mut(), z.as_mut());
                let ysame =
                    y0.data().iter().zip(y.data()).all(|(p, q)| p.to_bits() == q.to_bits());
                let zsame =
                    z0.data().iter().zip(z.data()).all(|(p, q)| p.to_bits() == q.to_bits());
                assert!(ysame, "{rows}x{cols} k={k}: fused Y differs");
                assert!(zsame, "{rows}x{cols} k={k}: fused Z differs from spmm_t(spmm)");
            }
        }
    }

    /// Repeat spmm calls (the cache-hit path) stay bitwise identical to
    /// the first call, and match the dense reference.
    #[test]
    fn spmm_band_cache_repeat_calls_identical() {
        let a = Csr::from_coo(&random_coo(700, 200, 20_000, 23)).unwrap();
        let ad = a.to_dense();
        let mut rng = Rng::new(24);
        let x = Mat::randn(200, 6, &mut rng);
        let mut y1 = Mat::zeros(700, 6);
        a.spmm(x.as_ref(), y1.as_mut());
        assert!(y1.max_abs_diff(&mat_nn(&ad, &x)) < 1e-12);
        for _ in 0..3 {
            let mut y2 = Mat::zeros(700, 6);
            a.spmm(x.as_ref(), y2.as_mut());
            let same = y1.data().iter().zip(y2.data()).all(|(p, q)| p.to_bits() == q.to_bits());
            assert!(same, "repeat spmm changed bits");
        }
    }
}
