//! Out-of-core row-band shards: operands bigger than RAM at in-core speed.
//!
//! The paper's blocked methods stream the operand through SpMM one row
//! band at a time, so the working set per iteration is a band, not the
//! matrix. Following "High-Performance Out-of-core Block Randomized SVD
//! on GPU" (Lu, Ino, Matsushita — PAPERS.md), this module tiles a CSR
//! operand into **row-band shards** on disk and streams them through a
//! double-buffered prefetch pipeline so the load of shard *i+1* hides
//! behind the compute on shard *i*.
//!
//! Three layers:
//!
//! * **Shard directory** ([`ShardDir`], [`write_shards_from_csr`],
//!   [`convert_mtx_to_shards`]): a small on-disk directory — a text
//!   manifest plus one binary CSR segment per row band. Shard boundaries
//!   come from the *same* 32-row-aligned nnz-balanced
//!   `balanced_row_bounds` partition the pool's spmm banding uses. The
//!   MatrixMarket converter is fully streaming (two `MmStream` passes +
//!   bounded per-shard spill files); it never materializes the full COO.
//! * **Resident operand** ([`ShardedOperand`]): loads shards on demand
//!   under a configurable resident-bytes cap. A deterministic pin-prefix
//!   policy caches leading shards while they fit
//!   `cap − 2·max_shard_bytes`; the rest stream through two arena slots
//!   (current + prefetch). `cap = 0` means unlimited (everything pins).
//! * **Prefetch pipeline**: one dedicated loader thread (spawned
//!   unpinned, like the pool's band-0 submitter — a GPU port maps it
//!   onto an async copy stream, see `backend/mod.rs` §Memory tiers)
//!   receives shard indices over a channel and sends back decoded
//!   slices; compute blocks only when a shard is not ready, and that
//!   stall time is measured ([`ShardStats::overlap_efficiency`]).
//!
//! ## Bitwise parity with the in-core solve
//!
//! At a fixed thread count the sharded `spmm`/`spmm_t` are
//! **bitwise-identical** to `Csr::spmm`/`Csr::spmm_t`:
//!
//! * `spmm` gathers — every output element is written exactly once by a
//!   fixed-order dot over its row, so *any* row partition (in-core bands
//!   or disk shards) produces identical bits. Shards reuse the same
//!   `spmm_rows` kernel on shard-local arrays.
//! * `spmm_t` scatters — per output column, the in-core kernel zeroes
//!   then accumulates entries in global row order. Shards are processed
//!   strictly in increasing row order (prefetch overlaps *loads*, never
//!   reorders *compute*), with the zero-fill on the first shard only, so
//!   each column sees the identical addition sequence.

use std::sync::mpsc;
use std::sync::Arc;
use std::time::Instant;

use super::coo::Coo;
use super::csr::{self, Csr};
use super::mm::MmStream;
use crate::error::{Error, Result};
use crate::la::mat::{MatMut, MatRef};
use crate::util::pool;
use crate::util::scalar::Scalar;

/// Binary shard file magic ("TRUNKSHD").
const MAGIC: u64 = 0x5452_554e_4b53_4844;
/// Manifest banner (format version).
const MANIFEST_BANNER: &str = "trunksvd-shards v1";
/// Manifest file name inside a shard directory.
const MANIFEST: &str = "shards.txt";
/// Bound on buffered [`ShardLoadEvent`]s between drains (mirrors the
/// staged ledger's event cap; aggregate [`ShardStats`] counters are
/// never capped).
const EVENT_CAP: usize = 4096;

fn io_err(path: &str, e: std::io::Error) -> Error {
    Error::Io { path: path.to_string(), source: e }
}

fn shard_err(detail: impl Into<String>) -> Error {
    Error::Parse { what: "shard", detail: detail.into() }
}

/// Per-shard metadata from the manifest.
#[derive(Clone, Copy, Debug)]
pub struct ShardMeta {
    /// Global row range `[r0, r1)` this shard covers.
    pub r0: usize,
    pub r1: usize,
    /// Nonzeros stored in the shard.
    pub nnz: usize,
}

impl ShardMeta {
    #[inline]
    pub fn local_rows(&self) -> usize {
        self.r1 - self.r0
    }
    /// Exact on-disk size of the shard file (header + indptr + indices +
    /// f64 values) — the bytes one disk→host load moves.
    #[inline]
    pub fn file_bytes(&self) -> usize {
        32 + 8 * (self.local_rows() + 1) + 4 * self.nnz + 8 * self.nnz
    }
    /// In-memory footprint of the decoded slice at element type `S`.
    #[inline]
    pub fn resident_bytes<S: Scalar>(&self) -> usize {
        8 * (self.local_rows() + 1) + 4 * self.nnz + std::mem::size_of::<S>() * self.nnz
    }
}

/// An opened shard directory: dtype-independent metadata for a CSR
/// operand tiled into row-band shards (values are stored as f64 on disk
/// and cast at load, mirroring the in-core `--dtype f32` semantics).
#[derive(Debug)]
pub struct ShardDir {
    dir: String,
    rows: usize,
    cols: usize,
    nnz: usize,
    shards: Vec<ShardMeta>,
}

impl ShardDir {
    /// Open a shard directory by parsing its manifest.
    pub fn open(dir: &str) -> Result<ShardDir> {
        let mpath = format!("{dir}/{MANIFEST}");
        let text = std::fs::read_to_string(&mpath).map_err(|e| io_err(&mpath, e))?;
        let mut lines = text.lines();
        if lines.next().map(str::trim) != Some(MANIFEST_BANNER) {
            return Err(shard_err(format!("{mpath}: bad banner (want '{MANIFEST_BANNER}')")));
        }
        let mut rows = None;
        let mut cols = None;
        let mut nnz = None;
        let mut count = None;
        let mut shards: Vec<ShardMeta> = Vec::new();
        for line in lines {
            let toks: Vec<&str> = line.split_whitespace().collect();
            let get = |i: usize| -> Result<usize> {
                toks.get(i)
                    .and_then(|t| t.parse().ok())
                    .ok_or_else(|| shard_err(format!("{mpath}: bad line '{line}'")))
            };
            match toks.first().copied() {
                None => continue,
                Some("rows") => rows = Some(get(1)?),
                Some("cols") => cols = Some(get(1)?),
                Some("nnz") => nnz = Some(get(1)?),
                Some("shards") => count = Some(get(1)?),
                Some("shard") => {
                    if get(1)? != shards.len() {
                        return Err(shard_err(format!("{mpath}: shard lines out of order")));
                    }
                    shards.push(ShardMeta { r0: get(2)?, r1: get(3)?, nnz: get(4)? });
                }
                Some(other) => {
                    return Err(shard_err(format!("{mpath}: unknown key '{other}'")));
                }
            }
        }
        let (rows, cols, nnz) = match (rows, cols, nnz) {
            (Some(r), Some(c), Some(z)) => (r, c, z),
            _ => return Err(shard_err(format!("{mpath}: missing rows/cols/nnz"))),
        };
        if count != Some(shards.len()) || shards.is_empty() {
            return Err(shard_err(format!("{mpath}: shard count mismatch")));
        }
        // Shards must tile [0, rows) contiguously and account for nnz.
        let mut at = 0usize;
        let mut z = 0usize;
        for s in &shards {
            if s.r0 != at || s.r1 <= s.r0 || s.r1 > rows {
                return Err(shard_err(format!("{mpath}: shards do not tile the row range")));
            }
            at = s.r1;
            z += s.nnz;
        }
        if at != rows || z != nnz {
            return Err(shard_err(format!("{mpath}: shard coverage mismatch")));
        }
        Ok(ShardDir { dir: dir.to_string(), rows, cols, nnz, shards })
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }
    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }
    #[inline]
    pub fn nnz(&self) -> usize {
        self.nnz
    }
    #[inline]
    pub fn num_shards(&self) -> usize {
        self.shards.len()
    }
    #[inline]
    pub fn meta(&self, i: usize) -> ShardMeta {
        self.shards[i]
    }
    #[inline]
    pub fn path(&self) -> &str {
        &self.dir
    }
    pub fn shard_path(&self, i: usize) -> String {
        format!("{}/shard_{i}.bin", self.dir)
    }
    /// Total on-disk operand bytes across shards.
    pub fn total_file_bytes(&self) -> usize {
        self.shards.iter().map(|s| s.file_bytes()).sum()
    }
    /// Largest decoded shard footprint at element type `S` (the streaming
    /// slot size the resident cap must accommodate twice).
    pub fn max_resident_bytes<S: Scalar>(&self) -> usize {
        self.shards.iter().map(|s| s.resident_bytes::<S>()).max().unwrap_or(0)
    }

    /// Read and decode shard `i`, casting values to `S`.
    pub fn load<S: Scalar>(&self, i: usize) -> Result<ShardSlice<S>> {
        let meta = self.shards[i];
        let path = self.shard_path(i);
        let bytes = std::fs::read(&path).map_err(|e| io_err(&path, e))?;
        if bytes.len() != meta.file_bytes() {
            return Err(shard_err(format!(
                "{path}: size {} != expected {}",
                bytes.len(),
                meta.file_bytes()
            )));
        }
        let u64_at = |off: usize| u64::from_le_bytes(bytes[off..off + 8].try_into().unwrap());
        let (magic, r0, r1, nnz) =
            (u64_at(0), u64_at(8) as usize, u64_at(16) as usize, u64_at(24) as usize);
        if magic != MAGIC || (r0, r1, nnz) != (meta.r0, meta.r1, meta.nnz) {
            return Err(shard_err(format!("{path}: header disagrees with manifest")));
        }
        let lr = meta.local_rows();
        let mut off = 32;
        let mut indptr = Vec::with_capacity(lr + 1);
        for _ in 0..=lr {
            indptr.push(u64_at(off) as usize);
            off += 8;
        }
        if indptr[0] != 0 || indptr[lr] != nnz || indptr.windows(2).any(|w| w[1] < w[0]) {
            return Err(shard_err(format!("{path}: corrupt indptr")));
        }
        let mut indices = Vec::with_capacity(nnz);
        for _ in 0..nnz {
            indices.push(u32::from_le_bytes(bytes[off..off + 4].try_into().unwrap()));
            off += 4;
        }
        if indices.iter().any(|&c| c as usize >= self.cols) {
            return Err(shard_err(format!("{path}: column index out of range")));
        }
        let mut values = Vec::with_capacity(nnz);
        for _ in 0..nnz {
            values.push(S::from_f64(f64::from_le_bytes(bytes[off..off + 8].try_into().unwrap())));
            off += 8;
        }
        Ok(ShardSlice { r0: meta.r0, r1: meta.r1, indptr, indices, values })
    }
}

/// One decoded row-band shard: a shard-local CSR segment covering global
/// rows `[r0, r1)` (indptr rebased to 0).
#[derive(Clone, Debug)]
pub struct ShardSlice<S: Scalar = f64> {
    pub r0: usize,
    pub r1: usize,
    pub indptr: Vec<usize>,
    pub indices: Vec<u32>,
    pub values: Vec<S>,
}

impl<S: Scalar> ShardSlice<S> {
    #[inline]
    pub fn local_rows(&self) -> usize {
        self.r1 - self.r0
    }
    #[inline]
    pub fn nnz(&self) -> usize {
        self.values.len()
    }
    #[inline]
    pub fn resident_bytes(&self) -> usize {
        8 * self.indptr.len() + 4 * self.indices.len()
            + std::mem::size_of::<S>() * self.values.len()
    }
}

fn write_shard_file(
    path: &str,
    r0: usize,
    r1: usize,
    indptr_local: &[usize],
    indices: &[u32],
    values: &[f64],
) -> Result<()> {
    use std::io::Write;
    let f = std::fs::File::create(path).map_err(|e| io_err(path, e))?;
    let mut w = std::io::BufWriter::new(f);
    (|| -> std::io::Result<()> {
        for v in [MAGIC, r0 as u64, r1 as u64, values.len() as u64] {
            w.write_all(&v.to_le_bytes())?;
        }
        for &p in indptr_local {
            w.write_all(&(p as u64).to_le_bytes())?;
        }
        for &c in indices {
            w.write_all(&c.to_le_bytes())?;
        }
        for &v in values {
            w.write_all(&v.to_le_bytes())?;
        }
        w.flush()
    })()
    .map_err(|e| io_err(path, e))
}

fn write_manifest(
    dir: &str,
    rows: usize,
    cols: usize,
    nnz: usize,
    metas: &[ShardMeta],
) -> Result<()> {
    let mpath = format!("{dir}/{MANIFEST}");
    let mut text = format!(
        "{MANIFEST_BANNER}\nrows {rows}\ncols {cols}\nnnz {nnz}\nshards {}\n",
        metas.len()
    );
    for (i, s) in metas.iter().enumerate() {
        text.push_str(&format!("shard {i} {} {} {}\n", s.r0, s.r1, s.nnz));
    }
    std::fs::write(&mpath, text).map_err(|e| io_err(&mpath, e))
}

/// Shard-boundary partition for an operand with row prefix `indptr`:
/// the pool's 32-row-aligned nnz-balanced bounds, so shards line up with
/// the in-core spmm banding.
pub fn shard_bounds(indptr: &[usize], shards: usize) -> Vec<usize> {
    csr::balanced_row_bounds(indptr, shards.max(1), 32)
}

/// Tile an in-core CSR operand into a shard directory (tests, the
/// `trunksvd shard` CLI on already-loaded operands, and benches).
pub fn write_shards_from_csr(dir: &str, a: &Csr<f64>, shards: usize) -> Result<ShardDir> {
    std::fs::create_dir_all(dir).map_err(|e| io_err(dir, e))?;
    if a.rows() == 0 {
        return Err(shard_err("cannot shard an empty operand"));
    }
    let bounds = shard_bounds(a.indptr(), shards);
    let mut metas = Vec::with_capacity(bounds.len() - 1);
    for (i, w) in bounds.windows(2).enumerate() {
        let (r0, r1) = (w[0], w[1]);
        let (lo, hi) = (a.indptr()[r0], a.indptr()[r1]);
        let indptr_local: Vec<usize> = a.indptr()[r0..=r1].iter().map(|&p| p - lo).collect();
        write_shard_file(
            &format!("{dir}/shard_{i}.bin"),
            r0,
            r1,
            &indptr_local,
            &a.indices()[lo..hi],
            &a.values()[lo..hi],
        )?;
        metas.push(ShardMeta { r0, r1, nnz: hi - lo });
    }
    write_manifest(dir, a.rows(), a.cols(), a.nnz(), &metas)?;
    ShardDir::open(dir)
}

/// Streaming MatrixMarket → shard converter. Two passes over the file
/// (never a full in-memory COO):
///
/// 1. per-row nonzero histogram → global indptr → the 32-row-aligned
///    nnz-balanced shard bounds;
/// 2. entries scattered to bounded per-shard spill files (raw triplets,
///    file order preserved), then each spill is assembled into one
///    shard-local CSR and written out — peak memory is one shard, not
///    the operand.
///
/// Per-row entry order matches `read_coo` restricted to the shard's
/// rows, so the resulting CSR segments are bitwise-identical to slicing
/// the in-core `read_csr` result.
pub fn convert_mtx_to_shards(mtx: &str, dir: &str, shards: usize) -> Result<ShardDir> {
    std::fs::create_dir_all(dir).map_err(|e| io_err(dir, e))?;
    // Pass 1: per-row counts → global indptr → shard bounds.
    let stream = MmStream::open(mtx)?;
    let h = stream.header();
    if h.rows == 0 {
        return Err(shard_err("cannot shard an empty operand"));
    }
    let mut indptr = vec![0usize; h.rows + 1];
    stream.for_each(|i, _, _| indptr[i + 1] += 1)?;
    for i in 0..h.rows {
        indptr[i + 1] += indptr[i];
    }
    let nnz = indptr[h.rows];
    let bounds = shard_bounds(&indptr, shards);
    let nshards = bounds.len() - 1;

    // Pass 2a: scatter entries to per-shard spill files (20-byte raw
    // triplets through small BufWriters; bounded memory).
    use std::io::{Read, Write};
    let spill_path = |i: usize| format!("{dir}/spill_{i}.tmp");
    {
        let mut spills: Vec<std::io::BufWriter<std::fs::File>> = (0..nshards)
            .map(|i| {
                let p = spill_path(i);
                std::fs::File::create(&p).map(std::io::BufWriter::new).map_err(|e| io_err(&p, e))
            })
            .collect::<Result<_>>()?;
        let mut werr: Option<std::io::Error> = None;
        MmStream::open(mtx)?.for_each(|i, j, v| {
            if werr.is_some() {
                return;
            }
            // partition_point: first bound > i, minus one = shard index.
            let s = bounds.partition_point(|&b| b <= i) - 1;
            let mut rec = [0u8; 20];
            rec[..8].copy_from_slice(&(i as u64).to_le_bytes());
            rec[8..12].copy_from_slice(&(j as u32).to_le_bytes());
            rec[12..].copy_from_slice(&v.to_le_bytes());
            if let Err(e) = spills[s].write_all(&rec) {
                werr = Some(e);
            }
        })?;
        if let Some(e) = werr {
            return Err(io_err(dir, e));
        }
        for (i, mut s) in spills.into_iter().enumerate() {
            s.flush().map_err(|e| io_err(&spill_path(i), e))?;
        }
    }

    // Pass 2b: assemble each spill into a shard-local CSR and write it.
    let mut metas = Vec::with_capacity(nshards);
    for s in 0..nshards {
        let (r0, r1) = (bounds[s], bounds[s + 1]);
        let p = spill_path(s);
        let mut f = std::io::BufReader::new(std::fs::File::open(&p).map_err(|e| io_err(&p, e))?);
        let mut coo = Coo::new(r1 - r0, h.cols);
        let mut rec = [0u8; 20];
        loop {
            match f.read_exact(&mut rec) {
                Ok(()) => {}
                Err(e) if e.kind() == std::io::ErrorKind::UnexpectedEof => break,
                Err(e) => return Err(io_err(&p, e)),
            }
            let i = u64::from_le_bytes(rec[..8].try_into().unwrap()) as usize;
            let j = u32::from_le_bytes(rec[8..12].try_into().unwrap()) as usize;
            let v = f64::from_le_bytes(rec[12..].try_into().unwrap());
            coo.push(i - r0, j, v);
        }
        let local = Csr::from_coo(&coo)?;
        write_shard_file(
            &format!("{dir}/shard_{s}.bin"),
            r0,
            r1,
            local.indptr(),
            local.indices(),
            local.values(),
        )?;
        metas.push(ShardMeta { r0, r1, nnz: local.nnz() });
        let _ = std::fs::remove_file(&p);
    }
    // from_coo merges duplicate (row, col) entries, so the manifest nnz
    // is the post-merge sum, not the .mtx entry count.
    let merged: usize = metas.iter().map(|m| m.nnz).sum();
    debug_assert!(merged <= nnz);
    write_manifest(dir, h.rows, h.cols, merged, &metas)?;
    ShardDir::open(dir)
}

// ---------------------------------------------------------------------
// Resident operand + prefetch pipeline
// ---------------------------------------------------------------------

/// One disk→host load the ledger records (drained by the staged
/// backend's tiered transfer accounting).
#[derive(Clone, Copy, Debug)]
pub struct ShardLoadEvent {
    pub shard: usize,
    pub file_bytes: usize,
    /// true for the one-time pin-prefix staging loads, false for the
    /// per-pass streaming loads.
    pub pinned: bool,
}

/// Streaming counters for one sharded operand.
#[derive(Clone, Copy, Debug, Default)]
pub struct ShardStats {
    /// One-time loads of the pinned prefix (plan-phase staging).
    pub pin_loads: usize,
    pub pin_bytes: usize,
    /// Per-pass streaming loads through the two arena slots.
    pub stream_loads: usize,
    pub stream_bytes: usize,
    /// Loader-side time spent reading + decoding streamed shards.
    pub load_secs: f64,
    /// Compute-side time spent blocked waiting for a shard.
    pub stall_secs: f64,
    /// High-water mark of decoded shard bytes resident at once.
    pub peak_resident_bytes: usize,
    /// Full sweeps over the operand (one spmm or spmm_t call each).
    pub passes: usize,
}

impl ShardStats {
    /// Fraction of loader time hidden behind compute: 1.0 means every
    /// streamed load finished before compute asked for it, 0.0 means
    /// compute waited for every byte (fully synchronous).
    pub fn overlap_efficiency(&self) -> f64 {
        if self.load_secs <= 0.0 {
            1.0
        } else {
            (1.0 - self.stall_secs / self.load_secs).clamp(0.0, 1.0)
        }
    }
}

enum LoaderMsg<S: Scalar> {
    Loaded { shard: usize, secs: f64, result: Result<ShardSlice<S>> },
}

struct Loader<S: Scalar> {
    tx: Option<mpsc::Sender<usize>>,
    rx: mpsc::Receiver<LoaderMsg<S>>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl<S: Scalar> Drop for Loader<S> {
    fn drop(&mut self) {
        self.tx.take(); // close the request channel → loader loop exits
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

/// A CSR operand resident across the disk↔host boundary: a pinned
/// prefix of shards plus a double-buffered streaming window under a
/// resident-bytes cap. See the module docs for policy and the bitwise
/// parity argument for [`ShardedOperand::spmm`] / `spmm_t`.
pub struct ShardedOperand<S: Scalar> {
    dir: Arc<ShardDir>,
    resident_cap: usize,
    init: bool,
    sync_only: bool,
    pinned: Vec<ShardSlice<S>>,
    pinned_bytes: usize,
    loader: Option<Loader<S>>,
    stats: ShardStats,
    events: Vec<ShardLoadEvent>,
}

impl<S: Scalar> ShardedOperand<S> {
    /// Wrap a shard directory under a resident-bytes cap (`0` =
    /// unlimited). Cheap: no I/O until the first pass (or
    /// [`ShardedOperand::ensure_resident`]).
    pub fn new(dir: Arc<ShardDir>, resident_cap: usize) -> ShardedOperand<S> {
        ShardedOperand {
            dir,
            resident_cap,
            init: false,
            sync_only: false,
            pinned: Vec::new(),
            pinned_bytes: 0,
            loader: None,
            stats: ShardStats::default(),
            events: Vec::new(),
        }
    }

    #[inline]
    pub fn dir(&self) -> &Arc<ShardDir> {
        &self.dir
    }
    #[inline]
    pub fn resident_cap(&self) -> usize {
        self.resident_cap
    }
    #[inline]
    pub fn stats(&self) -> ShardStats {
        self.stats
    }
    /// Drain the disk→host load events recorded since the last call
    /// (ledger feed for the staged backend). At most [`EVENT_CAP`]
    /// events buffer between drains; the aggregate counters in
    /// [`ShardStats`] are exact regardless.
    pub fn take_load_events(&mut self) -> Vec<ShardLoadEvent> {
        std::mem::take(&mut self.events)
    }

    fn push_event(&mut self, shard: usize, pinned: bool) {
        if self.events.len() < EVENT_CAP {
            self.events.push(ShardLoadEvent {
                shard,
                file_bytes: self.dir.meta(shard).file_bytes(),
                pinned,
            });
        }
    }

    /// Decide the pin prefix and load it; spawn the loader thread when
    /// anything will stream. Idempotent.
    pub fn ensure_resident(&mut self) -> Result<()> {
        if self.init {
            return Ok(());
        }
        let n = self.dir.num_shards();
        let sizes: Vec<usize> = (0..n).map(|i| self.dir.meta(i).resident_bytes::<S>()).collect();
        let maxb = sizes.iter().copied().max().unwrap_or(0);
        let total: usize = sizes.iter().sum();
        let cap = self.resident_cap;
        if cap > 0 && maxb > cap {
            return Err(Error::InvalidParam(format!(
                "resident cap {cap} B is smaller than the largest shard ({maxb} B); \
                 re-shard finer or raise the cap"
            )));
        }
        // Pin policy: everything if it fits, else a prefix while it fits
        // under cap − 2·max (two streaming slots: compute + prefetch).
        // cap < 2·max leaves one slot → synchronous degrade (no overlap,
        // but the cap still holds).
        let pin_budget = if cap == 0 || total <= cap {
            usize::MAX
        } else {
            self.sync_only = cap < 2 * maxb;
            if self.sync_only {
                0
            } else {
                cap - 2 * maxb
            }
        };
        let mut pinned_bytes = 0usize;
        for i in 0..n {
            if pinned_bytes.saturating_add(sizes[i]) > pin_budget {
                break;
            }
            let sl = self.dir.load::<S>(i)?;
            pinned_bytes += sl.resident_bytes();
            self.stats.pin_loads += 1;
            self.stats.pin_bytes += self.dir.meta(i).file_bytes();
            self.push_event(i, true);
            self.pinned.push(sl);
        }
        self.pinned_bytes = pinned_bytes;
        self.stats.peak_resident_bytes = self.stats.peak_resident_bytes.max(pinned_bytes);
        if self.pinned.len() < n && !self.sync_only {
            // Dedicated loader thread: one outstanding request at a time
            // (depth-1 prefetch = the classic double buffer). Spawned
            // unpinned — it does I/O, not compute.
            let (req_tx, req_rx) = mpsc::channel::<usize>();
            let (res_tx, res_rx) = mpsc::channel::<LoaderMsg<S>>();
            let dir = Arc::clone(&self.dir);
            let handle = std::thread::Builder::new()
                .name("trunksvd-shard-loader".into())
                .spawn(move || {
                    while let Ok(i) = req_rx.recv() {
                        let t0 = Instant::now();
                        let result = dir.load::<S>(i);
                        let secs = t0.elapsed().as_secs_f64();
                        if res_tx.send(LoaderMsg::Loaded { shard: i, secs, result }).is_err() {
                            break;
                        }
                    }
                })
                .map_err(|e| io_err("shard-loader", e))?;
            self.loader = Some(Loader { tx: Some(req_tx), rx: res_rx, handle: Some(handle) });
        }
        self.init = true;
        Ok(())
    }

    /// Visit every shard in increasing row order: pinned shards from
    /// cache, streamed shards through the prefetch pipeline. `f` runs on
    /// the calling thread (and fans out on the worker pool internally),
    /// so compute order — and therefore every floating-point result — is
    /// independent of load timing.
    fn for_each_shard(&mut self, mut f: impl FnMut(usize, &ShardSlice<S>)) -> Result<()> {
        self.ensure_resident()?;
        let n = self.dir.num_shards();
        let np = self.pinned.len();
        let dead = || Error::InvalidParam("shard loader thread died".into());
        // Kick off the first streamed load before touching the pinned
        // prefix, so even shard np's load hides behind pinned compute.
        if np < n {
            if let Some(l) = &self.loader {
                l.tx.as_ref().ok_or_else(dead)?.send(np).map_err(|_| dead())?;
            }
        }
        for (i, sl) in self.pinned.iter().enumerate() {
            f(i, sl);
        }
        for i in np..n {
            let sl = if let Some(l) = &self.loader {
                let t0 = Instant::now();
                let LoaderMsg::Loaded { shard, secs, result } = l.rx.recv().map_err(|_| dead())?;
                self.stats.stall_secs += t0.elapsed().as_secs_f64();
                debug_assert_eq!(shard, i, "loader answered out of order");
                self.stats.load_secs += secs;
                let sl = result?;
                // Prefetch the next streamed shard before computing on
                // this one — the whole point of the second slot.
                if i + 1 < n {
                    l.tx.as_ref().ok_or_else(dead)?.send(i + 1).map_err(|_| dead())?;
                }
                sl
            } else {
                // Synchronous degrade (cap leaves a single slot): load on
                // the compute thread; all load time is stall time.
                let t0 = Instant::now();
                let sl = self.dir.load::<S>(i)?;
                let secs = t0.elapsed().as_secs_f64();
                self.stats.load_secs += secs;
                self.stats.stall_secs += secs;
                sl
            };
            self.stats.stream_loads += 1;
            self.stats.stream_bytes += self.dir.meta(i).file_bytes();
            self.push_event(i, false);
            let inflight = if self.loader.is_some() && i + 1 < n {
                self.dir.meta(i + 1).resident_bytes::<S>()
            } else {
                0
            };
            let resident = self.pinned_bytes + sl.resident_bytes() + inflight;
            self.stats.peak_resident_bytes = self.stats.peak_resident_bytes.max(resident);
            f(i, &sl);
        }
        self.stats.passes += 1;
        Ok(())
    }

    /// Y = A · X over shards. Bitwise-identical to `Csr::spmm` at a
    /// fixed thread count (gather: partition-independent; see the
    /// module docs).
    pub fn spmm(&mut self, x: MatRef<'_, S>, y: &mut MatMut<'_, S>) -> Result<()> {
        assert_eq!(x.rows, self.dir.cols(), "sharded spmm inner dim");
        assert_eq!((y.rows, y.cols), (self.dir.rows(), x.cols), "sharded spmm out");
        if y.rows == 0 || x.cols == 0 {
            return Ok(());
        }
        self.for_each_shard(|_, sh| spmm_shard(sh, &x, y))
    }

    /// Y = Aᵀ · X over shards in increasing row order. Bitwise-identical
    /// to the in-core scatter `Csr::spmm_t` at a fixed thread count:
    /// per output column the addition sequence is exactly the global
    /// row-order scan, zero-filled once on the first shard.
    pub fn spmm_t(&mut self, x: MatRef<'_, S>, y: &mut MatMut<'_, S>) -> Result<()> {
        assert_eq!(x.rows, self.dir.rows(), "sharded spmm_t inner dim");
        assert_eq!((y.rows, y.cols), (self.dir.cols(), x.cols), "sharded spmm_t out");
        if y.rows == 0 || x.cols == 0 {
            return Ok(());
        }
        self.for_each_shard(|i, sh| spmm_t_shard(sh, &x, y, i == 0))
    }

    /// Fused Y = A·X, Z = Aᵀ·Y in **one** sweep over the shards
    /// (contract rule 8: the out-of-core `apply_ata_into`). Each shard
    /// is gathered into its row band of Y and immediately scattered into
    /// Z while its decoded CSR arrays are still host-resident, so the
    /// normal-equations power step reads the disk once per iteration
    /// instead of twice — under a tight `--resident-cap` this halves the
    /// `DiskToHost` traffic (one `ShardStats::passes` increment, each
    /// streamed shard loaded exactly once).
    ///
    /// Bitwise-identical to the `spmm` → `spmm_t` composition at any
    /// thread count: the gather writes each Y element exactly once, and
    /// the scatter accumulates each Z column in global increasing row
    /// order with the zero-fill on the first shard only.
    pub fn spmm_ata(
        &mut self,
        x: MatRef<'_, S>,
        y: &mut MatMut<'_, S>,
        z: &mut MatMut<'_, S>,
    ) -> Result<()> {
        assert_eq!(x.rows, self.dir.cols(), "sharded spmm_ata inner dim");
        assert_eq!((y.rows, y.cols), (self.dir.rows(), x.cols), "sharded spmm_ata y");
        assert_eq!((z.rows, z.cols), (self.dir.cols(), x.cols), "sharded spmm_ata z");
        if z.rows == 0 || x.cols == 0 {
            return Ok(());
        }
        self.for_each_shard(|i, sh| {
            spmm_shard(sh, &x, y);
            let yref = y.as_ref();
            spmm_t_shard(sh, &yref, z, i == 0);
        })
    }

    /// Fused Y = A·X and G = YᵀY in one sweep over the shards (contract
    /// rule 8: the out-of-core `apply_a_gram_into`). Each shard's band
    /// of Y is reduced into the Gram accumulator right after the gather,
    /// while it is cache-resident; bands fold in shard order (fixed),
    /// so the Gram is bitwise-reproducible at a fixed thread count and
    /// ε-equal to a dense `gram_into` over the assembled panel.
    pub fn spmm_gram(
        &mut self,
        x: MatRef<'_, S>,
        y: &mut MatMut<'_, S>,
        g: &mut MatMut<'_, S>,
    ) -> Result<()> {
        assert_eq!(x.rows, self.dir.cols(), "sharded spmm_gram inner dim");
        assert_eq!((y.rows, y.cols), (self.dir.rows(), x.cols), "sharded spmm_gram y");
        assert_eq!((g.rows, g.cols), (x.cols, x.cols), "sharded spmm_gram g");
        let k = x.cols;
        if y.rows == 0 || k == 0 {
            g.fill(S::ZERO);
            return Ok(());
        }
        let mut acc = vec![S::ZERO; k * k];
        self.for_each_shard(|_, sh| {
            spmm_shard(sh, &x, y);
            crate::la::blas3::gram_accumulate(y.as_ref(), sh.r0, sh.r1, &mut acc);
        })?;
        crate::la::blas3::gram_mirror(&acc, g);
        Ok(())
    }
}

/// Gather rows `[sh.r0, sh.r1)` of `A·X` from one shard into the global
/// output. Runs the same `spmm_rows` microkernel body as `Csr::spmm`,
/// parallel over nnz-balanced 32-aligned local bands (any partition is
/// bit-safe for the gather kernel).
fn spmm_shard<S: Scalar>(sh: &ShardSlice<S>, x: &MatRef<'_, S>, y: &mut MatMut<'_, S>) {
    let lr = sh.local_rows();
    if lr == 0 {
        return;
    }
    let m = y.rows;
    let k = x.cols;
    let work = sh.nnz() * k + lr * k;
    let bands = pool::planned_bands(work, lr.div_ceil(32));
    let bounds: Vec<usize> =
        if bands > 1 { csr::balanced_row_bounds(&sh.indptr, bands, 32) } else { vec![0, lr] };
    let nb = bounds.len() - 1;
    // Carve each output column's [r0, r1) segment into per-band
    // sub-slices (the prepared-task idiom from `Csr::transpose`).
    let mut tasks: Vec<(usize, usize, Vec<&mut [S]>)> =
        bounds.windows(2).map(|w| (w[0], w[1], Vec::with_capacity(k))).collect();
    for col in y.data.chunks_mut(m) {
        let (_, rest) = col.split_at_mut(sh.r0);
        let (mut seg, _) = rest.split_at_mut(lr);
        for (b, t) in tasks.iter_mut().enumerate() {
            let (head, tail) = seg.split_at_mut(bounds[b + 1] - bounds[b]);
            t.2.push(head);
            seg = tail;
        }
    }
    debug_assert_eq!(tasks.len(), nb);
    let (indptr, indices, values) = (&sh.indptr[..], &sh.indices[..], &sh.values[..]);
    pool::parallel_tasks(tasks, |_w, (l0, l1, mut cols)| {
        csr::spmm_rows(indptr, indices, values, x, l0, l1, &mut cols)
    });
}

/// Scatter one shard's contribution to `Y = AᵀX`, parallel over whole
/// output columns exactly like `Csr::spmm_t`; `first` zero-fills. Within
/// a column the entries accumulate in local (= global) row order, so the
/// shard loop reproduces the in-core addition sequence bit for bit.
fn spmm_t_shard<S: Scalar>(
    sh: &ShardSlice<S>,
    x: &MatRef<'_, S>,
    y: &mut MatMut<'_, S>,
    first: bool,
) {
    let n = y.rows;
    let k = x.cols;
    let work = sh.nnz() * k + if first { n * k } else { 0 };
    let (indptr, indices, values) = (&sh.indptr[..], &sh.indices[..], &sh.values[..]);
    let (r0, r1) = (sh.r0, sh.r1);
    pool::parallel_chunks_mut_work(y.data, n, work, |j, yj| {
        if first {
            yj.fill(S::ZERO);
        }
        let xj = &x.col(j)[r0..r1];
        for (li, &xij) in xj.iter().enumerate() {
            if xij == S::ZERO {
                continue;
            }
            let lo = indptr[li];
            let hi = indptr[li + 1];
            for p in lo..hi {
                yj[indices[p] as usize] += values[p] * xij;
            }
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen::sparse::{generate, SparseSpec};
    use crate::la::mat::Mat;
    use crate::sparse::mm;
    use crate::util::rng::Rng;

    fn tmp(name: &str) -> String {
        let dir = std::env::temp_dir().join("trunksvd_shard_tests").join(name);
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir.to_string_lossy().into_owned()
    }

    fn test_matrix(rows: usize, cols: usize, nnz: usize, seed: u64) -> Csr<f64> {
        generate(&SparseSpec { rows, cols, nnz, seed, ..Default::default() })
    }

    #[test]
    fn csr_shard_roundtrip_is_exact() {
        let a = test_matrix(300, 120, 4000, 3);
        let dir = tmp("rt");
        let sd = write_shards_from_csr(&dir, &a, 4).unwrap();
        assert_eq!((sd.rows(), sd.cols(), sd.nnz()), (a.rows(), a.cols(), a.nnz()));
        assert!(sd.num_shards() >= 2, "expected multiple shards");
        // Reassemble and compare segment by segment, bitwise.
        for i in 0..sd.num_shards() {
            let sl = sd.load::<f64>(i).unwrap();
            let (r0, r1) = (sl.r0, sl.r1);
            assert!(r0 % 32 == 0 || r0 == 0, "shard boundary not 32-aligned: {r0}");
            let lo = a.indptr()[r0];
            for li in 0..=sl.local_rows() {
                assert_eq!(sl.indptr[li], a.indptr()[r0 + li] - lo);
            }
            assert_eq!(&sl.indices[..], &a.indices()[lo..a.indptr()[r1]]);
            let av = &a.values()[lo..a.indptr()[r1]];
            assert!(sl.values.iter().zip(av).all(|(p, q)| p.to_bits() == q.to_bits()));
        }
    }

    #[test]
    fn mtx_converter_matches_read_csr_bitwise() {
        let a = test_matrix(250, 90, 3000, 7);
        let dir = tmp("conv");
        let mtx = format!("{dir}/a.mtx");
        mm::write_csr(&mtx, &a).unwrap();
        let b = mm::read_csr(&mtx).unwrap();
        let sd = convert_mtx_to_shards(&mtx, &dir, 3).unwrap();
        assert_eq!((sd.rows(), sd.cols(), sd.nnz()), (b.rows(), b.cols(), b.nnz()));
        let mut at = 0usize;
        for i in 0..sd.num_shards() {
            let sl = sd.load::<f64>(i).unwrap();
            assert_eq!(sl.r0, at);
            let lo = b.indptr()[sl.r0];
            assert_eq!(&sl.indices[..], &b.indices()[lo..b.indptr()[sl.r1]]);
            let bv = &b.values()[lo..b.indptr()[sl.r1]];
            assert!(sl.values.iter().zip(bv).all(|(p, q)| p.to_bits() == q.to_bits()));
            at = sl.r1;
        }
        assert_eq!(at, b.rows());
        // No spill files left behind.
        assert!(!std::path::Path::new(&format!("{dir}/spill_0.tmp")).exists());
    }

    #[test]
    fn symmetric_mtx_converts() {
        let dir = tmp("sym");
        let mtx = format!("{dir}/s.mtx");
        std::fs::write(
            &mtx,
            "%%MatrixMarket matrix coordinate pattern symmetric\n% c\n40 40 3\n2 1\n40 40\n7 3\n",
        )
        .unwrap();
        let sd = convert_mtx_to_shards(&mtx, &dir, 2).unwrap();
        let b = mm::read_csr(&mtx).unwrap();
        assert_eq!(sd.nnz(), b.nnz());
        assert_eq!(sd.rows(), 40);
    }

    fn sharded_kernels_match_incore(cap: usize) -> ShardStats {
        let a = test_matrix(500, 140, 9000, 11);
        let dir = tmp(&format!("kern{cap}"));
        let sd = Arc::new(write_shards_from_csr(&dir, &a, 5).unwrap());
        let mut op: ShardedOperand<f64> = ShardedOperand::new(Arc::clone(&sd), cap);
        let mut rng = Rng::new(12);
        for k in [1usize, 3, 8] {
            let x = Mat::randn(a.cols(), k, &mut rng);
            let mut y1 = Mat::zeros(a.rows(), k);
            let mut y2 = Mat::zeros(a.rows(), k);
            a.spmm(x.as_ref(), y1.as_mut());
            op.spmm(x.as_ref(), &mut y2.as_mut()).unwrap();
            assert!(
                y1.data().iter().zip(y2.data()).all(|(p, q)| p.to_bits() == q.to_bits()),
                "sharded spmm differs bitwise (k={k}, cap={cap})"
            );
            let xm = Mat::randn(a.rows(), k, &mut rng);
            let mut z1 = Mat::zeros(a.cols(), k);
            let mut z2 = Mat::zeros(a.cols(), k);
            a.spmm_t(xm.as_ref(), z1.as_mut());
            op.spmm_t(xm.as_ref(), &mut z2.as_mut()).unwrap();
            assert!(
                z1.data().iter().zip(z2.data()).all(|(p, q)| p.to_bits() == q.to_bits()),
                "sharded spmm_t differs bitwise (k={k}, cap={cap})"
            );
        }
        let stats = op.stats();
        assert_eq!(stats.passes, 6);
        if cap > 0 {
            assert!(
                stats.peak_resident_bytes <= cap,
                "peak {} exceeds cap {cap}",
                stats.peak_resident_bytes
            );
        }
        stats
    }

    #[test]
    fn sharded_spmm_bitwise_unlimited_cap() {
        let s = sharded_kernels_match_incore(0);
        assert_eq!(s.stream_loads, 0, "unlimited cap must pin everything");
        assert!(s.pin_loads >= 2);
    }

    #[test]
    fn sharded_spmm_bitwise_tight_cap_streams() {
        let a = test_matrix(500, 140, 9000, 11);
        let dir = tmp("capsize");
        let sd = write_shards_from_csr(&dir, &a, 5).unwrap();
        let maxb = sd.max_resident_bytes::<f64>();
        drop(sd);
        // Exactly two streaming slots, nothing pinned: prefetch path.
        let s = sharded_kernels_match_incore(2 * maxb);
        assert!(s.stream_loads > 0, "tight cap must stream");
        assert_eq!(s.pin_loads, 0);
        assert!(s.load_secs > 0.0);
        // One slot: synchronous degrade, still bitwise + capped.
        let s = sharded_kernels_match_incore(2 * maxb - 1);
        assert!(s.stream_loads > 0);
        assert!(s.overlap_efficiency() == 0.0, "sync degrade cannot overlap");
    }

    #[test]
    fn cap_smaller_than_a_shard_is_rejected() {
        let a = test_matrix(200, 80, 2000, 5);
        let dir = tmp("tiny");
        let sd = Arc::new(write_shards_from_csr(&dir, &a, 3).unwrap());
        let mut op: ShardedOperand<f64> = ShardedOperand::new(sd, 64);
        assert!(matches!(op.ensure_resident(), Err(Error::InvalidParam(_))));
    }

    #[test]
    fn load_events_cover_each_shard_once_per_pass() {
        let a = test_matrix(400, 100, 6000, 9);
        let dir = tmp("events");
        let sd = Arc::new(write_shards_from_csr(&dir, &a, 4).unwrap());
        let cap = 2 * sd.max_resident_bytes::<f64>();
        let mut op: ShardedOperand<f64> = ShardedOperand::new(Arc::clone(&sd), cap);
        let mut rng = Rng::new(2);
        let x = Mat::randn(a.cols(), 4, &mut rng);
        let mut y = Mat::zeros(a.rows(), 4);
        op.spmm(x.as_ref(), &mut y.as_mut()).unwrap();
        let ev1 = op.take_load_events();
        let streamed: Vec<usize> =
            ev1.iter().filter(|e| !e.pinned).map(|e| e.shard).collect();
        let pinned = ev1.iter().filter(|e| e.pinned).count();
        assert_eq!(pinned + streamed.len(), sd.num_shards(), "first pass touches every shard");
        // Second pass: only the streamed shards load again, each exactly once.
        op.spmm(x.as_ref(), &mut y.as_mut()).unwrap();
        let ev2 = op.take_load_events();
        let streamed2: Vec<usize> = ev2.iter().map(|e| e.shard).collect();
        assert!(ev2.iter().all(|e| !e.pinned));
        assert_eq!(streamed, streamed2);
        let total_stream_bytes: usize = ev2.iter().map(|e| e.file_bytes).sum();
        let expect: usize = streamed.iter().map(|&i| sd.meta(i).file_bytes()).sum();
        assert_eq!(total_stream_bytes, expect, "disk bytes exactly once per shard per pass");
    }

    #[test]
    fn fused_ata_one_pass_bitwise_and_gram() {
        let a = test_matrix(500, 140, 9000, 31);
        let dir = tmp("fusedata");
        let sd = Arc::new(write_shards_from_csr(&dir, &a, 5).unwrap());
        let cap = 2 * sd.max_resident_bytes::<f64>();
        let mut op: ShardedOperand<f64> = ShardedOperand::new(Arc::clone(&sd), cap);
        let mut rng = Rng::new(32);
        let x = Mat::randn(a.cols(), 6, &mut rng);
        let mut y0 = Mat::zeros(a.rows(), 6);
        let mut z0 = Mat::zeros(a.cols(), 6);
        a.spmm(x.as_ref(), y0.as_mut());
        a.spmm_t(y0.as_ref(), z0.as_mut());
        let mut y = Mat::zeros(a.rows(), 6);
        let mut z = Mat::zeros(a.cols(), 6);
        op.spmm_ata(x.as_ref(), &mut y.as_mut(), &mut z.as_mut()).unwrap();
        assert!(
            y0.data().iter().zip(y.data()).all(|(p, q)| p.to_bits() == q.to_bits()),
            "fused Y differs bitwise from in-core spmm"
        );
        assert!(
            z0.data().iter().zip(z.data()).all(|(p, q)| p.to_bits() == q.to_bits()),
            "fused Z differs bitwise from in-core spmm_t(spmm)"
        );
        // One sweep over the operand: exactly one pass, each shard
        // loaded exactly once (the disk-traffic halving the fused power
        // step buys under a tight resident cap).
        let stats = op.stats();
        assert_eq!(stats.passes, 1, "fused ata must be a single operand pass");
        let ev = op.take_load_events();
        let mut seen: Vec<usize> = ev.iter().map(|e| e.shard).collect();
        seen.sort_unstable();
        seen.dedup();
        assert_eq!(seen.len(), ev.len(), "a shard loaded twice within the fused pass");
        assert_eq!(seen.len(), sd.num_shards());
        // Fused gram: Y bitwise, Gram ε-equal to YᵀY; one more pass.
        let mut y2 = Mat::zeros(a.rows(), 6);
        let mut g = Mat::zeros(6, 6);
        op.spmm_gram(x.as_ref(), &mut y2.as_mut(), &mut g.as_mut()).unwrap();
        assert!(y0.data().iter().zip(y2.data()).all(|(p, q)| p.to_bits() == q.to_bits()));
        let expect = crate::la::blas3::mat_tn(&y0, &y0);
        let scale = expect.fro_norm().max(1.0);
        assert!(g.max_abs_diff(&expect) / scale < 1e-12, "Gram mismatch");
        assert_eq!(op.stats().passes, 2);
    }

    #[test]
    fn f32_loads_cast_values() {
        let a = test_matrix(150, 60, 1500, 21);
        let dir = tmp("f32");
        let sd = Arc::new(write_shards_from_csr(&dir, &a, 2).unwrap());
        let a32: Csr<f32> = a.cast();
        let mut op: ShardedOperand<f32> = ShardedOperand::new(sd, 0);
        let mut rng = Rng::new(22);
        let x: Mat<f32> = Mat::randn(a.cols(), 3, &mut rng);
        let mut y1 = Mat::zeros(a.rows(), 3);
        let mut y2 = Mat::zeros(a.rows(), 3);
        a32.spmm(x.as_ref(), y1.as_mut());
        op.spmm(x.as_ref(), &mut y2.as_mut()).unwrap();
        assert!(y1.data().iter().zip(y2.data()).all(|(p, q)| p.to_bits() == q.to_bits()));
    }

    #[test]
    fn manifest_rejects_corruption() {
        let a = test_matrix(100, 40, 900, 1);
        let dir = tmp("corrupt");
        write_shards_from_csr(&dir, &a, 2).unwrap();
        let m = format!("{dir}/{MANIFEST}");
        let text = std::fs::read_to_string(&m).unwrap().replace("rows 100", "rows 99");
        std::fs::write(&m, text).unwrap();
        assert!(ShardDir::open(&dir).is_err(), "row-coverage mismatch must be caught");
    }
}
