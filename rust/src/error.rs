//! Error types for the trunksvd library.
//!
//! Hand-implemented `Display`/`Error` (no `thiserror` in the offline
//! vendor set).

use std::fmt;

/// Library-wide error type.
#[derive(Debug)]
pub enum Error {
    /// Shape mismatch between operands.
    Shape { op: &'static str, detail: String },

    /// Cholesky factorization hit a non-positive pivot (matrix not
    /// numerically SPD). The orthogonalization layer catches this and
    /// falls back to CGS with re-orthogonalization (paper §3.2).
    CholeskyBreakdown { pivot: usize, value: f64 },

    /// Jacobi SVD failed to converge within the sweep limit.
    SvdNoConvergence { sweeps: usize, off: f64 },

    /// Invalid algorithm parameters (r, p, b constraints).
    InvalidParam(String),

    /// I/O error (MatrixMarket, artifacts, reports).
    Io {
        path: String,
        source: std::io::Error,
    },

    /// Parse error (MatrixMarket, JSON, CLI).
    Parse { what: &'static str, detail: String },

    /// PJRT / XLA runtime error.
    Xla(String),

    /// Requested artifact is not present in the manifest and the fallback
    /// builder cannot synthesize the op.
    MissingArtifact { op: String, shape: String },
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Shape { op, detail } => write!(f, "shape mismatch in {op}: {detail}"),
            Error::CholeskyBreakdown { pivot, value } => {
                write!(f, "cholesky breakdown at pivot {pivot} (value {value:.3e})")
            }
            Error::SvdNoConvergence { sweeps, off } => {
                write!(f, "jacobi SVD did not converge after {sweeps} sweeps (off {off:.3e})")
            }
            Error::InvalidParam(detail) => write!(f, "invalid parameter: {detail}"),
            Error::Io { path, source } => write!(f, "io error on {path}: {source}"),
            Error::Parse { what, detail } => write!(f, "parse error in {what}: {detail}"),
            Error::Xla(detail) => write!(f, "xla runtime: {detail}"),
            Error::MissingArtifact { op, shape } => {
                write!(f, "no artifact or fallback for op {op} with shape {shape}")
            }
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io { source, .. } => Some(source),
            _ => None,
        }
    }
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

/// Library-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

#[inline]
pub(crate) fn shape_err(op: &'static str, detail: impl Into<String>) -> Error {
    Error::Shape { op, detail: detail.into() }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_formats() {
        let e = Error::CholeskyBreakdown { pivot: 3, value: -1e-18 };
        assert!(e.to_string().contains("pivot 3"));
        let e = shape_err("gemm", "2x3 * 4x5");
        assert!(e.to_string().contains("gemm"));
    }

    #[test]
    fn io_source_is_chained() {
        let e = Error::Io {
            path: "x.mtx".into(),
            source: std::io::Error::new(std::io::ErrorKind::NotFound, "gone"),
        };
        assert!(std::error::Error::source(&e).is_some());
        assert!(e.to_string().contains("x.mtx"));
    }
}
