//! Error types for the trunksvd library.

use thiserror::Error;

/// Library-wide error type.
#[derive(Error, Debug)]
pub enum Error {
    /// Shape mismatch between operands.
    #[error("shape mismatch in {op}: {detail}")]
    Shape { op: &'static str, detail: String },

    /// Cholesky factorization hit a non-positive pivot (matrix not
    /// numerically SPD). The orthogonalization layer catches this and
    /// falls back to CGS with re-orthogonalization (paper §3.2).
    #[error("cholesky breakdown at pivot {pivot} (value {value:.3e})")]
    CholeskyBreakdown { pivot: usize, value: f64 },

    /// Jacobi SVD failed to converge within the sweep limit.
    #[error("jacobi SVD did not converge after {sweeps} sweeps (off {off:.3e})")]
    SvdNoConvergence { sweeps: usize, off: f64 },

    /// Invalid algorithm parameters (r, p, b constraints).
    #[error("invalid parameter: {0}")]
    InvalidParam(String),

    /// I/O error (MatrixMarket, artifacts, reports).
    #[error("io error on {path}: {source}")]
    Io {
        path: String,
        #[source]
        source: std::io::Error,
    },

    /// Parse error (MatrixMarket, JSON, CLI).
    #[error("parse error in {what}: {detail}")]
    Parse { what: &'static str, detail: String },

    /// PJRT / XLA runtime error.
    #[error("xla runtime: {0}")]
    Xla(String),

    /// Requested artifact is not present in the manifest and the fallback
    /// builder cannot synthesize the op.
    #[error("no artifact or fallback for op {op} with shape {shape}")]
    MissingArtifact { op: String, shape: String },
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

/// Library-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

#[inline]
pub(crate) fn shape_err(op: &'static str, detail: impl Into<String>) -> Error {
    Error::Shape { op, detail: detail.into() }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_formats() {
        let e = Error::CholeskyBreakdown { pivot: 3, value: -1e-18 };
        assert!(e.to_string().contains("pivot 3"));
        let e = shape_err("gemm", "2x3 * 4x5");
        assert!(e.to_string().contains("gemm"));
    }
}
