//! # trunksvd
//!
//! Reproduction of *"Fast Truncated SVD of Sparse and Dense Matrices on
//! Graphics Processors"* (Tomás, Quintana-Ortí, Anzt): the randomized
//! truncated SVD (RandSVD, Alg. 1) and the block Golub–Kahan–Lanczos
//! truncated SVD (LancSVD, Alg. 2), assembled from the paper's shared
//! building blocks — CGS-QR (Alg. 3), CholeskyQR2 (Alg. 4) and CGS-CQR2
//! (Alg. 5) — over two interchangeable compute backends:
//!
//! * [`backend::CpuBackend`] — a pure-rust dense/sparse substrate;
//! * [`backend::XlaBackend`] — AOT-compiled JAX/Pallas graphs executed
//!   through the PJRT runtime (the GPU-library stand-in).
//!
//! See `DESIGN.md` for the system inventory and the experiment index, and
//! `EXPERIMENTS.md` for paper-vs-measured results.

pub mod error;

pub mod util {
    pub mod counting_alloc;
    pub mod json;
    pub mod pool;
    pub mod rng;
    pub mod scalar;
    pub mod simd;
}

pub mod la {
    pub mod blas1;
    pub mod blas3;
    pub mod chol;
    pub mod mat;
    pub mod norms;
    pub mod qr;
    pub mod svd;
    pub mod workspace;
}

pub mod sparse {
    pub mod blockell;
    pub mod coo;
    pub mod csr;
    pub mod mm;
    pub mod shard;
}

pub mod gen {
    pub mod dense;
    pub mod sparse;
    pub mod suite;
}

pub mod algo;
pub mod bench_support;
pub mod cli;
pub mod backend;
pub mod coordinator;
pub mod cost;
pub mod metrics;

pub use algo::incremental::IncrementalSvd;
pub use algo::stream::StreamSketch;
pub use error::{Error, Result};
pub use runtime::serve::{JobKind, JobResult, JobSpec, JobStatus, ServeConfig, Server, ShapeClass};
pub use la::mat::Mat;
pub use la::workspace::{Plan, Workspace};
pub use sparse::csr::Csr;
pub use util::scalar::{DType, Scalar};

/// Crate version string.
pub fn version() -> &'static str {
    env!("CARGO_PKG_VERSION")
}
pub mod runtime;
