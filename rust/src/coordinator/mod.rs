//! Layer-3 coordinator: drivers, experiment reproduction, reporting.

pub mod driver;
pub mod experiments;
pub mod report;
