//! Solve driver: one (matrix, algorithm, backend) run → a [`RunReport`].
//!
//! The driver owns backend construction, algorithm dispatch, wall-clock
//! accounting, the paper's residual metric (Eq. 14), and the per-block
//! breakdown used by the Fig. 2 reproduction.

use std::rc::Rc;

use crate::algo::{
    lancsvd::lancsvd, randsvd::randsvd, residuals, LancSvdOpts, RandSvdOpts, TruncatedSvd,
};
use crate::backend::cpu::CpuBackend;
use crate::backend::staged::StagedBackend;
use crate::backend::xla::XlaBackend;
use crate::backend::{Backend, Operand};
use crate::error::{Error, Result};
use crate::metrics::{Block, Profile};
use crate::runtime::Runtime;
use crate::util::scalar::{DType, Scalar};

/// Which truncated-SVD algorithm to run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Algo {
    /// RandSVD (Alg. 1)
    Rand,
    /// LancSVD (Alg. 2)
    Lanc,
}

impl Algo {
    pub fn name(&self) -> &'static str {
        match self {
            Algo::Rand => "randsvd",
            Algo::Lanc => "lancsvd",
        }
    }
}

/// Which backend executes the building blocks.
#[derive(Clone)]
pub enum BackendChoice {
    /// Pure-rust substrate; Aᵀ·X starts on scatter and adaptively
    /// switches to a background-built transposed copy (the default).
    Cpu,
    /// Pure-rust, scatter SpMMᵀ only (the cuSPARSE-like baseline; the
    /// adaptive transpose is disabled — ablation arm).
    CpuScatter,
    /// Pure-rust with an eager explicit transposed CSR copy (paper's
    /// §4.1.2 strategy — ablation arm).
    CpuExplicitT,
    /// Device-contract simulation: arena-staged operand (CSR→Block-ELL),
    /// residency-tracked buffers, transfer ledger (`backend::staged`).
    Staged,
    /// AOT JAX/Pallas graphs through PJRT.
    Xla(Rc<Runtime>),
}

impl BackendChoice {
    pub fn name(&self) -> &'static str {
        match self {
            BackendChoice::Cpu => "cpu",
            BackendChoice::CpuScatter => "cpu-scatter",
            BackendChoice::CpuExplicitT => "cpu+expT",
            BackendChoice::Staged => "staged",
            BackendChoice::Xla(_) => "xla",
        }
    }
}

/// Algorithm parameters (r, p, b + init/tol) in one bundle.
#[derive(Clone, Debug)]
pub struct Params {
    pub r: usize,
    pub p: usize,
    pub b: usize,
    pub seed: u64,
    pub tol: Option<f64>,
    pub wanted: usize,
    pub restart: crate::algo::Restart,
    /// Working precision of the solve (`--dtype`; default f64). The fp32
    /// path runs the whole pipeline — SpMM/GEMM, Gram, CholeskyQR2, the
    /// small SVD — at f32 and is validated against the same residual
    /// targets as fp64 (paper's single-precision GPU regime).
    pub dtype: DType,
}

impl Default for Params {
    fn default() -> Self {
        Params {
            r: 256,
            p: 2,
            b: 16,
            seed: 0xC0FFEE,
            tol: None,
            wanted: 10,
            restart: crate::algo::Restart::Basic,
            dtype: DType::F64,
        }
    }
}

/// The outcome of one solve.
#[derive(Debug)]
pub struct RunReport {
    pub matrix: String,
    pub algo: Algo,
    pub backend: String,
    /// Element precision the solve ran in ("f32"/"f64"). Residuals are
    /// always *measured* (Eq. 14 on a fresh checking backend of the same
    /// dtype) and reported as f64, so fp32 accuracy is validated against
    /// the same targets as fp64 rather than assumed.
    pub dtype: &'static str,
    pub m: usize,
    pub n: usize,
    pub nnz: Option<usize>,
    pub params: Params,
    pub secs: f64,
    pub profile: Profile,
    pub sigma: Vec<f64>,
    pub residuals: Vec<f64>,
    pub est_residuals: Vec<f64>,
    pub iters: usize,
}

impl RunReport {
    /// Largest relative residual among the `wanted` leading triplets.
    pub fn max_residual(&self) -> f64 {
        self.residuals.iter().fold(0.0f64, |m, &x| m.max(x))
    }

    /// Fraction of wall time in a block (Fig. 2 breakdown).
    pub fn frac(&self, b: Block) -> f64 {
        let t = self.profile.total_secs();
        if t > 0.0 {
            self.profile.stat(b).secs / t
        } else {
            0.0
        }
    }

    /// One-line summary for logs.
    pub fn summary(&self) -> String {
        format!(
            "{:<18} {:<8} {:<8} {:<4} {:>9.3}s  R1={}  R{}={}  iters={}",
            self.matrix,
            self.algo.name(),
            self.backend,
            self.dtype,
            self.secs,
            super::report::sci(self.residuals.first().copied().unwrap_or(f64::NAN)),
            self.residuals.len(),
            super::report::sci(self.max_residual()),
            self.iters
        )
    }
}

/// Backend construction at any precision — the single place the
/// `BackendChoice` policy lives. Every backend family is generic over
/// the element type now, so `--dtype f32` combines with every
/// `--backend` (the XLA backend's PJRT interchange stays f64; see its
/// module docs).
pub fn make_backend_at<S: Scalar>(
    op: Operand<S>,
    choice: &BackendChoice,
) -> Result<Box<dyn Backend<S>>> {
    let sharded = matches!(op, Operand::Sharded { .. });
    Ok(match choice {
        BackendChoice::Cpu | BackendChoice::CpuScatter if sharded => {
            // Sharded Aᵀ·X is always the global-row-order scatter (the
            // bitwise parity reference), so `cpu` and `cpu-scatter`
            // coincide out-of-core. Resolve the shard manifest eagerly:
            // a cap smaller than the largest shard (or an unreadable
            // shard directory) must surface as `Err` here, not as a
            // panic inside the first infallible solve op.
            let mut be = CpuBackend::new(op);
            be.ensure_operand_resident()?;
            Box::new(be)
        }
        BackendChoice::Cpu => Box::new(CpuBackend::new(op)),
        BackendChoice::CpuScatter => Box::new(CpuBackend::new(op).scatter_only()),
        BackendChoice::CpuExplicitT if sharded => {
            return Err(Error::InvalidParam(
                "cpu-expt needs the whole operand in core to build the explicit \
                 transpose; sharded operands support cpu, cpu-scatter, or staged"
                    .into(),
            ))
        }
        BackendChoice::CpuExplicitT => Box::new(CpuBackend::new(op).with_explicit_transpose()),
        BackendChoice::Staged if sharded => {
            let mut be = StagedBackend::new(op);
            be.ensure_operand_resident()?;
            Box::new(be)
        }
        BackendChoice::Staged => Box::new(StagedBackend::new(op)),
        BackendChoice::Xla(rt) => Box::new(XlaBackend::new(rt.clone(), op)?),
    })
}

/// Build an f64 backend for an operand (compatibility shim over
/// [`make_backend_at`]).
pub fn make_backend(op: Operand, choice: &BackendChoice) -> Result<Box<dyn Backend>> {
    make_backend_at::<f64>(op, choice)
}

/// The [`BackendChoice`] subset whose constructed backends are `Send` —
/// what the multi-tenant serving layer (`crate::runtime::serve`) may
/// move across its solver threads and park in its operand cache. `Xla`
/// is excluded (it holds an `Rc<Runtime>`); ask for it through `serve`
/// and you get a typed job failure, not a compile error in the server.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SendBackendChoice {
    /// CPU substrate, *eager* explicit-transpose for in-core sparse
    /// operands (see [`make_send_backend_at`] for why serve's `cpu`
    /// differs from the interactive `cpu`).
    Cpu,
    /// CPU substrate, scatter SpMMᵀ only.
    CpuScatter,
    /// CPU substrate, eager explicit transpose (alias of `Cpu` for
    /// in-core sparse; rejected for sharded operands).
    CpuExplicitT,
    /// Device-contract simulation (`backend::staged`).
    Staged,
}

impl SendBackendChoice {
    pub fn name(&self) -> &'static str {
        match self {
            SendBackendChoice::Cpu => "cpu",
            SendBackendChoice::CpuScatter => "cpu-scatter",
            SendBackendChoice::CpuExplicitT => "cpu-expt",
            SendBackendChoice::Staged => "staged",
        }
    }

    /// Parse the CLI/workload-file tag (`cpu|cpu-scatter|cpu-expt|staged`).
    pub fn parse(tag: &str) -> Option<SendBackendChoice> {
        match tag {
            "cpu" => Some(SendBackendChoice::Cpu),
            "cpu-scatter" => Some(SendBackendChoice::CpuScatter),
            "cpu-expt" => Some(SendBackendChoice::CpuExplicitT),
            "staged" => Some(SendBackendChoice::Staged),
            _ => None,
        }
    }
}

/// Backend construction for the serving layer: like
/// [`make_backend_at`], but the result is `Send` (it crosses solver
/// threads and outlives jobs in the operand cache) and the transpose
/// policy is *schedule-deterministic*. The interactive `cpu` choice
/// adaptively adopts a background-built transposed copy, and the
/// adoption instant depends on OS scheduling — harmless for one-shot
/// runs, but serve pins repeat queries to bitwise-identical singular
/// values at a fixed thread count, so `Cpu` here builds the explicit
/// transpose *eagerly* for in-core sparse operands (staging cost paid
/// once at operand admission, amortized across every cached-backend
/// reuse — the serving trade). Sharded and dense operands keep their
/// already-deterministic paths.
pub fn make_send_backend_at<S: Scalar>(
    op: Operand<S>,
    choice: SendBackendChoice,
) -> Result<Box<dyn Backend<S> + Send>> {
    let sharded = matches!(op, Operand::Sharded { .. });
    Ok(match choice {
        SendBackendChoice::Cpu | SendBackendChoice::CpuScatter if sharded => {
            // Sharded Aᵀ·X is always the streaming scatter; resolve the
            // manifest/cap eagerly so misconfiguration is an `Err` here.
            let mut be = CpuBackend::new(op);
            be.ensure_operand_resident()?;
            Box::new(be)
        }
        SendBackendChoice::CpuExplicitT if sharded => {
            return Err(Error::InvalidParam(
                "cpu-expt needs the whole operand in core to build the explicit \
                 transpose; sharded operands support cpu, cpu-scatter, or staged"
                    .into(),
            ))
        }
        SendBackendChoice::Cpu | SendBackendChoice::CpuExplicitT => {
            Box::new(CpuBackend::new(op).with_explicit_transpose())
        }
        SendBackendChoice::CpuScatter => Box::new(CpuBackend::new(op).scatter_only()),
        SendBackendChoice::Staged if sharded => {
            let mut be = StagedBackend::new(op);
            be.ensure_operand_resident()?;
            Box::new(be)
        }
        SendBackendChoice::Staged => Box::new(StagedBackend::new(op)),
    })
}

/// Dispatch one solve on an already-built backend (any precision).
fn solve<S: Scalar, B: Backend<S> + ?Sized>(
    be: &mut B,
    algo: Algo,
    params: &Params,
) -> Result<TruncatedSvd<S>> {
    match algo {
        Algo::Rand => randsvd(
            be,
            &RandSvdOpts {
                r: params.r,
                p: params.p,
                b: params.b,
                seed: params.seed,
                init: crate::algo::InitDist::CenteredPoisson,
                fuse: None,
            },
        ),
        Algo::Lanc => lancsvd(
            be,
            &LancSvdOpts {
                r: params.r,
                p: params.p,
                b: params.b,
                seed: params.seed,
                init: crate::algo::InitDist::CenteredPoisson,
                tol: params.tol,
                wanted: params.wanted,
                restart: params.restart,
                fuse: None,
            },
        ),
    }
}

/// The dtype-independent run core: time the solve on `be`, then measure
/// residuals (Eq. 14) on a fresh CPU backend of the *same* precision and
/// convert everything reportable to f64. `op` is consumed by the
/// residual-check backend.
fn run_at<S: Scalar>(
    op: Operand<S>,
    be: &mut dyn Backend<S>,
    algo: Algo,
    params: &Params,
) -> Result<(f64, Profile, Vec<f64>, Vec<f64>, Vec<f64>, usize)> {
    let t0 = std::time::Instant::now();
    let svd = solve(be, algo, params)?;
    let secs = t0.elapsed().as_secs_f64();
    // Residual check runs on a fresh CPU backend (not timed).
    let mut check = CpuBackend::new(op);
    let res = residuals(&mut check, &svd, params.wanted);
    let sigma: Vec<f64> = svd.sigma[..params.wanted.min(svd.sigma.len())]
        .iter()
        .map(|s| s.to_f64())
        .collect();
    Ok((secs, svd.profile, sigma, res, svd.est_residuals, svd.iters))
}

/// Run one solve end-to-end and report. The operand arrives at f64 (the
/// canonical generator/I-O precision) and is converted once when
/// `params.dtype` selects fp32.
pub fn run(
    name: &str,
    op: Operand,
    algo: Algo,
    params: &Params,
    choice: &BackendChoice,
) -> Result<RunReport> {
    let (m, n) = op.shape();
    let nnz = op.nnz();
    let (secs, profile, sigma, res, est_res, iters) = match params.dtype {
        DType::F64 => {
            let mut be = make_backend_at::<f64>(op.clone(), choice)?;
            run_at(op, be.as_mut(), algo, params)?
        }
        DType::F32 => {
            let op32: Operand<f32> = op.cast();
            let mut be = make_backend_at::<f32>(op32.clone(), choice)?;
            run_at(op32, be.as_mut(), algo, params)?
        }
    };
    Ok(RunReport {
        matrix: name.to_string(),
        algo,
        backend: choice.name().to_string(),
        dtype: params.dtype.name(),
        m,
        n,
        nnz,
        params: params.clone(),
        secs,
        profile,
        sigma,
        residuals: res,
        est_residuals: est_res,
        iters,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen::dense::paper_dense;
    use crate::gen::sparse::{generate, SparseSpec};

    #[test]
    fn dense_run_produces_report() {
        let prob = paper_dense(120, 40, 7);
        let params = Params { r: 16, p: 4, b: 8, wanted: 5, ..Default::default() };
        let rep = run("toy", Operand::Dense(prob.a), Algo::Lanc, &params, &BackendChoice::Cpu)
            .unwrap();
        assert_eq!((rep.m, rep.n), (120, 40));
        assert_eq!(rep.sigma.len(), 5);
        assert_eq!(rep.residuals.len(), 5);
        assert!(rep.secs > 0.0);
        assert!(rep.max_residual() < 1e-3, "residuals {:?}", rep.residuals);
        assert!(rep.profile.total_secs() > 0.0);
        assert!(!rep.summary().is_empty());
    }

    #[test]
    fn f32_run_meets_f64_residual_target() {
        // A mildly conditioned known-spectrum problem: both dtypes must
        // hit the paper's 1e-4-class accuracy target, measured (not
        // assumed) by the driver's residual check at each dtype.
        let sigma: Vec<f64> = (0..16).map(|i| 1.0 / (1.0 + i as f64)).collect();
        let prob = crate::gen::dense::dense_with_spectrum(120, 16, &sigma, 11);
        let base = Params { r: 16, p: 4, b: 8, wanted: 4, ..Default::default() };
        let cpu = BackendChoice::Cpu;
        let r64 = run("spec", Operand::Dense(prob.a.clone()), Algo::Lanc, &base, &cpu).unwrap();
        let p32 = Params { dtype: crate::util::scalar::DType::F32, ..base };
        let r32 = run("spec", Operand::Dense(prob.a), Algo::Lanc, &p32, &cpu).unwrap();
        assert_eq!(r64.dtype, "f64");
        assert_eq!(r32.dtype, "f32");
        assert!(r64.max_residual() < 1e-4, "f64 residuals {:?}", r64.residuals);
        assert!(r32.max_residual() < 1e-4, "f32 residuals {:?}", r32.residuals);
        // Leading singular values agree across dtypes to f32 accuracy.
        for (s64, s32) in r64.sigma.iter().zip(&r32.sigma) {
            assert!((s64 - s32).abs() < 1e-3 * s64.max(1e-6), "{s64} vs {s32}");
        }
        assert!(r32.summary().contains("f32"));
    }

    #[test]
    fn staged_backend_runs_both_dtypes() {
        let spec = SparseSpec { rows: 150, cols: 70, nnz: 1800, seed: 13, ..Default::default() };
        let a = generate(&spec);
        let params = Params { r: 16, p: 3, b: 8, wanted: 4, ..Default::default() };
        let r64 = run(
            "staged-sp",
            Operand::sparse(a.clone()),
            Algo::Lanc,
            &params,
            &BackendChoice::Staged,
        )
        .unwrap();
        assert_eq!(r64.backend, "staged");
        assert!(r64.max_residual() < 1e-4, "residuals {:?}", r64.residuals);
        let p32 = Params { dtype: crate::util::scalar::DType::F32, ..params };
        let r32 =
            run("staged-sp32", Operand::sparse(a), Algo::Lanc, &p32, &BackendChoice::Staged)
                .unwrap();
        assert_eq!((r32.backend.as_str(), r32.dtype), ("staged", "f32"));
        assert!(r32.max_residual() < 1e-3, "f32 residuals {:?}", r32.residuals);
    }

    #[test]
    fn sparse_run_both_algos_and_expt() {
        let spec = SparseSpec { rows: 250, cols: 120, nnz: 3000, seed: 3, ..Default::default() };
        let a = generate(&spec);
        let params = Params { r: 32, p: 2, b: 16, wanted: 5, ..Default::default() };
        for algo in [Algo::Lanc, Algo::Rand] {
            for choice in [BackendChoice::Cpu, BackendChoice::CpuExplicitT] {
                let rep = run(
                    "toy-sparse",
                    Operand::sparse(a.clone()),
                    algo,
                    &Params {
                        p: if algo == Algo::Rand { 30 } else { 2 },
                        r: if algo == Algo::Rand { 16 } else { 32 },
                        ..params.clone()
                    },
                    &choice,
                )
                .unwrap();
                assert!(rep.nnz.is_some());
                assert!(
                    rep.max_residual() < 1e-2,
                    "{} {} residuals {:?}",
                    algo.name(),
                    choice.name(),
                    rep.residuals
                );
            }
        }
    }
}
