//! Solve driver: one (matrix, algorithm, backend) run → a [`RunReport`].
//!
//! The driver owns backend construction, algorithm dispatch, wall-clock
//! accounting, the paper's residual metric (Eq. 14), and the per-block
//! breakdown used by the Fig. 2 reproduction.

use std::rc::Rc;

use crate::algo::{lancsvd::lancsvd, randsvd::randsvd, residuals, LancSvdOpts, RandSvdOpts};
use crate::backend::cpu::CpuBackend;
use crate::backend::xla::XlaBackend;
use crate::backend::{Backend, Operand};
use crate::error::Result;
use crate::metrics::{Block, Profile};
use crate::runtime::Runtime;

/// Which truncated-SVD algorithm to run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Algo {
    /// RandSVD (Alg. 1)
    Rand,
    /// LancSVD (Alg. 2)
    Lanc,
}

impl Algo {
    pub fn name(&self) -> &'static str {
        match self {
            Algo::Rand => "randsvd",
            Algo::Lanc => "lancsvd",
        }
    }
}

/// Which backend executes the building blocks.
#[derive(Clone)]
pub enum BackendChoice {
    /// Pure-rust substrate; Aᵀ·X starts on scatter and adaptively
    /// switches to a background-built transposed copy (the default).
    Cpu,
    /// Pure-rust, scatter SpMMᵀ only (the cuSPARSE-like baseline; the
    /// adaptive transpose is disabled — ablation arm).
    CpuScatter,
    /// Pure-rust with an eager explicit transposed CSR copy (paper's
    /// §4.1.2 strategy — ablation arm).
    CpuExplicitT,
    /// AOT JAX/Pallas graphs through PJRT.
    Xla(Rc<Runtime>),
}

impl BackendChoice {
    pub fn name(&self) -> &'static str {
        match self {
            BackendChoice::Cpu => "cpu",
            BackendChoice::CpuScatter => "cpu-scatter",
            BackendChoice::CpuExplicitT => "cpu+expT",
            BackendChoice::Xla(_) => "xla",
        }
    }
}

/// Algorithm parameters (r, p, b + init/tol) in one bundle.
#[derive(Clone, Debug)]
pub struct Params {
    pub r: usize,
    pub p: usize,
    pub b: usize,
    pub seed: u64,
    pub tol: Option<f64>,
    pub wanted: usize,
    pub restart: crate::algo::Restart,
}

impl Default for Params {
    fn default() -> Self {
        Params {
            r: 256,
            p: 2,
            b: 16,
            seed: 0xC0FFEE,
            tol: None,
            wanted: 10,
            restart: crate::algo::Restart::Basic,
        }
    }
}

/// The outcome of one solve.
#[derive(Debug)]
pub struct RunReport {
    pub matrix: String,
    pub algo: Algo,
    pub backend: String,
    pub m: usize,
    pub n: usize,
    pub nnz: Option<usize>,
    pub params: Params,
    pub secs: f64,
    pub profile: Profile,
    pub sigma: Vec<f64>,
    pub residuals: Vec<f64>,
    pub est_residuals: Vec<f64>,
    pub iters: usize,
}

impl RunReport {
    /// Largest relative residual among the `wanted` leading triplets.
    pub fn max_residual(&self) -> f64 {
        self.residuals.iter().fold(0.0f64, |m, &x| m.max(x))
    }

    /// Fraction of wall time in a block (Fig. 2 breakdown).
    pub fn frac(&self, b: Block) -> f64 {
        let t = self.profile.total_secs();
        if t > 0.0 {
            self.profile.stat(b).secs / t
        } else {
            0.0
        }
    }

    /// One-line summary for logs.
    pub fn summary(&self) -> String {
        format!(
            "{:<18} {:<8} {:<8} {:>9.3}s  R1={}  R{}={}  iters={}",
            self.matrix,
            self.algo.name(),
            self.backend,
            self.secs,
            super::report::sci(self.residuals.first().copied().unwrap_or(f64::NAN)),
            self.residuals.len(),
            super::report::sci(self.max_residual()),
            self.iters
        )
    }
}

/// Build a backend for an operand.
pub fn make_backend(op: Operand, choice: &BackendChoice) -> Result<Box<dyn Backend>> {
    Ok(match (choice, op) {
        (BackendChoice::Cpu, op) => Box::new(CpuBackend::new(op)),
        (BackendChoice::CpuScatter, op) => Box::new(CpuBackend::new(op).scatter_only()),
        (BackendChoice::CpuExplicitT, op) => {
            Box::new(CpuBackend::new(op).with_explicit_transpose())
        }
        (BackendChoice::Xla(rt), Operand::Dense(a)) => {
            Box::new(XlaBackend::new_dense(rt.clone(), a)?)
        }
        (BackendChoice::Xla(rt), Operand::Sparse(a)) => {
            Box::new(XlaBackend::new_sparse(rt.clone(), a))
        }
    })
}

/// Run one solve end-to-end and report.
pub fn run(
    name: &str,
    op: Operand,
    algo: Algo,
    params: &Params,
    choice: &BackendChoice,
) -> Result<RunReport> {
    let (m, n) = op.shape();
    let nnz = op.nnz();
    let mut be = make_backend(op.clone(), choice)?;
    let t0 = std::time::Instant::now();
    let svd = match algo {
        Algo::Rand => randsvd(
            be.as_mut(),
            &RandSvdOpts {
                r: params.r,
                p: params.p,
                b: params.b,
                seed: params.seed,
                init: crate::algo::InitDist::CenteredPoisson,
            },
        )?,
        Algo::Lanc => lancsvd(
            be.as_mut(),
            &LancSvdOpts {
                r: params.r,
                p: params.p,
                b: params.b,
                seed: params.seed,
                init: crate::algo::InitDist::CenteredPoisson,
                tol: params.tol,
                wanted: params.wanted,
                restart: params.restart,
            },
        )?,
    };
    let secs = t0.elapsed().as_secs_f64();
    // Residual check runs on a fresh CPU backend (not timed).
    let mut check = CpuBackend::new(op);
    let res = residuals(&mut check, &svd, params.wanted);
    Ok(RunReport {
        matrix: name.to_string(),
        algo,
        backend: choice.name().to_string(),
        m,
        n,
        nnz,
        params: params.clone(),
        secs,
        profile: svd.profile,
        sigma: svd.sigma[..params.wanted.min(svd.sigma.len())].to_vec(),
        residuals: res,
        est_residuals: svd.est_residuals,
        iters: svd.iters,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen::dense::paper_dense;
    use crate::gen::sparse::{generate, SparseSpec};

    #[test]
    fn dense_run_produces_report() {
        let prob = paper_dense(120, 40, 7);
        let params = Params { r: 16, p: 4, b: 8, wanted: 5, ..Default::default() };
        let rep = run("toy", Operand::Dense(prob.a), Algo::Lanc, &params, &BackendChoice::Cpu)
            .unwrap();
        assert_eq!((rep.m, rep.n), (120, 40));
        assert_eq!(rep.sigma.len(), 5);
        assert_eq!(rep.residuals.len(), 5);
        assert!(rep.secs > 0.0);
        assert!(rep.max_residual() < 1e-3, "residuals {:?}", rep.residuals);
        assert!(rep.profile.total_secs() > 0.0);
        assert!(!rep.summary().is_empty());
    }

    #[test]
    fn sparse_run_both_algos_and_expt() {
        let spec = SparseSpec { rows: 250, cols: 120, nnz: 3000, seed: 3, ..Default::default() };
        let a = generate(&spec);
        let params = Params { r: 32, p: 2, b: 16, wanted: 5, ..Default::default() };
        for algo in [Algo::Lanc, Algo::Rand] {
            for choice in [BackendChoice::Cpu, BackendChoice::CpuExplicitT] {
                let rep = run(
                    "toy-sparse",
                    Operand::Sparse(a.clone()),
                    algo,
                    &Params {
                        p: if algo == Algo::Rand { 30 } else { 2 },
                        r: if algo == Algo::Rand { 16 } else { 32 },
                        ..params.clone()
                    },
                    &choice,
                )
                .unwrap();
                assert!(rep.nnz.is_some());
                assert!(
                    rep.max_residual() < 1e-2,
                    "{} {} residuals {:?}",
                    algo.name(),
                    choice.name(),
                    rep.residuals
                );
            }
        }
    }
}
