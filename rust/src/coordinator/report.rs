//! Report formatting: markdown tables, CSV files, and the JSON run log.

use std::fmt::Write as _;

use crate::error::{Error, Result};

/// A simple column-aligned markdown table builder.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Table {
        Table { headers: headers.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(cells.len(), self.headers.len(), "row arity");
        self.rows.push(cells);
        self
    }

    pub fn to_markdown(&self) -> String {
        let ncol = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let line = |cells: &[String], out: &mut String| {
            out.push('|');
            for i in 0..ncol {
                let _ = write!(out, " {:w$} |", cells[i], w = widths[i]);
            }
            out.push('\n');
        };
        line(&self.headers, &mut out);
        out.push('|');
        for w in &widths {
            let _ = write!(out, "{:-<w$}|", "", w = w + 2);
        }
        out.push('\n');
        for r in &self.rows {
            line(r, &mut out);
        }
        out
    }

    pub fn to_csv(&self) -> String {
        let mut out = String::new();
        let esc = |s: &str| {
            if s.contains(',') || s.contains('"') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.to_string()
            }
        };
        out.push_str(&self.headers.iter().map(|h| esc(h)).collect::<Vec<_>>().join(","));
        out.push('\n');
        for r in &self.rows {
            out.push_str(&r.iter().map(|c| esc(c)).collect::<Vec<_>>().join(","));
            out.push('\n');
        }
        out
    }
}

/// Write text to a file, creating parent directories.
pub fn write_file(path: &str, text: &str) -> Result<()> {
    if let Some(parent) = std::path::Path::new(path).parent() {
        std::fs::create_dir_all(parent)
            .map_err(|e| Error::Io { path: path.to_string(), source: e })?;
    }
    std::fs::write(path, text).map_err(|e| Error::Io { path: path.to_string(), source: e })
}

/// Compact scientific formatting used across reports.
pub fn sci(x: f64) -> String {
    if x == 0.0 {
        "0".to_string()
    } else if x.is_finite() {
        format!("{x:.2e}")
    } else {
        "inf".to_string()
    }
}

/// Seconds with ms precision.
pub fn secs(x: f64) -> String {
    format!("{x:.3}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn markdown_alignment_and_csv() {
        let mut t = Table::new(&["name", "value"]);
        t.row(vec!["a".into(), "1".into()]);
        t.row(vec!["longer".into(), "2.5".into()]);
        let md = t.to_markdown();
        assert!(md.contains("| name   |"));
        assert!(md.lines().count() == 4);
        let csv = t.to_csv();
        assert_eq!(csv.lines().next().unwrap(), "name,value");
        assert_eq!(csv.lines().count(), 3);
    }

    #[test]
    fn csv_escaping() {
        let mut t = Table::new(&["a"]);
        t.row(vec!["x,y".into()]);
        assert!(t.to_csv().contains("\"x,y\""));
    }

    #[test]
    fn sci_formats() {
        assert_eq!(sci(0.0), "0");
        assert_eq!(sci(1234.0), "1.23e3");
        assert_eq!(sci(f64::INFINITY), "inf");
    }
}
