//! Experiment drivers: one function per paper table/figure.
//!
//! Each driver regenerates the corresponding result on the scaled suite
//! (DESIGN.md §5), returns a markdown report, and writes CSV next to it
//! under `reports/`. The benches in `benches/` are thin wrappers over
//! these drivers so `cargo bench` reproduces every table and figure.

use crate::util::scalar::DType;
use crate::backend::Operand;
use crate::cost::device::DeviceModel;
use crate::cost::{self, Problem};
use crate::error::Result;
use crate::gen::dense::paper_dense;
use crate::gen::sparse::generate;
use crate::gen::suite::Suite;
use crate::metrics::Block;

use super::driver::{run, Algo, BackendChoice, Params};
use super::report::{sci, secs, write_file, Table};

/// How much of the suite to run (time control on the 1-core testbed).
#[derive(Clone)]
pub struct ExpOpts {
    /// Number of sparse suite matrices (representative subset); usize::MAX = all 46.
    pub subset: usize,
    /// Backend for the timed runs.
    pub backend: BackendChoice,
    /// Output directory for reports (md + csv).
    pub out_dir: String,
    /// Divide the paper's r (and dense sizes) by this extra factor for
    /// smoke runs; 1 = the scaled-paper configuration.
    pub shrink: usize,
    /// Solve precision for every run (suite.json `"dtype"` default or
    /// the `--dtype` flag).
    pub dtype: DType,
}

impl Default for ExpOpts {
    fn default() -> Self {
        ExpOpts {
            subset: 8,
            backend: BackendChoice::Cpu,
            out_dir: "reports".into(),
            shrink: 1,
            dtype: DType::F64,
        }
    }
}

fn lanc_params(o: &ExpOpts) -> Params {
    Params { r: (256 / o.shrink).max(32), p: 2, b: 16, dtype: o.dtype, ..Default::default() }
}

/// The three RandSVD configurations of Fig. 1 (§4.1.1).
fn rand_configs(o: &ExpOpts) -> Vec<(String, Params)> {
    let r_big = (256 / o.shrink).max(32);
    let p32 = (32 / o.shrink).max(4);
    let p96 = (96 / o.shrink).max(12);
    let d = o.dtype;
    let cfg = |r: usize, p: usize| Params { r, p, b: 16, dtype: d, ..Default::default() };
    vec![
        (format!("rand r={r_big} p=2"), cfg(r_big, 2)),
        (format!("rand r=16 p={p32}"), cfg(16, p32)),
        (format!("rand r=16 p={p96}"), cfg(16, p96)),
    ]
}

/// Figure 1: relative residuals R₁ and R₁₀ on the sparse suite for
/// LancSVD (r=256, p=2) and the three RandSVD configurations.
pub fn fig1(suite: &Suite, o: &ExpOpts) -> Result<String> {
    let entries = suite.representative(o.subset.min(suite.sparse.len()));
    let mut t = Table::new(&[
        "matrix", "m", "n", "nnz", "lanc R1", "lanc R10", "rand(rbig,2) R1", "rand(rbig,2) R10",
        "rand(16,p32) R1", "rand(16,p32) R10", "rand(16,p96) R1", "rand(16,p96) R10",
    ]);
    let mut md = String::from("# Fig. 1 — accuracy on the sparse suite (scaled stand-ins)\n\n");
    for e in entries {
        let a = generate(&e.spec);
        let lanc =
            run(&e.name, Operand::sparse(a.clone()), Algo::Lanc, &lanc_params(o), &o.backend)?;
        let mut cells = vec![
            e.name.clone(),
            e.spec.rows.to_string(),
            e.spec.cols.to_string(),
            a.nnz().to_string(),
            sci(lanc.residuals[0]),
            sci(*lanc.residuals.last().unwrap()),
        ];
        for (_, params) in rand_configs(o) {
            let rep = run(&e.name, Operand::sparse(a.clone()), Algo::Rand, &params, &o.backend)?;
            cells.push(sci(rep.residuals[0]));
            cells.push(sci(*rep.residuals.last().unwrap()));
        }
        t.row(cells);
    }
    md.push_str(&t.to_markdown());
    md.push_str(
        "\nPaper check: LancSVD R1 ∈ [1e-14, 1e-8]; RandSVD needs the large-p \
         configuration to approach LancSVD accuracy, and still lags on R10.\n",
    );
    write_file(&format!("{}/fig1_accuracy.md", o.out_dir), &md)?;
    write_file(&format!("{}/fig1_accuracy.csv", o.out_dir), &t.to_csv())?;
    Ok(md)
}

/// Figure 2: execution time + per-block breakdown + LancSVD speed-up over
/// RandSVD(16, 96) on the sparse suite.
pub fn fig2(suite: &Suite, o: &ExpOpts) -> Result<String> {
    let entries = suite.representative(o.subset.min(suite.sparse.len()));
    let mut t = Table::new(&[
        "matrix", "lanc s", "rand s", "speedup", "simA100 speedup", "lanc %mult_At",
        "lanc %orth_m", "rand %mult_At", "rand %orth_m",
    ]);
    let mut md = String::from("# Fig. 2 — execution time and breakdown (sparse suite)\n\n");
    let p96 = (96 / o.shrink).max(12);
    let rand_p = Params { r: 16, p: p96, b: 16, dtype: o.dtype, ..Default::default() };
    let mut wins = 0usize;
    let mut total = 0usize;
    for e in entries {
        let a = generate(&e.spec);
        let lanc =
            run(&e.name, Operand::sparse(a.clone()), Algo::Lanc, &lanc_params(o), &o.backend)?;
        let rand = run(&e.name, Operand::sparse(a), Algo::Rand, &rand_p, &o.backend)?;
        let speedup = rand.secs / lanc.secs;
        // Model time on the paper's platform (kernel-rate asymmetry the
        // scalar CPU testbed lacks — DESIGN.md §3).
        let dm = DeviceModel::a100();
        let sim = dm.sim_time(&rand.profile, true) / dm.sim_time(&lanc.profile, true);
        total += 1;
        if sim > 1.0 {
            wins += 1;
        }
        t.row(vec![
            e.name.clone(),
            secs(lanc.secs),
            secs(rand.secs),
            format!("{speedup:.2}x"),
            format!("{sim:.2}x"),
            format!("{:.0}%", 100.0 * lanc.frac(Block::MultAt)),
            format!("{:.0}%", 100.0 * lanc.frac(Block::OrthM)),
            format!("{:.0}%", 100.0 * rand.frac(Block::MultAt)),
            format!("{:.0}%", 100.0 * rand.frac(Block::OrthM)),
        ]);
    }
    md.push_str(&t.to_markdown());
    md.push_str(&format!(
        "\nLancSVD faster (sim-A100 model time) on {wins}/{total} matrices. Paper \
         check: speed-ups 1.2x-2.5x for most, <1x for a minority; SpMM-with-At \
         and the m-dimension orthogonalization dominate. The measured column is \
         the 1-core CPU substrate (no GPU kernel-rate asymmetry, so RandSVD's \
         fewer flops win there — consistent with the paper's own Fig. 3 analysis).\n"
    ));
    write_file(&format!("{}/fig2_time.md", o.out_dir), &md)?;
    write_file(&format!("{}/fig2_time.csv", o.out_dir), &t.to_csv())?;
    Ok(md)
}

/// Figure 3: theoretical flop distribution across building blocks
/// (pure cost model — runs on the full 46-matrix suite instantly).
pub fn fig3(suite: &Suite, o: &ExpOpts) -> Result<String> {
    let mut t = Table::new(&[
        "matrix", "algo", "total GF", "%mult_A", "%mult_At", "%orth_m", "%orth_n", "%small+fin",
    ]);
    let mut md = String::from("# Fig. 3 — theoretical flop distribution (Table 1 model)\n\n");
    let mut lanc_total = 0.0;
    let mut rand_total = 0.0;
    for e in &suite.sparse {
        let prob = Problem { m: e.spec.rows, n: e.spec.cols, nnz: Some(e.spec.nnz) };
        for (algo, c) in [
            ("lanc(256,2)", cost::lancsvd_cost(prob, 256, 2, 16)),
            ("rand(16,96)", cost::randsvd_cost(prob, 16, 96, 16)),
        ] {
            let tot = c.total();
            if algo.starts_with("lanc") {
                lanc_total += tot;
            } else {
                rand_total += tot;
            }
            t.row(vec![
                e.name.clone(),
                algo.to_string(),
                format!("{:.2}", tot / 1e9),
                format!("{:.0}%", 100.0 * c.mult_a / tot),
                format!("{:.0}%", 100.0 * c.mult_at / tot),
                format!("{:.0}%", 100.0 * c.orth_m / tot),
                format!("{:.0}%", 100.0 * c.orth_n / tot),
                format!("{:.0}%", 100.0 * (c.small_svd + c.finalize) / tot),
            ]);
        }
    }
    md.push_str(&t.to_markdown());
    md.push_str(&format!(
        "\nSuite-aggregate flops: LancSVD {:.1} GF vs RandSVD {:.1} GF — paper \
         check: RandSVD requires *fewer* flops yet (Fig. 2) runs slower, because \
         its flops concentrate in the slow transposed SpMM (96 vs 2 products \
         with Aᵀ per the configurations above).\n",
        lanc_total / 1e9,
        rand_total / 1e9
    ));
    write_file(&format!("{}/fig3_flops.md", o.out_dir), &md)?;
    write_file(&format!("{}/fig3_flops.csv", o.out_dir), &t.to_csv())?;
    Ok(md)
}

/// Figure 4: dense problems — residuals R₁..R₁₀ and execution time for
/// LancSVD (r=64, p∈{1,4}) vs RandSVD (r=16, p∈{6,24}).
pub fn fig4(suite: &Suite, o: &ExpOpts) -> Result<String> {
    let mut t = Table::new(&["m", "config", "time s", "R1", "R5", "R10"]);
    let mut md = String::from("# Fig. 4 — dense synthetic problems (Eq. 15/16 spectrum)\n\n");
    let cfg = |r: usize, p: usize| Params { r, p, b: 16, dtype: o.dtype, ..Default::default() };
    let configs: Vec<(Algo, String, Params)> = vec![
        (Algo::Lanc, "lanc r=64 p=1".into(), cfg(64, 1)),
        (Algo::Lanc, "lanc r=64 p=4".into(), cfg(64, 4)),
        (Algo::Rand, "rand r=16 p=6".into(), cfg(16, 6)),
        (Algo::Rand, "rand r=16 p=24".into(), cfg(16, 24)),
    ];
    for e in &suite.dense {
        let (m, n) = (e.rows / o.shrink, e.cols.min(e.rows / o.shrink));
        let prob = paper_dense(m, n, e.seed);
        for (algo, label, params) in &configs {
            let rep = run(&e.name, Operand::Dense(prob.a.clone()), *algo, params, &o.backend)?;
            t.row(vec![
                m.to_string(),
                label.clone(),
                secs(rep.secs),
                sci(rep.residuals[0]),
                sci(rep.residuals[4]),
                sci(rep.residuals[9]),
            ]);
        }
    }
    md.push_str(&t.to_markdown());
    md.push_str(
        "\nPaper check: one LancSVD sweep reaches ~1e-6..1e-4; RandSVD needs \
         p=6 to match. Four LancSVD sweeps reach ~1e-14; RandSVD needs p=24 — \
         a ~6x higher iteration count at matched accuracy, and correspondingly \
         longer runtime.\n",
    );
    write_file(&format!("{}/fig4_dense.md", o.out_dir), &md)?;
    write_file(&format!("{}/fig4_dense.csv", o.out_dir), &t.to_csv())?;
    Ok(md)
}

/// Table 1 validation: the analytic cost model must equal the
/// instrumentation counters recorded by a live run, step for step.
pub fn table1(o: &ExpOpts) -> Result<String> {
    let spec = crate::gen::sparse::SparseSpec {
        rows: 2000,
        cols: 900,
        nnz: 30_000,
        seed: 77,
        ..Default::default()
    };
    let a = generate(&spec);
    let prob = Problem { m: 2000, n: 900, nnz: Some(a.nnz()) };
    let mut md = String::from("# Table 1 — analytic cost model vs instrumented counters\n\n");
    let mut t = Table::new(&["algo", "block", "model GF", "measured GF", "ratio"]);
    let cases = [
        (Algo::Lanc, Params { r: 64, p: 2, b: 16, dtype: o.dtype, ..Default::default() }),
        (Algo::Rand, Params { r: 16, p: 8, b: 16, dtype: o.dtype, ..Default::default() }),
    ];
    let mut worst: f64 = 1.0;
    for (algo, params) in cases {
        let c = match algo {
            Algo::Lanc => cost::lancsvd_cost(prob, params.r, params.p, params.b),
            Algo::Rand => cost::randsvd_cost(prob, params.r, params.p, params.b),
        };
        let rep = run("model-check", Operand::sparse(a.clone()), algo, &params, &BackendChoice::Cpu)?;
        let pairs = [
            ("mult_A", c.mult_a, rep.profile.stat(Block::MultA).flops),
            ("mult_At", c.mult_at, rep.profile.stat(Block::MultAt).flops),
            ("orth_m", c.orth_m, rep.profile.stat(Block::OrthM).flops),
            ("orth_n", c.orth_n, rep.profile.stat(Block::OrthN).flops),
        ];
        for (name, model, meas) in pairs {
            let ratio = if model > 0.0 { meas / model } else { 1.0 };
            worst = worst.max(ratio.max(1.0 / ratio.max(1e-300)));
            t.row(vec![
                algo.name().into(),
                name.into(),
                format!("{:.4}", model / 1e9),
                format!("{:.4}", meas / 1e9),
                format!("{ratio:.3}"),
            ]);
        }
    }
    md.push_str(&t.to_markdown());
    md.push_str(&format!(
        "\nWorst model/measured deviation: {:.1}% (init orthonormalization and \
         the tiny host factorizations account for the residual gap).\n",
        100.0 * (worst - 1.0)
    ));
    write_file(&format!("{}/table1_cost.md", o.out_dir), &md)?;
    Ok(md)
}

/// Table 2: the suite registry (paper dims vs scaled stand-ins).
pub fn table2(suite: &Suite, o: &ExpOpts) -> Result<String> {
    let mut t = Table::new(&[
        "matrix", "paper rows", "paper cols", "paper nnz", "rows", "cols", "nnz", "skew",
    ]);
    for e in &suite.sparse {
        t.row(vec![
            e.name.clone(),
            e.paper_rows.to_string(),
            e.paper_cols.to_string(),
            e.paper_nnz.to_string(),
            e.spec.rows.to_string(),
            e.spec.cols.to_string(),
            e.spec.nnz.to_string(),
            format!("{:.1}", e.spec.skew),
        ]);
    }
    let mut md = String::from("# Table 2 — sparse suite (paper dims → scaled stand-ins)\n\n");
    md.push_str(&t.to_markdown());
    write_file(&format!("{}/table2_suite.md", o.out_dir), &md)?;
    write_file(&format!("{}/table2_suite.csv", o.out_dir), &t.to_csv())?;
    Ok(md)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_opts() -> ExpOpts {
        ExpOpts {
            subset: 1,
            backend: BackendChoice::Cpu,
            out_dir: std::env::temp_dir()
                .join("trunksvd_exp_tests")
                .to_string_lossy()
                .into_owned(),
            shrink: 8,
            ..Default::default()
        }
    }

    #[test]
    fn fig3_and_table2_full_suite_fast() {
        let suite = Suite::load_default().unwrap();
        let o = tiny_opts();
        let md3 = fig3(&suite, &o).unwrap();
        assert!(md3.contains("relat9"));
        assert!(md3.contains("RandSVD requires *fewer* flops"));
        let md2 = table2(&suite, &o).unwrap();
        assert_eq!(md2.matches('\n').count() > 46, true);
    }

    #[test]
    fn table1_model_matches_counters() {
        let o = tiny_opts();
        let md = table1(&o).unwrap();
        // Every ratio row must be ~1.00 (the model and the instrumentation
        // share formulas, so only init/guard work can diverge).
        for line in md.lines().filter(|l| l.contains("mult_")) {
            let ratio: f64 = line
                .rsplit('|')
                .nth(1)
                .unwrap()
                .trim()
                .parse()
                .unwrap();
            assert!((ratio - 1.0).abs() < 0.05, "line: {line}");
        }
    }

    #[test]
    fn fig1_smoke_single_matrix() {
        let suite = Suite::load_default().unwrap();
        let o = tiny_opts();
        let md = fig1(&suite, &o).unwrap();
        assert!(md.contains("lanc R1"));
    }
}
