//! Command-line interface (hand-rolled; no clap in the offline vendor
//! set).
//!
//! ```text
//! trunksvd info
//! trunksvd suite --list
//! trunksvd gen --name rel8 --out rel8.mtx
//! trunksvd shard --mtx F.mtx --out DIR [--shards N] [--resident-cap BYTES]
//! trunksvd solve (--suite NAME | --mtx FILE | --dense M N | --operand-shards DIR) \
//!                [--resident-cap BYTES] \
//!                [--algo lanc|rand] [--r R] [--p P] [--b B] [--seed S] \
//!                [--tol T] [--wanted K] [--dtype f32|f64] \
//!                [--backend cpu|cpu-scatter|cpu-expt|staged|xla]
//! trunksvd experiment fig1|fig2|fig3|fig4|table1|table2|all \
//!                [--subset N] [--shrink S] [--out DIR] [--dtype f32|f64] \
//!                [--backend ...]
//! trunksvd serve [--workers N] [--queue-cap N] [--backend cpu|...|staged] \
//!                [--deadline-ms MS] [--socket PATH]
//! trunksvd serve --replay config/workloads/W.json [--out BENCH_serve.json] \
//!                [--repeat N] [--workers N] [--queue-cap N]
//! ```

use std::collections::HashMap;
use std::rc::Rc;

use crate::backend::Operand;
use crate::coordinator::driver::{run, Algo, BackendChoice, Params, SendBackendChoice};
use crate::coordinator::experiments::{self, ExpOpts};
use crate::coordinator::report::sci;
use crate::error::{Error, Result};
use crate::gen::dense::paper_dense;
use crate::gen::sparse::generate;
use crate::gen::suite::Suite;
use crate::metrics::Block;
use crate::runtime::{default_artifact_dir, Runtime};
use crate::util::scalar::DType;

/// Parsed flags: positional args + `--key value` / `--flag` options.
#[derive(Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    pub flags: HashMap<String, String>,
}

pub fn parse_args<I: Iterator<Item = String>>(it: I) -> Result<Args> {
    let mut a = Args::default();
    let mut it = it.peekable();
    while let Some(tok) = it.next() {
        if let Some(key) = tok.strip_prefix("--") {
            let val = match it.peek() {
                Some(v) if !v.starts_with("--") => it.next().unwrap(),
                _ => "true".to_string(),
            };
            a.flags.insert(key.to_string(), val);
        } else {
            a.positional.push(tok);
        }
    }
    Ok(a)
}

impl Args {
    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }
    pub fn get_usize(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| Error::Parse {
                what: "cli",
                detail: format!("--{key} expects an integer, got '{v}'"),
            }),
        }
    }
    pub fn get_f64(&self, key: &str) -> Result<Option<f64>> {
        match self.get(key) {
            None => Ok(None),
            Some(v) => v
                .parse()
                .map(Some)
                .map_err(|_| Error::Parse {
                    what: "cli",
                    detail: format!("--{key} expects a number, got '{v}'"),
                }),
        }
    }
}

fn backend_choice(args: &Args) -> Result<BackendChoice> {
    match args.get("backend").unwrap_or("cpu") {
        "cpu" => Ok(BackendChoice::Cpu),
        "cpu-scatter" => Ok(BackendChoice::CpuScatter),
        "cpu-expt" => Ok(BackendChoice::CpuExplicitT),
        "staged" => Ok(BackendChoice::Staged),
        "xla" => {
            let rt = Runtime::new(&default_artifact_dir())?;
            Ok(BackendChoice::Xla(Rc::new(rt)))
        }
        other => Err(Error::Parse {
            what: "cli",
            detail: format!("unknown backend '{other}' (cpu|cpu-scatter|cpu-expt|staged|xla)"),
        }),
    }
}

const USAGE: &str = "usage: trunksvd <info|suite|gen|shard|solve|experiment> [options]
  info                         versions, artifact inventory
  suite --list                 print the Table-2 suite registry
  gen --name M --out F.mtx     generate a suite matrix to MatrixMarket
  shard --mtx F.mtx --out DIR  stream-convert to an out-of-core shard dir
        [--shards N] [--resident-cap BYTES]   (N defaults from the cost model)
  solve --suite NAME | --mtx FILE | --dense M N | --operand-shards DIR
        [--resident-cap BYTES]  out-of-core host-RAM budget (0 = unlimited)
        [--algo lanc|rand] [--r R] [--p P] [--b B] [--seed S]
        [--tol T] [--wanted K] [--restart basic|thick] [--keep K]
        [--dtype f32|f64] [--backend cpu|cpu-scatter|cpu-expt|staged|xla]
  experiment fig1|fig2|fig3|fig4|table1|table2|all
        [--subset N] [--shrink S] [--out DIR] [--dtype f32|f64] [--backend ...]
  serve [--workers N] [--queue-cap N] [--backend cpu|cpu-scatter|cpu-expt|staged]
        [--deadline-ms MS] [--socket PATH]
        line-delimited JSON jobs on stdin (or the unix socket), results out;
        see rust/src/runtime/serve.rs for the job schema; streaming tenants
        via {\"kind\": \"append\"|\"query\"|\"finalize\", \"stream\": NAME, \"cols\": C}
        keep a warm incremental-SVD basis per stream between jobs
  serve --replay config/workloads/W.json [--out BENCH_serve.json]
        [--repeat N] [--workers N] [--queue-cap N] [--backend ...]
        replay a committed workload against one warm server and write
        per-job latency / reuse-rate metrics (BENCH_ASSERT_REUSE=1 gates);
        workloads with append jobs also get an accuracy-vs-staleness audit
        against a from-scratch solve of each stream prefix";

/// Run the CLI; returns the process exit code.
pub fn main_with_args(argv: Vec<String>) -> i32 {
    match dispatch(argv) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e}");
            eprintln!("{USAGE}");
            1
        }
    }
}

fn dispatch(argv: Vec<String>) -> Result<()> {
    let args = parse_args(argv.into_iter())?;
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    match cmd {
        "info" => cmd_info(),
        "suite" => cmd_suite(),
        "gen" => cmd_gen(&args),
        "shard" => cmd_shard(&args),
        "solve" => cmd_solve(&args),
        "serve" => cmd_serve(&args),
        "experiment" => cmd_experiment(&args),
        "help" | "--help" => {
            println!("{USAGE}");
            Ok(())
        }
        other => Err(Error::Parse { what: "cli", detail: format!("unknown command '{other}'") }),
    }
}

fn cmd_info() -> Result<()> {
    println!("trunksvd {}", crate::version());
    let dir = default_artifact_dir();
    match Runtime::new(&dir) {
        Ok(rt) => println!(
            "artifacts: {} entries at {dir} (platform {})",
            rt.artifact_count(),
            rt.platform_name()
        ),
        Err(e) => println!("artifacts: unavailable ({e})"),
    }
    let suite = Suite::load_default()?;
    println!("suite: {} sparse + {} dense problems", suite.sparse.len(), suite.dense.len());
    Ok(())
}

fn cmd_suite() -> Result<()> {
    let suite = Suite::load_default()?;
    let o = ExpOpts::default();
    print!("{}", experiments::table2(&suite, &o)?);
    Ok(())
}

fn cmd_gen(args: &Args) -> Result<()> {
    let suite = Suite::load_default()?;
    let name = args.get("name").ok_or(Error::Parse {
        what: "cli",
        detail: "gen requires --name".into(),
    })?;
    let out = args.get("out").ok_or(Error::Parse {
        what: "cli",
        detail: "gen requires --out".into(),
    })?;
    let e = suite.sparse_by_name(name).ok_or(Error::Parse {
        what: "cli",
        detail: format!("unknown suite matrix '{name}'"),
    })?;
    let a = generate(&e.spec);
    crate::sparse::mm::write_csr(out, &a)?;
    println!("wrote {name} ({}x{}, nnz {}) to {out}", a.rows(), a.cols(), a.nnz());
    Ok(())
}

/// `shard`: stream-convert a MatrixMarket file into an out-of-core
/// row-band shard directory (`sparse::shard`). Shard count defaults
/// from the cost model's disk-tier sizing ([`DeviceModel::shard_count`])
/// given the operand's on-disk footprint and the `--resident-cap`
/// budget the solve will run under.
fn cmd_shard(args: &Args) -> Result<()> {
    let mtx = args
        .get("mtx")
        .ok_or(Error::Parse { what: "cli", detail: "shard requires --mtx".into() })?;
    let out = args
        .get("out")
        .ok_or(Error::Parse { what: "cli", detail: "shard requires --out".into() })?;
    let cap = args.get_usize("resident-cap", 0)?;
    let shards = match args.get("shards") {
        Some(_) => args.get_usize("shards", 0)?.max(1),
        None => {
            let h = crate::sparse::mm::MmStream::open(mtx)?.header();
            // Emitted entries (symmetric files expand ≤ 2×); per-entry
            // shard-file cost is one u32 index + one f64 value, plus the
            // u64 row-pointer array.
            let nnz = if h.symmetric { 2 * h.entries } else { h.entries };
            let total = 8 * (h.rows + 1) + 12 * nnz;
            crate::cost::device::DeviceModel::a100().shard_count(total, cap)
        }
    };
    let sd = crate::sparse::shard::convert_mtx_to_shards(mtx, out, shards)?;
    println!(
        "wrote {} shard(s) ({}x{}, nnz {}, {} file bytes) to {out}",
        sd.num_shards(),
        sd.rows(),
        sd.cols(),
        sd.nnz(),
        sd.total_file_bytes()
    );
    Ok(())
}

fn cmd_solve(args: &Args) -> Result<()> {
    let suite = Suite::load_default()?;
    let (name, op): (String, Operand) = if let Some(d) = args.get("operand-shards") {
        let dir = crate::sparse::shard::ShardDir::open(d)?;
        let cap = args.get_usize("resident-cap", 0)?;
        (d.to_string(), Operand::sharded(std::sync::Arc::new(dir), cap))
    } else if let Some(n) = args.get("suite") {
        let e = suite.sparse_by_name(n).ok_or(Error::Parse {
            what: "cli",
            detail: format!("unknown suite matrix '{n}'"),
        })?;
        (n.to_string(), Operand::sparse(generate(&e.spec)))
    } else if let Some(f) = args.get("mtx") {
        (f.to_string(), Operand::sparse(crate::sparse::mm::read_csr(f)?))
    } else if args.get("dense").is_some() {
        let m = args.get_usize("dense", 0)?;
        let n = args.get_usize("n", 500.min(m))?;
        (format!("dense{m}x{n}"), Operand::Dense(paper_dense(m, n, 42).a))
    } else {
        return Err(Error::Parse {
            what: "cli",
            detail: "solve requires --suite, --mtx, or --dense".into(),
        });
    };

    let algo = match args.get("algo").unwrap_or("lanc") {
        "lanc" => Algo::Lanc,
        "rand" => Algo::Rand,
        other => {
            return Err(Error::Parse {
                what: "cli",
                detail: format!("unknown algo '{other}'"),
            })
        }
    };
    let restart = match args.get("restart").unwrap_or("basic") {
        "basic" => crate::algo::Restart::Basic,
        "thick" => crate::algo::Restart::Thick {
            keep: args.get_usize("keep", 32)?,
        },
        other => {
            return Err(Error::Parse {
                what: "cli",
                detail: format!("unknown restart '{other}' (basic|thick)"),
            })
        }
    };
    let dtype = match args.get("dtype") {
        None => suite.default_dtype,
        Some(tag) => DType::parse(tag).ok_or(Error::Parse {
            what: "cli",
            detail: format!("unknown dtype '{tag}' (f32|f64)"),
        })?,
    };
    let params = Params {
        r: args.get_usize("r", if algo == Algo::Lanc { 256 } else { 16 })?,
        p: args.get_usize("p", if algo == Algo::Lanc { 2 } else { 96 })?,
        b: args.get_usize("b", 16)?,
        seed: args.get_usize("seed", 0xC0FFEE)? as u64,
        tol: args.get_f64("tol")?,
        wanted: args.get_usize("wanted", 10)?,
        restart,
        dtype,
    };
    let choice = backend_choice(args)?;
    let rep = run(&name, op, algo, &params, &choice)?;
    println!("{}", rep.summary());
    println!("  sigma: {}", rep.sigma.iter().map(|s| sci(*s)).collect::<Vec<_>>().join(" "));
    println!(
        "  residuals: {}",
        rep.residuals.iter().map(|r| sci(*r)).collect::<Vec<_>>().join(" ")
    );
    println!("  breakdown:");
    for b in Block::ALL {
        let s = rep.profile.stat(b);
        if s.calls > 0 {
            println!(
                "    {:<10} {:>8.3}s  {:>10.2} GF  {:>6} calls",
                b.name(),
                s.secs,
                s.flops / 1e9,
                s.calls
            );
        }
    }
    Ok(())
}

/// `serve`: the long-running multi-tenant solve service
/// (`runtime::serve`) — either interactive (line-delimited JSON jobs on
/// stdin or a unix socket) or replaying a committed workload file with
/// metrics output.
fn cmd_serve(args: &Args) -> Result<()> {
    use crate::runtime::serve::{
        replay_file, serve_connection, JobDefaults, ProtocolState, ReplayOverrides, ServeConfig,
        Server,
    };

    let backend = match args.get("backend") {
        None => SendBackendChoice::Cpu,
        Some("xla") => {
            return Err(Error::Parse {
                what: "cli",
                detail: "serve needs a Send backend (cpu|cpu-scatter|cpu-expt|staged); \
                         the xla backend is bound to its creating thread"
                    .into(),
            })
        }
        Some(tag) => SendBackendChoice::parse(tag).ok_or(Error::Parse {
            what: "cli",
            detail: format!("unknown backend '{tag}' (cpu|cpu-scatter|cpu-expt|staged)"),
        })?,
    };

    // Present-only flag → Some(parsed), absent → None (workload file or
    // ServeConfig default wins).
    let opt_usize = |key: &str| -> Result<Option<usize>> {
        match args.get(key) {
            None => Ok(None),
            Some(_) => args.get_usize(key, 0).map(Some),
        }
    };

    if let Some(workload) = args.get("replay") {
        let ov = ReplayOverrides {
            workers: opt_usize("workers")?,
            queue_cap: opt_usize("queue-cap")?,
            repeat: opt_usize("repeat")?,
            backend: args.get("backend").map(|_| backend),
        };
        let out = args.get("out").unwrap_or("BENCH_serve.json");
        let s = replay_file(workload, Some(out), &ov)?;
        let c = s.counters;
        println!(
            "replayed {workload}: {} run(s) x {} job(s) in {:.3}s \
             (ok {}, failed {}, rejected {}, bitwise_identical {})",
            s.runs,
            s.jobs_per_run,
            s.wall_secs,
            c.completed,
            c.failed,
            c.rejected_backpressure + c.rejected_deadline,
            s.deterministic,
        );
        println!(
            "  reuse: operand hits {}/{} (rework {}), warm workspaces {}/{}, \
             restart yields {}",
            c.operand_hits,
            c.operand_hits + c.operand_misses,
            c.operand_rework,
            c.ws_warm_reuses,
            c.ws_warm_reuses + c.ws_created,
            c.restart_yields,
        );
        if s.staleness_appends > 0 {
            println!(
                "  staleness: {} append(s) audited, max rel sigma err {:.3e} \
                 (tolerance 1e-4, within_tolerance {})",
                s.staleness_appends, s.staleness_max_rel, s.staleness_ok,
            );
        }
        println!("  report: {out}");
        return Ok(());
    }

    let cfg = ServeConfig {
        solvers: args.get_usize("workers", 2)?,
        queue_cap: args.get_usize("queue-cap", 16)?,
        backend,
        default_deadline: args
            .get_f64("deadline-ms")?
            .map(|ms| std::time::Duration::from_secs_f64(ms.max(0.0) / 1e3)),
        max_free_ws_per_class: args.get_usize("ws-per-class", 4)?,
    };
    let mut server = Server::new(cfg);
    let defaults = JobDefaults::default();

    if let Some(sock) = args.get("socket") {
        #[cfg(unix)]
        {
            eprintln!("serving on unix socket {sock}");
            crate::runtime::serve::serve_unix(&server, sock, &defaults)?;
            server.shutdown();
            return Ok(());
        }
        #[cfg(not(unix))]
        return Err(Error::Parse {
            what: "cli",
            detail: format!("--socket {sock} needs a unix platform; use stdin mode"),
        });
    }

    let st = ProtocolState::new();
    let stdin = std::io::stdin();
    let mut stdout = std::io::stdout();
    serve_connection(&server, &st, &defaults, stdin.lock(), &mut stdout)?;
    server.shutdown();
    let c = server.counters();
    eprintln!(
        "served {} job(s): ok {}, failed {}, rejected {}; operand hits {}, \
         warm workspaces {}",
        c.submitted,
        c.completed,
        c.failed,
        c.rejected_backpressure + c.rejected_deadline,
        c.operand_hits,
        c.ws_warm_reuses,
    );
    Ok(())
}

fn cmd_experiment(args: &Args) -> Result<()> {
    let which = args.positional.get(1).map(|s| s.as_str()).unwrap_or("all");
    let suite = Suite::load_default()?;
    let dtype = match args.get("dtype") {
        None => suite.default_dtype,
        Some(tag) => DType::parse(tag).ok_or(Error::Parse {
            what: "cli",
            detail: format!("unknown dtype '{tag}' (f32|f64)"),
        })?,
    };
    let o = ExpOpts {
        subset: args.get_usize("subset", 8)?,
        backend: backend_choice(args)?,
        out_dir: args.get("out").unwrap_or("reports").to_string(),
        shrink: args.get_usize("shrink", 1)?.max(1),
        dtype,
    };
    let mut ran = false;
    for (id, f) in [
        ("fig1", experiments::fig1 as fn(&Suite, &ExpOpts) -> Result<String>),
        ("fig2", experiments::fig2),
        ("fig3", experiments::fig3),
        ("fig4", experiments::fig4),
        ("table2", experiments::table2),
    ] {
        if which == id || which == "all" {
            println!("{}", f(&suite, &o)?);
            ran = true;
        }
    }
    if which == "table1" || which == "all" {
        println!("{}", experiments::table1(&o)?);
        ran = true;
    }
    if !ran {
        return Err(Error::Parse {
            what: "cli",
            detail: format!("unknown experiment '{which}'"),
        });
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> Vec<String> {
        s.split_whitespace().map(|t| t.to_string()).collect()
    }

    #[test]
    fn parse_flags_and_positionals() {
        let a = parse_args(argv("solve --r 64 --tol 1e-8 --verbose").into_iter()).unwrap();
        assert_eq!(a.positional, vec!["solve"]);
        assert_eq!(a.get("r"), Some("64"));
        assert_eq!(a.get_usize("r", 0).unwrap(), 64);
        assert_eq!(a.get_f64("tol").unwrap(), Some(1e-8));
        assert_eq!(a.get("verbose"), Some("true"));
        assert!(a.get_usize("tol", 0).is_err());
    }

    #[test]
    fn unknown_command_fails() {
        assert_eq!(main_with_args(argv("frobnicate")), 1);
    }

    #[test]
    fn info_and_suite_commands_run() {
        assert_eq!(main_with_args(argv("help")), 0);
        assert_eq!(main_with_args(argv("info")), 0);
    }

    #[test]
    fn solve_tiny_dense() {
        assert_eq!(
            main_with_args(argv("solve --dense 600 --n 64 --algo lanc --r 32 --p 2 --wanted 5")),
            0
        );
    }

    #[test]
    fn solve_tiny_dense_staged_backend() {
        assert_eq!(
            main_with_args(argv(
                "solve --dense 300 --n 32 --algo lanc --r 16 --p 2 --wanted 4 --backend staged"
            )),
            0
        );
        assert_eq!(
            main_with_args(argv("solve --dense 100 --n 16 --backend warp")),
            1,
            "unknown backend must be rejected"
        );
    }

    #[test]
    fn shard_then_solve_out_of_core() {
        let base = std::env::temp_dir().join("trunksvd_cli_shard_test");
        let _ = std::fs::remove_dir_all(&base);
        std::fs::create_dir_all(&base).unwrap();
        let mtx = base.join("a.mtx");
        let shards = base.join("shards");
        let spec = crate::gen::sparse::SparseSpec {
            rows: 220,
            cols: 90,
            nnz: 2600,
            seed: 5,
            ..Default::default()
        };
        crate::sparse::mm::write_csr(mtx.to_str().unwrap(), &generate(&spec)).unwrap();
        // Explicit shard count.
        assert_eq!(
            main_with_args(argv(&format!(
                "shard --mtx {} --out {} --shards 3",
                mtx.display(),
                shards.display()
            ))),
            0
        );
        // Solve out-of-core under a cap, both backends that support it.
        for backend in ["cpu", "staged"] {
            assert_eq!(
                main_with_args(argv(&format!(
                    "solve --operand-shards {} --resident-cap 1000000 --algo lanc \
                     --r 16 --p 2 --wanted 4 --backend {backend}",
                    shards.display()
                ))),
                0,
                "backend {backend}"
            );
        }
        // cpu-expt cannot build its transpose out-of-core.
        assert_eq!(
            main_with_args(argv(&format!(
                "solve --operand-shards {} --backend cpu-expt",
                shards.display()
            ))),
            1
        );
        // Model-driven default shard count also works.
        let shards2 = base.join("shards2");
        assert_eq!(
            main_with_args(argv(&format!(
                "shard --mtx {} --out {}",
                mtx.display(),
                shards2.display()
            ))),
            0
        );
        let _ = std::fs::remove_dir_all(&base);
    }

    #[test]
    fn solve_tiny_dense_f32() {
        assert_eq!(
            main_with_args(argv(
                "solve --dense 400 --n 48 --algo lanc --r 16 --p 2 --wanted 4 --dtype f32"
            )),
            0
        );
        assert_eq!(
            main_with_args(argv("solve --dense 100 --n 16 --dtype bf16")),
            1,
            "unknown dtype must be rejected"
        );
    }
}
