//! Synthetic sparse test-matrix generator (SuiteSparse stand-in).
//!
//! We cannot download the paper's Table 2 matrices offline, so each one is
//! replaced by a deterministic synthetic matrix that preserves the
//! characteristics the experiments are sensitive to (see DESIGN.md §3):
//!
//! * aspect ratio and density (scaled dims + nnz from `config/suite.json`);
//! * row-degree skew — a Zipf-like row-degree profile whose exponent is
//!   per-matrix (`skew`), so matrices like `specular`/`rail*` get the
//!   close-to-dense rows the paper calls out;
//! * a decaying singular spectrum — values are `d_r[i] · g · d_c[j]` with
//!   log-uniform row/column scalings spanning `value_decay` decades, which
//!   produces a wide, decaying spectrum (the regime where LancSVD's
//!   superlinear convergence vs. subspace iteration shows, Fig. 1).

use crate::la::mat::Mat;
use crate::sparse::coo::Coo;
use crate::sparse::csr::Csr;
use crate::util::rng::Rng;

/// Parameters for one synthetic sparse matrix.
#[derive(Clone, Debug)]
pub struct SparseSpec {
    pub rows: usize,
    pub cols: usize,
    pub nnz: usize,
    pub seed: u64,
    /// Zipf exponent for row degrees (0 = uniform; ~1.5 = heavy tail).
    pub skew: f64,
    /// Decades spanned by the row/col value scalings (spectrum spread).
    pub value_decay: f64,
}

impl Default for SparseSpec {
    fn default() -> Self {
        SparseSpec { rows: 1000, cols: 500, nnz: 8000, seed: 1, skew: 0.8, value_decay: 3.0 }
    }
}

/// Generate the matrix for a spec. Deterministic in `seed`.
pub fn generate(spec: &SparseSpec) -> Csr {
    let mut rng = Rng::new(spec.seed);
    let SparseSpec { rows, cols, nnz, skew, value_decay, .. } = *spec;
    assert!(rows > 0 && cols > 0);
    let nnz = nnz.min(rows * cols / 2).max(rows.max(cols));

    // Row degree profile ~ (i+1)^-skew, shuffled, normalized to sum nnz.
    let mut weights: Vec<f64> = (0..rows).map(|i| 1.0 / ((i + 1) as f64).powf(skew)).collect();
    // Shuffle so heavy rows are scattered (Fisher–Yates).
    for i in (1..rows).rev() {
        let j = rng.below(i + 1);
        weights.swap(i, j);
    }
    let wsum: f64 = weights.iter().sum();
    let mut degrees: Vec<usize> = weights
        .iter()
        .map(|w| ((w / wsum) * nnz as f64).round() as usize)
        .collect();
    // Clamp degrees to the column count and fix the total.
    for d in degrees.iter_mut() {
        *d = (*d).min(cols);
    }
    let mut total: usize = degrees.iter().sum();
    let mut i = 0;
    while total < nnz {
        if degrees[i % rows] < cols {
            degrees[i % rows] += 1;
            total += 1;
        }
        i += 1;
        if i > 4 * rows * 4 {
            break;
        }
    }
    while total > nnz {
        if degrees[i % rows] > 0 {
            degrees[i % rows] -= 1;
            total -= 1;
        }
        i += 1;
    }

    // Log-uniform row/column scalings spanning `value_decay` decades.
    // Column scales are quantized to a coarse grid (quarter-decades):
    // real SuiteSparse spectra contain *clusters* of near-equal singular
    // values, the regime where plain subspace iteration stalls and the
    // paper's block-Lanczos (with b >= cluster size) keeps converging.
    let row_scale: Vec<f64> = (0..rows)
        .map(|_| 10f64.powf(-value_decay * rng.uniform()))
        .collect();
    let col_scale: Vec<f64> = (0..cols)
        .map(|_| {
            let e = (-value_decay * rng.uniform() * 4.0).round() / 4.0;
            10f64.powf(e)
        })
        .collect();

    let mut coo = Coo::new(rows, cols);
    let mut mark = vec![u32::MAX; cols];
    for (r, &deg) in degrees.iter().enumerate() {
        if deg == 0 {
            continue;
        }
        if deg * 3 >= cols {
            // Dense-ish row: sample without replacement via partial shuffle.
            let mut idx: Vec<u32> = (0..cols as u32).collect();
            for k in 0..deg {
                let j = k + rng.below(cols - k);
                idx.swap(k, j);
            }
            for &c in &idx[..deg] {
                let v = row_scale[r] * col_scale[c as usize] * rng.normal();
                coo.push(r, c as usize, v);
            }
        } else {
            // Sparse row: rejection sampling with an epoch-marked bitmap.
            let mut placed = 0;
            while placed < deg {
                let c = rng.below(cols);
                if mark[c] == r as u32 {
                    continue;
                }
                mark[c] = r as u32;
                let v = row_scale[r] * col_scale[c] * rng.normal();
                coo.push(r, c, v);
                placed += 1;
            }
        }
    }
    Csr::from_coo(&coo).expect("generator produced valid coo")
}

/// Dense copy helper used by small-scale validation tests.
pub fn generate_dense_copy(spec: &SparseSpec) -> (Csr, Mat) {
    let a = generate(spec);
    let d = a.to_dense();
    (a, d)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_and_shaped() {
        let spec = SparseSpec { rows: 200, cols: 90, nnz: 1500, seed: 42, ..Default::default() };
        let a = generate(&spec);
        let b = generate(&spec);
        assert_eq!((a.rows(), a.cols()), (200, 90));
        assert_eq!(a.nnz(), b.nnz());
        assert!(a.to_dense().max_abs_diff(&b.to_dense()) < 1e-15);
        // nnz within rounding of the request
        assert!((a.nnz() as isize - 1500).unsigned_abs() < 32, "nnz {}", a.nnz());
    }

    #[test]
    fn skew_creates_heavy_rows() {
        let flat = generate(&SparseSpec { rows: 300, cols: 200, nnz: 3000, seed: 1, skew: 0.0, ..Default::default() });
        let skewed = generate(&SparseSpec { rows: 300, cols: 200, nnz: 3000, seed: 1, skew: 1.5, ..Default::default() });
        let max_deg = |a: &Csr| (0..a.rows()).map(|i| a.row(i).0.len()).max().unwrap();
        assert!(max_deg(&skewed) > 2 * max_deg(&flat), "{} vs {}", max_deg(&skewed), max_deg(&flat));
    }

    #[test]
    fn no_duplicate_columns_within_rows() {
        let a = generate(&SparseSpec { rows: 120, cols: 40, nnz: 2000, seed: 3, ..Default::default() });
        for i in 0..a.rows() {
            let (cols, _) = a.row(i);
            for w in cols.windows(2) {
                assert!(w[0] < w[1], "row {i} has duplicate/unsorted cols");
            }
        }
    }

    #[test]
    fn value_decay_spreads_magnitudes() {
        let a = generate(&SparseSpec { rows: 400, cols: 200, nnz: 4000, seed: 5, value_decay: 6.0, ..Default::default() });
        let mags: Vec<f64> = a.values().iter().map(|v| v.abs()).filter(|&v| v > 0.0).collect();
        let max = mags.iter().cloned().fold(0.0, f64::max);
        let min = mags.iter().cloned().fold(f64::INFINITY, f64::min);
        assert!(max / min > 1e6, "spread {:.1e}", max / min);
    }
}
