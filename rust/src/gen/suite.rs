//! Experiment-suite registry (the scaled Table 2 + dense suite).
//!
//! `config/suite.json` is the single source of truth shared with the
//! python AOT pipeline: the 46 sparse stand-ins (name, paper dims, scaled
//! dims, nnz, generator seed/skew) and the 4 dense problems, plus the
//! artifact shape buckets.

use crate::error::{Error, Result};
use crate::gen::sparse::SparseSpec;
use crate::util::json::{self, Json};
use crate::util::scalar::DType;

/// One sparse suite entry.
#[derive(Clone, Debug)]
pub struct SparseEntry {
    pub name: String,
    pub paper_rows: usize,
    pub paper_cols: usize,
    pub paper_nnz: usize,
    pub spec: SparseSpec,
}

/// One dense suite entry.
#[derive(Clone, Debug)]
pub struct DenseEntry {
    pub name: String,
    pub rows: usize,
    pub cols: usize,
    pub paper_rows: usize,
    pub paper_cols: usize,
    pub seed: u64,
}

/// Artifact shape buckets (shared with python/compile/aot.py).
#[derive(Clone, Debug)]
pub struct Buckets {
    pub q_pow2_min: usize,
    pub q_pow2_max: usize,
    pub s_buckets: Vec<usize>,
    pub b: usize,
}

/// Parsed suite configuration.
#[derive(Clone, Debug)]
pub struct Suite {
    pub sparse: Vec<SparseEntry>,
    pub dense: Vec<DenseEntry>,
    pub buckets: Buckets,
    /// Default solve precision for the experiment drivers (top-level
    /// `"dtype"` key, default f64); overridable per run via `--dtype`.
    pub default_dtype: DType,
}

/// Locate `config/suite.json`: `$TRUNKSVD_CONFIG`, then ./config, then the
/// crate root (for `cargo test` from anywhere).
pub fn default_config_path() -> String {
    if let Ok(p) = std::env::var("TRUNKSVD_CONFIG") {
        return p;
    }
    let local = "config/suite.json";
    if std::path::Path::new(local).exists() {
        return local.to_string();
    }
    concat!(env!("CARGO_MANIFEST_DIR"), "/config/suite.json").to_string()
}

impl Suite {
    /// Load the default suite configuration.
    pub fn load_default() -> Result<Suite> {
        Suite::load(&default_config_path())
    }

    /// Load from an explicit path.
    pub fn load(path: &str) -> Result<Suite> {
        let doc = json::parse_file(path)?;
        Self::from_json(&doc)
    }

    fn from_json(doc: &Json) -> Result<Suite> {
        let req_usize = |o: &Json, k: &str| -> Result<usize> {
            o.req(k)?.as_usize().ok_or(Error::Parse {
                what: "suite",
                detail: format!("field '{k}' not a number"),
            })
        };
        let req_f64 = |o: &Json, k: &str| -> Result<f64> {
            o.req(k)?.as_f64().ok_or(Error::Parse {
                what: "suite",
                detail: format!("field '{k}' not a number"),
            })
        };
        let mut sparse = Vec::new();
        for e in doc.req("sparse")?.as_arr().unwrap_or(&[]) {
            sparse.push(SparseEntry {
                name: e.req("name")?.as_str().unwrap_or("").to_string(),
                paper_rows: req_usize(e, "paper_rows")?,
                paper_cols: req_usize(e, "paper_cols")?,
                paper_nnz: req_usize(e, "paper_nnz")?,
                spec: SparseSpec {
                    rows: req_usize(e, "rows")?,
                    cols: req_usize(e, "cols")?,
                    nnz: req_usize(e, "nnz")?,
                    seed: e.req("seed")?.as_u64().unwrap_or(0),
                    skew: req_f64(e, "skew")?,
                    value_decay: req_f64(e, "value_decay")?,
                },
            });
        }
        let mut dense = Vec::new();
        for e in doc.req("dense")?.as_arr().unwrap_or(&[]) {
            dense.push(DenseEntry {
                name: e.req("name")?.as_str().unwrap_or("").to_string(),
                rows: req_usize(e, "rows")?,
                cols: req_usize(e, "cols")?,
                paper_rows: req_usize(e, "paper_rows")?,
                paper_cols: req_usize(e, "paper_cols")?,
                seed: e.req("seed")?.as_u64().unwrap_or(0),
            });
        }
        let b = doc.req("artifact_buckets")?;
        let buckets = Buckets {
            q_pow2_min: req_usize(b, "q_pow2_min")?,
            q_pow2_max: req_usize(b, "q_pow2_max")?,
            s_buckets: b
                .req("s_buckets")?
                .as_arr()
                .unwrap_or(&[])
                .iter()
                .filter_map(|x| x.as_usize())
                .collect(),
            b: req_usize(b, "b")?,
        };
        let default_dtype = match doc.get("dtype") {
            None => DType::F64,
            Some(v) => {
                let tag = v.as_str().ok_or(Error::Parse {
                    what: "suite",
                    detail: "field 'dtype' must be a string (f32|f64)".into(),
                })?;
                DType::parse(tag).ok_or(Error::Parse {
                    what: "suite",
                    detail: format!("unknown dtype '{tag}' (f32|f64)"),
                })?
            }
        };
        Ok(Suite { sparse, dense, buckets, default_dtype })
    }

    /// Look up a sparse entry by name.
    pub fn sparse_by_name(&self, name: &str) -> Option<&SparseEntry> {
        self.sparse.iter().find(|e| e.name == name)
    }

    /// A small representative subset for quick benchmark runs: spans
    /// aspect ratios (tall, wide, extreme) and row-degree skews.
    pub fn representative(&self, k: usize) -> Vec<&SparseEntry> {
        let preferred = [
            "mesh_deform",  // tall, few cols
            "connectus",    // extremely wide
            "rel8",         // tall
            "lp_osa_60",    // wide
            "specular",     // heavy rows
            "fome21",       // balanced wide
            "ESOC",         // tall, denser
            "ch8-8-b4",     // tall structured
            "GL7d23",       // wide
            "dbic1",        // wide
            "shar_te2-b2",  // tall
            "12month1",     // wide heavy rows
        ];
        let mut out: Vec<&SparseEntry> = preferred
            .iter()
            .filter_map(|n| self.sparse_by_name(n))
            .take(k)
            .collect();
        for e in &self.sparse {
            if out.len() >= k {
                break;
            }
            if !out.iter().any(|x| x.name == e.name) {
                out.push(e);
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn loads_checked_in_config() {
        let s = Suite::load_default().unwrap();
        assert_eq!(s.sparse.len(), 46);
        assert_eq!(s.dense.len(), 4);
        assert_eq!(s.default_dtype, DType::F64);
        assert_eq!(s.buckets.b, 16);
        assert!(s.buckets.s_buckets.contains(&256));
        // paper dims preserved
        let relat9 = s.sparse_by_name("relat9").unwrap();
        assert_eq!(relat9.paper_rows, 12_360_060);
        assert!(relat9.spec.rows <= 32_768 + 1);
        // every scaled matrix satisfies the r=256 feasibility floor
        for e in &s.sparse {
            assert!(e.spec.rows.min(e.spec.cols) >= 512, "{} too small", e.name);
            assert!(e.spec.nnz <= e.spec.rows * e.spec.cols / 2, "{} too dense", e.name);
        }
    }

    #[test]
    fn representative_subset() {
        let s = Suite::load_default().unwrap();
        let r = s.representative(12);
        assert_eq!(r.len(), 12);
        let mut names: Vec<&str> = r.iter().map(|e| e.name.as_str()).collect();
        names.dedup();
        assert_eq!(names.len(), 12);
    }
}
