//! Dense synthetic problems (paper §4.2, Eqs. 15–16).
//!
//! A = X Σ Yᵀ with random orthonormal X (m×n), Y (n×n) and the paper's
//! spectrum: σ_i = 10^(15·i/(n/2) − 14) for i ≤ n/2 (note: descending
//! when indexed from the largest), 10⁻¹⁴ otherwise — i.e. half the
//! spectrum decays geometrically from 10¹ down to ~10⁻¹⁴ and the other
//! half sits at the double-precision rounding floor.

use crate::la::blas3::mat_nn;
use crate::la::mat::Mat;
use crate::la::qr::random_orthonormal;
use crate::util::rng::Rng;

/// The paper's Eq. 16 singular-value profile, returned descending.
pub fn paper_spectrum(n: usize) -> Vec<f64> {
    let half = n / 2;
    let mut s: Vec<f64> = (1..=n)
        .map(|i| {
            if i <= half {
                10f64.powf(15.0 * i as f64 / half as f64 - 14.0)
            } else {
                1e-14
            }
        })
        .collect();
    s.sort_by(|a, b| b.partial_cmp(a).unwrap());
    s
}

/// A dense problem with known singular triplets.
pub struct DenseProblem {
    pub a: Mat,
    /// true singular values, descending
    pub sigma: Vec<f64>,
    /// true left singular vectors (m×n)
    pub u: Mat,
    /// true right singular vectors (n×n)
    pub v: Mat,
}

/// Build A = X·diag(sigma)·Yᵀ for a given spectrum.
pub fn dense_with_spectrum(m: usize, n: usize, sigma: &[f64], seed: u64) -> DenseProblem {
    assert!(m >= n && sigma.len() == n);
    let mut rng = Rng::new(seed);
    let x = random_orthonormal(m, n, &mut rng);
    let y = random_orthonormal(n, n, &mut rng);
    let mut xs = x.clone();
    for j in 0..n {
        let s = sigma[j];
        for v in xs.col_mut(j) {
            *v *= s;
        }
    }
    let a = mat_nn(&xs, &y.transpose());
    DenseProblem { a, sigma: sigma.to_vec(), u: x, v: y }
}

/// The paper's synthetic dense benchmark problem (Eq. 15 + Eq. 16).
pub fn paper_dense(m: usize, n: usize, seed: u64) -> DenseProblem {
    let sigma = paper_spectrum(n);
    dense_with_spectrum(m, n, &sigma, seed)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::la::svd::jacobi_svd;

    #[test]
    fn spectrum_shape() {
        let s = paper_spectrum(100);
        assert_eq!(s.len(), 100);
        // descending
        for w in s.windows(2) {
            assert!(w[0] >= w[1]);
        }
        // top value 10^(15*50/50 - 14) = 10
        assert!((s[0] - 10.0).abs() < 1e-12);
        // floor
        assert_eq!(s[99], 1e-14);
        let floor_count = s.iter().filter(|&&x| x == 1e-14).count();
        assert!(floor_count >= 50, "floor count {floor_count}");
    }

    #[test]
    fn constructed_problem_has_requested_spectrum() {
        let sigma: Vec<f64> = (0..6).map(|i| 2.0f64.powi(-(i as i32))).collect();
        let p = dense_with_spectrum(24, 6, &sigma, 7);
        let svd = jacobi_svd(&p.a).unwrap();
        for i in 0..6 {
            assert!(
                (svd.s[i] - sigma[i]).abs() / sigma[i] < 1e-10,
                "sigma_{i}: {} vs {}",
                svd.s[i],
                sigma[i]
            );
        }
    }

    #[test]
    fn deterministic() {
        let p1 = paper_dense(30, 10, 5);
        let p2 = paper_dense(30, 10, 5);
        assert!(p1.a.max_abs_diff(&p2.a) < 1e-15);
    }
}
