//! Device-contract staged backend: a CPU-resident simulation of a real
//! device target, with a transfer ledger that *enforces* the backend
//! author's contract (see the `backend` module docs).
//!
//! The paper's GPU execution model keeps every hot-loop operand in
//! preallocated device memory: the operand matrix is staged once (into
//! the hardware-friendly Block-ELL layout for the Pallas SpMM kernel),
//! every planned buffer is device-resident for the whole solve, and only
//! the tiny POTRF/GESVD factors cross the host boundary per iteration.
//! [`StagedBackend`] simulates exactly that on the CPU so the contract
//! can be proven and regression-tested *before* a real device port:
//!
//! * **Operand staging** — `new_sparse` + [`Backend::plan`] stage the
//!   CSR operand into a private arena as a pair of [`BlockEll`] matrices
//!   (A and the explicit Aᵀ, paper §4.1.2 — the natural device trade),
//!   falling back to an arena CSR when the ELL fill factor would blow
//!   the memory budget (the cuSPARSE-CSR regime). Dense operands stage a
//!   dense arena copy.
//! * **Residency tracking** — the caller's workspace buffers play the
//!   role of arena memory, and a byte-interval set records which ranges
//!   the "device" has produced. Every op input is checked against it:
//!   reading a non-resident **panel** (`rows ∈ {m, n}`) is a host→arena
//!   transfer; doing so inside a hot phase (`MultA`/`MultAt`/`OrthM`/
//!   `OrthN`) is a contract violation and **panics** when enforcement is
//!   on (the default). Factor-sized data (`rows ≤ r`) crosses freely —
//!   that is the sanctioned POTRF/GESVD traffic — and is ledgered, not
//!   punished.
//! * **Transfer ledger** — every host↔arena copy is recorded with op
//!   name, direction, bytes, phase, and panel/factor class
//!   ([`TransferLedger`]); intra-arena staging memcpys (the pad copies
//!   around the Block-ELL kernel, [`Backend::copy_into`] panel moves)
//!   are counted separately as arena→arena traffic. `bench_blocks`
//!   exports the counters to `BENCH_kernels.json` and the conformance
//!   suite asserts **zero hot-loop panel transfers** per solve.
//!
//! The real GPU port starts from this file: replace the arena memcpys
//! with `cudaMemcpy`, the Block-ELL host kernel with the Pallas/cuSPARSE
//! launch, and keep the ledger in debug builds.
//!
//! Known simulation limits (documented, deliberate): host-side reads of
//! device-written factors (e.g. POTRF consuming the Gram matrix) cannot
//! be observed directly, so the arena→host half of each factor crossing
//! is recorded when the factor is *produced* by a device op; host writes
//! into resident buffers (the algorithms' defensive zero-fills inside
//! sanctioned windows) are invisible to the ledger, which is safe here
//! because arena and host share storage.

use std::sync::Arc;

use super::{Backend, Operand};
use crate::la::blas3;
use crate::la::mat::{Mat, MatMut, MatRef};
use crate::la::workspace::{names, Plan, Workspace};
use crate::metrics::{Block, Profile, Timer};
use crate::sparse::blockell::BlockEll;
use crate::sparse::csr::Csr;
use crate::sparse::shard::{ShardStats, ShardedOperand};
use crate::util::scalar::Scalar;

/// Transfer direction across (or within) the simulated memory tiers
/// (disk ↔ host ↔ arena; see backend module docs §6).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Direction {
    /// Host memory → device arena (upload).
    HostToArena,
    /// Device arena → host memory (download).
    ArenaToHost,
    /// Intra-arena staging copy (device-to-device; `cudaMemcpyD2D`).
    ArenaToArena,
    /// Disk tier → host: one out-of-core shard load (whole row-band CSR
    /// segments only — never panel traffic, so always `panel = false`).
    DiskToHost,
}

/// One ledgered copy.
#[derive(Clone, Debug)]
pub struct TransferEvent {
    /// Backend op that triggered the copy.
    pub op: &'static str,
    pub dir: Direction,
    pub bytes: usize,
    /// Profile phase the copy happened under.
    pub phase: Block,
    /// Panel-sized (`rows ∈ {m, n}`) vs factor-sized (`rows ≤ r`).
    pub panel: bool,
}

/// Aggregated ledger counters (cheap to snapshot for per-solve deltas).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct LedgerTotals {
    pub h2a_count: u64,
    pub h2a_bytes: u64,
    pub a2h_count: u64,
    pub a2h_bytes: u64,
    pub a2a_count: u64,
    pub a2a_bytes: u64,
    /// Panel-sized host↔arena transfers inside a hot phase — contract
    /// violations. Stays 0 on conforming solves (and panics instead,
    /// unless enforcement was turned off).
    pub hot_panel_transfers: u64,
    /// Factor-sized host↔arena crossings inside hot phases — the
    /// sanctioned POTRF traffic.
    pub hot_factor_crossings: u64,
    pub hot_factor_bytes: u64,
    /// One-time operand staging volume (CSR arrays / dense payload; for
    /// sharded operands, the pinned-prefix shard bytes).
    pub staged_operand_bytes: u64,
    /// Disk-tier traffic: out-of-core shard loads (count and file
    /// bytes). Stays 0 for in-core operands.
    pub disk_count: u64,
    pub disk_bytes: u64,
    /// Number of `plan()` calls (solve staging events).
    pub plans: u64,
}

const EVENT_CAP: usize = 4096;

/// Records every host↔arena copy the staged backend performs. Event
/// storage is capacity-bounded (the counters keep accumulating past the
/// cap), so steady-state solves never reallocate it.
#[derive(Debug)]
pub struct TransferLedger {
    totals: LedgerTotals,
    events: Vec<TransferEvent>,
    dropped: u64,
}

impl Default for TransferLedger {
    fn default() -> Self {
        TransferLedger {
            totals: LedgerTotals::default(),
            events: Vec::with_capacity(EVENT_CAP),
            dropped: 0,
        }
    }
}

impl TransferLedger {
    fn record(
        &mut self,
        op: &'static str,
        dir: Direction,
        bytes: usize,
        phase: Block,
        panel: bool,
    ) {
        let hot = is_hot(phase);
        match dir {
            Direction::HostToArena => {
                self.totals.h2a_count += 1;
                self.totals.h2a_bytes += bytes as u64;
            }
            Direction::ArenaToHost => {
                self.totals.a2h_count += 1;
                self.totals.a2h_bytes += bytes as u64;
            }
            Direction::ArenaToArena => {
                self.totals.a2a_count += 1;
                self.totals.a2a_bytes += bytes as u64;
            }
            Direction::DiskToHost => {
                self.totals.disk_count += 1;
                self.totals.disk_bytes += bytes as u64;
            }
        }
        // Disk-tier shard traffic is sanctioned operand streaming, never
        // a host↔arena contract crossing — keep it out of the hot-loop
        // panel/factor accounting.
        if hot && matches!(dir, Direction::HostToArena | Direction::ArenaToHost) {
            if panel {
                self.totals.hot_panel_transfers += 1;
            } else {
                self.totals.hot_factor_crossings += 1;
                self.totals.hot_factor_bytes += bytes as u64;
            }
        }
        if self.events.len() < EVENT_CAP {
            self.events.push(TransferEvent { op, dir, bytes, phase, panel });
        } else {
            self.dropped += 1;
        }
    }

    pub fn totals(&self) -> LedgerTotals {
        self.totals
    }

    /// The recorded events (bounded at an internal cap; see
    /// [`TransferLedger::dropped_events`]).
    pub fn events(&self) -> &[TransferEvent] {
        &self.events
    }

    /// Events past the storage cap (counters still include them).
    pub fn dropped_events(&self) -> u64 {
        self.dropped
    }

    /// Panel-sized hot-loop transfers — 0 on a conforming solve.
    pub fn hot_panel_transfers(&self) -> u64 {
        self.totals.hot_panel_transfers
    }
}

fn is_hot(phase: Block) -> bool {
    matches!(phase, Block::MultA | Block::MultAt | Block::OrthM | Block::OrthN)
}

/// Sorted, disjoint byte-interval set over host addresses: which ranges
/// of the caller's workspace the simulated device currently owns.
#[derive(Debug, Default)]
struct IntervalSet {
    spans: Vec<(usize, usize)>,
}

impl IntervalSet {
    fn clear(&mut self) {
        self.spans.clear();
    }

    fn insert(&mut self, lo: usize, hi: usize) {
        if lo >= hi {
            return;
        }
        // First span that could merge (end >= lo), then every span that
        // starts inside the merged range.
        let i = self.spans.partition_point(|&(_, e)| e < lo);
        let mut nlo = lo;
        let mut nhi = hi;
        let mut j = i;
        while j < self.spans.len() && self.spans[j].0 <= nhi {
            nlo = nlo.min(self.spans[j].0);
            nhi = nhi.max(self.spans[j].1);
            j += 1;
        }
        self.spans.splice(i..j, std::iter::once((nlo, nhi)));
    }

    /// Bytes of [lo, hi) not covered by any span.
    fn uncovered(&self, lo: usize, hi: usize) -> usize {
        if lo >= hi {
            return 0;
        }
        let mut missing = hi - lo;
        let start = self.spans.partition_point(|&(_, e)| e <= lo);
        for &(s, e) in &self.spans[start..] {
            if s >= hi {
                break;
            }
            missing -= e.min(hi) - s.max(lo);
        }
        missing
    }
}

/// The arena-staged form of the operand.
enum DeviceOperand<S: Scalar> {
    /// Paper layout: Block-ELL of A and of the explicit Aᵀ (§4.1.2).
    BlockEll { a: BlockEll<S>, at: BlockEll<S> },
    /// ELL-hostile operands stay CSR in the arena (the cuSPARSE regime);
    /// A itself is shared with the host copy (arena == host storage in
    /// this simulation), the gather transpose is arena-built.
    Csr { at: Csr<S> },
    /// Dense arena copy.
    Dense(Mat<S>),
    /// Out-of-core operand: only a pinned prefix + two streaming slots
    /// of row-band shards are ever arena-resident (disk tier below the
    /// arena; loads are ledgered as [`Direction::DiskToHost`]).
    Sharded(ShardedOperand<S>),
}

impl<S: Scalar> DeviceOperand<S> {
    fn format(&self) -> &'static str {
        match self {
            DeviceOperand::BlockEll { .. } => "blockell",
            DeviceOperand::Csr { .. } => "csr",
            DeviceOperand::Dense(_) => "dense",
            DeviceOperand::Sharded(_) => "sharded",
        }
    }
}

/// Zero-padded staging panels around the Block-ELL kernel (the arena
/// memcpys a real port replaces with `cudaMemcpy`).
struct StagePad<S: Scalar> {
    x: Mat<S>,
    y: Mat<S>,
}

fn csr_bytes<S: Scalar>(a: &Csr<S>) -> usize {
    a.nnz() * (std::mem::size_of::<S>() + std::mem::size_of::<u32>())
        + (a.rows() + 1) * std::mem::size_of::<usize>()
}

/// Stage `x` into the zero-padded arena panel, run the Block-ELL SpMM,
/// and unpad the result into `y`. Shared by A·X and Aᵀ·X (which differ
/// only in which staged [`BlockEll`] they launch). Returns the bytes
/// moved by the two arena memcpys for the caller's ledger record.
fn blockell_apply<S: Scalar>(
    ell: &BlockEll<S>,
    pad: &mut StagePad<S>,
    x: MatRef<S>,
    y: &mut MatMut<S>,
) -> usize {
    let k = x.cols;
    let mut xp = pad.x.view_mut(ell.padded_cols(), k);
    for j in 0..k {
        let src = x.col(j);
        let dst = xp.col_mut(j);
        dst[..src.len()].copy_from_slice(src);
        dst[src.len()..].fill(S::ZERO);
    }
    let mut yp = pad.y.view_mut(ell.padded_rows(), k);
    ell.spmm(xp.as_ref(), yp.reborrow());
    for j in 0..k {
        y.col_mut(j).copy_from_slice(&yp.col(j)[..y.rows]);
    }
    std::mem::size_of::<S>() * k * (x.rows + y.rows)
}

/// Simulated-device backend: arena-staged operand, residency-checked
/// `*_into` ops, transfer ledger. See the module docs.
pub struct StagedBackend<S: Scalar = f64> {
    a: Operand<S>,
    dev: Option<DeviceOperand<S>>,
    pad: Option<StagePad<S>>,
    /// Block-ELL block size for sparse operand staging.
    bs: usize,
    /// Fill-factor cap above which sparse staging falls back to CSR.
    fill_cap: f64,
    planned: Option<Plan>,
    resident: IntervalSet,
    ledger: TransferLedger,
    enforce: bool,
    profile: Profile,
}

impl<S: Scalar> StagedBackend<S> {
    pub fn new_sparse(a: impl Into<Arc<Csr<S>>>) -> StagedBackend<S> {
        StagedBackend::new(Operand::Sparse(a.into()))
    }

    pub fn new_dense(a: Mat<S>) -> StagedBackend<S> {
        StagedBackend::new(Operand::Dense(a))
    }

    /// Out-of-core construction: the operand stays on disk as a shard
    /// directory and streams through the prefetch pipeline under
    /// `resident_cap` bytes (`0` = unlimited).
    pub fn new_sharded(
        dir: Arc<crate::sparse::shard::ShardDir>,
        resident_cap: usize,
    ) -> StagedBackend<S> {
        StagedBackend::new(Operand::Sharded { dir, resident_cap })
    }

    pub fn new(a: Operand<S>) -> StagedBackend<S> {
        StagedBackend {
            a,
            dev: None,
            pad: None,
            bs: 8,
            fill_cap: 16.0,
            planned: None,
            resident: IntervalSet::default(),
            ledger: TransferLedger::default(),
            enforce: true,
            profile: Profile::new(),
        }
    }

    /// Block-ELL block size for the sparse operand staging (default 8).
    pub fn with_block_size(mut self, bs: usize) -> StagedBackend<S> {
        assert!(bs > 0, "block size must be >= 1");
        assert!(self.dev.is_none(), "operand already staged");
        self.bs = bs;
        self
    }

    /// Fill-factor cap for the Block-ELL staging (default 16×nnz); above
    /// it the operand stays CSR in the arena.
    pub fn with_fill_cap(mut self, cap: f64) -> StagedBackend<S> {
        assert!(self.dev.is_none(), "operand already staged");
        self.fill_cap = cap;
        self
    }

    /// Toggle hot-loop transfer enforcement (panics on violation when
    /// on; on by default). With enforcement off, violations only count
    /// in [`LedgerTotals::hot_panel_transfers`].
    pub fn enforce_transfers(mut self, on: bool) -> StagedBackend<S> {
        self.enforce = on;
        self
    }

    pub fn operand(&self) -> &Operand<S> {
        &self.a
    }

    /// The plan recorded by the last [`Backend::plan`] call, if any.
    pub fn planned(&self) -> Option<&Plan> {
        self.planned.as_ref()
    }

    /// Arena layout the operand was staged into
    /// ("blockell"/"csr"/"dense"), or `None` before staging.
    pub fn device_format(&self) -> Option<&'static str> {
        self.dev.as_ref().map(|d| d.format())
    }

    pub fn ledger(&self) -> &TransferLedger {
        &self.ledger
    }

    /// Take the ledger, resetting it (the residency map is untouched).
    pub fn take_ledger(&mut self) -> TransferLedger {
        std::mem::take(&mut self.ledger)
    }

    /// Streaming counters of a sharded operand (`None` when in-core).
    /// `overlap_efficiency()` on the stats is the ledger's third-tier
    /// overlap figure: the fraction of loader time hidden behind
    /// compute.
    pub fn shard_stats(&self) -> Option<ShardStats> {
        match &self.dev {
            Some(DeviceOperand::Sharded(sh)) => Some(sh.stats()),
            _ => None,
        }
    }

    /// For sharded operands: validate the resident cap and stage the
    /// pinned prefix + loader thread, ledgering the pin loads. Surfaces
    /// cap misconfiguration as an `Err` instead of a panic inside the
    /// first solve op. No-op (Ok) for in-core operands.
    pub fn ensure_operand_resident(&mut self) -> crate::error::Result<()> {
        self.ensure_staged();
        if let Some(DeviceOperand::Sharded(sh)) = self.dev.as_mut() {
            sh.ensure_resident()?;
        }
        self.drain_shard_events("plan.stage_operand");
        Ok(())
    }

    /// Move buffered shard-load events into the ledger: pinned-prefix
    /// loads count as one-time operand staging, streamed loads as
    /// disk-tier traffic under `op`.
    fn drain_shard_events(&mut self, op: &'static str) {
        let Some(DeviceOperand::Sharded(sh)) = self.dev.as_mut() else { return };
        let events = sh.take_load_events();
        let phase = self.profile.phase();
        for e in events {
            let name = if e.pinned { "plan.stage_operand" } else { op };
            self.ledger.record(name, Direction::DiskToHost, e.file_bytes, phase, false);
            if e.pinned {
                self.ledger.totals.staged_operand_bytes += e.file_bytes as u64;
            }
        }
    }

    fn ensure_staged(&mut self) {
        if self.dev.is_some() {
            return;
        }
        let dev = match &self.a {
            Operand::Dense(a) => {
                let bytes = std::mem::size_of_val(a.data());
                self.ledger.record(
                    "plan.stage_operand",
                    Direction::HostToArena,
                    bytes,
                    self.profile.phase(),
                    true,
                );
                self.ledger.totals.staged_operand_bytes += bytes as u64;
                DeviceOperand::Dense(a.clone())
            }
            Operand::Sparse(a) => {
                let bytes = csr_bytes(a.as_ref());
                self.ledger.record(
                    "plan.stage_operand",
                    Direction::HostToArena,
                    bytes,
                    self.profile.phase(),
                    true,
                );
                self.ledger.totals.staged_operand_bytes += bytes as u64;
                let at = a.transpose();
                let ell_a = BlockEll::from_csr_auto(a, self.bs);
                let ell_at = BlockEll::from_csr_auto(&at, self.bs);
                let nnz = a.nnz();
                if ell_a.fill_factor(nnz) <= self.fill_cap
                    && ell_at.fill_factor(nnz) <= self.fill_cap
                {
                    DeviceOperand::BlockEll { a: ell_a, at: ell_at }
                } else {
                    DeviceOperand::Csr { at }
                }
            }
            // No bytes move at staging time for a sharded operand: the
            // pin-prefix loads happen (and are ledgered) when
            // `ensure_operand_resident` / the first pass runs.
            Operand::Sharded { dir, resident_cap } => {
                DeviceOperand::Sharded(ShardedOperand::new(Arc::clone(dir), *resident_cap))
            }
        };
        self.dev = Some(dev);
    }

    /// Make sure the padded staging panels cover `k` columns (planned
    /// solves size them once in `plan`; unplanned one-shot calls grow
    /// them lazily — allocation outside the steady state is allowed).
    fn ensure_pads(&mut self, k: usize) {
        let Some(DeviceOperand::BlockEll { a, at }) = &self.dev else { return };
        let x_rows = a.padded_cols().max(at.padded_cols());
        let y_rows = a.padded_rows().max(at.padded_rows());
        let need = match &self.pad {
            Some(p) => p.x.rows() < x_rows || p.y.rows() < y_rows || p.x.cols() < k,
            None => true,
        };
        if need {
            let k_cap = k.max(self.pad.as_ref().map_or(0, |p| p.x.cols()));
            self.pad = Some(StagePad {
                x: Mat::zeros(x_rows, k_cap),
                y: Mat::zeros(y_rows, k_cap),
            });
        }
    }

    fn is_panel(&self, rows: usize, cols: usize) -> bool {
        let (m, n) = self.a.shape();
        let r = self.planned.as_ref().map_or(0, |p| p.r);
        (rows == m || rows == n) && rows.max(cols) > r
    }

    /// Residency check for one op input. A non-resident range is a
    /// host→arena transfer; a panel-sized one inside a hot phase is a
    /// contract violation (panic under enforcement).
    fn note_read(&mut self, op: &'static str, rows: usize, cols: usize, data: &[S]) {
        let lo = data.as_ptr() as usize;
        let hi = lo + std::mem::size_of_val(data);
        let missing = self.resident.uncovered(lo, hi);
        if missing == 0 {
            return;
        }
        let panel = self.is_panel(rows, cols);
        let phase = self.profile.phase();
        self.ledger.record(op, Direction::HostToArena, missing, phase, panel);
        if panel {
            if is_hot(phase) && self.enforce && self.planned.is_some() {
                panic!(
                    "staged backend: op '{op}' read a non-resident {rows}x{cols} panel \
                     ({missing} bytes) in hot phase {phase:?} — unsanctioned host→arena \
                     transfer; only POTRF/GESVD factor crossings may cross mid-loop \
                     (see backend module docs, rule 3)"
                );
            }
            // The uploaded panel is arena-resident from here on.
            self.resident.insert(lo, hi);
        }
        // Factor-sized host data deliberately stays non-resident: the
        // POTRF/GESVD factors re-cross on every call, as on real hardware.
    }

    /// Mark one op output arena-resident. Factor-sized outputs also
    /// record the arena→host half of their crossing (the host consumes
    /// them: POTRF reads the Gram factor, the assembly loops read H/R).
    fn note_write(
        &mut self,
        op: &'static str,
        rows: usize,
        cols: usize,
        data: &[S],
        host_consumed: bool,
    ) {
        let lo = data.as_ptr() as usize;
        let hi = lo + std::mem::size_of_val(data);
        self.resident.insert(lo, hi);
        if host_consumed && !self.is_panel(rows, cols) {
            self.ledger.record(
                op,
                Direction::ArenaToHost,
                hi - lo,
                self.profile.phase(),
                false,
            );
        }
    }

    /// Pre-mark the orth snapshot buffer arena-resident: the host
    /// composition snapshots the panel into `orth.snap` (a device-side
    /// copy on real hardware) and the breakdown fallback feeds it back
    /// through `proj_into`.
    fn mark_snap_resident(&mut self, ws: &Workspace<S>) {
        let (lo, hi) = {
            let snap = ws.buf(names::ORTH_SNAP);
            let lo = snap.data().as_ptr() as usize;
            (lo, lo + std::mem::size_of_val(snap.data()))
        };
        self.resident.insert(lo, hi);
    }
}

impl<S: Scalar> Backend<S> for StagedBackend<S> {
    fn m(&self) -> usize {
        self.a.shape().0
    }
    fn n(&self) -> usize {
        self.a.shape().1
    }
    fn nnz(&self) -> Option<usize> {
        self.a.nnz()
    }

    fn plan(&mut self, plan: &Plan) {
        self.ensure_staged();
        self.ensure_operand_resident().expect("sharded operand staging at plan");
        self.planned = Some(plan.clone());
        self.ensure_pads(plan.r.max(plan.b).max(1));
        // Fresh solve: the previous solve's residency is stale (the
        // algorithms host-initialize their state buffers before the
        // first staged upload).
        self.resident.clear();
        self.ledger.totals.plans += 1;
    }

    fn apply_a_into(&mut self, x: MatRef<S>, mut y: MatMut<S>) {
        assert_eq!((y.rows, y.cols), (self.m(), x.cols), "apply_a_into out shape");
        self.ensure_staged();
        self.ensure_pads(x.cols);
        self.note_read("apply_a", x.rows, x.cols, x.data);
        let t = Timer::start(self.mult_flops(x.cols));
        match self.dev.as_mut().expect("operand staged above") {
            DeviceOperand::Dense(a) => {
                blas3::gemm_nn(S::ONE, a.as_ref(), x, S::ZERO, y.reborrow())
            }
            DeviceOperand::Csr { .. } => {
                let Operand::Sparse(a) = &self.a else { unreachable!("csr arena, sparse host") };
                a.spmm(x, y.reborrow());
            }
            DeviceOperand::BlockEll { a, .. } => {
                let pad = self.pad.as_mut().expect("pads sized above");
                let moved = blockell_apply(a, pad, x, &mut y);
                self.ledger.record(
                    "apply_a",
                    Direction::ArenaToArena,
                    moved,
                    self.profile.phase(),
                    true,
                );
            }
            DeviceOperand::Sharded(sh) => {
                sh.spmm(x, &mut y).expect("sharded operand I/O during apply_a");
            }
        }
        t.stop(&mut self.profile);
        self.drain_shard_events("apply_a");
        self.note_write("apply_a", y.rows, y.cols, y.data, true);
    }

    fn apply_at_into(&mut self, x: MatRef<S>, mut y: MatMut<S>) {
        assert_eq!((y.rows, y.cols), (self.n(), x.cols), "apply_at_into out shape");
        self.ensure_staged();
        self.ensure_pads(x.cols);
        self.note_read("apply_at", x.rows, x.cols, x.data);
        let t = Timer::start(self.mult_flops(x.cols));
        match self.dev.as_mut().expect("operand staged above") {
            DeviceOperand::Dense(a) => {
                blas3::gemm_tn(S::ONE, a.as_ref(), x, S::ZERO, y.reborrow())
            }
            // Arena-resident explicit transpose: gather SpMM, never the
            // scatter kernel (the device already paid the §4.1.2 trade).
            DeviceOperand::Csr { at } => at.spmm(x, y.reborrow()),
            DeviceOperand::BlockEll { at, .. } => {
                let pad = self.pad.as_mut().expect("pads sized above");
                let moved = blockell_apply(at, pad, x, &mut y);
                self.ledger.record(
                    "apply_at",
                    Direction::ArenaToArena,
                    moved,
                    self.profile.phase(),
                    true,
                );
            }
            // No in-core transpose exists: stream the row-order scatter
            // (bitwise-identical to the in-core scatter kernel).
            DeviceOperand::Sharded(sh) => {
                sh.spmm_t(x, &mut y).expect("sharded operand I/O during apply_at");
            }
        }
        t.stop(&mut self.profile);
        self.drain_shard_events("apply_at");
        self.note_write("apply_at", y.rows, y.cols, y.data, true);
    }

    // ---- fused operand-pass tier (contract rule 8) ----

    fn apply_a_gram_into(&mut self, x: MatRef<S>, mut y: MatMut<S>, mut g: MatMut<S>) {
        assert_eq!((y.rows, y.cols), (self.m(), x.cols), "apply_a_gram_into y shape");
        assert_eq!((g.rows, g.cols), (x.cols, x.cols), "apply_a_gram_into g shape");
        self.ensure_staged();
        self.ensure_pads(x.cols);
        self.note_read("apply_a_gram", x.rows, x.cols, x.data);
        let k = x.cols;
        let flops = self.mult_flops(k) + k as f64 * k as f64 * y.rows as f64;
        let t = Timer::start(flops);
        match self.dev.as_mut().expect("operand staged above") {
            DeviceOperand::Dense(a) => {
                blas3::gemm_nn(S::ONE, a.as_ref(), x, S::ZERO, y.reborrow());
                blas3::gram_into(y.as_ref(), g.reborrow());
            }
            DeviceOperand::Csr { .. } => {
                let Operand::Sparse(a) = &self.a else { unreachable!("csr arena, sparse host") };
                a.spmm_gram(x, y.reborrow(), g.reborrow());
            }
            DeviceOperand::BlockEll { a, .. } => {
                // Pad x, run the fused padded-panel kernel, unpad y. The
                // Gram over the padded panel equals the unpadded one
                // (A's padding rows are exactly zero).
                let pad = self.pad.as_mut().expect("pads sized above");
                let mut yp = pad.y.view_mut(a.padded_rows(), k);
                {
                    let mut xp = pad.x.view_mut(a.padded_cols(), k);
                    for j in 0..k {
                        let src = x.col(j);
                        let dst = xp.col_mut(j);
                        dst[..src.len()].copy_from_slice(src);
                        dst[src.len()..].fill(S::ZERO);
                    }
                    a.spmm_gram(xp.as_ref(), yp.reborrow(), g.reborrow());
                }
                for j in 0..k {
                    y.col_mut(j).copy_from_slice(&yp.col(j)[..y.rows]);
                }
                let moved = std::mem::size_of::<S>() * k * (x.rows + y.rows);
                self.ledger.record(
                    "apply_a_gram",
                    Direction::ArenaToArena,
                    moved,
                    self.profile.phase(),
                    true,
                );
            }
            DeviceOperand::Sharded(sh) => {
                sh.spmm_gram(x, &mut y, &mut g)
                    .expect("sharded operand I/O during apply_a_gram");
            }
        }
        t.stop(&mut self.profile);
        self.drain_shard_events("apply_a_gram");
        self.note_write("apply_a_gram", y.rows, y.cols, y.data, true);
        // The b×b Gram is consumed by the host POTRF downdate — the
        // sanctioned factor download (rule 3).
        self.note_write("apply_a_gram", g.rows, g.cols, g.data, true);
    }

    fn apply_ata_into(&mut self, x: MatRef<S>, mut y: MatMut<S>, mut z: MatMut<S>) {
        assert_eq!((y.rows, y.cols), (self.m(), x.cols), "apply_ata_into y shape");
        assert_eq!((z.rows, z.cols), (self.n(), x.cols), "apply_ata_into z shape");
        self.ensure_staged();
        self.ensure_pads(x.cols);
        self.note_read("apply_ata", x.rows, x.cols, x.data);
        let t = Timer::start(2.0 * self.mult_flops(x.cols));
        match self.dev.as_mut().expect("operand staged above") {
            DeviceOperand::Dense(a) => {
                blas3::gemm_nn(S::ONE, a.as_ref(), x, S::ZERO, y.reborrow());
                blas3::gemm_tn(S::ONE, a.as_ref(), y.as_ref(), S::ZERO, z.reborrow());
            }
            DeviceOperand::Csr { at } => {
                let Operand::Sparse(a) = &self.a else { unreachable!("csr arena, sparse host") };
                a.spmm(x, y.reborrow());
                at.spmm(y.as_ref(), z.reborrow());
            }
            DeviceOperand::BlockEll { a, at } => {
                // Single-pad fused chain: the forward product's padded
                // output panel is exactly the transposed product's padded
                // input (`a.padded_rows() == at.padded_cols()` at one
                // block size, and A's padding rows are zero), so the
                // unfused pair's intermediate unpad→repad memcpy is
                // skipped; pad.x is recycled as the Z output panel.
                let pad = self.pad.as_mut().expect("pads sized above");
                let k = x.cols;
                debug_assert_eq!(a.padded_rows(), at.padded_cols());
                let mut yp = pad.y.view_mut(a.padded_rows(), k);
                {
                    let mut xp = pad.x.view_mut(a.padded_cols(), k);
                    for j in 0..k {
                        let src = x.col(j);
                        let dst = xp.col_mut(j);
                        dst[..src.len()].copy_from_slice(src);
                        dst[src.len()..].fill(S::ZERO);
                    }
                    a.spmm(xp.as_ref(), yp.reborrow());
                }
                for j in 0..k {
                    y.col_mut(j).copy_from_slice(&yp.col(j)[..y.rows]);
                }
                let mut zp = pad.x.view_mut(at.padded_rows(), k);
                at.spmm(yp.as_ref(), zp.reborrow());
                for j in 0..k {
                    z.col_mut(j).copy_from_slice(&zp.col(j)[..z.rows]);
                }
                let moved = std::mem::size_of::<S>() * k * (x.rows + y.rows + z.rows);
                self.ledger.record(
                    "apply_ata",
                    Direction::ArenaToArena,
                    moved,
                    self.profile.phase(),
                    true,
                );
            }
            DeviceOperand::Sharded(sh) => {
                sh.spmm_ata(x, &mut y, &mut z)
                    .expect("sharded operand I/O during apply_ata");
            }
        }
        t.stop(&mut self.profile);
        self.drain_shard_events("apply_ata");
        self.note_write("apply_ata", y.rows, y.cols, y.data, true);
        self.note_write("apply_ata", z.rows, z.cols, z.data, true);
    }

    fn operand_bytes(&self) -> usize {
        match &self.a {
            Operand::Sparse(a) => csr_bytes(a.as_ref()),
            Operand::Dense(a) => a.rows() * a.cols() * std::mem::size_of::<S>(),
            Operand::Sharded { dir, .. } => dir.total_file_bytes(),
        }
    }

    fn operand_on_disk(&self) -> bool {
        matches!(self.a, Operand::Sharded { .. })
    }

    fn gram_into(&mut self, q: MatRef<S>, mut w: MatMut<S>) {
        self.note_read("gram", q.rows, q.cols, q.data);
        let flops = q.cols as f64 * q.cols as f64 * q.rows as f64;
        let t = Timer::start(flops);
        blas3::gram_into(q, w.reborrow());
        t.stop(&mut self.profile);
        self.note_write("gram", w.rows, w.cols, w.data, true);
    }

    fn proj_into(&mut self, p: MatRef<S>, q: MatRef<S>, mut h: MatMut<S>) {
        self.note_read("proj", p.rows, p.cols, p.data);
        self.note_read("proj", q.rows, q.cols, q.data);
        let flops = 2.0 * p.rows as f64 * p.cols as f64 * q.cols as f64;
        let t = Timer::start(flops);
        blas3::gemm_tn(S::ONE, p, q, S::ZERO, h.reborrow());
        t.stop(&mut self.profile);
        self.note_write("proj", h.rows, h.cols, h.data, true);
    }

    fn subtract_proj(&mut self, mut q: MatMut<S>, p: MatRef<S>, h: MatRef<S>) {
        self.note_read("subtract_proj", q.rows, q.cols, q.data);
        self.note_read("subtract_proj", p.rows, p.cols, p.data);
        self.note_read("subtract_proj", h.rows, h.cols, h.data);
        let flops = 2.0 * p.rows as f64 * p.cols as f64 * h.cols as f64;
        let t = Timer::start(flops);
        blas3::gemm_nn(-S::ONE, p, h, S::ONE, q.reborrow());
        t.stop(&mut self.profile);
        self.note_write("subtract_proj", q.rows, q.cols, q.data, false);
    }

    fn tri_solve_right(&mut self, mut q: MatMut<S>, l: MatRef<S>) {
        self.note_read("tri_solve_right", q.rows, q.cols, q.data);
        // The host-computed Cholesky factor crossing back to the device —
        // the sanctioned POTRF upload (factor-sized, never residency-
        // cached, so it re-records every call as on real hardware).
        self.note_read("tri_solve_right", l.rows, l.cols, l.data);
        let flops = q.cols as f64 * q.cols as f64 * q.rows as f64;
        let t = Timer::start(flops);
        blas3::trsm_right_lt(l, q.reborrow());
        t.stop(&mut self.profile);
        self.note_write("tri_solve_right", q.rows, q.cols, q.data, false);
    }

    fn gemm_nn_into(&mut self, a: MatRef<S>, b: MatRef<S>, mut c: MatMut<S>) {
        assert_eq!((c.rows, c.cols), (a.rows, b.cols), "gemm_nn_into out shape");
        self.note_read("gemm_nn", a.rows, a.cols, a.data);
        self.note_read("gemm_nn", b.rows, b.cols, b.data);
        let flops = 2.0 * a.rows as f64 * a.cols as f64 * b.cols as f64;
        let t = Timer::start(flops);
        blas3::gemm_nn(S::ONE, a, b, S::ZERO, c.reborrow());
        t.stop(&mut self.profile);
        self.note_write("gemm_nn", c.rows, c.cols, c.data, false);
    }

    fn copy_into(&mut self, src: MatRef<S>, mut dst: MatMut<S>) {
        assert_eq!((src.rows, src.cols), (dst.rows, dst.cols), "copy_into shape");
        self.note_read("copy_into", src.rows, src.cols, src.data);
        dst.data.copy_from_slice(src.data);
        self.ledger.record(
            "copy_into",
            Direction::ArenaToArena,
            std::mem::size_of_val(src.data),
            self.profile.phase(),
            self.is_panel(src.rows, src.cols),
        );
        self.note_write("copy_into", dst.rows, dst.cols, dst.data, false);
    }

    fn stage_in(&mut self, src: MatRef<S>) {
        let lo = src.data.as_ptr() as usize;
        let hi = lo + std::mem::size_of_val(src.data);
        let missing = self.resident.uncovered(lo, hi);
        if missing > 0 {
            self.ledger.record(
                "stage_in",
                Direction::HostToArena,
                missing,
                self.profile.phase(),
                self.is_panel(src.rows, src.cols),
            );
        }
        self.resident.insert(lo, hi);
    }

    fn orth_cholqr2_into(
        &mut self,
        q: MatMut<S>,
        r: MatMut<S>,
        ws: &Workspace<S>,
    ) -> crate::error::Result<()> {
        self.mark_snap_resident(ws);
        crate::algo::orth::cholqr2_into_host(self, q, r, ws)
    }

    fn orth_cgs_cqr2_into(
        &mut self,
        q: MatMut<S>,
        p: MatRef<'_, S>,
        h: MatMut<S>,
        r: MatMut<S>,
        ws: &Workspace<S>,
    ) -> crate::error::Result<()> {
        self.mark_snap_resident(ws);
        crate::algo::orth::cgs_cqr2_into_host(self, q, p, h, r, ws)
    }

    fn orth_cgs_cqr2_pregram_into(
        &mut self,
        q: MatMut<S>,
        p: MatRef<'_, S>,
        g: MatRef<'_, S>,
        h: MatMut<S>,
        r: MatMut<S>,
        ws: &Workspace<S>,
    ) -> crate::error::Result<()> {
        self.mark_snap_resident(ws);
        crate::algo::orth::cgs_cqr2_pregram_into_host(self, q, p, g, h, r, ws)
    }

    fn profile_mut(&mut self) -> &mut Profile {
        &mut self.profile
    }

    fn take_profile(&mut self) -> Profile {
        std::mem::take(&mut self.profile)
    }

    fn name(&self) -> &'static str {
        "staged"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::lancsvd::lancsvd;
    use crate::algo::randsvd::randsvd;
    use crate::algo::{residuals, LancSvdOpts, RandSvdOpts};
    use crate::backend::cpu::CpuBackend;
    use crate::gen::sparse::{generate, SparseSpec};
    use crate::la::blas3::{mat_nn, mat_tn};
    use crate::sparse::coo::Coo;
    use crate::util::rng::Rng;

    #[test]
    fn interval_set_insert_merge_uncovered() {
        let mut s = IntervalSet::default();
        assert_eq!(s.uncovered(10, 20), 10);
        s.insert(10, 20);
        assert_eq!(s.uncovered(10, 20), 0);
        assert_eq!(s.uncovered(5, 25), 10);
        s.insert(30, 40);
        s.insert(18, 32); // bridges both spans
        assert_eq!(s.spans, vec![(10, 40)]);
        assert_eq!(s.uncovered(0, 50), 20);
        s.insert(40, 45); // adjacent: merges
        assert_eq!(s.spans, vec![(10, 45)]);
        s.insert(0, 5);
        assert_eq!(s.spans, vec![(0, 5), (10, 45)]);
        assert_eq!(s.uncovered(3, 12), 5);
        s.clear();
        assert_eq!(s.uncovered(10, 20), 10);
        // Degenerate ranges are no-ops.
        s.insert(7, 7);
        assert!(s.spans.is_empty());
        assert_eq!(s.uncovered(7, 7), 0);
    }

    fn small_sparse(seed: u64) -> Csr {
        let mut rng = Rng::new(seed);
        let mut coo = Coo::new(40, 24);
        for _ in 0..300 {
            coo.push(rng.below(40), rng.below(24), rng.normal());
        }
        Csr::from_coo(&coo).unwrap()
    }

    #[test]
    fn sparse_ops_match_cpu_reference() {
        let a = small_sparse(1);
        let ad = a.to_dense();
        let mut be = StagedBackend::new_sparse(a);
        assert_eq!(be.device_format(), None, "staged lazily");
        let mut rng = Rng::new(2);
        let x = Mat::randn(24, 4, &mut rng);
        let y = be.apply_a(x.as_ref());
        assert!(y.max_abs_diff(&mat_nn(&ad, &x)) < 1e-12);
        let z = Mat::randn(40, 4, &mut rng);
        let w = be.apply_at(z.as_ref());
        assert!(w.max_abs_diff(&mat_tn(&ad, &z)) < 1e-12);
        assert!(be.device_format().is_some());
        // The operand staging was ledgered.
        assert!(be.ledger().totals().staged_operand_bytes > 0);
    }

    #[test]
    fn fill_cap_falls_back_to_csr() {
        // A low-density operand at a tiny fill cap stages as CSR; a
        // generous cap admits Block-ELL. Numbers agree either way.
        let spec = SparseSpec { rows: 96, cols: 64, nnz: 300, seed: 3, ..Default::default() };
        let a = generate(&spec);
        let ad = a.to_dense();
        let mut rng = Rng::new(4);
        let x = Mat::randn(64, 3, &mut rng);
        let mut ell = StagedBackend::new_sparse(a.clone()).with_fill_cap(1e9);
        let mut csr = StagedBackend::new_sparse(a).with_fill_cap(1.0);
        let ye = ell.apply_a(x.as_ref());
        let yc = csr.apply_a(x.as_ref());
        assert_eq!(ell.device_format(), Some("blockell"));
        assert_eq!(csr.device_format(), Some("csr"));
        assert!(ye.max_abs_diff(&mat_nn(&ad, &x)) < 1e-12);
        assert!(yc.max_abs_diff(&mat_nn(&ad, &x)) < 1e-12);
        // Only the Block-ELL path pays arena staging memcpys.
        assert!(ell.ledger().totals().a2a_bytes > 0);
        assert_eq!(csr.ledger().totals().a2a_bytes, 0);
    }

    #[test]
    fn fused_ops_match_composition_bitwise() {
        // Both device formats exercise the fused tier: Block-ELL takes
        // the single-pad chain, CSR the arena explicit transpose.
        for (cap, fmt) in [(1e9, "blockell"), (1.0, "csr")] {
            let a = small_sparse(13);
            let ad = a.to_dense();
            let mut be = StagedBackend::new_sparse(a.clone()).with_fill_cap(cap);
            let mut un = StagedBackend::new_sparse(a).with_fill_cap(cap);
            let mut rng = Rng::new(14);
            let x = Mat::randn(24, 4, &mut rng);
            let y0 = un.apply_a(x.as_ref());
            let z0 = un.apply_at(y0.as_ref());
            let mut y = Mat::zeros(40, 4);
            let mut z = Mat::zeros(24, 4);
            be.apply_ata_into(x.as_ref(), y.as_mut(), z.as_mut());
            assert_eq!(be.device_format(), Some(fmt));
            // Fused Aᵀ(A·Q) is bitwise the unfused staged composition:
            // the forward kernel is shared, and the padded intermediate
            // equals the unpad→repad roundtrip exactly (padding rows
            // are zero).
            assert_eq!(y.data(), y0.data(), "{fmt}: fused Y drifted");
            assert_eq!(z.data(), z0.data(), "{fmt}: fused Z drifted");
            let mut y2 = Mat::zeros(40, 4);
            let mut g = Mat::zeros(4, 4);
            be.apply_a_gram_into(x.as_ref(), y2.as_mut(), g.as_mut());
            assert_eq!(y2.data(), y0.data(), "{fmt}: fused-gram Y drifted");
            assert!(g.max_abs_diff(&mat_tn(&y0, &y0)) < 1e-11, "{fmt}: Gram drifted");
            assert!(y.max_abs_diff(&mat_nn(&ad, &x)) < 1e-12);
            assert!(be.operand_bytes() > 0);
            assert!(!be.operand_on_disk());
        }
    }

    #[test]
    fn lancsvd_ledger_zero_hot_panel_transfers() {
        let spec = SparseSpec { rows: 120, cols: 60, nnz: 1400, seed: 7, ..Default::default() };
        let a = generate(&spec);
        let mut be = StagedBackend::new_sparse(a.clone());
        let opts = LancSvdOpts { r: 16, p: 3, b: 8, wanted: 5, ..Default::default() };
        let svd = lancsvd(&mut be, &opts).unwrap();
        let t = be.ledger().totals();
        assert_eq!(t.hot_panel_transfers, 0, "hot-loop panels must stay resident: {t:?}");
        assert!(t.hot_factor_crossings > 0, "POTRF factor crossings expected: {t:?}");
        assert_eq!(t.plans, 1);
        let mut check = CpuBackend::new_sparse(a);
        let res = residuals(&mut check, &svd, 5);
        assert!(res.iter().all(|&x| x < 1e-4), "residuals {res:?}");
    }

    #[test]
    fn randsvd_ledger_zero_hot_panel_transfers_and_matches_cpu() {
        let spec = SparseSpec { rows: 100, cols: 50, nnz: 1000, seed: 9, ..Default::default() };
        let a = generate(&spec);
        let opts = RandSvdOpts { r: 12, p: 10, b: 4, seed: 5, ..Default::default() };
        let mut sbe = StagedBackend::new_sparse(a.clone());
        let svd_s = randsvd(&mut sbe, &opts).unwrap();
        assert_eq!(sbe.ledger().hot_panel_transfers(), 0);
        let mut cbe = CpuBackend::new_sparse(a);
        let svd_c = randsvd(&mut cbe, &opts).unwrap();
        // Same algorithm, same arithmetic order in every kernel the two
        // backends share — sigmas agree to rounding. (The Block-ELL SpMM
        // sums in a different order than CSR, so not bitwise.)
        for i in 0..6 {
            assert!(
                (svd_s.sigma[i] - svd_c.sigma[i]).abs() <= 1e-9 * svd_c.sigma[0],
                "sigma_{i}: staged {} cpu {}",
                svd_s.sigma[i],
                svd_c.sigma[i]
            );
        }
    }

    #[test]
    fn hot_panel_violation_panics() {
        let a = small_sparse(11);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let mut be = StagedBackend::new_sparse(a);
            be.plan(&Plan::lancsvd(40, 24, 8, 2, 4));
            be.profile_mut().set_phase(Block::MultA);
            // A fresh host matrix was never staged: reading it in a hot
            // phase is the contract violation the backend must reject.
            let x = Mat::from_fn(24, 4, |i, j| (i + j) as f64);
            let mut y = Mat::zeros(40, 4);
            be.apply_a_into(x.as_ref(), y.as_mut());
        }));
        let err = result.expect_err("unsanctioned hot-loop transfer must panic");
        let msg = err.downcast_ref::<String>().cloned().unwrap_or_default();
        assert!(msg.contains("unsanctioned"), "panic message: {msg}");
    }

    #[test]
    fn enforcement_off_counts_instead_of_panicking() {
        let a = small_sparse(12);
        let mut be = StagedBackend::new_sparse(a).enforce_transfers(false);
        be.plan(&Plan::lancsvd(40, 24, 8, 2, 4));
        be.profile_mut().set_phase(Block::MultA);
        let x = Mat::from_fn(24, 4, |i, j| (i * j) as f64);
        let mut y = Mat::zeros(40, 4);
        be.apply_a_into(x.as_ref(), y.as_mut());
        assert_eq!(be.ledger().hot_panel_transfers(), 1);
        // Staged via stage_in, the same read is clean.
        be.take_ledger();
        be.stage_in(x.as_ref());
        be.apply_a_into(x.as_ref(), y.as_mut());
        assert_eq!(be.ledger().hot_panel_transfers(), 0);
    }

    #[test]
    fn stage_in_and_copy_into_keep_panels_resident() {
        let a = small_sparse(13);
        let mut be = StagedBackend::new_sparse(a);
        be.plan(&Plan::randsvd(40, 24, 8, 2, 4));
        let x = Mat::from_fn(24, 8, |i, j| (i as f64) - (j as f64));
        be.stage_in(x.as_ref());
        let t0 = be.ledger().totals();
        assert_eq!(t0.h2a_count, 2, "operand staging + stage_in");
        // copy_into between resident and fresh arena destinations is
        // arena→arena traffic, not a host crossing.
        let mut dst = Mat::zeros(24, 8);
        be.copy_into(x.as_ref(), dst.as_mut());
        let t1 = be.ledger().totals();
        assert_eq!(t1.h2a_count, t0.h2a_count, "no new host crossing");
        assert!(t1.a2a_bytes > t0.a2a_bytes);
        // Re-staging resident data records nothing.
        be.stage_in(x.as_ref());
        assert_eq!(be.ledger().totals().h2a_count, t1.h2a_count);
    }

    #[test]
    fn dense_backend_stages_arena_copy() {
        let mut rng = Rng::new(21);
        let ad: Mat = Mat::randn(30, 18, &mut rng);
        let mut be = StagedBackend::new_dense(ad.clone());
        let x = Mat::randn(18, 3, &mut rng);
        let y = be.apply_a(x.as_ref());
        assert!(y.max_abs_diff(&mat_nn(&ad, &x)) < 1e-12);
        assert_eq!(be.device_format(), Some("dense"));
        assert_eq!(
            be.ledger().totals().staged_operand_bytes,
            (30 * 18 * std::mem::size_of::<f64>()) as u64
        );
        let z = Mat::randn(30, 3, &mut rng);
        let w = be.apply_at(z.as_ref());
        assert!(w.max_abs_diff(&mat_tn(&ad, &z)) < 1e-12);
    }

    #[test]
    fn f32_instantiation_solves() {
        let spec = SparseSpec { rows: 90, cols: 45, nnz: 900, seed: 17, ..Default::default() };
        let a: Csr<f32> = generate(&spec).cast();
        let mut be = StagedBackend::<f32>::new_sparse(a.clone());
        let opts = LancSvdOpts { r: 12, p: 3, b: 4, wanted: 4, ..Default::default() };
        let svd = lancsvd(&mut be, &opts).unwrap();
        assert_eq!(be.ledger().hot_panel_transfers(), 0);
        let mut check = CpuBackend::<f32>::new_sparse(a);
        let res = residuals(&mut check, &svd, 4);
        assert!(res.iter().all(|&x| x < 1e-3), "f32 residuals {res:?}");
    }

    #[test]
    fn event_log_is_bounded() {
        let mut led = TransferLedger::default();
        for _ in 0..(EVENT_CAP + 10) {
            led.record("x", Direction::HostToArena, 8, Block::Other, false);
        }
        assert_eq!(led.events().len(), EVENT_CAP);
        assert_eq!(led.dropped_events(), 10);
        assert_eq!(led.totals().h2a_count as usize, EVENT_CAP + 10);
    }
}
