//! PJRT/XLA backend: the GPU-library stand-in.
//!
//! Executes the paper's device building blocks through AOT-compiled
//! JAX/Pallas graphs (see `python/compile/`) on the PJRT CPU client — the
//! role cuBLAS/cuSPARSE play on the paper's A100. Semantics:
//!
//! * **Fused orthogonalization** — `orth_cholqr2` / `orth_cgs_cqr2`
//!   dispatch to the whole-graph artifacts (Gram→Cholesky→TRSM ×2 fused,
//!   with the b×b Cholesky *in-graph*), padding q to its power-of-two
//!   bucket and the history width s to its bucket with zeros (exact
//!   no-ops — asserted in the python tests). Breakdown is detected as
//!   NaN in the returned factor → fall back to the host path (which runs
//!   the paper's CGS2 fallback).
//! * **Dense multiplications** — A is staged once into a device-resident
//!   padded buffer; apply_a/apply_at run the matmul artifacts via
//!   `execute_b` (no per-call A transfer). Missing shapes fall back to
//!   runtime-built XlaBuilder GEMMs, then to the CPU substrate.
//! * **Sparse multiplications** — PJRT-CPU has no cuSPARSE analogue; CSR
//!   SpMM runs on the host substrate (the block-ELL Pallas kernel exists
//!   and is integration-tested, see `tests/test_xla_runtime.rs`, but CSR
//!   is the production path). The Aᵀ·X fallback carries the same
//!   adaptive cached-transpose strategy as the CPU backend. Documented
//!   in DESIGN.md §3.

use std::rc::Rc;

use super::{AdaptiveTranspose, Backend, Operand};
use crate::error::{Error, Result};
use crate::la::blas3;
use crate::la::mat::{Mat, MatRef};
use crate::metrics::{Profile, Timer};
use crate::runtime::convert::{literal_to_mat, mat_to_literal, pow2_bucket};
use crate::runtime::{builder_ops, Runtime};

/// Bucketing limits (mirror config/suite.json artifact_buckets).
const Q_MIN: usize = 512;
const Q_MAX: usize = 65536;
const S_MAX: usize = 256;
const B_ART: usize = 16;
const N_PAD: usize = 512;
const R_BUCKETS: [usize; 3] = [16, 64, 256];

/// The XLA/PJRT compute backend.
pub struct XlaBackend {
    rt: Rc<Runtime>,
    a: Operand,
    /// Device-resident padded A (dense operands only), shape m_pad×N_PAD.
    a_buf: Option<xla::PjRtBuffer>,
    /// Host literal backing `a_buf`. The PJRT CPU client copies from the
    /// literal *asynchronously* on its thread pool, so the source must
    /// outlive the buffer's first use — dropping it early is a
    /// use-after-free inside libxla_extension (observed SIGSEGV in
    /// AbstractTfrtCpuBuffer::CopyFromLiteral).
    _a_lit: Option<xla::Literal>,
    m_pad: usize,
    /// Adaptive cached transpose for the host-CSR Aᵀ·X fallback (PJRT
    /// CPU has no cuSPARSE analogue, so sparse products run on the host
    /// substrate — with the same scatter→cached-gather adaptivity as
    /// the CPU backend).
    at_cache: AdaptiveTranspose,
    profile: Profile,
}

fn r_bucket(r: usize) -> Option<usize> {
    R_BUCKETS.iter().copied().find(|&b| b >= r)
}

impl XlaBackend {
    /// Wrap a dense operand; stages the (padded) matrix to the device if
    /// an artifact family covers its shape.
    pub fn new_dense(rt: Rc<Runtime>, a: Mat) -> Result<XlaBackend> {
        let m_pad = pow2_bucket(a.rows(), Q_MIN, Q_MAX);
        let stageable = a.rows() <= m_pad && a.cols() <= N_PAD;
        let (a_buf, a_lit) = if stageable {
            let lit = mat_to_literal(&a, m_pad, N_PAD)?;
            let buf = rt.stage(&lit)?;
            (Some(buf), Some(lit))
        } else {
            (None, None)
        };
        Ok(XlaBackend {
            rt,
            a: Operand::Dense(a),
            a_buf,
            _a_lit: a_lit,
            m_pad,
            at_cache: AdaptiveTranspose::new(None),
            profile: Profile::new(),
        })
    }

    /// Wrap a sparse operand (CSR SpMM runs on the host substrate).
    pub fn new_sparse(rt: Rc<Runtime>, a: crate::sparse::csr::Csr) -> XlaBackend {
        XlaBackend {
            rt,
            a: Operand::Sparse(a),
            a_buf: None,
            _a_lit: None,
            m_pad: 0,
            at_cache: AdaptiveTranspose::from_env(),
            profile: Profile::new(),
        }
    }

    pub fn runtime(&self) -> &Runtime {
        &self.rt
    }

    /// Fused-orth artifact path for Alg. 4. Returns None when no artifact
    /// applies (wrong b, q too large) so the caller can fall back.
    fn try_cholqr2_artifact(&mut self, q: &mut Mat) -> Result<Option<Mat>> {
        let (qr, b) = (q.rows(), q.cols());
        if b != B_ART || qr > Q_MAX {
            return Ok(None);
        }
        let q_pad = pow2_bucket(qr, Q_MIN, Q_MAX);
        let in_shape = [q_pad, b];
        if !self.rt.has_artifact("cholqr2", &[&in_shape]) {
            return Ok(None);
        }
        let flops = crate::cost::ca4(b, qr);
        let t = Timer::start(flops);
        let lit = mat_to_literal(q, q_pad, b)?;
        let outs = self.rt.run_artifact("cholqr2", &[&in_shape], &[lit])?;
        let q_out = literal_to_mat(&outs[0], qr, b)?;
        let r_out = literal_to_mat(&outs[1], b, b)?;
        t.stop(&mut self.profile);
        if !mat_finite(&r_out) || !mat_finite(&q_out) {
            return Ok(None); // breakdown: NaN signal → host fallback
        }
        *q = q_out;
        Ok(Some(r_out))
    }

    /// Fused-orth artifact path for Alg. 5 (None → fall back).
    fn try_cgs_cqr2_artifact(
        &mut self,
        q: &mut Mat,
        p: MatRef<'_>,
    ) -> Result<Option<(Mat, Mat)>> {
        let (qr, b) = (q.rows(), q.cols());
        let s = p.cols;
        if b != B_ART || qr > Q_MAX || s > S_MAX {
            return Ok(None);
        }
        let q_pad = pow2_bucket(qr, Q_MIN, Q_MAX);
        let s_pad = pow2_bucket(s.max(16), 16, S_MAX);
        let q_shape = [q_pad, b];
        let p_shape = [q_pad, s_pad];
        if !self.rt.has_artifact("cgs_cqr2", &[&q_shape, &p_shape]) {
            return Ok(None);
        }
        let flops = crate::cost::ca5(b, qr, s);
        let t = Timer::start(flops);
        let ql = mat_to_literal(q, q_pad, b)?;
        let pl = mat_to_literal(&p.to_owned(), q_pad, s_pad)?;
        let outs = self.rt.run_artifact("cgs_cqr2", &[&q_shape, &p_shape], &[ql, pl])?;
        let q_out = literal_to_mat(&outs[0], qr, b)?;
        let h_out = literal_to_mat(&outs[1], s, b)?;
        let r_out = literal_to_mat(&outs[2], b, b)?;
        t.stop(&mut self.profile);
        if !mat_finite(&q_out) || !mat_finite(&r_out) {
            return Ok(None);
        }
        *q = q_out;
        Ok(Some((h_out, r_out)))
    }

    /// Dense apply through the staged buffer + matmul artifact.
    fn dense_apply_artifact(&mut self, x: MatRef<'_>, transposed: bool) -> Result<Option<Mat>> {
        let Operand::Dense(a) = &self.a else { return Ok(None) };
        let Some(a_buf) = &self.a_buf else { return Ok(None) };
        let (m, n) = (a.rows(), a.cols());
        let k = x.cols;
        let Some(k_pad) = r_bucket(k) else { return Ok(None) };
        let (op, a_shape, x_shape, out_rows) = if transposed {
            ("matmul_tn", [self.m_pad, N_PAD], [self.m_pad, k_pad], n)
        } else {
            ("matmul_nn", [self.m_pad, N_PAD], [N_PAD, k_pad], m)
        };
        if !self.rt.has_artifact(op, &[&a_shape, &x_shape]) {
            return Ok(None);
        }
        let xo = x.to_owned();
        let xl = mat_to_literal(&xo, x_shape[0], x_shape[1])?;
        let x_buf = self.rt.stage(&xl)?;
        let outs = self.rt.run_artifact_b(op, &[&a_shape, &x_shape], &[a_buf, &x_buf])?;
        let y = literal_to_mat(&outs[0], out_rows, k)?;
        Ok(Some(y))
    }
}

fn mat_finite(m: &Mat) -> bool {
    m.data().iter().all(|x| x.is_finite())
}

impl Backend for XlaBackend {
    fn m(&self) -> usize {
        self.a.shape().0
    }
    fn n(&self) -> usize {
        self.a.shape().1
    }
    fn nnz(&self) -> Option<usize> {
        self.a.nnz()
    }

    fn apply_a(&mut self, x: MatRef) -> Mat {
        let t = Timer::start(self.mult_flops(x.cols));
        let y = match self.dense_apply_artifact(x, false) {
            Ok(Some(y)) => y,
            _ => match &self.a {
                // Host CSR SpMM (documented substitution) or CPU fallback.
                Operand::Sparse(a) => {
                    let mut y = Mat::zeros(a.rows(), x.cols);
                    a.spmm(&x.to_owned(), &mut y);
                    y
                }
                Operand::Dense(a) => {
                    builder_ops::matmul_nn(&self.rt, a, &x.to_owned()).unwrap_or_else(|_| {
                        let mut y = Mat::zeros(a.rows(), x.cols);
                        blas3::gemm_nn(1.0, a.as_ref(), x, 0.0, &mut y);
                        y
                    })
                }
            },
        };
        t.stop(&mut self.profile);
        y
    }

    fn apply_at(&mut self, x: MatRef) -> Mat {
        let t = Timer::start(self.mult_flops(x.cols));
        let y = match self.dense_apply_artifact(x, true) {
            Ok(Some(y)) => y,
            _ => match &self.a {
                Operand::Sparse(a) => {
                    let xo = x.to_owned();
                    let mut y = Mat::zeros(a.cols(), x.cols);
                    match self.at_cache.advance(a, x.cols) {
                        Some(at) => at.spmm(&xo, &mut y),
                        None => a.spmm_t(&xo, &mut y),
                    }
                    y
                }
                Operand::Dense(a) => {
                    builder_ops::matmul_tn(&self.rt, a, &x.to_owned()).unwrap_or_else(|_| {
                        let mut y = Mat::zeros(a.cols(), x.cols);
                        blas3::gemm_tn(1.0, a.as_ref(), x, 0.0, &mut y);
                        y
                    })
                }
            },
        };
        t.stop(&mut self.profile);
        y
    }

    fn gram(&mut self, q: MatRef) -> Mat {
        // Fine-grained op (only reached on the host fallback path).
        let flops = q.cols as f64 * q.cols as f64 * q.rows as f64;
        let t = Timer::start(flops);
        let w = blas3::gram(q);
        t.stop(&mut self.profile);
        w
    }

    fn proj(&mut self, p: MatRef, q: MatRef) -> Mat {
        let flops = 2.0 * p.rows as f64 * p.cols as f64 * q.cols as f64;
        let t = Timer::start(flops);
        let mut h = Mat::zeros(p.cols, q.cols);
        blas3::gemm_tn(1.0, p, q, 0.0, &mut h);
        t.stop(&mut self.profile);
        h
    }

    fn subtract_proj(&mut self, q: &mut Mat, p: MatRef, h: &Mat) {
        let flops = 2.0 * p.rows as f64 * p.cols as f64 * h.cols() as f64;
        let t = Timer::start(flops);
        blas3::gemm_nn(-1.0, p, h.as_ref(), 1.0, q);
        t.stop(&mut self.profile);
    }

    fn tri_solve_right(&mut self, q: &mut Mat, l: &Mat) {
        let flops = q.cols() as f64 * q.cols() as f64 * q.rows() as f64;
        let t = Timer::start(flops);
        blas3::trsm_right_lt(l, q);
        t.stop(&mut self.profile);
    }

    fn gemm_nn(&mut self, a: MatRef, b: MatRef) -> Mat {
        let flops = 2.0 * a.rows as f64 * a.cols as f64 * b.cols as f64;
        let t = Timer::start(flops);
        // Runtime-built GEMM keeps this on the XLA path for any shape.
        let ao = a.to_owned();
        let bo = b.to_owned();
        let c = builder_ops::matmul_nn(&self.rt, &ao, &bo).unwrap_or_else(|_| {
            let mut c = Mat::zeros(a.rows, b.cols);
            blas3::gemm_nn(1.0, a, b, 0.0, &mut c);
            c
        });
        t.stop(&mut self.profile);
        c
    }

    fn orth_cholqr2(&mut self, q: &mut Mat) -> Result<Mat> {
        match self.try_cholqr2_artifact(q) {
            Ok(Some(r)) => Ok(r),
            Ok(None) => crate::algo::orth::cholqr2_host(self, q),
            Err(Error::Xla(_)) => {
                // Runtime trouble (missing file, compile failure): degrade
                // to the host path rather than abort the solve.
                crate::algo::orth::cholqr2_host(self, q)
            }
            Err(e) => Err(e),
        }
    }

    fn orth_cgs_cqr2(&mut self, q: &mut Mat, p: MatRef<'_>) -> Result<(Mat, Mat)> {
        match self.try_cgs_cqr2_artifact(q, p) {
            Ok(Some(hr)) => Ok(hr),
            Ok(None) => crate::algo::orth::cgs_cqr2_host(self, q, p),
            Err(Error::Xla(_)) => crate::algo::orth::cgs_cqr2_host(self, q, p),
            Err(e) => Err(e),
        }
    }

    fn profile_mut(&mut self) -> &mut Profile {
        &mut self.profile
    }

    fn take_profile(&mut self) -> Profile {
        std::mem::take(&mut self.profile)
    }

    fn name(&self) -> &'static str {
        "xla"
    }
}
