//! PJRT/XLA backend: the GPU-library stand-in.
//!
//! Executes the paper's device building blocks through AOT-compiled
//! JAX/Pallas graphs (see `python/compile/`) on the PJRT CPU client — the
//! role cuBLAS/cuSPARSE play on the paper's A100. Implements the
//! out-parameter [`Backend`] op set: the artifact paths stage host
//! literals in and out of PJRT (those transfers allocate — that is the
//! nature of this stand-in), then copy the result into the caller's
//! workspace buffer; every fallback path writes into the caller's
//! buffer directly through the CPU substrate. Semantics:
//!
//! * **Fused orthogonalization** — `orth_cholqr2_into` /
//!   `orth_cgs_cqr2_into` dispatch to the whole-graph artifacts
//!   (Gram→Cholesky→TRSM ×2 fused, with the b×b Cholesky *in-graph*),
//!   padding q to its power-of-two bucket and the history width s to its
//!   bucket with zeros (exact no-ops — asserted in the python tests).
//!   Breakdown is detected as NaN in the returned factor → fall back to
//!   the host path (which runs the paper's CGS2 fallback).
//! * **Dense multiplications** — A is staged once into a device-resident
//!   padded buffer; apply_a/apply_at run the matmul artifacts via
//!   `execute_b` (no per-call A transfer). Missing shapes fall back to
//!   runtime-built XlaBuilder GEMMs, then to the CPU substrate.
//! * **Sparse multiplications** — PJRT-CPU has no cuSPARSE analogue; CSR
//!   SpMM runs on the host substrate (the block-ELL Pallas kernel exists
//!   and is integration-tested, see `tests/test_xla_runtime.rs`, but CSR
//!   is the production path). The Aᵀ·X fallback carries the same
//!   adaptive cached-transpose strategy as the CPU backend (operand
//!   shared via `Arc`, pending build joined on drop). Documented in
//!   DESIGN.md §3.
//!
//! Generic over the element precision `S` (default f64). The PJRT
//! interchange literal is always f64 — the precision the artifacts were
//! lowered at — so an `S = f32` solve rounds through f64 on the
//! artifact/builder paths (values match a native-f32 device to f32
//! rounding, pinned by the conformance suite's ε-scaled tolerances); the
//! host fallback paths run natively at `S`. A runtime without a PJRT
//! client ([`Runtime::host_only`]) degrades every op to the host
//! substrate, which is how this backend runs in offline/stub builds.

use std::rc::Rc;
use std::sync::Arc;

use super::{AdaptiveTranspose, Backend, Operand};
use crate::error::{Error, Result};
use crate::la::blas3;
use crate::la::mat::{Mat, MatMut, MatRef};
use crate::la::workspace::{Plan, Workspace};
use crate::metrics::{Profile, Timer};
use crate::runtime::convert::{literal_to_mat_s, matref_to_literal_s, pow2_bucket};
use crate::runtime::{builder_ops, Runtime};
use crate::sparse::csr::Csr;
use crate::util::scalar::Scalar;

/// Bucketing limits (mirror config/suite.json artifact_buckets).
const Q_MIN: usize = 512;
const Q_MAX: usize = 65536;
const S_MAX: usize = 256;
const B_ART: usize = 16;
const N_PAD: usize = 512;
const R_BUCKETS: [usize; 3] = [16, 64, 256];

/// The XLA/PJRT compute backend (generic element precision; the device
/// interchange runs at f64 — see the module docs).
pub struct XlaBackend<S: Scalar = f64> {
    rt: Rc<Runtime>,
    a: Operand<S>,
    /// Device-resident padded A (dense operands only), shape m_pad×N_PAD.
    a_buf: Option<xla::PjRtBuffer>,
    /// Host literal backing `a_buf`. The PJRT CPU client copies from the
    /// literal *asynchronously* on its thread pool, so the source must
    /// outlive the buffer's first use — dropping it early is a
    /// use-after-free inside libxla_extension (observed SIGSEGV in
    /// AbstractTfrtCpuBuffer::CopyFromLiteral).
    _a_lit: Option<xla::Literal>,
    m_pad: usize,
    /// Adaptive cached transpose for the host-CSR Aᵀ·X fallback (PJRT
    /// CPU has no cuSPARSE analogue, so sparse products run on the host
    /// substrate — with the same scatter→cached-gather adaptivity as
    /// the CPU backend).
    at_cache: AdaptiveTranspose<S>,
    /// Plan of the current solve ([`Backend::plan`]); a real device
    /// target would stage per-shape buffers here.
    planned: Option<Plan>,
    profile: Profile,
}

fn r_bucket(r: usize) -> Option<usize> {
    R_BUCKETS.iter().copied().find(|&b| b >= r)
}

impl<S: Scalar> XlaBackend<S> {
    /// Wrap a dense operand; stages the (padded) matrix to the device if
    /// an artifact family covers its shape. A host-only runtime (no PJRT
    /// client) skips staging and runs on the fallback paths; a *real*
    /// client's staging failure still propagates — silent demotion to
    /// the host substrate would mask device faults.
    pub fn new_dense(rt: Rc<Runtime>, a: Mat<S>) -> Result<XlaBackend<S>> {
        let m_pad = pow2_bucket(a.rows(), Q_MIN, Q_MAX);
        let stageable = a.rows() <= m_pad && a.cols() <= N_PAD;
        let (a_buf, a_lit) = if stageable && rt.has_client() {
            let lit = matref_to_literal_s(a.as_ref(), m_pad, N_PAD)?;
            let buf = rt.stage(&lit)?;
            (Some(buf), Some(lit))
        } else {
            (None, None)
        };
        Ok(XlaBackend {
            rt,
            a: Operand::Dense(a),
            a_buf,
            _a_lit: a_lit,
            m_pad,
            at_cache: AdaptiveTranspose::new(None),
            planned: None,
            profile: Profile::new(),
        })
    }

    /// Wrap a sparse operand (CSR SpMM runs on the host substrate).
    pub fn new_sparse(rt: Rc<Runtime>, a: impl Into<Arc<Csr<S>>>) -> XlaBackend<S> {
        XlaBackend {
            rt,
            a: Operand::Sparse(a.into()),
            a_buf: None,
            _a_lit: None,
            m_pad: 0,
            at_cache: AdaptiveTranspose::from_env(),
            planned: None,
            profile: Profile::new(),
        }
    }

    /// Wrap either in-core operand kind. Sharded (out-of-core) operands
    /// are rejected: the AOT artifact paths need the whole operand as a
    /// device literal (use the cpu or staged backend to stream shards).
    pub fn new(rt: Rc<Runtime>, a: Operand<S>) -> Result<XlaBackend<S>> {
        match a {
            Operand::Dense(a) => XlaBackend::new_dense(rt, a),
            Operand::Sparse(a) => Ok(XlaBackend::new_sparse(rt, a)),
            Operand::Sharded { .. } => Err(crate::error::Error::InvalidParam(
                "the xla backend cannot stream a sharded operand; \
                 use --backend cpu or --backend staged"
                    .into(),
            )),
        }
    }

    pub fn runtime(&self) -> &Runtime {
        &self.rt
    }

    /// The plan recorded by the last [`Backend::plan`] call, if any.
    pub fn planned(&self) -> Option<&Plan> {
        self.planned.as_ref()
    }

    /// Fused-orth artifact path for Alg. 4. Returns None when no artifact
    /// applies (wrong b, q too large) so the caller can fall back.
    fn try_cholqr2_artifact(&mut self, q: &mut MatMut<'_, S>) -> Result<Option<Mat<S>>> {
        let (qr, b) = (q.rows, q.cols);
        if b != B_ART || qr > Q_MAX {
            return Ok(None);
        }
        let q_pad = pow2_bucket(qr, Q_MIN, Q_MAX);
        let in_shape = [q_pad, b];
        if !self.rt.has_artifact("cholqr2", &[&in_shape]) {
            return Ok(None);
        }
        let flops = crate::cost::ca4(b, qr);
        let t = Timer::start(flops);
        let lit = matref_to_literal_s(q.as_ref(), q_pad, b)?;
        let outs = self.rt.run_artifact("cholqr2", &[&in_shape], &[lit])?;
        let q_out: Mat<S> = literal_to_mat_s(&outs[0], qr, b)?;
        let r_out: Mat<S> = literal_to_mat_s(&outs[1], b, b)?;
        t.stop(&mut self.profile);
        if !mat_finite(&r_out) || !mat_finite(&q_out) {
            return Ok(None); // breakdown: NaN signal → host fallback
        }
        q.data.copy_from_slice(q_out.data());
        Ok(Some(r_out))
    }

    /// Fused-orth artifact path for Alg. 5 (None → fall back).
    fn try_cgs_cqr2_artifact(
        &mut self,
        q: &mut MatMut<'_, S>,
        p: MatRef<'_, S>,
    ) -> Result<Option<(Mat<S>, Mat<S>)>> {
        let (qr, b) = (q.rows, q.cols);
        let s = p.cols;
        if b != B_ART || qr > Q_MAX || s > S_MAX {
            return Ok(None);
        }
        let q_pad = pow2_bucket(qr, Q_MIN, Q_MAX);
        let s_pad = pow2_bucket(s.max(16), 16, S_MAX);
        let q_shape = [q_pad, b];
        let p_shape = [q_pad, s_pad];
        if !self.rt.has_artifact("cgs_cqr2", &[&q_shape, &p_shape]) {
            return Ok(None);
        }
        let flops = crate::cost::ca5(b, qr, s);
        let t = Timer::start(flops);
        let ql = matref_to_literal_s(q.as_ref(), q_pad, b)?;
        let pl = matref_to_literal_s(p, q_pad, s_pad)?;
        let outs = self.rt.run_artifact("cgs_cqr2", &[&q_shape, &p_shape], &[ql, pl])?;
        let q_out: Mat<S> = literal_to_mat_s(&outs[0], qr, b)?;
        let h_out: Mat<S> = literal_to_mat_s(&outs[1], s, b)?;
        let r_out: Mat<S> = literal_to_mat_s(&outs[2], b, b)?;
        t.stop(&mut self.profile);
        if !mat_finite(&q_out) || !mat_finite(&r_out) {
            return Ok(None);
        }
        q.data.copy_from_slice(q_out.data());
        Ok(Some((h_out, r_out)))
    }

    /// Dense apply through the staged buffer + matmul artifact.
    fn dense_apply_artifact(
        &mut self,
        x: MatRef<'_, S>,
        transposed: bool,
    ) -> Result<Option<Mat<S>>> {
        let Operand::Dense(a) = &self.a else { return Ok(None) };
        let Some(a_buf) = &self.a_buf else { return Ok(None) };
        let (m, n) = (a.rows(), a.cols());
        let k = x.cols;
        let Some(k_pad) = r_bucket(k) else { return Ok(None) };
        let (op, a_shape, x_shape, out_rows) = if transposed {
            ("matmul_tn", [self.m_pad, N_PAD], [self.m_pad, k_pad], n)
        } else {
            ("matmul_nn", [self.m_pad, N_PAD], [N_PAD, k_pad], m)
        };
        if !self.rt.has_artifact(op, &[&a_shape, &x_shape]) {
            return Ok(None);
        }
        let xl = matref_to_literal_s(x, x_shape[0], x_shape[1])?;
        let x_buf = self.rt.stage(&xl)?;
        let outs = self.rt.run_artifact_b(op, &[&a_shape, &x_shape], &[a_buf, &x_buf])?;
        let y = literal_to_mat_s(&outs[0], out_rows, k)?;
        Ok(Some(y))
    }
}

fn mat_finite<S: Scalar>(m: &Mat<S>) -> bool {
    m.data().iter().all(|x| x.is_finite())
}

impl<S: Scalar> Backend<S> for XlaBackend<S> {
    fn m(&self) -> usize {
        self.a.shape().0
    }
    fn n(&self) -> usize {
        self.a.shape().1
    }
    fn nnz(&self) -> Option<usize> {
        self.a.nnz()
    }

    fn plan(&mut self, plan: &Plan) {
        self.planned = Some(plan.clone());
    }

    fn apply_a_into(&mut self, x: MatRef<S>, mut y: MatMut<S>) {
        // Same out-shape contract the CPU kernels assert.
        assert_eq!((y.rows, y.cols), (self.m(), x.cols), "apply_a_into out shape");
        let t = Timer::start(self.mult_flops(x.cols));
        match self.dense_apply_artifact(x, false) {
            Ok(Some(out)) => y.data.copy_from_slice(out.data()),
            _ => match &self.a {
                // Host CSR SpMM (documented substitution) or CPU fallback.
                Operand::Sparse(a) => a.spmm(x, y),
                // Without a client the builder path cannot ever succeed:
                // skip its per-call operand clones and go straight host.
                Operand::Dense(a) if !self.rt.has_client() => {
                    blas3::gemm_nn(S::ONE, a.as_ref(), x, S::ZERO, y)
                }
                Operand::Dense(a) => match builder_ops::matmul_nn(&self.rt, a, &x.to_owned()) {
                    Ok(out) => y.data.copy_from_slice(out.data()),
                    Err(_) => blas3::gemm_nn(S::ONE, a.as_ref(), x, S::ZERO, y),
                },
            },
        }
        t.stop(&mut self.profile);
    }

    fn apply_at_into(&mut self, x: MatRef<S>, mut y: MatMut<S>) {
        assert_eq!((y.rows, y.cols), (self.n(), x.cols), "apply_at_into out shape");
        let t = Timer::start(self.mult_flops(x.cols));
        match self.dense_apply_artifact(x, true) {
            Ok(Some(out)) => y.data.copy_from_slice(out.data()),
            _ => match &self.a {
                Operand::Sparse(a) => match self.at_cache.advance(a, x.cols) {
                    Some(at) => at.spmm(x, y),
                    None => a.spmm_t(x, y),
                },
                Operand::Dense(a) if !self.rt.has_client() => {
                    blas3::gemm_tn(S::ONE, a.as_ref(), x, S::ZERO, y)
                }
                Operand::Dense(a) => match builder_ops::matmul_tn(&self.rt, a, &x.to_owned()) {
                    Ok(out) => y.data.copy_from_slice(out.data()),
                    Err(_) => blas3::gemm_tn(S::ONE, a.as_ref(), x, S::ZERO, y),
                },
            },
        }
        t.stop(&mut self.profile);
    }

    fn gram_into(&mut self, q: MatRef<S>, w: MatMut<S>) {
        // Fine-grained op (only reached on the host fallback path).
        let flops = q.cols as f64 * q.cols as f64 * q.rows as f64;
        let t = Timer::start(flops);
        blas3::gram_into(q, w);
        t.stop(&mut self.profile);
    }

    fn proj_into(&mut self, p: MatRef<S>, q: MatRef<S>, h: MatMut<S>) {
        let flops = 2.0 * p.rows as f64 * p.cols as f64 * q.cols as f64;
        let t = Timer::start(flops);
        blas3::gemm_tn(S::ONE, p, q, S::ZERO, h);
        t.stop(&mut self.profile);
    }

    fn subtract_proj(&mut self, q: MatMut<S>, p: MatRef<S>, h: MatRef<S>) {
        let flops = 2.0 * p.rows as f64 * p.cols as f64 * h.cols as f64;
        let t = Timer::start(flops);
        blas3::gemm_nn(-S::ONE, p, h, S::ONE, q);
        t.stop(&mut self.profile);
    }

    fn tri_solve_right(&mut self, q: MatMut<S>, l: MatRef<S>) {
        let flops = q.cols as f64 * q.cols as f64 * q.rows as f64;
        let t = Timer::start(flops);
        blas3::trsm_right_lt(l, q);
        t.stop(&mut self.profile);
    }

    fn gemm_nn_into(&mut self, a: MatRef<S>, b: MatRef<S>, mut c: MatMut<S>) {
        assert_eq!((c.rows, c.cols), (a.rows, b.cols), "gemm_nn_into out shape");
        let flops = 2.0 * a.rows as f64 * a.cols as f64 * b.cols as f64;
        let t = Timer::start(flops);
        if self.rt.has_client() {
            // Runtime-built GEMM keeps this on the XLA path for any shape.
            let ao = a.to_owned();
            let bo = b.to_owned();
            match builder_ops::matmul_nn(&self.rt, &ao, &bo) {
                Ok(out) => c.data.copy_from_slice(out.data()),
                Err(_) => blas3::gemm_nn(S::ONE, a, b, S::ZERO, c),
            }
        } else {
            blas3::gemm_nn(S::ONE, a, b, S::ZERO, c);
        }
        t.stop(&mut self.profile);
    }

    fn orth_cholqr2_into(
        &mut self,
        mut q: MatMut<S>,
        mut r: MatMut<S>,
        ws: &Workspace<S>,
    ) -> Result<()> {
        assert_eq!((r.rows, r.cols), (q.cols, q.cols), "orth_cholqr2_into R shape");
        match self.try_cholqr2_artifact(&mut q) {
            Ok(Some(r_out)) => {
                r.data.copy_from_slice(r_out.data());
                Ok(())
            }
            Ok(None) => crate::algo::orth::cholqr2_into_host(self, q, r, ws),
            Err(Error::Xla(_)) => {
                // Runtime trouble (missing file, compile failure): degrade
                // to the host path rather than abort the solve.
                crate::algo::orth::cholqr2_into_host(self, q, r, ws)
            }
            Err(e) => Err(e),
        }
    }

    fn orth_cgs_cqr2_into(
        &mut self,
        mut q: MatMut<S>,
        p: MatRef<'_, S>,
        mut h: MatMut<S>,
        mut r: MatMut<S>,
        ws: &Workspace<S>,
    ) -> Result<()> {
        assert_eq!((h.rows, h.cols), (p.cols, q.cols), "orth_cgs_cqr2_into H shape");
        assert_eq!((r.rows, r.cols), (q.cols, q.cols), "orth_cgs_cqr2_into R shape");
        match self.try_cgs_cqr2_artifact(&mut q, p) {
            Ok(Some((h_out, r_out))) => {
                h.data.copy_from_slice(h_out.data());
                r.data.copy_from_slice(r_out.data());
                Ok(())
            }
            Ok(None) => crate::algo::orth::cgs_cqr2_into_host(self, q, p, h, r, ws),
            Err(Error::Xla(_)) => crate::algo::orth::cgs_cqr2_into_host(self, q, p, h, r, ws),
            Err(e) => Err(e),
        }
    }

    fn profile_mut(&mut self) -> &mut Profile {
        &mut self.profile
    }

    fn take_profile(&mut self) -> Profile {
        std::mem::take(&mut self.profile)
    }

    fn name(&self) -> &'static str {
        "xla"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::la::blas3::{mat_nn, mat_tn};
    use crate::util::rng::Rng;

    #[test]
    fn host_only_runtime_runs_dense_fallbacks() {
        // No PJRT client: construction succeeds (staging degrades) and
        // every op lands on the host substrate with correct numbers.
        let rt = Rc::new(Runtime::host_only());
        let mut rng = Rng::new(1);
        let ad: Mat = Mat::randn(60, 20, &mut rng);
        let mut be = XlaBackend::new_dense(rt, ad.clone()).unwrap();
        assert!(be.a_buf.is_none(), "staging must degrade without a client");
        let x = Mat::randn(20, 4, &mut rng);
        assert!(be.apply_a(x.as_ref()).max_abs_diff(&mat_nn(&ad, &x)) < 1e-12);
        let z = Mat::randn(60, 4, &mut rng);
        assert!(be.apply_at(z.as_ref()).max_abs_diff(&mat_tn(&ad, &z)) < 1e-12);
        let mut q = Mat::randn(60, 8, &mut rng);
        let r = be.orth_cholqr2(&mut q).unwrap();
        assert!(crate::la::norms::orth_error(&q) < 1e-12);
        assert_eq!((r.rows(), r.cols()), (8, 8));
    }

    #[test]
    fn host_only_runtime_runs_f32() {
        let rt = Rc::new(Runtime::host_only());
        let mut rng = Rng::new(2);
        let ad: Mat<f32> = Mat::randn(40, 16, &mut rng);
        let mut be = XlaBackend::<f32>::new_dense(rt, ad.clone()).unwrap();
        let x: Mat<f32> = Mat::randn(16, 3, &mut rng);
        let y = be.apply_a(x.as_ref());
        let mut expect: Mat<f32> = Mat::zeros(40, 3);
        blas3::gemm_nn(1.0f32, ad.as_ref(), x.as_ref(), 0.0f32, expect.as_mut());
        assert!(y.max_abs_diff(&expect) < 1e-5);
        assert_eq!(be.name(), "xla");
    }

    #[test]
    fn plan_hook_records_plan() {
        let rt = Rc::new(Runtime::host_only());
        let mut be = XlaBackend::new_dense(rt, Mat::<f64>::zeros(30, 10)).unwrap();
        assert!(be.planned().is_none());
        let plan = Plan::randsvd(30, 10, 6, 2, 3);
        be.plan(&plan);
        let seen = be.planned().expect("plan recorded");
        assert_eq!((seen.m, seen.n, seen.r, seen.b), (30, 10, 6, 3));
    }
}
