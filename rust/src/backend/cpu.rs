//! Pure-rust reference backend.
//!
//! Implements the out-parameter [`Backend`] op set over the native
//! dense/sparse substrates: every `*_into` kernel writes straight into
//! the caller's workspace buffer (or basis-panel view), so the
//! steady-state inner iterations of both algorithms run with **zero
//! heap allocations** on this backend — the property the counting-
//! allocator test and the `BENCH_ASSERT_NOALLOC` gate pin.
//!
//! The transposed product `apply_at_into` starts on the scatter kernel
//! (the cuSPARSE-like "implicit transpose" the paper identifies as the
//! bottleneck) and *adaptively* switches to a pre-transposed CSR copy
//! built on a background thread once enough Aᵀ·X calls have been
//! observed (paper §4.1.2's explicit-copy trade-off, amortized; the
//! operand is shared into the builder via `Arc`, and a pending build is
//! joined on drop). [`CpuBackend::with_explicit_transpose`] builds the
//! copy eagerly and [`CpuBackend::scatter_only`] pins the scatter
//! baseline — both are kept so the ablation benches can compare all
//! three strategies.

use super::{AdaptiveTranspose, Backend, Operand};
use crate::la::blas3;
use crate::la::mat::{Mat, MatMut, MatRef};
use crate::la::workspace::Plan;
use crate::metrics::{Profile, Timer};
use crate::sparse::csr::Csr;
use crate::sparse::shard::{ShardStats, ShardedOperand};
use crate::util::scalar::Scalar;

/// Reference CPU backend, generic over the element precision (default
/// f64; `CpuBackend<f32>` is the paper's single-precision regime).
pub struct CpuBackend<S: Scalar = f64> {
    a: Operand<S>,
    /// Explicit-Aᵀ strategy state (adaptive by default).
    at: AdaptiveTranspose<S>,
    /// The plan of the current solve, recorded by [`Backend::plan`].
    /// The CPU backend needs no device staging — the caller's workspace
    /// buffers are its "device memory" — but keeping the plan makes the
    /// hook observable (tests) and feeds future per-plan tuning.
    planned: Option<Plan>,
    /// Streaming state for an [`Operand::Sharded`] operand (loader
    /// thread, pin cache, stats); `None` for in-core operands.
    sharded: Option<ShardedOperand<S>>,
    profile: Profile,
}

impl<S: Scalar> CpuBackend<S> {
    pub fn new_sparse(a: impl Into<std::sync::Arc<Csr<S>>>) -> CpuBackend<S> {
        CpuBackend {
            a: Operand::Sparse(a.into()),
            at: AdaptiveTranspose::from_env(),
            planned: None,
            sharded: None,
            profile: Profile::new(),
        }
    }

    pub fn new_dense(a: Mat<S>) -> CpuBackend<S> {
        CpuBackend {
            a: Operand::Dense(a),
            at: AdaptiveTranspose::new(None),
            planned: None,
            sharded: None,
            profile: Profile::new(),
        }
    }

    pub fn new(a: Operand<S>) -> CpuBackend<S> {
        match a {
            Operand::Sparse(a) => CpuBackend::new_sparse(a),
            Operand::Dense(a) => CpuBackend::new_dense(a),
            Operand::Sharded { dir, resident_cap } => CpuBackend {
                sharded: Some(ShardedOperand::new(std::sync::Arc::clone(&dir), resident_cap)),
                a: Operand::Sharded { dir, resident_cap },
                // No in-core copy exists to transpose; Aᵀ·X always runs
                // the streaming scatter (bitwise-identical to in-core
                // scatter-only at a fixed thread count).
                at: AdaptiveTranspose::new(None),
                planned: None,
                profile: Profile::new(),
            },
        }
    }

    /// For sharded operands: validate the resident cap and stage the
    /// pin prefix + loader thread now, so cap misconfiguration surfaces
    /// as an `Err` at build time instead of a panic inside the first
    /// (infallible) solve op. No-op for in-core operands.
    pub fn ensure_operand_resident(&mut self) -> crate::error::Result<()> {
        match &mut self.sharded {
            Some(op) => op.ensure_resident(),
            None => Ok(()),
        }
    }

    /// Streaming counters of a sharded operand (`None` when in-core).
    pub fn shard_stats(&self) -> Option<ShardStats> {
        self.sharded.as_ref().map(|op| op.stats())
    }

    /// Store an explicit transposed CSR copy *eagerly* and use
    /// gather-SpMM for every Aᵀ·X (paper §4.1.2: "explicitly storing a
    /// transposed copy of the sparse matrix"). No-op for dense operands.
    pub fn with_explicit_transpose(mut self) -> CpuBackend<S> {
        if let Operand::Sparse(a) = &self.a {
            self.at = AdaptiveTranspose::with_built(a.transpose());
        }
        self
    }

    /// Disable the adaptive transpose: every Aᵀ·X stays on the scatter
    /// kernel (the ablation baseline).
    pub fn scatter_only(mut self) -> CpuBackend<S> {
        self.at = AdaptiveTranspose::new(None);
        self
    }

    /// Override the adaptive threshold (number of scatter Aᵀ·X calls
    /// before the background transpose build starts).
    pub fn with_adaptive_threshold(mut self, after: usize) -> CpuBackend<S> {
        self.at = AdaptiveTranspose::new(Some(after));
        self
    }

    pub fn operand(&self) -> &Operand<S> {
        &self.a
    }

    /// The plan recorded by the last [`Backend::plan`] call, if any.
    pub fn planned(&self) -> Option<&Plan> {
        self.planned.as_ref()
    }
}

impl<S: Scalar> Backend<S> for CpuBackend<S> {
    fn m(&self) -> usize {
        self.a.shape().0
    }
    fn n(&self) -> usize {
        self.a.shape().1
    }
    fn nnz(&self) -> Option<usize> {
        self.a.nnz()
    }

    fn plan(&mut self, plan: &Plan) {
        self.planned = Some(plan.clone());
    }

    fn apply_a_into(&mut self, x: MatRef<S>, mut y: MatMut<S>) {
        let t = Timer::start(self.mult_flops(x.cols));
        match &self.a {
            Operand::Sparse(a) => a.spmm(x, y),
            Operand::Dense(a) => blas3::gemm_nn(S::ONE, a.as_ref(), x, S::ZERO, y),
            Operand::Sharded { .. } => self
                .sharded
                .as_mut()
                .expect("sharded operand state")
                .spmm(x, &mut y)
                .expect("sharded operand I/O during apply_a"),
        }
        t.stop(&mut self.profile);
    }

    fn apply_at_into(&mut self, x: MatRef<S>, mut y: MatMut<S>) {
        let t = Timer::start(self.mult_flops(x.cols));
        match &self.a {
            Operand::Sparse(a) => match self.at.advance(a, x.cols) {
                Some(at) => at.spmm(x, y),
                None => a.spmm_t(x, y),
            },
            Operand::Dense(a) => blas3::gemm_tn(S::ONE, a.as_ref(), x, S::ZERO, y),
            Operand::Sharded { .. } => self
                .sharded
                .as_mut()
                .expect("sharded operand state")
                .spmm_t(x, &mut y)
                .expect("sharded operand I/O during apply_at"),
        }
        t.stop(&mut self.profile);
    }

    // ---- fused operand-pass tier (contract rule 8) ----

    fn apply_a_gram_into(&mut self, x: MatRef<S>, mut y: MatMut<S>, mut g: MatMut<S>) {
        let k = x.cols;
        let flops = self.mult_flops(k) + k as f64 * k as f64 * y.rows as f64;
        let t = Timer::start(flops);
        match &self.a {
            Operand::Sparse(a) => a.spmm_gram(x, y, g),
            Operand::Dense(a) => {
                blas3::gemm_nn(S::ONE, a.as_ref(), x, S::ZERO, y.reborrow());
                blas3::gram_into(y.as_ref(), g);
            }
            Operand::Sharded { .. } => self
                .sharded
                .as_mut()
                .expect("sharded operand state")
                .spmm_gram(x, &mut y, &mut g)
                .expect("sharded operand I/O during apply_a_gram"),
        }
        t.stop(&mut self.profile);
    }

    fn apply_ata_into(&mut self, x: MatRef<S>, mut y: MatMut<S>, mut z: MatMut<S>) {
        let t = Timer::start(2.0 * self.mult_flops(x.cols));
        match &self.a {
            // Deliberately does NOT consult the adaptive transpose: the
            // fused sweep must stay on the band-serial gather+scatter so
            // a background-build adoption can never flip the numerics
            // mid-solve (rule 8 determinism).
            Operand::Sparse(a) => a.spmm_ata(x, y, z),
            Operand::Dense(a) => {
                blas3::gemm_nn(S::ONE, a.as_ref(), x, S::ZERO, y.reborrow());
                blas3::gemm_tn(S::ONE, a.as_ref(), y.as_ref(), S::ZERO, z);
            }
            Operand::Sharded { .. } => self
                .sharded
                .as_mut()
                .expect("sharded operand state")
                .spmm_ata(x, &mut y, &mut z)
                .expect("sharded operand I/O during apply_ata"),
        }
        t.stop(&mut self.profile);
    }

    fn operand_bytes(&self) -> usize {
        match &self.a {
            Operand::Sparse(a) => {
                a.nnz() * (std::mem::size_of::<S>() + 4) + 8 * (a.rows() + 1)
            }
            Operand::Dense(a) => a.rows() * a.cols() * std::mem::size_of::<S>(),
            Operand::Sharded { dir, .. } => dir.total_file_bytes(),
        }
    }

    fn operand_on_disk(&self) -> bool {
        matches!(self.a, Operand::Sharded { .. })
    }

    fn gram_into(&mut self, q: MatRef<S>, w: MatMut<S>) {
        let flops = q.cols as f64 * q.cols as f64 * q.rows as f64; // syrk: b²q
        let t = Timer::start(flops);
        blas3::gram_into(q, w);
        t.stop(&mut self.profile);
    }

    fn proj_into(&mut self, p: MatRef<S>, q: MatRef<S>, h: MatMut<S>) {
        let flops = 2.0 * p.rows as f64 * p.cols as f64 * q.cols as f64;
        let t = Timer::start(flops);
        blas3::gemm_tn(S::ONE, p, q, S::ZERO, h);
        t.stop(&mut self.profile);
    }

    fn subtract_proj(&mut self, q: MatMut<S>, p: MatRef<S>, h: MatRef<S>) {
        let flops = 2.0 * p.rows as f64 * p.cols as f64 * h.cols as f64;
        let t = Timer::start(flops);
        blas3::gemm_nn(-S::ONE, p, h, S::ONE, q);
        t.stop(&mut self.profile);
    }

    fn tri_solve_right(&mut self, q: MatMut<S>, l: MatRef<S>) {
        let flops = q.cols as f64 * q.cols as f64 * q.rows as f64; // b²q
        let t = Timer::start(flops);
        blas3::trsm_right_lt(l, q);
        t.stop(&mut self.profile);
    }

    fn gemm_nn_into(&mut self, a: MatRef<S>, b: MatRef<S>, c: MatMut<S>) {
        let flops = 2.0 * a.rows as f64 * a.cols as f64 * b.cols as f64;
        let t = Timer::start(flops);
        blas3::gemm_nn(S::ONE, a, b, S::ZERO, c);
        t.stop(&mut self.profile);
    }

    fn profile_mut(&mut self) -> &mut Profile {
        &mut self.profile
    }

    fn take_profile(&mut self) -> Profile {
        std::mem::take(&mut self.profile)
    }

    fn name(&self) -> &'static str {
        if self.sharded.is_some() {
            "cpu-ooc"
        } else if self.at.built() {
            "cpu+expT"
        } else if self.at.enabled() || matches!(self.a, Operand::Dense(_)) {
            "cpu"
        } else {
            "cpu-scatter"
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::la::blas3::{mat_nn, mat_tn};
    use crate::metrics::Block;
    use crate::sparse::coo::Coo;
    use crate::util::rng::Rng;

    fn small_sparse(seed: u64) -> Csr {
        let mut rng = Rng::new(seed);
        let mut coo = Coo::new(20, 12);
        for _ in 0..60 {
            coo.push(rng.below(20), rng.below(12), rng.normal());
        }
        Csr::from_coo(&coo).unwrap()
    }

    #[test]
    fn sparse_ops_match_dense_reference() {
        let a = small_sparse(1);
        let ad = a.to_dense();
        let mut be = CpuBackend::new_sparse(a);
        let mut rng = Rng::new(2);
        let x = Mat::randn(12, 4, &mut rng);
        let y = be.apply_a(x.as_ref());
        assert!(y.max_abs_diff(&mat_nn(&ad, &x)) < 1e-12);
        let z = Mat::randn(20, 4, &mut rng);
        let w = be.apply_at(z.as_ref());
        assert!(w.max_abs_diff(&mat_tn(&ad, &z)) < 1e-12);
    }

    #[test]
    fn into_ops_write_into_panels() {
        // The out-parameter forms target arbitrary panel views — here
        // the middle columns of a wider buffer — without disturbing the
        // rest of the buffer.
        let a = small_sparse(21);
        let ad = a.to_dense();
        let mut be = CpuBackend::new_sparse(a);
        let mut rng = Rng::new(22);
        let x = Mat::randn(12, 2, &mut rng);
        let mut buf = Mat::from_fn(20, 4, |_, _| 7.0);
        be.apply_a_into(x.as_ref(), buf.panel_mut(1, 2));
        let expect = mat_nn(&ad, &x);
        for j in 0..2 {
            for i in 0..20 {
                assert!((buf.at(i, 1 + j) - expect.at(i, j)).abs() < 1e-12, "({i},{j})");
            }
        }
        // untouched columns keep their sentinel
        assert!(buf.col(0).iter().all(|&v| v == 7.0));
        assert!(buf.col(3).iter().all(|&v| v == 7.0));
    }

    #[test]
    fn plan_hook_records_plan() {
        let mut be = CpuBackend::new_dense(Mat::zeros(30, 10));
        assert!(be.planned().is_none());
        let plan = Plan::lancsvd(30, 10, 8, 2, 4);
        be.plan(&plan);
        let seen = be.planned().expect("plan recorded");
        assert_eq!((seen.m, seen.n, seen.r, seen.b), (30, 10, 8, 4));
    }

    #[test]
    fn explicit_transpose_same_numbers() {
        let a = small_sparse(3);
        let mut b1 = CpuBackend::new_sparse(a.clone()).scatter_only();
        let mut b2 = CpuBackend::new_sparse(a).with_explicit_transpose();
        let mut rng = Rng::new(4);
        let z = Mat::randn(20, 3, &mut rng);
        let w1 = b1.apply_at(z.as_ref());
        let w2 = b2.apply_at(z.as_ref());
        assert!(w1.max_abs_diff(&w2) < 1e-12);
        assert_eq!(b1.name(), "cpu-scatter");
        assert_eq!(b2.name(), "cpu+expT");
    }

    #[test]
    fn adaptive_transpose_adopts_in_background() {
        let a = small_sparse(8);
        let ad = a.to_dense();
        let mut be = CpuBackend::new_sparse(a).with_adaptive_threshold(1);
        let mut rng = Rng::new(9);
        let z = Mat::randn(20, 3, &mut rng);
        let expect = mat_tn(&ad, &z);
        assert_eq!(be.name(), "cpu");
        // Keep issuing Aᵀ·X; results must stay exact through the scatter
        // → cached-gather switch, which happens once the background
        // build finishes.
        for _ in 0..400 {
            let w = be.apply_at(z.as_ref());
            assert!(w.max_abs_diff(&expect) < 1e-12);
            if be.name() == "cpu+expT" {
                return;
            }
            std::thread::sleep(std::time::Duration::from_millis(2));
        }
        panic!("adaptive transpose was never adopted");
    }

    #[test]
    fn drop_before_adoption_is_clean() {
        // A backend dropped while its background transpose build is
        // pending must join the builder (no detached thread, no panic).
        let a = small_sparse(15);
        let mut be = CpuBackend::new_sparse(a).with_adaptive_threshold(0);
        let mut rng = Rng::new(16);
        let z = Mat::randn(20, 2, &mut rng);
        let _ = be.apply_at(z.as_ref()); // spawns the build
        drop(be);
    }

    #[test]
    fn scatter_only_never_builds() {
        let a = small_sparse(10);
        let ad = a.to_dense();
        let mut be = CpuBackend::new_sparse(a).scatter_only();
        let mut rng = Rng::new(11);
        let z = Mat::randn(20, 2, &mut rng);
        let expect = mat_tn(&ad, &z);
        for _ in 0..32 {
            let w = be.apply_at(z.as_ref());
            assert!(w.max_abs_diff(&expect) < 1e-12);
        }
        assert_eq!(be.name(), "cpu-scatter");
    }

    #[test]
    fn fused_ops_match_composition() {
        let a = small_sparse(30);
        let ad = a.to_dense();
        let mut be = CpuBackend::new_sparse(a);
        let mut rng = Rng::new(31);
        let x = Mat::randn(12, 3, &mut rng);
        let y0 = mat_nn(&ad, &x);
        let mut y = Mat::zeros(20, 3);
        let mut g = Mat::zeros(3, 3);
        be.apply_a_gram_into(x.as_ref(), y.as_mut(), g.as_mut());
        assert!(y.max_abs_diff(&y0) < 1e-12);
        assert!(g.max_abs_diff(&mat_tn(&y0, &y0)) < 1e-11);
        let mut y2 = Mat::zeros(20, 3);
        let mut z = Mat::zeros(12, 3);
        be.apply_ata_into(x.as_ref(), y2.as_mut(), z.as_mut());
        assert!(y2.max_abs_diff(&y0) < 1e-12);
        assert!(z.max_abs_diff(&mat_tn(&ad, &y0)) < 1e-11);
        assert!(be.operand_bytes() > 0);
        assert!(!be.operand_on_disk());
        // Dense operand takes the two-gemm fused arm.
        let mut bd = CpuBackend::new_dense(ad.clone());
        let mut yd = Mat::zeros(20, 3);
        let mut zd = Mat::zeros(12, 3);
        bd.apply_ata_into(x.as_ref(), yd.as_mut(), zd.as_mut());
        assert!(zd.max_abs_diff(&z) < 1e-11);
        assert_eq!(bd.operand_bytes(), 20 * 12 * std::mem::size_of::<f64>());
    }

    #[test]
    fn profile_collects_phase_flops() {
        let a = small_sparse(5);
        let nz = a.nnz() as f64;
        let mut be = CpuBackend::new_sparse(a);
        be.profile_mut().set_phase(Block::MultA);
        let mut rng = Rng::new(6);
        let x = Mat::randn(12, 4, &mut rng);
        let _ = be.apply_a(x.as_ref());
        let p = be.take_profile();
        assert_eq!(p.stat(Block::MultA).calls, 1);
        assert!((p.stat(Block::MultA).flops - 2.0 * nz * 4.0).abs() < 1e-9);
        // take_profile reset
        assert_eq!(be.profile_mut().stat(Block::MultA).calls, 0);
    }

    #[test]
    fn dense_backend_ops() {
        let mut rng = Rng::new(7);
        let ad = Mat::randn(15, 9, &mut rng);
        let mut be = CpuBackend::new_dense(ad.clone());
        assert_eq!((be.m(), be.n()), (15, 9));
        assert_eq!(be.nnz(), None);
        let x = Mat::randn(9, 2, &mut rng);
        assert!(be.apply_a(x.as_ref()).max_abs_diff(&mat_nn(&ad, &x)) < 1e-12);
        let q = Mat::randn(15, 3, &mut rng);
        let w = be.gram(q.as_ref());
        assert!(w.max_abs_diff(&mat_tn(&q, &q)) < 1e-12);
        assert_eq!(be.name(), "cpu");
    }
}
