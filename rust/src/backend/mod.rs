//! Compute-backend abstraction.
//!
//! The paper builds RandSVD and LancSVD from a fixed set of device
//! building blocks (Table 1): multiplications with A/Aᵀ (cuSPARSE SpMM or
//! cuBLAS GEMM), Gram products, CGS projections, and right-side triangular
//! solves — with the tiny POTRF/GESVD factorizations staying on the host.
//!
//! [`Backend`] is exactly that op set. Two implementations exist:
//! [`cpu::CpuBackend`] (pure-rust substrate, the reference) and
//! [`xla::XlaBackend`] (AOT JAX/Pallas artifacts through PJRT — the
//! GPU-library stand-in). All operands are host `Mat`s; backends may stage
//! them to device buffers internally.
//!
//! Every op self-records wall time and Table-1 flops into the backend's
//! [`Profile`] under the phase set by the running algorithm, which is how
//! Figs. 2–3's breakdowns are measured.

pub mod cpu;
pub mod xla;

use crate::la::mat::{Mat, MatRef};
use crate::metrics::Profile;

/// The device building-block set shared by both SVD algorithms.
pub trait Backend {
    /// Problem row count (m).
    fn m(&self) -> usize;
    /// Problem column count (n).
    fn n(&self) -> usize;
    /// Non-zeros if the operand is sparse, `None` for dense.
    fn nnz(&self) -> Option<usize>;

    /// Y = A · X  with X n×k (SpMM / GEMM).
    fn apply_a(&mut self, x: MatRef) -> Mat;
    /// Y = Aᵀ · X  with X m×k (transposed SpMM / GEMM).
    fn apply_at(&mut self, x: MatRef) -> Mat;
    /// W = QᵀQ (SYRK-shaped Gram product).
    fn gram(&mut self, q: MatRef) -> Mat;
    /// H = PᵀQ (block-CGS projection).
    fn proj(&mut self, p: MatRef, q: MatRef) -> Mat;
    /// Q ← Q − P·H (block-CGS update).
    fn subtract_proj(&mut self, q: &mut Mat, p: MatRef, h: &Mat);
    /// Q ← Q·L⁻ᵀ with L lower-triangular b×b (the TRSM of CholeskyQR2).
    fn tri_solve_right(&mut self, q: &mut Mat, l: &Mat);
    /// C = A·B (the finalize GEMMs forming U_T / V_T and the restart).
    fn gemm_nn(&mut self, a: MatRef, b: MatRef) -> Mat;

    /// CholeskyQR2 orthonormalization of a q×b panel (Alg. 4), returning
    /// R with `Q_in = Q_out·R`. The default composes the fine-grained ops
    /// with the host POTRF; the XLA backend overrides it with the fused
    /// AOT graph (falling back here on breakdown or unbucketable shapes).
    fn orth_cholqr2(&mut self, q: &mut Mat) -> crate::error::Result<Mat> {
        crate::algo::orth::cholqr2_host(self, q)
    }

    /// CGS + CholeskyQR2 orthogonalization against a history panel
    /// (Alg. 5), returning (H, R) with `Q_in ≈ P·H + Q_out·R`. Override
    /// semantics as for [`Backend::orth_cholqr2`].
    fn orth_cgs_cqr2(
        &mut self,
        q: &mut Mat,
        p: MatRef<'_>,
    ) -> crate::error::Result<(Mat, Mat)> {
        crate::algo::orth::cgs_cqr2_host(self, q, p)
    }

    /// The per-block profile (phase is set by the algorithms).
    fn profile_mut(&mut self) -> &mut Profile;
    /// Take the accumulated profile, resetting it.
    fn take_profile(&mut self) -> Profile;
    /// Short backend name for reports.
    fn name(&self) -> &'static str;

    /// Flop cost of one apply_a/apply_at with k dense columns (used both
    /// for instrumentation and by the analytic model).
    fn mult_flops(&self, k: usize) -> f64 {
        match self.nnz() {
            Some(nz) => 2.0 * nz as f64 * k as f64,
            None => 2.0 * self.m() as f64 * self.n() as f64 * k as f64,
        }
    }
}

/// Adaptive explicit-transpose cache for the sparse Aᵀ·X path.
///
/// The paper mitigates the scatter SpMMᵀ bottleneck by "explicitly
/// storing a transposed copy of the sparse matrix" (§4.1.2), trading
/// nnz memory for gather-speed products. This helper makes that trade
/// adaptive: after `after` scatter calls (default
/// `TRUNKSVD_ADAPTIVE_SPMMT`, see [`AdaptiveTranspose::from_env`]) the
/// transposed CSR copy is built on a background thread and adopted as
/// soon as it is ready, so no Aᵀ·X call ever waits on the build. Both
/// backends embed one; the ablation benches disable it (`new(None)`) to
/// keep the pure-scatter baseline measurable.
pub(crate) struct AdaptiveTranspose {
    at: Option<crate::sparse::csr::Csr>,
    pending: Option<std::thread::JoinHandle<crate::sparse::csr::Csr>>,
    calls: usize,
    after: Option<usize>,
}

impl AdaptiveTranspose {
    /// `after` = number of scatter calls before the build starts;
    /// `None` disables the adaptive build (pure-scatter baseline).
    pub fn new(after: Option<usize>) -> AdaptiveTranspose {
        AdaptiveTranspose { at: None, pending: None, calls: 0, after }
    }

    /// Threshold from `TRUNKSVD_ADAPTIVE_SPMMT` (default 4 scatter calls
    /// — one LancSVD restart touches Aᵀ well past that, while one-shot
    /// uses never pay the transpose).
    pub fn from_env() -> AdaptiveTranspose {
        let after = std::env::var("TRUNKSVD_ADAPTIVE_SPMMT")
            .ok()
            .and_then(|s| s.parse::<usize>().ok())
            .unwrap_or(4);
        AdaptiveTranspose::new(Some(after))
    }

    /// Wrap an eagerly built transpose (the paper's always-on variant).
    pub fn with_built(at: crate::sparse::csr::Csr) -> AdaptiveTranspose {
        AdaptiveTranspose { at: Some(at), pending: None, calls: 0, after: None }
    }

    /// Record one Aᵀ·X call against operand `a`; returns the cached
    /// transpose if it is available (caller then uses gather-SpMM).
    pub fn advance(&mut self, a: &crate::sparse::csr::Csr) -> Option<&crate::sparse::csr::Csr> {
        if self.at.is_none() {
            if let Some(h) = &self.pending {
                if h.is_finished() {
                    let h = self.pending.take().expect("pending checked above");
                    self.at = Some(h.join().expect("transpose builder panicked"));
                }
            } else if self.after.is_some_and(|n| self.calls >= n) {
                let a = a.clone();
                self.pending = Some(std::thread::spawn(move || a.transpose()));
            }
        }
        self.calls += 1;
        self.at.as_ref()
    }

    /// Is the transposed copy adopted (i.e. Aᵀ·X now runs as gather)?
    pub fn built(&self) -> bool {
        self.at.is_some()
    }

    /// Is the adaptive build enabled at all?
    pub fn enabled(&self) -> bool {
        self.after.is_some() || self.at.is_some()
    }
}

/// The operand matrix a backend is constructed around.
#[derive(Clone, Debug)]
pub enum Operand {
    Sparse(crate::sparse::csr::Csr),
    Dense(Mat),
}

impl Operand {
    pub fn shape(&self) -> (usize, usize) {
        match self {
            Operand::Sparse(a) => (a.rows(), a.cols()),
            Operand::Dense(a) => (a.rows(), a.cols()),
        }
    }
    pub fn nnz(&self) -> Option<usize> {
        match self {
            Operand::Sparse(a) => Some(a.nnz()),
            Operand::Dense(_) => None,
        }
    }
}
