//! Compute-backend abstraction: an out-parameter op set over a planned
//! workspace — and the **normative contract** a backend author must
//! satisfy.
//!
//! The paper builds RandSVD and LancSVD from a fixed set of device
//! building blocks (Table 1): multiplications with A/Aᵀ (cuSPARSE SpMM or
//! cuBLAS GEMM), Gram products, CGS projections, and right-side triangular
//! solves — with the tiny POTRF/GESVD factorizations staying on the host.
//! Crucially, every operand of those blocks lives in a **preallocated
//! device buffer**: the iteration loop launches kernels against resident
//! memory and never allocates or transfers.
//!
//! # Backend author's contract
//!
//! A conforming [`Backend`] implementation obeys the following rules.
//! They are enforced mechanically: `tests/test_backend_conformance.rs`
//! runs every backend through the same battery (op parity vs
//! [`cpu::CpuBackend`] at ε-scaled tolerances, plan lifecycle, end-to-end
//! residual targets, transfer-ledger discipline), and
//! [`staged::StagedBackend`] — the CPU-resident device simulation — turns
//! rule violations into panics in test builds.
//!
//! ## 1. Plan lifecycle
//!
//! * [`Backend::plan`] is called **once per solve**, after the caller's
//!   [`Workspace`] exists and before any solve op runs, with the same
//!   [`Plan`] the workspace was allocated from. Stage device buffers for
//!   exactly the planned shapes here (operand residency, padded staging
//!   panels, per-shape queues). `plan` may allocate; nothing after it may.
//! * A backend must also accept **ops before any `plan` call** (the thin
//!   value-returning wrappers and one-shot unit callers): stage lazily or
//!   run a fallback path — never reject. Steady-state guarantees apply
//!   only to planned solves.
//! * A second `plan` call — same or different shapes — must restage
//!   cleanly (workspace reuse across solves, re-plan on shape change).
//!   Solves must be reproducible across restaging: the same planned solve
//!   through one backend yields bitwise-identical results.
//!
//! ## 2. Op semantics and aliasing
//!
//! * Every `*_into` op writes **exactly** its declared output view and
//!   touches nothing else; out-shapes are asserted, not inferred.
//! * Operand views come from the caller's workspace (or panels of it) and
//!   may alias *disjointly* (e.g. the history and the current block of
//!   one basis panel via `split_at_col`). An op must never retain a view
//!   past its call, and an `orth_*` override may borrow only the
//!   internal scratch entries `orth.{w,l1,l2,hbar,snap}` from the passed
//!   workspace — the algorithm loops hold `orth.{h,r}` and every
//!   `lanc.*`/`rand.*`/`svd.*` buffer across the call, and the arena's
//!   `RefCell` guard panics on a double borrow (runtime aliasing
//!   rejection). Backends needing more scratch stage their own in `plan`.
//! * Data movement between planned buffers goes through
//!   [`Backend::copy_into`] (device-to-device on a device target), and
//!   host-initialized data (RNG sketches) is declared with
//!   [`Backend::stage_in`] before the first device op reads it.
//!
//! ## 3. Sanctioned host crossings
//!
//! Only the paper's two host factorizations may move data across the
//! host↔device boundary during the iteration loop:
//!
//! * **POTRF** — the b×b Gram factor W crosses to the host, the Cholesky
//!   factor L crosses back (inside the `orth_*` kernels);
//! * **GESVD** — the r×r bidiagonal/triangular factor crosses to the
//!   host, Ū/V̄ cross back (between outer iterations).
//!
//! Everything else — the m×b / n×b blocks, the n×r / m×r bases, the
//! sketches — stays device-resident from `plan` to the final U/V
//! formation. "Factor-sized" means `rows ≤ r`; anything with `rows ∈
//! {m, n}` is a panel and must never cross mid-loop.
//!
//! ## 4. Ledger expectations
//!
//! A device(-simulating) backend keeps a transfer ledger
//! ([`staged::TransferLedger`]) recording every host↔arena copy with op
//! name, direction, and bytes. For one planned `lancsvd`/`randsvd`
//! solve the ledger must show **zero hot-loop panel transfers**: during
//! the `MultA`/`MultAt`/`OrthM`/`OrthN` phases only factor-sized
//! crossings (rule 3) may appear. [`staged::StagedBackend`] enforces
//! this with a panic in test builds and exports the counters to
//! `BENCH_kernels.json` (`staged_ledger` entry) so CI gates on them.
//!
//! ## 5. Instrumentation
//!
//! Every op self-records wall time and Table-1 flops into the backend's
//! [`Profile`] under the phase set by the running algorithm (Figs. 2–3
//! breakdowns), and zero-heap-allocation steady state is expected of
//! host-resident backends (pinned by `tests/test_workspace.rs` and the
//! `BENCH_ASSERT_NOALLOC` gate).
//!
//! ## 6. Memory tiers & out-of-core operands
//!
//! With [`Operand::Sharded`] the operand lives on a third tier below the
//! host: **disk ↔ host ↔ arena**. The sanctioned crossings per tier:
//!
//! * **disk → host** — whole row-band shards only, loaded by the
//!   dedicated loader thread of [`crate::sparse::shard::ShardedOperand`]
//!   (never by a compute worker on the prefetch path), at most once per
//!   shard per `apply_a`/`apply_at` pass, plus the one-time pin-prefix
//!   staging at `plan`. The resident decoded bytes must stay under the
//!   configured `--resident-cap` at all times (pinned prefix + compute
//!   slot + prefetch slot).
//! * **host ↔ arena** — unchanged: rule 3's factor-sized crossings only
//!   during the hot loop. Shard traffic is *operand* traffic and must
//!   never appear as a panel crossing; the staged ledger records it
//!   under the disk direction with `panel = false`, so the
//!   zero-hot-loop-panel-transfer guarantee (rule 4) is unaffected.
//! * **overlap discipline** — compute on shard *i* must not reorder
//!   around the load of shard *i+1*: prefetch overlaps *I/O*, never
//!   *arithmetic*. Shards tile the operand on the same 32-row-aligned
//!   nnz-balanced bounds as the pool's spmm banding, so a sharded solve
//!   is **bitwise-identical** to the in-core solve at a fixed thread
//!   count (gather spmm is partition-independent; scatter spmmᵀ runs
//!   shards in increasing row order with a first-shard-only zero fill).
//!   The ledger reports `overlap_efficiency` (fraction of loader time
//!   hidden behind compute); `BENCH_ASSERT_OVERLAP=1` gates it.
//!
//! **GPU port mapping.** The loader thread is the CPU stand-in for an
//! async copy engine: a CUDA port replaces the request channel with
//! `cudaMemcpyAsync` on a dedicated copy *stream* into the second of two
//! device-resident shard slots, the `recv` with a `cudaEvent` wait on
//! that stream, and keeps the same depth-1 double buffer — compute
//! stream consumes slot `i % 2` while the copy stream fills
//! `(i + 1) % 2`. Pinned-prefix shards map to buffers uploaded once at
//! `plan` and left device-resident; `overlap_efficiency` maps to
//! `1 − (event-wait time) / (copy-stream busy time)` unchanged.
//!
//! ## 7. CPU microkernels
//!
//! Host-resident backends (and host fallback paths of device backends)
//! reach the shared SIMD microkernel layer in [`crate::util::simd`]
//! through the `la::blas*` / `sparse::*` kernels rather than open-coding
//! inner loops. The layer's contract matters to conformance:
//!
//! * **Bitwise level-independence.** Every microkernel uses one
//!   lane-blocked accumulator layout and one reduction tree across the
//!   scalar reference and all ISA paths (no FMA), so `TRUNKSVD_SIMD=off`
//!   and every ISA produce bitwise-identical results at a fixed thread
//!   count. A backend op built on these kernels inherits rule 1's
//!   reproducibility guarantee for free; an op that hand-rolls its inner
//!   loops must match the reference kernels bitwise or it will fail the
//!   cross-backend determinism battery (`tests/test_simd_kernels.rs`,
//!   `tests/test_threaded_kernels.rs`).
//! * **Dispatch is process-global.** The active level resolves from
//!   `TRUNKSVD_SIMD` once (tests override in-process via
//!   `simd::set_level`); backends must not cache kernel choices keyed on
//!   a level they sampled earlier.
//! * **Threading composition.** Microkernels are serial building blocks;
//!   parallelism comes from the `util::pool` band partitioning above
//!   them (whose worker pinning is governed by `TRUNKSVD_PIN`, see
//!   `util::pool` docs). Backends should not nest their own threads
//!   around pool-dispatching kernels — nested calls degrade serial.
//!
//! ## 8. Fused ops
//!
//! The fused tier ([`Backend::apply_a_gram_into`],
//! [`Backend::apply_ata_into`], [`Backend::orth_cgs_cqr2_pregram_into`])
//! exists to cut **operand passes**, not flops: the bandwidth-bound
//! building blocks re-stream A (and the freshly produced panels) from
//! DRAM or disk, so consuming each row band / shard by every op that
//! needs it while it is still resident halves the dominant traffic.
//!
//! * **Semantics.** `apply_a_gram_into(q, y, g)` ≡ `apply_a_into(q, y)`
//!   then `gram_into(y, g)`; `apply_ata_into(q, y, z)` ≡
//!   `apply_a_into(q, y)` then `apply_at_into(y, z)` — in both, `y`
//!   holds A·Q on return (the algorithms rely on that for the scratch
//!   reuse). `orth_cgs_cqr2_pregram_into` is `orth_cgs_cqr2_into` with
//!   the panel Gram `g = QᵀQ` precomputed by the fused sweep: the first
//!   CholeskyQR pass uses the downdate `W = G − HᵀH` (exact when the
//!   history is orthonormal) instead of re-streaming the q×b panel, and
//!   on a downdate-induced Cholesky breakdown must recompute the Gram
//!   directly and retry before falling back to CGS2.
//! * **Default-fallback legality.** The trait defaults compose the
//!   unfused ops, so a backend without fused kernels (e.g.
//!   [`xla::XlaBackend`]) stays conforming unchanged — the fused tier is
//!   an optimization contract, never a correctness requirement. The
//!   algorithms consult the cost model (`crate::cost::should_fuse`,
//!   `TRUNKSVD_FUSE={auto,on,off}`) through [`Backend::operand_bytes`] /
//!   [`Backend::operand_on_disk`] before taking the fused path.
//! * **Ledger expectations.** A fused op is **one** staged pass: it notes
//!   one hot-loop read of `q` and writes of `y`/`z`/`g`, performs zero
//!   extra panel crossings versus the composition (rule 4 unchanged),
//!   and out-of-core reads each disk shard **exactly once** — the
//!   headline saving; the unfused composition reads each shard twice.
//! * **Determinism.** Fused kernels follow rule 7: fixed band order with
//!   a first-band-only zero fill for the scatter half (bitwise equal to
//!   the unfused scatter composition at a fixed thread count) and fixed
//!   band-order reduction for the Gram half (ε-equal to `gram_into`,
//!   bitwise-reproducible at a fixed thread count). Conformance pins
//!   fused-vs-unfused ε-parity and fixed-thread determinism across
//!   backends and dtypes (`tests/test_fused_ops.rs`).
//!
//! ## 9. Streaming ops
//!
//! The streaming tier (`algo::incremental::IncrementalSvd::update_with`,
//! driven by the serve layer's `append` jobs) runs the per-block
//! project → expand → small-SVD → rotate update entirely through the
//! composable ops above — there is no dedicated streaming kernel — but
//! it has its own crossing budget, because the operand is *the arriving
//! block*, not a staged matrix. Per appended m×c block, the sanctioned
//! host↔device crossings are exactly:
//!
//! * the arriving block itself, **once** (it is new data by definition:
//!   the `copy_into` that lands C in the extended-panel workspace
//!   buffer is the upload on a device target);
//! * the c×c POTRF round-trips of the two CholeskyQR2 passes on the
//!   residual (rule 3 unchanged — factor-sized, never O(m));
//! * the (k+c)×(k+c) augmented core down to the host GESVD and the two
//!   factor-sized rotation panels (Ū_r, V̄_r) back up for the basis
//!   rotation GEMMs (the Table 1 split: factor-sized traffic is
//!   sanctioned, panel-sized traffic is not);
//! * nothing else — the warm basis U stays device-resident between
//!   appends (it lives in planned buffers and moves only through
//!   `copy_into`/`gemm_nn_into`), and the right factor V is
//!   **host-resident bookkeeping** by design (it is cols_seen-tall —
//!   operand-sized, not subspace-sized — and no kernel ever consumes
//!   it; keeping it off the device is the memory-budget choice, not a
//!   contract violation).
//!
//! A query on the warm basis performs **zero** crossings: it reads the
//! already-host-resident σ. Backends need no new entry points for any
//! of this; the contract here is the crossing budget the staged
//! ledger audits per append.
//!
//! # Implementations
//!
//! * [`cpu::CpuBackend`] — pure-rust substrate, the conformance
//!   reference; allocation-free in steady state.
//! * [`xla::XlaBackend`] — AOT JAX/Pallas graphs through PJRT (the
//!   GPU-library stand-in); artifact paths stage host literals (those
//!   transfers are the nature of this stand-in), fallback paths run the
//!   host substrate. Generic over the element precision; the PJRT
//!   interchange literal is f64, so f32 solves round through it on the
//!   artifact paths.
//! * [`staged::StagedBackend`] — simulates a device target on the CPU:
//!   stages the operand (CSR→Block-ELL) into a private arena, tracks
//!   buffer residency, and ledgers every host↔arena crossing. The
//!   drop-in scaffold for the real GPU port: replace its arena memcpys
//!   with `cudaMemcpy` and its kernels with device launches.

pub mod cpu;
pub mod staged;
pub mod xla;

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use crate::la::mat::{Mat, MatMut, MatRef};
use crate::la::workspace::{Plan, Workspace};
use crate::metrics::Profile;
use crate::util::scalar::Scalar;

/// The device building-block set shared by both SVD algorithms, in
/// out-parameter form over a planned workspace.
///
/// Generic over the element precision `S` (default `f64`), so a bound of
/// `B: Backend` keeps meaning the f64 op set while the algorithm drivers
/// (`algo::{randsvd, lancsvd}`) are generic over `Backend<S>` and run
/// end-to-end in either precision. The XLA backend implements `Backend`
/// (f64) only; `CpuBackend<S>` covers both dtypes.
pub trait Backend<S: Scalar = f64> {
    /// Problem row count (m).
    fn m(&self) -> usize;
    /// Problem column count (n).
    fn n(&self) -> usize;
    /// Non-zeros if the operand is sparse, `None` for dense.
    fn nnz(&self) -> Option<usize>;

    /// Stage for a planned solve: called once per solve, before the
    /// iteration starts, with the [`Plan`] the workspace was allocated
    /// from. Device backends allocate/stage resident buffers for these
    /// shapes here; the CPU backend records the plan (its buffers *are*
    /// the workspace). Default: no-op.
    fn plan(&mut self, plan: &Plan) {
        let _ = plan;
    }

    /// Y ← A · X  with X n×k, Y m×k (SpMM / GEMM).
    fn apply_a_into(&mut self, x: MatRef<S>, y: MatMut<S>);
    /// Y ← Aᵀ · X  with X m×k, Y n×k (transposed SpMM / GEMM).
    fn apply_at_into(&mut self, x: MatRef<S>, y: MatMut<S>);

    // ---- fused operand-pass tier (contract rule 8) --------------------

    /// Fused sweep: Y ← A · X **and** G ← YᵀY in one pass over the
    /// operand, with the Gram accumulated per row band while Y's band is
    /// still cache-resident. X n×k, Y m×k, G k×k. Default: the unfused
    /// composition (legal for every backend; see contract rule 8).
    fn apply_a_gram_into(&mut self, x: MatRef<S>, mut y: MatMut<S>, g: MatMut<S>) {
        self.apply_a_into(x, y.reborrow());
        self.gram_into(y.as_ref(), g);
    }

    /// Fused power step: Y ← A · X and Z ← Aᵀ · Y band-by-band (and, for
    /// sharded operands, shard-by-shard — each shard read from disk
    /// exactly once instead of twice). X n×k, Y m×k scratch (holds A·X
    /// on return), Z n×k. Default: the unfused composition.
    fn apply_ata_into(&mut self, x: MatRef<S>, mut y: MatMut<S>, z: MatMut<S>) {
        self.apply_a_into(x, y.reborrow());
        self.apply_at_into(y.as_ref(), z);
    }

    /// Total bytes of the operand's value + index storage — the signal
    /// the cost model's fusion policy compares against the LLC
    /// ([`crate::cost::should_fuse`]). Backends that cannot say (the XLA
    /// stand-in stages literals) report 0, which keeps the Auto policy
    /// on the unfused path.
    fn operand_bytes(&self) -> usize {
        0
    }

    /// Does the operand stream from disk (sharded under a resident cap)?
    /// The Auto fusion policy always fuses on-disk operands: the fused
    /// power step halves their per-iteration disk traffic.
    fn operand_on_disk(&self) -> bool {
        false
    }
    /// W ← QᵀQ (SYRK-shaped Gram product, W b×b).
    fn gram_into(&mut self, q: MatRef<S>, w: MatMut<S>);
    /// H ← PᵀQ (block-CGS projection, H s×b).
    fn proj_into(&mut self, p: MatRef<S>, q: MatRef<S>, h: MatMut<S>);
    /// Q ← Q − P·H (block-CGS update, in place).
    fn subtract_proj(&mut self, q: MatMut<S>, p: MatRef<S>, h: MatRef<S>);
    /// Q ← Q·L⁻ᵀ with L lower-triangular b×b (the TRSM of CholeskyQR2,
    /// in place).
    fn tri_solve_right(&mut self, q: MatMut<S>, l: MatRef<S>);
    /// C ← A·B (the finalize GEMMs forming U_T / V_T and the restart).
    fn gemm_nn_into(&mut self, a: MatRef<S>, b: MatRef<S>, c: MatMut<S>);

    /// dst ← src between planned buffers (same shape). On a device
    /// target this is a **device-to-device** copy — the algorithms route
    /// every panel copy (basis recording, thick-restart compaction)
    /// through it so no panel ever round-trips the host mid-loop.
    /// Default: plain host memcpy (correct for host-resident backends).
    fn copy_into(&mut self, src: MatRef<S>, mut dst: MatMut<S>) {
        assert_eq!((src.rows, src.cols), (dst.rows, dst.cols), "copy_into shape");
        dst.data.copy_from_slice(src.data);
    }

    /// Declare a host-initialized buffer (an RNG-filled sketch or start
    /// block) ready for device use. Device backends upload it here —
    /// once, inside the setup phase — so the first iteration op finds it
    /// resident instead of paying (and a ledger flagging) a hot-loop
    /// transfer. Default: no-op for host-resident backends.
    fn stage_in(&mut self, src: MatRef<S>) {
        let _ = src;
    }

    /// CholeskyQR2 orthonormalization of a q×b panel (Alg. 4), in place,
    /// writing R (b×b, `Q_in = Q_out·R`) into the caller's buffer.
    ///
    /// **Workspace contract:** an implementation may borrow only the
    /// internal scratch entries `orth.{w,l1,l2,hbar,snap}` from `ws`.
    /// The algorithm loops keep `orth.{h,r}` (and every `lanc.*` /
    /// `rand.*` / `svd.*` buffer) borrowed across this call as the
    /// out-parameter destinations — touching them from inside an
    /// override trips the arena's runtime aliasing guard. Backends
    /// needing more scratch should stage their own in [`Backend::plan`].
    ///
    /// The default composes the fine-grained ops with the host
    /// POTRF; the XLA backend overrides it with the fused AOT graph
    /// (falling back here on breakdown or unbucketable shapes).
    fn orth_cholqr2_into(
        &mut self,
        q: MatMut<S>,
        r: MatMut<S>,
        ws: &Workspace<S>,
    ) -> crate::error::Result<()> {
        crate::algo::orth::cholqr2_into_host(self, q, r, ws)
    }

    /// CGS + CholeskyQR2 orthogonalization against a history panel
    /// (Alg. 5), in place, writing H (s×b) and R (b×b) with
    /// `Q_in ≈ P·H + Q_out·R` into the caller's buffers. Override
    /// semantics — including the workspace contract on which `orth.*`
    /// entries may be borrowed — as for [`Backend::orth_cholqr2_into`].
    fn orth_cgs_cqr2_into(
        &mut self,
        q: MatMut<S>,
        p: MatRef<'_, S>,
        h: MatMut<S>,
        r: MatMut<S>,
        ws: &Workspace<S>,
    ) -> crate::error::Result<()> {
        crate::algo::orth::cgs_cqr2_into_host(self, q, p, h, r, ws)
    }

    /// [`Backend::orth_cgs_cqr2_into`] with the panel Gram `g = QᵀQ`
    /// precomputed by the fused [`Backend::apply_a_gram_into`] sweep:
    /// the first CholeskyQR pass downdates `W = G − HᵀH` instead of
    /// re-streaming the q×b panel (exact when `p` is orthonormal — the
    /// Lanczos invariant), recomputing the Gram directly on a
    /// downdate-induced breakdown before the CGS2 fallback. Workspace
    /// contract as for [`Backend::orth_cholqr2_into`].
    fn orth_cgs_cqr2_pregram_into(
        &mut self,
        q: MatMut<S>,
        p: MatRef<'_, S>,
        g: MatRef<'_, S>,
        h: MatMut<S>,
        r: MatMut<S>,
        ws: &Workspace<S>,
    ) -> crate::error::Result<()> {
        crate::algo::orth::cgs_cqr2_pregram_into_host(self, q, p, g, h, r, ws)
    }

    // ---- thin value-returning wrappers (tests / examples / one-shot) --

    /// Allocating wrapper over [`Backend::apply_a_into`].
    fn apply_a(&mut self, x: MatRef<S>) -> Mat<S> {
        let mut y = Mat::zeros(self.m(), x.cols);
        self.apply_a_into(x, y.as_mut());
        y
    }
    /// Allocating wrapper over [`Backend::apply_at_into`].
    fn apply_at(&mut self, x: MatRef<S>) -> Mat<S> {
        let mut y = Mat::zeros(self.n(), x.cols);
        self.apply_at_into(x, y.as_mut());
        y
    }
    /// Allocating wrapper over [`Backend::gram_into`].
    fn gram(&mut self, q: MatRef<S>) -> Mat<S> {
        let mut w = Mat::zeros(q.cols, q.cols);
        self.gram_into(q, w.as_mut());
        w
    }
    /// Allocating wrapper over [`Backend::proj_into`].
    fn proj(&mut self, p: MatRef<S>, q: MatRef<S>) -> Mat<S> {
        let mut h = Mat::zeros(p.cols, q.cols);
        self.proj_into(p, q, h.as_mut());
        h
    }
    /// Allocating wrapper over [`Backend::gemm_nn_into`].
    fn gemm_nn(&mut self, a: MatRef<S>, b: MatRef<S>) -> Mat<S> {
        let mut c = Mat::zeros(a.rows, b.cols);
        self.gemm_nn_into(a, b, c.as_mut());
        c
    }
    /// Allocating wrapper over [`Backend::orth_cholqr2_into`] with a
    /// throwaway orth workspace.
    fn orth_cholqr2(&mut self, q: &mut Mat<S>) -> crate::error::Result<Mat<S>> {
        let ws = Workspace::new(Plan::orth(q.rows(), 0, q.cols()));
        let mut r = Mat::zeros(q.cols(), q.cols());
        self.orth_cholqr2_into(q.as_mut(), r.as_mut(), &ws)?;
        Ok(r)
    }
    /// Allocating wrapper over [`Backend::orth_cgs_cqr2_into`] with a
    /// throwaway orth workspace.
    fn orth_cgs_cqr2(
        &mut self,
        q: &mut Mat<S>,
        p: MatRef<'_, S>,
    ) -> crate::error::Result<(Mat<S>, Mat<S>)> {
        let ws = Workspace::new(Plan::orth(q.rows(), p.cols, q.cols()));
        let mut h = Mat::zeros(p.cols, q.cols());
        let mut r = Mat::zeros(q.cols(), q.cols());
        self.orth_cgs_cqr2_into(q.as_mut(), p, h.as_mut(), r.as_mut(), &ws)?;
        Ok((h, r))
    }

    /// The per-block profile (phase is set by the algorithms).
    fn profile_mut(&mut self) -> &mut Profile;
    /// Take the accumulated profile, resetting it.
    fn take_profile(&mut self) -> Profile;
    /// Short backend name for reports.
    fn name(&self) -> &'static str;

    /// Flop cost of one apply_a/apply_at with k dense columns (used both
    /// for instrumentation and by the analytic model).
    fn mult_flops(&self, k: usize) -> f64 {
        match self.nnz() {
            Some(nz) => 2.0 * nz as f64 * k as f64,
            None => 2.0 * self.m() as f64 * self.n() as f64 * k as f64,
        }
    }
}

/// How many scatter Aᵀ·X calls to tolerate before building the explicit
/// transposed copy.
#[derive(Clone, Copy, Debug)]
pub(crate) enum TransposeThreshold {
    /// Never build (pure-scatter ablation baseline).
    Disabled,
    /// Build after exactly this many scatter calls (env / explicit
    /// override).
    Fixed(usize),
    /// Resolve from the cost model on the first Aᵀ·X call (default):
    /// [`crate::cost::adaptive_transpose_threshold`] estimates the
    /// nnz-sweep crossover between repeated scatter and the one-time
    /// build from the operand shape and the observed column-block width.
    Auto,
}

/// A transpose build in flight on a background thread. The operand is
/// shared into the builder via `Arc` (no deep CSR clone), and the build
/// is joined — or cancelled, if it has not started — when the owner is
/// dropped, so no detached thread ever outlives its backend.
struct PendingBuild<S: Scalar> {
    cancel: Arc<AtomicBool>,
    handle: std::thread::JoinHandle<Option<crate::sparse::csr::Csr<S>>>,
}

/// Adaptive explicit-transpose cache for the sparse Aᵀ·X path.
///
/// The paper mitigates the scatter SpMMᵀ bottleneck by "explicitly
/// storing a transposed copy of the sparse matrix" (§4.1.2), trading
/// nnz memory for gather-speed products. This helper makes that trade
/// adaptive: after the threshold number of scatter calls — by default a
/// per-matrix estimate from the cost model, overridable via the
/// `TRUNKSVD_ADAPTIVE_SPMMT` env var (see [`AdaptiveTranspose::from_env`])
/// — the transposed CSR copy is built on a background thread and adopted
/// as soon as it is ready, so no Aᵀ·X call ever waits on the build. Both
/// backends embed one; the ablation benches disable it (`new(None)`) to
/// keep the pure-scatter baseline measurable.
///
/// Lifecycle: the builder thread receives the operand as an `Arc`
/// clone — a pointer bump, not a deep copy of the nnz arrays — and a
/// cancel flag. Dropping the `AdaptiveTranspose` (backend teardown
/// before adoption) sets the flag and *joins* the handle: a build that
/// has not started is skipped, one in flight finishes and is discarded.
/// Either way the thread never outlives the backend and the Arc is
/// released deterministically.
///
/// Threading interplay: the background build calls `Csr::transpose`,
/// whose parallel passes submit to the same persistent `util::pool` the
/// foreground kernels use. Submissions are serialized by the pool, so
/// the build's bands simply queue between foreground SpMM jobs instead
/// of oversubscribing the machine with a second thread set.
pub(crate) struct AdaptiveTranspose<S: Scalar = f64> {
    at: Option<crate::sparse::csr::Csr<S>>,
    pending: Option<PendingBuild<S>>,
    calls: usize,
    after: TransposeThreshold,
    /// Cost-model estimate, cached on the first `advance` in Auto mode.
    resolved: Option<usize>,
}

impl<S: Scalar> AdaptiveTranspose<S> {
    /// `after` = number of scatter calls before the build starts;
    /// `None` disables the adaptive build (pure-scatter baseline).
    pub fn new(after: Option<usize>) -> AdaptiveTranspose<S> {
        let after = match after {
            Some(n) => TransposeThreshold::Fixed(n),
            None => TransposeThreshold::Disabled,
        };
        AdaptiveTranspose { at: None, pending: None, calls: 0, after, resolved: None }
    }

    /// Threshold policy: `TRUNKSVD_ADAPTIVE_SPMMT` (a fixed call count)
    /// if set, otherwise the cost model's per-matrix crossover estimate
    /// resolved lazily on the first Aᵀ·X call.
    pub fn from_env() -> AdaptiveTranspose<S> {
        let after = match std::env::var("TRUNKSVD_ADAPTIVE_SPMMT")
            .ok()
            .and_then(|s| s.parse::<usize>().ok())
        {
            Some(n) => TransposeThreshold::Fixed(n),
            None => TransposeThreshold::Auto,
        };
        AdaptiveTranspose { at: None, pending: None, calls: 0, after, resolved: None }
    }

    /// Wrap an eagerly built transpose (the paper's always-on variant).
    pub fn with_built(at: crate::sparse::csr::Csr<S>) -> AdaptiveTranspose<S> {
        AdaptiveTranspose {
            at: Some(at),
            pending: None,
            calls: 0,
            after: TransposeThreshold::Disabled,
            resolved: None,
        }
    }

    /// Record one Aᵀ·X call against operand `a` with a `k`-column dense
    /// block; returns the cached transpose if it is available (caller
    /// then uses gather-SpMM). The operand arrives as an `Arc` so the
    /// background build shares it instead of deep-cloning the CSR.
    pub fn advance(
        &mut self,
        a: &Arc<crate::sparse::csr::Csr<S>>,
        k: usize,
    ) -> Option<&crate::sparse::csr::Csr<S>> {
        if self.at.is_none() {
            let threshold = match self.after {
                TransposeThreshold::Disabled => None,
                TransposeThreshold::Fixed(n) => Some(n),
                TransposeThreshold::Auto => Some(*self.resolved.get_or_insert_with(|| {
                    crate::cost::adaptive_transpose_threshold(a.rows(), a.cols(), a.nnz(), k)
                })),
            };
            if let Some(p) = &self.pending {
                if p.handle.is_finished() {
                    let p = self.pending.take().expect("pending checked above");
                    // `None` means the build was cancelled before it
                    // started (only possible via drop, which also joins —
                    // but be tolerant).
                    if let Some(at) = p.handle.join().expect("transpose builder panicked") {
                        self.at = Some(at);
                    }
                }
            } else if threshold.is_some_and(|n| self.calls >= n) {
                let a = Arc::clone(a);
                let cancel = Arc::new(AtomicBool::new(false));
                let cancel_in = Arc::clone(&cancel);
                let handle = std::thread::spawn(move || {
                    if cancel_in.load(Ordering::Acquire) {
                        return None;
                    }
                    Some(a.transpose())
                });
                self.pending = Some(PendingBuild { cancel, handle });
            }
        }
        self.calls += 1;
        self.at.as_ref()
    }

    /// Is the transposed copy adopted (i.e. Aᵀ·X now runs as gather)?
    pub fn built(&self) -> bool {
        self.at.is_some()
    }

    /// Is the adaptive build enabled at all?
    pub fn enabled(&self) -> bool {
        !matches!(self.after, TransposeThreshold::Disabled) || self.at.is_some()
    }

    /// Is a background build currently pending (spawned, not adopted)?
    #[cfg(test)]
    pub fn pending(&self) -> bool {
        self.pending.is_some()
    }
}

impl<S: Scalar> Drop for AdaptiveTranspose<S> {
    fn drop(&mut self) {
        if let Some(p) = self.pending.take() {
            // Ask a not-yet-started build to skip the work, then join so
            // the thread (and its Arc on the operand) cannot outlive us.
            p.cancel.store(true, Ordering::Release);
            let _ = p.handle.join();
        }
    }
}

/// The operand matrix a backend is constructed around. Sparse operands
/// are held behind an `Arc` so backends, residual checkers, and the
/// background transpose build all share one copy of the index/value
/// arrays (cloning an `Operand` is a pointer bump for sparse).
#[derive(Clone, Debug)]
pub enum Operand<S: Scalar = f64> {
    Sparse(Arc<crate::sparse::csr::Csr<S>>),
    Dense(Mat<S>),
    /// A disk-resident CSR operand tiled into row-band shards
    /// (`sparse::shard`), streamed under a resident-bytes cap
    /// (`0` = unlimited). Values are stored f64 on disk and cast to `S`
    /// at load, so `cast()` is a metadata re-tag, not a copy.
    Sharded { dir: Arc<crate::sparse::shard::ShardDir>, resident_cap: usize },
}

impl<S: Scalar> Operand<S> {
    /// Wrap a CSR operand (shared ownership).
    pub fn sparse(a: crate::sparse::csr::Csr<S>) -> Operand<S> {
        Operand::Sparse(Arc::new(a))
    }
    /// Wrap a dense operand.
    pub fn dense(a: Mat<S>) -> Operand<S> {
        Operand::Dense(a)
    }
    /// Wrap an out-of-core shard directory under a resident-bytes cap.
    pub fn sharded(dir: Arc<crate::sparse::shard::ShardDir>, resident_cap: usize) -> Operand<S> {
        Operand::Sharded { dir, resident_cap }
    }
    pub fn shape(&self) -> (usize, usize) {
        match self {
            Operand::Sparse(a) => (a.rows(), a.cols()),
            Operand::Dense(a) => (a.rows(), a.cols()),
            Operand::Sharded { dir, .. } => (dir.rows(), dir.cols()),
        }
    }
    pub fn nnz(&self) -> Option<usize> {
        match self {
            Operand::Sparse(a) => Some(a.nnz()),
            Operand::Dense(_) => None,
            Operand::Sharded { dir, .. } => Some(dir.nnz()),
        }
    }
    /// Copy into another element precision (the `--dtype` conversion).
    pub fn cast<T: Scalar>(&self) -> Operand<T> {
        match self {
            Operand::Sparse(a) => Operand::Sparse(Arc::new(a.cast())),
            Operand::Dense(a) => Operand::Dense(a.cast()),
            Operand::Sharded { dir, resident_cap } => {
                Operand::Sharded { dir: Arc::clone(dir), resident_cap: *resident_cap }
            }
        }
    }

    /// A collision-free identity token for operand-level caching
    /// (`runtime::serve` keys its warm-backend cache on it), or `None`
    /// when the operand carries no stable identity:
    ///
    /// * `Sparse` — the process-unique [`Csr::generation`] stamp
    ///   (`crate::sparse::csr::Csr::generation`). `Arc`-clones share the
    ///   stamp (same matrix ⇒ same key); rebuilding or deep-cloning a
    ///   matrix — even with identical contents — mints a fresh stamp and
    ///   therefore misses, which is the conservative direction.
    /// * `Sharded` — the shard-directory path plus the resident cap (the
    ///   cap changes staging behavior, so it is part of the identity).
    /// * `Dense` — `None`. A bare `Mat` has no generation stamp, and its
    ///   data pointer is unusable as a key (a freed-and-reused
    ///   allocation would alias a dead entry). Callers that *know* two
    ///   dense operands are the same matrix pass their own tag at the
    ///   job layer instead (`runtime::serve::JobSpec::operand_tag`).
    pub fn identity_key(&self) -> Option<String> {
        match self {
            Operand::Sparse(a) => Some(format!("csr:g{}", a.generation())),
            Operand::Dense(_) => None,
            Operand::Sharded { dir, resident_cap } => {
                Some(format!("shards:{}:cap{resident_cap}", dir.path()))
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::coo::Coo;
    use crate::sparse::csr::Csr;
    use crate::util::rng::Rng;

    fn biggish_sparse(seed: u64) -> Csr {
        let mut rng = Rng::new(seed);
        let mut coo = Coo::new(400, 300);
        for _ in 0..20_000 {
            coo.push(rng.below(400), rng.below(300), rng.normal());
        }
        Csr::from_coo(&coo).unwrap()
    }

    /// Large enough that the background transpose cannot finish inside
    /// the few microseconds between spawn and assertion.
    fn huge_sparse(seed: u64) -> Csr {
        let mut rng = Rng::new(seed);
        let mut coo = Coo::new(3000, 2500);
        for _ in 0..400_000 {
            coo.push(rng.below(3000), rng.below(2500), rng.normal());
        }
        Csr::from_coo(&coo).unwrap()
    }

    #[test]
    fn advance_shares_operand_via_arc() {
        let a = Arc::new(huge_sparse(1));
        let mut at: AdaptiveTranspose = AdaptiveTranspose::new(Some(0));
        assert!(at.advance(&a, 4).is_none(), "first call spawns, no adoption yet");
        // The builder thread holds an Arc *clone* of the operand — a
        // pointer bump, not a deep copy. While the build is in flight
        // the strong count is therefore ≥ 2; a regression back to deep
        // cloning would leave it at 1 here.
        assert!(at.pending(), "build must be pending after the spawning call");
        assert!(
            Arc::strong_count(&a) >= 2,
            "builder must share the operand via Arc, not deep-clone it"
        );
        // Eventually adopted, and numerically the real transpose.
        for _ in 0..20_000 {
            if at.advance(&a, 4).is_some() {
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(1));
        }
        let adopted = at.advance(&a, 4).expect("background transpose adopted");
        assert_eq!((adopted.rows(), adopted.cols()), (2500, 3000));
        assert_eq!(adopted.nnz(), a.nnz());
    }

    #[test]
    fn drop_joins_pending_build() {
        // Dropping right after the spawn must join (not detach) the
        // builder; this is a no-hang/no-leak smoke test.
        for seed in 0..4 {
            let a = Arc::new(biggish_sparse(10 + seed));
            let mut at: AdaptiveTranspose = AdaptiveTranspose::new(Some(0));
            let _ = at.advance(&a, 8);
            drop(at);
            // The operand Arc is ours again after the join completes
            // (drop is synchronous), modulo the adopted-copy case where
            // the build finished first and was discarded.
            assert_eq!(Arc::strong_count(&a), 1);
        }
    }

    #[test]
    fn operand_clone_is_shallow_for_sparse() {
        let op: Operand = Operand::sparse(biggish_sparse(7));
        let c = op.clone();
        match (&op, &c) {
            (Operand::Sparse(a), Operand::Sparse(b)) => {
                assert!(Arc::ptr_eq(a, b), "sparse operand clones must share storage");
            }
            _ => panic!("expected sparse operands"),
        }
        assert_eq!(op.shape(), (400, 300));
        assert!(op.nnz().unwrap() > 0);
    }
}
