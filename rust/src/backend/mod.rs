//! Compute-backend abstraction.
//!
//! The paper builds RandSVD and LancSVD from a fixed set of device
//! building blocks (Table 1): multiplications with A/Aᵀ (cuSPARSE SpMM or
//! cuBLAS GEMM), Gram products, CGS projections, and right-side triangular
//! solves — with the tiny POTRF/GESVD factorizations staying on the host.
//!
//! [`Backend`] is exactly that op set. Two implementations exist:
//! [`cpu::CpuBackend`] (pure-rust substrate, the reference) and
//! [`xla::XlaBackend`] (AOT JAX/Pallas artifacts through PJRT — the
//! GPU-library stand-in). All operands are host `Mat`s; backends may stage
//! them to device buffers internally.
//!
//! Every op self-records wall time and Table-1 flops into the backend's
//! [`Profile`] under the phase set by the running algorithm, which is how
//! Figs. 2–3's breakdowns are measured.

pub mod cpu;
pub mod xla;

use crate::la::mat::{Mat, MatRef};
use crate::metrics::Profile;
use crate::util::scalar::Scalar;

/// The device building-block set shared by both SVD algorithms.
///
/// Generic over the element precision `S` (default `f64`), so a bound of
/// `B: Backend` keeps meaning the f64 op set while the algorithm drivers
/// (`algo::{randsvd, lancsvd}`) are generic over `Backend<S>` and run
/// end-to-end in either precision. The XLA backend implements `Backend`
/// (f64) only; `CpuBackend<S>` covers both dtypes.
pub trait Backend<S: Scalar = f64> {
    /// Problem row count (m).
    fn m(&self) -> usize;
    /// Problem column count (n).
    fn n(&self) -> usize;
    /// Non-zeros if the operand is sparse, `None` for dense.
    fn nnz(&self) -> Option<usize>;

    /// Y = A · X  with X n×k (SpMM / GEMM).
    fn apply_a(&mut self, x: MatRef<S>) -> Mat<S>;
    /// Y = Aᵀ · X  with X m×k (transposed SpMM / GEMM).
    fn apply_at(&mut self, x: MatRef<S>) -> Mat<S>;
    /// W = QᵀQ (SYRK-shaped Gram product).
    fn gram(&mut self, q: MatRef<S>) -> Mat<S>;
    /// H = PᵀQ (block-CGS projection).
    fn proj(&mut self, p: MatRef<S>, q: MatRef<S>) -> Mat<S>;
    /// Q ← Q − P·H (block-CGS update).
    fn subtract_proj(&mut self, q: &mut Mat<S>, p: MatRef<S>, h: &Mat<S>);
    /// Q ← Q·L⁻ᵀ with L lower-triangular b×b (the TRSM of CholeskyQR2).
    fn tri_solve_right(&mut self, q: &mut Mat<S>, l: &Mat<S>);
    /// C = A·B (the finalize GEMMs forming U_T / V_T and the restart).
    fn gemm_nn(&mut self, a: MatRef<S>, b: MatRef<S>) -> Mat<S>;

    /// CholeskyQR2 orthonormalization of a q×b panel (Alg. 4), returning
    /// R with `Q_in = Q_out·R`. The default composes the fine-grained ops
    /// with the host POTRF; the XLA backend overrides it with the fused
    /// AOT graph (falling back here on breakdown or unbucketable shapes).
    fn orth_cholqr2(&mut self, q: &mut Mat<S>) -> crate::error::Result<Mat<S>> {
        crate::algo::orth::cholqr2_host(self, q)
    }

    /// CGS + CholeskyQR2 orthogonalization against a history panel
    /// (Alg. 5), returning (H, R) with `Q_in ≈ P·H + Q_out·R`. Override
    /// semantics as for [`Backend::orth_cholqr2`].
    fn orth_cgs_cqr2(
        &mut self,
        q: &mut Mat<S>,
        p: MatRef<'_, S>,
    ) -> crate::error::Result<(Mat<S>, Mat<S>)> {
        crate::algo::orth::cgs_cqr2_host(self, q, p)
    }

    /// The per-block profile (phase is set by the algorithms).
    fn profile_mut(&mut self) -> &mut Profile;
    /// Take the accumulated profile, resetting it.
    fn take_profile(&mut self) -> Profile;
    /// Short backend name for reports.
    fn name(&self) -> &'static str;

    /// Flop cost of one apply_a/apply_at with k dense columns (used both
    /// for instrumentation and by the analytic model).
    fn mult_flops(&self, k: usize) -> f64 {
        match self.nnz() {
            Some(nz) => 2.0 * nz as f64 * k as f64,
            None => 2.0 * self.m() as f64 * self.n() as f64 * k as f64,
        }
    }
}

/// How many scatter Aᵀ·X calls to tolerate before building the explicit
/// transposed copy.
#[derive(Clone, Copy, Debug)]
pub(crate) enum TransposeThreshold {
    /// Never build (pure-scatter ablation baseline).
    Disabled,
    /// Build after exactly this many scatter calls (env / explicit
    /// override).
    Fixed(usize),
    /// Resolve from the cost model on the first Aᵀ·X call (default):
    /// [`crate::cost::adaptive_transpose_threshold`] estimates the
    /// nnz-sweep crossover between repeated scatter and the one-time
    /// build from the operand shape and the observed column-block width.
    Auto,
}

/// Adaptive explicit-transpose cache for the sparse Aᵀ·X path.
///
/// The paper mitigates the scatter SpMMᵀ bottleneck by "explicitly
/// storing a transposed copy of the sparse matrix" (§4.1.2), trading
/// nnz memory for gather-speed products. This helper makes that trade
/// adaptive: after the threshold number of scatter calls — by default a
/// per-matrix estimate from the cost model, overridable via the
/// `TRUNKSVD_ADAPTIVE_SPMMT` env var (see [`AdaptiveTranspose::from_env`])
/// — the transposed CSR copy is built on a background thread and adopted
/// as soon as it is ready, so no Aᵀ·X call ever waits on the build. Both
/// backends embed one; the ablation benches disable it (`new(None)`) to
/// keep the pure-scatter baseline measurable.
///
/// Threading interplay: the background build calls `Csr::transpose`,
/// whose parallel passes submit to the same persistent `util::pool` the
/// foreground kernels use. Submissions are serialized by the pool, so
/// the build's bands simply queue between foreground SpMM jobs instead
/// of oversubscribing the machine with a second thread set.
pub(crate) struct AdaptiveTranspose<S: Scalar = f64> {
    at: Option<crate::sparse::csr::Csr<S>>,
    pending: Option<std::thread::JoinHandle<crate::sparse::csr::Csr<S>>>,
    calls: usize,
    after: TransposeThreshold,
    /// Cost-model estimate, cached on the first `advance` in Auto mode.
    resolved: Option<usize>,
}

impl<S: Scalar> AdaptiveTranspose<S> {
    /// `after` = number of scatter calls before the build starts;
    /// `None` disables the adaptive build (pure-scatter baseline).
    pub fn new(after: Option<usize>) -> AdaptiveTranspose<S> {
        let after = match after {
            Some(n) => TransposeThreshold::Fixed(n),
            None => TransposeThreshold::Disabled,
        };
        AdaptiveTranspose { at: None, pending: None, calls: 0, after, resolved: None }
    }

    /// Threshold policy: `TRUNKSVD_ADAPTIVE_SPMMT` (a fixed call count)
    /// if set, otherwise the cost model's per-matrix crossover estimate
    /// resolved lazily on the first Aᵀ·X call.
    pub fn from_env() -> AdaptiveTranspose<S> {
        let after = match std::env::var("TRUNKSVD_ADAPTIVE_SPMMT")
            .ok()
            .and_then(|s| s.parse::<usize>().ok())
        {
            Some(n) => TransposeThreshold::Fixed(n),
            None => TransposeThreshold::Auto,
        };
        AdaptiveTranspose { at: None, pending: None, calls: 0, after, resolved: None }
    }

    /// Wrap an eagerly built transpose (the paper's always-on variant).
    pub fn with_built(at: crate::sparse::csr::Csr<S>) -> AdaptiveTranspose<S> {
        AdaptiveTranspose {
            at: Some(at),
            pending: None,
            calls: 0,
            after: TransposeThreshold::Disabled,
            resolved: None,
        }
    }

    /// Record one Aᵀ·X call against operand `a` with a `k`-column dense
    /// block; returns the cached transpose if it is available (caller
    /// then uses gather-SpMM).
    pub fn advance(
        &mut self,
        a: &crate::sparse::csr::Csr<S>,
        k: usize,
    ) -> Option<&crate::sparse::csr::Csr<S>> {
        if self.at.is_none() {
            let threshold = match self.after {
                TransposeThreshold::Disabled => None,
                TransposeThreshold::Fixed(n) => Some(n),
                TransposeThreshold::Auto => Some(*self.resolved.get_or_insert_with(|| {
                    crate::cost::adaptive_transpose_threshold(a.rows(), a.cols(), a.nnz(), k)
                })),
            };
            if let Some(h) = &self.pending {
                if h.is_finished() {
                    let h = self.pending.take().expect("pending checked above");
                    self.at = Some(h.join().expect("transpose builder panicked"));
                }
            } else if threshold.is_some_and(|n| self.calls >= n) {
                let a = a.clone();
                self.pending = Some(std::thread::spawn(move || a.transpose()));
            }
        }
        self.calls += 1;
        self.at.as_ref()
    }

    /// Is the transposed copy adopted (i.e. Aᵀ·X now runs as gather)?
    pub fn built(&self) -> bool {
        self.at.is_some()
    }

    /// Is the adaptive build enabled at all?
    pub fn enabled(&self) -> bool {
        !matches!(self.after, TransposeThreshold::Disabled) || self.at.is_some()
    }
}

/// The operand matrix a backend is constructed around.
#[derive(Clone, Debug)]
pub enum Operand<S: Scalar = f64> {
    Sparse(crate::sparse::csr::Csr<S>),
    Dense(Mat<S>),
}

impl<S: Scalar> Operand<S> {
    pub fn shape(&self) -> (usize, usize) {
        match self {
            Operand::Sparse(a) => (a.rows(), a.cols()),
            Operand::Dense(a) => (a.rows(), a.cols()),
        }
    }
    pub fn nnz(&self) -> Option<usize> {
        match self {
            Operand::Sparse(a) => Some(a.nnz()),
            Operand::Dense(_) => None,
        }
    }
    /// Copy into another element precision (the `--dtype` conversion).
    pub fn cast<T: Scalar>(&self) -> Operand<T> {
        match self {
            Operand::Sparse(a) => Operand::Sparse(a.cast()),
            Operand::Dense(a) => Operand::Dense(a.cast()),
        }
    }
}
