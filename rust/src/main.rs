//! trunksvd CLI entrypoint (Layer-3 leader process).
fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    std::process::exit(trunksvd::cli::main_with_args(argv));
}
