//! Hand-rolled benchmark harness (no criterion in the offline vendor
//! set): warm-up + repeated timed runs, median/min statistics, GFLOP/s
//! reporting, and the paper-style experiment wrappers used by the
//! `benches/` binaries.

use std::time::Instant;

/// Timing statistics over repeated runs.
#[derive(Clone, Copy, Debug)]
pub struct Stats {
    pub median: f64,
    pub min: f64,
    pub max: f64,
    pub runs: usize,
}

/// Run `f` `warmup + runs` times; report stats over the timed runs.
/// (Paper §4: "results ... averaged over several executions following
/// warm-up runs".)
pub fn time_runs<F: FnMut()>(warmup: usize, runs: usize, mut f: F) -> Stats {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(runs);
    for _ in 0..runs {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    Stats {
        median: samples[samples.len() / 2],
        min: samples[0],
        max: *samples.last().unwrap(),
        runs,
    }
}

/// Adaptive repeat counts: fast ops get more runs, slow ones fewer.
pub fn auto_runs(approx_secs: f64) -> (usize, usize) {
    if approx_secs < 0.01 {
        (3, 15)
    } else if approx_secs < 0.5 {
        (2, 7)
    } else if approx_secs < 5.0 {
        (1, 3)
    } else {
        (0, 1)
    }
}

/// GFLOP/s for a flop count + time.
pub fn gflops(flops: f64, secs: f64) -> f64 {
    flops / secs / 1e9
}

/// Read a usize bench parameter from the environment (e.g.
/// `BENCH_SUBSET=46 cargo bench`), with a default.
pub fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

/// Standard bench banner.
pub fn banner(name: &str, detail: &str) {
    println!("\n=== {name} ===");
    if !detail.is_empty() {
        println!("{detail}");
    }
}

/// Peak resident set size of this process in KiB (Linux `VmHWM` from
/// `/proc/self/status`; 0 where unavailable). Used by the `alloc_probe`
/// section of `bench_blocks` to track the steady-state memory ceiling
/// alongside the allocation counters.
pub fn peak_rss_kb() -> u64 {
    #[cfg(target_os = "linux")]
    {
        if let Ok(status) = std::fs::read_to_string("/proc/self/status") {
            for line in status.lines() {
                if let Some(rest) = line.strip_prefix("VmHWM:") {
                    return rest
                        .trim()
                        .trim_end_matches("kB")
                        .trim()
                        .parse::<u64>()
                        .unwrap_or(0);
                }
            }
        }
    }
    0
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_runs_counts_and_orders() {
        let mut n = 0;
        let s = time_runs(2, 5, || {
            n += 1;
            std::thread::sleep(std::time::Duration::from_millis(1));
        });
        assert_eq!(n, 7);
        assert_eq!(s.runs, 5);
        assert!(s.min <= s.median && s.median <= s.max);
        assert!(s.median >= 0.001);
    }

    #[test]
    fn auto_runs_monotone() {
        assert!(auto_runs(0.001).1 > auto_runs(1.0).1);
        assert_eq!(auto_runs(100.0).1, 1);
    }

    #[test]
    fn gflops_math() {
        assert!((gflops(2e9, 1.0) - 2.0).abs() < 1e-12);
    }
}
