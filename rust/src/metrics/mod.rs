//! Per-building-block instrumentation.
//!
//! Figures 2 and 3 of the paper break execution time / theoretical flops
//! down across the major building blocks of each algorithm. The backends
//! record wall time and flops into a [`Profile`] under the currently
//! active [`Block`] phase, which the algorithms set as they move through
//! their steps.

use std::time::Instant;

/// The building-block categories of Figs. 2–3.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Block {
    /// SpMM / GEMM with A (steps S1 in Alg. 1, S4 in Alg. 2)
    MultA,
    /// SpMM / GEMM with Aᵀ (steps S3 in Alg. 1, S2 in Alg. 2)
    MultAt,
    /// Orthogonalization of m-dimension panels (Alg. 1 S2; Alg. 2 S1/S5)
    OrthM,
    /// Orthogonalization of n-dimension panels (Alg. 1 S4; Alg. 2 S3)
    OrthN,
    /// Host-side small factorizations (POTRF within orth is charged to
    /// Orth*, this block is the r×r GESVD)
    SmallSvd,
    /// Post-loop GEMMs forming U_T/V_T (and the restart GEMM in Alg. 2)
    Finalize,
    /// Initial random generation + first orthonormalization
    Init,
    /// Anything else (residual checks, copies)
    Other,
}

impl Block {
    pub const ALL: [Block; 8] = [
        Block::MultA,
        Block::MultAt,
        Block::OrthM,
        Block::OrthN,
        Block::SmallSvd,
        Block::Finalize,
        Block::Init,
        Block::Other,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            Block::MultA => "mult_A",
            Block::MultAt => "mult_At",
            Block::OrthM => "orth_m",
            Block::OrthN => "orth_n",
            Block::SmallSvd => "small_svd",
            Block::Finalize => "finalize",
            Block::Init => "init",
            Block::Other => "other",
        }
    }

    fn index(&self) -> usize {
        Block::ALL.iter().position(|b| b == self).unwrap()
    }
}

/// Accumulated time + flops per block.
#[derive(Clone, Copy, Debug, Default)]
pub struct BlockStat {
    pub secs: f64,
    pub flops: f64,
    pub calls: u64,
}

/// A run profile: per-block stats plus the active phase.
#[derive(Clone, Debug)]
pub struct Profile {
    stats: [BlockStat; 8],
    phase: Block,
}

impl Default for Profile {
    fn default() -> Self {
        Profile { stats: [BlockStat::default(); 8], phase: Block::Other }
    }
}

impl Profile {
    pub fn new() -> Profile {
        Profile::default()
    }

    /// Set the phase that subsequent records are charged to.
    pub fn set_phase(&mut self, b: Block) {
        self.phase = b;
    }

    pub fn phase(&self) -> Block {
        self.phase
    }

    /// Charge `secs`/`flops` to the current phase.
    pub fn record(&mut self, secs: f64, flops: f64) {
        let s = &mut self.stats[self.phase.index()];
        s.secs += secs;
        s.flops += flops;
        s.calls += 1;
    }

    /// Charge to an explicit block regardless of phase.
    pub fn record_block(&mut self, b: Block, secs: f64, flops: f64) {
        let s = &mut self.stats[b.index()];
        s.secs += secs;
        s.flops += flops;
        s.calls += 1;
    }

    pub fn stat(&self, b: Block) -> BlockStat {
        self.stats[b.index()]
    }

    pub fn total_secs(&self) -> f64 {
        self.stats.iter().map(|s| s.secs).sum()
    }

    pub fn total_flops(&self) -> f64 {
        self.stats.iter().map(|s| s.flops).sum()
    }

    /// Merge another profile into this one.
    pub fn merge(&mut self, other: &Profile) {
        for (a, b) in self.stats.iter_mut().zip(&other.stats) {
            a.secs += b.secs;
            a.flops += b.flops;
            a.calls += b.calls;
        }
    }

    /// One-line breakdown, ordered as Fig. 2's legend.
    pub fn summary(&self) -> String {
        let mut parts = Vec::new();
        for b in Block::ALL {
            let s = self.stat(b);
            if s.calls > 0 {
                parts.push(format!("{}={:.3}s/{:.2}GF", b.name(), s.secs, s.flops / 1e9));
            }
        }
        parts.join(" ")
    }
}

/// RAII timer: charges the elapsed time (+ flops) to the profile's current
/// phase on drop. Usage: `let _t = Timer::start(&mut prof, flops);`
pub struct Timer {
    t0: Instant,
    flops: f64,
}

impl Timer {
    pub fn start(flops: f64) -> Timer {
        Timer { t0: Instant::now(), flops }
    }
    pub fn stop(self, prof: &mut Profile) {
        prof.record(self.t0.elapsed().as_secs_f64(), self.flops);
    }
    pub fn stop_block(self, prof: &mut Profile, b: Block) {
        prof.record_block(b, self.t0.elapsed().as_secs_f64(), self.flops);
    }
}

/// Nearest-rank percentile of a sample (`p` in `[0, 100]`): the smallest
/// value with at least `p`% of the sample at or below it. `0.0` on an
/// empty sample. Used by the serve replayer's per-job latency summary
/// (`runtime::serve` → `BENCH_serve.json`).
pub fn percentile(samples: &[f64], p: f64) -> f64 {
    if samples.is_empty() {
        return 0.0;
    }
    let mut v = samples.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal));
    let rank = ((p / 100.0) * v.len() as f64).ceil() as usize;
    v[rank.clamp(1, v.len()) - 1]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn phase_accounting() {
        let mut p = Profile::new();
        p.set_phase(Block::MultA);
        p.record(1.0, 100.0);
        p.record(0.5, 50.0);
        p.set_phase(Block::OrthM);
        p.record(2.0, 10.0);
        assert_eq!(p.stat(Block::MultA).calls, 2);
        assert!((p.stat(Block::MultA).secs - 1.5).abs() < 1e-12);
        assert!((p.stat(Block::OrthM).flops - 10.0).abs() < 1e-12);
        assert!((p.total_secs() - 3.5).abs() < 1e-12);
        assert!((p.total_flops() - 160.0).abs() < 1e-12);
    }

    #[test]
    fn merge_adds() {
        let mut a = Profile::new();
        a.set_phase(Block::Finalize);
        a.record(1.0, 5.0);
        let mut b = Profile::new();
        b.set_phase(Block::Finalize);
        b.record(2.0, 7.0);
        a.merge(&b);
        assert_eq!(a.stat(Block::Finalize).calls, 2);
        assert!((a.stat(Block::Finalize).flops - 12.0).abs() < 1e-12);
    }

    #[test]
    fn timer_records_elapsed() {
        let mut p = Profile::new();
        p.set_phase(Block::Other);
        let t = Timer::start(42.0);
        std::thread::sleep(std::time::Duration::from_millis(5));
        t.stop(&mut p);
        let s = p.stat(Block::Other);
        assert!(s.secs >= 0.004, "timer {}", s.secs);
        assert_eq!(s.flops, 42.0);
    }

    #[test]
    fn summary_mentions_active_blocks() {
        let mut p = Profile::new();
        p.set_phase(Block::MultAt);
        p.record(0.1, 2e9);
        let s = p.summary();
        assert!(s.contains("mult_At"));
        assert!(!s.contains("orth_m"));
    }
}
