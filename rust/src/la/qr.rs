//! Householder QR (comparator / fallback path).
//!
//! The paper's fast path uses CholeskyQR2 + block-CGS; Householder QR is
//! kept as (a) the numerically bullet-proof fallback when CholeskyQR2
//! breaks down on an extremely ill-conditioned panel, and (b) the oracle
//! the orthogonalization tests compare against. It is also used to
//! generate Haar-distributed orthonormal test matrices.
//!
//! Threading: the reflector recurrence is inherently sequential, so this
//! module stays serial by design; the parallel work in the fast
//! orthogonalization path lives in the `blas3` kernels (Gram/GEMM) it
//! falls back *from*, which run on the persistent `util::pool` workers.
//! The per-reflector column work (dots, axpy updates) goes through
//! `blas1`, so it picks up the `util::simd` microkernels transitively —
//! serial but still vectorized.

use super::blas1::{axpy, dot, nrm2, scal};
use super::mat::{Mat, MatMut, MatRef};
use crate::util::rng::Rng;
use crate::util::scalar::Scalar;

/// Thin QR via Householder reflections, out-parameter form: A (m×n,
/// m ≥ n) is factored as A = Q·R with Q (m×n, orthonormal columns) and
/// R (n×n upper triangular, lower triangle zeroed) written into
/// caller-provided buffers. `q` doubles as the reflector workspace —
/// A is copied into it, the vₖ are stored below the diagonal, and Q is
/// then formed *in place* over the reflector storage (LAPACK `orgqr`
/// style, right-to-left). O(n) beta/diagonal bookkeeping and one
/// reflector copy per column still allocate — this is the host
/// comparator/fallback path, not a device building block.
pub fn householder_qr_into<S: Scalar>(a: MatRef<S>, mut q: MatMut<S>, mut r: MatMut<S>) {
    let (m, n) = (a.rows, a.cols);
    assert!(m >= n, "householder_qr needs m >= n");
    assert_eq!((q.rows, q.cols), (m, n), "householder_qr_into Q shape");
    assert_eq!((r.rows, r.cols), (n, n), "householder_qr_into R shape");
    q.data.copy_from_slice(a.data);
    let work = &mut q;
    // v_k stored in-place below the diagonal; betas on the side.
    let mut betas = vec![S::ZERO; n];
    let mut rdiag = vec![S::ZERO; n];
    for k in 0..n {
        // Build the reflector for column k.
        let col = &work.col(k)[k..];
        let alpha = nrm2(col);
        let a0 = col[0];
        let sign = if a0 >= S::ZERO { S::ONE } else { -S::ONE };
        let r_kk = -sign * alpha;
        rdiag[k] = r_kk;
        if alpha == S::ZERO {
            betas[k] = S::ZERO;
            continue;
        }
        // v = x - r_kk * e1, normalized so v[0] = 1.
        let v0 = a0 - r_kk;
        let colm = &mut work.col_mut(k)[k..];
        colm[0] = S::ONE;
        if v0 != S::ZERO {
            let inv = S::ONE / v0;
            for x in colm.iter_mut().skip(1) {
                *x *= inv;
            }
        }
        let vnorm2 = S::ONE + colm[1..].iter().map(|x| *x * *x).sum::<S>();
        betas[k] = S::from_f64(2.0) / vnorm2;
        // Apply (I - beta v vᵀ) to the trailing columns.
        let rows = m;
        for j in (k + 1)..n {
            let (vpart, cpart) = {
                let (head, tail) = work.data.split_at_mut(j * rows);
                let v = &head[k * rows + k..(k + 1) * rows];
                let c = &mut tail[k..rows];
                (v, c)
            };
            let s = betas[k] * dot(vpart, cpart);
            axpy(-s, vpart, cpart);
        }
    }
    // Extract R (upper triangle + diagonal; strict lower zeroed).
    for j in 0..n {
        for i in 0..n {
            if i < j {
                r.set(i, j, work.at(i, j));
            } else if i == j {
                r.set(i, j, rdiag[j]);
            } else {
                r.set(i, j, S::ZERO);
            }
        }
    }
    // Form thin Q in place over the reflector storage (orgqr):
    // right-to-left, apply reflector k to the already-formed columns
    // k+1..n, then column k itself becomes (I − βₖ vₖ vₖᵀ)·e_k.
    for k in (0..n).rev() {
        let beta = betas[k];
        let v: Vec<S> = work.col(k)[k..].to_vec();
        if beta != S::ZERO {
            for j in (k + 1)..n {
                let cj = &mut work.col_mut(j)[k..];
                let s = beta * dot(&v, cj);
                axpy(-s, &v, cj);
            }
        }
        let ck = work.col_mut(k);
        ck.fill(S::ZERO);
        if beta == S::ZERO {
            ck[k] = S::ONE;
        } else {
            for (i, &vi) in v.iter().enumerate() {
                ck[k + i] = -beta * vi;
            }
            ck[k] += S::ONE; // v[0] = 1 ⇒ Q[k,k] = 1 − β
        }
    }
}

/// Allocating wrapper around [`householder_qr_into`].
pub fn householder_qr<S: Scalar>(a: &Mat<S>) -> (Mat<S>, Mat<S>) {
    let (m, n) = (a.rows(), a.cols());
    let mut q = Mat::zeros(m, n);
    let mut r = Mat::zeros(n, n);
    householder_qr_into(a.as_ref(), q.as_mut(), r.as_mut());
    (q, r)
}

/// Random matrix with Haar-ish orthonormal columns (QR of a Gaussian).
pub fn random_orthonormal<S: Scalar>(m: usize, n: usize, rng: &mut Rng) -> Mat<S> {
    assert!(m >= n);
    let g = Mat::randn(m, n, rng);
    let (mut q, r) = householder_qr(&g);
    // Fix the sign convention (diag(R) > 0) so the distribution is Haar.
    for j in 0..n {
        if r.at(j, j) < S::ZERO {
            scal(-S::ONE, q.col_mut(j));
        }
    }
    q
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::la::blas3::{mat_nn, mat_tn};
    use crate::la::norms::orth_error;

    #[test]
    fn qr_reconstructs_and_q_orthonormal() {
        let mut rng = Rng::new(21);
        for &(m, n) in &[(1usize, 1usize), (8, 3), (40, 10), (33, 33), (100, 7)] {
            let a = Mat::randn(m, n, &mut rng);
            let (q, r) = householder_qr(&a);
            let back = mat_nn(&q, &r);
            assert!(back.max_abs_diff(&a) < 1e-10, "reconstruct {m}x{n}");
            assert!(orth_error(&q) < 1e-12, "orthonormal {m}x{n}");
            // R upper triangular
            for j in 0..n {
                for i in (j + 1)..n {
                    assert_eq!(r.at(i, j), 0.0);
                }
            }
        }
    }

    #[test]
    fn handles_rank_deficiency_gracefully() {
        // Zero column: Q still orthonormal-ish on the nonzero part.
        let mut a = Mat::randn(20, 4, &mut Rng::new(3));
        a.col_mut(2).fill(0.0);
        let (q, r) = householder_qr(&a);
        let back = mat_nn(&q, &r);
        assert!(back.max_abs_diff(&a) < 1e-10);
    }

    #[test]
    fn random_orthonormal_is_orthonormal() {
        let mut rng = Rng::new(5);
        let q = random_orthonormal(50, 12, &mut rng);
        let w = mat_tn(&q, &q);
        let eye = Mat::eye(12);
        assert!(w.max_abs_diff(&eye) < 1e-12);
    }
}
