//! Norms and orthogonality diagnostics.

use super::blas1::nrm2;
use super::blas3::{gram, mat_nn};
use super::mat::Mat;
use crate::util::rng::Rng;
use crate::util::scalar::Scalar;

/// ‖QᵀQ − I‖_F — the orthogonality defect used throughout the tests and
/// the CholeskyQR2 quality checks.
pub fn orth_error<S: Scalar>(q: &Mat<S>) -> f64 {
    let w = gram(q.as_ref());
    let n = q.cols();
    let mut s = S::ZERO;
    for j in 0..n {
        for i in 0..n {
            let d = w.at(i, j) - if i == j { S::ONE } else { S::ZERO };
            s += d * d;
        }
    }
    s.sqrt().to_f64()
}

/// Spectral-norm estimate of a dense matrix via power iteration on AᵀA.
pub fn spectral_norm_est<S: Scalar>(a: &Mat<S>, iters: usize, seed: u64) -> f64 {
    let n = a.cols();
    let mut rng = Rng::new(seed);
    let mut v: Mat<S> = Mat::randn(n, 1, &mut rng);
    let nv = nrm2(v.col(0));
    if nv == S::ZERO {
        return 0.0;
    }
    for x in v.col_mut(0) {
        *x /= nv;
    }
    let mut sigma = S::ZERO;
    for _ in 0..iters {
        let av = mat_nn(a, &v); // m×1
        let mut atav = Mat::zeros(n, 1);
        super::blas3::gemm_tn(S::ONE, a.as_ref(), av.as_ref(), S::ZERO, atav.as_mut());
        let nrm = nrm2(atav.col(0));
        if nrm == S::ZERO {
            return 0.0;
        }
        sigma = nrm.sqrt();
        for x in atav.col_mut(0) {
            *x /= nrm;
        }
        v = atav;
    }
    sigma.to_f64()
}

/// Condition-number estimate κ₂(A) ≈ σ_max/σ_min via the small Gram SVD —
/// only for skinny panels (cols ≤ 512); used in CholeskyQR2 diagnostics.
pub fn panel_cond_est<S: Scalar>(a: &Mat<S>) -> f64 {
    let w = gram(a.as_ref());
    match super::svd::jacobi_svd(&w) {
        Ok(svd) => {
            let smax = svd.s.first().copied().unwrap_or(S::ZERO);
            let smin = svd.s.last().copied().unwrap_or(S::ZERO);
            if smin <= S::ZERO {
                f64::INFINITY
            } else {
                (smax / smin).sqrt().to_f64()
            }
        }
        Err(_) => f64::INFINITY,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::la::qr::random_orthonormal;

    #[test]
    fn orth_error_zero_for_orthonormal() {
        let mut rng = Rng::new(1);
        let q: Mat<f64> = random_orthonormal(40, 8, &mut rng);
        assert!(orth_error(&q) < 1e-13);
        let mut bad = q.clone();
        let c0 = bad.col(0).to_vec();
        bad.col_mut(1).copy_from_slice(&c0);
        assert!(orth_error(&bad) > 1.0);
    }

    #[test]
    fn spectral_norm_of_diagonal() {
        let mut a = Mat::zeros(6, 6);
        for i in 0..6 {
            a.set(i, i, (i + 1) as f64);
        }
        let est = spectral_norm_est(&a, 50, 3);
        assert!((est - 6.0).abs() < 1e-6, "est {est}");
    }

    #[test]
    fn cond_est_identityish() {
        let mut rng = Rng::new(2);
        let q: Mat<f64> = random_orthonormal(30, 5, &mut rng);
        let c = panel_cond_est(&q);
        assert!((c - 1.0).abs() < 1e-6, "cond {c}");
    }
}
