//! Level-3 dense kernels (column-major).
//!
//! These are the CPU-substrate analogues of the cuBLAS calls in Table 1 of
//! the paper: GEMM (normal and transposed-A), SYRK-style Gram products,
//! and the right-side triangular solve/multiply used by CholeskyQR2.
//!
//! Layout notes: everything is column-major with leading dimension ==
//! rows, so `gemm_nn` is an axpy-panel kernel (streams contiguous columns)
//! and `gemm_tn` is a dot-panel kernel — both auto-vectorize well. The
//! reduction-shaped kernels (`gram`'s column-pair dots, the TRMM row
//! dots) run on the explicit `util::simd` microkernels so their
//! lane-blocked reduction order is pinned independently of what the
//! auto-vectorizer chooses — that is what makes `TRUNKSVD_SIMD=off`
//! bitwise-reproducible against every ISA path.
//!
//! Threading model: the GEMMs partition *output columns* in groups of 4
//! (`parallel_chunks_mut` on C's storage — column groups are contiguous
//! in column-major layout, so ownership is a plain slice split). `gram`
//! instead partitions the *rows* of Q: each thread streams its row band
//! tile-by-tile into a private b×b accumulator and the partials are
//! summed with `parallel_reduce` — a SYRK is reduction-shaped, so row
//! partitioning exposes q/tile-way parallelism where column partitioning
//! would only expose b/4.
//!
//! All three run on the persistent worker pool (`util::pool`): the
//! CholeskyQR2/CGS inner loops call these kernels dozens of times per
//! iteration on q×b panels, which is exactly the repeated-small-launch
//! pattern where spawn-per-call dispatch dominated (RSVDPACK's blocked
//! multi-core observation). Panels below the `cost::parallel_cutoff`
//! grain skip dispatch entirely and run serial; larger panels reuse the
//! same static row/column bands per worker call after call (band
//! affinity), and `parallel_reduce`'s fixed band partition + in-order
//! fold keep `gram` bitwise-deterministic at a fixed thread count.

use super::mat::{Mat, MatMut, MatRef};
use crate::util::pool::{self, parallel_chunks_mut, parallel_reduce_work};
use crate::util::scalar::Scalar;

/// C = alpha * A * B + beta * C, with A: m×k, B: k×n, C: m×n.
///
/// Out-parameter form: C is a borrowed [`MatMut`] view, so callers hand
/// in workspace buffers or basis panels and the kernel never allocates.
///
/// Register-blocked over *pairs of output-column pairs*: each pass over A
/// updates 4 columns of C at once, cutting A's memory traffic 4× vs a
/// column-at-a-time kernel — the panel shapes here (n ≤ 16, k ≤ 512,
/// m huge) are memory-bound on A. (§Perf: 4.2 → ~9 GF/s on the
/// m=32768 orthogonalization panels.)
pub fn gemm_nn<S: Scalar>(alpha: S, a: MatRef<S>, b: MatRef<S>, beta: S, c: MatMut<S>) {
    let (m, k) = (a.rows, a.cols);
    let n = b.cols;
    assert_eq!(b.rows, k, "gemm_nn inner dim");
    assert_eq!((c.rows, c.cols), (m, n), "gemm_nn output shape");
    let cm = c.rows;
    // Row tile: the A tile (≤128×k) is pulled into L2 once and reused for
    // every output-column group, so A's RAM traffic is a single stream
    // regardless of n (§Perf iteration 4).
    const ROW_TILE: usize = 128;
    // Parallel over groups of 4 output columns.
    parallel_chunks_mut(c.data, 4 * cm, |jg, cg| {
        let j0 = 4 * jg;
        let njb = cg.len() / cm; // 1..=4 columns in this group
        if beta == S::ZERO {
            cg.fill(S::ZERO);
        } else if beta != S::ONE {
            for x in cg.iter_mut() {
                *x *= beta;
            }
        }
        if njb == 4 {
            let (c01, c23) = cg.split_at_mut(2 * cm);
            let (c0, c1) = c01.split_at_mut(cm);
            let (c2, c3) = c23.split_at_mut(cm);
            let b0 = b.col(j0);
            let b1 = b.col(j0 + 1);
            let b2 = b.col(j0 + 2);
            let b3 = b.col(j0 + 3);
            let mut r0 = 0;
            while r0 < m {
                let rl = ROW_TILE.min(m - r0);
                // Two contraction steps per pass: 8 FMAs per A load pair.
                let mut l = 0;
                let k2 = k - k % 2;
                while l < k2 {
                    let al = &a.col(l)[r0..r0 + rl];
                    let al1 = &a.col(l + 1)[r0..r0 + rl];
                    let (x0, y0) = (alpha * b0[l], alpha * b0[l + 1]);
                    let (x1, y1) = (alpha * b1[l], alpha * b1[l + 1]);
                    let (x2, y2) = (alpha * b2[l], alpha * b2[l + 1]);
                    let (x3, y3) = (alpha * b3[l], alpha * b3[l + 1]);
                    let cc0 = &mut c0[r0..r0 + rl];
                    let cc1 = &mut c1[r0..r0 + rl];
                    let cc2 = &mut c2[r0..r0 + rl];
                    let cc3 = &mut c3[r0..r0 + rl];
                    for i in 0..rl {
                        let av = al[i];
                        let av1 = al1[i];
                        cc0[i] += av * x0 + av1 * y0;
                        cc1[i] += av * x1 + av1 * y1;
                        cc2[i] += av * x2 + av1 * y2;
                        cc3[i] += av * x3 + av1 * y3;
                    }
                    l += 2;
                }
                while l < k {
                    let al = &a.col(l)[r0..r0 + rl];
                    let x0 = alpha * b0[l];
                    let x1 = alpha * b1[l];
                    let x2 = alpha * b2[l];
                    let x3 = alpha * b3[l];
                    for i in 0..rl {
                        let av = al[i];
                        c0[r0 + i] += av * x0;
                        c1[r0 + i] += av * x1;
                        c2[r0 + i] += av * x2;
                        c3[r0 + i] += av * x3;
                    }
                    l += 1;
                }
                r0 += rl;
            }
        } else {
            // Remainder columns: column-at-a-time with 4-way k unroll.
            for (jj, cj) in cg.chunks_mut(cm).enumerate() {
                let bj = b.col(j0 + jj);
                let mut l = 0;
                let k4 = k - k % 4;
                while l < k4 {
                    let x0 = alpha * bj[l];
                    let x1 = alpha * bj[l + 1];
                    let x2 = alpha * bj[l + 2];
                    let x3 = alpha * bj[l + 3];
                    let a0 = a.col(l);
                    let a1 = a.col(l + 1);
                    let a2 = a.col(l + 2);
                    let a3 = a.col(l + 3);
                    for i in 0..m {
                        cj[i] += a0[i] * x0 + a1[i] * x1 + a2[i] * x2 + a3[i] * x3;
                    }
                    l += 4;
                }
                while l < k {
                    let x = alpha * bj[l];
                    let al = a.col(l);
                    for i in 0..m {
                        cj[i] += al[i] * x;
                    }
                    l += 1;
                }
            }
        }
    });
}

/// C = alpha * Aᵀ * B + beta * C, with A: q×m, B: q×n, C: m×n.
///
/// Register-blocked 2×4 (two A columns × four B columns per pass): each
/// streamed (A², B⁴) load pair feeds 8 FMAs, and B is streamed m/2 times
/// instead of m — the projection H = PᵀQ here has m ≤ 256, n ≤ 16 with
/// huge q, so traffic on the tall operands dominates. (§Perf log.)
/// Out-parameter form: C is a borrowed [`MatMut`] (see [`gemm_nn`]).
pub fn gemm_tn<S: Scalar>(alpha: S, a: MatRef<S>, b: MatRef<S>, beta: S, c: MatMut<S>) {
    let (q, m) = (a.rows, a.cols);
    let n = b.cols;
    assert_eq!(b.rows, q, "gemm_tn inner dim");
    assert_eq!((c.rows, c.cols), (m, n), "gemm_tn output shape");
    let cm = c.rows;
    // Row-tiled so the skinny B panel stays cache-resident while the tall
    // A panel streams exactly once: without tiling B is re-streamed m/2
    // times (512 MB of traffic on the m-side projections). Tile of 1024
    // rows × n ≤ 16 cols = 128 KiB — comfortably L2.
    const ROW_TILE: usize = 1024;
    // One task per group of 4 output columns (B columns).
    parallel_chunks_mut(c.data, 4 * cm, |jg, cg| {
        let j0 = 4 * jg;
        let njb = cg.len() / cm;
        // zero/scale the output group once; accumulate over row tiles.
        if beta == S::ZERO {
            cg.fill(S::ZERO);
        } else if beta != S::ONE {
            for x in cg.iter_mut() {
                *x *= beta;
            }
        }
        let mut t0 = 0;
        while t0 < q {
            let tl = ROW_TILE.min(q - t0);
            let mut i = 0;
            while i < m {
                let ni = (m - i).min(4);
                let mut acc = [[S::ZERO; 4]; 4];
                let a0 = &a.col(i)[t0..t0 + tl];
                let a1 = if ni >= 2 { &a.col(i + 1)[t0..t0 + tl] } else { a0 };
                let a2 = if ni >= 3 { &a.col(i + 2)[t0..t0 + tl] } else { a0 };
                let a3 = if ni >= 4 { &a.col(i + 3)[t0..t0 + tl] } else { a0 };
                if njb == 4 && ni == 4 {
                    let b0 = &b.col(j0)[t0..t0 + tl];
                    let b1 = &b.col(j0 + 1)[t0..t0 + tl];
                    let b2 = &b.col(j0 + 2)[t0..t0 + tl];
                    let b3 = &b.col(j0 + 3)[t0..t0 + tl];
                    for t in 0..tl {
                        let (av0, av1, av2, av3) = (a0[t], a1[t], a2[t], a3[t]);
                        let (bv0, bv1, bv2, bv3) = (b0[t], b1[t], b2[t], b3[t]);
                        acc[0][0] += av0 * bv0;
                        acc[0][1] += av0 * bv1;
                        acc[0][2] += av0 * bv2;
                        acc[0][3] += av0 * bv3;
                        acc[1][0] += av1 * bv0;
                        acc[1][1] += av1 * bv1;
                        acc[1][2] += av1 * bv2;
                        acc[1][3] += av1 * bv3;
                        acc[2][0] += av2 * bv0;
                        acc[2][1] += av2 * bv1;
                        acc[2][2] += av2 * bv2;
                        acc[2][3] += av2 * bv3;
                        acc[3][0] += av3 * bv0;
                        acc[3][1] += av3 * bv1;
                        acc[3][2] += av3 * bv2;
                        acc[3][3] += av3 * bv3;
                    }
                } else if njb == 4 {
                    let b0 = &b.col(j0)[t0..t0 + tl];
                    let b1 = &b.col(j0 + 1)[t0..t0 + tl];
                    let b2 = &b.col(j0 + 2)[t0..t0 + tl];
                    let b3 = &b.col(j0 + 3)[t0..t0 + tl];
                    let cols = [a0, a1, a2, a3];
                    for (ii, av) in cols.iter().enumerate().take(ni) {
                        for t in 0..tl {
                            let v = av[t];
                            acc[ii][0] += v * b0[t];
                            acc[ii][1] += v * b1[t];
                            acc[ii][2] += v * b2[t];
                            acc[ii][3] += v * b3[t];
                        }
                    }
                } else {
                    let cols = [a0, a1, a2, a3];
                    for jj in 0..njb {
                        let bj = &b.col(j0 + jj)[t0..t0 + tl];
                        for (ii, av) in cols.iter().enumerate().take(ni) {
                            let mut s0 = S::ZERO;
                            for t in 0..tl {
                                s0 += av[t] * bj[t];
                            }
                            acc[ii][jj] += s0;
                        }
                    }
                }
                for jj in 0..njb {
                    for ii in 0..ni {
                        cg[jj * cm + i + ii] += alpha * acc[ii][jj];
                    }
                }
                i += ni;
            }
            t0 += tl;
        }
    });
}

/// Upper-triangle tile accumulation shared by the serial and banded
/// Gram paths: adds Q[t0+lo..t0+hi, :]ᵀ·Q[…] into `acc` (column-major
/// b×b, upper triangle only), walking cache-resident row tiles.
/// `pub(crate)` so the fused operand-pass kernels (`sparse::csr`,
/// `sparse::shard`) can accumulate the Gram of a freshly produced row
/// band while it is still cache-resident, with the same tile walk and
/// `util::simd` reduction order as [`gram_into`].
pub(crate) fn gram_accumulate<S: Scalar>(q: MatRef<S>, lo: usize, hi: usize, acc: &mut [S]) {
    let b = q.cols;
    // 256 rows × b ≤ 32 cols × 8 B = 64 KiB worst case — L2-resident.
    const TILE: usize = 256;
    let mut t0 = lo;
    while t0 < hi {
        let tl = TILE.min(hi - t0);
        for j in 0..b {
            let qj = &q.col(j)[t0..t0 + tl];
            // Two (i, j) entries per pass over qj, each pair running on
            // the `util::simd` dot2 microkernel.
            let mut i = 0;
            while i + 1 <= j {
                let qi0 = &q.col(i)[t0..t0 + tl];
                let qi1 = &q.col(i + 1)[t0..t0 + tl];
                let (s0, s1) = S::simd_dot2(qi0, qi1, qj);
                acc[j * b + i] += s0;
                acc[j * b + i + 1] += s1;
                i += 2;
            }
            if i <= j {
                let qi = &q.col(i)[t0..t0 + tl];
                acc[j * b + i] += S::simd_dot(qi, qj);
            }
        }
        t0 += tl;
    }
}

/// [`gram_accumulate`] over a band's detached column slices (the
/// prepared-task form the fused SpMM+Gram kernel hands its workers:
/// each task owns disjoint sub-slices of Y's columns, not a `MatRef`).
/// Same 256-row tile walk and `simd_dot2`/`simd_dot` reduction order,
/// so a fixed band partition yields bitwise-reproducible partials.
pub(crate) fn gram_accumulate_cols<S: Scalar>(cols: &[&mut [S]], acc: &mut [S]) {
    let b = cols.len();
    let rows = if b == 0 { 0 } else { cols[0].len() };
    const TILE: usize = 256;
    let mut t0 = 0;
    while t0 < rows {
        let tl = TILE.min(rows - t0);
        for j in 0..b {
            let qj: &[S] = &cols[j][t0..t0 + tl];
            let mut i = 0;
            while i + 1 <= j {
                let qi0: &[S] = &cols[i][t0..t0 + tl];
                let qi1: &[S] = &cols[i + 1][t0..t0 + tl];
                let (s0, s1) = S::simd_dot2(qi0, qi1, qj);
                acc[j * b + i] += s0;
                acc[j * b + i + 1] += s1;
                i += 2;
            }
            if i <= j {
                let qi: &[S] = &cols[i][t0..t0 + tl];
                acc[j * b + i] += S::simd_dot(qi, qj);
            }
        }
        t0 += tl;
    }
}

/// Mirror a column-major upper-triangle accumulator into a full
/// symmetric b×b output (the finishing step every Gram path shares).
pub(crate) fn gram_mirror<S: Scalar>(acc: &[S], w: &mut MatMut<S>) {
    let b = w.cols;
    for j in 0..b {
        for i in 0..=j {
            let s = acc[j * b + i];
            w.set(i, j, s);
            w.set(j, i, s);
        }
    }
}

/// Gram matrix W = QᵀQ into a caller-provided b×b buffer, exploiting
/// symmetry (computes the upper triangle then mirrors). This is the
/// SYRK of Alg. 4 steps S1/S4 and sits inside every CholeskyQR2 call.
///
/// Row-tiled parallel SYRK: the q rows are split across threads
/// (`parallel_reduce`); each thread walks its row band in tiles small
/// enough to stay cache-resident (so the b(b+1)/2 column-pair dots read
/// the tile from L1/L2, not RAM) and accumulates into a private b×b
/// upper triangle. The partials are summed in the reduction and the
/// triangle is mirrored once at the end. Panels under the pool's
/// serial cutoff accumulate *directly into W* — the same op order as a
/// one-band reduction (bitwise identical) with zero heap allocation,
/// which is what keeps the steady-state inner iterations alloc-free.
pub fn gram_into<S: Scalar>(q: MatRef<S>, mut w: MatMut<S>) {
    let (rows, b) = (q.rows, q.cols);
    assert_eq!((w.rows, w.cols), (b, b), "gram_into output shape");
    if b == 0 {
        return;
    }
    // Work estimate: each row contributes a b-element read re-used for
    // b(b+1)/2 dot terms; rows·b elements is the bandwidth-side truth
    // the serial-cutoff decision needs (the raw row count alone would
    // serialize wide q×b panels).
    if pool::planned_bands(rows * b, rows) <= 1 {
        w.fill(S::ZERO);
        gram_accumulate(q, 0, rows, w.data);
        for j in 0..b {
            for i in 0..=j {
                let s = w.data[j * b + i];
                w.set(j, i, s);
            }
        }
        return;
    }
    let acc = parallel_reduce_work(
        rows,
        rows * b,
        vec![S::ZERO; b * b],
        |lo, hi| {
            let mut acc = vec![S::ZERO; b * b];
            gram_accumulate(q, lo, hi, &mut acc);
            acc
        },
        |mut a, b_part| {
            for (x, y) in a.iter_mut().zip(&b_part) {
                *x += *y;
            }
            a
        },
    );
    for j in 0..b {
        for i in 0..=j {
            let s = acc[j * b + i];
            w.set(i, j, s);
            w.set(j, i, s);
        }
    }
}

/// Allocating convenience wrapper around [`gram_into`].
pub fn gram<S: Scalar>(q: MatRef<S>) -> Mat<S> {
    let mut w = Mat::zeros(q.cols, q.cols);
    gram_into(q, w.as_mut());
    w
}

/// Q ← Q · L⁻ᵀ with L lower-triangular b×b (right-side TRSM of Alg. 4
/// steps S3/S6), fully in place on a borrowed panel view.
/// Column-recurrence on the upper-triangular U = Lᵀ:
/// X[:,j] = (Q[:,j] − Σ_{i<j} X[:,i]·U[i,j]) / U[j,j],  U[i,j] = L[j,i].
///
/// The tall-column work is entirely `blas1::axpy`/`scal`, so this picks
/// up the `util::simd` elementwise microkernels transitively (axpy/scal
/// are bitwise-safe under any vector width — no reductions involved).
pub fn trsm_right_lt<S: Scalar>(l: MatRef<S>, mut q: MatMut<S>) {
    let b = l.rows;
    assert_eq!(l.cols, b, "trsm L square");
    assert_eq!(q.cols, b, "trsm panel cols");
    for j in 0..b {
        // subtract contributions of already-solved columns
        for i in 0..j {
            let u_ij = l.at(j, i);
            if u_ij != S::ZERO {
                let (xi, xj) = q.col_pair_mut(i, j);
                super::blas1::axpy(-u_ij, xi, xj);
            }
        }
        let inv = S::ONE / l.at(j, j);
        super::blas1::scal(inv, q.col_mut(j));
    }
}

/// R = Lᵀ · L̄ᵀ for lower-triangular L, L̄ (b×b), written into a
/// caller-provided b×b buffer (every entry is written: the upper
/// triangle gets the product, the strict lower triangle zeros). This is
/// the tiny TRMM of Alg. 4 step S7 / Alg. 5 step S11.
pub fn trmm_lt_lt_into<S: Scalar>(l: MatRef<S>, lbar: MatRef<S>, mut r: MatMut<S>) {
    let b = l.rows;
    assert_eq!(lbar.rows, b, "trmm factor shapes");
    assert_eq!((r.rows, r.cols), (b, b), "trmm output shape");
    // R[i,j] = Σ_t Lᵀ[i,t] · L̄ᵀ[t,j] = Σ_t L[t,i] · L̄[j,t], t in [i, j].
    // L̄'s row j is strided in column-major storage; stage it once per j
    // into a stack buffer so every (i, j) dot is contiguous×contiguous
    // and runs on the `util::simd` dot microkernel. The buffer is fixed
    // size to keep the kernel allocation-free (alloc-probed steady
    // state); panels wider than ROW_BUF fall back to the strided loop.
    const ROW_BUF: usize = 256;
    if b <= ROW_BUF {
        let mut rowj = [S::ZERO; ROW_BUF];
        for j in 0..b {
            for (t, slot) in rowj.iter_mut().enumerate().take(j + 1) {
                *slot = lbar.at(j, t);
            }
            for i in 0..b {
                if i <= j {
                    r.set(i, j, S::simd_dot(&l.col(i)[i..=j], &rowj[i..=j]));
                } else {
                    r.set(i, j, S::ZERO);
                }
            }
        }
    } else {
        for j in 0..b {
            for i in 0..b {
                if i <= j {
                    let mut s = S::ZERO;
                    for t in i..=j {
                        s += l.at(t, i) * lbar.at(j, t);
                    }
                    r.set(i, j, s);
                } else {
                    r.set(i, j, S::ZERO);
                }
            }
        }
    }
}

/// Allocating convenience wrapper around [`trmm_lt_lt_into`].
pub fn trmm_lt_lt<S: Scalar>(l: &Mat<S>, lbar: &Mat<S>) -> Mat<S> {
    let mut r = Mat::zeros(l.rows(), l.rows());
    trmm_lt_lt_into(l.as_ref(), lbar.as_ref(), r.as_mut());
    r
}

/// Convenience: C = AᵀB as an owned matrix.
pub fn mat_tn<S: Scalar>(a: &Mat<S>, b: &Mat<S>) -> Mat<S> {
    let mut c = Mat::zeros(a.cols(), b.cols());
    gemm_tn(S::ONE, a.as_ref(), b.as_ref(), S::ZERO, c.as_mut());
    c
}

/// Convenience: C = A·B as an owned matrix.
pub fn mat_nn<S: Scalar>(a: &Mat<S>, b: &Mat<S>) -> Mat<S> {
    let mut c = Mat::zeros(a.rows(), b.cols());
    gemm_nn(S::ONE, a.as_ref(), b.as_ref(), S::ZERO, c.as_mut());
    c
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn naive_nn(a: &Mat, b: &Mat) -> Mat {
        Mat::from_fn(a.rows(), b.cols(), |i, j| {
            (0..a.cols()).map(|l| a.at(i, l) * b.at(l, j)).sum()
        })
    }

    #[test]
    fn gemm_nn_matches_naive() {
        let mut rng = Rng::new(1);
        for &(m, k, n) in &[(1, 1, 1), (5, 3, 4), (17, 9, 13), (32, 8, 8), (33, 7, 2)] {
            let a = Mat::randn(m, k, &mut rng);
            let b = Mat::randn(k, n, &mut rng);
            let mut c = Mat::randn(m, n, &mut rng);
            let expect = {
                let mut e = naive_nn(&a, &b);
                for j in 0..n {
                    for i in 0..m {
                        let v = 2.0 * e.at(i, j) + 0.5 * c.at(i, j);
                        e.set(i, j, v);
                    }
                }
                e
            };
            gemm_nn(2.0, a.as_ref(), b.as_ref(), 0.5, c.as_mut());
            assert!(c.max_abs_diff(&expect) < 1e-10, "shape {m}x{k}x{n}");
        }
    }

    #[test]
    fn gemm_tn_matches_naive() {
        let mut rng = Rng::new(2);
        for &(q, m, n) in &[(1, 1, 1), (11, 3, 5), (64, 16, 16), (37, 5, 1), (20, 2, 9)] {
            let a = Mat::randn(q, m, &mut rng);
            let b = Mat::randn(q, n, &mut rng);
            let mut c = Mat::zeros(m, n);
            gemm_tn(1.0, a.as_ref(), b.as_ref(), 0.0, c.as_mut());
            let expect = naive_nn(&a.transpose(), &b);
            assert!(c.max_abs_diff(&expect) < 1e-10, "shape {q}x{m}x{n}");
        }
    }

    #[test]
    fn gram_is_symmetric_and_correct() {
        let mut rng = Rng::new(3);
        let q = Mat::randn(23, 6, &mut rng);
        let w = gram(q.as_ref());
        let expect = mat_tn(&q, &q);
        assert!(w.max_abs_diff(&expect) < 1e-12);
        for i in 0..6 {
            for j in 0..6 {
                assert_eq!(w.at(i, j), w.at(j, i));
            }
        }
    }

    #[test]
    fn gram_ragged_shapes_match_gemm() {
        // Rows straddling the 256-row tile and odd b exercise the pair /
        // remainder loops of the tiled SYRK.
        let mut rng = Rng::new(31);
        for &(rows, b) in &[(1usize, 1usize), (5, 3), (255, 7), (256, 8), (257, 9), (700, 16)] {
            let q = Mat::randn(rows, b, &mut rng);
            let w = gram(q.as_ref());
            let expect = mat_tn(&q, &q);
            assert!(w.max_abs_diff(&expect) < 1e-10, "shape {rows}x{b}");
        }
    }

    #[test]
    fn gram_accumulate_cols_bitwise_matches_matref_path() {
        // The detached-column form used by the fused kernels must follow
        // the exact tile walk and reduction order of the MatRef form.
        let mut rng = Rng::new(32);
        for &(rows, b) in &[(1usize, 1usize), (255, 3), (300, 5), (700, 8)] {
            let mut q = Mat::randn(rows, b, &mut rng);
            let mut acc1 = vec![0.0; b * b];
            gram_accumulate(q.as_ref(), 0, rows, &mut acc1);
            let mut acc2 = vec![0.0; b * b];
            {
                let cols: Vec<&mut [f64]> = q.data_mut().chunks_mut(rows).collect();
                gram_accumulate_cols(&cols, &mut acc2);
            }
            for (x, y) in acc1.iter().zip(&acc2) {
                assert_eq!(x.to_bits(), y.to_bits(), "shape {rows}x{b}");
            }
        }
    }

    #[test]
    fn trsm_right_lt_solves() {
        let mut rng = Rng::new(4);
        let b = 5;
        // Build a well-conditioned lower-triangular L.
        let mut l = Mat::zeros(b, b);
        for j in 0..b {
            for i in j..b {
                l.set(i, j, if i == j { 2.0 + j as f64 } else { 0.3 * rng.normal() });
            }
        }
        let x_true = Mat::randn(12, b, &mut rng);
        // Q = X_true * Lᵀ
        let q0 = mat_nn(&x_true, &l.transpose());
        let mut q = q0.clone();
        trsm_right_lt(l.as_ref(), q.as_mut());
        assert!(q.max_abs_diff(&x_true) < 1e-10);
    }

    #[test]
    fn trmm_lt_lt_matches_dense() {
        let mut rng = Rng::new(5);
        let b = 6;
        let mut l = Mat::zeros(b, b);
        let mut lb = Mat::zeros(b, b);
        for j in 0..b {
            for i in j..b {
                l.set(i, j, rng.normal());
                lb.set(i, j, rng.normal());
            }
        }
        let r = trmm_lt_lt(&l, &lb);
        let expect = mat_nn(&l.transpose(), &lb.transpose());
        assert!(r.max_abs_diff(&expect) < 1e-12);
    }
}
