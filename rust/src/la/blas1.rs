//! Level-1 vector kernels used across the building blocks.

/// Dot product.
#[inline]
pub fn dot(x: &[f64], y: &[f64]) -> f64 {
    debug_assert_eq!(x.len(), y.len());
    // 4-way split accumulation: lets LLVM vectorize and improves the
    // rounding behaviour vs a single serial accumulator.
    let n = x.len();
    let n4 = n - n % 4;
    let (mut s0, mut s1, mut s2, mut s3) = (0.0, 0.0, 0.0, 0.0);
    let mut i = 0;
    while i < n4 {
        s0 += x[i] * y[i];
        s1 += x[i + 1] * y[i + 1];
        s2 += x[i + 2] * y[i + 2];
        s3 += x[i + 3] * y[i + 3];
        i += 4;
    }
    let mut s = (s0 + s1) + (s2 + s3);
    while i < n {
        s += x[i] * y[i];
        i += 1;
    }
    s
}

/// y += a * x
#[inline]
pub fn axpy(a: f64, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, xi) in y.iter_mut().zip(x) {
        *yi += a * xi;
    }
}

/// x *= a
#[inline]
pub fn scal(a: f64, x: &mut [f64]) {
    for xi in x.iter_mut() {
        *xi *= a;
    }
}

/// Euclidean norm with scaling against overflow/underflow.
pub fn nrm2(x: &[f64]) -> f64 {
    let amax = x.iter().fold(0.0f64, |m, v| m.max(v.abs()));
    if amax == 0.0 || !amax.is_finite() {
        return amax;
    }
    // Fast path: comfortably inside the dynamic range.
    if amax > 1e-140 && amax < 1e140 {
        return dot(x, x).sqrt();
    }
    let inv = 1.0 / amax;
    let mut s = 0.0;
    for v in x {
        let t = v * inv;
        s += t * t;
    }
    amax * s.sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dot_matches_naive() {
        let x: Vec<f64> = (0..37).map(|i| (i as f64).sin()).collect();
        let y: Vec<f64> = (0..37).map(|i| (i as f64).cos()).collect();
        let naive: f64 = x.iter().zip(&y).map(|(a, b)| a * b).sum();
        assert!((dot(&x, &y) - naive).abs() < 1e-12);
    }

    #[test]
    fn axpy_scal() {
        let x = vec![1.0, 2.0, 3.0];
        let mut y = vec![10.0, 20.0, 30.0];
        axpy(2.0, &x, &mut y);
        assert_eq!(y, vec![12.0, 24.0, 36.0]);
        scal(0.5, &mut y);
        assert_eq!(y, vec![6.0, 12.0, 18.0]);
    }

    #[test]
    fn nrm2_scaled_extremes() {
        assert!((nrm2(&[3.0, 4.0]) - 5.0).abs() < 1e-15);
        let tiny = vec![1e-200, 1e-200];
        let expect = 1e-200 * 2.0f64.sqrt();
        assert!((nrm2(&tiny) - expect).abs() / expect < 1e-12);
        let huge = vec![1e200, 1e200];
        assert!((nrm2(&huge) - 1e200 * 2.0f64.sqrt()).abs() / 1e200 < 1e-12);
        assert_eq!(nrm2(&[]), 0.0);
    }
}
