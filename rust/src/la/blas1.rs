//! Level-1 vector kernels used across the building blocks.
//!
//! Generic over [`Scalar`] so the same kernels serve the f32 and f64
//! substrates; accumulation happens in the element precision (the fp32
//! path trades ~√n·ε_32 dot-product error for double the effective
//! memory bandwidth, which the tolerance-driven stopping rules absorb).
//!
//! All three hot kernels delegate to the `util::simd` microkernel layer
//! (`Scalar::simd_dot` / `simd_axpy` / `simd_scal`): the dot's
//! lane-blocked accumulators and fixed reduction tree are pinned there,
//! so results are bitwise identical between the scalar reference and
//! every ISA path (see `util::simd` module docs).

use crate::util::scalar::Scalar;

/// Dot product (lane-blocked accumulation; see `util::simd`).
#[inline]
pub fn dot<S: Scalar>(x: &[S], y: &[S]) -> S {
    debug_assert_eq!(x.len(), y.len());
    S::simd_dot(x, y)
}

/// y += a * x
#[inline]
pub fn axpy<S: Scalar>(a: S, x: &[S], y: &mut [S]) {
    debug_assert_eq!(x.len(), y.len());
    S::simd_axpy(a, x, y)
}

/// x *= a
#[inline]
pub fn scal<S: Scalar>(a: S, x: &mut [S]) {
    S::simd_scal(a, x)
}

/// Euclidean norm with scaling against overflow/underflow.
pub fn nrm2<S: Scalar>(x: &[S]) -> S {
    let amax = x.iter().fold(S::ZERO, |m, v| m.max(v.abs()));
    if amax == S::ZERO || !amax.is_finite() {
        return amax;
    }
    // Fast path: comfortably inside the dynamic range of S.
    let (lo, hi) = S::safe_sq_range();
    if amax > lo && amax < hi {
        return dot(x, x).sqrt();
    }
    let inv = S::ONE / amax;
    let mut s = S::ZERO;
    for v in x {
        let t = *v * inv;
        s += t * t;
    }
    amax * s.sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dot_matches_naive() {
        let x: Vec<f64> = (0..37).map(|i| (i as f64).sin()).collect();
        let y: Vec<f64> = (0..37).map(|i| (i as f64).cos()).collect();
        let naive: f64 = x.iter().zip(&y).map(|(a, b)| a * b).sum();
        assert!((dot(&x, &y) - naive).abs() < 1e-12);
    }

    #[test]
    fn axpy_scal() {
        let x = vec![1.0, 2.0, 3.0];
        let mut y = vec![10.0, 20.0, 30.0];
        axpy(2.0, &x, &mut y);
        assert_eq!(y, vec![12.0, 24.0, 36.0]);
        scal(0.5, &mut y);
        assert_eq!(y, vec![6.0, 12.0, 18.0]);
    }

    #[test]
    fn nrm2_scaled_extremes() {
        assert!((nrm2(&[3.0, 4.0]) - 5.0).abs() < 1e-15);
        let tiny = vec![1e-200, 1e-200];
        let expect = 1e-200 * 2.0f64.sqrt();
        assert!((nrm2(&tiny) - expect).abs() / expect < 1e-12);
        let huge = vec![1e200, 1e200];
        assert!((nrm2(&huge) - 1e200 * 2.0f64.sqrt()).abs() / 1e200 < 1e-12);
        assert_eq!(nrm2::<f64>(&[]), 0.0);
    }

    #[test]
    fn f32_kernels_match_f64_to_f32_precision() {
        let x64: Vec<f64> = (0..129).map(|i| ((i * 37 % 101) as f64 - 50.0) / 17.0).collect();
        let y64: Vec<f64> = (0..129).map(|i| ((i * 11 % 97) as f64 - 48.0) / 13.0).collect();
        let x32: Vec<f32> = x64.iter().map(|&v| v as f32).collect();
        let y32: Vec<f32> = y64.iter().map(|&v| v as f32).collect();
        let d64 = dot(&x64, &y64);
        let d32 = dot(&x32, &y32) as f64;
        assert!((d64 - d32).abs() < 1e-3 * d64.abs().max(1.0), "{d64} vs {d32}");
        let n64 = nrm2(&x64);
        let n32 = nrm2(&x32) as f64;
        assert!((n64 - n32).abs() < 1e-4 * n64, "{n64} vs {n32}");
        // f32 overflow guard: squares of 1e20 overflow f32, the scaled
        // path must not.
        let big = vec![1e20f32, 1e20f32];
        let n = nrm2(&big);
        assert!(n.is_finite());
        assert!((n as f64 - 1e20 * 2.0f64.sqrt()).abs() / 1e20 < 1e-3);
    }
}
