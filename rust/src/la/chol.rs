//! Cholesky factorization (the POTRF of Table 1).
//!
//! Operands here are tiny (b×b or r×r, b ≤ 256), matching the paper's
//! hybrid design where POTRF runs on the host CPU — far below the
//! `cost::parallel_cutoff` grain, so these factorizations never touch
//! the worker pool; the surrounding CholeskyQR2 Gram/TRSM panels do. We still provide a
//! blocked right-looking variant for the larger r×r case. Breakdown (a
//! non-positive pivot) is reported as an error so the orthogonalization
//! layer can fall back to re-orthogonalized CGS (paper §3.2).

use super::mat::{Mat, MatMut, MatRef};
use crate::error::{Error, Result};
use crate::util::scalar::Scalar;

/// Factor columns [j0, j0+jb) of `l` in place (lower Cholesky of the
/// diagonal block, which previous panel updates have already reduced),
/// reading/writing only within the block. The k-sums run over the
/// block-local columns, matching the copy-out-and-factor step of the
/// blocked algorithm without the copy.
fn potrf_in_place_range<S: Scalar>(l: &mut MatMut<S>, j0: usize, jb: usize) -> Result<()> {
    for j in j0..j0 + jb {
        // diagonal — fused multiply-add keeps the pivot accumulation at
        // one rounding per term, which is what decides breakdown at f32
        let mut d = l.at(j, j);
        for k in j0..j {
            let v = l.at(j, k);
            d = v.mul_add(-v, d);
        }
        if d <= S::ZERO || !d.is_finite() {
            return Err(Error::CholeskyBreakdown { pivot: j, value: d.to_f64() });
        }
        let djj = d.sqrt();
        l.set(j, j, djj);
        let inv = S::ONE / djj;
        // column update below the diagonal (within the block)
        for i in (j + 1)..j0 + jb {
            let mut s = l.at(i, j);
            for k in j0..j {
                s = l.at(i, k).mul_add(-l.at(j, k), s);
            }
            l.set(i, j, s * inv);
        }
    }
    Ok(())
}

/// Lower Cholesky fully in place on a borrowed square view: A = L·Lᵀ
/// with L overwriting A (upper triangle zeroed). Blocked right-looking
/// for n > 64, with the diagonal-block factorization running in place —
/// no temporaries, which is what keeps the CholeskyQR2 passes inside
/// the iteration loops allocation-free. The panel update (L21 solve +
/// A22 rank-jb update) runs in column axpy form on the `util::simd`
/// microkernels while staying bitwise-identical to the scalar
/// recurrence (see inline comments). Breakdown (non-positive pivot)
/// is reported as an error so the orthogonalization layer can fall back
/// to re-orthogonalized CGS (paper §3.2).
pub fn potrf_in_place<S: Scalar>(l: &mut MatMut<S>) -> Result<()> {
    let n = l.rows;
    assert_eq!(l.cols, n, "potrf needs square input");
    let nb = 32usize;
    if n <= 64 {
        potrf_in_place_range(l, 0, n)?;
    } else {
        let mut j0 = 0;
        while j0 < n {
            let jb = nb.min(n - j0);
            potrf_in_place_range(l, j0, jb)?;
            let rest = n - j0 - jb;
            if rest > 0 {
                // L21 = A21 · L11⁻ᵀ  (solve X L11ᵀ = A21), column axpy
                // form on the `util::simd` microkernels. Per element this
                // is the same k-ordered recurrence as the scalar loop and
                // s + (−ljk)·lik ≡ s − ljk·lik bitwise (negation is
                // exact), so the blocked/unblocked parity is preserved.
                for j in 0..jb {
                    for k in 0..j {
                        let ljk = l.at(j0 + j, j0 + k);
                        let (ck, cj) = l.col_pair_mut(j0 + k, j0 + j);
                        S::simd_axpy(-ljk, &ck[j0 + jb..n], &mut cj[j0 + jb..n]);
                    }
                    let d = l.at(j0 + j, j0 + j);
                    for v in l.col_mut(j0 + j)[j0 + jb..n].iter_mut() {
                        *v /= d;
                    }
                }
                // A22 −= L21 · L21ᵀ (lower triangle only), same axpy form.
                for jj in 0..rest {
                    for k in 0..jb {
                        let f = l.at(j0 + jb + jj, j0 + k);
                        let (ck, cj) = l.col_pair_mut(j0 + k, j0 + jb + jj);
                        S::simd_axpy(-f, &ck[j0 + jb + jj..n], &mut cj[j0 + jb + jj..n]);
                    }
                }
            }
            j0 += jb;
        }
    }
    // zero the upper triangle
    for j in 1..n {
        for i in 0..j {
            l.set(i, j, S::ZERO);
        }
    }
    Ok(())
}

/// Out-parameter POTRF: copy `a` into the caller-provided `l` and
/// factor in place ([`potrf_in_place`]). The orthogonalization layer
/// calls this with workspace buffers so no allocation happens per pass.
pub fn potrf_into<S: Scalar>(a: MatRef<S>, mut l: MatMut<S>) -> Result<()> {
    assert_eq!(a.rows, a.cols, "potrf needs square input");
    assert_eq!((l.rows, l.cols), (a.rows, a.cols), "potrf_into output shape");
    l.data.copy_from_slice(a.data);
    potrf_in_place(&mut l)
}

/// Unblocked lower Cholesky: A = L·Lᵀ; returns L (strictly lower + diag),
/// upper triangle zeroed. Errors with `CholeskyBreakdown` on a
/// non-positive pivot.
pub fn potrf_unblocked<S: Scalar>(a: &Mat<S>) -> Result<Mat<S>> {
    let n = a.rows();
    assert_eq!(a.cols(), n, "potrf needs square input");
    let mut l = a.clone();
    for j in 0..n {
        // diagonal — fused multiply-add keeps the pivot accumulation at
        // one rounding per term, which is what decides breakdown at f32
        let mut d = l.at(j, j);
        for k in 0..j {
            let v = l.at(j, k);
            d = v.mul_add(-v, d);
        }
        if d <= S::ZERO || !d.is_finite() {
            return Err(Error::CholeskyBreakdown { pivot: j, value: d.to_f64() });
        }
        let djj = d.sqrt();
        l.set(j, j, djj);
        let inv = S::ONE / djj;
        // column update below the diagonal
        for i in (j + 1)..n {
            let mut s = l.at(i, j);
            for k in 0..j {
                s = l.at(i, k).mul_add(-l.at(j, k), s);
            }
            l.set(i, j, s * inv);
        }
    }
    // zero the upper triangle
    for j in 1..n {
        for i in 0..j {
            l.set(i, j, S::ZERO);
        }
    }
    Ok(l)
}

/// Blocked right-looking lower Cholesky with panel width `nb`.
/// Identical contract to [`potrf_unblocked`].
pub fn potrf_blocked<S: Scalar>(a: &Mat<S>, nb: usize) -> Result<Mat<S>> {
    let n = a.rows();
    if n <= nb {
        return potrf_unblocked(a);
    }
    let mut l = a.clone();
    let mut j0 = 0;
    while j0 < n {
        let jb = nb.min(n - j0);
        // Factor the diagonal block A11 (updated in previous iterations).
        let a11 = Mat::from_fn(jb, jb, |i, j| l.at(j0 + i, j0 + j));
        let l11 = potrf_unblocked(&a11).map_err(|e| match e {
            Error::CholeskyBreakdown { pivot, value } => {
                Error::CholeskyBreakdown { pivot: j0 + pivot, value }
            }
            e => e,
        })?;
        for j in 0..jb {
            for i in 0..jb {
                l.set(j0 + i, j0 + j, l11.at(i, j));
            }
        }
        let rest = n - j0 - jb;
        if rest > 0 {
            // L21 = A21 · L11⁻ᵀ  (solve X L11ᵀ = A21, row-block)
            for j in 0..jb {
                for i in 0..rest {
                    let mut s = l.at(j0 + jb + i, j0 + j);
                    for k in 0..j {
                        s -= l.at(j0 + jb + i, j0 + k) * l11.at(j, k);
                    }
                    l.set(j0 + jb + i, j0 + j, s / l11.at(j, j));
                }
            }
            // A22 −= L21 · L21ᵀ (lower triangle only)
            for jj in 0..rest {
                for ii in jj..rest {
                    let mut s = l.at(j0 + jb + ii, j0 + jb + jj);
                    for k in 0..jb {
                        s -= l.at(j0 + jb + ii, j0 + k) * l.at(j0 + jb + jj, j0 + k);
                    }
                    l.set(j0 + jb + ii, j0 + jb + jj, s);
                }
            }
        }
        j0 += jb;
    }
    for j in 1..n {
        for i in 0..j {
            l.set(i, j, S::ZERO);
        }
    }
    Ok(l)
}

/// Default allocating entry point: blocked for n > 64. Thin wrapper
/// over [`potrf_into`]; the hot paths call the into/in-place forms with
/// workspace buffers directly.
pub fn potrf<S: Scalar>(a: &Mat<S>) -> Result<Mat<S>> {
    let mut l = Mat::zeros(a.rows(), a.cols());
    potrf_into(a.as_ref(), l.as_mut())?;
    Ok(l)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::la::blas3::{mat_nn, mat_tn};
    use crate::util::rng::Rng;

    fn spd(n: usize, seed: u64) -> Mat {
        let mut rng = Rng::new(seed);
        let g = Mat::randn(n + 4, n, &mut rng);
        let mut w = mat_tn(&g, &g);
        for i in 0..n {
            w.add_at(i, i, 1e-3);
        }
        w
    }

    #[test]
    fn unblocked_reconstructs() {
        for n in [1, 2, 5, 16, 33] {
            let a = spd(n, n as u64);
            let l = potrf_unblocked(&a).unwrap();
            let back = mat_nn(&l, &l.transpose());
            assert!(back.max_abs_diff(&a) < 1e-9 * (n as f64), "n={n}");
        }
    }

    #[test]
    fn blocked_matches_unblocked() {
        for n in [48, 100, 130] {
            let a = spd(n, 100 + n as u64);
            let l1 = potrf_unblocked(&a).unwrap();
            let l2 = potrf_blocked(&a, 32).unwrap();
            assert!(l1.max_abs_diff(&l2) < 1e-9, "n={n}");
        }
    }

    #[test]
    fn breakdown_detected_with_pivot_index() {
        // Rank-deficient: Gram of a matrix with a repeated column.
        let mut rng = Rng::new(9);
        let mut g: Mat<f64> = Mat::randn(10, 4, &mut rng);
        let c0 = g.col(0).to_vec();
        g.col_mut(2).copy_from_slice(&c0);
        let w = mat_tn(&g, &g);
        match potrf(&w) {
            Err(Error::CholeskyBreakdown { pivot, .. }) => assert_eq!(pivot, 2),
            other => panic!("expected breakdown, got {other:?}"),
        }
    }

    #[test]
    fn into_form_matches_allocating_form_bitwise() {
        for n in [1usize, 5, 33, 64, 100, 130] {
            let a = spd(n, 300 + n as u64);
            let l1 = if n > 64 { potrf_blocked(&a, 32).unwrap() } else { potrf_unblocked(&a).unwrap() };
            let mut l2 = Mat::zeros(n, n);
            potrf_into(a.as_ref(), l2.as_mut()).unwrap();
            assert_eq!(l1.data(), l2.data(), "n={n}");
        }
    }

    #[test]
    fn into_form_reports_breakdown_pivot() {
        let mut rng = Rng::new(10);
        let mut g: Mat<f64> = Mat::randn(10, 4, &mut rng);
        let c0 = g.col(0).to_vec();
        g.col_mut(2).copy_from_slice(&c0);
        let w = mat_tn(&g, &g);
        let mut l = Mat::zeros(4, 4);
        match potrf_into(w.as_ref(), l.as_mut()) {
            Err(Error::CholeskyBreakdown { pivot, .. }) => assert_eq!(pivot, 2),
            other => panic!("expected breakdown, got {other:?}"),
        }
    }

    #[test]
    fn upper_triangle_is_zeroed() {
        let a = spd(6, 77);
        let l = potrf(&a).unwrap();
        for j in 1..6 {
            for i in 0..j {
                assert_eq!(l.at(i, j), 0.0);
            }
        }
    }
}
