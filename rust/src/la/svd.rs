//! Small dense SVD (the GESVD of Table 1).
//!
//! The paper ships the r×r (r ≤ 256) SVD to LAPACK on the host. With no
//! LAPACK available offline we implement a one-sided Jacobi SVD: simple,
//! numerically robust (high relative accuracy on small singular values),
//! and easily fast enough for r ≤ 256 — matching the paper's "negligible
//! cost" role for this block.

use super::blas1::{dot, nrm2};
use super::mat::{Mat, MatMut, MatRef};
use crate::error::{Error, Result};
use crate::util::scalar::Scalar;

/// Result of a (thin) SVD: A = U · diag(s) · Vᵀ with U m×n, s desc-sorted,
/// V n×n.
#[derive(Clone, Debug)]
pub struct Svd<S: Scalar = f64> {
    pub u: Mat<S>,
    pub s: Vec<S>,
    pub v: Mat<S>,
}

/// Reusable O(n) bookkeeping for [`jacobi_svd_scratch_into`]: the norm
/// cache, sort keys, permutation and column-cycling scratch that the
/// plain into-form allocates per call. Streaming callers
/// (`algo::incremental`) hold one of these across updates so every
/// small-core SVD in the update loop is strictly allocation-free.
pub struct JacobiScratch<S: Scalar = f64> {
    norms: Vec<S>,
    svals: Vec<(S, usize)>,
    perm: Vec<usize>,
    done: Vec<bool>,
    tmp: Vec<S>,
    deficient: Vec<usize>,
}

impl<S: Scalar> JacobiScratch<S> {
    /// Scratch sized for inputs up to `rows_max`×`n_max` (the column
    /// cycling buffer serves both the m-row U and the n-row V
    /// permutations, so it is sized at the larger of the two).
    pub fn with_capacity(rows_max: usize, n_max: usize) -> JacobiScratch<S> {
        JacobiScratch {
            norms: Vec::with_capacity(n_max),
            svals: Vec::with_capacity(n_max),
            perm: Vec::with_capacity(n_max),
            done: Vec::with_capacity(n_max),
            tmp: Vec::with_capacity(rows_max.max(n_max)),
            deficient: Vec::with_capacity(n_max),
        }
    }
}

/// One-sided Jacobi SVD of A (m×n, m ≥ n), out-parameter form.
///
/// `u` (m×n) doubles as the rotation workspace — A is copied into it and
/// rotated in place — and `v` (n×n) accumulates the rotations, so the
/// caller can hand in planned workspace buffers and the big factors
/// never hit the allocator (the per-restart call in LancSVD writes
/// straight into `svd.u`/`svd.v` workspace slots). `s` is cleared and
/// refilled. This convenience form still allocates the O(n) bookkeeping
/// per call; pass a reused [`JacobiScratch`] to
/// [`jacobi_svd_scratch_into`] for the fully allocation-free path.
///
/// Rotates column pairs until all pairs are numerically orthogonal; then
/// σ_j = ‖a_j‖, U = A·diag(1/σ), and V accumulates the rotations.
/// Columns with σ below `n·ε·σ_max` are completed to an orthonormal set
/// (their singular vectors are arbitrary).
pub fn jacobi_svd_into<S: Scalar>(
    a: MatRef<S>,
    u: MatMut<S>,
    s_out: &mut Vec<S>,
    v: MatMut<S>,
) -> Result<()> {
    let mut scratch = JacobiScratch::with_capacity(a.rows, a.cols);
    jacobi_svd_scratch_into(a, u, s_out, v, &mut scratch)
}

/// [`jacobi_svd_into`] with caller-owned bookkeeping: allocation-free
/// when `scratch` was sized (via [`JacobiScratch::with_capacity`]) for
/// this problem and `s_out` has capacity ≥ n — except on the
/// rank-deficient path, where basis completion still allocates its
/// candidate column (degenerate inputs only, never the steady state).
pub fn jacobi_svd_scratch_into<S: Scalar>(
    a: MatRef<S>,
    mut u: MatMut<S>,
    s_out: &mut Vec<S>,
    mut v: MatMut<S>,
    scratch: &mut JacobiScratch<S>,
) -> Result<()> {
    let (m, n) = (a.rows, a.cols);
    assert!(m >= n, "jacobi_svd needs m >= n (got {m}x{n})");
    assert_eq!((u.rows, u.cols), (m, n), "jacobi_svd_into U shape");
    assert_eq!((v.rows, v.cols), (n, n), "jacobi_svd_into V shape");
    let JacobiScratch { norms, svals, perm, done, tmp, deficient } = scratch;
    let w = &mut u; // rotation workspace aliases the U output
    w.data.copy_from_slice(a.data);
    v.fill(S::ZERO);
    for i in 0..n {
        v.set(i, i, S::ONE);
    }
    let eps = S::EPSILON;
    let max_sweeps = 60;
    let mut converged = false;
    let mut last_off = S::ZERO;
    // Numerically-zero column threshold: pairs involving columns whose
    // norm has collapsed below n·ε·‖A‖ carry only rounding noise — their
    // "relative" off-diagonal never settles and would stall the cyclic
    // sweep on rank-deficient inputs.
    // Cached squared column norms, updated analytically per rotation
    // (§Perf: cuts the per-pair dot count from 3 to 1; the cache is
    // refreshed every few sweeps to bound drift).
    norms.clear();
    norms.extend((0..n).map(|j| dot(w.col(j), w.col(j))));
    let colnorm_max0 = norms.iter().copied().fold(S::ZERO, S::max);
    let tiny2 = S::from_f64((n as f64 * eps.to_f64()).powi(2)) * colnorm_max0;
    for sweep in 0..max_sweeps {
        if sweep > 0 && sweep % 4 == 0 {
            for (j, nj) in norms.iter_mut().enumerate() {
                *nj = dot(w.col(j), w.col(j));
            }
        }
        let mut off = S::ZERO;
        let mut rotated = false;
        for p in 0..n {
            for q in (p + 1)..n {
                let (app, aqq) = (norms[p], norms[q]);
                let denom = (app * aqq).sqrt();
                if denom == S::ZERO || app <= tiny2 || aqq <= tiny2 {
                    continue;
                }
                let apq = dot(w.col(p), w.col(q));
                let rel = apq.abs() / denom;
                off = off.max(rel);
                if rel <= S::from_f64(1e2) * eps {
                    continue;
                }
                rotated = true;
                // Jacobi rotation that zeroes the (p,q) Gram entry.
                // (sign(0) must be +1: equal-norm parallel columns would
                // otherwise yield a null rotation and stall convergence.)
                let two = S::from_f64(2.0);
                let tau = (aqq - app) / (two * apq);
                let sgn = if tau >= S::ZERO { S::ONE } else { -S::ONE };
                let t = sgn / (tau.abs() + (S::ONE + tau * tau).sqrt());
                let c = S::ONE / (S::ONE + t * t).sqrt();
                let s = c * t;
                rotate_cols(w, p, q, c, s);
                rotate_cols(&mut v, p, q, c, s);
                // norm updates under the rotation (exact in real arith.)
                norms[p] = c * c * app - two * c * s * apq + s * s * aqq;
                norms[q] = s * s * app + two * c * s * apq + c * c * aqq;
            }
        }
        last_off = off;
        if !rotated || off <= S::from_f64(1e2) * eps {
            converged = true;
            break;
        }
    }
    if !converged {
        return Err(Error::SvdNoConvergence { sweeps: max_sweeps, off: last_off.to_f64() });
    }

    // Extract singular values and sort descending. The in-place
    // unstable sort keeps this allocation-free; the index tiebreak
    // makes it a total order, so ties land exactly where the old
    // stable sort put them.
    svals.clear();
    svals.extend((0..n).map(|j| (nrm2(w.col(j)), j)));
    svals.sort_unstable_by(|a, b| b.0.partial_cmp(&a.0).unwrap().then(a.1.cmp(&b.1)));
    let smax = svals.first().map(|x| x.0).unwrap_or(S::ZERO);
    let tiny = S::from_f64(n as f64) * eps * smax;

    s_out.clear();
    s_out.extend(svals.iter().map(|x| x.0));
    // Reorder U (= rotated A) and V columns into descending-σ order in
    // place (cycle-following permutation, one column of scratch).
    perm.clear();
    perm.extend(svals.iter().map(|x| x.1));
    permute_columns(w, perm, done, tmp);
    permute_columns(&mut v, perm, done, tmp);

    deficient.clear();
    for (out_j, &sigma) in s_out.iter().enumerate() {
        if sigma > tiny && sigma > S::ZERO {
            let inv = S::ONE / sigma;
            for x in w.col_mut(out_j) {
                *x *= inv;
            }
        } else {
            // Zero the collapsed column (it carries only rounding noise)
            // so basis completion sees exactly what the allocating form
            // always saw.
            w.col_mut(out_j).fill(S::ZERO);
            deficient.push(out_j);
        }
    }
    // Complete rank-deficient directions to an orthonormal basis via
    // Gram-Schmidt against the existing columns of U.
    if !deficient.is_empty() {
        complete_basis(w, deficient);
    }
    Ok(())
}

/// Allocating wrapper around [`jacobi_svd_into`] (tests / one-shot
/// callers; the solve loops pass workspace buffers to the into form).
pub fn jacobi_svd<S: Scalar>(a: &Mat<S>) -> Result<Svd<S>> {
    let (m, n) = (a.rows(), a.cols());
    let mut u = Mat::zeros(m, n);
    let mut s = Vec::with_capacity(n);
    let mut v = Mat::zeros(n, n);
    jacobi_svd_into(a.as_ref(), u.as_mut(), &mut s, v.as_mut())?;
    Ok(Svd { u, s, v })
}

/// Apply the column permutation `out column j ← source column perm[j]`
/// in place (cycle following; `perm` must be a permutation of 0..n).
/// `done`/`tmp` are caller-owned scratch (allocation-free when their
/// capacity covers n flags / `m.rows` elements).
fn permute_columns<S: Scalar>(
    m: &mut MatMut<S>,
    perm: &[usize],
    done: &mut Vec<bool>,
    tmp: &mut Vec<S>,
) {
    let rows = m.rows;
    let n = perm.len();
    done.clear();
    done.resize(n, false);
    tmp.clear();
    tmp.resize(rows, S::ZERO);
    for start in 0..n {
        if done[start] || perm[start] == start {
            done[start] = true;
            continue;
        }
        tmp.copy_from_slice(m.col(start));
        let mut j = start;
        loop {
            let src = perm[j];
            if src == start {
                m.col_mut(j).copy_from_slice(&tmp);
                done[j] = true;
                break;
            }
            let (s_col, d_col) = m.col_pair_mut(src, j);
            d_col.copy_from_slice(s_col);
            done[j] = true;
            j = src;
        }
    }
}

fn rotate_cols<S: Scalar>(m: &mut MatMut<S>, p: usize, q: usize, c: S, s: S) {
    let rows = m.rows;
    assert!(p < q, "rotate_cols expects p < q");
    let (head, tail) = m.data.split_at_mut(q * rows);
    let cp = &mut head[p * rows..(p + 1) * rows];
    let cq = &mut tail[..rows];
    for i in 0..rows {
        let xp = cp[i];
        let xq = cq[i];
        cp[i] = c * xp - s * xq;
        cq[i] = s * xp + c * xq;
    }
}

/// Fill the listed (near-zero) columns of U with unit vectors orthogonal
/// to all other columns (Gram–Schmidt over coordinate seeds).
fn complete_basis<S: Scalar>(u: &mut MatMut<S>, deficient: &[usize]) {
    let m = u.rows;
    let n = u.cols;
    for &j in deficient {
        let mut best: Option<Vec<S>> = None;
        for seed in 0..m.min(n + deficient.len() + 2) {
            let mut cand = vec![S::ZERO; m];
            cand[seed] = S::ONE;
            // Orthogonalize twice (CGS2) against all other columns.
            for _ in 0..2 {
                for k in 0..n {
                    if k == j {
                        continue;
                    }
                    let proj = dot(&cand, u.col(k));
                    for i in 0..m {
                        cand[i] -= proj * u.col(k)[i];
                    }
                }
            }
            let nrm = nrm2(&cand);
            if nrm > S::from_f64(0.5) {
                for x in cand.iter_mut() {
                    *x /= nrm;
                }
                best = Some(cand);
                break;
            }
        }
        if let Some(cand) = best {
            u.col_mut(j).copy_from_slice(&cand);
        }
    }
}

/// Truncate an SVD to its leading `r` triplets.
pub fn truncate<S: Scalar>(svd: &Svd<S>, r: usize) -> Svd<S> {
    Svd {
        u: svd.u.panel_owned(0, r),
        s: svd.s[..r].to_vec(),
        v: svd.v.panel_owned(0, r),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::la::blas3::mat_nn;
    use crate::la::norms::orth_error;
    use crate::la::qr::random_orthonormal;
    use crate::util::rng::Rng;

    fn reconstruct(svd: &Svd) -> Mat {
        let n = svd.s.len();
        let mut us = svd.u.clone();
        for j in 0..n {
            let s = svd.s[j];
            for x in us.col_mut(j) {
                *x *= s;
            }
        }
        mat_nn(&us, &svd.v.transpose())
    }

    #[test]
    fn svd_of_known_spectrum() {
        let mut rng = Rng::new(31);
        let (m, n) = (30, 8);
        let x = random_orthonormal(m, n, &mut rng);
        let y = random_orthonormal(n, n, &mut rng);
        let sig: Vec<f64> = (0..n).map(|i| 10.0f64.powi(-(i as i32))).collect();
        let mut xs = x.clone();
        for j in 0..n {
            for v in xs.col_mut(j) {
                *v *= sig[j];
            }
        }
        let a = mat_nn(&xs, &y.transpose());
        let svd = jacobi_svd(&a).unwrap();
        for i in 0..n {
            assert!(
                (svd.s[i] - sig[i]).abs() / sig[i] < 1e-10,
                "sigma_{i}: {} vs {}",
                svd.s[i],
                sig[i]
            );
        }
        assert!(reconstruct(&svd).max_abs_diff(&a) < 1e-10);
        assert!(orth_error(&svd.u) < 1e-12);
        assert!(orth_error(&svd.v) < 1e-12);
    }

    #[test]
    fn svd_square_and_tall() {
        let mut rng = Rng::new(32);
        for &(m, n) in &[(6usize, 6usize), (40, 12), (9, 1), (256, 16)] {
            let a = Mat::randn(m, n, &mut rng);
            let svd = jacobi_svd(&a).unwrap();
            assert!(
                reconstruct(&svd).max_abs_diff(&a) < 1e-9,
                "reconstruct {m}x{n}"
            );
            // descending
            for i in 1..n {
                assert!(svd.s[i] <= svd.s[i - 1] + 1e-12);
            }
        }
    }

    #[test]
    fn rank_deficient_input() {
        let mut rng = Rng::new(33);
        let mut a = Mat::randn(20, 5, &mut rng);
        let c0 = a.col(0).to_vec();
        a.col_mut(3).copy_from_slice(&c0); // rank 4
        let svd = jacobi_svd(&a).unwrap();
        assert!(svd.s[4] < 1e-10 * svd.s[0]);
        assert!(reconstruct(&svd).max_abs_diff(&a) < 1e-9);
        assert!(orth_error(&svd.u) < 1e-8);
    }

    #[test]
    fn zero_matrix() {
        let a = Mat::zeros(7, 3);
        let svd = jacobi_svd(&a).unwrap();
        assert!(svd.s.iter().all(|&s| s == 0.0));
        assert!(orth_error(&svd.u) < 1e-12);
    }

    #[test]
    fn truncate_keeps_leading() {
        let a: Mat<f64> = Mat::randn(12, 6, &mut Rng::new(4));
        let svd = jacobi_svd(&a).unwrap();
        let t = truncate(&svd, 3);
        assert_eq!(t.u.cols(), 3);
        assert_eq!(t.v.cols(), 3);
        assert_eq!(t.s.len(), 3);
        assert_eq!(t.s[..], svd.s[..3]);
    }

    #[test]
    fn banded_bk_matrix_like_lancsvd() {
        // B_k lower-banded (Eq. 8 structure): diag blocks lower-tri,
        // sub-diagonal blocks upper-tri. Check SVD handles it.
        let r = 32;
        let b = 8;
        let mut rng = Rng::new(35);
        let mut bk = Mat::zeros(r, r);
        for blk in 0..(r / b) {
            for j in 0..b {
                for i in j..b {
                    bk.set(blk * b + i, blk * b + j, rng.normal());
                }
            }
            if blk + 1 < r / b {
                for j in 0..b {
                    for i in 0..=j {
                        bk.set((blk + 1) * b + i, blk * b + j, 0.1 * rng.normal());
                    }
                }
            }
        }
        let svd = jacobi_svd(&bk).unwrap();
        assert!(reconstruct(&svd).max_abs_diff(&bk) < 1e-9);
    }
}
