//! Workspace planning: preallocated, shape-checked buffers for the
//! allocation-free steady state.
//!
//! The paper assembles RandSVD and LancSVD from device building blocks
//! whose operands live in **preallocated GPU buffers** — the iteration
//! loop never allocates. RSVDPACK and the out-of-core block-RSVD work
//! likewise size every panel and scratch block up front, because
//! allocation inside the iteration is what kills GPU (and NUMA-CPU)
//! throughput. This module is the host-side analogue:
//!
//! * a [`Plan`] is computed **once per solve** from the problem and
//!   algorithm parameters `(m, n, r, p, b)` and lists every named buffer
//!   the solve will touch, with its exact shape;
//! * a [`Workspace`] materializes the plan as an arena of named,
//!   shape-checked, `RefCell`-guarded `Mat` buffers. Algorithms and the
//!   orthogonalization kernels borrow buffers by name; borrowing the
//!   same buffer twice panics (runtime aliasing rejection), and
//!   [`Workspace::mat`] additionally panics on a shape mismatch.
//!
//! ## Plan lifecycle
//!
//! 1. the algorithm entry point builds the `Plan` from `(m, n, r, p, b)`;
//! 2. `Workspace::new(plan)` allocates every buffer with **banded
//!    first-touch** (below);
//! 3. the algorithm hands the plan to the backend via
//!    [`crate::backend::Backend::plan`] so device backends can stage
//!    buffers for exactly these shapes;
//! 4. the solve runs: every inner-iteration operand is a borrow of a
//!    planned buffer (or a panel view of one) and every kernel is an
//!    out-parameter `*_into` op — zero heap allocations in steady state
//!    (pinned by `tests/test_workspace.rs` and the
//!    `BENCH_ASSERT_NOALLOC` gate in `bench_blocks`);
//! 5. the workspace outlives the solve and can be handed to the next
//!    solve with the same plan (`lancsvd_with` / `randsvd_with`), so
//!    repeated solves — restarts, parameter sweeps, services — pay the
//!    allocation and page-fault cost once.
//!
//! ## Banded first-touch (NUMA placement)
//!
//! On first-touch NUMA systems a page belongs to the node of the thread
//! that faults it in. PR 3's pool gives every `(rows, threads)`
//! partition a *static* banding — band `w` is always the same row range
//! on the same long-lived worker. [`Workspace::new`] therefore
//! zero-fills each buffer through the pool in page-aligned **row
//! bands** (the `parallel_row_blocks` decomposition the gather SpMM and
//! the row-tiled SYRK use), so each page of a worker's row band is
//! faulted by that worker — instead of every page landing on the
//! submitting thread's node, which is what `Mat::zeros` inside the
//! iteration did before this refactor. Column-group-partitioned GEMM
//! outputs see a compromise placement (their workers own columns); the
//! row-banded choice follows the paper's sparse hot path, where the
//! SpMM/SYRK row streams dominate bandwidth.

use std::cell::{RefCell, RefMut};
use std::mem::MaybeUninit;

use super::mat::Mat;
use crate::error::{Error, Result};
use crate::util::pool;
use crate::util::scalar::Scalar;

/// Canonical buffer names. Kept as constants so algorithm and kernel
/// layers agree on spelling and the planner can size them in one place.
pub mod names {
    /// b×b Gram matrix W = QᵀQ (CholeskyQR pass scratch).
    pub const ORTH_W: &str = "orth.w";
    /// b×b first-pass Cholesky factor L.
    pub const ORTH_L1: &str = "orth.l1";
    /// b×b second-pass Cholesky factor L̄.
    pub const ORTH_L2: &str = "orth.l2";
    /// b×b small triangular factor destination (R of Alg. 4/5 blocks).
    /// Caller-owned: the algorithm loops hold this while calling the
    /// backend orth kernels — backend overrides must not borrow it
    /// (see the workspace contract on `Backend::orth_cholqr2_into`).
    pub const ORTH_R: &str = "orth.r";
    /// History-projection coefficients H (capacity s_max×b, viewed
    /// s×b). Caller-owned, as for [`ORTH_R`].
    pub const ORTH_H: &str = "orth.h";
    /// Second-pass projection coefficients H̄ (capacity s_max×b).
    pub const ORTH_HBAR: &str = "orth.hbar";
    /// Panel snapshot for the Cholesky-breakdown fallback (capacity
    /// q_max×b, viewed rows×b).
    pub const ORTH_SNAP: &str = "orth.snap";

    /// LancSVD: right Lanczos basis P = [Q₁ … Q_k] (n×r).
    pub const LANC_P: &str = "lanc.p";
    /// LancSVD: left Lanczos basis P̄ = [Q̄₁ … Q̄_k] (m×r).
    pub const LANC_PBAR: &str = "lanc.pbar";
    /// LancSVD: block-bidiagonal B_k (r×r).
    pub const LANC_B: &str = "lanc.b";
    /// LancSVD: last sub-diagonal block R_k (b×b, residual estimates).
    pub const LANC_RK: &str = "lanc.rk";
    /// LancSVD: current left block Q̄ᵢ (m×b).
    pub const LANC_QBAR: &str = "lanc.qbar";
    /// LancSVD: next left block Q̄ᵢ₊₁ (m×b).
    pub const LANC_QNEXT: &str = "lanc.qnext";
    /// LancSVD: restart rotation scratch (capacity max(m,n)×r).
    pub const LANC_TMP: &str = "lanc.tmp";

    /// RandSVD: right sketch Q (n×r).
    pub const RAND_Q: &str = "rand.q";
    /// RandSVD: left sketch Q̄ (m×r).
    pub const RAND_QBAR: &str = "rand.qbar";
    /// RandSVD: last triangular factor R (r×r).
    pub const RAND_R: &str = "rand.r";
    /// RandSVD: fused power-step destination Z = Aᵀ(A·Q) (n×r). Planned
    /// unconditionally so the fuse decision can flip per solve without
    /// re-planning; ping-pongs with [`RAND_Q`] on the fused path.
    pub const RAND_Z: &str = "rand.z";

    /// LancSVD: fused-sweep Gram G = Q̄ᵢ₊₁ᵀQ̄ᵢ₊₁ (b×b), produced by
    /// `Backend::apply_a_gram_into` and consumed by the Gram-downdated
    /// first CholeskyQR pass in `orth_cgs_cqr2_pregram_into`.
    pub const LANC_G: &str = "lanc.g";

    /// Host GESVD: left factor Ū of the small r×r SVD (r×r).
    pub const SVD_U: &str = "svd.u";
    /// Host GESVD: right factor V̄ of the small r×r SVD (r×r).
    pub const SVD_V: &str = "svd.v";

    /// Incremental update: projection coefficients H = UᵀC (capacity
    /// r×b, viewed k×c).
    pub const INC_H: &str = "inc.h";
    /// Incremental update: re-orthogonalization corrections G = UᵀE
    /// (capacity r×b).
    pub const INC_G: &str = "inc.g";
    /// Incremental update: folded correction product G·R_E (capacity
    /// r×b).
    pub const INC_GRE: &str = "inc.gre";
    /// Incremental update: first-pass triangular factor R_E (capacity
    /// b×b, viewed c×c).
    pub const INC_RE: &str = "inc.re";
    /// Incremental update: second-pass triangular factor T (b×b).
    pub const INC_T: &str = "inc.t";
    /// Incremental update: folded product T·R_E (b×b).
    pub const INC_TRE: &str = "inc.tre";
    /// Incremental update: extended left basis [U Q_E] (m×(r+b)).
    pub const INC_EXT: &str = "inc.ext";
    /// Incremental update: rotated left basis destination (m×r).
    pub const INC_UNEW: &str = "inc.unew";
    /// Incremental update: augmented core [diag(s) H; 0 R_E]
    /// ((r+b)×(r+b), viewed (k+c)×(k+c)).
    pub const INC_CORE: &str = "inc.core";
    /// Incremental update: core-SVD left factor Ū ((r+b)×(r+b)).
    pub const INC_CU: &str = "inc.cu";
    /// Incremental update: core-SVD right factor V̄ ((r+b)×(r+b)).
    pub const INC_CV: &str = "inc.cv";
    /// Incremental update: extended right factor [V 0; 0 I] (capacity
    /// n×(r+b), viewed (cols_seen+c)×(k+c)).
    pub const INC_VEXT: &str = "inc.vext";
    /// Incremental update: rotated right factor destination (capacity
    /// n×r).
    pub const INC_VNEW: &str = "inc.vnew";
}

/// Which solve a [`Plan`] was computed for (shapes differ per algorithm).
/// `Hash` because the serving layer (`runtime::serve`) keys its warm
/// workspace pool on `(kind, m, n, r, p, b, dtype)` shape classes.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum PlanKind {
    /// LancSVD (Alg. 2): Lanczos bases + B_k + restart scratch.
    LancSvd,
    /// RandSVD (Alg. 1): the two sketches + triangular factor.
    RandSvd,
    /// Standalone orthogonalization (the thin value-returning wrappers
    /// and the orth/cgs_qr unit paths).
    Orth,
    /// Streaming incremental update (`algo::incremental`): per-block
    /// project → expand → small-SVD → rotate scratch. Here `n` is the
    /// planned stream capacity (total columns the stream may absorb),
    /// `r` the rank cap and `b` the maximum arriving block width.
    Incremental,
}

#[derive(Clone, Debug)]
struct PlanEntry {
    name: &'static str,
    rows: usize,
    cols: usize,
}

/// The buffer plan of one solve: computed once from `(m, n, r, p, b)`,
/// consumed by [`Workspace::new`] and handed to
/// [`crate::backend::Backend::plan`] so backends can stage device
/// buffers for exactly these shapes.
#[derive(Clone, Debug)]
pub struct Plan {
    pub kind: PlanKind,
    /// Operand row count.
    pub m: usize,
    /// Operand column count.
    pub n: usize,
    /// Subspace / Krylov width.
    pub r: usize,
    /// Outer-iteration budget (does not affect any buffer shape; carried
    /// so backends can size per-iteration device queues if they want).
    pub p: usize,
    /// Block width of the orthogonalization panels.
    pub b: usize,
    entries: Vec<PlanEntry>,
}

impl Plan {
    fn push(&mut self, name: &'static str, rows: usize, cols: usize) {
        debug_assert!(
            self.entries.iter().all(|e| e.name != name),
            "plan: duplicate buffer '{name}'"
        );
        self.entries.push(PlanEntry { name, rows, cols });
    }

    /// The orthogonalization scratch set shared by every plan: Gram /
    /// Cholesky factors at the block width, projection coefficients up
    /// to history width `s_max`, and the breakdown snapshot at panel
    /// height `q_max`.
    fn push_orth(&mut self, q_max: usize, s_max: usize, b: usize) {
        let s_max = s_max.max(1);
        let b = b.max(1);
        self.push(names::ORTH_W, b, b);
        self.push(names::ORTH_L1, b, b);
        self.push(names::ORTH_L2, b, b);
        self.push(names::ORTH_R, b, b);
        self.push(names::ORTH_H, s_max, b);
        self.push(names::ORTH_HBAR, s_max, b);
        self.push(names::ORTH_SNAP, q_max.max(1), b);
    }

    /// Plan for one LancSVD solve (Alg. 2) on an m×n operand with Krylov
    /// width r, restart budget p, block width b.
    pub fn lancsvd(m: usize, n: usize, r: usize, p: usize, b: usize) -> Plan {
        let q_max = m.max(n);
        let mut plan = Plan { kind: PlanKind::LancSvd, m, n, r, p, b, entries: Vec::new() };
        plan.push_orth(q_max, r, b);
        plan.push(names::LANC_P, n, r);
        plan.push(names::LANC_PBAR, m, r);
        plan.push(names::LANC_B, r, r);
        plan.push(names::LANC_RK, b, b);
        plan.push(names::LANC_QBAR, m, b);
        plan.push(names::LANC_QNEXT, m, b);
        plan.push(names::LANC_G, b.max(1), b.max(1));
        plan.push(names::LANC_TMP, q_max, r);
        plan.push(names::SVD_U, r, r);
        plan.push(names::SVD_V, r, r);
        plan
    }

    /// Plan for one RandSVD solve (Alg. 1) on an m×n operand with sketch
    /// width r, power-iteration budget p, CGS-QR block width b.
    pub fn randsvd(m: usize, n: usize, r: usize, p: usize, b: usize) -> Plan {
        let q_max = m.max(n);
        let mut plan = Plan { kind: PlanKind::RandSvd, m, n, r, p, b, entries: Vec::new() };
        plan.push_orth(q_max, r, b.min(r.max(1)));
        plan.push(names::RAND_Q, n, r);
        plan.push(names::RAND_QBAR, m, r);
        plan.push(names::RAND_R, r, r);
        plan.push(names::RAND_Z, n, r);
        plan.push(names::SVD_U, r, r);
        plan.push(names::SVD_V, r, r);
        plan
    }

    /// Plan for the streaming incremental update (`algo::incremental`)
    /// on an m-row stream capped at `n` total columns, rank cap `r`,
    /// maximum block width `b`. The augmented-core buffers are sized at
    /// (r+b)² — the largest core a single update can assemble — and the
    /// right-factor scratch at the full stream capacity, so one
    /// workspace serves the stream from its first block to its last.
    pub fn incremental(m: usize, n: usize, r: usize, b: usize) -> Plan {
        let r = r.max(1);
        let b = b.max(1);
        let aug = r + b;
        let mut plan = Plan { kind: PlanKind::Incremental, m, n, r, p: 1, b, entries: Vec::new() };
        plan.push_orth(m.max(1), r, b);
        plan.push(names::INC_H, r, b);
        plan.push(names::INC_G, r, b);
        plan.push(names::INC_GRE, r, b);
        plan.push(names::INC_RE, b, b);
        plan.push(names::INC_T, b, b);
        plan.push(names::INC_TRE, b, b);
        plan.push(names::INC_EXT, m, aug);
        plan.push(names::INC_UNEW, m, r);
        plan.push(names::INC_CORE, aug, aug);
        plan.push(names::INC_CU, aug, aug);
        plan.push(names::INC_CV, aug, aug);
        plan.push(names::INC_VEXT, n.max(1), aug);
        plan.push(names::INC_VNEW, n.max(1), r);
        plan
    }

    /// Plan for standalone orthogonalization of rows×b panels against
    /// histories up to s_max columns (the thin value-returning wrappers).
    pub fn orth(rows: usize, s_max: usize, b: usize) -> Plan {
        let mut plan = Plan {
            kind: PlanKind::Orth,
            m: rows,
            n: rows,
            r: s_max.max(b),
            p: 1,
            b,
            entries: Vec::new(),
        };
        plan.push_orth(rows, s_max, b);
        plan
    }

    /// Declared shape of a named buffer, if the plan has it.
    pub fn shape_of(&self, name: &str) -> Option<(usize, usize)> {
        self.entries.iter().find(|e| e.name == name).map(|e| (e.rows, e.cols))
    }

    /// Iterate every planned buffer as `(name, rows, cols)` — the
    /// enumeration a device backend walks in [`plan`] staging
    /// (`crate::backend::Backend::plan`) to reserve arena space for
    /// exactly the shapes the solve will touch.
    pub fn entries(&self) -> impl Iterator<Item = (&'static str, usize, usize)> + '_ {
        self.entries.iter().map(|e| (e.name, e.rows, e.cols))
    }

    /// Total planned elements (diagnostics / memory budgeting).
    pub fn total_elems(&self) -> usize {
        self.entries.iter().map(|e| e.rows * e.cols).sum()
    }

    /// Validate that this plan covers a solve of the given kind and
    /// shape — the guard the `*_with` algorithm entry points run before
    /// reusing a caller-provided workspace.
    pub fn require(&self, kind: PlanKind, m: usize, n: usize, r: usize, b: usize) -> Result<()> {
        if self.kind != kind || self.m != m || self.n != n || self.r != r || self.b != b {
            return Err(Error::InvalidParam(format!(
                "workspace plan mismatch: plan is {:?} (m={}, n={}, r={}, b={}), \
                 solve needs {:?} (m={m}, n={n}, r={r}, b={b})",
                self.kind, self.m, self.n, self.r, self.b, kind
            )));
        }
        Ok(())
    }
}

/// Arena of named, shape-checked buffers backing one solve (see the
/// module docs for the plan lifecycle). Buffers sit behind `RefCell`s,
/// so a `&Workspace` can hand out disjoint mutable borrows while the
/// `&mut Backend` is live; double-borrowing one buffer panics.
pub struct Workspace<S: Scalar = f64> {
    plan: Plan,
    bufs: Vec<RefCell<Mat<S>>>,
}

impl<S: Scalar> Workspace<S> {
    /// Allocate every planned buffer with banded first-touch through the
    /// worker pool (see the module docs). Throwaway [`PlanKind::Orth`]
    /// arenas — built per call by the legacy value-returning wrappers —
    /// skip the pooled first-touch: their buffers are short-lived
    /// write-before-read scratch, so paying a pool dispatch to place
    /// their pages would be pure overhead.
    pub fn new(plan: Plan) -> Workspace<S> {
        let banded = !matches!(plan.kind, PlanKind::Orth);
        let bufs = plan
            .entries
            .iter()
            .map(|e| RefCell::new(first_touch_mat(e.rows, e.cols, banded)))
            .collect();
        Workspace { plan, bufs }
    }

    /// The plan this workspace was allocated for.
    pub fn plan(&self) -> &Plan {
        &self.plan
    }

    /// Total allocated elements across all buffers.
    pub fn total_elems(&self) -> usize {
        self.plan.total_elems()
    }

    fn index(&self, name: &str) -> usize {
        self.plan.entries.iter().position(|e| e.name == name).unwrap_or_else(|| {
            panic!("workspace: no buffer '{name}' in a {:?} plan", self.plan.kind)
        })
    }

    /// Borrow a buffer mutably by name, with no shape requirement (use
    /// [`Mat::view_mut`] on the result for sub-shape scratch views).
    /// Panics if the buffer is already borrowed — the aliasing guard.
    pub fn buf(&self, name: &str) -> RefMut<'_, Mat<S>> {
        let i = self.index(name);
        self.bufs[i].try_borrow_mut().unwrap_or_else(|_| {
            panic!("workspace: buffer '{name}' is already borrowed (aliasing rejected)")
        })
    }

    /// Borrow a buffer mutably by name, panicking unless its planned
    /// shape is exactly `rows`×`cols` — the shape-checked entry point
    /// the algorithms use for their full-size state buffers.
    pub fn mat(&self, name: &str, rows: usize, cols: usize) -> RefMut<'_, Mat<S>> {
        let b = self.buf(name);
        assert!(
            b.rows() == rows && b.cols() == cols,
            "workspace: buffer '{name}' is {}x{}, caller expects {rows}x{cols}",
            b.rows(),
            b.cols()
        );
        b
    }
}

/// Allocate a zeroed rows×cols matrix. With `banded` set, pages are
/// first-touched in page-aligned **row bands** on the pool workers —
/// the decomposition [`pool::parallel_row_blocks`] hands the gather
/// SpMM and the row-tiled SYRK, whose workers stream the same row range
/// of every column call after call — so each page of a worker's row
/// band is faulted (and on a first-touch NUMA host, placed) by that
/// worker. Column-group-partitioned GEMM outputs see a compromise
/// placement (their workers own columns, not rows); the row-banded
/// choice follows the paper's sparse hot path, where the SpMM stream is
/// the bandwidth that matters. Small buffers fall under the pool's
/// serial cutoff and are touched by the caller — they are
/// cache-resident anyway. Without `banded`, the caller zero-fills
/// directly (throwaway scratch arenas).
///
/// When worker pinning is on (`TRUNKSVD_PIN=core|node`) the banded
/// path switches from the work-estimated partition to
/// [`pool::first_touch_bounds`]: one page-aligned band per configured
/// worker, so small buffers can't collapse to the serial path and
/// leave all their pages on the caller's node.
fn first_touch_mat<S: Scalar>(rows: usize, cols: usize, banded: bool) -> Mat<S> {
    let len = rows * cols;
    let mut data: Vec<S> = Vec::with_capacity(len);
    {
        let spare = &mut data.spare_capacity_mut()[..len];
        let page_elems = (4096 / std::mem::size_of::<S>()).max(1);
        let zero_band = |_r0: usize, _r1: usize, cols: &mut [&mut [MaybeUninit<S>]]| {
            for col in cols.iter_mut() {
                for x in col.iter_mut() {
                    x.write(S::ZERO);
                }
            }
        };
        if banded && rows > 0 && pool::pin_level() != pool::PinLevel::Off {
            // Pinned workers: force exactly one page-aligned band per
            // worker regardless of the work estimate, so every page of
            // band `w` is faulted (NUMA first-touch placed) on the
            // worker pinned to band `w`'s node — the same worker the
            // banded kernels hand that row range to.
            let bounds = pool::first_touch_bounds(rows, page_elems);
            pool::parallel_row_blocks_bounds(spare, rows, &bounds, zero_band);
        } else if banded && rows > 0 {
            pool::parallel_row_blocks_work(spare, rows, page_elems, len, zero_band);
        } else {
            for x in spare.iter_mut() {
                x.write(S::ZERO);
            }
        }
    }
    // SAFETY: all `len` elements were initialized just above.
    unsafe { data.set_len(len) };
    Mat::from_vec(rows, cols, data).expect("first_touch_mat sized its buffer")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plan_lists_expected_buffers() {
        let plan = Plan::lancsvd(100, 40, 16, 4, 8);
        assert_eq!(plan.shape_of(names::LANC_P), Some((40, 16)));
        assert_eq!(plan.shape_of(names::LANC_PBAR), Some((100, 16)));
        assert_eq!(plan.shape_of(names::ORTH_SNAP), Some((100, 8)));
        assert_eq!(plan.shape_of(names::ORTH_H), Some((16, 8)));
        assert_eq!(plan.shape_of(names::LANC_G), Some((8, 8)));
        assert_eq!(plan.shape_of("nope"), None);
        assert!(plan.total_elems() > 0);

        let plan = Plan::randsvd(100, 40, 16, 4, 8);
        assert_eq!(plan.shape_of(names::RAND_Q), Some((40, 16)));
        assert_eq!(plan.shape_of(names::RAND_QBAR), Some((100, 16)));
        assert_eq!(plan.shape_of(names::RAND_R), Some((16, 16)));
        assert_eq!(plan.shape_of(names::RAND_Z), Some((40, 16)));

        let plan = Plan::incremental(100, 40, 12, 6);
        assert_eq!(plan.kind, PlanKind::Incremental);
        assert_eq!(plan.shape_of(names::INC_EXT), Some((100, 18)));
        assert_eq!(plan.shape_of(names::INC_CORE), Some((18, 18)));
        assert_eq!(plan.shape_of(names::INC_VEXT), Some((40, 18)));
        assert_eq!(plan.shape_of(names::INC_VNEW), Some((40, 12)));
        assert_eq!(plan.shape_of(names::INC_H), Some((12, 6)));
        assert_eq!(plan.shape_of(names::ORTH_SNAP), Some((100, 6)));
        assert!(plan.require(PlanKind::Incremental, 100, 40, 12, 6).is_ok());
        assert!(plan.require(PlanKind::Incremental, 100, 40, 12, 8).is_err());
    }

    #[test]
    fn entries_enumerate_every_buffer() {
        let plan = Plan::lancsvd(100, 40, 16, 4, 8);
        let entries: Vec<_> = plan.entries().collect();
        assert!(entries.iter().any(|&(n, r, c)| (n, r, c) == (names::LANC_P, 40, 16)));
        assert_eq!(
            entries.iter().map(|&(_, r, c)| r * c).sum::<usize>(),
            plan.total_elems(),
            "entries must cover the whole arena"
        );
        // Names are unique — a device arena can key on them.
        for (i, &(n, ..)) in entries.iter().enumerate() {
            assert!(entries[..i].iter().all(|&(m, ..)| m != n), "duplicate '{n}'");
        }
    }

    #[test]
    fn workspace_buffers_are_zeroed_and_shaped() {
        let ws: Workspace = Workspace::new(Plan::lancsvd(33, 21, 8, 2, 4));
        let b = ws.mat(names::LANC_B, 8, 8);
        assert_eq!(b.fro_norm(), 0.0);
        drop(b);
        let p = ws.mat(names::LANC_P, 21, 8);
        assert!(p.data().iter().all(|&x| x == 0.0));
    }

    #[test]
    fn require_matches_and_rejects() {
        let plan = Plan::lancsvd(50, 30, 16, 3, 8);
        assert!(plan.require(PlanKind::LancSvd, 50, 30, 16, 8).is_ok());
        assert!(plan.require(PlanKind::LancSvd, 50, 30, 16, 4).is_err());
        assert!(plan.require(PlanKind::RandSvd, 50, 30, 16, 8).is_err());
    }

    #[test]
    #[should_panic(expected = "aliasing rejected")]
    fn double_borrow_panics() {
        let ws: Workspace = Workspace::new(Plan::orth(32, 8, 4));
        let _a = ws.buf(names::ORTH_W);
        let _b = ws.buf(names::ORTH_W);
    }

    #[test]
    #[should_panic(expected = "caller expects")]
    fn shape_mismatch_panics() {
        let ws: Workspace = Workspace::new(Plan::orth(32, 8, 4));
        let _w = ws.mat(names::ORTH_W, 5, 5);
    }

    #[test]
    #[should_panic(expected = "no buffer")]
    fn unknown_name_panics() {
        let ws: Workspace = Workspace::new(Plan::orth(32, 8, 4));
        let _w = ws.buf(names::LANC_P);
    }

    #[test]
    fn first_touch_covers_large_buffers() {
        // Large enough to clear the pool's serial cutoff with threads > 1.
        for banded in [true, false] {
            let m = first_touch_mat::<f64>(4096, 64, banded);
            assert_eq!((m.rows(), m.cols()), (4096, 64));
            assert!(m.data().iter().all(|&x| x == 0.0), "banded={banded}");
        }
        // Degenerate shapes stay well-formed.
        let z = first_touch_mat::<f64>(0, 5, true);
        assert_eq!((z.rows(), z.cols()), (0, 5));
        let z = first_touch_mat::<f32>(7, 0, true);
        assert_eq!((z.rows(), z.cols()), (7, 0));
    }
}
