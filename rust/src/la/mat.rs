//! Column-major dense matrix type.
//!
//! Column-major is the natural layout for the paper's algorithms: every
//! building block (CGS projections, CholeskyQR, Lanczos bases) operates on
//! *column panels*, which are contiguous sub-slices in this layout, so
//! panel views are zero-copy.

use crate::error::{shape_err, Result};
use crate::util::rng::Rng;

/// Dense f64 matrix, column-major: element (i, j) is `data[j * rows + i]`.
#[derive(Clone, Debug, PartialEq)]
pub struct Mat {
    rows: usize,
    cols: usize,
    data: Vec<f64>,
}

impl Mat {
    /// Zero matrix of the given shape.
    pub fn zeros(rows: usize, cols: usize) -> Mat {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Identity (or rectangular identity) matrix.
    pub fn eye(n: usize) -> Mat {
        let mut m = Mat::zeros(n, n);
        for i in 0..n {
            m.data[i * n + i] = 1.0;
        }
        m
    }

    /// Build from a closure over (row, col).
    pub fn from_fn(rows: usize, cols: usize, f: impl Fn(usize, usize) -> f64) -> Mat {
        let mut data = Vec::with_capacity(rows * cols);
        for j in 0..cols {
            for i in 0..rows {
                data.push(f(i, j));
            }
        }
        Mat { rows, cols, data }
    }

    /// Wrap an existing column-major buffer.
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f64>) -> Result<Mat> {
        if data.len() != rows * cols {
            return Err(shape_err(
                "from_vec",
                format!("{}x{} needs {} elements, got {}", rows, cols, rows * cols, data.len()),
            ));
        }
        Ok(Mat { rows, cols, data })
    }

    /// Standard-normal random matrix.
    pub fn randn(rows: usize, cols: usize, rng: &mut Rng) -> Mat {
        let mut m = Mat::zeros(rows, cols);
        rng.fill_normal(&mut m.data);
        m
    }

    /// Centered-Poisson random matrix (paper's cuRAND init distribution).
    pub fn rand_centered_poisson(rows: usize, cols: usize, rng: &mut Rng) -> Mat {
        let mut m = Mat::zeros(rows, cols);
        rng.fill_centered_poisson(&mut m.data);
        m
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }
    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }
    #[inline]
    pub fn data(&self) -> &[f64] {
        &self.data
    }
    #[inline]
    pub fn data_mut(&mut self) -> &mut [f64] {
        &mut self.data
    }

    #[inline]
    pub fn at(&self, i: usize, j: usize) -> f64 {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[j * self.rows + i]
    }

    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: f64) {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[j * self.rows + i] = v;
    }

    #[inline]
    pub fn add_at(&mut self, i: usize, j: usize, v: f64) {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[j * self.rows + i] += v;
    }

    /// Contiguous view of column `j`.
    #[inline]
    pub fn col(&self, j: usize) -> &[f64] {
        &self.data[j * self.rows..(j + 1) * self.rows]
    }

    /// Mutable view of column `j`.
    #[inline]
    pub fn col_mut(&mut self, j: usize) -> &mut [f64] {
        &mut self.data[j * self.rows..(j + 1) * self.rows]
    }

    /// Zero-copy read view of the column panel [j0, j0+k).
    pub fn panel(&self, j0: usize, k: usize) -> MatRef<'_> {
        assert!(j0 + k <= self.cols, "panel out of range");
        MatRef {
            rows: self.rows,
            cols: k,
            data: &self.data[j0 * self.rows..(j0 + k) * self.rows],
        }
    }

    /// Zero-copy mutable view of the column panel [j0, j0+k).
    pub fn panel_mut(&mut self, j0: usize, k: usize) -> MatMut<'_> {
        assert!(j0 + k <= self.cols, "panel out of range");
        let rows = self.rows;
        MatMut {
            rows,
            cols: k,
            data: &mut self.data[j0 * rows..(j0 + k) * rows],
        }
    }

    /// Whole-matrix read view.
    pub fn as_ref(&self) -> MatRef<'_> {
        MatRef { rows: self.rows, cols: self.cols, data: &self.data }
    }

    /// Whole-matrix mutable view.
    pub fn as_mut(&mut self) -> MatMut<'_> {
        MatMut { rows: self.rows, cols: self.cols, data: &mut self.data }
    }

    /// Copy of the column panel [j0, j0+k) as an owned matrix.
    pub fn panel_owned(&self, j0: usize, k: usize) -> Mat {
        Mat {
            rows: self.rows,
            cols: k,
            data: self.panel(j0, k).data.to_vec(),
        }
    }

    /// Overwrite the column panel [j0, j0+k) from `src` (same rows).
    pub fn set_panel(&mut self, j0: usize, src: &Mat) {
        assert_eq!(self.rows, src.rows, "set_panel rows");
        assert!(j0 + src.cols <= self.cols, "set_panel range");
        let dst = &mut self.data[j0 * self.rows..(j0 + src.cols) * self.rows];
        dst.copy_from_slice(&src.data);
    }

    /// Explicit transpose (used by tests and small matrices only).
    pub fn transpose(&self) -> Mat {
        Mat::from_fn(self.cols, self.rows, |i, j| self.at(j, i))
    }

    /// Frobenius norm.
    pub fn fro_norm(&self) -> f64 {
        self.data.iter().map(|x| x * x).sum::<f64>().sqrt()
    }

    /// max |a_ij - b_ij|
    pub fn max_abs_diff(&self, other: &Mat) -> f64 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f64::max)
    }

    /// Horizontal concatenation [A | B].
    pub fn hcat(&self, other: &Mat) -> Mat {
        assert_eq!(self.rows, other.rows, "hcat rows");
        let mut data = Vec::with_capacity(self.data.len() + other.data.len());
        data.extend_from_slice(&self.data);
        data.extend_from_slice(&other.data);
        Mat { rows: self.rows, cols: self.cols + other.cols, data }
    }

    /// In-place scale.
    pub fn scale(&mut self, a: f64) {
        for x in &mut self.data {
            *x *= a;
        }
    }
}

/// Borrowed read-only column-major view (contiguous, leading dim == rows).
#[derive(Clone, Copy, Debug)]
pub struct MatRef<'a> {
    pub rows: usize,
    pub cols: usize,
    pub data: &'a [f64],
}

impl<'a> MatRef<'a> {
    #[inline]
    pub fn at(&self, i: usize, j: usize) -> f64 {
        self.data[j * self.rows + i]
    }
    #[inline]
    pub fn col(&self, j: usize) -> &[f64] {
        &self.data[j * self.rows..(j + 1) * self.rows]
    }
    pub fn to_owned(&self) -> Mat {
        Mat { rows: self.rows, cols: self.cols, data: self.data.to_vec() }
    }
    /// Sub-panel of this view.
    pub fn panel(&self, j0: usize, k: usize) -> MatRef<'a> {
        assert!(j0 + k <= self.cols);
        MatRef {
            rows: self.rows,
            cols: k,
            data: &self.data[j0 * self.rows..(j0 + k) * self.rows],
        }
    }
}

/// Borrowed mutable column-major view (contiguous, leading dim == rows).
#[derive(Debug)]
pub struct MatMut<'a> {
    pub rows: usize,
    pub cols: usize,
    pub data: &'a mut [f64],
}

impl<'a> MatMut<'a> {
    #[inline]
    pub fn at(&self, i: usize, j: usize) -> f64 {
        self.data[j * self.rows + i]
    }
    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: f64) {
        self.data[j * self.rows + i] = v;
    }
    #[inline]
    pub fn col_mut(&mut self, j: usize) -> &mut [f64] {
        &mut self.data[j * self.rows..(j + 1) * self.rows]
    }
    pub fn as_ref(&self) -> MatRef<'_> {
        MatRef { rows: self.rows, cols: self.cols, data: self.data }
    }
    pub fn reborrow(&mut self) -> MatMut<'_> {
        MatMut { rows: self.rows, cols: self.cols, data: self.data }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn layout_is_column_major() {
        let m = Mat::from_fn(2, 3, |i, j| (10 * i + j) as f64);
        assert_eq!(m.data(), &[0.0, 10.0, 1.0, 11.0, 2.0, 12.0]);
        assert_eq!(m.at(1, 2), 12.0);
    }

    #[test]
    fn panel_views_are_contiguous() {
        let m = Mat::from_fn(3, 4, |i, j| (j * 3 + i) as f64);
        let p = m.panel(1, 2);
        assert_eq!(p.rows, 3);
        assert_eq!(p.cols, 2);
        assert_eq!(p.data, &[3.0, 4.0, 5.0, 6.0, 7.0, 8.0]);
        assert_eq!(p.at(2, 1), 8.0);
    }

    #[test]
    fn set_panel_roundtrip() {
        let mut m = Mat::zeros(3, 4);
        let src = Mat::from_fn(3, 2, |i, j| 1.0 + (i + j) as f64);
        m.set_panel(2, &src);
        assert_eq!(m.panel_owned(2, 2), src);
        assert_eq!(m.at(0, 0), 0.0);
    }

    #[test]
    fn transpose_and_eye() {
        let m = Mat::from_fn(2, 3, |i, j| (i * 3 + j) as f64);
        let t = m.transpose();
        assert_eq!(t.at(2, 1), m.at(1, 2));
        let i3 = Mat::eye(3);
        assert_eq!(i3.at(1, 1), 1.0);
        assert_eq!(i3.at(0, 1), 0.0);
    }

    #[test]
    fn hcat_shapes() {
        let a = Mat::zeros(3, 2);
        let b = Mat::from_fn(3, 1, |_, _| 5.0);
        let c = a.hcat(&b);
        assert_eq!((c.rows(), c.cols()), (3, 3));
        assert_eq!(c.at(2, 2), 5.0);
    }

    #[test]
    fn from_vec_checks_len() {
        assert!(Mat::from_vec(2, 2, vec![0.0; 3]).is_err());
        assert!(Mat::from_vec(2, 2, vec![0.0; 4]).is_ok());
    }
}
