//! Column-major dense matrix type, generic over the element precision.
//!
//! Column-major is the natural layout for the paper's algorithms: every
//! building block (CGS projections, CholeskyQR, Lanczos bases) operates on
//! *column panels*, which are contiguous sub-slices in this layout, so
//! panel views are zero-copy.
//!
//! ## The `Scalar` abstraction
//!
//! [`Mat<S>`] is generic over [`Scalar`] (`f32` or `f64`) with **`f64` as
//! the default type parameter**, so `Mat` written bare in type positions
//! means `Mat<f64>` and the f64-only layers (the XLA backend, MatrixMarket
//! I/O defaults, the generators) compile unchanged. The GPU experiments in
//! the paper run in single precision; the fp32 instantiation halves the
//! element width of every memory-bound kernel (SpMM, SYRK, CholeskyQR2)
//! and is selected at runtime via `--dtype f32` (see
//! `coordinator::driver`). Precision boundaries:
//!
//! * element data is `S`; shapes/indices stay `usize`/`u32`;
//! * norms and diagnostics return `S` (callers converting into reports go
//!   through `Scalar::to_f64`);
//! * [`Mat::cast`] is the explicit dtype conversion (rounds via f64).

use crate::error::{shape_err, Result};
use crate::util::rng::Rng;
use crate::util::scalar::Scalar;

/// Dense matrix, column-major: element (i, j) is `data[j * rows + i]`.
/// `S` is the element precision (default `f64`).
#[derive(Clone, Debug, PartialEq)]
pub struct Mat<S: Scalar = f64> {
    rows: usize,
    cols: usize,
    data: Vec<S>,
}

impl<S: Scalar> Mat<S> {
    /// Zero matrix of the given shape.
    pub fn zeros(rows: usize, cols: usize) -> Mat<S> {
        Mat { rows, cols, data: vec![S::ZERO; rows * cols] }
    }

    /// Identity (or rectangular identity) matrix.
    pub fn eye(n: usize) -> Mat<S> {
        let mut m = Mat::zeros(n, n);
        for i in 0..n {
            m.data[i * n + i] = S::ONE;
        }
        m
    }

    /// Build from a closure over (row, col).
    pub fn from_fn(rows: usize, cols: usize, f: impl Fn(usize, usize) -> S) -> Mat<S> {
        let mut data = Vec::with_capacity(rows * cols);
        for j in 0..cols {
            for i in 0..rows {
                data.push(f(i, j));
            }
        }
        Mat { rows, cols, data }
    }

    /// Wrap an existing column-major buffer.
    pub fn from_vec(rows: usize, cols: usize, data: Vec<S>) -> Result<Mat<S>> {
        if data.len() != rows * cols {
            return Err(shape_err(
                "from_vec",
                format!("{}x{} needs {} elements, got {}", rows, cols, rows * cols, data.len()),
            ));
        }
        Ok(Mat { rows, cols, data })
    }

    /// Standard-normal random matrix (drawn from the shared f64 stream
    /// and rounded to `S`; see `Rng::fill_normal`).
    pub fn randn(rows: usize, cols: usize, rng: &mut Rng) -> Mat<S> {
        let mut m = Mat::zeros(rows, cols);
        rng.fill_normal(&mut m.data);
        m
    }

    /// Centered-Poisson random matrix (paper's cuRAND init distribution).
    pub fn rand_centered_poisson(rows: usize, cols: usize, rng: &mut Rng) -> Mat<S> {
        let mut m = Mat::zeros(rows, cols);
        rng.fill_centered_poisson(&mut m.data);
        m
    }

    /// Copy into another element precision (values round through f64).
    pub fn cast<T: Scalar>(&self) -> Mat<T> {
        Mat {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().map(|&v| T::from_f64(v.to_f64())).collect(),
        }
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }
    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }
    #[inline]
    pub fn data(&self) -> &[S] {
        &self.data
    }
    #[inline]
    pub fn data_mut(&mut self) -> &mut [S] {
        &mut self.data
    }

    #[inline]
    pub fn at(&self, i: usize, j: usize) -> S {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[j * self.rows + i]
    }

    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: S) {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[j * self.rows + i] = v;
    }

    #[inline]
    pub fn add_at(&mut self, i: usize, j: usize, v: S) {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[j * self.rows + i] += v;
    }

    /// Contiguous view of column `j`.
    #[inline]
    pub fn col(&self, j: usize) -> &[S] {
        &self.data[j * self.rows..(j + 1) * self.rows]
    }

    /// Mutable view of column `j`.
    #[inline]
    pub fn col_mut(&mut self, j: usize) -> &mut [S] {
        &mut self.data[j * self.rows..(j + 1) * self.rows]
    }

    /// Zero-copy read view of the column panel [j0, j0+k).
    pub fn panel(&self, j0: usize, k: usize) -> MatRef<'_, S> {
        assert!(j0 + k <= self.cols, "panel out of range");
        MatRef {
            rows: self.rows,
            cols: k,
            data: &self.data[j0 * self.rows..(j0 + k) * self.rows],
        }
    }

    /// Zero-copy mutable view of the column panel [j0, j0+k).
    pub fn panel_mut(&mut self, j0: usize, k: usize) -> MatMut<'_, S> {
        assert!(j0 + k <= self.cols, "panel out of range");
        let rows = self.rows;
        MatMut {
            rows,
            cols: k,
            data: &mut self.data[j0 * rows..(j0 + k) * rows],
        }
    }

    /// Split at column `j` into a read view of columns [0, j) and a
    /// mutable view of columns [j, cols). The workhorse of the
    /// allocation-free algorithm loops: orthogonalize the current block
    /// *in place inside the basis panel* against the already-built
    /// history without copying either out.
    pub fn split_at_col(&mut self, j: usize) -> (MatRef<'_, S>, MatMut<'_, S>) {
        assert!(j <= self.cols, "split_at_col out of range");
        let rows = self.rows;
        let (head, tail) = self.data.split_at_mut(j * rows);
        (
            MatRef { rows, cols: j, data: head },
            MatMut { rows, cols: self.cols - j, data: tail },
        )
    }

    /// Reinterpret the leading rows·cols elements of this matrix's
    /// storage as a rows×cols column-major view. Workspace buffers are
    /// planned at their capacity shape and viewed at the live shape
    /// (e.g. the s×b projection block inside an r×b scratch buffer).
    pub fn view_mut(&mut self, rows: usize, cols: usize) -> MatMut<'_, S> {
        assert!(
            rows * cols <= self.data.len(),
            "view_mut {}x{} exceeds buffer capacity {}",
            rows,
            cols,
            self.data.len()
        );
        MatMut { rows, cols, data: &mut self.data[..rows * cols] }
    }

    /// Whole-matrix read view.
    pub fn as_ref(&self) -> MatRef<'_, S> {
        MatRef { rows: self.rows, cols: self.cols, data: &self.data }
    }

    /// Whole-matrix mutable view.
    pub fn as_mut(&mut self) -> MatMut<'_, S> {
        MatMut { rows: self.rows, cols: self.cols, data: &mut self.data }
    }

    /// Copy of the column panel [j0, j0+k) as an owned matrix.
    pub fn panel_owned(&self, j0: usize, k: usize) -> Mat<S> {
        Mat {
            rows: self.rows,
            cols: k,
            data: self.panel(j0, k).data.to_vec(),
        }
    }

    /// Overwrite the column panel [j0, j0+k) from `src` (same rows).
    pub fn set_panel(&mut self, j0: usize, src: &Mat<S>) {
        self.set_panel_ref(j0, src.as_ref());
    }

    /// [`Mat::set_panel`] from a borrowed view (no owned source needed).
    pub fn set_panel_ref(&mut self, j0: usize, src: MatRef<'_, S>) {
        assert_eq!(self.rows, src.rows, "set_panel rows");
        assert!(j0 + src.cols <= self.cols, "set_panel range");
        let dst = &mut self.data[j0 * self.rows..(j0 + src.cols) * self.rows];
        dst.copy_from_slice(src.data);
    }

    /// Explicit transpose (used by tests and small matrices only).
    pub fn transpose(&self) -> Mat<S> {
        Mat::from_fn(self.cols, self.rows, |i, j| self.at(j, i))
    }

    /// Frobenius norm.
    pub fn fro_norm(&self) -> S {
        self.data.iter().map(|x| *x * *x).sum::<S>().sqrt()
    }

    /// max |a_ij - b_ij|
    pub fn max_abs_diff(&self, other: &Mat<S>) -> S {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (*a - *b).abs())
            .fold(S::ZERO, S::max)
    }

    /// Horizontal concatenation [A | B].
    pub fn hcat(&self, other: &Mat<S>) -> Mat<S> {
        assert_eq!(self.rows, other.rows, "hcat rows");
        let mut data = Vec::with_capacity(self.data.len() + other.data.len());
        data.extend_from_slice(&self.data);
        data.extend_from_slice(&other.data);
        Mat { rows: self.rows, cols: self.cols + other.cols, data }
    }

    /// In-place scale.
    pub fn scale(&mut self, a: S) {
        for x in &mut self.data {
            *x *= a;
        }
    }
}

/// Borrowed read-only column-major view (contiguous, leading dim == rows).
#[derive(Clone, Copy, Debug)]
pub struct MatRef<'a, S: Scalar = f64> {
    pub rows: usize,
    pub cols: usize,
    pub data: &'a [S],
}

impl<'a, S: Scalar> MatRef<'a, S> {
    #[inline]
    pub fn at(&self, i: usize, j: usize) -> S {
        self.data[j * self.rows + i]
    }
    #[inline]
    pub fn col(&self, j: usize) -> &[S] {
        &self.data[j * self.rows..(j + 1) * self.rows]
    }
    pub fn to_owned(&self) -> Mat<S> {
        Mat { rows: self.rows, cols: self.cols, data: self.data.to_vec() }
    }
    /// Sub-panel of this view.
    pub fn panel(&self, j0: usize, k: usize) -> MatRef<'a, S> {
        assert!(j0 + k <= self.cols);
        MatRef {
            rows: self.rows,
            cols: k,
            data: &self.data[j0 * self.rows..(j0 + k) * self.rows],
        }
    }
}

/// Borrowed mutable column-major view (contiguous, leading dim == rows).
#[derive(Debug)]
pub struct MatMut<'a, S: Scalar = f64> {
    pub rows: usize,
    pub cols: usize,
    pub data: &'a mut [S],
}

impl<'a, S: Scalar> MatMut<'a, S> {
    #[inline]
    pub fn at(&self, i: usize, j: usize) -> S {
        self.data[j * self.rows + i]
    }
    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: S) {
        self.data[j * self.rows + i] = v;
    }
    #[inline]
    pub fn col(&self, j: usize) -> &[S] {
        &self.data[j * self.rows..(j + 1) * self.rows]
    }
    #[inline]
    pub fn col_mut(&mut self, j: usize) -> &mut [S] {
        &mut self.data[j * self.rows..(j + 1) * self.rows]
    }
    /// Disjoint (read col `i`, write col `j`) pair, `i != j` — lets the
    /// CGS fallbacks project one column out of another without copying
    /// the source column to satisfy the borrow checker.
    pub fn col_pair_mut(&mut self, i: usize, j: usize) -> (&[S], &mut [S]) {
        assert!(i != j, "col_pair_mut needs distinct columns");
        let rows = self.rows;
        if i < j {
            let (head, tail) = self.data.split_at_mut(j * rows);
            (&head[i * rows..(i + 1) * rows], &mut tail[..rows])
        } else {
            // Order of returns is (read, write) regardless of layout.
            let (head, tail) = self.data.split_at_mut(i * rows);
            (&tail[..rows], &mut head[j * rows..(j + 1) * rows])
        }
    }
    /// Mutable sub-panel [j0, j0+k) of this view.
    pub fn panel_mut(&mut self, j0: usize, k: usize) -> MatMut<'_, S> {
        assert!(j0 + k <= self.cols, "panel_mut out of range");
        let rows = self.rows;
        MatMut { rows, cols: k, data: &mut self.data[j0 * rows..(j0 + k) * rows] }
    }
    /// Split at column `j`: (read view of [0, j), mut view of [j, cols)).
    pub fn split_at_col(&mut self, j: usize) -> (MatRef<'_, S>, MatMut<'_, S>) {
        assert!(j <= self.cols, "split_at_col out of range");
        let rows = self.rows;
        let cols = self.cols;
        let (head, tail) = self.data.split_at_mut(j * rows);
        (MatRef { rows, cols: j, data: head }, MatMut { rows, cols: cols - j, data: tail })
    }
    /// Fill every element with `v`.
    pub fn fill(&mut self, v: S) {
        self.data.fill(v);
    }
    pub fn as_ref(&self) -> MatRef<'_, S> {
        MatRef { rows: self.rows, cols: self.cols, data: self.data }
    }
    pub fn reborrow(&mut self) -> MatMut<'_, S> {
        MatMut { rows: self.rows, cols: self.cols, data: self.data }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn layout_is_column_major() {
        let m = Mat::from_fn(2, 3, |i, j| (10 * i + j) as f64);
        assert_eq!(m.data(), &[0.0, 10.0, 1.0, 11.0, 2.0, 12.0]);
        assert_eq!(m.at(1, 2), 12.0);
    }

    #[test]
    fn panel_views_are_contiguous() {
        let m = Mat::from_fn(3, 4, |i, j| (j * 3 + i) as f64);
        let p = m.panel(1, 2);
        assert_eq!(p.rows, 3);
        assert_eq!(p.cols, 2);
        assert_eq!(p.data, &[3.0, 4.0, 5.0, 6.0, 7.0, 8.0]);
        assert_eq!(p.at(2, 1), 8.0);
    }

    #[test]
    fn set_panel_roundtrip() {
        let mut m = Mat::<f64>::zeros(3, 4);
        let src = Mat::from_fn(3, 2, |i, j| 1.0 + (i + j) as f64);
        m.set_panel(2, &src);
        assert_eq!(m.panel_owned(2, 2), src);
        assert_eq!(m.at(0, 0), 0.0);
    }

    #[test]
    fn transpose_and_eye() {
        let m = Mat::from_fn(2, 3, |i, j| (i * 3 + j) as f64);
        let t = m.transpose();
        assert_eq!(t.at(2, 1), m.at(1, 2));
        let i3 = Mat::<f64>::eye(3);
        assert_eq!(i3.at(1, 1), 1.0);
        assert_eq!(i3.at(0, 1), 0.0);
    }

    #[test]
    fn hcat_shapes() {
        let a = Mat::<f64>::zeros(3, 2);
        let b = Mat::from_fn(3, 1, |_, _| 5.0);
        let c = a.hcat(&b);
        assert_eq!((c.rows(), c.cols()), (3, 3));
        assert_eq!(c.at(2, 2), 5.0);
    }

    #[test]
    fn from_vec_checks_len() {
        assert!(Mat::from_vec(2, 2, vec![0.0f64; 3]).is_err());
        assert!(Mat::from_vec(2, 2, vec![0.0f64; 4]).is_ok());
    }

    #[test]
    fn f32_instantiation_and_cast() {
        let m64 = Mat::from_fn(3, 2, |i, j| (i as f64 + 0.5) * (j as f64 + 1.0));
        let m32: Mat<f32> = m64.cast();
        assert_eq!((m32.rows(), m32.cols()), (3, 2));
        for j in 0..2 {
            for i in 0..3 {
                assert_eq!(m32.at(i, j), m64.at(i, j) as f32, "({i},{j})");
            }
        }
        // Round-trip back to f64 carries only f32 rounding.
        let back: Mat<f64> = m32.cast();
        assert!(back.max_abs_diff(&m64) <= f32::EPSILON as f64 * 4.0);
        // Basic ops work at f32.
        let z = Mat::<f32>::zeros(4, 4);
        assert_eq!(z.fro_norm(), 0.0f32);
        let e = Mat::<f32>::eye(2);
        assert_eq!(e.at(0, 0), 1.0f32);
    }
}
